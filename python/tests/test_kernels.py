"""L1 kernel correctness: Pallas kernels vs the pure-numpy oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fastscan as fs
from compile.kernels import lut as lutk
from compile.kernels import ref


def _random_problem(rng, n, m, q, d_sub=4):
    codes = rng.integers(0, fs.KSUB, size=(n, m), dtype=np.int32)
    qluts = rng.integers(0, 256, size=(q, m * fs.KSUB), dtype=np.int32)
    return codes, qluts


class TestFastScanKernel:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(1)
        codes, qluts = _random_problem(rng, n=fs.BLOCK_N * 2, m=16, q=8)
        got = np.asarray(fs.fastscan(jnp.asarray(codes), jnp.asarray(qluts)))
        expect = ref.ref_fastscan(codes, qluts.reshape(8, 16, fs.KSUB).astype(np.uint8))
        np.testing.assert_array_equal(got, expect)

    def test_single_block(self):
        rng = np.random.default_rng(2)
        codes, qluts = _random_problem(rng, n=fs.BLOCK_N, m=4, q=1)
        got = np.asarray(fs.fastscan(jnp.asarray(codes), jnp.asarray(qluts)))
        expect = ref.ref_fastscan(codes, qluts.reshape(1, 4, fs.KSUB).astype(np.uint8))
        np.testing.assert_array_equal(got, expect)

    def test_rejects_unaligned_n(self):
        rng = np.random.default_rng(3)
        codes, qluts = _random_problem(rng, n=100, m=4, q=1)
        with pytest.raises(AssertionError):
            fs.fastscan(jnp.asarray(codes), jnp.asarray(qluts))

    def test_extreme_values(self):
        # all codes point at the max table entry: acc = m * 255
        m, q = 8, 2
        codes = np.full((fs.BLOCK_N, m), 7, dtype=np.int32)
        qluts = np.zeros((q, m * fs.KSUB), dtype=np.int32)
        qluts[:, 7::fs.KSUB] = 255
        got = np.asarray(fs.fastscan(jnp.asarray(codes), jnp.asarray(qluts)))
        assert (got == m * 255).all()

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=32),
        q=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_shapes(self, m, q, seed):
        rng = np.random.default_rng(seed)
        codes, qluts = _random_problem(rng, n=fs.BLOCK_N, m=m, q=q)
        got = np.asarray(fs.fastscan(jnp.asarray(codes), jnp.asarray(qluts)))
        expect = ref.ref_fastscan(codes, qluts.reshape(q, m, fs.KSUB).astype(np.uint8))
        np.testing.assert_array_equal(got, expect)

    def test_vmem_estimate_within_budget(self):
        # structural perf check recorded in DESIGN.md §Perf
        assert fs.vmem_bytes_estimate(m=16, q=8) < 16 * 2**20


class TestLutKernel:
    def test_matches_ref(self):
        rng = np.random.default_rng(4)
        q, m, dsub = lutk.BLOCK_Q, 8, 4
        queries = rng.normal(size=(q, m * dsub)).astype(np.float32)
        codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
        got = np.asarray(lutk.build_luts(jnp.asarray(queries), jnp.asarray(codebooks)))
        expect = ref.ref_luts(queries, codebooks).reshape(q, m * fs.KSUB)
        np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)

    def test_multi_block(self):
        rng = np.random.default_rng(5)
        q, m, dsub = lutk.BLOCK_Q * 3, 4, 8
        queries = rng.normal(size=(q, m * dsub)).astype(np.float32)
        codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
        got = np.asarray(lutk.build_luts(jnp.asarray(queries), jnp.asarray(codebooks)))
        expect = ref.ref_luts(queries, codebooks).reshape(q, m * fs.KSUB)
        np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)

    def test_zero_distance_at_codeword(self):
        # a query equal to codeword (m, k) must have T[m, k] == 0
        rng = np.random.default_rng(6)
        m, dsub = 4, 4
        codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
        query = codebooks[:, 3, :].reshape(1, m * dsub)  # pick k=3 from each m
        queries = np.repeat(query, lutk.BLOCK_Q, axis=0).astype(np.float32)
        luts = np.asarray(
            lutk.build_luts(jnp.asarray(queries), jnp.asarray(codebooks))
        ).reshape(lutk.BLOCK_Q, m, fs.KSUB)
        np.testing.assert_allclose(luts[:, np.arange(m), 3], 0.0, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 4, 8, 16]),
        dsub=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_shapes(self, m, dsub, seed):
        rng = np.random.default_rng(seed)
        queries = rng.normal(size=(lutk.BLOCK_Q, m * dsub)).astype(np.float32)
        codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
        got = np.asarray(lutk.build_luts(jnp.asarray(queries), jnp.asarray(codebooks)))
        expect = ref.ref_luts(queries, codebooks).reshape(lutk.BLOCK_Q, m * fs.KSUB)
        np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-4)


class TestRefInternalConsistency:
    """The oracle itself must satisfy the analytic identities."""

    def test_quantize_bounds(self):
        rng = np.random.default_rng(7)
        luts = rng.uniform(1.0, 9.0, size=(3, 8, fs.KSUB)).astype(np.float32)
        qluts, delta, bias = ref.ref_quantize(luts)
        assert qluts.dtype == np.uint8
        # per-row min is 0; global max is 255
        assert (qluts.min(axis=2) == 0).all()
        assert qluts.max() == 255
        # decode error bounded by M * delta / 2 per accumulation
        codes = rng.integers(0, fs.KSUB, size=(50, 8))
        acc = ref.ref_fastscan(codes, qluts)
        dec = ref.ref_decode(acc, delta, bias)
        exact = ref.ref_adc_exact(codes, luts)
        bound = 0.5 * delta * 8 + 1e-4
        assert (np.abs(dec - exact) <= bound[None, :] + 1e-3).all()

    def test_adc_exact_equals_norm(self):
        rng = np.random.default_rng(8)
        m, dsub = 4, 4
        codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
        queries = rng.normal(size=(2, m * dsub)).astype(np.float32)
        codes = rng.integers(0, fs.KSUB, size=(10, m))
        luts = ref.ref_luts(queries, codebooks)
        d = ref.ref_adc_exact(codes, luts)
        # reconstruct and verify
        for n in range(10):
            rec = np.concatenate([codebooks[mm, codes[n, mm]] for mm in range(m)])
            for q in range(2):
                direct = np.sum((queries[q] - rec) ** 2)
                np.testing.assert_allclose(d[n, q], direct, rtol=1e-4)

    def test_search_returns_sorted(self):
        rng = np.random.default_rng(9)
        m, dsub = 4, 4
        codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
        queries = rng.normal(size=(4, m * dsub)).astype(np.float32)
        codes = rng.integers(0, fs.KSUB, size=(128, m))
        d, idx = ref.ref_search(queries, codes, codebooks, k=5)
        assert d.shape == (4, 5) and idx.shape == (4, 5)
        assert (np.diff(d, axis=1) >= -1e-6).all()
        assert ((idx >= 0) & (idx < 128)).all()
