"""L2 model correctness: the composed pipeline vs the oracle, plus the
AOT lowering path (HLO text generation) on a small variant."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import fastscan as fs
from compile.kernels import lut as lutk
from compile.kernels import ref


def _problem(seed, q=lutk.BLOCK_Q, n=fs.BLOCK_N, m=8, dsub=4):
    rng = np.random.default_rng(seed)
    queries = rng.normal(size=(q, m * dsub)).astype(np.float32)
    codebooks = rng.normal(size=(m, fs.KSUB, dsub)).astype(np.float32)
    codes = rng.integers(0, fs.KSUB, size=(n, m), dtype=np.int32)
    return queries, codes, codebooks


class TestQuantizeLuts:
    def test_matches_ref(self):
        rng = np.random.default_rng(11)
        luts = rng.uniform(0.5, 7.0, size=(4, 8 * fs.KSUB)).astype(np.float32)
        q_got, d_got, b_got = model.quantize_luts(jnp.asarray(luts))
        q_exp, d_exp, b_exp = ref.ref_quantize(luts.reshape(4, 8, fs.KSUB))
        np.testing.assert_array_equal(
            np.asarray(q_got).reshape(4, 8, fs.KSUB), q_exp.astype(np.int32)
        )
        np.testing.assert_allclose(np.asarray(d_got), d_exp, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(b_got), b_exp, rtol=1e-5, atol=1e-5)

    def test_constant_tables(self):
        luts = np.full((2, 4 * fs.KSUB), 3.0, dtype=np.float32)
        q, d, b = model.quantize_luts(jnp.asarray(luts))
        assert (np.asarray(q) == 0).all()
        np.testing.assert_allclose(np.asarray(d), 1.0)
        np.testing.assert_allclose(np.asarray(b), 12.0)  # 4 tables × 3.0


class TestPqSearch:
    def test_pipeline_matches_oracle(self):
        queries, codes, codebooks = _problem(12)
        d_got, i_got = model.pq_search(
            jnp.asarray(queries), jnp.asarray(codes), jnp.asarray(codebooks), k=10
        )
        # oracle: quantized top-k, then compare *quantized decode* ordering
        luts = ref.ref_luts(queries, codebooks)
        qluts, delta, bias = ref.ref_quantize(luts)
        acc = ref.ref_fastscan(codes, qluts)
        dec = ref.ref_decode(acc, delta, bias).T  # (Q, N)
        i_got = np.asarray(i_got)
        d_got = np.asarray(d_got)
        for q in range(queries.shape[0]):
            kth = np.sort(dec[q])[9]
            # every returned candidate is within the quantized top-k set
            assert (dec[q][i_got[q]] <= kth + 1e-4).all()
            # decoded distances match the oracle's decode for those ids
            np.testing.assert_allclose(d_got[q], dec[q][i_got[q]], rtol=1e-5, atol=1e-4)

    def test_self_query_found(self):
        # a query equal to the reconstruction of code row 7 must rank it first
        queries, codes, codebooks = _problem(13, m=4, dsub=8)
        rec = np.concatenate([codebooks[m, codes[7, m]] for m in range(4)])
        queries[0] = rec
        d, i = model.pq_search(
            jnp.asarray(queries), jnp.asarray(codes), jnp.asarray(codebooks), k=5
        )
        i = np.asarray(i)
        d = np.asarray(d)
        # row 7 (or an identical-code row) at distance ~0
        assert d[0, 0] < 1e-3, d[0]
        got_codes = codes[i[0, 0]]
        np.testing.assert_array_equal(got_codes, codes[7])

    @settings(max_examples=8, deadline=None)
    @given(
        m=st.sampled_from([2, 4, 8, 16]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_pipeline_decode_error(self, m, seed):
        # decoded top-1 distance within quantization bound of exact ADC best
        queries, codes, codebooks = _problem(seed, m=m, dsub=2)
        d, i = model.pq_search(
            jnp.asarray(queries), jnp.asarray(codes), jnp.asarray(codebooks), k=1
        )
        luts = ref.ref_luts(queries, codebooks)
        exact = ref.ref_adc_exact(codes, luts).T  # (Q, N)
        _, delta, _ = ref.ref_quantize(luts)
        bound = delta * m + 1e-3  # decode err (M·Δ/2) + selection err (M·Δ/2)
        best = exact.min(axis=1)
        assert (np.asarray(d)[:, 0] <= best + bound).all()


class TestAotLowering:
    """The HLO-text bridge must lower cleanly (small variant, in-process)."""

    def test_search_lowering_produces_hlo_text(self):
        from compile import aot

        cfg = dict(q=lutk.BLOCK_Q, n=fs.BLOCK_N, d=32, m=8, k=5)
        name, lowered, meta = aot.export_search(cfg)
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert name == f"search_q{cfg['q']}_n{cfg['n']}_d32_m8_k5"
        assert meta["outputs"][0]["shape"] == [cfg["q"], 5]

    def test_fastscan_lowering(self):
        from compile import aot

        name, lowered, meta = aot.export_fastscan(dict(q=2, n=fs.BLOCK_N, m=4))
        text = aot.to_hlo_text(lowered)
        assert "HloModule" in text
        assert meta["kind"] == "fastscan"

    def test_lowered_module_executes_like_eager(self):
        # compile the lowered module in-process and compare to eager
        queries, codes, codebooks = _problem(14, m=4, dsub=8)
        fn = jax.jit(lambda a, b, c: model.pq_search(a, b, c, k=3))
        lowered = fn.lower(
            jax.ShapeDtypeStruct(queries.shape, jnp.float32),
            jax.ShapeDtypeStruct(codes.shape, jnp.int32),
            jax.ShapeDtypeStruct(codebooks.shape, jnp.float32),
        )
        compiled = lowered.compile()
        d1, i1 = compiled(
            jnp.asarray(queries), jnp.asarray(codes), jnp.asarray(codebooks)
        )
        d2, i2 = model.pq_search(
            jnp.asarray(queries), jnp.asarray(codes), jnp.asarray(codebooks), k=3
        )
        np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
