"""AOT export: lower the L2 model to HLO text for the rust PJRT runtime.

Interchange format is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Produces ``artifacts/<name>.hlo.txt`` plus ``artifacts/manifest.json``
describing every artifact's inputs/outputs so the rust side can
shape-check at load time.

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fastscan as fs
from .kernels import lut as lutk

# Exported configurations. Shapes are fixed at AOT time (one executable per
# variant, like any serving system); the rust coordinator pads batches up.
#   Q: query batch; N: codes per scan unit; D: dim; M: sub-quantizers.
SEARCH_CONFIGS = [
    dict(q=8, n=4096, d=64, m=16, k=10),
    dict(q=8, n=4096, d=128, m=16, k=10),
]
FASTSCAN_CONFIGS = [
    dict(q=8, n=4096, m=16),
]
LUT_CONFIGS = [
    dict(q=8, d=64, m=16),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_search(cfg):
    q, n, d, m, k = cfg["q"], cfg["n"], cfg["d"], cfg["m"], cfg["k"]
    dsub = d // m
    fn = functools.partial(model.pq_search, k=k)
    lowered = jax.jit(fn).lower(
        _spec((q, d), jnp.float32),
        _spec((n, m), jnp.int32),
        _spec((m, fs.KSUB, dsub), jnp.float32),
    )
    name = f"search_q{q}_n{n}_d{d}_m{m}_k{k}"
    return name, lowered, {
        "kind": "search",
        "inputs": [
            {"name": "queries", "shape": [q, d], "dtype": "f32"},
            {"name": "codes", "shape": [n, m], "dtype": "i32"},
            {"name": "codebooks", "shape": [m, fs.KSUB, dsub], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "distances", "shape": [q, k], "dtype": "f32"},
            {"name": "labels", "shape": [q, k], "dtype": "i32"},
        ],
        **cfg,
    }


def export_fastscan(cfg):
    q, n, m = cfg["q"], cfg["n"], cfg["m"]
    lowered = jax.jit(lambda c, t: (model.fastscan_distances(c, t),)).lower(
        _spec((n, m), jnp.int32),
        _spec((q, m * fs.KSUB), jnp.int32),
    )
    name = f"fastscan_q{q}_n{n}_m{m}"
    return name, lowered, {
        "kind": "fastscan",
        "inputs": [
            {"name": "codes", "shape": [n, m], "dtype": "i32"},
            {"name": "qluts", "shape": [q, m * fs.KSUB], "dtype": "i32"},
        ],
        "outputs": [{"name": "acc", "shape": [n, q], "dtype": "i32"}],
        **cfg,
    }


def export_lut(cfg):
    q, d, m = cfg["q"], cfg["d"], cfg["m"]
    dsub = d // m
    lowered = jax.jit(model.lut_pipeline).lower(
        _spec((q, d), jnp.float32),
        _spec((m, fs.KSUB, dsub), jnp.float32),
    )
    name = f"lut_q{q}_d{d}_m{m}"
    return name, lowered, {
        "kind": "lut",
        "inputs": [
            {"name": "queries", "shape": [q, d], "dtype": "f32"},
            {"name": "codebooks", "shape": [m, fs.KSUB, dsub], "dtype": "f32"},
        ],
        "outputs": [
            {"name": "qluts", "shape": [q, m * fs.KSUB], "dtype": "i32"},
            {"name": "delta", "shape": [q], "dtype": "f32"},
            {"name": "bias", "shape": [q], "dtype": "f32"},
        ],
        **cfg,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": "hlo-text", "block_n": fs.BLOCK_N, "block_q": lutk.BLOCK_Q,
                "artifacts": []}
    jobs = (
        [export_search(c) for c in SEARCH_CONFIGS]
        + [export_fastscan(c) for c in FASTSCAN_CONFIGS]
        + [export_lut(c) for c in LUT_CONFIGS]
    )
    for name, lowered, meta in jobs:
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = f"{name}.hlo.txt"
        meta["hlo_chars"] = len(text)
        manifest["artifacts"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}/manifest.json with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
