"""Pallas LUT-construction kernel: per-query ADC distance tables.

Paper Eq. 2 (extended from VQ to PQ): ``T[q, m, k] = ||q_m − c_{m,k}||²``.
Built once per query batch, then scalar-quantized by the L2 model into the
u8 tables the fastscan kernel consumes.

Blocked over the query batch; the codebooks (M×16×dsub, a few KiB) are
pinned in VMEM across grid steps, mirroring how the scan kernel pins the
quantized tables.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fastscan import KSUB

# Queries per grid step.
BLOCK_Q = 8


def _lut_block_kernel(q_ref, cb_ref, out_ref, *, m: int, dsub: int):
    """q_ref: f32[bq, m·dsub]; cb_ref: f32[m, 16·dsub]; out: f32[bq, m·16]."""
    bq = q_ref.shape[0]
    q = q_ref[...].reshape(bq, m, 1, dsub)
    cb = cb_ref[...].reshape(1, m, KSUB, dsub)
    diff = q - cb  # (bq, m, 16, dsub)
    out_ref[...] = jnp.sum(diff * diff, axis=-1).reshape(bq, m * KSUB)


def build_luts(queries: jax.Array, codebooks: jax.Array) -> jax.Array:
    """f32 ADC tables for a query batch.

    queries   : f32[Q, D] with Q a multiple of ``BLOCK_Q`` (model pads)
    codebooks : f32[M, 16, dsub] with M·dsub == D
    Returns f32[Q, M·16].
    """
    nq, d = queries.shape
    m, ksub, dsub = codebooks.shape
    assert ksub == KSUB
    assert m * dsub == d, (m, dsub, d)
    assert nq % BLOCK_Q == 0, f"Q={nq} must be a multiple of {BLOCK_Q}"
    cb_flat = codebooks.reshape(m, ksub * dsub)
    kernel = functools.partial(_lut_block_kernel, m=m, dsub=dsub)
    return pl.pallas_call(
        kernel,
        grid=(nq // BLOCK_Q,),
        in_specs=[
            pl.BlockSpec((BLOCK_Q, d), lambda i: (i, 0)),  # stream queries
            pl.BlockSpec((m, ksub * dsub), lambda i: (0, 0)),  # codebooks pinned
        ],
        out_specs=pl.BlockSpec((BLOCK_Q, m * KSUB), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, m * KSUB), jnp.float32),
        interpret=True,
    )(queries, cb_flat)
