"""Pure-jnp/numpy oracle for the 4-bit PQ pipeline.

Every Pallas kernel and the L2 model are asserted against these functions;
they mirror the rust implementation bit-for-bit in the integer domain:

* ``ref_luts``        — paper Eq. 2 extended to PQ (f32 distance tables)
* ``ref_quantize``    — paper Eq. 4's scalar quantization (u8 tables with
                        per-sub-quantizer bias and one global scale, same
                        scheme as ``rust/src/pq/lut.rs``)
* ``ref_fastscan``    — integer table-gather accumulation (what the SIMD
                        kernel computes)
* ``ref_search``      — the full quantized search with exact re-ranking
"""

import numpy as np


def ref_luts(queries: np.ndarray, codebooks: np.ndarray) -> np.ndarray:
    """f32 ADC tables.

    queries: (Q, D) f32; codebooks: (M, K, dsub) with M*dsub == D.
    Returns (Q, M, K) where [q, m, k] = ||queries[q, m-th slice] - codebooks[m, k]||².
    """
    Q, D = queries.shape
    M, K, dsub = codebooks.shape
    assert M * dsub == D, (M, dsub, D)
    qs = queries.reshape(Q, M, 1, dsub)
    diff = qs - codebooks[None]  # (Q, M, K, dsub)
    return np.sum(diff * diff, axis=-1).astype(np.float32)


def ref_quantize(luts: np.ndarray):
    """u8-quantize f32 tables (per batch row).

    luts: (Q, M, K) f32. Returns (qluts u8 (Q, M, K), delta (Q,), bias (Q,)),
    with delta = max-per-query table range / 255 and bias = Σ_m min_k.
    """
    mins = luts.min(axis=2, keepdims=True)  # (Q, M, 1)
    ranges = (luts - mins).max(axis=(1, 2))  # (Q,)
    delta = np.where(ranges > 0, ranges / 255.0, 1.0).astype(np.float32)
    q = np.round((luts - mins) / delta[:, None, None])
    qluts = np.clip(q, 0, 255).astype(np.uint8)
    bias = mins.sum(axis=(1, 2)).astype(np.float32)
    return qluts, delta, bias


def ref_fastscan(codes: np.ndarray, qluts: np.ndarray) -> np.ndarray:
    """Integer ADC accumulation.

    codes: (N, M) ints < K; qluts: (Q, M, K) u8.
    Returns (N, Q) int32: [n, q] = Σ_m qluts[q, m, codes[n, m]].
    """
    N, M = codes.shape
    Q, M2, K = qluts.shape
    assert M == M2
    gathered = qluts[:, np.arange(M)[None, :], codes]  # (Q, N, M)
    return gathered.sum(axis=-1, dtype=np.int32).T  # (N, Q)


def ref_decode(acc: np.ndarray, delta: np.ndarray, bias: np.ndarray) -> np.ndarray:
    """Decode quantized accumulations to f32 distances. acc: (N, Q)."""
    return acc.astype(np.float32) * delta[None, :] + bias[None, :]


def ref_adc_exact(codes: np.ndarray, luts: np.ndarray) -> np.ndarray:
    """Exact f32 ADC distances: (N, Q)."""
    N, M = codes.shape
    gathered = luts[:, np.arange(M)[None, :], codes]  # (Q, N, M)
    return gathered.sum(axis=-1).T.astype(np.float32)


def ref_search(queries, codes, codebooks, k):
    """Full pipeline with exact re-rank: returns (dists (Q, k) f32, ids (Q, k) i32).

    Quantized scan selects candidates; top-k is taken on the *quantized*
    distances, then re-scored with the exact tables (mirrors the rust path
    with an effectively unlimited reservoir).
    """
    luts = ref_luts(queries, codebooks)
    qluts, delta, bias = ref_quantize(luts)
    acc = ref_fastscan(codes, qluts)  # (N, Q)
    dec = ref_decode(acc, delta, bias).T  # (Q, N)
    idx = np.argsort(dec, axis=1, kind="stable")[:, :k]  # (Q, k)
    exact = ref_adc_exact(codes, luts).T  # (Q, N)
    d = np.take_along_axis(exact, idx, axis=1)
    order = np.argsort(d, axis=1, kind="stable")
    return np.take_along_axis(d, order, axis=1).astype(np.float32), np.take_along_axis(
        idx, order, axis=1
    ).astype(np.int32)
