"""Pallas fastscan kernel: 4-bit-PQ lookup re-thought for the TPU MXU.

Hardware adaptation of the paper's §3 (see DESIGN.md §Hardware-Adaptation):

* The paper keeps the 16-entry u8 tables **register-resident** and turns
  the table lookup into an in-register parallel shuffle (two ``vqtbl1q_u8``
  = one virtual 256-bit ``_mm256_shuffle_epi8``).
* A TPU has no byte shuffle, but the same locality insight maps to VMEM +
  MXU: the quantized tables stay **VMEM-resident across all grid steps**
  (``BlockSpec`` index map pins them), and the 16-way lookup becomes a
  **one-hot × table matmul**, the MXU's native parallel primitive.
* Where the paper fuses *two* sub-quantizer tables per 256-bit shuffle,
  the MXU contraction fuses **all M tables at once**: the one-hot code
  matrix is reshaped to ``(block_n, M·16)`` and contracted against the
  flattened tables in one ``dot`` — the natural widening of the paper's
  pair-bundling to a 128×128 systolic array.
* Batching Q queries turns the scan into a dense
  ``(block_n, M·16) × (M·16, Q)`` matmul — the register trick becomes a
  roofline-friendly GEMM.

Accumulation is int32 (MXU-native), which cannot saturate for any
``M ≤ 256`` (max Σ = 256·255 ≪ 2³¹), so no clamping is needed — this is
checked against the NEON u16 semantics in the rust tests by keeping
M·255 < 65 536 in exported configurations.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Codes processed per grid step. 512 × (M·16) one-hot bytes ≈ 128 KiB at
# M=16 — comfortably inside a 16 MiB VMEM budget together with the tables.
BLOCK_N = 512

KSUB = 16  # 4-bit codes: the paper's K


def _fastscan_block_kernel(codes_ref, luts_ref, out_ref, *, m: int):
    """One grid step: (block_n, m) codes × (q, m·16) tables → (block_n, q).

    codes_ref : i32[block_n, m]   — VMEM block of unpacked 4-bit codes
    luts_ref  : i32[q, m·16]      — u8-valued tables, VMEM-resident
    out_ref   : i32[block_n, q]
    """
    codes = codes_ref[...]  # (bn, m)
    bn = codes.shape[0]
    # one-hot over the 16 codewords; (bn, m, 16)
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, m, KSUB), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32)
    # fuse all m tables into one contraction (paper: 2 per shuffle)
    onehot2 = onehot.reshape(bn, m * KSUB)
    luts = luts_ref[...].astype(jnp.float32)  # (q, m·16)
    acc = jnp.dot(onehot2, luts.T)  # MXU: (bn, q)
    out_ref[...] = acc.astype(jnp.int32)


def fastscan(codes: jax.Array, luts: jax.Array) -> jax.Array:
    """Quantized ADC accumulation for all codes against all query tables.

    codes : i32[N, M] with values in [0, 16); N must be a multiple of
            ``BLOCK_N`` (the L2 model pads).
    luts  : i32[Q, M·16] with values in [0, 256) (u8 tables widened).
    Returns i32[N, Q].
    """
    n, m = codes.shape
    q, mk = luts.shape
    assert mk == m * KSUB, (mk, m)
    assert n % BLOCK_N == 0, f"N={n} must be a multiple of {BLOCK_N}"
    grid = (n // BLOCK_N,)
    kernel = functools.partial(_fastscan_block_kernel, m=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, m), lambda i: (i, 0)),  # stream codes
            pl.BlockSpec((q, mk), lambda i: (0, 0)),  # tables pinned in VMEM
        ],
        out_specs=pl.BlockSpec((BLOCK_N, q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.int32),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(codes, luts)


def vmem_bytes_estimate(m: int, q: int) -> int:
    """Static VMEM footprint of one grid step (for DESIGN.md §Perf).

    one-hot f32 + codes i32 + tables f32 + out i32.
    """
    onehot = BLOCK_N * m * KSUB * 4
    codes = BLOCK_N * m * 4
    luts = q * m * KSUB * 4
    out = BLOCK_N * q * 4
    return onehot + codes + luts + out
