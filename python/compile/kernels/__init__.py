"""L1 Pallas kernels: the paper's compute hot-spot on TPU-shaped hardware.

`fastscan.py` is the 4-bit-PQ lookup kernel re-thought for the MXU (see
DESIGN.md par. Hardware-Adaptation); `lut.py` builds the per-query distance
tables; `ref.py` is the pure-jnp oracle both are tested against.

All kernels are lowered with ``interpret=True`` -- the CPU PJRT plugin used
by the rust runtime cannot execute Mosaic custom-calls.
"""

from . import fastscan, lut, ref  # noqa: F401
