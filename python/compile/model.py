"""L2: the JAX compute graph for the batched 4-bit PQ search.

Composes the L1 Pallas kernels into the full per-query-batch pipeline the
rust coordinator executes through PJRT:

    queries ──► build_luts (L1) ──► quantize (Eq. 4) ──► fastscan (L1)
            ──► decode ──► top-k

Everything here runs only at ``make artifacts`` time; ``aot.py`` lowers
these functions to HLO text which ``rust/src/runtime`` loads and executes.
The quantization scheme matches ``rust/src/pq/lut.rs`` exactly so both
hot paths produce the same integer accumulations.
"""

import jax
import jax.numpy as jnp

from .kernels import fastscan as fs
from .kernels import lut as lutk


def quantize_luts(luts: jax.Array):
    """Scalar-quantize f32 tables to u8-valued i32 (paper Eq. 4).

    luts: f32[Q, M·16]. Per query: per-table bias (min entry), one global
    scale Δ = max table range / 255. Returns (qluts i32[Q, M·16],
    delta f32[Q], bias f32[Q]). Matches ``rust/src/pq/lut.rs``.
    """
    nq, mk = luts.shape
    m = mk // fs.KSUB
    t = luts.reshape(nq, m, fs.KSUB)
    mins = jnp.min(t, axis=2, keepdims=True)  # (Q, M, 1)
    ranges = jnp.max(t - mins, axis=(1, 2))  # (Q,)
    delta = jnp.where(ranges > 0, ranges / 255.0, 1.0)
    q = jnp.round((t - mins) / delta[:, None, None])
    qluts = jnp.clip(q, 0, 255).astype(jnp.int32).reshape(nq, mk)
    bias = jnp.sum(mins, axis=(1, 2))
    return qluts, delta, bias


def pq_search(queries: jax.Array, codes: jax.Array, codebooks: jax.Array, k: int):
    """Batched 4-bit PQ search (quantized scan + top-k + affine decode).

    queries   : f32[Q, D]      (Q multiple of BLOCK_Q)
    codes     : i32[N, M]      (N multiple of BLOCK_N, values < 16)
    codebooks : f32[M, 16, dsub]
    Returns (dists f32[Q, k], labels i32[Q, k]).

    Top-k is taken on the quantized distances (like the rust reservoir with
    rerank disabled); distances are decoded with the affine (Δ, bias).
    """
    luts = lutk.build_luts(queries, codebooks)  # (Q, M·16) f32
    qluts, delta, bias = quantize_luts(luts)
    acc = fs.fastscan(codes, qluts)  # (N, Q) i32
    dec = acc.T.astype(jnp.float32) * delta[:, None] + bias[:, None]  # (Q, N)
    # top-k via full sort rather than lax.top_k: the TopK HLO op carries a
    # `largest=` attribute that xla_extension 0.5.1's text parser rejects,
    # while sort round-trips cleanly through the HLO-text bridge.
    idx = jnp.argsort(dec, axis=1)[:, :k]
    d = jnp.take_along_axis(dec, idx, axis=1)
    return d, idx.astype(jnp.int32)


def fastscan_distances(codes: jax.Array, qluts: jax.Array):
    """Bare quantized scan (the L1 kernel as an exported unit): i32[N, Q]."""
    return fs.fastscan(codes, qluts)


def lut_pipeline(queries: jax.Array, codebooks: jax.Array):
    """LUT build + quantization as an exported unit.

    Returns (qluts i32[Q, M·16], delta f32[Q], bias f32[Q]).
    """
    luts = lutk.build_luts(queries, codebooks)
    return quantize_luts(luts)
