//! Cross-module integration tests: full pipelines through the public API.

use armpq::coordinator::{Client, IvfBackend, Server, ServerConfig};
use armpq::datasets::SyntheticDataset;
use armpq::eval::{ground_truth, recall_at_r};
use armpq::index::{index_factory, Index};
use armpq::ivf::{IvfParams, IvfPq4};
use armpq::pq::PqParams;
use std::sync::Arc;

/// Fig. 2's central claim at the public-API level: for every M, naive PQ
/// and 4-bit fastscan PQ return the same recall (same codes, same K).
#[test]
fn fig2_accuracy_equivalence_across_m() {
    let ds = SyntheticDataset::sift_like(5_000, 50, 1001);
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    for m in [8usize, 16, 32] {
        let mut naive = index_factory(ds.dim, &format!("PQ{m}x4")).unwrap();
        naive.train(&ds.train).unwrap();
        naive.add(&ds.base).unwrap();
        let rn = naive.search(&ds.queries, 10).unwrap();

        let mut fast = index_factory(ds.dim, &format!("PQ{m}x4fs")).unwrap();
        fast.train(&ds.train).unwrap();
        fast.add(&ds.base).unwrap();
        let rf = fast.search(&ds.queries, 10).unwrap();

        let rec_n = recall_at_r(&gt, 1, &rn.labels, 10, 10);
        let rec_f = recall_at_r(&gt, 1, &rf.labels, 10, 10);
        assert!(
            (rec_n - rec_f).abs() <= 0.06,
            "M={m}: naive {rec_n} vs fastscan {rec_f}"
        );
    }
}

/// Table 1's pipeline at small scale: IVF+HNSW+PQ16x4fs must achieve
/// higher recall with more probes and stay well-formed.
#[test]
fn table1_pipeline_small() {
    // SIFT-like data: M=16 4-bit PQ reaches usable recall there (the
    // deep-like set at M=16 sits near 0.05 recall@1, matching Fig. 2b).
    let ds = SyntheticDataset::sift_like(8_000, 40, 1002);
    let mut idx = index_factory(ds.dim, "IVF64_HNSW16,PQ16x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let mut recalls = Vec::new();
    for nprobe in [1usize, 4, 16] {
        idx.set_param("nprobe", &nprobe.to_string()).unwrap();
        let r = idx.search(&ds.queries, 10).unwrap();
        recalls.push(recall_at_r(&gt, 1, &r.labels, 10, 10));
    }
    // recall here is capped by PQ quantization, not probe coverage, so
    // only rough monotonicity can be asserted (paper Table 1 likewise
    // moves just 0.072 → 0.086 across nprobe 1 → 4)
    assert!(recalls[2] + 0.05 >= recalls[0], "{recalls:?}");
    assert!(recalls[2] > 0.3, "nprobe=16 recall {}", recalls[2]);
}

/// Serving stack end-to-end over a real TCP socket, checked for recall.
#[test]
fn serve_stack_end_to_end() {
    let ds = SyntheticDataset::sift_like(4_000, 30, 1003);
    let mut params = IvfParams::new(16);
    params.coarse_hnsw = true;
    let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(16));
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.nprobe = 8;
    let backend = Arc::new(IvfBackend::new(idx).unwrap());
    let server = Server::start(backend, ServerConfig::default()).unwrap();

    let mut client = Client::connect(&server.addr).unwrap();
    client.ping().unwrap();
    let mut labels = Vec::new();
    for qi in 0..ds.nq() {
        let (d, l, _) = client.search(ds.query(qi), 10).unwrap();
        assert_eq!(d.len(), 10);
        assert!(d.windows(2).all(|w| w[0] <= w[1]));
        labels.extend(l);
    }
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let recall = recall_at_r(&gt, 1, &labels, 10, 10);
    assert!(recall > 0.2, "served recall {recall}");
    let stats = client.stats().unwrap();
    assert!(stats.get("requests_total").unwrap().as_usize().unwrap() >= ds.nq());
    server.stop();
}

/// The whole three-layer stack: rust-trained PQ codes searched through the
/// AOT-compiled JAX/Pallas artifact, validated against the rust kernel.
#[test]
fn pjrt_three_layer_stack() {
    use armpq::coordinator::service::{PjrtBackend, SearchBackend};
    use armpq::pq::fastscan::{fastscan_distances_all, KernelLuts};
    use armpq::pq::{PackedCodes4, ProductQuantizer, QuantizedLuts};
    use armpq::runtime::EngineHandle;
    use armpq::util::rng::Rng;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Arc::new(EngineHandle::spawn(dir).unwrap());
    let Some(meta) = engine.manifest.find_by("search", &[("d", 64)]).cloned() else {
        return;
    };
    let (n, d, m) = (meta.params["n"], meta.params["d"], meta.params["m"]);

    let mut rng = Rng::new(1004);
    let train: Vec<f32> = (0..2000 * d).map(|_| rng.next_gaussian()).collect();
    let pq = ProductQuantizer::train(&train, d, &PqParams::new_4bit(m)).unwrap();
    let base: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian()).collect();
    let codes_u8 = pq.encode(&base).unwrap();
    let codes_i32: Vec<i32> = codes_u8.iter().map(|&c| c as i32).collect();

    let backend = PjrtBackend::new(engine, d, codes_i32, pq.centroids.clone()).unwrap();
    let queries: Vec<f32> = (0..4 * d).map(|_| rng.next_gaussian()).collect();
    let (dists, labels) = backend.search_batch(&queries, 5).unwrap();

    // rust oracle: quantized fastscan on the same codes
    let packed = PackedCodes4::pack(&codes_u8, m).unwrap();
    for qi in 0..4 {
        let luts = pq.compute_luts(&queries[qi * d..(qi + 1) * d]);
        let qluts = QuantizedLuts::from_f32(&luts, m, 16);
        let kluts = KernelLuts::build(&qluts, packed.m_pad);
        let all = fastscan_distances_all(&packed, &kluts, armpq::simd::Backend::Portable);
        let best = all.iter().enumerate().min_by_key(|&(_, &v)| v).unwrap();
        assert_eq!(labels[qi * 5] as usize, best.0, "query {qi}");
        let decoded = qluts.decode(*best.1);
        assert!(
            (decoded - dists[qi * 5]).abs() < 1e-2 * (1.0 + decoded.abs()),
            "query {qi}: {decoded} vs {}",
            dists[qi * 5]
        );
    }
}

/// Factory-built indexes are interchangeable through the trait object.
#[test]
fn factory_polymorphism() {
    let ds = SyntheticDataset::gaussian(2_000, 20, 32, 1005);
    let specs = ["Flat", "PQ8x4", "PQ8x4fs", "IVF16,PQ8x4fs"];
    let mut results = Vec::new();
    for spec in specs {
        let mut idx = index_factory(ds.dim, spec).unwrap();
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let _ = idx.set_param("nprobe", "16");
        let r = idx.search(&ds.queries, 5).unwrap();
        assert_eq!(r.nq(), 20, "{spec}");
        results.push(r);
    }
    // Naive PQ and fastscan share codes: their top-1 must usually agree
    // (pure-gaussian 32-D data is too hard to demand flat-recall instead).
    let agree = (0..20)
        .filter(|&qi| results[1].row(qi)[0] == results[2].row(qi)[0])
        .count();
    assert!(agree >= 14, "naive/fastscan top-1 agreement only {agree}/20");
}

/// fvecs round-trip through the dataset IO + gen-data path.
#[test]
fn dataset_io_roundtrip() {
    use armpq::datasets::io::{read_fvecs, write_fvecs};
    let ds = SyntheticDataset::deep_like(100, 5, 1006);
    let dir = std::env::temp_dir().join(format!("armpq_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("base.fvecs");
    write_fvecs(&path, ds.dim, &ds.base).unwrap();
    let (dim, data) = read_fvecs(&path).unwrap();
    assert_eq!(dim, ds.dim);
    assert_eq!(data, ds.base);
}
