//! Cross-module integration tests: full pipelines through the public API.

use armpq::coordinator::{Client, IvfBackend, Server, ServerConfig};
use armpq::datasets::SyntheticDataset;
use armpq::eval::{ground_truth, recall_at_r};
use armpq::index::{
    index_factory, Filter, Hit, Index, QueryKind, QueryRequest, SearchParams, SearchRequest,
};
use armpq::ivf::{IvfParams, IvfPq4};
use armpq::pq::PqParams;
use std::sync::Arc;

/// Fig. 2's central claim at the public-API level: for every M, naive PQ
/// and 4-bit fastscan PQ return the same recall (same codes, same K).
#[test]
fn fig2_accuracy_equivalence_across_m() {
    let ds = SyntheticDataset::sift_like(5_000, 50, 1001);
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    for m in [8usize, 16, 32] {
        let mut naive = index_factory(ds.dim, &format!("PQ{m}x4")).unwrap();
        naive.train(&ds.train).unwrap();
        naive.add(&ds.base).unwrap();
        let rn = naive.search(&ds.queries, 10, None).unwrap();

        let mut fast = index_factory(ds.dim, &format!("PQ{m}x4fs")).unwrap();
        fast.train(&ds.train).unwrap();
        fast.add(&ds.base).unwrap();
        fast.seal().unwrap();
        let rf = fast.search(&ds.queries, 10, None).unwrap();

        let rec_n = recall_at_r(&gt, 1, &rn.labels, 10, 10);
        let rec_f = recall_at_r(&gt, 1, &rf.labels, 10, 10);
        assert!(
            (rec_n - rec_f).abs() <= 0.06,
            "M={m}: naive {rec_n} vs fastscan {rec_f}"
        );
    }
}

/// Table 1's pipeline at small scale: IVF+HNSW+PQ16x4fs must achieve
/// higher recall with more probes and stay well-formed.
#[test]
fn table1_pipeline_small() {
    // SIFT-like data: M=16 4-bit PQ reaches usable recall there (the
    // deep-like set at M=16 sits near 0.05 recall@1, matching Fig. 2b).
    let ds = SyntheticDataset::sift_like(8_000, 40, 1002);
    let mut idx = index_factory(ds.dim, "IVF64_HNSW16,PQ16x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let mut recalls = Vec::new();
    for nprobe in [1usize, 4, 16] {
        // half via the set_param shim, half via per-request params — the
        // two surfaces must agree
        let r = if nprobe == 4 {
            idx.set_param("nprobe", "4").unwrap();
            let r = idx.search(&ds.queries, 10, None).unwrap();
            idx.set_param("nprobe", "1").unwrap();
            r
        } else {
            let req = SearchRequest::new(&ds.queries, 10).nprobe(nprobe);
            idx.search_req(&req).unwrap()
        };
        recalls.push(recall_at_r(&gt, 1, &r.labels, 10, 10));
    }
    // recall here is capped by PQ quantization, not probe coverage, so
    // only rough monotonicity can be asserted (paper Table 1 likewise
    // moves just 0.072 → 0.086 across nprobe 1 → 4)
    assert!(recalls[2] + 0.05 >= recalls[0], "{recalls:?}");
    assert!(recalls[2] > 0.3, "nprobe=16 recall {}", recalls[2]);
}

/// Serving stack end-to-end over a real TCP socket, checked for recall.
#[test]
fn serve_stack_end_to_end() {
    let ds = SyntheticDataset::sift_like(4_000, 30, 1003);
    let mut params = IvfParams::new(16);
    params.coarse_hnsw = true;
    let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(16));
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.nprobe = 8;
    let backend = Arc::new(IvfBackend::new(idx).unwrap());
    let server = Server::start(backend, ServerConfig::default()).unwrap();

    let mut client = Client::connect(&server.addr).unwrap();
    client.ping().unwrap();
    let mut labels = Vec::new();
    for qi in 0..ds.nq() {
        let (d, l, _) = client.search(ds.query(qi), 10).unwrap();
        assert_eq!(d.len(), 10);
        assert!(d.windows(2).all(|w| w[0] <= w[1]));
        labels.extend(l);
    }
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let recall = recall_at_r(&gt, 1, &labels, 10, 10);
    assert!(recall > 0.2, "served recall {recall}");
    let stats = client.stats().unwrap();
    assert!(stats.get("requests_total").unwrap().as_usize().unwrap() >= ds.nq());
    server.stop();
}

/// The whole three-layer stack: rust-trained PQ codes searched through the
/// AOT-compiled JAX/Pallas artifact, validated against the rust kernel.
#[test]
fn pjrt_three_layer_stack() {
    use armpq::coordinator::service::{PjrtBackend, SearchBackend};
    use armpq::pq::fastscan::{fastscan_distances_all, KernelLuts};
    use armpq::pq::{CodeWidth, PackedCodes, ProductQuantizer, QuantizedLuts};
    use armpq::runtime::EngineHandle;
    use armpq::util::rng::Rng;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Arc::new(EngineHandle::spawn(dir).unwrap());
    let Some(meta) = engine.manifest.find_by("search", &[("d", 64)]).cloned() else {
        return;
    };
    let (n, d, m) = (meta.params["n"], meta.params["d"], meta.params["m"]);

    let mut rng = Rng::new(1004);
    let train: Vec<f32> = (0..2000 * d).map(|_| rng.next_gaussian()).collect();
    let pq = ProductQuantizer::train(&train, d, &PqParams::new_4bit(m)).unwrap();
    let base: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian()).collect();
    let codes_u8 = pq.encode(&base).unwrap();
    let codes_i32: Vec<i32> = codes_u8.iter().map(|&c| c as i32).collect();

    let backend = PjrtBackend::new(engine, d, codes_i32, pq.centroids.clone()).unwrap();
    let queries: Vec<f32> = (0..4 * d).map(|_| rng.next_gaussian()).collect();
    let (dists, labels) = backend.search_batch(&queries, 5, None).unwrap();

    // rust oracle: quantized fastscan on the same codes
    let packed = PackedCodes::pack(&codes_u8, m, CodeWidth::W4).unwrap();
    for qi in 0..4 {
        let luts = pq.compute_luts(&queries[qi * d..(qi + 1) * d]);
        let qluts = QuantizedLuts::from_f32(&luts, m, 16);
        let kluts = KernelLuts::build(&qluts, packed.lut_rows);
        let all = fastscan_distances_all(&packed, &kluts, armpq::simd::Backend::Portable);
        let best = all.iter().enumerate().min_by_key(|&(_, &v)| v).unwrap();
        assert_eq!(labels[qi * 5] as usize, best.0, "query {qi}");
        let decoded = qluts.decode(*best.1);
        assert!(
            (decoded - dists[qi * 5]).abs() < 1e-2 * (1.0 + decoded.abs()),
            "query {qi}: {decoded} vs {}",
            dists[qi * 5]
        );
    }
}

/// Factory-built indexes are interchangeable through the trait object.
#[test]
fn factory_polymorphism() {
    let ds = SyntheticDataset::gaussian(2_000, 20, 32, 1005);
    let specs = ["Flat", "PQ8x4", "PQ8x4fs", "IVF16,PQ8x4fs"];
    let mut results = Vec::new();
    for spec in specs {
        let mut idx = index_factory(ds.dim, spec).unwrap();
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let _ = idx.set_param("nprobe", "16");
        let r = idx.search(&ds.queries, 5, None).unwrap();
        assert_eq!(r.nq(), 20, "{spec}");
        results.push(r);
    }
    // Naive PQ and fastscan share codes: their top-1 must usually agree
    // (pure-gaussian 32-D data is too hard to demand flat-recall instead).
    let agree = (0..20)
        .filter(|&qi| results[1].row(qi)[0] == results[2].row(qi)[0])
        .count();
    assert!(agree >= 14, "naive/fastscan top-1 agreement only {agree}/20");
}

/// fvecs round-trip through the dataset IO + gen-data path.
#[test]
fn dataset_io_roundtrip() {
    use armpq::datasets::io::{read_fvecs, write_fvecs};
    let ds = SyntheticDataset::deep_like(100, 5, 1006);
    let dir = std::env::temp_dir().join(format!("armpq_it_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("base.fvecs");
    write_fvecs(&path, ds.dim, &ds.base).unwrap();
    let (dim, data) = read_fvecs(&path).unwrap();
    assert_eq!(dim, ds.dim);
    assert_eq!(data, ds.base);
}


/// Build a sealed IVF index for the concurrency tests, shared as
/// `Arc<dyn Index>` (the trait is `Send + Sync`, search is `&self`).
fn sealed_ivf(ds: &armpq::datasets::Dataset) -> Arc<dyn Index> {
    let mut idx = index_factory(ds.dim, "IVF16,PQ8x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.set_param("nprobe", "4").unwrap();
    idx.set_param("reservoir_factor", "32").unwrap();
    idx.seal().unwrap();
    Arc::from(idx)
}

/// 8 threads searching the same sealed `IndexIvfPq4` through
/// `Arc<dyn Index>` must each get results identical to the serial pass —
/// the immutability guarantee of the query phase.
#[test]
fn concurrent_search_matches_serial() {
    let ds = SyntheticDataset::sift_like(4_000, 32, 1007);
    let idx = sealed_ivf(&ds);
    let serial = idx.search(&ds.queries, 10, None).unwrap();
    let queries = Arc::new(ds.queries.clone());
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let idx = idx.clone();
            let queries = queries.clone();
            std::thread::spawn(move || idx.search(&queries, 10, None).unwrap())
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.labels, serial.labels, "concurrent labels diverge from serial");
        assert_eq!(r.distances, serial.distances, "concurrent distances diverge from serial");
    }
}

/// Concurrent requests with different per-request `SearchParams` must each
/// see exactly the results of a serial run with those same parameters —
/// overrides never leak between in-flight requests or into the defaults.
#[test]
fn concurrent_params_do_not_leak() {
    let ds = SyntheticDataset::sift_like(4_000, 32, 1008);
    let idx = sealed_ivf(&ds);
    // serial references for each nprobe
    let narrow = SearchParams::new().with_nprobe(1);
    let wide = SearchParams::new().with_nprobe(16);
    let ref_narrow = idx.search(&ds.queries, 10, Some(&narrow)).unwrap();
    let ref_wide = idx.search(&ds.queries, 10, Some(&wide)).unwrap();
    let ref_default = idx.search(&ds.queries, 10, None).unwrap();
    // wider probing must actually change something, or this test is vacuous
    assert_ne!(ref_narrow.labels, ref_wide.labels, "nprobe sweep had no effect");

    let queries = Arc::new(ds.queries.clone());
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let idx = idx.clone();
            let queries = queries.clone();
            std::thread::spawn(move || {
                let params = if t % 2 == 0 {
                    SearchParams::new().with_nprobe(1)
                } else {
                    SearchParams::new().with_nprobe(16)
                };
                (t, idx.search(&queries, 10, Some(&params)).unwrap())
            })
        })
        .collect();
    for h in handles {
        let (t, r) = h.join().unwrap();
        let reference = if t % 2 == 0 { &ref_narrow } else { &ref_wide };
        assert_eq!(r.labels, reference.labels, "thread {t}: params leaked");
        assert_eq!(r.distances, reference.distances, "thread {t}: params leaked");
    }
    // defaults survive untouched
    let after = idx.search(&ds.queries, 10, None).unwrap();
    assert_eq!(after.labels, ref_default.labels, "overrides mutated the defaults");
}

/// Per-request params through the whole serving stack: TCP clients sending
/// different nprobe values concurrently get batched together without
/// cross-talk.
#[test]
fn concurrent_serve_stack_params() {
    let ds = SyntheticDataset::sift_like(2_000, 8, 1009);
    let mut idx = IvfPq4::new(ds.dim, IvfParams::new(16), PqParams::new_4bit(8));
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.nprobe = 4;
    idx.fastscan.reservoir_factor = 32;
    let backend = Arc::new(IvfBackend::new(idx).unwrap());
    // direct references (no batching) per nprobe
    use armpq::coordinator::SearchBackend;
    let q0 = &ds.queries[..ds.dim];
    let (_d1, l_narrow) =
        backend.search_batch(q0, 5, Some(&SearchParams::new().with_nprobe(1))).unwrap();
    let (_d2, l_wide) =
        backend.search_batch(q0, 5, Some(&SearchParams::new().with_nprobe(16))).unwrap();

    let server = Server::start(backend, ServerConfig::default()).unwrap();
    let addr = server.addr;
    let q0 = ds.queries[..ds.dim].to_vec();
    let mut handles = Vec::new();
    for t in 0..6 {
        let q0 = q0.clone();
        handles.push(std::thread::spawn(move || {
            let mut c = Client::connect(&addr).unwrap();
            let nprobe = if t % 2 == 0 { 1 } else { 16 };
            let params = SearchParams::new().with_nprobe(nprobe);
            let (_d, l, _b) = c.search_with(&q0, 5, Some(&params)).unwrap();
            (t, l)
        }));
    }
    for h in handles {
        let (t, l) = h.join().unwrap();
        let expect = if t % 2 == 0 { &l_narrow } else { &l_wide };
        assert_eq!(&l, expect, "client {t} saw another request's nprobe");
    }
    server.stop();
}

// ---------------------------------------------------------------- threads
//
// The threads_ tests below are the acceptance suite of the plan/execute
// layer: results must be BIT-IDENTICAL for every executor thread count —
// the deterministic per-list merge makes the schedule invisible. CI runs
// them as named steps and additionally re-runs the whole integration
// suite under ARMPQ_THREADS=1 and ARMPQ_THREADS=4 on both architectures.

/// Stats comparison that ignores the concurrency gauges (threads_used and
/// scratch_bytes legitimately differ between executors).
fn core_stats(s: &armpq::index::QueryStats) -> (usize, usize, f64) {
    (s.codes_scanned, s.lists_probed, s.filter_selectivity)
}

/// Acceptance: for every backend × width × query kind × filter, results
/// with a 4-thread executor are bit-identical to a 1-thread executor —
/// including odd batch sizes (7, 3, 1) and nprobe (8, and full-probe 16)
/// above the thread count. The nq=1 cases exercise the intra-query
/// multi-list fan-out; the nq=7 cases the batch fan-out.
#[test]
fn threads_differential_fastscan_and_ivf() {
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::gaussian(900, 7, 32, 1300);
    let exec1 = QueryExecutor::new(1);
    let exec4 = QueryExecutor::new(4);
    let sparse_ids: Vec<i64> = (0..900).step_by(7).collect();
    for bits in [2usize, 4, 8] {
        for spec in [
            format!("PQ8x{bits}fs"),
            format!("IVF16,PQ8x{bits}fs,nprobe=8"),
        ] {
            let mut idx = index_factory(ds.dim, &spec).unwrap();
            idx.train(&ds.train).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            // a radius that certainly admits hits: the serial top-20 tail
            let probe = idx
                .query(&QueryRequest::top_k(&ds.queries[..ds.dim], 20))
                .unwrap();
            let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
            for backend in armpq::simd::available_backends() {
                // nprobe=16 (> 4 threads, full probe) through per-request params
                let params = SearchParams::new().with_backend(backend).with_nprobe(16);
                let filters = [
                    None,
                    Some(Filter::id_range(100, 600)),
                    Some(Filter::id_set(&sparse_ids)),
                    Some(Filter::predicate(|id| id % 3 == 0)),
                ];
                for filter in filters {
                    for kind in [QueryKind::TopK { k: 9 }, QueryKind::Range { radius }] {
                        for nq in [7usize, 3, 1] {
                            let req = QueryRequest {
                                queries: &ds.queries[..nq * ds.dim],
                                kind,
                                filter: filter.clone(),
                                params: Some(params.clone()),
                                trace: false,
                            };
                            let r1 = idx.query_exec(&req, &exec1).unwrap();
                            let r4 = idx.query_exec(&req, &exec4).unwrap();
                            assert_eq!(
                                r1.hits, r4.hits,
                                "{spec} {backend:?} {kind:?} {filter:?} nq={nq}: \
                                 threaded hits diverge from serial"
                            );
                            let s1: Vec<_> = r1.stats.iter().map(core_stats).collect();
                            let s4: Vec<_> = r4.stats.iter().map(core_stats).collect();
                            assert_eq!(s1, s4, "{spec} {backend:?} nq={nq}: stats diverge");
                        }
                    }
                }
            }
        }
    }
}

/// The non-fastscan indexes ride the same executor: exact flat, naive PQ
/// and the refinement wrapper are bit-identical across thread counts too.
#[test]
fn threads_differential_flat_pq_refine() {
    use armpq::exec::QueryExecutor;
    use armpq::index::IndexRefineFlat;
    let ds = SyntheticDataset::gaussian(700, 5, 32, 1301);
    let exec1 = QueryExecutor::new(1);
    let exec4 = QueryExecutor::new(4);
    let mut indexes: Vec<Box<dyn Index>> = vec![
        index_factory(ds.dim, "Flat").unwrap(),
        index_factory(ds.dim, "PQ8x4").unwrap(),
        Box::new(IndexRefineFlat::new(index_factory(ds.dim, "PQ8x4fs").unwrap())),
    ];
    for idx in &mut indexes {
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
    }
    for idx in &indexes {
        let probe = idx.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 15)).unwrap();
        let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
        for filter in [None, Some(Filter::id_range(50, 500))] {
            for kind in [QueryKind::TopK { k: 6 }, QueryKind::Range { radius }] {
                for nq in [5usize, 1] {
                    let req = QueryRequest {
                        queries: &ds.queries[..nq * ds.dim],
                        kind,
                        filter: filter.clone(),
                        params: None,
                        trace: false,
                    };
                    let r1 = idx.query_exec(&req, &exec1).unwrap();
                    let r4 = idx.query_exec(&req, &exec4).unwrap();
                    assert_eq!(
                        r1.hits,
                        r4.hits,
                        "{} {kind:?} {filter:?} nq={nq}",
                        idx.describe()
                    );
                }
            }
        }
    }
}

/// The serving layer on an explicit shared executor: a sharded router
/// whose shards all ride one 4-thread executor returns exactly what the
/// 1-thread build returns, and the response stats surface the
/// concurrency (threads_used ≥ 1, scratch high-water > 0).
#[test]
fn threads_sharded_backend_shared_executor() {
    use armpq::coordinator::{SearchBackend, ShardedBackend};
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::sift_like(2_000, 6, 1302);
    let dim = ds.dim;
    let per = 1_000usize;
    let build_shards = || -> Vec<Arc<dyn Index>> {
        (0..2)
            .map(|s| {
                let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(8));
                idx.train(&ds.train).unwrap();
                let slice = &ds.base[s * per * dim..(s + 1) * per * dim];
                let ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
                idx.add_with_ids(slice, &ids).unwrap();
                idx.nprobe = 4;
                idx.seal().unwrap();
                Arc::new(armpq::index::IndexIvfPq4::from_inner(idx)) as Arc<dyn Index>
            })
            .collect()
    };
    let serial =
        ShardedBackend::from_indexes_with_executor(build_shards(), QueryExecutor::new(1)).unwrap();
    let wide =
        ShardedBackend::from_indexes_with_executor(build_shards(), QueryExecutor::new(4)).unwrap();
    let req = QueryRequest::top_k(&ds.queries, 5);
    let r1 = serial.query_batch(&req).unwrap();
    let r4 = wide.query_batch(&req).unwrap();
    assert_eq!(r1.hits, r4.hits, "sharded results depend on thread count");
    assert!(r4.stats[0].threads_used >= 1);
    assert!(r4.stats[0].scratch_bytes > 0, "scratch high-water not surfaced");
}

// ---------------------------------------------------------------- widths

/// Acceptance: for each width in {2, 4, 8}, every backend this host
/// offers produces bit-identical reservoir contents on random data.
/// CI runs this as a named step on x86_64 (Portable vs SSSE3) and under
/// QEMU aarch64 (Portable vs NEON).
#[test]
fn width_differential_reservoir_contents() {
    use armpq::pq::bitwidth::build_width_luts;
    use armpq::pq::fastscan::scan_into_reservoir;
    use armpq::pq::{CodeWidth, PackedCodes};
    use armpq::simd::available_backends;
    use armpq::util::rng::Rng;
    use armpq::util::topk::U16Reservoir;

    let backends = available_backends();
    let mut rng = Rng::new(1100);
    for width in CodeWidth::ALL {
        for trial in 0..10 {
            // partial blocks and odd M on purpose
            let n = 1 + rng.below(400);
            let m = 1 + rng.below(12);
            let k = 1 + rng.below(10);
            let cols = width.code_columns(m);
            let sub_ksub = width.sub_ksub();
            let codes: Vec<u8> =
                (0..n * cols).map(|_| (rng.next_u32() as usize % sub_ksub) as u8).collect();
            let luts_f32: Vec<f32> =
                (0..cols * sub_ksub).map(|_| rng.next_f32() * 9.0).collect();
            let packed = PackedCodes::pack(&codes, m, width).unwrap();
            let wl = build_width_luts(&luts_f32, m, width);
            let mut reference: Option<Vec<(u16, i64)>> = None;
            for &backend in &backends {
                let mut res = U16Reservoir::new(k, 4);
                scan_into_reservoir(&packed, &wl.kernel, backend, None, &mut res);
                let mut cands = res.into_candidates();
                cands.sort_unstable();
                match &reference {
                    None => reference = Some(cands),
                    Some(want) => assert_eq!(
                        &cands, want,
                        "{width} trial {trial} n={n} m={m} k={k} {backend:?}: \
                         reservoir contents differ between backends"
                    ),
                }
            }
        }
    }
}

/// Acceptance: `index_factory("PQ16x{B}fs")` round-trips build→seal→search
/// for every width, flat and IVF-composed, returning well-formed results.
#[test]
fn width_factory_build_seal_search_roundtrip() {
    let ds = SyntheticDataset::gaussian(1_500, 15, 32, 1101);
    for bits in [2usize, 4, 8] {
        for spec in [
            format!("PQ16x{bits}fs"),
            format!("IVF8,PQ16x{bits}fs,nprobe=8"),
        ] {
            let mut idx = index_factory(ds.dim, &spec).unwrap();
            idx.train(&ds.train).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            assert_eq!(idx.ntotal(), 1_500, "{spec}");
            let r = idx.search(&ds.queries, 10, None).unwrap();
            assert_eq!(r.nq(), 15, "{spec}");
            assert_eq!(r.labels.len(), 150, "{spec}");
            assert!(
                r.labels.iter().all(|&l| (-1..1_500).contains(&l)),
                "{spec}: labels out of range"
            );
            for qi in 0..15 {
                let row = &r.distances[qi * 10..(qi + 1) * 10];
                assert!(
                    row.windows(2).all(|w| w[0] <= w[1]),
                    "{spec}: query {qi} distances unsorted {row:?}"
                );
                assert!(row.iter().all(|d| d.is_finite()), "{spec}: non-finite distance");
            }
            assert!(
                idx.describe().contains(&format!("x{bits}fs")),
                "{spec}: {}",
                idx.describe()
            );
        }
    }
}

/// Acceptance: recall is monotone in code width at fixed M —
/// recall(2-bit) ≤ recall(4-bit) ≤ recall(8-bit) (small tolerance), and
/// the 2→8 gap is strict: the widths are real operating points, not
/// aliases of one another.
#[test]
fn width_recall_monotonic_at_fixed_m() {
    let ds = SyntheticDataset::gaussian(2_500, 40, 32, 1102);
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    // rerank off: the property is about raw code fidelity
    let params = SearchParams::new().with_rerank(false).with_reservoir_factor(16);
    let mut recalls = Vec::new();
    for bits in [2usize, 4, 8] {
        let mut idx = index_factory(ds.dim, &format!("PQ8x{bits}fs")).unwrap();
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let r = idx.search(&ds.queries, 10, Some(&params)).unwrap();
        recalls.push(recall_at_r(&gt, 1, &r.labels, 10, 10));
    }
    assert!(
        recalls[0] <= recalls[1] + 0.05 && recalls[1] <= recalls[2] + 0.05,
        "recall@10 not monotone in width: {recalls:?}"
    );
    assert!(recalls[2] > recalls[0], "8-bit must beat 2-bit: {recalls:?}");
}

// ---------------------------------------------------------------- queries
//
// The query_ tests below are the acceptance suite of the typed
// QueryRequest/QueryResponse API: filter pushdown must be bit-identical to
// post-filtering, range queries must hit the exact boundary, and both must
// ride the whole serving stack. CI runs them as named steps on x86_64
// (Portable vs SSSE3) and under QEMU aarch64 (Portable vs NEON).

/// Acceptance: filtered query ≡ unfiltered-query-then-post-filter,
/// bit-identical hits, across every width and every backend this host
/// offers. Comparison uses complete admitted sets (k = admitted count,
/// reservoir sized past n) so it is insensitive to tie order at a k
/// boundary; distances are exact (rerank on).
#[test]
fn query_filtered_matches_postfilter_widths_and_backends() {
    let ds = SyntheticDataset::gaussian(700, 5, 32, 1200);
    let filter = Filter::id_range(150, 450); // 300 of 700
    for bits in [2usize, 4, 8] {
        let mut idx = index_factory(ds.dim, &format!("PQ8x{bits}fs")).unwrap();
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        for backend in armpq::simd::available_backends() {
            let params = SearchParams::new().with_backend(backend).with_reservoir_factor(8);
            let filtered = idx
                .query(
                    &QueryRequest::top_k(&ds.queries, 300)
                        .with_filter(filter.clone())
                        .with_params(params.clone()),
                )
                .unwrap();
            let full = idx
                .query(&QueryRequest::top_k(&ds.queries, 700).with_params(params.clone()))
                .unwrap();
            for qi in 0..5 {
                let want: Vec<Hit> = full.hits[qi]
                    .iter()
                    .filter(|h| filter.matches(h.label))
                    .copied()
                    .collect();
                assert_eq!(
                    filtered.hits[qi], want,
                    "x{bits}fs {backend:?} q{qi}: filtered ≠ post-filtered"
                );
                let st = &filtered.stats[qi];
                assert_eq!(st.codes_scanned, 700);
                assert!((st.filter_selectivity - 300.0 / 700.0).abs() < 1e-9);
            }
        }
    }
}

/// Acceptance: flat-fastscan range queries with re-ranking return exactly
/// the ids whose exact ADC distance is within the radius — verified
/// against the scalar ADC oracle, on every backend, filtered and not.
#[test]
fn query_range_matches_exact_adc_oracle() {
    use armpq::index::IndexPq4FastScan;
    use armpq::pq::adc::adc_distances_all;
    let ds = SyntheticDataset::gaussian(600, 4, 32, 1201);
    let mut idx = IndexPq4FastScan::new(ds.dim, 8);
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let pq = idx.pq().unwrap();
    let codes = idx.staging_codes();
    for backend in armpq::simd::available_backends() {
        let params = SearchParams::new().with_backend(backend);
        for qi in 0..4 {
            let q = &ds.queries[qi * ds.dim..(qi + 1) * ds.dim];
            let luts = pq.compute_luts(q);
            let all = adc_distances_all(pq, &luts, codes);
            let mut sorted = all.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let radius = sorted[60]; // ~10%
            let resp = idx
                .query(&QueryRequest::range(q, radius).with_params(params.clone()))
                .unwrap();
            let row = &resp.hits[0];
            let want = all.iter().filter(|&&d| d <= radius).count();
            assert_eq!(row.len(), want, "{backend:?} q{qi}");
            assert!(row.windows(2).all(|w| w[0].distance <= w[1].distance));
            for h in row {
                assert_eq!(h.distance, all[h.label as usize], "{backend:?} q{qi}");
            }
            // filtered range ≡ post-filtered range, bit-identical
            let fresp = idx
                .query(
                    &QueryRequest::range(q, radius)
                        .with_filter(Filter::predicate(|id| id % 2 == 0))
                        .with_params(params.clone()),
                )
                .unwrap();
            let fwant: Vec<Hit> = row.iter().filter(|h| h.label % 2 == 0).copied().collect();
            assert_eq!(fresp.hits[0], fwant, "{backend:?} q{qi}");
        }
    }
}

/// Acceptance: empty and full filters return well-formed empty/complete
/// responses on flat and IVF indexes alike.
#[test]
fn query_empty_and_full_filter_edges() {
    let ds = SyntheticDataset::gaussian(900, 4, 32, 1202);
    for spec in ["PQ8x4fs", "IVF8,PQ8x4fs,nprobe=8"] {
        let mut idx = index_factory(ds.dim, spec).unwrap();
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        // empty: well-formed, zero hits, zero selectivity
        let empty = idx
            .query(&QueryRequest::top_k(&ds.queries, 5).with_filter(Filter::id_set(&[])))
            .unwrap();
        assert_eq!(empty.nq(), 4, "{spec}");
        assert!(empty.hits.iter().all(|r| r.is_empty()), "{spec}");
        assert!(empty.stats.iter().all(|s| s.filter_selectivity == 0.0), "{spec}");
        // full: identical to no filter at all
        let full = idx
            .query(
                &QueryRequest::top_k(&ds.queries, 5)
                    .with_filter(Filter::id_range(i64::MIN / 2, i64::MAX / 2)),
            )
            .unwrap();
        let bare = idx.query(&QueryRequest::top_k(&ds.queries, 5)).unwrap();
        assert_eq!(full.hits, bare.hits, "{spec}");
        // range with an empty filter is empty too, not an error
        let r = idx
            .query(&QueryRequest::range(&ds.queries, 1e9).with_filter(Filter::id_range(5, 5)))
            .unwrap();
        assert!(r.hits.iter().all(|row| row.is_empty()), "{spec}");
    }
}

/// The search shim is a thin view over query: identical results, padded.
#[test]
fn query_search_shim_equivalence() {
    let ds = SyntheticDataset::gaussian(800, 6, 32, 1203);
    for spec in ["Flat", "PQ8x4", "PQ8x4fs", "IVF8,PQ8x4fs,nprobe=4"] {
        let mut idx = index_factory(ds.dim, spec).unwrap();
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let via_shim = idx.search(&ds.queries, 7, None).unwrap();
        let via_query =
            idx.query(&QueryRequest::top_k(&ds.queries, 7)).unwrap().into_search_result(7);
        assert_eq!(via_shim.labels, via_query.labels, "{spec}");
        assert_eq!(via_shim.distances, via_query.distances, "{spec}");
    }
}

/// Filtered and range queries through the sharded router: filters push
/// down into every shard, range hits merge across shards in order, and a
/// label living on both shards (duplicate add) appears exactly once.
#[test]
fn query_sharded_filter_range_and_dedupe() {
    use armpq::coordinator::{SearchBackend, ShardedBackend};
    let ds = SyntheticDataset::sift_like(2_000, 6, 1204);
    let dim = ds.dim;
    let per = 1_000usize;
    let mut shards: Vec<Arc<dyn Index>> = Vec::new();
    for s in 0..2 {
        let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(8));
        idx.train(&ds.train).unwrap();
        let slice = &ds.base[s * per * dim..(s + 1) * per * dim];
        // shards overlap on id 500: the duplicate-add scenario
        let mut ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
        if s == 1 {
            ids[0] = 500;
        }
        idx.add_with_ids(slice, &ids).unwrap();
        idx.nprobe = 4;
        idx.fastscan.reservoir_factor = 32;
        idx.seal().unwrap();
        shards.push(Arc::new(armpq::index::IndexIvfPq4::from_inner(idx)));
    }
    let router = ShardedBackend::from_indexes(shards).unwrap();
    // filtered top-k: labels obey the filter after the merge, no dupes
    let req = QueryRequest::top_k(&ds.queries, 10).with_filter(Filter::id_range(0, 1_500));
    let resp = router.query_batch(&req).unwrap();
    for (qi, row) in resp.hits.iter().enumerate() {
        assert!(row.iter().all(|h| (0..1_500).contains(&h.label)), "q{qi}: {row:?}");
        let mut seen = std::collections::HashSet::new();
        assert!(row.iter().all(|h| seen.insert(h.label)), "q{qi}: duplicate label");
    }
    // merged stats aggregate scan work across shards
    assert!(resp.stats[0].codes_scanned >= 2_000);
    // range: merged variable-length hits, ascending, deduped
    let rreq = QueryRequest::range(&ds.queries, 150_000.0);
    let rresp = router.query_batch(&rreq).unwrap();
    for row in &rresp.hits {
        assert!(row.windows(2).all(|w| w[0].distance <= w[1].distance));
        let mut seen = std::collections::HashSet::new();
        assert!(row.iter().all(|h| seen.insert(h.label)), "range duplicate label");
    }
}

/// Filtered and range queries end-to-end over TCP: kernel → index →
/// batcher → line-JSON protocol → client, with per-request stats.
#[test]
fn query_serving_stack_filter_and_range() {
    let ds = SyntheticDataset::sift_like(3_000, 10, 1205);
    let mut idx = IvfPq4::new(ds.dim, IvfParams::new(16), PqParams::new_4bit(8));
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.nprobe = 16;
    idx.fastscan.reservoir_factor = 32;
    let backend = Arc::new(IvfBackend::new(idx).unwrap());
    let server = Server::start(backend, ServerConfig::default()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();

    // filtered top-k: every hit obeys the filter; stats flow back
    let (hits, stats) = client
        .query(
            ds.query(0),
            &QueryKind::TopK { k: 10 },
            Some(&Filter::id_range(0, 1_000)),
            Some(&SearchParams::new().with_nprobe(16).with_reservoir_factor(64)),
        )
        .unwrap();
    assert!(!hits.is_empty());
    assert!(hits.iter().all(|h| (0..1_000).contains(&h.label)), "{hits:?}");
    assert!(stats.codes_scanned > 0);
    assert!(stats.filter_selectivity > 0.0 && stats.filter_selectivity <= 1.0);

    // filtered ≡ post-filter through the whole stack: a full-k unfiltered
    // query post-filtered must agree on the leading hits (distances are
    // exact ADC and survive the JSON round-trip bit-exactly)
    let (all_hits, _) = client
        .query(
            ds.query(0),
            &QueryKind::TopK { k: 1_000 },
            None,
            Some(&SearchParams::new().with_nprobe(16).with_reservoir_factor(64)),
        )
        .unwrap();
    let want: Vec<f32> = all_hits
        .iter()
        .filter(|h| (0..1_000).contains(&h.label))
        .take(hits.len())
        .map(|h| h.distance)
        .collect();
    let got: Vec<f32> = hits.iter().map(|h| h.distance).collect();
    assert_eq!(got, want, "served filtered ≠ post-filtered");

    // range query over the wire
    let radius = all_hits[all_hits.len() / 10].distance;
    let (rhits, _) = client
        .query(ds.query(0), &QueryKind::Range { radius }, None, None)
        .unwrap();
    assert!(!rhits.is_empty());
    assert!(rhits.iter().all(|h| h.distance <= radius));
    assert!(rhits.windows(2).all(|w| w[0].distance <= w[1].distance));

    // legacy search verb still serves unchanged alongside
    let (d, l, _) = client.search(ds.query(1), 5).unwrap();
    assert_eq!((d.len(), l.len()), (5, 5));
    // and the stats verb exposes the new histograms
    let sj = client.stats().unwrap();
    assert!(sj.get("codes_scanned_mean").unwrap().as_f64().unwrap() > 0.0);
    assert!(sj.get("filter_selectivity_mean").is_some());
    server.stop();
}

/// The serving stack accepts width-parametric indexes end to end: a
/// sharded router over two 2-bit shards (same codebook → batch-level LUT
/// reuse) behind the batcher returns the same results as direct search.
#[test]
fn width_serving_stack_with_lut_reuse() {
    use armpq::coordinator::{Batcher, BatcherConfig, ShardedBackend};

    let ds = SyntheticDataset::gaussian(1_200, 6, 32, 1103);
    let per = 600usize;
    let mut shards: Vec<Arc<dyn Index>> = Vec::new();
    for s in 0..2 {
        let mut idx = armpq::index::IndexIvfPq4::new_width(
            ds.dim,
            4,
            8,
            armpq::pq::CodeWidth::W2,
            false,
            8,
        );
        idx.train(&ds.train).unwrap();
        let slice = &ds.base[s * per * ds.dim..(s + 1) * per * ds.dim];
        let ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
        idx.inner_mut().add_with_ids(slice, &ids).unwrap();
        idx.set_param("nprobe", "4").unwrap();
        idx.set_param("reservoir_factor", "32").unwrap();
        idx.seal().unwrap();
        shards.push(Arc::new(idx));
    }
    let router = Arc::new(ShardedBackend::from_indexes(shards).unwrap());
    assert!(router.reuses_luts(), "same-codebook shards must share LUT builds");

    use armpq::coordinator::SearchBackend;
    let (d_direct, l_direct) = router.search_batch(&ds.queries[..ds.dim], 5, None).unwrap();

    let batcher = Batcher::start(router.clone(), BatcherConfig::default());
    let resp = batcher.search(ds.queries[..ds.dim].to_vec(), 5, None).unwrap();
    assert_eq!(resp.labels, l_direct);
    assert_eq!(resp.distances, d_direct);
    batcher.shutdown();
}

// --------------------------------------------------------------- segments
//
// The segment_ tests below are the acceptance suite of the streaming
// segmented index: interleaved insert/delete/flush/compact histories must
// be bit-identical to equivalently-built one-shot indexes, at every
// executor thread count, with deletes composing into the same kernel
// admission masks as user filters. CI runs them as named steps under
// ARMPQ_THREADS=1 and ARMPQ_THREADS=4 on both architectures.

/// Acceptance: a segmented index flushed and compacted down to one
/// segment is bit-identical to a one-shot sealed fastscan index built
/// from the same vectors in the same order (training is deterministic,
/// so both sides share a codebook) — for every code width, both query
/// kinds, batch and single-query paths.
#[test]
fn segment_matches_one_shot_sealed_index() {
    use armpq::exec::QueryExecutor;
    use armpq::index::IndexPq4FastScan;
    use armpq::pq::CodeWidth;
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    let ds = SyntheticDataset::gaussian(600, 5, 32, 1400);
    let exec = QueryExecutor::new(2);
    for width in CodeWidth::ALL {
        let mut seg = SegmentedIndex::new(
            ds.dim,
            8,
            width,
            SegmentedParams { flush_threshold: 128, max_segments: 4 },
        )
        .unwrap();
        seg.train(&ds.train).unwrap();
        // stream in uneven batches so flushes land mid-stream
        let mut off = 0usize;
        for chunk in [200usize, 57, 343] {
            seg.insert(&ds.base[off * ds.dim..(off + chunk) * ds.dim], None).unwrap();
            off += chunk;
        }
        seg.flush().unwrap();
        seg.compact().unwrap();
        assert_eq!(seg.segment_stats().unwrap().segments, 1, "{width}");

        let mut one = IndexPq4FastScan::new_width(ds.dim, 8, width);
        one.train(&ds.train).unwrap();
        one.add(&ds.base).unwrap();
        one.seal().unwrap();

        let probe = one.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 20)).unwrap();
        let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
        for kind in [QueryKind::TopK { k: 10 }, QueryKind::Range { radius }] {
            for nq in [5usize, 1] {
                let req = QueryRequest {
                    queries: &ds.queries[..nq * ds.dim],
                    kind,
                    filter: None,
                    params: None,
                    trace: false,
                };
                let rs = seg.query_exec(&req, &exec).unwrap();
                let ro = one.query_exec(&req, &exec).unwrap();
                assert_eq!(rs.hits, ro.hits, "{width} {kind:?} nq={nq}");
            }
        }
    }
}

/// Acceptance: delete-then-query is bit-identical to querying an
/// undeleted twin with the deletion set composed into the filter —
/// across widths × kinds × filter shapes, with deletes spanning both
/// sealed segments (tombstones) and the memtable (direct removal).
#[test]
fn segment_delete_matches_composed_filter() {
    use armpq::exec::QueryExecutor;
    use armpq::pq::CodeWidth;
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    use std::collections::HashSet;
    let ds = SyntheticDataset::gaussian(500, 4, 32, 1401);
    let exec = QueryExecutor::new(4);
    // ids 0..399 end up sealed (two flushed batches), 400..499 memtable
    let deleted: Vec<i64> = (0..500).step_by(9).collect();
    let dset: HashSet<i64> = deleted.iter().copied().collect();
    let sparse: Vec<i64> = (0..500).step_by(3).collect();
    for width in CodeWidth::ALL {
        let build = || {
            let mut idx = SegmentedIndex::new(
                ds.dim,
                8,
                width,
                SegmentedParams { flush_threshold: 150, max_segments: 8 },
            )
            .unwrap();
            idx.train(&ds.train).unwrap();
            for (start, len) in [(0usize, 200usize), (200, 200), (400, 100)] {
                idx.insert(&ds.base[start * ds.dim..(start + len) * ds.dim], None).unwrap();
            }
            idx
        };
        let del = build();
        assert_eq!(del.delete(&deleted).unwrap(), deleted.len(), "{width}");
        let twin = build();

        let probe = twin.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 25)).unwrap();
        let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
        let users = [None, Some(Filter::id_range(40, 460)), Some(Filter::id_set(&sparse))];
        for kind in [QueryKind::TopK { k: 12 }, QueryKind::Range { radius }] {
            for user in &users {
                let rd = del
                    .query_exec(
                        &QueryRequest {
                            queries: &ds.queries,
                            kind,
                            filter: user.clone(),
                            params: None,
                            trace: false,
                        },
                        &exec,
                    )
                    .unwrap();
                let composed = {
                    let dset = dset.clone();
                    let user = user.clone();
                    Filter::predicate(move |id| {
                        !dset.contains(&id) && user.as_ref().map_or(true, |f| f.matches(id))
                    })
                };
                let rt = twin
                    .query_exec(
                        &QueryRequest {
                            queries: &ds.queries,
                            kind,
                            filter: Some(composed),
                            params: None,
                            trace: false,
                        },
                        &exec,
                    )
                    .unwrap();
                assert_eq!(rd.hits, rt.hits, "{width} {kind:?} user={user:?}");
            }
        }
    }
}

/// Acceptance: an interleaved insert/delete/flush/compact history ends
/// bit-identical to a fresh segmented index built in one shot from the
/// surviving rows with their surviving ids — at 1 and 4 executor threads,
/// batch and single-query paths.
#[test]
fn segment_compaction_equivalence() {
    use armpq::exec::QueryExecutor;
    use armpq::pq::CodeWidth;
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    use std::collections::BTreeSet;
    let ds = SyntheticDataset::gaussian(700, 5, 32, 1402);
    let dim = ds.dim;
    let mut idx = SegmentedIndex::new(
        dim,
        8,
        CodeWidth::W4,
        SegmentedParams { flush_threshold: 200, max_segments: 3 },
    )
    .unwrap();
    idx.train(&ds.train).unwrap();
    let mut live: BTreeSet<i64> = BTreeSet::new();
    live.extend(idx.insert(&ds.base[..300 * dim], None).unwrap());
    let d1: Vec<i64> = (0..300).step_by(11).collect();
    idx.delete(&d1).unwrap();
    for id in &d1 {
        live.remove(id);
    }
    live.extend(idx.insert(&ds.base[300 * dim..550 * dim], None).unwrap());
    // overlaps d1 on multiples of 11·17 — delete counts live rows only
    let d2: Vec<i64> = (100..500).step_by(17).collect();
    idx.delete(&d2).unwrap();
    for id in &d2 {
        live.remove(id);
    }
    idx.flush().unwrap();
    idx.compact().unwrap();
    live.extend(idx.insert(&ds.base[550 * dim..700 * dim], None).unwrap());
    let d3 = [560i64, 570, 5, 205]; // memtable and sealed rows alike
    idx.delete(&d3).unwrap();
    for id in &d3 {
        live.remove(id);
    }
    // end the history sealed: compaction folds tombstones away physically
    idx.flush().unwrap();
    idx.compact().unwrap();
    let st = idx.segment_stats().unwrap();
    assert_eq!((st.segments, st.tombstones, st.memtable_entries), (1, 0, 0));

    // one-shot twin: surviving rows, surviving ids, one insert
    let order: Vec<i64> = live.iter().copied().collect();
    let mut flat = Vec::with_capacity(order.len() * dim);
    for &id in &order {
        let r = id as usize;
        flat.extend_from_slice(&ds.base[r * dim..(r + 1) * dim]);
    }
    let mut one = SegmentedIndex::new(
        dim,
        8,
        CodeWidth::W4,
        SegmentedParams { flush_threshold: 100_000, max_segments: 8 },
    )
    .unwrap();
    one.train(&ds.train).unwrap();
    one.insert(&flat, Some(&order)).unwrap();
    one.flush().unwrap();
    one.compact().unwrap();
    assert_eq!(idx.ntotal(), one.ntotal());

    let probe = one.query(&QueryRequest::top_k(&ds.queries[..dim], 15)).unwrap();
    let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
    for threads in [1usize, 4] {
        let exec = QueryExecutor::new(threads);
        for kind in [QueryKind::TopK { k: 10 }, QueryKind::Range { radius }] {
            for nq in [5usize, 1] {
                let req = QueryRequest {
                    queries: &ds.queries[..nq * dim],
                    kind,
                    filter: None,
                    params: None,
                    trace: false,
                };
                let ri = idx.query_exec(&req, &exec).unwrap();
                let ro = one.query_exec(&req, &exec).unwrap();
                assert_eq!(ri.hits, ro.hits, "threads={threads} {kind:?} nq={nq}");
            }
        }
    }
}

/// Acceptance: on a live mixed structure (two sealed segments + populated
/// memtable + tombstones), results are bit-identical between 1- and
/// 4-thread executors for both kinds, filtered and not, including the
/// nq=1 intra-query fan-out across segments.
#[test]
fn segment_threads_differential() {
    use armpq::exec::QueryExecutor;
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    let ds = SyntheticDataset::gaussian(600, 5, 32, 1403);
    let mut seg = SegmentedIndex::new(
        ds.dim,
        8,
        armpq::pq::CodeWidth::W4,
        SegmentedParams { flush_threshold: 100, max_segments: 8 },
    )
    .unwrap();
    seg.train(&ds.train).unwrap();
    for (start, len) in [(0usize, 250usize), (250, 250), (500, 80)] {
        seg.insert(&ds.base[start * ds.dim..(start + len) * ds.dim], None).unwrap();
    }
    let dead: Vec<i64> = (0..580).step_by(13).collect();
    seg.delete(&dead).unwrap();
    let st = seg.segment_stats().unwrap();
    assert_eq!(st.segments, 2);
    assert!(st.memtable_entries > 0 && st.tombstones > 0);

    let exec1 = QueryExecutor::new(1);
    let exec4 = QueryExecutor::new(4);
    let probe = seg.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 20)).unwrap();
    let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
    for kind in [QueryKind::TopK { k: 9 }, QueryKind::Range { radius }] {
        for filter in [
            None,
            Some(Filter::id_range(30, 520)),
            Some(Filter::predicate(|id| id % 3 == 0)),
        ] {
            for nq in [5usize, 1] {
                let req = QueryRequest {
                    queries: &ds.queries[..nq * ds.dim],
                    kind,
                    filter: filter.clone(),
                    params: None,
                    trace: false,
                };
                let r1 = seg.query_exec(&req, &exec1).unwrap();
                let r4 = seg.query_exec(&req, &exec4).unwrap();
                assert_eq!(
                    r1.hits, r4.hits,
                    "{kind:?} {filter:?} nq={nq}: threaded hits diverge from serial"
                );
                let s1: Vec<_> = r1.stats.iter().map(core_stats).collect();
                let s4: Vec<_> = r4.stats.iter().map(core_stats).collect();
                assert_eq!(s1, s4, "{kind:?} nq={nq}: stats diverge");
                // 2 sealed segments + memtable = 3 scan units, both ways
                assert_eq!(r1.stats[0].segments_scanned, 3);
                assert_eq!(r4.stats[0].segments_scanned, 3);
            }
        }
    }
}

/// Lifecycle: the background worker is stoppable and restartable through
/// `stop_background` (idempotent both ways), keeps maintaining while
/// running, and the index stays fully usable — inline maintenance —
/// after an explicit stop. `Drop` reuses the same path, so the final
/// implicit drop of a stopped index is a no-op join.
#[test]
fn segment_background_worker_stop_and_restart() {
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    let ds = SyntheticDataset::gaussian(500, 4, 32, 1407);
    let dim = ds.dim;
    let seg = {
        let mut s = SegmentedIndex::new(
            dim,
            8,
            armpq::pq::CodeWidth::W4,
            SegmentedParams { flush_threshold: 64, max_segments: 4 },
        )
        .unwrap();
        s.train(&ds.train).unwrap();
        s
    };
    // stop without a worker: no-op
    seg.stop_background();
    seg.spawn_background();
    seg.spawn_background(); // idempotent spawn
    seg.insert(&ds.base[..300 * dim], None).unwrap();
    // the worker must flush the over-threshold memtable on its own
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while seg.segment_stats().unwrap().flushes == 0 {
        assert!(std::time::Instant::now() < deadline, "background worker never flushed");
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    seg.stop_background();
    seg.stop_background(); // idempotent stop
    // still fully usable: maintenance reverts to inline on the write path
    seg.insert(&ds.base[300 * dim..500 * dim], None).unwrap();
    seg.flush().unwrap();
    seg.compact().unwrap();
    assert_eq!(seg.ntotal(), 500);
    let r = seg.query(&QueryRequest::top_k(&ds.queries[..dim], 5)).unwrap();
    assert_eq!(r.hits[0].len(), 5);
    // and restartable: a second worker generation picks up new inserts
    seg.spawn_background();
    seg.delete(&[1, 2]).unwrap();
    assert_eq!(seg.ntotal(), 498);
    // drop with the worker running exercises the Drop → stop_background path
}

/// Smoke: concurrent inserts/deletes (with the background worker
/// flushing and compacting underneath) never produce a malformed or
/// failed query — readers ride immutable snapshots.
#[test]
fn segment_concurrent_insert_query_smoke() {
    use armpq::exec::QueryExecutor;
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    let ds = SyntheticDataset::gaussian(700, 4, 32, 1404);
    let dim = ds.dim;
    let mut seg = SegmentedIndex::new(
        dim,
        8,
        armpq::pq::CodeWidth::W4,
        SegmentedParams { flush_threshold: 64, max_segments: 4 },
    )
    .unwrap();
    seg.train(&ds.train).unwrap();
    seg.insert(&ds.base[..100 * dim], None).unwrap();
    seg.spawn_background();
    let seg = Arc::new(seg);

    let writer = {
        let seg = seg.clone();
        let base = ds.base.clone();
        std::thread::spawn(move || {
            for i in 100..600usize {
                seg.insert(&base[i * dim..(i + 1) * dim], None).unwrap();
                if i % 7 == 0 {
                    seg.delete(&[i as i64 - 50]).unwrap();
                }
            }
        })
    };
    let mut readers = Vec::new();
    for _ in 0..3 {
        let seg = seg.clone();
        let queries = ds.queries.clone();
        readers.push(std::thread::spawn(move || {
            let exec = QueryExecutor::new(2);
            for round in 0..50usize {
                let q = &queries[(round % 4) * dim..(round % 4 + 1) * dim];
                let r = seg.query_exec(&QueryRequest::top_k(q, 5), &exec).unwrap();
                let hits = &r.hits[0];
                assert!(hits.windows(2).all(|w| w[0].distance <= w[1].distance));
                assert!(hits.iter().all(|h| h.label >= 0));
            }
        }));
    }
    writer.join().unwrap();
    for r in readers {
        r.join().unwrap();
    }
    seg.flush().unwrap();
    seg.compact().unwrap();
    // 600 inserted, 71 deleted (i in 100..600 with i % 7 == 0)
    assert_eq!(seg.ntotal(), 600 - 71);
    let st = seg.segment_stats().unwrap();
    assert_eq!((st.segments, st.tombstones, st.memtable_entries), (1, 0, 0));
}

/// Persistence: a manifest + per-segment files round-trip reproduces the
/// exact structure (segments, memtable, tombstones) and bit-identical
/// answers, and the loaded index keeps streaming without id collisions.
#[test]
fn segment_persistence_roundtrip() {
    use armpq::exec::QueryExecutor;
    use armpq::index::io::{load_segmented, save_segmented};
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    let ds = SyntheticDataset::gaussian(400, 3, 32, 1405);
    let mut seg = SegmentedIndex::new(
        ds.dim,
        8,
        armpq::pq::CodeWidth::W4,
        SegmentedParams { flush_threshold: 120, max_segments: 8 },
    )
    .unwrap();
    seg.train(&ds.train).unwrap();
    for (start, len) in [(0usize, 150usize), (150, 150), (300, 60)] {
        seg.insert(&ds.base[start * ds.dim..(start + len) * ds.dim], None).unwrap();
    }
    let dead: Vec<i64> = (0..350).step_by(10).collect();
    seg.delete(&dead).unwrap();

    let dir = std::env::temp_dir().join(format!("armpq_seg_io_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("seg.idx");
    save_segmented(&seg, &path).unwrap();
    let loaded = load_segmented(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let (a, b) = (seg.segment_stats().unwrap(), loaded.segment_stats().unwrap());
    assert_eq!(
        (a.segments, a.memtable_entries, a.tombstones),
        (b.segments, b.memtable_entries, b.tombstones)
    );
    assert_eq!(seg.ntotal(), loaded.ntotal());
    let exec = QueryExecutor::new(2);
    let probe = seg.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 15)).unwrap();
    let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
    for kind in [QueryKind::TopK { k: 8 }, QueryKind::Range { radius }] {
        let req = QueryRequest { queries: &ds.queries, kind, filter: None, params: None, trace: false };
        assert_eq!(
            seg.query_exec(&req, &exec).unwrap().hits,
            loaded.query_exec(&req, &exec).unwrap().hits,
            "{kind:?}"
        );
    }
    // streaming resumes past the persisted id counter
    let more = loaded.insert(&ds.base[..2 * ds.dim], None).unwrap();
    assert!(more.iter().all(|&id| id >= 360), "{more:?}");
}

/// The factory + trait-object + serving-adapter flow: "SEG…" specs build
/// a streaming index behind `Box<dyn Index>`, sealed-only indexes refuse
/// the streaming verbs, and the generic backend adapter serves it with
/// segment stats attached.
#[test]
fn segment_factory_trait_object_flow() {
    use armpq::coordinator::{IndexBackend, SearchBackend};
    let ds = SyntheticDataset::gaussian(500, 4, 32, 1406);
    let mut idx = index_factory(ds.dim, "SEG128,PQ8x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    let ids = idx.insert(&ds.base, None).unwrap();
    assert_eq!(ids.len(), 500);
    assert_eq!(idx.delete(&[0, 1, 2]).unwrap(), 3);
    assert_eq!(idx.ntotal(), 497);
    assert!(idx.segment_stats().unwrap().segments >= 1);
    assert!(idx.describe().starts_with("SEG(PQ8x4fs"), "{}", idx.describe());

    // sealed single-segment indexes refuse the streaming verbs
    let sealed = index_factory(ds.dim, "PQ8x4fs").unwrap();
    assert!(sealed.insert(&ds.base[..ds.dim], None).is_err());
    assert!(sealed.delete(&[1]).is_err());
    assert!(sealed.segment_stats().is_none());

    let backend = IndexBackend::new(Arc::from(idx)).unwrap();
    let resp = backend.query_batch(&QueryRequest::top_k(&ds.queries, 5)).unwrap();
    assert_eq!(resp.hits.len(), ds.nq());
    assert!(resp.stats[0].segments_scanned >= 1);
    for hits in &resp.hits {
        assert!(!hits.is_empty());
        assert!(hits.iter().all(|h| h.label > 2), "{hits:?}");
    }
}

// ---------------------------------------------------------------------------
// storage_: zero-copy mmap loading vs heap loading, differentially.
//
// Format v3 lays packed code regions out 64-byte-aligned so a mapped open
// can hand them to the kernels in place. These tests hold the storage
// layer to the only spec that matters: a mapped index is *bit-identical*
// to a heap-loaded one under every backend, width, query kind and filter,
// and a damaged file fails cleanly instead of answering wrong.
// ---------------------------------------------------------------------------

fn storage_tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("armpq_storage_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run every (backend × kind × filter) combination against two indexes
/// and demand bit-identical responses plus the expected mapped-bytes
/// accounting on the mapped side.
fn storage_assert_differential(
    heap: &dyn Index,
    mapped: &dyn Index,
    queries: &[f32],
    radius: f32,
    tag: &str,
) {
    for backend in armpq::simd::available_backends() {
        let params = SearchParams::new().with_backend(backend);
        for kind in [QueryKind::TopK { k: 10 }, QueryKind::Range { radius }] {
            for filter in [None, Some(Filter::id_range(3, 700))] {
                let req = QueryRequest {
                    queries,
                    kind,
                    filter: filter.clone(),
                    params: Some(params.clone()),
                    trace: false,
                };
                let h = heap.query(&req).unwrap();
                let m = mapped.query(&req).unwrap();
                assert_eq!(h.hits, m.hits, "{tag} {backend:?} {kind:?} filter={:?}", filter.is_some());
                assert!(
                    h.stats.iter().all(|s| s.bytes_mapped == 0),
                    "{tag}: heap load reported mapped bytes"
                );
                assert!(
                    m.stats.iter().all(|s| s.bytes_mapped > 0),
                    "{tag}: mapped load reported no mapped bytes"
                );
            }
        }
    }
}

/// Flat fastscan: save v3, reopen heap + mapped across all three widths;
/// the mapped code block must be a zero-copy 64-byte-aligned window and
/// every query surface must agree bit-for-bit.
#[test]
fn storage_mmap_heap_differential_flat() {
    use armpq::index::io::{load_pq4fs_with, save_pq4fs};
    use armpq::index::IndexPq4FastScan;
    use armpq::pq::CodeWidth;
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(900, 4, 32, 1501);
    let dir = storage_tmpdir("flat");
    let opens_before = armpq::storage::counters().mmap_open_total();
    for width in CodeWidth::ALL {
        let mut idx = IndexPq4FastScan::new_width(ds.dim, 8, width);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let path = dir.join(format!("flat_{width}.idx"));
        save_pq4fs(&idx, &path).unwrap();

        let heap = load_pq4fs_with(&path, &OpenOptions::heap()).unwrap();
        let mapped = load_pq4fs_with(&path, &OpenOptions::mapped()).unwrap();
        let packed = mapped.packed().unwrap();
        assert!(packed.data.is_mapped(), "{width}");
        assert_eq!(packed.data[..].as_ptr() as usize % 64, 0, "{width}: unaligned code region");
        assert!(packed.mapped_bytes() > 0, "{width}");
        assert!(heap.packed().unwrap().mapped_bytes() == 0, "{width}");

        let probe = heap.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 90)).unwrap();
        let radius = probe.hits[0].last().unwrap().distance;
        storage_assert_differential(&heap, &mapped, &ds.queries, radius, &format!("flat {width}"));
    }
    assert!(
        armpq::storage::counters().mmap_open_total() >= opens_before + 3,
        "mapped opens not counted"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// IVF fastscan: per-list packed regions load zero-copy and answer
/// identically to the heap load across widths, backends, kinds, filters.
#[test]
fn storage_mmap_heap_differential_ivf() {
    use armpq::index::io::{load_ivfpq4_with, save_ivfpq4};
    use armpq::index::IndexIvfPq4;
    use armpq::pq::CodeWidth;
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(1_200, 4, 32, 1502);
    let dir = storage_tmpdir("ivf");
    for width in CodeWidth::ALL {
        let mut idx = IndexIvfPq4::new_width(ds.dim, 12, 8, width, false, 32);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        idx.set_param("nprobe", "12").unwrap();
        let path = dir.join(format!("ivf_{width}.idx"));
        save_ivfpq4(idx.inner(), &path).unwrap();

        let mut heap =
            IndexIvfPq4::from_inner(load_ivfpq4_with(&path, &OpenOptions::heap()).unwrap());
        let mut mapped =
            IndexIvfPq4::from_inner(load_ivfpq4_with(&path, &OpenOptions::mapped()).unwrap());
        // probe everything so the differential exercises every list
        heap.set_param("nprobe", "12").unwrap();
        mapped.set_param("nprobe", "12").unwrap();
        // every non-empty list is a mapped, cache-line-aligned window
        for c in 0..12 {
            if let Some(p) = mapped.inner().list_packed(c) {
                assert!(p.data.is_mapped(), "{width} list {c}");
                assert_eq!(p.data[..].as_ptr() as usize % 64, 0, "{width} list {c}");
            }
        }
        let probe = heap.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 90)).unwrap();
        let radius = probe.hits[0].last().unwrap().distance;
        storage_assert_differential(&heap, &mapped, &ds.queries, radius, &format!("ivf {width}"));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Segmented: a multi-segment index with tombstones round-trips through
/// v3, answers identically mapped vs heap, and stays *writable* after a
/// zero-copy open (mapped rows must survive the next flush).
#[test]
fn storage_mmap_heap_differential_segmented() {
    use armpq::index::io::{load_segmented_with, save_segmented};
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(600, 4, 32, 1503);
    let dir = storage_tmpdir("seg");
    let mut seg = SegmentedIndex::new(
        ds.dim,
        8,
        armpq::pq::CodeWidth::W4,
        SegmentedParams { flush_threshold: 150, max_segments: 8 },
    )
    .unwrap();
    seg.train(&ds.train).unwrap();
    seg.insert(&ds.base, None).unwrap();
    seg.delete(&(0..60).step_by(3).collect::<Vec<i64>>()).unwrap();
    seg.flush().unwrap();
    let path = dir.join("seg.idx");
    save_segmented(&seg, &path).unwrap();

    let heap = load_segmented_with(&path, &OpenOptions::heap()).unwrap();
    let mapped = load_segmented_with(&path, &OpenOptions::mapped()).unwrap();
    assert_eq!(heap.ntotal(), mapped.ntotal());
    let probe = heap.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 60)).unwrap();
    let radius = probe.hits[0].last().unwrap().distance;
    storage_assert_differential(&heap, &mapped, &ds.queries, radius, "segmented");

    // a mapped index keeps streaming: new rows land next to mapped
    // segments and compaction rematerializes mapped codes losslessly
    let before = mapped.ntotal();
    mapped.insert(&ds.base[..4 * ds.dim], Some(&[9001, 9002, 9003, 9004])).unwrap();
    mapped.flush().unwrap();
    mapped.compact().unwrap();
    assert_eq!(mapped.ntotal(), before + 4);
    let r = mapped.query(&QueryRequest::top_k(&ds.base[..ds.dim], 5)).unwrap();
    assert!(r.hits[0].iter().any(|h| h.label == 9001), "{:?}", r.hits[0]);
    std::fs::remove_dir_all(&dir).ok();
}

/// Truncations at every section boundary (and a few unaligned offsets)
/// plus corrupted magic must all fail with `Error::CorruptIndex` — never
/// panic, never return a half-loaded index — under heap and mapped opens.
#[test]
fn storage_truncated_and_corrupt_files_fail_cleanly() {
    use armpq::index::io::{load_pq4fs_with, open_index, save_pq4fs};
    use armpq::index::IndexPq4FastScan;
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(400, 2, 16, 1504);
    let dir = storage_tmpdir("corrupt");
    let mut idx = IndexPq4FastScan::new(ds.dim, 8);
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let path = dir.join("flat.idx");
    save_pq4fs(&idx, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    let cut = dir.join("cut.idx");
    for len in [0usize, 4, 7, 8, 12, 16, 40, bytes.len() / 2, bytes.len() - 1] {
        std::fs::write(&cut, &bytes[..len]).unwrap();
        for opts in [OpenOptions::heap(), OpenOptions::mapped()] {
            match load_pq4fs_with(&cut, &opts) {
                Err(armpq::Error::CorruptIndex(_)) => {}
                other => panic!("truncate@{len} opts={opts:?}: {:?}", other.map(|_| ())),
            }
        }
    }
    // flipped magic: rejected by the typed loader and by open_index
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    std::fs::write(&cut, &bad).unwrap();
    assert!(matches!(
        load_pq4fs_with(&cut, &OpenOptions::heap()),
        Err(armpq::Error::CorruptIndex(_))
    ));
    assert!(matches!(
        open_index(&cut, &OpenOptions::mapped()),
        Err(armpq::Error::CorruptIndex(_))
    ));
    // and no half-written temp files ever survive a save
    assert!(std::fs::read_dir(&dir)
        .unwrap()
        .all(|e| !e.unwrap().file_name().to_string_lossy().ends_with(".tmp")));
    std::fs::remove_dir_all(&dir).ok();
}

/// A budget-capped mapped open (1 MiB — far below the code region) must
/// still answer bit-identically: the budget controls *residency advice*,
/// never correctness.
#[test]
fn storage_budget_capped_open_is_correct() {
    use armpq::index::io::{load_pq4fs_with, save_pq4fs};
    use armpq::index::IndexPq4FastScan;
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(2_000, 4, 32, 1505);
    let dir = storage_tmpdir("budget");
    let mut idx = IndexPq4FastScan::new(ds.dim, 16);
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let path = dir.join("flat.idx");
    save_pq4fs(&idx, &path).unwrap();

    let heap = load_pq4fs_with(&path, &OpenOptions::heap()).unwrap();
    for budget_mb in [0u64, 1] {
        let capped = load_pq4fs_with(
            &path,
            &OpenOptions { mmap: true, budget_mb: Some(budget_mb) },
        )
        .unwrap();
        assert!(capped.packed().unwrap().data.is_mapped());
        let a = heap.query(&QueryRequest::top_k(&ds.queries, 10)).unwrap();
        let b = capped.query(&QueryRequest::top_k(&ds.queries, 10)).unwrap();
        assert_eq!(a.hits, b.hits, "budget_mb={budget_mb}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// v3 saves are stable fixed points: save → load (heap and mapped) →
/// save again produces byte-identical files, so re-saving a loaded index
/// never silently rewrites or migrates content.
#[test]
fn storage_v3_roundtrip_is_idempotent() {
    use armpq::index::io::{load_pq4fs_with, save_pq4fs};
    use armpq::index::IndexPq4FastScan;
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(500, 2, 32, 1506);
    let dir = storage_tmpdir("fixpoint");
    let mut idx = IndexPq4FastScan::new(ds.dim, 8);
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let p1 = dir.join("a.idx");
    save_pq4fs(&idx, &p1).unwrap();
    for opts in [OpenOptions::heap(), OpenOptions::mapped()] {
        let loaded = load_pq4fs_with(&p1, &opts).unwrap();
        let p2 = dir.join("b.idx");
        save_pq4fs(&loaded, &p2).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "re-save after {opts:?} load changed bytes"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The generic `open_index` entry point dispatches every v3 kind and
/// respects open options — the path `serve --index-file` takes.
#[test]
fn storage_open_index_dispatches_kinds() {
    use armpq::index::io::{open_index, save_ivfpq4, save_pq4fs, save_segmented};
    use armpq::index::{IndexIvfPq4, IndexPq4FastScan};
    use armpq::segment::{SegmentedIndex, SegmentedParams};
    use armpq::storage::OpenOptions;
    let ds = SyntheticDataset::gaussian(500, 4, 32, 1507);
    let dir = storage_tmpdir("open");

    let mut flat = IndexPq4FastScan::new(ds.dim, 8);
    flat.train(&ds.train).unwrap();
    flat.add(&ds.base).unwrap();
    flat.seal().unwrap();
    save_pq4fs(&flat, &dir.join("flat.idx")).unwrap();

    let mut ivf = IndexIvfPq4::new_width(ds.dim, 8, 8, armpq::pq::CodeWidth::W4, false, 32);
    ivf.train(&ds.train).unwrap();
    ivf.add(&ds.base).unwrap();
    ivf.seal().unwrap();
    save_ivfpq4(ivf.inner(), &dir.join("ivf.idx")).unwrap();

    let mut seg = SegmentedIndex::new(
        ds.dim,
        8,
        armpq::pq::CodeWidth::W4,
        SegmentedParams { flush_threshold: 200, max_segments: 8 },
    )
    .unwrap();
    seg.train(&ds.train).unwrap();
    seg.insert(&ds.base, None).unwrap();
    seg.flush().unwrap();
    save_segmented(&seg, &dir.join("seg.idx")).unwrap();

    for (name, describe_frag) in [("flat.idx", "PQ8x4fs"), ("ivf.idx", "IVF8"), ("seg.idx", "SEG")]
    {
        for opts in [OpenOptions::heap(), OpenOptions::mapped()] {
            let opened = open_index(&dir.join(name), &opts).unwrap();
            assert_eq!(opened.ntotal(), 500, "{name} {opts:?}");
            assert!(
                opened.describe().contains(describe_frag),
                "{name}: {}",
                opened.describe()
            );
            let r = opened.query(&QueryRequest::top_k(&ds.queries, 5)).unwrap();
            assert_eq!(r.nq(), ds.nq(), "{name} {opts:?}");
            assert!(r.hits.iter().all(|row| !row.is_empty()), "{name} {opts:?}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ───────────────────────── observability (obs_) ─────────────────────────

/// The differential guarantee of the tracing layer: `trace: true` returns
/// bit-identical hits and stats to `trace: false`, on every index family,
/// at 1 and 4 executor threads, for top-k and range — plus exactly one
/// span row per query when tracing and none otherwise.
#[test]
fn obs_trace_identical_results() {
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::sift_like(2_000, 6, 4101);
    let builders: Vec<(&str, Box<dyn Index>)> = vec![
        ("flat", index_factory(ds.dim, "PQ8x4fs").unwrap()),
        ("ivf", index_factory(ds.dim, "IVF8,PQ8x4fs,nprobe=8").unwrap()),
        ("seg", index_factory(ds.dim, "SEG256,PQ8x4fs").unwrap()),
    ];
    for (name, mut idx) in builders {
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let probe = idx.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 20)).unwrap();
        let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
        for threads in [1usize, 4] {
            let exec = QueryExecutor::new(threads);
            for kind in [QueryKind::TopK { k: 7 }, QueryKind::Range { radius }] {
                let plain = QueryRequest {
                    queries: &ds.queries,
                    kind,
                    filter: None,
                    params: None,
                    trace: false,
                };
                let traced = plain.clone().with_trace();
                let r0 = idx.query_exec(&plain, &exec).unwrap();
                let r1 = idx.query_exec(&traced, &exec).unwrap();
                assert_eq!(r0.hits, r1.hits, "{name} t={threads} {kind:?}: hits diverge");
                assert_eq!(r0.stats, r1.stats, "{name} t={threads} {kind:?}: stats diverge");
                assert!(r0.traces.is_empty(), "{name}: untraced response carries spans");
                assert_eq!(
                    r1.traces.len(),
                    ds.nq(),
                    "{name} t={threads} {kind:?}: one span row per query"
                );
                for (qi, spans) in r1.traces.iter().enumerate() {
                    assert!(
                        spans.iter().any(|s| s.phase == armpq::obs::Phase::Total),
                        "{name} q{qi}: no total span in {spans:?}"
                    );
                }
            }
        }
    }
}

/// Phase accounting: on a serial executor every phase is a wall-clock
/// leaf, so the per-phase sum must land close to the query's own total
/// span — the breakdown explains the latency instead of inventing one.
#[test]
fn obs_phase_sum_tracks_total() {
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::sift_like(30_000, 4, 4102);
    let mut idx = index_factory(ds.dim, "IVF32,PQ16x4fs,nprobe=32").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let exec = QueryExecutor::new(1);
    let req = QueryRequest::top_k(&ds.queries, 10).with_trace();
    // warm once so page-in/lazy-init noise lands outside the measured run
    idx.query_exec(&req, &exec).unwrap();
    let resp = idx.query_exec(&req, &exec).unwrap();
    for (qi, spans) in resp.traces.iter().enumerate() {
        let total = armpq::obs::total_us(spans).expect("total span");
        let sum = armpq::obs::phase_sum_us(spans);
        // the phases must explain the total: at least 70% covered (glue
        // between spans is untimed) and never exceeding it by >10% + 50µs
        // of timer quantization slack
        assert!(
            sum * 10 >= total * 7,
            "q{qi}: phases {sum}µs explain too little of total {total}µs: {spans:?}"
        );
        assert!(
            sum <= total + total / 10 + 50,
            "q{qi}: phases {sum}µs exceed total {total}µs: {spans:?}"
        );
    }
}

/// The <2%-overhead-when-off budget, enforced structurally: after warmup,
/// untraced steady-state queries allocate no new scratch arenas and the
/// scratch high-water mark stays put — the TraceBuf lives inline in
/// pooled scratch and never touches the heap while disabled.
#[test]
fn obs_steady_state_no_alloc_when_off() {
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::sift_like(4_000, 8, 4103);
    let mut idx = index_factory(ds.dim, "IVF16,PQ8x4fs,nprobe=8").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let exec = QueryExecutor::new(2);
    let req = QueryRequest::top_k(&ds.queries, 10);
    for _ in 0..3 {
        idx.query_exec(&req, &exec).unwrap();
    }
    let arenas = exec.scratch_arenas_created();
    let high_water = exec.scratch_high_water_bytes();
    for _ in 0..20 {
        idx.query_exec(&req, &exec).unwrap();
    }
    assert_eq!(exec.scratch_arenas_created(), arenas, "steady state allocated arenas");
    assert_eq!(exec.scratch_high_water_bytes(), high_water, "scratch grew in steady state");
    // a traced query re-uses the same pooled scratch too
    idx.query_exec(&req.clone().with_trace(), &exec).unwrap();
    assert_eq!(exec.scratch_arenas_created(), arenas, "tracing allocated arenas");
}

/// The traced wire path against a segmented (mutable) backend: the client
/// parses every stats field and the span array, segment phases show up,
/// and tracing changes nothing about the hits.
#[test]
fn obs_client_parses_stats_and_trace() {
    use armpq::coordinator::{service::IndexBackend, SearchBackend};
    use armpq::obs::Phase;
    let ds = SyntheticDataset::sift_like(1_500, 8, 4104);
    let mut idx = index_factory(ds.dim, "SEG256,PQ8x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    let backend: Arc<dyn SearchBackend> = Arc::new(IndexBackend::new(Arc::from(idx)).unwrap());
    let server = Server::start(backend, ServerConfig::default()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    let rows: Vec<Vec<f32>> =
        (0..600).map(|i| ds.base[i * ds.dim..(i + 1) * ds.dim].to_vec()).collect();
    client.insert(&rows, None).unwrap();
    let q = &ds.queries[..ds.dim];
    let (plain_hits, _) = client.query(q, &QueryKind::TopK { k: 5 }, None, None).unwrap();
    let (hits, stats, spans) =
        client.query_traced(q, &QueryKind::TopK { k: 5 }, None, None).unwrap();
    assert_eq!(hits, plain_hits, "tracing changed wire results");
    assert!(stats.codes_scanned > 0);
    assert!(stats.segments_scanned >= 1, "{stats:?}");
    assert!(spans.iter().any(|s| s.phase == Phase::Total && s.us > 0), "{spans:?}");
    assert!(
        spans.iter().any(|s| s.phase == Phase::SegmentScan || s.phase == Phase::MemtableScan),
        "no segment/memtable scan phase in {spans:?}"
    );
    let scan_counts: u64 = spans
        .iter()
        .filter(|s| {
            matches!(s.phase, Phase::ListScan | Phase::SegmentScan | Phase::MemtableScan)
        })
        .map(|s| s.count)
        .sum();
    assert!(scan_counts > 0, "scan spans carry no code counts: {spans:?}");
    server.stop();
}

/// The `metrics` verb emits well-formed Prometheus text exposition:
/// exactly one `# TYPE` per family, monotone cumulative buckets, and all
/// the families the JSON stats verb exposes — phases and residency
/// included.
#[test]
fn obs_prometheus_exposition_valid() {
    let ds = SyntheticDataset::sift_like(2_000, 10, 4105);
    let mut params = IvfParams::new(8);
    params.coarse_hnsw = false;
    let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(8));
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.nprobe = 8;
    let backend = Arc::new(IvfBackend::new(idx).unwrap());
    let server = Server::start(backend, ServerConfig::default()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    for qi in 0..ds.nq() {
        if qi % 2 == 0 {
            client.query_traced(ds.query(qi), &QueryKind::TopK { k: 5 }, None, None).unwrap();
        } else {
            client.search(ds.query(qi), 5).unwrap();
        }
    }
    let text = client.metrics_text().unwrap();
    // one # TYPE line per family
    let mut seen = std::collections::HashSet::new();
    for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
        let fam = line.split_whitespace().nth(2).unwrap();
        assert!(seen.insert(fam.to_string()), "duplicate # TYPE for {fam}\n{text}");
    }
    for fam in [
        "armpq_requests_total",
        "armpq_errors_total",
        "armpq_exec_threads",
        "armpq_e2e_us",
        "armpq_queue_us",
        "armpq_service_us",
        "armpq_batch_latency_us",
        "armpq_codes_scanned",
        "armpq_batch_occupancy",
        "armpq_phase_us",
        "armpq_resident_sampled_bytes",
    ] {
        assert!(seen.contains(fam), "family {fam} missing from exposition\n{text}");
    }
    // cumulative histogram buckets are monotone and end at the count
    let buckets: Vec<u64> = text
        .lines()
        .filter(|l| l.starts_with("armpq_e2e_us_bucket"))
        .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
        .collect();
    assert!(!buckets.is_empty());
    assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "non-monotone: {buckets:?}");
    let count: u64 = text
        .lines()
        .find(|l| l.starts_with("armpq_e2e_us_count"))
        .and_then(|l| l.split_whitespace().last())
        .unwrap()
        .parse()
        .unwrap();
    assert_eq!(*buckets.last().unwrap(), count);
    assert_eq!(count, ds.nq() as u64);
    // traced queries fed the per-phase histograms
    assert!(
        text.contains("armpq_phase_us_count{phase=\"total\"}"),
        "phase histograms empty\n{text}"
    );
    server.stop();
}

/// The slow-query log is bounded, sorted worst-first, and keeps the trace
/// of queries that asked for one.
#[test]
fn obs_slowlog_bounded() {
    let ds = SyntheticDataset::sift_like(2_000, 30, 4106);
    let mut idx = IvfPq4::new(ds.dim, IvfParams::new(8), PqParams::new_4bit(8));
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.nprobe = 8;
    let backend = Arc::new(IvfBackend::new(idx).unwrap());
    let server = Server::start(backend, ServerConfig::default()).unwrap();
    let mut client = Client::connect(&server.addr).unwrap();
    for qi in 0..ds.nq() {
        client.query_traced(ds.query(qi), &QueryKind::TopK { k: 5 }, None, None).unwrap();
    }
    let log = client.slowlog().unwrap();
    let rows = log.as_arr().unwrap();
    assert!(!rows.is_empty() && rows.len() <= 8, "slowlog has {} entries", rows.len());
    let e2e: Vec<f64> =
        rows.iter().map(|r| r.get("e2e_us").and_then(|x| x.as_f64()).unwrap()).collect();
    assert!(e2e.windows(2).all(|w| w[0] >= w[1]), "slowlog not worst-first: {e2e:?}");
    // every entry was a traced query, so its span breakdown rode along
    assert!(
        rows[0].get("trace").and_then(|t| t.as_arr()).is_some_and(|t| !t.is_empty()),
        "worst entry lost its trace: {}",
        log.to_string()
    );
    server.stop();
}

// ------------------------------------------------------------------- exec
//
// The exec_ tests below are the acceptance suite of the persistent worker
// pool: pool-backed executors must be bit-identical to the scoped-thread
// baseline (`QueryExecutor::new_scoped`) at every thread count, across
// kinds, filters, batch and intra-query fan-out, and through the sharded
// router with NUMA placement. CI runs them as named steps under
// ARMPQ_THREADS=1 and ARMPQ_THREADS=4 on both architectures.

/// Acceptance: the pool-backed executor returns exactly what the
/// per-call scoped-thread executor returns, for every thread count ×
/// kind × filter × batch size, on an IVF index (batch fan-out at nq > 1,
/// multi-list fan-out at nq = 1) — work-stealing moves where a unit
/// runs, never what it computes.
#[test]
fn exec_pool_matches_scoped_full_stack() {
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::gaussian(800, 6, 32, 1600);
    let mut idx = index_factory(ds.dim, "IVF16,PQ8x4fs,nprobe=8").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let probe = idx.query(&QueryRequest::top_k(&ds.queries[..ds.dim], 20)).unwrap();
    let radius = probe.hits[0].last().map(|h| h.distance * 1.01).unwrap_or(1.0);
    let serial_ref = idx
        .query_exec(&QueryRequest::top_k(&ds.queries, 9), &QueryExecutor::new_scoped(1))
        .unwrap();
    for threads in [1usize, 2, 4] {
        let pooled = QueryExecutor::new(threads);
        let scoped = QueryExecutor::new_scoped(threads);
        for kind in [QueryKind::TopK { k: 9 }, QueryKind::Range { radius }] {
            for filter in [None, Some(Filter::id_range(100, 600))] {
                for nq in [6usize, 1] {
                    let req = QueryRequest {
                        queries: &ds.queries[..nq * ds.dim],
                        kind,
                        filter: filter.clone(),
                        params: None,
                        trace: false,
                    };
                    let rp = idx.query_exec(&req, &pooled).unwrap();
                    let rs = idx.query_exec(&req, &scoped).unwrap();
                    assert_eq!(
                        rp.hits, rs.hits,
                        "threads={threads} {kind:?} {filter:?} nq={nq}: pool ≠ scoped"
                    );
                    let sp: Vec<_> = rp.stats.iter().map(core_stats).collect();
                    let ss: Vec<_> = rs.stats.iter().map(core_stats).collect();
                    assert_eq!(sp, ss, "threads={threads} {kind:?} nq={nq}: stats diverge");
                }
            }
        }
        // and both agree with the 1-thread scoped reference
        let rp = idx.query_exec(&QueryRequest::top_k(&ds.queries, 9), &pooled).unwrap();
        assert_eq!(rp.hits, serial_ref.hits, "threads={threads}: pool ≠ serial reference");
    }
}

/// The sharded router on the pool: shards are interleaved across NUMA
/// nodes at construction, fan out through `run_shards` with node-tagged
/// units, and a 4-thread pooled router answers bit-identically to a
/// 1-thread scoped one. The process-global steal/task counters only ever
/// grow.
#[test]
fn exec_router_numa_placement_and_pool_counters() {
    use armpq::coordinator::{SearchBackend, ShardedBackend};
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::sift_like(1_800, 6, 1601);
    let dim = ds.dim;
    let per = 600usize;
    let build_shards = || -> Vec<Arc<dyn Index>> {
        (0..3)
            .map(|s| {
                let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(8));
                idx.train(&ds.train).unwrap();
                let slice = &ds.base[s * per * dim..(s + 1) * per * dim];
                let ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
                idx.add_with_ids(slice, &ids).unwrap();
                idx.nprobe = 4;
                idx.seal().unwrap();
                Arc::new(armpq::index::IndexIvfPq4::from_inner(idx)) as Arc<dyn Index>
            })
            .collect()
    };
    let tasks_before = armpq::exec::pool::counters()
        .tasks_executed
        .load(std::sync::atomic::Ordering::Relaxed);
    let pooled =
        ShardedBackend::from_indexes_with_executor(build_shards(), QueryExecutor::new(4)).unwrap();
    let scoped = ShardedBackend::from_indexes_with_executor(build_shards(), QueryExecutor::new_scoped(1))
        .unwrap();
    // placement: one node entry per shard, round-robin over real nodes
    let nodes = pooled.shard_nodes();
    let nnodes = armpq::exec::pool::topology().node_count().max(1);
    assert_eq!(nodes.len(), 3);
    for (i, &nd) in nodes.iter().enumerate() {
        assert_eq!(nd, i % nnodes, "shard {i} not interleaved: {nodes:?}");
    }
    let req = QueryRequest::top_k(&ds.queries, 5).with_filter(Filter::id_range(0, 1_500));
    let rp = pooled.query_batch(&req).unwrap();
    let rs = scoped.query_batch(&req).unwrap();
    assert_eq!(rp.hits, rs.hits, "pooled router ≠ scoped router");
    let tasks_after = armpq::exec::pool::counters()
        .tasks_executed
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(tasks_after >= tasks_before, "pool task counter went backwards");
}

/// `QueryStats.threads_used` reports measured pool participation — never
/// more than the executor budget or the batch width — and the pool
/// snapshot surfaces worker count and per-worker busy fractions.
#[test]
fn exec_stats_report_measured_fanout() {
    use armpq::exec::QueryExecutor;
    let ds = SyntheticDataset::gaussian(600, 8, 32, 1602);
    let mut idx = index_factory(ds.dim, "PQ8x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let exec = QueryExecutor::new(4);
    let r = idx.query_exec(&QueryRequest::top_k(&ds.queries, 5), &exec).unwrap();
    for s in &r.stats {
        assert!(s.threads_used >= 1 && s.threads_used <= 4, "threads_used {}", s.threads_used);
    }
    // single-query batch: the fan-out cannot exceed the batch width
    let r1 = idx
        .query_exec(&QueryRequest::top_k(&ds.queries[..ds.dim], 5), &exec)
        .unwrap();
    assert_eq!(r1.stats[0].threads_used, 1, "nq=1 flat query must report one participant");
    let pool = exec.worker_pool().expect("pool-backed executor");
    let snap = pool.snapshot();
    assert_eq!(snap.workers, 3);
    assert_eq!(snap.busy_permille.len(), 3);
    assert!(snap.busy_permille.iter().all(|&p| p <= 1000));
}

// ---------------------------------------------------------------------------
// Experiment lab: spec expansion, runner measurements, trajectory record,
// and the regression gate (lab_*).

/// The committed smoke spec is the one CI runs: it must parse, expand
/// deterministically, and cover the acceptance grid (≥ 12 trials over
/// ≥ 2 widths × 2 backends × both query kinds).
#[test]
fn lab_smoke_spec_covers_acceptance_grid() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/experiments/lab_smoke.json");
    let text = std::fs::read_to_string(path).unwrap();
    let specs = armpq::lab::SweepSpec::parse_text(&text).unwrap();
    assert_eq!(specs.len(), 1);
    let trials = specs[0].expand();
    assert_eq!(trials, specs[0].expand(), "expansion must be deterministic");
    assert!(trials.len() >= 12, "smoke spec expands to only {}", trials.len());

    let widths: std::collections::BTreeSet<usize> =
        trials.iter().map(|t| t.width_bits).collect();
    let backends: std::collections::BTreeSet<&str> =
        trials.iter().map(|t| t.backend.name()).collect();
    let kinds: std::collections::BTreeSet<&str> =
        trials.iter().map(|t| t.kind.name()).collect();
    assert!(widths.len() >= 2, "widths covered: {widths:?}");
    assert!(backends.len() >= 2, "backends covered: {backends:?}");
    assert_eq!(kinds.len(), 2, "kinds covered: {kinds:?}");
    // ids unique; repeats share their case key
    let ids: std::collections::BTreeSet<&str> =
        trials.iter().map(|t| t.id.as_str()).collect();
    assert_eq!(ids.len(), trials.len());
}

/// The lab's recall measurement must agree with a direct `eval/`
/// computation over the same index, params and executor — on a quantized
/// index, not just an exact one.
#[test]
fn lab_recall_agrees_with_eval_on_quantized_index() {
    use armpq::exec::QueryExecutor;
    let spec_text = r#"{"name": "agree", "dataset": "gaussian", "n": 1500,
        "nq": 16, "k": 5, "seed": 11, "repeats": 1,
        "factories": ["PQ8x4fs"], "backends": ["portable"],
        "threads": [1], "kinds": ["topk"]}"#;
    let spec = &armpq::lab::SweepSpec::parse_text(spec_text).unwrap()[0];
    let trials = spec.expand();
    assert_eq!(trials.len(), 1);
    let out = armpq::lab::LabRunner::new().run_trial(&trials[0]);
    assert_eq!(out.status, armpq::lab::TrialStatus::Ok, "{:?}", out.error);
    let m = out.metrics.unwrap();

    // the same measurement by hand, through the same public paths
    let ds = SyntheticDataset::by_name("gaussian", 1500, 16, 11).unwrap();
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 5);
    let mut idx = index_factory(ds.dim, "PQ8x4fs").unwrap();
    idx.train(&ds.train).unwrap();
    idx.add(&ds.base).unwrap();
    idx.seal().unwrap();
    let exec = QueryExecutor::new(1);
    let params = SearchParams::new().with_backend(armpq::simd::Backend::Portable);
    let req = QueryRequest::top_k(&ds.queries, 5).with_params(params);
    let resp = idx.query_exec(&req, &exec).unwrap();
    let flat = resp.into_search_result(5);
    let want_r1 = recall_at_r(&gt, 5, &flat.labels, 5, 1);
    let want_rk = recall_at_r(&gt, 5, &flat.labels, 5, 5);
    assert_eq!(m.recall_at_1, want_r1, "lab recall@1 disagrees with eval/");
    assert_eq!(m.recall_at_k, want_rk, "lab recall@k disagrees with eval/");
}

/// End-to-end through the record and gate layers: run a tiny sweep,
/// append it to a trajectory in a temp dir, validate every emitted trial
/// against the record schema, then gate a clean re-run (pass) and an
/// injected throughput regression (fail) — the CI contract.
#[test]
fn lab_record_and_gate_end_to_end() {
    use armpq::lab::{self, GateConfig};
    use armpq::util::json::Json;

    let spec_text = r#"{"name": "e2e", "dataset": "gaussian", "n": 1200,
        "nq": 10, "k": 4, "seed": 3, "repeats": 2,
        "factories": ["Flat", "PQ8x4fs"], "backends": ["portable"],
        "threads": [1], "kinds": ["topk", "range"]}"#;
    let spec = &lab::SweepSpec::parse_text(spec_text).unwrap()[0];
    let trials = spec.expand();
    assert_eq!(trials.len(), 8); // 2 factories × 2 kinds × 2 repeats

    let mut runner = lab::LabRunner::new();
    let outcomes = runner.run_all(&trials, |_| {});
    let trial_json: Vec<Json> = outcomes.iter().map(|o| o.to_json()).collect();
    for t in &trial_json {
        let errs = lab::validate_trial_json(t);
        assert!(errs.is_empty(), "schema violations: {errs:?}\n{}", t.to_string());
    }
    assert!(outcomes.iter().all(|o| o.status == lab::TrialStatus::Ok));

    // record: append twice, reload, baseline = last run for the spec name
    let dir = std::env::temp_dir().join(format!("armpq_lab_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let host = lab::HostFingerprint::detect();
    let path = lab::Trajectory::path_for(&dir, &host);
    let mut traj = lab::Trajectory::load_or_new(&path, host.clone()).unwrap();
    traj.append_and_save(&path, lab::RunRecord {
        git_rev: "rev0".into(),
        spec_name: spec.name.clone(),
        unix_time: 1,
        trials: trial_json.clone(),
    })
    .unwrap();
    let reloaded = lab::Trajectory::load_or_new(&path, host).unwrap();
    let baseline = reloaded.last_run_for_spec("e2e").unwrap();
    assert_eq!(baseline.trials.len(), trials.len());

    // clean re-run through the real measurement path → gate passes. The
    // loose QPS/p99/phase margins keep shared-runner timing noise out of
    // the test; recall is deterministic and still gated at the default
    // epsilon.
    let fresh: Vec<Json> =
        runner.run_all(&trials, |_| {}).iter().map(|o| o.to_json()).collect();
    let loose = GateConfig {
        max_qps_drop: 0.75,
        max_p99_increase: 10.0,
        max_phase_share_drift: 0.9,
        ..GateConfig::default()
    };
    let report = lab::enforce(&baseline.trials, &fresh, &loose).unwrap();
    assert!(report.passed(), "{}", report.render());

    // exact self-comparison passes at the default 10% threshold
    let cfg = GateConfig::default();
    assert!(lab::enforce(&baseline.trials, &baseline.trials, &cfg).unwrap().passed());

    // the pass is visible on the metrics surface without plumbing
    let prom = armpq::coordinator::metrics::Metrics::new().to_prometheus();
    assert!(prom.contains("armpq_lab_gate_verdict 1"), "{prom}");
    assert!(prom.contains("armpq_lab_trials_total"));

    // injected 50% throughput drop on every trial: gate must fail
    let mut slow = baseline.trials.clone();
    for t in &mut slow {
        if let Some(q) = t.get("qps").and_then(Json::as_f64) {
            t.set("qps", Json::Num(q * 0.5));
        }
    }
    let err = lab::enforce(&baseline.trials, &slow, &cfg);
    assert!(err.is_err(), "gate passed a 50% throughput drop");
    let prom = armpq::coordinator::metrics::Metrics::new().to_prometheus();
    assert!(prom.contains("armpq_lab_gate_verdict 2"), "{prom}");

    std::fs::remove_dir_all(&dir).ok();
}
