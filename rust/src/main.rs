//! `armpq` CLI: the leader entrypoint of the 4-bit PQ serving stack.
//!
//! ```text
//! armpq info                        host/backend/artifact report
//! armpq gen-data  --dataset sift --n 100000 --out data/
//! armpq search    --factory PQ16x4fs --dataset deep --n 100000 --k 10
//! armpq serve     --factory IVF256_HNSW32,PQ16x4fs --n 200000 --addr 127.0.0.1:7878
//! armpq client    --addr 127.0.0.1:7878 --nq 100 --k 10
//! armpq bench-fig2   [--dataset sift|deep] [--n …] [--m 8,16,32,64]
//! armpq bench-table1 [--n …] [--nlist …] [--nprobe 1,2,4]
//! armpq bench-micro  [--m 16] [--width 2,4,8] [--threads 1,2,4]
//! armpq bench-layout [--n …] [--m 16] [--width 2,4,8]
//! armpq bench-pjrt   [--artifacts artifacts]
//! armpq lab run     --spec experiments/lab_smoke.json [--out BENCH.json]
//! armpq lab compare --spec experiments/lab_smoke.json [--baseline BENCH.json]
//! armpq lab report  [--file BENCH.json]
//! ```
//!
//! Fastscan code width is part of the factory grammar (`PQ16x2fs`,
//! `PQ16x8fs`, `IVF100,PQ16x2fs,nprobe=8`); the bench commands sweep it
//! with `--width`.

use armpq::config::ExperimentConfig;
use armpq::coordinator::{IvfBackend, Server, ServerConfig};
use armpq::datasets::io::write_fvecs;
use armpq::eval::{ground_truth, recall_at_r};
use armpq::experiments;
use armpq::index::{index_factory, Index};
use armpq::ivf::{IvfParams, IvfPq4};
use armpq::lab;
use armpq::pq::PqParams;
use armpq::util::args::Args;
use armpq::util::bench::Table;
use armpq::util::json::Json;
use armpq::util::timer::Timer;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "help".into());
    let code = match run(&cmd, &args) {
        Ok(()) => {
            let unknown = args.unknown_keys();
            if !unknown.is_empty() {
                eprintln!("warning: unrecognized flags: {unknown:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> armpq::Result<()> {
    match cmd {
        "info" => info(args),
        "gen-data" => gen_data(args),
        "search" => search(args),
        "serve" => serve(args),
        "client" => client(args),
        "lab" => lab_cmd(args),
        "bench-fig2" => {
            let cfg = ExperimentConfig::from_args(args)?;
            let ms = args.get_usize_list("m", &[8, 16, 32, 64]);
            let t = experiments::run_fig2(&cfg.dataset, cfg.n, cfg.nq, &ms, cfg.trials, cfg.seed)?;
            emit_table(&t, args)?;
            Ok(())
        }
        "bench-table1" => {
            let cfg = ExperimentConfig::from_args(args)?;
            let nlist = args.get_usize("nlist", (cfg.n as f64).sqrt() as usize);
            let nprobes = args.get_usize_list("nprobe", &[1, 2, 4]);
            let m = args.get_usize("pq-m", 16);
            // --mmap / --budget-mb (or factory storage keys) measure the
            // zero-copy mapped reopen instead of the in-heap build
            let open = cfg.open_options()?;
            let open = open.mmap.then_some(open);
            let t = experiments::run_table1_with(
                cfg.n, cfg.nq, nlist, m, &nprobes, cfg.trials, cfg.seed, open.as_ref(),
            )?;
            emit_table(&t, args)?;
            Ok(())
        }
        "bench-micro" => {
            let cfg = ExperimentConfig::from_args(args)?;
            let m = args.get_usize("m", 16);
            // `--filter-selectivity 1,10,50,100` adds the filter-pushdown
            // sweep (masked scan vs scan-then-post-filter) per width
            let sels = args.get_usize_list("filter-selectivity", &[]);
            let filter_n = args.get_usize("filter-n", 320_000);
            // `--threads 1,2,4` appends the executor thread-scaling curve
            // per width (empty = skip; `--threads 0` = default 1,2,4,ncpu)
            let threads = args.get_usize_list("threads", &[]);
            // `--width 2,4,8` (CLI or config file) sweeps the
            // Quicker-ADC trade-off axis in one run
            for &width in &cfg.widths {
                let t = experiments::run_kernel_micro(m, width);
                emit_table(&t, args)?;
                if !sels.is_empty() {
                    let t = experiments::run_filter_micro(filter_n, m, width, &sels, cfg.seed);
                    emit_table(&t, args)?;
                }
                if !threads.is_empty() {
                    let axis = experiments::default_thread_axis(
                        &threads.iter().copied().filter(|&t| t > 0).collect::<Vec<_>>(),
                    );
                    let t = experiments::run_thread_scaling(
                        &cfg.dataset,
                        cfg.n,
                        cfg.nq,
                        (cfg.n as f64).sqrt() as usize,
                        m,
                        width,
                        &axis,
                        cfg.trials,
                        cfg.seed,
                    )?;
                    emit_table(&t, args)?;
                }
            }
            Ok(())
        }
        "bench-layout" => {
            let cfg = ExperimentConfig::from_args(args)?;
            let m = args.get_usize("m", 16);
            let n = args.get_usize("n", 320_000);
            // `--range` switches to the range-query mode of the ablation
            let range_mode = args.get_flag("range");
            for &width in &cfg.widths {
                let t = if range_mode {
                    experiments::run_ablation_layout_range(n, m, width, cfg.seed)
                } else {
                    experiments::run_ablation_layout(n, m, width, cfg.seed)
                };
                emit_table(&t, args)?;
            }
            Ok(())
        }
        "bench-pjrt" => {
            let dir = args.get_str("artifacts", "artifacts");
            let t = experiments::run_pjrt_e2e(std::path::Path::new(&dir), 3)?;
            emit_table(&t, args)?;
            Ok(())
        }
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{HELP}");
            std::process::exit(2);
        }
    }
}

/// Print a bench table (or, with `--json`, emit it through the lab's
/// record format) and persist the JSONL copy either way.
fn emit_table(t: &Table, args: &Args) -> armpq::Result<()> {
    if args.get_flag("json") {
        println!("{}", lab::table_to_json(t).to_string());
    } else {
        t.print();
    }
    t.save()?;
    Ok(())
}

/// `armpq lab run|compare|report` — the experiment lab's CLI surface.
fn lab_cmd(args: &Args) -> armpq::Result<()> {
    let sub = args.positional.get(1).map(String::as_str).unwrap_or("help");
    match sub {
        "run" => lab_run(args),
        "compare" => lab_compare(args),
        "report" => lab_report(args),
        _ => {
            println!("{LAB_HELP}");
            Ok(())
        }
    }
}

const LAB_HELP: &str = "armpq lab — declarative sweeps with a recorded trajectory
  lab run     --spec <file> | --spec-json <inline>
              [--out <BENCH file>] [--dry-run] [--no-record]
              expand the spec, run every trial (one JSON line each on
              stdout), append a run record to the trajectory file
  lab compare --spec <file> | --spec-json <inline>
              [--baseline <BENCH file>] [--max-qps-drop 0.10]
              [--recall-epsilon 0.02] [--noise-mult 2.0]
              [--max-p99-increase 0.25] [--max-phase-drift 0.15]
              [--inject-qps-drop <frac>]
              re-run the spec and gate it against the last recorded run
              for the same spec name; non-zero exit on regression (mean
              QPS drop, recall drop beyond baseline noise, mean-p99 rise,
              or any trace phase's share of time drifting)
  lab report  [--file <BENCH file>]
              validate every recorded trial against the record schema and
              summarize the trajectory; non-zero exit on schema violations
The trajectory file defaults to BENCH_<host-slug>.json in the current
directory; its host fingerprint must match this machine.";

/// Load the spec text from `--spec <path>` or `--spec-json <inline>`.
fn lab_load_specs(args: &Args) -> armpq::Result<Vec<lab::SweepSpec>> {
    let text = if let Some(inline) = args.get_opt("spec-json") {
        inline
    } else if let Some(path) = args.get_opt("spec") {
        std::fs::read_to_string(&path)
            .map_err(|e| armpq::Error::Config(format!("cannot read spec {path:?}: {e}")))?
    } else {
        return Err(armpq::Error::Config(
            "lab: pass --spec <file> or --spec-json <inline json>".into(),
        ));
    };
    lab::SweepSpec::parse_text(&text)
}

fn lab_trajectory_path(args: &Args, key: &str, host: &lab::HostFingerprint) -> PathBuf {
    match args.get_opt(key) {
        Some(p) => PathBuf::from(p),
        None => lab::Trajectory::path_for(Path::new("."), host),
    }
}

/// Execute one spec's trials, streaming a JSON line per trial.
fn lab_run_spec(
    runner: &mut lab::LabRunner,
    spec: &lab::SweepSpec,
    quiet: bool,
) -> Vec<Json> {
    let trials = spec.expand();
    eprintln!("lab: spec {:?} expands to {} trials", spec.name, trials.len());
    let outcomes = runner.run_all(&trials, |o| {
        if !quiet {
            println!("{}", o.to_json().to_string());
        }
    });
    let (ok, skipped, failed) = outcomes.iter().fold((0, 0, 0), |acc, o| match o.status {
        lab::TrialStatus::Ok => (acc.0 + 1, acc.1, acc.2),
        lab::TrialStatus::Skipped => (acc.0, acc.1 + 1, acc.2),
        lab::TrialStatus::Failed => (acc.0, acc.1, acc.2 + 1),
    });
    eprintln!("lab: spec {:?} done — {ok} ok, {skipped} skipped, {failed} failed", spec.name);
    outcomes.iter().map(|o| o.to_json()).collect()
}

fn lab_run(args: &Args) -> armpq::Result<()> {
    let specs = lab_load_specs(args)?;
    if args.get_flag("dry-run") {
        for spec in &specs {
            for t in spec.expand() {
                println!("{}", t.id);
            }
        }
        return Ok(());
    }
    let host = lab::HostFingerprint::detect();
    let out = lab_trajectory_path(args, "out", &host);
    let record = !args.get_flag("no-record");
    let mut trajectory = if record {
        Some(lab::Trajectory::load_or_new(&out, host.clone())?)
    } else {
        None
    };
    let git_rev = lab::git_revision(Path::new("."));
    let mut runner = lab::LabRunner::new();
    for spec in &specs {
        let trials = lab_run_spec(&mut runner, spec, false);
        if let Some(t) = trajectory.as_mut() {
            let unix_time = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map_or(0, |d| d.as_secs());
            t.append_and_save(&out, lab::RunRecord {
                git_rev: git_rev.clone(),
                spec_name: spec.name.clone(),
                unix_time,
                trials,
            })?;
            eprintln!(
                "lab: appended run for {:?} at {git_rev} to {} ({} runs total)",
                spec.name,
                out.display(),
                t.runs.len()
            );
        }
    }
    Ok(())
}

fn lab_compare(args: &Args) -> armpq::Result<()> {
    let specs = lab_load_specs(args)?;
    let host = lab::HostFingerprint::detect();
    let baseline_path = lab_trajectory_path(args, "baseline", &host);
    let trajectory = lab::Trajectory::load_or_new(&baseline_path, host)?;
    let cfg = lab::GateConfig {
        max_qps_drop: args.get_f64("max-qps-drop", 0.10),
        min_recall_epsilon: args.get_f64("recall-epsilon", 0.02),
        noise_mult: args.get_f64("noise-mult", 2.0),
        max_p99_increase: args.get_f64("max-p99-increase", 0.25),
        max_phase_share_drift: args.get_f64("max-phase-drift", 0.15),
    };
    // testing hook (CI forced-fail mode): scale fresh throughput down to
    // prove the gate trips on a real regression signal
    let inject = args.get_f64("inject-qps-drop", 0.0);

    let mut runner = lab::LabRunner::new();
    let mut failure: Option<armpq::Error> = None;
    for spec in &specs {
        let Some(baseline) = trajectory.last_run_for_spec(&spec.name) else {
            eprintln!(
                "lab: no recorded baseline for spec {:?} in {} — nothing to compare",
                spec.name,
                baseline_path.display()
            );
            continue;
        };
        let mut fresh = lab_run_spec(&mut runner, spec, true);
        if inject > 0.0 {
            for t in &mut fresh {
                if let Some(qps) = t.get("qps").and_then(Json::as_f64) {
                    t.set("qps", Json::Num(qps * (1.0 - inject)));
                }
            }
        }
        match lab::enforce(&baseline.trials, &fresh, &cfg) {
            Ok(report) => {
                println!(
                    "lab: gate PASS for {:?} vs {} ({} cases)\n{}",
                    spec.name,
                    baseline.git_rev,
                    report.verdicts.len(),
                    report.render()
                );
            }
            Err(e) => {
                eprintln!("lab: gate FAIL for {:?} vs {}", spec.name, baseline.git_rev);
                failure.get_or_insert(e);
            }
        }
    }
    match failure {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

fn lab_report(args: &Args) -> armpq::Result<()> {
    let host = lab::HostFingerprint::detect();
    let path = lab_trajectory_path(args, "file", &host);
    let text = std::fs::read_to_string(&path)
        .map_err(|e| armpq::Error::Config(format!("cannot read {}: {e}", path.display())))?;
    let trajectory = lab::Trajectory::from_json_text(&text)?;
    println!(
        "trajectory {} — host {} ({}), {} run(s)",
        path.display(),
        trajectory.host.slug(),
        trajectory.host.cpu_model,
        trajectory.runs.len()
    );
    let mut violations = 0usize;
    for (ri, run) in trajectory.runs.iter().enumerate() {
        let mut ok = 0;
        let mut other = 0;
        for t in &run.trials {
            for err in lab::validate_trial_json(t) {
                let id = t.get("id").and_then(Json::as_str).unwrap_or("?");
                eprintln!("run {ri} trial {id}: {err}");
                violations += 1;
            }
            match t.get("status").and_then(Json::as_str) {
                Some("ok") => ok += 1,
                _ => other += 1,
            }
        }
        println!(
            "  run {ri}: spec {:?} rev {} — {} trials ({ok} ok, {other} skipped/failed)",
            run.spec_name,
            run.git_rev,
            run.trials.len()
        );
    }
    if violations > 0 {
        return Err(armpq::Error::Config(format!(
            "{violations} trial(s) violate the record schema"
        )));
    }
    println!("all recorded trials conform to the record schema");
    Ok(())
}

const HELP: &str = "armpq — ARM 4-bit PQ reproduction (SIMD ANN search)
commands:
  info          host/backend/artifact report
  gen-data      write synthetic datasets as fvecs
  search        build an index from a factory string and run queries
  serve         start the TCP batching coordinator (--index-file <path>
                serves a saved index; --mmap opens it zero-copy and
                --budget-mb <MiB> caps advised residency; --metrics-addr
                HOST:PORT serves Prometheus exposition over HTTP;
                --pin pins pool workers to cores; --queue-depth <n>
                bounds the admission queue, full = reject 'overloaded';
                --deadline-ms <ms> degrades explicit nprobe under backlog)
  client        drive a running server (--trace prints a per-phase span
                breakdown; --metrics fetches the Prometheus exposition;
                --slowlog dumps the server's worst-query log)
  bench-fig2    paper Fig. 2 (PQ vs 4-bit PQ recall/QPS sweep)
  bench-table1  paper Table 1 (IVF+HNSW+PQ16x4fs at scale; --mmap
                measures the zero-copy mapped reopen, --budget-mb caps it)
  bench-micro   paper Fig. 1 lookup-op micro-benchmark (--width 2,4,8;
                --filter-selectivity 1,10,50,100 adds the filter-pushdown
                sweep, --filter-n sets its database size)
  bench-layout  interleaved-vs-flat layout ablation (--width 2,4,8;
                --range benches the range-query scan instead of top-k)
  bench-pjrt    3-layer PJRT end-to-end comparison
  lab           experiment lab: `lab run|compare|report` (see `armpq lab`)
                — declarative sweep specs, recorded BENCH_<host>.json
                trajectory, and the CI regression gate; every bench-*
                command also accepts --json to emit the lab record format
common flags: --dataset sift|deep|gaussian --n <int> --nq <int> --k <int>
              --factory <spec> --nprobe <list> --seed <int> --config <file>
              --backend portable|ssse3|neon (default: best for this host)
              --width 2|4|8 (fastscan code width for kernel benches;
              index width goes in the factory string, e.g. PQ16x2fs)";

fn info(args: &Args) -> armpq::Result<()> {
    println!("armpq {} — ARM 4-bit PQ reproduction", env!("CARGO_PKG_VERSION"));
    println!("simd backends: {:?} (best: {:?})", armpq::simd::available_backends(), armpq::simd::best_backend());
    println!("threads: {}", armpq::util::threads::default_threads());
    let dir = args.get_str("artifacts", "artifacts");
    match armpq::runtime::Manifest::load(std::path::Path::new(&dir)) {
        Ok(m) => {
            println!("artifacts ({}):", dir);
            for a in &m.artifacts {
                println!("  {:30} kind={:9} params={:?}", a.name, a.kind, a.params);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn gen_data(args: &Args) -> armpq::Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let out = args.get_str("out", "data");
    std::fs::create_dir_all(&out)?;
    let ds = experiments::make_dataset(&cfg.dataset, cfg.n, cfg.nq, cfg.seed);
    let base = format!("{out}/{}_{}k", cfg.dataset, cfg.n / 1000);
    write_fvecs(std::path::Path::new(&format!("{base}_base.fvecs")), ds.dim, &ds.base)?;
    write_fvecs(std::path::Path::new(&format!("{base}_query.fvecs")), ds.dim, &ds.queries)?;
    write_fvecs(std::path::Path::new(&format!("{base}_learn.fvecs")), ds.dim, &ds.train)?;
    println!("wrote {base}_{{base,query,learn}}.fvecs (dim {})", ds.dim);
    Ok(())
}

fn search(args: &Args) -> armpq::Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let ds = experiments::make_dataset(&cfg.dataset, cfg.n, cfg.nq, cfg.seed);
    println!("dataset {} n={} nq={} dim={}", cfg.dataset, cfg.n, cfg.nq, ds.dim);
    let mut idx = index_factory(ds.dim, &cfg.factory)?;
    if let Some(backend) = cfg.backend {
        if !backend.is_available() {
            eprintln!("warning: backend {backend} not available on this host; kernel falls back to portable semantics");
        }
        // capability probe: the per-request params carry the same value to
        // the search below, so this shim call only exists to warn when the
        // index type has no backend knob at all (the value itself agrees)
        if let Err(e) = idx.set_param("backend", backend.name()) {
            eprintln!("warning: --backend ignored for this index type: {e}");
        }
    }
    let t = Timer::start();
    idx.train(&ds.train)?;
    println!("trained {} in {:.1}s", idx.describe(), t.elapsed_s());
    let t = Timer::start();
    idx.add(&ds.base)?;
    idx.seal()?;
    println!("added+sealed {} vectors in {:.1}s", idx.ntotal(), t.elapsed_s());
    // Explicitly-given knobs (CLI or config file) become per-request
    // overrides; implicit defaults never shadow factory-string defaults
    // like "IVF100,PQ16x4fs,nprobe=8". The historical implicit default
    // (nprobe=4, matching `armpq serve`) still applies as an index
    // default when neither the user nor the factory string set one.
    let spec_sets_nprobe = armpq::index::factory::spec_search_params(&cfg.factory)
        .map(|p| p.nprobe.is_some())
        .unwrap_or(false);
    if !cfg.nprobe_explicit && !spec_sets_nprobe && cfg.nprobe > 0 {
        let _ = idx.set_param("nprobe", &cfg.nprobe.to_string());
    }
    let params = cfg.search_params();
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let t = Timer::start();
    let r = idx.search(&ds.queries, cfg.k, Some(&params))?;
    let ms = t.elapsed_ms() / cfg.nq as f64;
    println!(
        "recall@1 {:.3}  recall@{} {:.3}  {:.3} ms/query  {:.0} QPS",
        recall_at_r(&gt, 1, &r.labels, cfg.k, 1),
        cfg.k,
        recall_at_r(&gt, 1, &r.labels, cfg.k, cfg.k),
        ms,
        1e3 / ms
    );
    Ok(())
}

fn serve(args: &Args) -> armpq::Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let addr = args.get_str("addr", "127.0.0.1:7878");
    // `--metrics-addr HOST:PORT` binds a one-endpoint HTTP listener whose
    // every GET answers with the Prometheus text exposition
    let metrics_addr = args.get_opt("metrics-addr");
    // `--pin` pins the worker pool's threads to cores; must be set before
    // anything touches the process-global executor (lazily constructed)
    if args.get_flag("pin") {
        std::env::set_var("ARMPQ_PIN", "1");
    }
    // serving-runtime knobs: bounded admission queue (full → the wire
    // rejects with an "overloaded" error) and an optional per-request
    // deadline budget that degrades explicit nprobe under backlog
    let mut batcher = armpq::coordinator::BatcherConfig::default();
    batcher.queue_depth = args.get_usize("queue-depth", batcher.queue_depth);
    let deadline_ms = args.get_usize("deadline-ms", 0);
    if deadline_ms > 0 {
        batcher.deadline = Some(std::time::Duration::from_millis(deadline_ms as u64));
    }

    // `--index-file` serves a saved index instead of building a synthetic
    // one; `--mmap` / `--budget-mb` (or factory-string `mmap=true,…`)
    // select a zero-copy open with a residency budget.
    if let Some(path) = args.get_opt("index-file") {
        let opts = cfg.open_options()?;
        let index: Arc<dyn Index> =
            Arc::from(armpq::index::io::open_index(std::path::Path::new(&path), &opts)?);
        let dim = index.dim();
        println!(
            "opened {path} ({}, dim {dim}, {} rows, {})",
            index.describe(),
            index.ntotal(),
            if opts.mmap { "mapped" } else { "heap" }
        );
        let backend = Arc::new(armpq::coordinator::IndexBackend::new(index)?);
        let server = Server::start(
            backend,
            ServerConfig {
                addr: addr.clone(),
                metrics_addr: metrics_addr.clone(),
                batcher: batcher.clone(),
            },
        )?;
        if let Some(m) = server.metrics_addr {
            println!("metrics exposition on http://{m}/metrics");
        }
        println!("serving on {} (dim {dim}) — Ctrl-C to stop", server.addr);
        loop {
            std::thread::sleep(std::time::Duration::from_secs(5));
            println!("stats: {}", server.metrics_json().to_string());
        }
    }

    let nlist = args.get_usize("nlist", (cfg.n as f64).sqrt() as usize);
    let m = args.get_usize("pq-m", 16);
    let ds = experiments::make_dataset(&cfg.dataset, cfg.n, cfg.nq, cfg.seed);

    let mut params = IvfParams::new(nlist);
    params.coarse_hnsw = true;
    let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(m));
    println!("training IVF{nlist}_HNSW32,PQ{m}x4fs on {} vectors…", cfg.n);
    idx.train(&ds.train)?;
    idx.add(&ds.base)?;
    idx.nprobe = cfg.nprobe.max(1);
    if let Some(b) = cfg.backend {
        if !b.is_available() {
            eprintln!("warning: backend {b} not available on this host; kernel falls back to portable semantics");
        }
        idx.fastscan.backend = b;
    }
    let backend = Arc::new(IvfBackend::new(idx)?);
    let server = Server::start(
        backend,
        ServerConfig { addr: addr.clone(), metrics_addr, batcher },
    )?;
    if let Some(m) = server.metrics_addr {
        println!("metrics exposition on http://{m}/metrics");
    }
    println!("serving on {} (dim {}) — Ctrl-C to stop", server.addr, ds.dim);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        println!("stats: {}", server.metrics_json().to_string());
    }
}

fn client(args: &Args) -> armpq::Result<()> {
    let cfg = ExperimentConfig::from_args(args)?;
    let addr: std::net::SocketAddr = args
        .get_str("addr", "127.0.0.1:7878")
        .parse()
        .map_err(|e| armpq::Error::Serve(format!("bad addr: {e}")))?;
    let mut client = armpq::coordinator::Client::connect(&addr)?;
    client.ping()?;
    // `--metrics` / `--slowlog`: fetch the observability surfaces and exit
    if args.get_flag("metrics") {
        println!("{}", client.metrics_text()?);
        return Ok(());
    }
    if args.get_flag("slowlog") {
        println!("{}", client.slowlog()?.to_string());
        return Ok(());
    }
    let trace = args.get_flag("trace");
    // queries drawn from the same distribution as the served dataset
    let ds = experiments::make_dataset(&cfg.dataset, 1, cfg.nq, cfg.seed);
    let mut stats = armpq::util::timer::LatencyStats::new();
    for qi in 0..cfg.nq {
        let t = Timer::start();
        if trace {
            let kind = armpq::index::query::QueryKind::TopK { k: cfg.k };
            let (_hits, _qstats, spans) =
                client.query_traced(ds.query(qi), &kind, None, None)?;
            stats.record_ms(t.elapsed_ms());
            if qi == 0 {
                println!("phase breakdown (query 0):");
                for s in &spans {
                    println!(
                        "  {:14} {:8} us  count={:<8} bytes={}",
                        s.phase.name(),
                        s.us,
                        s.count,
                        s.bytes
                    );
                }
            }
        } else {
            let (_d, _l, batch) = client.search(ds.query(qi), cfg.k)?;
            stats.record_ms(t.elapsed_ms());
            if qi == 0 {
                println!("first response: batch_size={batch}");
            }
        }
    }
    println!(
        "{} queries: mean {:.2} ms  p50 {:.2}  p95 {:.2}  QPS {:.0}",
        stats.count(),
        stats.mean_ms(),
        stats.percentile_ms(50.0),
        stats.percentile_ms(95.0),
        stats.qps()
    );
    println!("server stats: {}", client.stats()?.to_string());
    Ok(())
}
