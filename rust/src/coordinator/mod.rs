//! L3 serving coordinator: dynamic batching, backend routing, TCP serving
//! and metrics — the layer that turns the 4-bit-PQ library into a service.
//!
//! Architecture (vLLM-router-like, scaled to this paper's scope):
//!
//! ```text
//!   TCP clients ──► server (thread per conn, line-JSON protocol)
//!                      │ QueryRequest { vector, k, reply channel }
//!                      ▼
//!                dynamic batcher (max_batch / max_wait window)
//!                      │ grouped by k, concatenated
//!                      ▼
//!                SearchBackend (sealed IVF-PQ index, or the PJRT
//!                pipeline from runtime/) ──► responses routed back
//! ```
//!
//! Everything is std-thread + mpsc (no tokio in the vendored crate set);
//! on the paper's workload (sub-ms searches) OS threads are not the
//! bottleneck — the batcher exists to amortize LUT construction across
//! queries, which is the coordinator-level counterpart of the paper's
//! register-resident tables.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::ShardedBackend;
pub use server::{Client, Server, ServerConfig};
pub use service::{IvfBackend, SearchBackend};
