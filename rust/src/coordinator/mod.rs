//! L3 serving coordinator: dynamic batching, backend routing, TCP serving
//! and metrics — the layer that turns the 4-bit-PQ library into a service.
//!
//! Architecture (vLLM-router-like, scaled to this paper's scope):
//!
//! ```text
//!   TCP clients ──► server (thread per conn, line-JSON protocol)
//!                      │ QueryRequest { vector, k, params, reply channel }
//!                      ▼
//!                dynamic batcher (max_batch / max_wait window)
//!                      │ grouped by (k, params), concatenated
//!                      ▼
//!                SearchBackend (sealed index behind Arc<dyn Index>, or
//!                the PJRT pipeline from runtime/) ──► responses routed
//! ```
//!
//! Search is read-only end to end: backends take `&self` and forward
//! per-request [`crate::index::SearchParams`], so shards fan out across
//! threads without a per-index mutex and concurrent requests with
//! different parameters never interfere.
//!
//! Everything is std-thread + mpsc (no tokio in the vendored crate set);
//! on the paper's workload (sub-ms searches) OS threads are not the
//! bottleneck — the batcher exists to amortize LUT construction across
//! queries, which is the coordinator-level counterpart of the paper's
//! register-resident tables.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::ShardedBackend;
pub use server::{Client, Server, ServerConfig};
pub use service::{IndexBackend, IvfBackend, SearchBackend};
