//! L3 serving coordinator: dynamic batching, backend routing, TCP serving
//! and metrics — the layer that turns the 4-bit-PQ library into a service.
//!
//! Architecture (vLLM-router-like, scaled to this paper's scope):
//!
//! ```text
//!   TCP clients ──► server (thread per conn, line-JSON protocol:
//!                      kind topk|range, optional id_range/id_set filter;
//!                      insert/delete verbs for segmented backends)
//!                      │ PendingQuery { vector, kind, filter, params, reply }
//!                      ▼
//!                dynamic batcher (max_batch / max_wait window)
//!                      │ grouped by (kind, filter, params) into ONE
//!                      │ typed QueryRequest per group
//!                      ▼
//!                SearchBackend::query_batch (sealed index or segmented
//!                index behind Arc<dyn Index>, a shard fan-out, or the
//!                PJRT pipeline)
//!                      │ QueryResponse { per-query hits + stats }
//!                      ▼
//!                responses routed back; stats folded into metrics
//!                (codes_scanned / filter_selectivity histograms,
//!                segment-lifecycle gauges, per-phase trace histograms,
//!                slow-query log)
//! ```
//!
//! The whole pipe speaks the typed request/response model of
//! [`crate::index::query`]: filters ride the request into the fastscan
//! kernels (mask pushdown — no post-hoc rescans anywhere in the serving
//! path) and range queries return variable-length hits that
//! [`ShardedBackend`] merges across shards, deduplicating labels that
//! legitimately live on more than one shard.
//!
//! # Mutability and the segment lifecycle
//!
//! Queries are read-only end to end: backends take `&self` and forward
//! per-request [`crate::index::SearchParams`], so shards fan out across
//! threads without a per-index mutex and concurrent requests with
//! different parameters never interfere.
//!
//! Mutations are layered on without giving that up. The `insert` and
//! `delete` wire verbs route to [`SearchBackend::insert`] /
//! [`SearchBackend::delete`], which a backend over a
//! [`crate::segment::SegmentedIndex`] answers by `&self` snapshot swap:
//! new rows land in a mutable memtable, deletes become tombstones over
//! the sealed segment stack, and a flush/compaction worker migrates
//! memtable rows into sealed segments in the background. In-flight
//! batched queries keep scanning the snapshot they started with — no
//! reader ever blocks on a writer. Sealed single-segment backends keep
//! their defaults and answer both verbs with an error, so read-only
//! deployments are unchanged. The `stats` verb exposes the lifecycle
//! (`segments`, `memtable_entries`, `tombstones`, `flushes_total`,
//! `compactions_total`) next to the per-query `segments_scanned` gauge.
//!
//! **Batch-level LUT reuse:** batcher groups share one backend call, and
//! [`ShardedBackend`] computes each group's per-query scan LUTs once
//! (when every shard reports the same `lut_signature`) and fans them out
//! via `query_batch_with_luts` — the serving-layer counterpart of the
//! paper's register-resident tables. LUTs depend only on the query
//! vectors, so the reuse applies to every kind/filter combination.
//!
//! **One shared executor:** every index-backed backend carries a
//! [`crate::exec::QueryExecutor`] (defaulting to the process-global one)
//! and threads it through `query_batch` — batch fan-out across queries,
//! intra-query multi-list fan-out for lone large-`nprobe` IVF queries,
//! per-thread scratch arenas reused allocation-free in steady state. The
//! `stats` verb exposes the resulting concurrency (`exec_threads`,
//! `scratch_high_water_bytes`) plus a whole-window `batch_latency_us`
//! histogram so the thread win is measurable from the wire.
//!
//! # Observability: traces, phase histograms, exposition
//!
//! A `search` request carrying `"trace": true` returns a per-phase span
//! breakdown (plan compile, coarse quantization, LUT build, list/segment/
//! memtable scan, merge, rerank — see [`crate::obs`]) alongside its hits.
//! Tracing is bit-identical to not tracing and free when off; the batcher
//! runs a group traced if *any* member asked and hands spans back only to
//! the members that did. Completed spans also feed [`Metrics`]'
//! per-phase latency histograms, and every query is offered to a bounded
//! slow-query log (the worst end-to-end queries, each with its trace when
//! one was captured).
//!
//! Two wire verbs expose this without JSON spelunking: `metrics` returns
//! the full Prometheus text exposition (every `stats` gauge and histogram,
//! the per-phase histograms, and a `mincore`-sampled residency gauge,
//! refreshed at scrape time), and `slowlog` dumps the slow-query ring.
//! [`ServerConfig::metrics_addr`] additionally binds a one-endpoint HTTP
//! listener serving the same exposition to stock Prometheus scrapers.
//!
//! # The serving runtime: persistent pool, admission control, deadlines
//!
//! The coordinator sits on the persistent worker pool of
//! [`crate::exec::pool`] rather than per-call thread spawning: the global
//! [`crate::exec::QueryExecutor`] owns its workers for the process
//! lifetime (optionally pinned via `ARMPQ_PIN`, NUMA-placed from
//! `/sys/devices/system/node`), and every fan-out in this module — batch
//! windows across queries, probed lists within a query, shards across the
//! router — submits units to the same pool. [`ShardedBackend`] interleaves
//! its shards across NUMA nodes at construction and tags each shard's
//! fan-out unit with its home node, so pool workers prefer same-node
//! shards and steal cross-node only when idle.
//!
//! In front of that sits admission control. The batcher's submission
//! queue is **bounded** ([`BatcherConfig::queue_depth`]): a full queue
//! rejects new work at the door with [`crate::Error::Overloaded`] (the
//! wire renders it as an `err` whose message contains `overloaded`, the
//! token clients back off on) instead of queueing unboundedly and letting
//! tail latency grow without limit. Admitted work is never cancelled.
//! With a configured [`BatcherConfig::deadline`], requests that have
//! already burned half their budget in the queue — or that arrive in a
//! window formed while the queue is more than half full — degrade
//! *effort, never correctness*: an explicit per-request `nprobe` override
//! is halved (quartered past the full budget, floored at 1), which trades
//! recall for latency along the paper's own nprobe/recall curve; results
//! stay exact for the parameters actually used, and requests without an
//! explicit `nprobe` are left untouched. The `stats`/`metrics` verbs
//! expose the whole loop: `admission_queue_depth`,
//! `admission_rejections_total`, `deadline_degraded_total`, plus the
//! pool's `pool_workers` / `pool_queue_depth` / `pool_tasks_total` /
//! `pool_steals_total` and per-worker busy-fraction gauges.
//!
//! Everything is std-thread + mpsc (no tokio in the vendored crate set);
//! on the paper's workload (sub-ms searches) OS threads are not the
//! bottleneck — the batcher exists to amortize LUT construction across
//! queries, and the pool to stop paying thread spawn/teardown on every
//! one of them.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod service;

pub use batcher::{Batcher, BatcherConfig, ServeResponse};
pub use metrics::Metrics;
pub use router::ShardedBackend;
pub use server::{Client, Server, ServerConfig};
pub use service::{IndexBackend, IvfBackend, SearchBackend};
