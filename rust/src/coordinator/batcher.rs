//! Dynamic batcher: collects concurrent queries into windows and runs them
//! through a [`SearchBackend`] as one batched call.
//!
//! Policy (vLLM-style continuous batching, simplified to stateless search):
//! the worker blocks for the first request, then drains the queue up to
//! `max_batch` or until `max_wait` elapses, groups by `(k, params)`,
//! executes, and routes each response to its reply channel. Batching
//! amortizes per-query fixed costs — above all LUT construction, the
//! serving-layer analog of the paper keeping tables register-resident:
//! each `(k, params)` group becomes ONE backend call, and a sharded
//! backend ([`crate::coordinator::ShardedBackend`]) computes the group's
//! per-query scan LUTs once and reuses them across its whole shard
//! fan-out instead of rebuilding per shard.
//! Per-request [`SearchParams`] are part of the grouping key, so requests
//! carrying different overrides never share (or pollute) a backend call.

use super::metrics::Metrics;
use super::service::SearchBackend;
use crate::index::SearchParams;
use crate::Result;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One in-flight query.
pub struct QueryRequest {
    pub vector: Vec<f32>,
    pub k: usize,
    /// Per-request parameter overrides; part of the batching key, so
    /// requests with different parameters never share a backend call.
    pub params: Option<SearchParams>,
    pub enqueued: Instant,
    pub reply: SyncSender<Result<QueryResponse>>,
}

/// The answer routed back to the submitting client.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub distances: Vec<f32>,
    pub labels: Vec<i64>,
    /// Time spent waiting for batch formation.
    pub queue_us: u64,
    /// Backend execution time of the whole batch.
    pub service_us: u64,
    /// How many queries shared the batch.
    pub batch_size: usize,
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Worker threads draining the shared queue.
    pub workers: usize,
    /// Bounded queue depth (backpressure: submit blocks when full).
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            workers: 1,
            queue_depth: 1024,
        }
    }
}

/// Handle to a running batcher.
pub struct Batcher {
    tx: SyncSender<QueryRequest>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Batcher {
    /// Spawn the worker threads.
    pub fn start(backend: Arc<dyn SearchBackend>, cfg: BatcherConfig) -> Batcher {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<QueryRequest>(cfg.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let backend = backend.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(rx, backend, metrics, cfg);
            }));
        }
        Batcher { tx, metrics, workers }
    }

    /// Enqueue a query; returns the reply receiver.
    pub fn submit(
        &self,
        vector: Vec<f32>,
        k: usize,
        params: Option<SearchParams>,
    ) -> std::sync::mpsc::Receiver<Result<QueryResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.metrics.requests_total.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // normalize Some(no overrides) to None so it batches with bare
        // requests instead of forming its own (k, params) group
        let params = params.filter(|p| !p.is_empty());
        let req = QueryRequest { vector, k, params, enqueued: Instant::now(), reply: reply_tx };
        // A send error means shutdown; the caller sees a disconnected reply.
        let _ = self.tx.send(req);
        reply_rx
    }

    /// Convenience: submit and wait.
    pub fn search(
        &self,
        vector: Vec<f32>,
        k: usize,
        params: Option<SearchParams>,
    ) -> Result<QueryResponse> {
        self.submit(vector, k, params)
            .recv()
            .map_err(|_| crate::Error::Serve("batcher shut down".into()))?
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<QueryRequest>>>,
    backend: Arc<dyn SearchBackend>,
    metrics: Arc<Metrics>,
    cfg: BatcherConfig,
) {
    loop {
        // Block for the first request of a window.
        let first = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // channel closed
            }
        };
        let window_start = Instant::now();
        let mut batch = vec![first];
        // Drain until the window closes.
        while batch.len() < cfg.max_batch {
            let remaining = cfg.max_wait.saturating_sub(window_start.elapsed());
            let next = {
                let guard = rx.lock().unwrap();
                if remaining.is_zero() {
                    match guard.try_recv() {
                        Ok(r) => Some(r),
                        Err(_) => None,
                    }
                } else {
                    match guard.recv_timeout(remaining) {
                        Ok(r) => Some(r),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => None,
                    }
                }
            };
            match next {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        execute_batch(&*backend, &metrics, batch);
    }
}

fn execute_batch(backend: &dyn SearchBackend, metrics: &Metrics, batch: Vec<QueryRequest>) {
    metrics.record_batch(batch.len());
    let batch_size = batch.len();
    // group by (k, params) so one backend call serves each combination —
    // per-request overrides must never leak into a neighbor's search
    let mut groups: Vec<((usize, Option<SearchParams>), Vec<QueryRequest>)> = Vec::new();
    for r in batch {
        match groups.iter_mut().find(|(key, _)| key.0 == r.k && key.1 == r.params) {
            Some((_, g)) => g.push(r),
            None => groups.push(((r.k, r.params.clone()), vec![r])),
        }
    }
    for ((k, params), group) in groups {
        let mut queries = Vec::with_capacity(group.len() * backend.dim());
        for r in &group {
            queries.extend_from_slice(&r.vector);
        }
        let t0 = Instant::now();
        let result = backend.search_batch(&queries, k, params.as_ref());
        let service_us = t0.elapsed().as_micros() as u64;
        metrics.service_us.record(service_us.max(1));
        match result {
            Ok((d, l)) => {
                for (i, r) in group.into_iter().enumerate() {
                    let queue_us = (t0 - r.enqueued).as_micros() as u64;
                    metrics.queue_us.record(queue_us.max(1));
                    metrics.e2e_us.record((queue_us + service_us).max(1));
                    let resp = QueryResponse {
                        distances: d[i * k..(i + 1) * k].to_vec(),
                        labels: l[i * k..(i + 1) * k].to_vec(),
                        queue_us,
                        service_us,
                        batch_size,
                    };
                    let _ = r.reply.send(Ok(resp));
                }
            }
            Err(e) => {
                metrics.errors_total.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let msg = e.to_string();
                for r in group {
                    let _ = r.reply.send(Err(crate::Error::Serve(msg.clone())));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic toy backend: distance = |k|, label = floor(v[0]).
    struct EchoBackend {
        dim: usize,
        delay: Duration,
    }

    impl SearchBackend for EchoBackend {
        fn dim(&self) -> usize {
            self.dim
        }
        fn search_batch(
            &self,
            queries: &[f32],
            k: usize,
            _params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            std::thread::sleep(self.delay);
            let nq = queries.len() / self.dim;
            let mut d = Vec::new();
            let mut l = Vec::new();
            for qi in 0..nq {
                for r in 0..k {
                    d.push(r as f32);
                    l.push(queries[qi * self.dim] as i64);
                }
            }
            Ok((d, l))
        }
        fn describe(&self) -> String {
            "echo".into()
        }
    }

    #[test]
    fn routes_responses_to_correct_clients() {
        let be = Arc::new(EchoBackend { dim: 2, delay: Duration::ZERO });
        let b = Batcher::start(be, BatcherConfig::default());
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push((i, b.submit(vec![i as f32, 0.0], 3, None)));
        }
        for (i, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.labels, vec![i as i64; 3]);
            assert_eq!(resp.distances, vec![0.0, 1.0, 2.0]);
        }
        b.shutdown();
    }

    #[test]
    fn batches_form_under_concurrency() {
        // slow backend + concurrent submitters → batches larger than 1
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::from_millis(3) });
        let b = Arc::new(Batcher::start(
            be,
            BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2), ..Default::default() },
        ));
        let mut handles = Vec::new();
        for i in 0..32 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.search(vec![i as f32], 1, None).unwrap()
            }));
        }
        let responses: Vec<QueryResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch > 1, "no batching happened (max={max_batch})");
        assert_eq!(b.metrics.requests_total.load(std::sync::atomic::Ordering::Relaxed), 32);
    }

    #[test]
    fn mixed_k_in_one_window() {
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::ZERO });
        let b = Batcher::start(be, BatcherConfig::default());
        let r1 = b.submit(vec![1.0], 2, None);
        let r2 = b.submit(vec![2.0], 5, None);
        assert_eq!(r1.recv().unwrap().unwrap().distances.len(), 2);
        assert_eq!(r2.recv().unwrap().unwrap().distances.len(), 5);
        b.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::ZERO });
        let b = Batcher::start(be, BatcherConfig { workers: 2, ..Default::default() });
        let resp = b.search(vec![5.0], 1, None).unwrap();
        assert_eq!(resp.labels, vec![5]);
        b.shutdown(); // must not hang
    }

    /// Failure injection: backend errors propagate to every waiter.
    struct FailBackend;
    impl SearchBackend for FailBackend {
        fn dim(&self) -> usize {
            1
        }
        fn search_batch(
            &self,
            _q: &[f32],
            _k: usize,
            _params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            Err(crate::Error::Serve("injected".into()))
        }
        fn describe(&self) -> String {
            "fail".into()
        }
    }

    /// Backend that echoes the per-request nprobe back as the label, to
    /// prove overrides reach the backend per-group and never leak.
    struct ParamEchoBackend;
    impl SearchBackend for ParamEchoBackend {
        fn dim(&self) -> usize {
            1
        }
        fn search_batch(
            &self,
            queries: &[f32],
            k: usize,
            params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            let nprobe = params.and_then(|p| p.nprobe).unwrap_or(0) as i64;
            let nq = queries.len();
            Ok((vec![0.0; nq * k], vec![nprobe; nq * k]))
        }
        fn describe(&self) -> String {
            "param-echo".into()
        }
    }

    #[test]
    fn per_request_params_do_not_leak_across_batch() {
        let b = Arc::new(Batcher::start(
            Arc::new(ParamEchoBackend),
            BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2), ..Default::default() },
        ));
        let mut handles = Vec::new();
        for i in 0..24u64 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let nprobe = (i % 3) as usize; // 0 means "no params"
                let params =
                    (nprobe > 0).then(|| SearchParams::new().with_nprobe(nprobe));
                let resp = b.search(vec![i as f32], 2, params).unwrap();
                (nprobe as i64, resp)
            }));
        }
        for h in handles {
            let (nprobe, resp) = h.join().unwrap();
            assert_eq!(resp.labels, vec![nprobe; 2], "params leaked between requests");
        }
    }

    #[test]
    fn backend_errors_propagate() {
        let b = Batcher::start(Arc::new(FailBackend), BatcherConfig::default());
        let err = b.search(vec![0.0], 1, None).unwrap_err();
        assert!(err.to_string().contains("injected"));
        assert_eq!(b.metrics.errors_total.load(std::sync::atomic::Ordering::Relaxed), 1);
        b.shutdown();
    }
}
