//! Dynamic batcher: collects concurrent queries into windows and runs them
//! through a [`SearchBackend`] as one batched call.
//!
//! Policy (vLLM-style continuous batching, simplified to stateless search):
//! the worker blocks for the first request, then drains the queue up to
//! `max_batch` or until `max_wait` elapses, groups by
//! `(kind, filter, params)`, executes, and routes each response to its
//! reply channel. Batching amortizes per-query fixed costs — above all LUT
//! construction, the serving-layer analog of the paper keeping tables
//! register-resident: each group becomes ONE backend [`QueryRequest`], and
//! a sharded backend ([`crate::coordinator::ShardedBackend`]) computes the
//! group's per-query scan LUTs once and reuses them across its whole shard
//! fan-out instead of rebuilding per shard.
//!
//! The grouping key is exact equality — kind AND filter AND params — so
//! requests carrying different overrides, different filters, or different
//! query kinds never share (or pollute) a backend call. Filters compare
//! structurally (`IdSet`/`IdRange`) or by closure identity (`Predicate`);
//! the [`crate::index::query::Filter::signature`] is for metrics only.
//!
//! # Admission control and deadlines
//!
//! The submit path **never blocks and never queues unboundedly**: the
//! admission queue is the bounded `sync_channel(queue_depth)`, and when it
//! is full the request is rejected at the door with
//! [`crate::Error::Overloaded`] (counted in
//! `admission_rejections_total`). An overloaded server therefore keeps
//! answering admitted work at full speed instead of building a latency
//! cliff — clients back off and retry.
//!
//! With a [`BatcherConfig::deadline`] configured, each window additionally
//! applies **deadline-aware degradation**: requests that have burned most
//! of their budget in the queue, or windows formed while the queue is
//! deep, get their *per-request* `nprobe` override halved (level 1) or
//! quartered (level 2), floored at 1. Only effort is degraded — never
//! correctness: results are still exact for the probes scanned, requests
//! without an explicit `nprobe` are never touched (index defaults are the
//! backend's business), and degradation is OFF unless a deadline is set
//! (the default), so batching stays bit-identical to the direct path.

use super::metrics::Metrics;
use super::service::SearchBackend;
use crate::index::query::{pad_hits, Filter, QueryKind, QueryRequest, QueryStats};
use crate::index::SearchParams;
use crate::obs::TraceSpan;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One in-flight query waiting for batch formation.
pub struct PendingQuery {
    pub vector: Vec<f32>,
    pub kind: QueryKind,
    /// Part of the batching key (exact equality), like `kind` and `params`.
    pub filter: Option<Filter>,
    pub params: Option<SearchParams>,
    /// Collect per-phase trace spans for this query. NOT part of the
    /// batching key: tracing never changes results (bit-identity
    /// invariant), so traced and untraced requests share a group and the
    /// group runs traced if ANY member asked.
    pub trace: bool,
    pub enqueued: Instant,
    pub reply: SyncSender<Result<ServeResponse>>,
}

/// The answer routed back to the submitting client.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    /// Top-k responses are padded to exactly `k` entries with
    /// `(INFINITY, -1)` (the legacy wire shape); range responses are
    /// variable-length and unpadded.
    pub distances: Vec<f32>,
    pub labels: Vec<i64>,
    /// Per-query execution stats from the backend.
    pub stats: QueryStats,
    /// Time spent waiting for batch formation.
    pub queue_us: u64,
    /// Backend execution time of the whole batch.
    pub service_us: u64,
    /// How many queries shared the batch.
    pub batch_size: usize,
    /// Per-phase spans for this query (empty unless the request asked
    /// for tracing).
    pub trace: Vec<TraceSpan>,
}

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Worker threads draining the shared queue.
    pub workers: usize,
    /// Bounded admission queue depth; a full queue rejects with
    /// [`crate::Error::Overloaded`] instead of blocking the submitter.
    pub queue_depth: usize,
    /// Per-request latency budget. `None` (the default) disables
    /// deadline-aware degradation entirely. `Some(d)`: requests that spent
    /// more than `d/2` queued — or windows formed with the queue more than
    /// half full — have their explicit `nprobe` override halved; past `d`
    /// (or a ¾-full queue) it is quartered, floored at 1. Requests without
    /// an explicit `nprobe` are never modified.
    pub deadline: Option<Duration>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            workers: 1,
            queue_depth: 1024,
            deadline: None,
        }
    }
}

/// Handle to a running batcher.
pub struct Batcher {
    tx: SyncSender<PendingQuery>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Requests admitted but not yet pulled into a window — the pressure
    /// signal for admission metrics and deadline degradation.
    depth: Arc<AtomicUsize>,
}

impl Batcher {
    /// Spawn the worker threads.
    pub fn start(backend: Arc<dyn SearchBackend>, cfg: BatcherConfig) -> Batcher {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = sync_channel::<PendingQuery>(cfg.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let depth = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let backend = backend.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            let depth = depth.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(rx, backend, metrics, cfg, depth);
            }));
        }
        Batcher { tx, metrics, workers, depth }
    }

    /// Admitted-but-unscheduled requests right now.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Enqueue a typed query; returns the reply receiver.
    pub fn submit_query(
        &self,
        vector: Vec<f32>,
        kind: QueryKind,
        filter: Option<Filter>,
        params: Option<SearchParams>,
    ) -> Receiver<Result<ServeResponse>> {
        self.submit_query_traced(vector, kind, filter, params, false)
    }

    /// Enqueue a typed query, optionally requesting per-phase trace spans
    /// in the response; returns the reply receiver.
    pub fn submit_query_traced(
        &self,
        vector: Vec<f32>,
        kind: QueryKind,
        filter: Option<Filter>,
        params: Option<SearchParams>,
        trace: bool,
    ) -> Receiver<Result<ServeResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.metrics.requests_total.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        // normalize Some(no overrides) to None so it batches with bare
        // requests instead of forming its own group
        let params = params.filter(|p| !p.is_empty());
        let req = PendingQuery {
            vector,
            kind,
            filter,
            params,
            trace,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        // Bounded admission: a full queue rejects at the door instead of
        // blocking the connection thread behind an unbounded backlog.
        match self.tx.try_send(req) {
            Ok(()) => {
                let d = self.depth.fetch_add(1, Ordering::AcqRel) + 1;
                self.metrics.admission_queue_depth.store(d as u64, Ordering::Relaxed);
            }
            Err(TrySendError::Full(req)) => {
                self.metrics
                    .admission_rejections_total
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let _ = req.reply.send(Err(crate::Error::Overloaded));
            }
            // Disconnected means shutdown; the caller sees a disconnected
            // reply channel, same as the pre-admission behavior.
            Err(TrySendError::Disconnected(_)) => {}
        }
        reply_rx
    }

    /// Enqueue an unfiltered top-k query (the legacy entry).
    pub fn submit(
        &self,
        vector: Vec<f32>,
        k: usize,
        params: Option<SearchParams>,
    ) -> Receiver<Result<ServeResponse>> {
        self.submit_query(vector, QueryKind::TopK { k }, None, params)
    }

    /// Convenience: submit a top-k query and wait.
    pub fn search(
        &self,
        vector: Vec<f32>,
        k: usize,
        params: Option<SearchParams>,
    ) -> Result<ServeResponse> {
        self.query(vector, QueryKind::TopK { k }, None, params)
    }

    /// Convenience: submit any typed query and wait.
    pub fn query(
        &self,
        vector: Vec<f32>,
        kind: QueryKind,
        filter: Option<Filter>,
        params: Option<SearchParams>,
    ) -> Result<ServeResponse> {
        self.submit_query(vector, kind, filter, params)
            .recv()
            .map_err(|_| crate::Error::Serve("batcher shut down".into()))?
    }

    /// Convenience: submit a traced typed query and wait. The response's
    /// `trace` holds the per-phase spans for this query.
    pub fn query_traced(
        &self,
        vector: Vec<f32>,
        kind: QueryKind,
        filter: Option<Filter>,
        params: Option<SearchParams>,
    ) -> Result<ServeResponse> {
        self.submit_query_traced(vector, kind, filter, params, true)
            .recv()
            .map_err(|_| crate::Error::Serve("batcher shut down".into()))?
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<Receiver<PendingQuery>>>,
    backend: Arc<dyn SearchBackend>,
    metrics: Arc<Metrics>,
    cfg: BatcherConfig,
    depth: Arc<AtomicUsize>,
) {
    loop {
        // Block for the first request of a window.
        let first = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(r) => r,
                Err(_) => return, // channel closed
            }
        };
        depth.fetch_sub(1, Ordering::AcqRel);
        let window_start = Instant::now();
        let mut batch = vec![first];
        // Drain until the window closes.
        while batch.len() < cfg.max_batch {
            let remaining = cfg.max_wait.saturating_sub(window_start.elapsed());
            let next = {
                let guard = rx.lock().unwrap();
                if remaining.is_zero() {
                    match guard.try_recv() {
                        Ok(r) => Some(r),
                        Err(_) => None,
                    }
                } else {
                    match guard.recv_timeout(remaining) {
                        Ok(r) => Some(r),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => None,
                    }
                }
            };
            match next {
                Some(r) => {
                    depth.fetch_sub(1, Ordering::AcqRel);
                    batch.push(r);
                }
                None => break,
            }
        }
        let backlog = depth.load(Ordering::Acquire);
        metrics.admission_queue_depth.store(backlog as u64, Ordering::Relaxed);
        execute_batch(&*backend, &metrics, &cfg, backlog, batch);
    }
}

type GroupKey = (QueryKind, Option<Filter>, Option<SearchParams>);

/// Degradation level for one request under the configured deadline: 0 =
/// untouched, 1 = halve the explicit `nprobe`, 2 = quarter it.
fn degrade_level(cfg: &BatcherConfig, backlog: usize, queued_for: Duration) -> u32 {
    let Some(deadline) = cfg.deadline else { return 0 };
    let cap = cfg.queue_depth.max(1);
    let mut level = 0;
    if backlog > cap / 2 || queued_for > deadline / 2 {
        level = 1;
    }
    if backlog > cap * 3 / 4 || queued_for >= deadline {
        level = 2;
    }
    level
}

/// Apply a degradation level to a request's params. Only an explicit
/// per-request `nprobe > 1` is ever reduced (floored at 1); everything
/// else — including requests with no override — passes through untouched.
/// Returns whether a reduction actually happened.
fn degrade_params(params: &mut Option<SearchParams>, level: u32) -> bool {
    if level == 0 {
        return false;
    }
    if let Some(p) = params {
        if let Some(np) = p.nprobe {
            let reduced = (np >> level).max(1);
            if reduced < np {
                p.nprobe = Some(reduced);
                return true;
            }
        }
    }
    false
}

fn execute_batch(
    backend: &dyn SearchBackend,
    metrics: &Metrics,
    cfg: &BatcherConfig,
    backlog: usize,
    mut batch: Vec<PendingQuery>,
) {
    // Deadline-aware degradation BEFORE grouping, so degraded and
    // untouched requests form separate groups and overrides never leak.
    if cfg.deadline.is_some() {
        let now = Instant::now();
        for r in &mut batch {
            let level = degrade_level(cfg, backlog, now.saturating_duration_since(r.enqueued));
            if degrade_params(&mut r.params, level) {
                metrics
                    .deadline_degraded_total
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
    }
    metrics.record_batch(batch.len());
    let batch_size = batch.len();
    let batch_t0 = Instant::now();
    // group by (kind, filter, params) so one backend call serves each
    // combination — per-request kinds/filters/overrides must never leak
    // into a neighbor's query
    let mut groups: Vec<(GroupKey, Vec<PendingQuery>)> = Vec::new();
    for r in batch {
        match groups
            .iter_mut()
            .find(|(key, _)| key.0 == r.kind && key.1 == r.filter && key.2 == r.params)
        {
            Some((_, g)) => g.push(r),
            None => groups.push(((r.kind, r.filter.clone(), r.params.clone()), vec![r])),
        }
    }
    for ((kind, filter, params), group) in groups {
        let mut queries = Vec::with_capacity(group.len() * backend.dim());
        for r in &group {
            queries.extend_from_slice(&r.vector);
        }
        // Tracing is bit-identical, so the group runs traced if ANY member
        // asked; spans are handed back only to the members that did.
        let group_trace = group.iter().any(|r| r.trace);
        let req = QueryRequest { queries: &queries, kind, filter, params, trace: group_trace };
        let t0 = Instant::now();
        let result = backend.query_batch(&req);
        let service_us = t0.elapsed().as_micros() as u64;
        metrics.service_us.record(service_us.max(1));
        match result {
            Ok(resp) => {
                for (i, r) in group.into_iter().enumerate() {
                    let queue_us = (t0 - r.enqueued).as_micros() as u64;
                    metrics.queue_us.record(queue_us.max(1));
                    metrics.e2e_us.record((queue_us + service_us).max(1));
                    let stats = resp.stats.get(i).copied().unwrap_or_default();
                    // legacy backends synthesize default stats
                    // (codes_scanned 0); recording those would drag the
                    // scan-work histograms toward zero, so only real scan
                    // work is folded in
                    if stats.codes_scanned > 0 {
                        metrics.record_query_stats(&stats);
                    }
                    let trace = if group_trace {
                        resp.traces.get(i).cloned().unwrap_or_default()
                    } else {
                        Vec::new()
                    };
                    if !trace.is_empty() {
                        metrics.record_trace(&trace);
                    }
                    // every query is a slowlog candidate; the trace rides
                    // along when present so the worst entries come with a
                    // phase breakdown for free
                    metrics.record_slow(
                        queue_us + service_us,
                        match kind {
                            QueryKind::TopK { .. } => "topk",
                            QueryKind::Range { .. } => "range",
                        },
                        1,
                        &trace,
                    );
                    // top-k keeps the legacy padded wire shape; range hits
                    // are inherently variable-length
                    let (distances, labels) = match kind {
                        QueryKind::TopK { k } => pad_hits(&resp.hits[i], k),
                        QueryKind::Range { .. } => (
                            resp.hits[i].iter().map(|h| h.distance).collect(),
                            resp.hits[i].iter().map(|h| h.label).collect(),
                        ),
                    };
                    let out = ServeResponse {
                        distances,
                        labels,
                        stats,
                        queue_us,
                        service_us,
                        batch_size,
                        trace: if r.trace { trace } else { Vec::new() },
                    };
                    let _ = r.reply.send(Ok(out));
                }
            }
            Err(e) => {
                metrics.errors_total.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let msg = e.to_string();
                for r in group {
                    let _ = r.reply.send(Err(crate::Error::Serve(msg.clone())));
                }
            }
        }
    }
    // whole-window execution latency (all groups): the wire-visible view
    // of the executor's thread win at a given batch size
    metrics
        .batch_latency_us
        .record((batch_t0.elapsed().as_micros() as u64).max(1));
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic toy backend: distance = rank, label = floor(v[0]).
    struct EchoBackend {
        dim: usize,
        delay: Duration,
    }

    impl SearchBackend for EchoBackend {
        fn dim(&self) -> usize {
            self.dim
        }
        fn search_batch(
            &self,
            queries: &[f32],
            k: usize,
            _params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            std::thread::sleep(self.delay);
            let nq = queries.len() / self.dim;
            let mut d = Vec::new();
            let mut l = Vec::new();
            for qi in 0..nq {
                for r in 0..k {
                    d.push(r as f32);
                    l.push(queries[qi * self.dim] as i64);
                }
            }
            Ok((d, l))
        }
        fn describe(&self) -> String {
            "echo".into()
        }
    }

    #[test]
    fn routes_responses_to_correct_clients() {
        let be = Arc::new(EchoBackend { dim: 2, delay: Duration::ZERO });
        let b = Batcher::start(be, BatcherConfig::default());
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push((i, b.submit(vec![i as f32, 0.0], 3, None)));
        }
        for (i, rx) in rxs {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.labels, vec![i as i64; 3]);
            assert_eq!(resp.distances, vec![0.0, 1.0, 2.0]);
        }
        b.shutdown();
    }

    #[test]
    fn batches_form_under_concurrency() {
        // slow backend + concurrent submitters → batches larger than 1
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::from_millis(3) });
        let b = Arc::new(Batcher::start(
            be,
            BatcherConfig { max_batch: 16, max_wait: Duration::from_millis(2), ..Default::default() },
        ));
        let mut handles = Vec::new();
        for i in 0..32 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                b.search(vec![i as f32], 1, None).unwrap()
            }));
        }
        let responses: Vec<ServeResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch > 1, "no batching happened (max={max_batch})");
        assert_eq!(b.metrics.requests_total.load(std::sync::atomic::Ordering::Relaxed), 32);
    }

    #[test]
    fn mixed_k_in_one_window() {
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::ZERO });
        let b = Batcher::start(be, BatcherConfig::default());
        let r1 = b.submit(vec![1.0], 2, None);
        let r2 = b.submit(vec![2.0], 5, None);
        assert_eq!(r1.recv().unwrap().unwrap().distances.len(), 2);
        assert_eq!(r2.recv().unwrap().unwrap().distances.len(), 5);
        b.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::ZERO });
        let b = Batcher::start(be, BatcherConfig { workers: 2, ..Default::default() });
        let resp = b.search(vec![5.0], 1, None).unwrap();
        assert_eq!(resp.labels, vec![5]);
        b.shutdown(); // must not hang
    }

    /// Failure injection: backend errors propagate to every waiter.
    struct FailBackend;
    impl SearchBackend for FailBackend {
        fn dim(&self) -> usize {
            1
        }
        fn search_batch(
            &self,
            _q: &[f32],
            _k: usize,
            _params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            Err(crate::Error::Serve("injected".into()))
        }
        fn describe(&self) -> String {
            "fail".into()
        }
    }

    /// Backend that echoes the per-request nprobe back as the label, to
    /// prove overrides reach the backend per-group and never leak.
    struct ParamEchoBackend;
    impl SearchBackend for ParamEchoBackend {
        fn dim(&self) -> usize {
            1
        }
        fn search_batch(
            &self,
            queries: &[f32],
            k: usize,
            params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            let nprobe = params.and_then(|p| p.nprobe).unwrap_or(0) as i64;
            let nq = queries.len();
            Ok((vec![0.0; nq * k], vec![nprobe; nq * k]))
        }
        fn describe(&self) -> String {
            "param-echo".into()
        }
    }

    #[test]
    fn per_request_params_do_not_leak_across_batch() {
        let b = Arc::new(Batcher::start(
            Arc::new(ParamEchoBackend),
            BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2), ..Default::default() },
        ));
        let mut handles = Vec::new();
        for i in 0..24u64 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let nprobe = (i % 3) as usize; // 0 means "no params"
                let params =
                    (nprobe > 0).then(|| SearchParams::new().with_nprobe(nprobe));
                let resp = b.search(vec![i as f32], 2, params).unwrap();
                (nprobe as i64, resp)
            }));
        }
        for h in handles {
            let (nprobe, resp) = h.join().unwrap();
            assert_eq!(resp.labels, vec![nprobe; 2], "params leaked between requests");
        }
    }

    /// Backend that echoes the request's filter signature (or 0) back as
    /// the label: requests with different filters must never share a call.
    struct FilterEchoBackend;
    impl SearchBackend for FilterEchoBackend {
        fn dim(&self) -> usize {
            1
        }
        fn search_batch(
            &self,
            queries: &[f32],
            k: usize,
            _params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            Ok((vec![0.0; queries.len() * k], vec![0; queries.len() * k]))
        }
        fn query_batch(
            &self,
            req: &crate::index::query::QueryRequest<'_>,
        ) -> Result<crate::index::query::QueryResponse> {
            use crate::index::query::{Hit, QueryResponse, QueryStats};
            let tag = req.filter.as_ref().map(|f| f.signature() as i64 & 0xFFFF).unwrap_or(0);
            let nq = req.queries.len();
            Ok(QueryResponse {
                hits: vec![vec![Hit { distance: 0.0, label: tag }]; nq],
                stats: vec![QueryStats::default(); nq],
                traces: Vec::new(),
            })
        }
        fn describe(&self) -> String {
            "filter-echo".into()
        }
    }

    #[test]
    fn filters_partition_the_batch() {
        use crate::index::query::Filter;
        let b = Arc::new(Batcher::start(
            Arc::new(FilterEchoBackend),
            BatcherConfig { max_batch: 32, max_wait: Duration::from_millis(2), ..Default::default() },
        ));
        let filters = [None, Some(Filter::id_range(0, 10)), Some(Filter::id_range(0, 20))];
        let expect: Vec<i64> = filters
            .iter()
            .map(|f| f.as_ref().map(|f| f.signature() as i64 & 0xFFFF).unwrap_or(0))
            .collect();
        let mut handles = Vec::new();
        for i in 0..18usize {
            let b = b.clone();
            let filter = filters[i % 3].clone();
            handles.push(std::thread::spawn(move || {
                let resp =
                    b.query(vec![i as f32], QueryKind::TopK { k: 1 }, filter, None).unwrap();
                (i % 3, resp)
            }));
        }
        for h in handles {
            let (which, resp) = h.join().unwrap();
            assert_eq!(resp.labels, vec![expect[which]], "filter leaked between requests");
        }
    }

    #[test]
    fn backend_errors_propagate() {
        let b = Batcher::start(Arc::new(FailBackend), BatcherConfig::default());
        let err = b.search(vec![0.0], 1, None).unwrap_err();
        assert!(err.to_string().contains("injected"));
        assert_eq!(b.metrics.errors_total.load(std::sync::atomic::Ordering::Relaxed), 1);
        b.shutdown();
    }

    /// Bounded admission: with a tiny queue and a slow backend, a burst is
    /// partially rejected with `Error::Overloaded` — and once the backlog
    /// drains, the batcher serves new work again (responsive, not wedged).
    #[test]
    fn overload_rejects_with_bounded_queue_then_recovers() {
        let be = Arc::new(EchoBackend { dim: 1, delay: Duration::from_millis(20) });
        let b = Batcher::start(
            be,
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
                queue_depth: 2,
                ..Default::default()
            },
        );
        // the burst arrives faster than 20ms-per-window service can drain
        let rxs: Vec<_> = (0..16).map(|i| b.submit(vec![i as f32], 1, None)).collect();
        let mut ok = 0usize;
        let mut overloaded = 0usize;
        for rx in rxs {
            match rx.recv().unwrap() {
                Ok(_) => ok += 1,
                Err(e) => {
                    assert!(e.to_string().contains("overloaded"), "unexpected error: {e}");
                    overloaded += 1;
                }
            }
        }
        assert!(ok >= 1, "admitted work must still complete");
        assert!(overloaded >= 1, "a 16-deep burst into a 2-deep queue must reject");
        assert_eq!(
            b.metrics.admission_rejections_total.load(std::sync::atomic::Ordering::Relaxed),
            overloaded as u64
        );
        // recovered: the queue drained, so a fresh request is admitted
        let resp = b.search(vec![7.0], 1, None).unwrap();
        assert_eq!(resp.labels, vec![7]);
        assert_eq!(b.queue_depth(), 0);
        b.shutdown();
    }

    /// Deadline degradation reduces only the explicit per-request `nprobe`
    /// (floored at 1); requests without an override are never touched, and
    /// with no deadline configured nothing changes at all.
    #[test]
    fn overload_deadline_degrades_nprobe_only() {
        // deadline ZERO ⇒ every request is past its budget ⇒ level 2
        let b = Batcher::start(
            Arc::new(ParamEchoBackend),
            BatcherConfig { deadline: Some(Duration::ZERO), ..Default::default() },
        );
        let resp = b.search(vec![1.0], 2, Some(SearchParams::new().with_nprobe(8))).unwrap();
        assert_eq!(resp.labels, vec![2; 2], "nprobe 8 must quarter to 2 at level 2");
        let resp = b.search(vec![1.0], 2, Some(SearchParams::new().with_nprobe(1))).unwrap();
        assert_eq!(resp.labels, vec![1; 2], "nprobe floor is 1");
        let resp = b.search(vec![1.0], 2, None).unwrap();
        assert_eq!(resp.labels, vec![0; 2], "no override ⇒ untouched");
        assert!(
            b.metrics.deadline_degraded_total.load(std::sync::atomic::Ordering::Relaxed) >= 1
        );
        b.shutdown();

        // no deadline ⇒ bit-identical to the pre-deadline batcher
        let b = Batcher::start(Arc::new(ParamEchoBackend), BatcherConfig::default());
        let resp = b.search(vec![1.0], 2, Some(SearchParams::new().with_nprobe(8))).unwrap();
        assert_eq!(resp.labels, vec![8; 2], "no deadline ⇒ nprobe untouched");
        assert_eq!(b.metrics.deadline_degraded_total.load(std::sync::atomic::Ordering::Relaxed), 0);
        b.shutdown();
    }
}
