//! Serving metrics: lock-free counters + latency histograms, JSON export.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed-bucket microsecond histogram (powers of two from 1 µs to ~8 s).
#[derive(Debug, Default)]
pub struct UsHistogram {
    buckets: [AtomicU64; 24],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl UsHistogram {
    pub fn record(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(23);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate percentile from bucket upper bounds.
    pub fn percentile_us(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64; // bucket upper bound
            }
        }
        (1u64 << 24) as f64
    }
}

/// Coordinator-wide metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub batches_total: AtomicU64,
    pub batched_queries_total: AtomicU64,
    pub errors_total: AtomicU64,
    /// time from enqueue to batch formation
    pub queue_us: UsHistogram,
    /// backend search time per batch
    pub service_us: UsHistogram,
    /// whole-batch execution latency (all groups of one window, end to
    /// end) — the histogram that makes the executor's thread win
    /// measurable from the wire: at fixed batch size, more threads → the
    /// distribution shifts left
    pub batch_latency_us: UsHistogram,
    /// end-to-end per request
    pub e2e_us: UsHistogram,
    /// per-request codes scanned (log2 buckets; sourced from
    /// `QueryResponse` stats)
    pub codes_scanned: UsHistogram,
    /// per-request filter selectivity in permille (0–1000; 1000 =
    /// unfiltered)
    pub filter_selectivity_pm: UsHistogram,
    /// widest executor fan-out observed on any request (gauge, max)
    pub exec_threads: AtomicU64,
    /// executor scratch-arena high-water bytes (gauge, max) — the
    /// steady-state working set the allocation-free scan path reuses
    pub scratch_high_water_bytes: AtomicU64,
    /// rows accepted through the `insert` verb
    pub inserts_total: AtomicU64,
    /// live rows removed through the `delete` verb
    pub deletes_total: AtomicU64,
    /// widest per-query segment fan-out observed (gauge, max; 0 when the
    /// backend is a sealed single-segment index)
    pub segments_scanned: AtomicU64,
    /// segment-lifecycle gauges (latest observation via
    /// [`Metrics::record_segment_stats`]) — together they make compaction
    /// pressure observable: a growing memtable means the flush worker is
    /// behind, growing tombstones mean dead rows are bloating scans
    pub segments: AtomicU64,
    pub memtable_entries: AtomicU64,
    pub tombstones: AtomicU64,
    pub flushes_total: AtomicU64,
    pub compactions_total: AtomicU64,
    /// storage-layer residency gauges (latest observation via
    /// [`Metrics::record_storage_stats`], sourced from
    /// [`crate::storage::counters`]): how many packed-code bytes are
    /// mmap-backed, how many of those are advised resident, and how many
    /// mmap opens the process has performed
    pub mapped_code_bytes: AtomicU64,
    pub resident_code_bytes: AtomicU64,
    pub mmap_open_total: AtomicU64,
    /// recent batch sizes (bounded ring, for mean occupancy)
    batch_sizes: Mutex<Vec<usize>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one request's [`crate::index::query::QueryStats`] into the
    /// scan-work histograms and concurrency gauges.
    pub fn record_query_stats(&self, stats: &crate::index::query::QueryStats) {
        self.codes_scanned.record(stats.codes_scanned as u64);
        let pm = (stats.filter_selectivity.clamp(0.0, 1.0) * 1000.0).round() as u64;
        self.filter_selectivity_pm.record(pm);
        self.exec_threads.fetch_max(stats.threads_used as u64, Ordering::Relaxed);
        self.scratch_high_water_bytes
            .fetch_max(stats.scratch_bytes as u64, Ordering::Relaxed);
        self.segments_scanned
            .fetch_max(stats.segments_scanned as u64, Ordering::Relaxed);
    }

    /// Record the segment-lifecycle gauges from a backend's current
    /// [`crate::segment::SegmentStats`] (no-op for `None`, i.e. sealed
    /// single-segment backends). Called after mutations and on the `stats`
    /// verb, so the gauges track the latest observed state.
    pub fn record_segment_stats(&self, stats: Option<crate::segment::SegmentStats>) {
        let Some(s) = stats else { return };
        self.segments.store(s.segments as u64, Ordering::Relaxed);
        self.memtable_entries.store(s.memtable_entries as u64, Ordering::Relaxed);
        self.tombstones.store(s.tombstones as u64, Ordering::Relaxed);
        self.flushes_total.store(s.flushes, Ordering::Relaxed);
        self.compactions_total.store(s.compactions, Ordering::Relaxed);
    }

    /// Refresh the storage residency gauges from the process-wide
    /// [`crate::storage::counters`]. Called on the `stats` verb so the
    /// export reflects the current mapped/resident state.
    pub fn record_storage_stats(&self) {
        let c = crate::storage::counters();
        self.mapped_code_bytes.store(c.mapped_code_bytes(), Ordering::Relaxed);
        self.resident_code_bytes.store(c.resident_code_bytes(), Ordering::Relaxed);
        self.mmap_open_total.store(c.mmap_open_total(), Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches_total.fetch_add(1, Ordering::Relaxed);
        self.batched_queries_total.fetch_add(size as u64, Ordering::Relaxed);
        let mut v = self.batch_sizes.lock().unwrap();
        if v.len() >= 4096 {
            v.drain(..2048);
        }
        v.push(size);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_total.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_queries_total.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Export as JSON (served by the `stats` command of the TCP protocol).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("requests_total", Json::Num(self.requests_total.load(Ordering::Relaxed) as f64))
            .set("batches_total", Json::Num(self.batches_total.load(Ordering::Relaxed) as f64))
            .set("errors_total", Json::Num(self.errors_total.load(Ordering::Relaxed) as f64))
            .set("mean_batch_size", Json::Num(self.mean_batch_size()))
            .set("queue_mean_us", Json::Num(self.queue_us.mean_us()))
            .set("service_mean_us", Json::Num(self.service_us.mean_us()))
            .set("batch_latency_mean_us", Json::Num(self.batch_latency_us.mean_us()))
            .set("batch_latency_p50_us", Json::Num(self.batch_latency_us.percentile_us(50.0)))
            .set("batch_latency_p95_us", Json::Num(self.batch_latency_us.percentile_us(95.0)))
            .set(
                "exec_threads",
                Json::Num(self.exec_threads.load(Ordering::Relaxed) as f64),
            )
            .set(
                "scratch_high_water_bytes",
                Json::Num(self.scratch_high_water_bytes.load(Ordering::Relaxed) as f64),
            )
            .set("e2e_mean_us", Json::Num(self.e2e_us.mean_us()))
            .set("e2e_p50_us", Json::Num(self.e2e_us.percentile_us(50.0)))
            .set("e2e_p95_us", Json::Num(self.e2e_us.percentile_us(95.0)))
            .set("e2e_p99_us", Json::Num(self.e2e_us.percentile_us(99.0)))
            .set("codes_scanned_count", Json::Num(self.codes_scanned.count() as f64))
            .set("codes_scanned_mean", Json::Num(self.codes_scanned.mean_us()))
            .set("codes_scanned_p95", Json::Num(self.codes_scanned.percentile_us(95.0)))
            .set(
                "filter_selectivity_mean",
                Json::Num(self.filter_selectivity_pm.mean_us() / 1000.0),
            )
            .set(
                "filter_selectivity_p50",
                Json::Num(self.filter_selectivity_pm.percentile_us(50.0) / 1000.0),
            )
            .set("inserts_total", Json::Num(self.inserts_total.load(Ordering::Relaxed) as f64))
            .set("deletes_total", Json::Num(self.deletes_total.load(Ordering::Relaxed) as f64))
            .set(
                "segments_scanned",
                Json::Num(self.segments_scanned.load(Ordering::Relaxed) as f64),
            )
            .set("segments", Json::Num(self.segments.load(Ordering::Relaxed) as f64))
            .set(
                "memtable_entries",
                Json::Num(self.memtable_entries.load(Ordering::Relaxed) as f64),
            )
            .set("tombstones", Json::Num(self.tombstones.load(Ordering::Relaxed) as f64))
            .set("flushes_total", Json::Num(self.flushes_total.load(Ordering::Relaxed) as f64))
            .set(
                "compactions_total",
                Json::Num(self.compactions_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "mapped_code_bytes",
                Json::Num(self.mapped_code_bytes.load(Ordering::Relaxed) as f64),
            )
            .set(
                "resident_code_bytes",
                Json::Num(self.resident_code_bytes.load(Ordering::Relaxed) as f64),
            )
            .set(
                "mmap_open_total",
                Json::Num(self.mmap_open_total.load(Ordering::Relaxed) as f64),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_percentiles() {
        let h = UsHistogram::default();
        for us in [10u64, 20, 30, 40, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1e-9);
        // p50 falls in the bucket containing 20-30 µs → upper bound 32 or 64
        let p50 = h.percentile_us(50.0);
        assert!(p50 >= 16.0 && p50 <= 64.0, "p50 {p50}");
        let p99 = h.percentile_us(99.0);
        assert!(p99 >= 1000.0, "p99 {p99}");
    }

    #[test]
    fn empty_histogram() {
        let h = UsHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(99.0), 0.0);
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
        let j = m.to_json();
        assert_eq!(j.get("batches_total").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn json_has_expected_keys() {
        let m = Metrics::new();
        m.requests_total.fetch_add(3, Ordering::Relaxed);
        m.e2e_us.record(500);
        let j = m.to_json();
        for key in [
            "requests_total",
            "e2e_p95_us",
            "service_mean_us",
            "codes_scanned_mean",
            "filter_selectivity_mean",
            "batch_latency_p50_us",
            "batch_latency_p95_us",
            "exec_threads",
            "scratch_high_water_bytes",
            "inserts_total",
            "deletes_total",
            "segments_scanned",
            "memtable_entries",
            "tombstones",
            "mapped_code_bytes",
            "resident_code_bytes",
            "mmap_open_total",
        ] {
            assert!(j.get(key).is_some(), "{key}");
        }
    }

    /// Storage residency gauges mirror the process-wide counters.
    #[test]
    fn storage_gauges_refresh_from_counters() {
        let m = Metrics::new();
        m.record_storage_stats();
        // counters are process-global (other tests may map files), so the
        // invariant checked here is consistency, not a specific value
        let c = crate::storage::counters();
        assert_eq!(m.mapped_code_bytes.load(Ordering::Relaxed), c.mapped_code_bytes());
        assert_eq!(m.mmap_open_total.load(Ordering::Relaxed), c.mmap_open_total());
        let j = m.to_json();
        assert!(j.get("resident_code_bytes").is_some());
    }

    /// Segment-lifecycle gauges track the latest observation; `None` (a
    /// sealed single-segment backend) leaves them untouched.
    #[test]
    fn segment_stats_gauges() {
        use crate::segment::SegmentStats;
        let m = Metrics::new();
        m.record_segment_stats(Some(SegmentStats {
            segments: 3,
            sealed_rows: 900,
            memtable_entries: 42,
            tombstones: 7,
            flushes: 5,
            compactions: 2,
        }));
        m.record_segment_stats(None); // no-op
        assert_eq!(m.segments.load(Ordering::Relaxed), 3);
        assert_eq!(m.memtable_entries.load(Ordering::Relaxed), 42);
        assert_eq!(m.tombstones.load(Ordering::Relaxed), 7);
        let j = m.to_json();
        assert_eq!(j.get("flushes_total").unwrap().as_usize().unwrap(), 5);
        assert_eq!(j.get("compactions_total").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("tombstones").unwrap().as_usize().unwrap(), 7);
    }

    /// The scan-work histograms (satellite: per-request codes_scanned /
    /// filter_selectivity sourced from QueryResponse stats).
    #[test]
    fn query_stats_recorded() {
        use crate::index::query::QueryStats;
        let m = Metrics::new();
        m.record_query_stats(&QueryStats {
            codes_scanned: 4096,
            lists_probed: 8,
            filter_selectivity: 0.25,
            threads_used: 4,
            scratch_bytes: 1 << 16,
            segments_scanned: 3,
            ..Default::default()
        });
        m.record_query_stats(&QueryStats {
            codes_scanned: 4096,
            lists_probed: 8,
            filter_selectivity: 0.75,
            threads_used: 2,
            scratch_bytes: 1 << 14,
            ..Default::default()
        });
        assert_eq!(m.codes_scanned.count(), 2);
        // gauges keep the maxima
        assert_eq!(m.exec_threads.load(Ordering::Relaxed), 4);
        assert_eq!(m.scratch_high_water_bytes.load(Ordering::Relaxed), 1 << 16);
        assert_eq!(m.segments_scanned.load(Ordering::Relaxed), 3);
        assert!((m.codes_scanned.mean_us() - 4096.0).abs() < 1e-9);
        let j = m.to_json();
        let sel = j.get("filter_selectivity_mean").unwrap().as_f64().unwrap();
        assert!((sel - 0.5).abs() < 1e-9, "{sel}");
        assert_eq!(j.get("codes_scanned_count").unwrap().as_usize().unwrap(), 2);
    }
}
