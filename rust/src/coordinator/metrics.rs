//! Serving metrics: lock-free counters + latency histograms, JSON and
//! Prometheus text exposition, per-phase profiling fed by completed
//! [`crate::obs::TraceSpan`]s, and a bounded slow-query log.

use crate::obs::{Phase, TraceSpan, NUM_PHASES};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of power-of-two buckets: values from 1 up to `2^24` (~16.7M —
/// ~16.7 s when the unit is µs, or 16M codes when it's a count).
const BUCKETS: usize = 24;

/// Fixed-bucket power-of-two histogram. The unit is whatever the caller
/// records — microseconds for the latency families, plain counts for
/// `codes_scanned` and batch occupancy. Bucket `i` holds values in
/// `[2^i, 2^(i+1))` (bucket 0 also absorbs 0).
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

/// Historical name: every original family recorded microseconds.
pub type UsHistogram = Histogram;

impl Histogram {
    pub fn record(&self, v: u64) {
        let b = (64 - v.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Approximate percentile, linearly interpolated **within** the
    /// winning bucket (rank position between the bucket's bounds) rather
    /// than snapped to its upper bound — the upper-bound snap
    /// overestimated every percentile by up to 2×.
    pub fn percentile(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil().max(1.0);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (seen + c) as f64 >= target {
                let lo = if i == 0 { 0.0 } else { (1u64 << i) as f64 };
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (target - seen as f64) / c as f64;
                return lo + frac * (hi - lo);
            }
            seen += c;
        }
        (1u64 << BUCKETS) as f64
    }

    /// [`Histogram::mean`] under the historical microsecond-family name.
    pub fn mean_us(&self) -> f64 {
        self.mean()
    }

    /// [`Histogram::percentile`] under the historical name.
    pub fn percentile_us(&self, p: f64) -> f64 {
        self.percentile(p)
    }

    /// Append this histogram in Prometheus text exposition (cumulative
    /// `_bucket{le=…}` lines + `_sum`/`_count`). `labels` is either empty
    /// or a `key="value"` pair to merge into every bucket's label set.
    fn write_prometheus(&self, out: &mut String, name: &str, labels: &str) {
        use std::fmt::Write;
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            let _ = writeln!(
                out,
                "{name}_bucket{{{labels}{sep}le=\"{}\"}} {cum}",
                1u64 << (i + 1)
            );
        }
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", self.count());
        if labels.is_empty() {
            let _ = writeln!(out, "{name}_sum {}", self.sum());
            let _ = writeln!(out, "{name}_count {}", self.count());
        } else {
            let _ = writeln!(out, "{name}_sum{{{labels}}} {}", self.sum());
            let _ = writeln!(out, "{name}_count{{{labels}}} {}", self.count());
        }
    }
}

/// How many worst-by-latency queries the slow-query log retains.
pub const SLOWLOG_CAPACITY: usize = 8;

/// One retained slow query: its end-to-end latency, the request shape,
/// and the full phase trace (when the query ran traced; empty otherwise).
#[derive(Clone, Debug)]
pub struct SlowQuery {
    pub e2e_us: u64,
    /// `"topk"` / `"range"` (matches the wire verbs).
    pub kind: String,
    pub nq: usize,
    pub trace: Vec<TraceSpan>,
}

/// Coordinator-wide metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests_total: AtomicU64,
    pub batches_total: AtomicU64,
    pub batched_queries_total: AtomicU64,
    pub errors_total: AtomicU64,
    /// time from enqueue to batch formation
    pub queue_us: UsHistogram,
    /// backend search time per batch
    pub service_us: UsHistogram,
    /// whole-batch execution latency (all groups of one window, end to
    /// end) — the histogram that makes the executor's thread win
    /// measurable from the wire: at fixed batch size, more threads → the
    /// distribution shifts left
    pub batch_latency_us: UsHistogram,
    /// end-to-end per request
    pub e2e_us: UsHistogram,
    /// per-request codes scanned (log2 buckets; sourced from
    /// `QueryResponse` stats)
    pub codes_scanned: Histogram,
    /// per-request filter selectivity in permille (0–1000; 1000 =
    /// unfiltered)
    pub filter_selectivity_pm: Histogram,
    /// queries per executed batch (log2 occupancy distribution — the
    /// mean alone hides bimodal windows)
    pub batch_occupancy: Histogram,
    /// per-phase wall time across traced queries, indexed by
    /// [`Phase::idx`] — the serving-side aggregate of the paper's Fig. 2
    /// cost split
    pub phase_us: [UsHistogram; NUM_PHASES],
    /// widest executor fan-out observed on any request (gauge, max)
    pub exec_threads: AtomicU64,
    /// executor scratch-arena high-water bytes (gauge, max) — the
    /// steady-state working set the allocation-free scan path reuses
    pub scratch_high_water_bytes: AtomicU64,
    /// rows accepted through the `insert` verb
    pub inserts_total: AtomicU64,
    /// live rows removed through the `delete` verb
    pub deletes_total: AtomicU64,
    /// widest per-query segment fan-out observed (gauge, max; 0 when the
    /// backend is a sealed single-segment index)
    pub segments_scanned: AtomicU64,
    /// segment-lifecycle gauges (latest observation via
    /// [`Metrics::record_segment_stats`]) — together they make compaction
    /// pressure observable: a growing memtable means the flush worker is
    /// behind, growing tombstones mean dead rows are bloating scans
    pub segments: AtomicU64,
    pub memtable_entries: AtomicU64,
    pub tombstones: AtomicU64,
    pub flushes_total: AtomicU64,
    pub compactions_total: AtomicU64,
    /// storage-layer residency gauges (latest observation via
    /// [`Metrics::record_storage_stats`], sourced from
    /// [`crate::storage::counters`]): how many packed-code bytes are
    /// mmap-backed, how many of those are advised resident, how many the
    /// kernel actually holds in RAM (`mincore`-sampled), and how many
    /// mmap opens the process has performed
    pub mapped_code_bytes: AtomicU64,
    pub resident_code_bytes: AtomicU64,
    pub resident_sampled_bytes: AtomicU64,
    pub mmap_open_total: AtomicU64,
    /// experiment-lab gauges (latest observation via
    /// [`Metrics::record_lab_stats`], sourced from
    /// [`crate::lab::counters`]): trials executed/failed this process and
    /// the last regression-gate verdict (0 none, 1 pass, 2 fail) — long
    /// sweeps are observable from the same scrape as served traffic
    pub lab_trials_total: AtomicU64,
    pub lab_trials_failed: AtomicU64,
    pub lab_gate_verdict: AtomicU64,
    /// admission-control instruments (fed by the batcher): current
    /// depth of the bounded admission queue (gauge), requests rejected
    /// at the door with [`crate::Error::Overloaded`] (counter), and
    /// requests whose `nprobe` was degraded by the deadline policy
    /// (counter) — together they show whether the server is shedding
    /// load and how it is paying for it
    pub admission_queue_depth: AtomicU64,
    pub admission_rejections_total: AtomicU64,
    pub deadline_degraded_total: AtomicU64,
    /// worker-pool instruments (latest observation via
    /// [`Metrics::record_pool_stats`], sourced from the process-global
    /// [`crate::exec::pool::counters`] and the global executor's pool
    /// snapshot): persistent workers, jobs currently queued, lifetime
    /// tasks executed on workers, lifetime cross-queue steals, and a
    /// per-worker busy fraction in permille of wall time since spawn
    pub pool_workers: AtomicU64,
    pub pool_queue_depth: AtomicU64,
    pub pool_tasks_total: AtomicU64,
    pub pool_steals_total: AtomicU64,
    pool_busy_permille: Mutex<Vec<u64>>,
    /// bounded worst-by-latency query ring (see [`Metrics::record_slow`])
    slowlog: Mutex<Vec<SlowQuery>>,
    /// admission floor: the smallest e2e in a **full** slowlog — reads
    /// below it skip the lock entirely on the hot path
    slow_floor_us: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one request's [`crate::index::query::QueryStats`] into the
    /// scan-work histograms and concurrency gauges.
    pub fn record_query_stats(&self, stats: &crate::index::query::QueryStats) {
        self.codes_scanned.record(stats.codes_scanned as u64);
        let pm = (stats.filter_selectivity.clamp(0.0, 1.0) * 1000.0).round() as u64;
        self.filter_selectivity_pm.record(pm);
        self.exec_threads.fetch_max(stats.threads_used as u64, Ordering::Relaxed);
        self.scratch_high_water_bytes
            .fetch_max(stats.scratch_bytes as u64, Ordering::Relaxed);
        self.segments_scanned
            .fetch_max(stats.segments_scanned as u64, Ordering::Relaxed);
    }

    /// Fold one traced query's completed spans into the per-phase
    /// latency histograms.
    pub fn record_trace(&self, spans: &[TraceSpan]) {
        for s in spans {
            self.phase_us[s.phase.idx()].record(s.us);
        }
    }

    /// Offer one finished query to the slow-query log: a bounded ring of
    /// the [`SLOWLOG_CAPACITY`] worst queries by end-to-end latency,
    /// each with its full trace when one was collected. Lock-free reject
    /// for queries faster than everything already retained.
    pub fn record_slow(&self, e2e_us: u64, kind: &str, nq: usize, trace: &[TraceSpan]) {
        if e2e_us <= self.slow_floor_us.load(Ordering::Relaxed) {
            return;
        }
        let mut log = self.slowlog.lock().unwrap();
        log.push(SlowQuery { e2e_us, kind: kind.to_string(), nq, trace: trace.to_vec() });
        log.sort_by(|a, b| b.e2e_us.cmp(&a.e2e_us));
        log.truncate(SLOWLOG_CAPACITY);
        if log.len() == SLOWLOG_CAPACITY {
            self.slow_floor_us.store(log.last().unwrap().e2e_us, Ordering::Relaxed);
        }
    }

    /// Snapshot of the slow-query log, worst first.
    pub fn slowlog(&self) -> Vec<SlowQuery> {
        self.slowlog.lock().unwrap().clone()
    }

    /// The slow-query log as a JSON array (the `slowlog` verb's payload).
    pub fn slowlog_json(&self) -> Json {
        let rows = self
            .slowlog()
            .into_iter()
            .map(|q| {
                let mut o = Json::obj();
                o.set("e2e_us", Json::Num(q.e2e_us as f64))
                    .set("kind", Json::Str(q.kind))
                    .set("nq", Json::Num(q.nq as f64))
                    .set(
                        "trace",
                        Json::Arr(
                            q.trace
                                .iter()
                                .map(|s| {
                                    let mut t = Json::obj();
                                    t.set("phase", Json::Str(s.phase.name().to_string()))
                                        .set("us", Json::Num(s.us as f64))
                                        .set("count", Json::Num(s.count as f64))
                                        .set("bytes", Json::Num(s.bytes as f64));
                                    t
                                })
                                .collect(),
                        ),
                    );
                o
            })
            .collect();
        Json::Arr(rows)
    }

    /// Record the segment-lifecycle gauges from a backend's current
    /// [`crate::segment::SegmentStats`] (no-op for `None`, i.e. sealed
    /// single-segment backends). Called after mutations and on the `stats`
    /// verb, so the gauges track the latest observed state.
    pub fn record_segment_stats(&self, stats: Option<crate::segment::SegmentStats>) {
        let Some(s) = stats else { return };
        self.segments.store(s.segments as u64, Ordering::Relaxed);
        self.memtable_entries.store(s.memtable_entries as u64, Ordering::Relaxed);
        self.tombstones.store(s.tombstones as u64, Ordering::Relaxed);
        self.flushes_total.store(s.flushes, Ordering::Relaxed);
        self.compactions_total.store(s.compactions, Ordering::Relaxed);
    }

    /// Refresh the storage residency gauges from the process-wide
    /// [`crate::storage::counters`]. Called on the `stats`/`metrics`
    /// verbs so the export reflects the current mapped/resident state.
    pub fn record_storage_stats(&self) {
        let c = crate::storage::counters();
        self.mapped_code_bytes.store(c.mapped_code_bytes(), Ordering::Relaxed);
        self.resident_code_bytes.store(c.resident_code_bytes(), Ordering::Relaxed);
        self.resident_sampled_bytes.store(c.resident_sampled_bytes(), Ordering::Relaxed);
        self.mmap_open_total.store(c.mmap_open_total(), Ordering::Relaxed);
    }

    /// Refresh the experiment-lab gauges from the process-wide
    /// [`crate::lab::counters`]. Self-called by the exports, so a lab
    /// sweep inside a serving process shows up without extra plumbing.
    pub fn record_lab_stats(&self) {
        let s = crate::lab::counters().snapshot();
        self.lab_trials_total.store(s.trials_total, Ordering::Relaxed);
        self.lab_trials_failed.store(s.trials_failed, Ordering::Relaxed);
        self.lab_gate_verdict.store(s.last_gate, Ordering::Relaxed);
    }

    /// Refresh the worker-pool gauges from the process-global pool
    /// counters and — when the global executor has been created — its
    /// pool's live snapshot. Self-called by the exports; uses
    /// [`crate::exec::QueryExecutor::global_get`] so a metrics scrape
    /// never *spawns* a pool in a process that hasn't needed one yet.
    pub fn record_pool_stats(&self) {
        let c = crate::exec::pool::counters();
        self.pool_steals_total.store(c.steals.load(Ordering::Relaxed), Ordering::Relaxed);
        self.pool_tasks_total
            .store(c.tasks_executed.load(Ordering::Relaxed), Ordering::Relaxed);
        let snap = crate::exec::QueryExecutor::global_get()
            .and_then(|e| e.worker_pool().map(|p| p.snapshot()));
        let Some(s) = snap else { return };
        self.pool_workers.store(s.workers as u64, Ordering::Relaxed);
        self.pool_queue_depth.store(s.queue_depth as u64, Ordering::Relaxed);
        *self.pool_busy_permille.lock().unwrap() = s.busy_permille;
    }

    /// Latest per-worker busy fractions (permille of wall time since the
    /// pool was spawned), as captured by [`Metrics::record_pool_stats`].
    pub fn pool_busy_permille(&self) -> Vec<u64> {
        self.pool_busy_permille.lock().unwrap().clone()
    }

    pub fn record_batch(&self, size: usize) {
        self.batches_total.fetch_add(1, Ordering::Relaxed);
        self.batched_queries_total.fetch_add(size as u64, Ordering::Relaxed);
        self.batch_occupancy.record(size as u64);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches_total.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_queries_total.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Export as JSON (served by the `stats` command of the TCP protocol).
    pub fn to_json(&self) -> Json {
        self.record_lab_stats();
        self.record_pool_stats();
        let mut o = Json::obj();
        o.set("requests_total", Json::Num(self.requests_total.load(Ordering::Relaxed) as f64))
            .set("batches_total", Json::Num(self.batches_total.load(Ordering::Relaxed) as f64))
            .set("errors_total", Json::Num(self.errors_total.load(Ordering::Relaxed) as f64))
            .set("mean_batch_size", Json::Num(self.mean_batch_size()))
            .set("batch_occupancy_p95", Json::Num(self.batch_occupancy.percentile(95.0)))
            .set("queue_mean_us", Json::Num(self.queue_us.mean_us()))
            .set("queue_p99_us", Json::Num(self.queue_us.percentile_us(99.0)))
            .set("service_mean_us", Json::Num(self.service_us.mean_us()))
            .set("batch_latency_mean_us", Json::Num(self.batch_latency_us.mean_us()))
            .set("batch_latency_p50_us", Json::Num(self.batch_latency_us.percentile_us(50.0)))
            .set("batch_latency_p95_us", Json::Num(self.batch_latency_us.percentile_us(95.0)))
            .set("batch_latency_p99_us", Json::Num(self.batch_latency_us.percentile_us(99.0)))
            .set(
                "exec_threads",
                Json::Num(self.exec_threads.load(Ordering::Relaxed) as f64),
            )
            .set(
                "scratch_high_water_bytes",
                Json::Num(self.scratch_high_water_bytes.load(Ordering::Relaxed) as f64),
            )
            .set("e2e_mean_us", Json::Num(self.e2e_us.mean_us()))
            .set("e2e_p50_us", Json::Num(self.e2e_us.percentile_us(50.0)))
            .set("e2e_p95_us", Json::Num(self.e2e_us.percentile_us(95.0)))
            .set("e2e_p99_us", Json::Num(self.e2e_us.percentile_us(99.0)))
            .set("codes_scanned_count", Json::Num(self.codes_scanned.count() as f64))
            .set("codes_scanned_mean", Json::Num(self.codes_scanned.mean()))
            .set("codes_scanned_p95", Json::Num(self.codes_scanned.percentile(95.0)))
            .set(
                "filter_selectivity_mean",
                Json::Num(self.filter_selectivity_pm.mean() / 1000.0),
            )
            .set(
                "filter_selectivity_p50",
                Json::Num(self.filter_selectivity_pm.percentile(50.0) / 1000.0),
            )
            .set("inserts_total", Json::Num(self.inserts_total.load(Ordering::Relaxed) as f64))
            .set("deletes_total", Json::Num(self.deletes_total.load(Ordering::Relaxed) as f64))
            .set(
                "segments_scanned",
                Json::Num(self.segments_scanned.load(Ordering::Relaxed) as f64),
            )
            .set("segments", Json::Num(self.segments.load(Ordering::Relaxed) as f64))
            .set(
                "memtable_entries",
                Json::Num(self.memtable_entries.load(Ordering::Relaxed) as f64),
            )
            .set("tombstones", Json::Num(self.tombstones.load(Ordering::Relaxed) as f64))
            .set("flushes_total", Json::Num(self.flushes_total.load(Ordering::Relaxed) as f64))
            .set(
                "compactions_total",
                Json::Num(self.compactions_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "mapped_code_bytes",
                Json::Num(self.mapped_code_bytes.load(Ordering::Relaxed) as f64),
            )
            .set(
                "resident_code_bytes",
                Json::Num(self.resident_code_bytes.load(Ordering::Relaxed) as f64),
            )
            .set(
                "resident_sampled_bytes",
                Json::Num(self.resident_sampled_bytes.load(Ordering::Relaxed) as f64),
            )
            .set(
                "mmap_open_total",
                Json::Num(self.mmap_open_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "lab_trials_total",
                Json::Num(self.lab_trials_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "lab_trials_failed",
                Json::Num(self.lab_trials_failed.load(Ordering::Relaxed) as f64),
            )
            .set(
                "lab_gate_verdict",
                Json::Num(self.lab_gate_verdict.load(Ordering::Relaxed) as f64),
            )
            .set(
                "admission_queue_depth",
                Json::Num(self.admission_queue_depth.load(Ordering::Relaxed) as f64),
            )
            .set(
                "admission_rejections_total",
                Json::Num(self.admission_rejections_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "deadline_degraded_total",
                Json::Num(self.deadline_degraded_total.load(Ordering::Relaxed) as f64),
            )
            .set("pool_workers", Json::Num(self.pool_workers.load(Ordering::Relaxed) as f64))
            .set(
                "pool_queue_depth",
                Json::Num(self.pool_queue_depth.load(Ordering::Relaxed) as f64),
            )
            .set(
                "pool_tasks_total",
                Json::Num(self.pool_tasks_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "pool_steals_total",
                Json::Num(self.pool_steals_total.load(Ordering::Relaxed) as f64),
            )
            .set(
                "pool_busy_permille",
                Json::Arr(
                    self.pool_busy_permille
                        .lock()
                        .unwrap()
                        .iter()
                        .map(|&p| Json::Num(p as f64))
                        .collect(),
                ),
            );
        o
    }

    /// Export everything in Prometheus text exposition format (the
    /// `metrics` verb and the `--metrics-addr` HTTP endpoint): one
    /// `# TYPE` per family; counters monotone, gauges latest-value,
    /// histograms cumulative. Covers every scalar of
    /// [`Metrics::to_json`] plus the per-phase histograms.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write;
        self.record_lab_stats();
        self.record_pool_stats();
        let mut out = String::with_capacity(8192);
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        let gauge = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        };
        let histogram = |out: &mut String, name: &str, help: &str, h: &Histogram| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} histogram");
            h.write_prometheus(out, name, "");
        };
        let ld = Ordering::Relaxed;
        counter(&mut out, "armpq_requests_total", "Requests accepted on the wire.", self.requests_total.load(ld));
        counter(&mut out, "armpq_batches_total", "Batches executed by the batcher.", self.batches_total.load(ld));
        counter(&mut out, "armpq_batched_queries_total", "Queries executed through batches.", self.batched_queries_total.load(ld));
        counter(&mut out, "armpq_errors_total", "Requests that returned an error.", self.errors_total.load(ld));
        counter(&mut out, "armpq_inserts_total", "Rows accepted through the insert verb.", self.inserts_total.load(ld));
        counter(&mut out, "armpq_deletes_total", "Live rows removed through the delete verb.", self.deletes_total.load(ld));
        counter(&mut out, "armpq_flushes_total", "Memtable flushes performed by the backend.", self.flushes_total.load(ld));
        counter(&mut out, "armpq_compactions_total", "Segment compactions performed by the backend.", self.compactions_total.load(ld));
        counter(&mut out, "armpq_mmap_open_total", "mmap opens performed by the storage layer.", self.mmap_open_total.load(ld));
        counter(&mut out, "armpq_lab_trials_total", "Experiment-lab trials executed by this process.", self.lab_trials_total.load(ld));
        counter(&mut out, "armpq_lab_trials_failed", "Experiment-lab trials that failed.", self.lab_trials_failed.load(ld));
        counter(&mut out, "armpq_admission_rejections_total", "Requests rejected at the admission queue.", self.admission_rejections_total.load(ld));
        counter(&mut out, "armpq_deadline_degraded_total", "Requests whose nprobe was degraded by the deadline policy.", self.deadline_degraded_total.load(ld));
        counter(&mut out, "armpq_pool_tasks_total", "Helper jobs executed on worker-pool threads.", self.pool_tasks_total.load(ld));
        counter(&mut out, "armpq_pool_steals_total", "Helper jobs stolen across worker queues.", self.pool_steals_total.load(ld));
        gauge(&mut out, "armpq_exec_threads", "Widest executor fan-out observed.", self.exec_threads.load(ld));
        gauge(&mut out, "armpq_scratch_high_water_bytes", "Executor scratch-arena high water.", self.scratch_high_water_bytes.load(ld));
        gauge(&mut out, "armpq_segments_scanned", "Widest per-query segment fan-out observed.", self.segments_scanned.load(ld));
        gauge(&mut out, "armpq_segments", "Sealed segments in the backend.", self.segments.load(ld));
        gauge(&mut out, "armpq_memtable_entries", "Live rows in the memtable.", self.memtable_entries.load(ld));
        gauge(&mut out, "armpq_tombstones", "Tombstoned rows awaiting compaction.", self.tombstones.load(ld));
        gauge(&mut out, "armpq_mapped_code_bytes", "Packed-code bytes backed by mmap.", self.mapped_code_bytes.load(ld));
        gauge(&mut out, "armpq_resident_code_bytes", "Mapped code bytes advised resident.", self.resident_code_bytes.load(ld));
        gauge(&mut out, "armpq_resident_sampled_bytes", "Mapped code bytes actually in RAM (mincore-sampled).", self.resident_sampled_bytes.load(ld));
        gauge(&mut out, "armpq_lab_gate_verdict", "Last regression-gate verdict: 0 none, 1 pass, 2 fail.", self.lab_gate_verdict.load(ld));
        gauge(&mut out, "armpq_admission_queue_depth", "Requests currently held in the bounded admission queue.", self.admission_queue_depth.load(ld));
        gauge(&mut out, "armpq_pool_workers", "Persistent worker threads in the global executor's pool.", self.pool_workers.load(ld));
        gauge(&mut out, "armpq_pool_queue_depth", "Helper jobs currently queued on pool workers.", self.pool_queue_depth.load(ld));
        {
            let busy = self.pool_busy_permille.lock().unwrap();
            let _ = writeln!(out, "# HELP armpq_pool_worker_busy_permille Per-worker busy time, permille of pool lifetime.");
            let _ = writeln!(out, "# TYPE armpq_pool_worker_busy_permille gauge");
            for (w, p) in busy.iter().enumerate() {
                let _ = writeln!(out, "armpq_pool_worker_busy_permille{{worker=\"{w}\"}} {p}");
            }
        }
        histogram(&mut out, "armpq_queue_us", "Enqueue-to-batch-formation wait, microseconds.", &self.queue_us);
        histogram(&mut out, "armpq_service_us", "Backend search time per batch, microseconds.", &self.service_us);
        histogram(&mut out, "armpq_batch_latency_us", "Whole-batch execution latency, microseconds.", &self.batch_latency_us);
        histogram(&mut out, "armpq_e2e_us", "End-to-end request latency, microseconds.", &self.e2e_us);
        histogram(&mut out, "armpq_codes_scanned", "Codes scanned per request.", &self.codes_scanned);
        histogram(&mut out, "armpq_filter_selectivity_permille", "Filter selectivity per request, permille.", &self.filter_selectivity_pm);
        histogram(&mut out, "armpq_batch_occupancy", "Queries per executed batch.", &self.batch_occupancy);
        let _ = writeln!(out, "# HELP armpq_phase_us Per-phase wall time of traced queries, microseconds.");
        let _ = writeln!(out, "# TYPE armpq_phase_us histogram");
        for phase in Phase::ALL {
            let h = &self.phase_us[phase.idx()];
            if h.count() == 0 {
                continue;
            }
            h.write_prometheus(&mut out, "armpq_phase_us", &format!("phase=\"{}\"", phase.name()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_mean_and_percentiles() {
        let h = UsHistogram::default();
        for us in [10u64, 20, 30, 40, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 220.0).abs() < 1e-9);
        // p50 falls in the bucket containing 20-30 µs → upper bound 32 or 64
        let p50 = h.percentile_us(50.0);
        assert!(p50 >= 16.0 && p50 <= 64.0, "p50 {p50}");
        let p99 = h.percentile_us(99.0);
        assert!(p99 >= 1000.0, "p99 {p99}");
    }

    /// The interpolation fix: a percentile must land **inside** its
    /// bucket, not snap to the upper bound, and a single-value histogram
    /// must not report more than 2× the value.
    #[test]
    fn percentile_interpolates_within_bucket() {
        let h = Histogram::default();
        for _ in 0..100 {
            h.record(100); // bucket [64, 128)
        }
        let p50 = h.percentile(50.0);
        let p99 = h.percentile(99.0);
        assert!(p50 >= 64.0 && p50 < 128.0, "p50 {p50}");
        assert!(p99 >= 64.0 && p99 <= 128.0, "p99 {p99}");
        assert!(p50 < p99, "interpolation should spread ranks: {p50} vs {p99}");
        // old behavior returned exactly 128 for every percentile
        assert!(p50 < 128.0);
    }

    /// Lab counters surface through both exports without explicit
    /// plumbing (the exports refresh the gauges themselves).
    #[test]
    fn lab_gauges_in_exports() {
        crate::lab::counters().record_trial(false);
        crate::lab::counters().record_gate(true);
        let m = Metrics::new();
        let j = m.to_json();
        assert!(j.get("lab_trials_total").unwrap().as_f64().unwrap() >= 1.0);
        assert!(j.get("lab_trials_failed").is_some());
        // the gate tests in lab::gate run in this binary too, so only
        // assert a verdict was recorded (1 pass / 2 fail), not which one
        let verdict = j.get("lab_gate_verdict").unwrap().as_f64().unwrap();
        assert!(verdict == 1.0 || verdict == 2.0, "verdict {verdict}");
        let text = m.to_prometheus();
        for family in
            ["armpq_lab_trials_total", "armpq_lab_trials_failed", "armpq_lab_gate_verdict"]
        {
            assert!(text.contains(&format!("# TYPE {family}")), "missing {family}");
        }
    }

    #[test]
    fn empty_histogram() {
        let h = UsHistogram::default();
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.percentile_us(99.0), 0.0);
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.record_batch(4);
        m.record_batch(8);
        assert_eq!(m.mean_batch_size(), 6.0);
        assert_eq!(m.batch_occupancy.count(), 2);
        assert_eq!(m.batch_occupancy.sum(), 12);
        let j = m.to_json();
        assert_eq!(j.get("batches_total").unwrap().as_usize().unwrap(), 2);
        assert!(j.get("batch_occupancy_p95").is_some());
    }

    #[test]
    fn json_has_expected_keys() {
        let m = Metrics::new();
        m.requests_total.fetch_add(3, Ordering::Relaxed);
        m.e2e_us.record(500);
        let j = m.to_json();
        for key in [
            "requests_total",
            "e2e_p95_us",
            "e2e_p99_us",
            "service_mean_us",
            "queue_p99_us",
            "codes_scanned_mean",
            "filter_selectivity_mean",
            "batch_latency_p50_us",
            "batch_latency_p95_us",
            "batch_latency_p99_us",
            "batch_occupancy_p95",
            "exec_threads",
            "scratch_high_water_bytes",
            "inserts_total",
            "deletes_total",
            "segments_scanned",
            "memtable_entries",
            "tombstones",
            "mapped_code_bytes",
            "resident_code_bytes",
            "resident_sampled_bytes",
            "mmap_open_total",
            "admission_queue_depth",
            "admission_rejections_total",
            "deadline_degraded_total",
            "pool_workers",
            "pool_queue_depth",
            "pool_tasks_total",
            "pool_steals_total",
            "pool_busy_permille",
        ] {
            assert!(j.get(key).is_some(), "{key}");
        }
    }

    /// Pool gauges track the process-global pool counters and the global
    /// executor's snapshot; admission instruments export in both formats.
    #[test]
    fn pool_and_admission_gauges_in_exports() {
        // drive at least one fan-out through the global (pool-backed)
        // executor so the task counter has something to show when the
        // machine grants more than one thread
        let exec = crate::exec::QueryExecutor::global();
        exec.run_batch(8, |i, _scratch| i * 2);
        let m = Metrics::new();
        m.admission_rejections_total.fetch_add(3, Ordering::Relaxed);
        m.deadline_degraded_total.fetch_add(1, Ordering::Relaxed);
        let j = m.to_json();
        assert_eq!(j.get("admission_rejections_total").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("deadline_degraded_total").unwrap().as_usize().unwrap(), 1);
        // the export refreshed the pool gauges itself
        let tasks = j.get("pool_tasks_total").unwrap().as_f64().unwrap();
        assert_eq!(tasks as u64, crate::exec::pool::counters().tasks_executed.load(Ordering::Relaxed));
        let busy = j.get("pool_busy_permille").unwrap().as_arr().unwrap();
        assert_eq!(busy.len(), m.pool_busy_permille().len());
        let text = m.to_prometheus();
        for family in [
            "armpq_admission_queue_depth",
            "armpq_admission_rejections_total",
            "armpq_deadline_degraded_total",
            "armpq_pool_workers",
            "armpq_pool_queue_depth",
            "armpq_pool_tasks_total",
            "armpq_pool_steals_total",
            "armpq_pool_worker_busy_permille",
        ] {
            assert!(text.contains(&format!("# TYPE {family}")), "missing {family}");
        }
        assert!(text.contains("armpq_admission_rejections_total 3"));
    }

    /// Storage residency gauges mirror the process-wide counters.
    #[test]
    fn storage_gauges_refresh_from_counters() {
        let m = Metrics::new();
        m.record_storage_stats();
        // counters are process-global (other tests may map files), so the
        // invariant checked here is consistency, not a specific value
        let c = crate::storage::counters();
        assert_eq!(m.mapped_code_bytes.load(Ordering::Relaxed), c.mapped_code_bytes());
        assert_eq!(m.mmap_open_total.load(Ordering::Relaxed), c.mmap_open_total());
        let j = m.to_json();
        assert!(j.get("resident_code_bytes").is_some());
    }

    /// Segment-lifecycle gauges track the latest observation; `None` (a
    /// sealed single-segment backend) leaves them untouched.
    #[test]
    fn segment_stats_gauges() {
        use crate::segment::SegmentStats;
        let m = Metrics::new();
        m.record_segment_stats(Some(SegmentStats {
            segments: 3,
            sealed_rows: 900,
            memtable_entries: 42,
            tombstones: 7,
            flushes: 5,
            compactions: 2,
        }));
        m.record_segment_stats(None); // no-op
        assert_eq!(m.segments.load(Ordering::Relaxed), 3);
        assert_eq!(m.memtable_entries.load(Ordering::Relaxed), 42);
        assert_eq!(m.tombstones.load(Ordering::Relaxed), 7);
        let j = m.to_json();
        assert_eq!(j.get("flushes_total").unwrap().as_usize().unwrap(), 5);
        assert_eq!(j.get("compactions_total").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("tombstones").unwrap().as_usize().unwrap(), 7);
    }

    /// The scan-work histograms (satellite: per-request codes_scanned /
    /// filter_selectivity sourced from QueryResponse stats).
    #[test]
    fn query_stats_recorded() {
        use crate::index::query::QueryStats;
        let m = Metrics::new();
        m.record_query_stats(&QueryStats {
            codes_scanned: 4096,
            lists_probed: 8,
            filter_selectivity: 0.25,
            threads_used: 4,
            scratch_bytes: 1 << 16,
            segments_scanned: 3,
            ..Default::default()
        });
        m.record_query_stats(&QueryStats {
            codes_scanned: 4096,
            lists_probed: 8,
            filter_selectivity: 0.75,
            threads_used: 2,
            scratch_bytes: 1 << 14,
            ..Default::default()
        });
        assert_eq!(m.codes_scanned.count(), 2);
        // gauges keep the maxima
        assert_eq!(m.exec_threads.load(Ordering::Relaxed), 4);
        assert_eq!(m.scratch_high_water_bytes.load(Ordering::Relaxed), 1 << 16);
        assert_eq!(m.segments_scanned.load(Ordering::Relaxed), 3);
        assert!((m.codes_scanned.mean() - 4096.0).abs() < 1e-9);
        let j = m.to_json();
        let sel = j.get("filter_selectivity_mean").unwrap().as_f64().unwrap();
        assert!((sel - 0.5).abs() < 1e-9, "{sel}");
        assert_eq!(j.get("codes_scanned_count").unwrap().as_usize().unwrap(), 2);
    }

    /// Traced spans land in the matching per-phase histograms.
    #[test]
    fn trace_spans_feed_phase_histograms() {
        let m = Metrics::new();
        m.record_trace(&[
            TraceSpan { phase: Phase::LutBuild, us: 10, count: 0, bytes: 0 },
            TraceSpan { phase: Phase::ListScan, us: 50, count: 1024, bytes: 0 },
            TraceSpan { phase: Phase::Total, us: 70, count: 0, bytes: 0 },
        ]);
        m.record_trace(&[TraceSpan { phase: Phase::ListScan, us: 30, count: 512, bytes: 0 }]);
        assert_eq!(m.phase_us[Phase::ListScan.idx()].count(), 2);
        assert_eq!(m.phase_us[Phase::ListScan.idx()].sum(), 80);
        assert_eq!(m.phase_us[Phase::Total.idx()].count(), 1);
        assert_eq!(m.phase_us[Phase::CoarseQuant.idx()].count(), 0);
    }

    /// The slow-query log keeps the worst `SLOWLOG_CAPACITY` by e2e,
    /// sorted worst-first, and rejects sub-floor queries without growing.
    #[test]
    fn slowlog_bounded_and_sorted() {
        let m = Metrics::new();
        for us in [500u64, 100, 900, 300, 700, 200, 800, 400, 600, 1000] {
            m.record_slow(us, "topk", 1, &[]);
        }
        let log = m.slowlog();
        assert_eq!(log.len(), SLOWLOG_CAPACITY);
        assert_eq!(log[0].e2e_us, 1000);
        assert!(log.windows(2).all(|w| w[0].e2e_us >= w[1].e2e_us));
        let floor = log.last().unwrap().e2e_us;
        // below-floor offers are rejected (lock-free fast path)
        m.record_slow(floor - 1, "topk", 1, &[]);
        assert_eq!(m.slowlog().len(), SLOWLOG_CAPACITY);
        assert_eq!(m.slowlog().last().unwrap().e2e_us, floor);
        // traces ride along
        m.record_slow(
            5000,
            "range",
            2,
            &[TraceSpan { phase: Phase::Total, us: 5000, count: 0, bytes: 0 }],
        );
        let log = m.slowlog();
        assert_eq!(log[0].e2e_us, 5000);
        assert_eq!(log[0].kind, "range");
        assert_eq!(log[0].trace.len(), 1);
        let j = m.slowlog_json();
        let rows = j.as_arr().unwrap();
        assert_eq!(rows.len(), SLOWLOG_CAPACITY);
        assert_eq!(rows[0].get("e2e_us").unwrap().as_usize().unwrap(), 5000);
    }

    /// Prometheus exposition is well-formed: one `# TYPE` per family,
    /// cumulative (monotone) histogram buckets ending at `+Inf`, and
    /// every JSON scalar family represented.
    #[test]
    fn prometheus_exposition_well_formed() {
        let m = Metrics::new();
        m.requests_total.fetch_add(7, Ordering::Relaxed);
        m.e2e_us.record(100);
        m.e2e_us.record(10_000);
        m.record_batch(4);
        m.record_trace(&[
            TraceSpan { phase: Phase::ListScan, us: 80, count: 0, bytes: 0 },
            TraceSpan { phase: Phase::Total, us: 100, count: 0, bytes: 0 },
        ]);
        let text = m.to_prometheus();
        // one # TYPE per family name
        let mut seen = std::collections::HashSet::new();
        for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
            let name = line.split_whitespace().nth(2).unwrap();
            assert!(seen.insert(name.to_string()), "duplicate # TYPE for {name}");
        }
        for family in [
            "armpq_requests_total",
            "armpq_errors_total",
            "armpq_inserts_total",
            "armpq_deletes_total",
            "armpq_exec_threads",
            "armpq_mapped_code_bytes",
            "armpq_resident_sampled_bytes",
            "armpq_queue_us",
            "armpq_e2e_us",
            "armpq_codes_scanned",
            "armpq_batch_occupancy",
            "armpq_phase_us",
        ] {
            assert!(seen.contains(family), "missing family {family}");
        }
        assert!(text.contains("armpq_requests_total 7"));
        // cumulative buckets: counts monotone nondecreasing in le order,
        // closed by +Inf == _count
        let e2e_buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("armpq_e2e_us_bucket"))
            .map(|l| l.split_whitespace().last().unwrap().parse().unwrap())
            .collect();
        assert!(!e2e_buckets.is_empty());
        assert!(e2e_buckets.windows(2).all(|w| w[0] <= w[1]), "{e2e_buckets:?}");
        assert_eq!(*e2e_buckets.last().unwrap(), 2);
        assert!(text.contains("armpq_e2e_us_count 2"));
        assert!(text.contains("armpq_e2e_us_sum 10100"));
        // phase histogram carries its label and only hit phases appear
        assert!(text.contains("armpq_phase_us_bucket{phase=\"list_scan\",le=\"128\"}"));
        assert!(text.contains("armpq_phase_us_sum{phase=\"total\"} 100"));
        assert!(!text.contains("phase=\"coarse_quant\""));
    }
}
