//! Search backends the coordinator can route to.
//!
//! Backends are immutable once constructed: `search_batch` takes `&self`
//! plus optional per-request [`SearchParams`], so any backend can serve
//! concurrent batches without a lock.
//!
//! Every index-backed backend carries a [`QueryExecutor`] fixed at
//! construction (defaulting to the process-global one) and threads it
//! through `query_batch` — the coordinator shares ONE executor (thread
//! budget + scratch pool) across all backends and shards instead of each
//! layer improvising its own parallelism.

use crate::exec::QueryExecutor;
use crate::index::query::{Hit, QueryKind, QueryRequest, QueryResponse, QueryStats};
use crate::index::{params, Index, SearchParams};
use crate::ivf::IvfPq4;
use crate::runtime::{EngineHandle, Tensor};
use crate::{Error, Result};
use std::sync::Arc;

/// A batched search implementation behind the batcher.
pub trait SearchBackend: Send + Sync {
    fn dim(&self) -> usize;
    /// Search `nq × dim` queries; returns `(distances, labels)` `nq × k`.
    /// `params` applies to this call only; backends without runtime knobs
    /// ignore it.
    fn search_batch(
        &self,
        queries: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)>;
    /// Answer a typed [`QueryRequest`] (top-k/range, optional filter).
    /// The default covers unfiltered top-k via [`SearchBackend::search_batch`];
    /// backends without filter/range support reject everything else
    /// instead of silently mis-serving.
    fn query_batch(&self, req: &QueryRequest<'_>) -> Result<QueryResponse> {
        match (&req.kind, &req.filter) {
            (QueryKind::TopK { k: 0 }, None) => {
                // k == 0 must still yield one (empty) hit row per query —
                // downstream consumers index rows by query position
                let nq = req.queries.len() / self.dim().max(1);
                Ok(QueryResponse::empty(nq))
            }
            (QueryKind::TopK { k }, None) => {
                let (d, l) = self.search_batch(req.queries, *k, req.params.as_ref())?;
                Ok(padded_to_response(&d, &l, *k))
            }
            _ => Err(Error::Serve(format!(
                "backend {} supports only unfiltered top-k queries",
                self.describe()
            ))),
        }
    }
    /// [`SearchBackend::query_batch`] with precomputed LUTs; the default
    /// ignores them and recomputes.
    fn query_batch_with_luts(&self, req: &QueryRequest<'_>, _luts: &[f32]) -> Result<QueryResponse> {
        self.query_batch(req)
    }
    /// Fingerprint of the backend's scan-LUT construction (see
    /// [`crate::index::Index::lut_signature`]). Backends sharing an equal
    /// `Some` signature accept each other's [`SearchBackend::compute_scan_luts`]
    /// output, letting the shard router build per-query LUTs once per
    /// `(k, params)` batch group instead of once per shard.
    fn lut_signature(&self) -> Option<u64> {
        None
    }
    /// Per-query scan LUTs for signature-equal backends (`None` = no
    /// shared-LUT fast path).
    fn compute_scan_luts(&self, _queries: &[f32]) -> Option<Vec<f32>> {
        None
    }
    /// [`SearchBackend::search_batch`] with precomputed LUTs; the default
    /// ignores them and recomputes.
    fn search_batch_with_luts(
        &self,
        queries: &[f32],
        _luts: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        self.search_batch(queries, k, params)
    }
    /// Append vectors to a **streaming** backend (`ids: None` assigns
    /// sequential ids; explicit ids upsert). Backends over sealed-only
    /// indexes reject this — route writes to a segmented backend.
    fn insert(&self, _vectors: &[f32], _ids: Option<&[i64]>) -> Result<Vec<i64>> {
        Err(Error::Serve(format!(
            "backend {} is read-only (insert needs a segmented index)",
            self.describe()
        )))
    }
    /// Remove rows by id from a streaming backend; returns how many live
    /// rows were removed.
    fn delete(&self, _ids: &[i64]) -> Result<usize> {
        Err(Error::Serve(format!(
            "backend {} is read-only (delete needs a segmented index)",
            self.describe()
        )))
    }
    /// Segment-lifecycle counters, if this backend has a segment lifecycle.
    fn segment_stats(&self) -> Option<crate::segment::SegmentStats> {
        None
    }
    fn describe(&self) -> String;
}

/// Convert a padded `nq × k` `(distances, labels)` pair into a typed
/// response (pad entries dropped; stats default since legacy backends
/// report none).
pub(crate) fn padded_to_response(d: &[f32], l: &[i64], k: usize) -> QueryResponse {
    debug_assert!(k > 0, "k == 0 is handled by the caller (needs nq from the request)");
    if k == 0 {
        return QueryResponse::default();
    }
    let nq = l.len() / k;
    let mut hits = Vec::with_capacity(nq);
    for qi in 0..nq {
        let row: Vec<Hit> = (0..k)
            .filter(|&r| l[qi * k + r] >= 0)
            .map(|r| Hit { distance: d[qi * k + r], label: l[qi * k + r] })
            .collect();
        hits.push(row);
    }
    QueryResponse { stats: vec![QueryStats::default(); nq], hits, traces: Vec::new() }
}

/// Backend over any sealed index shared as `Arc<dyn Index>` — the generic
/// adapter the shard router uses so one sealed index (or several) can be
/// fanned out across threads lock-free.
pub struct IndexBackend {
    index: Arc<dyn Index>,
    exec: QueryExecutor,
}

impl IndexBackend {
    /// Wraps a trained, sealed index on the process-global executor.
    /// Sealing is validated up front with a one-query probe search, so a
    /// forgotten `seal()` fails here at construction instead of on every
    /// request at serve time.
    pub fn new(index: Arc<dyn Index>) -> Result<Self> {
        Self::with_executor(index, QueryExecutor::global().clone())
    }

    /// [`IndexBackend::new`] on an explicit (typically shared) executor —
    /// how the shard router threads one thread-budget + scratch pool
    /// through every shard.
    pub fn with_executor(index: Arc<dyn Index>, exec: QueryExecutor) -> Result<Self> {
        if !index.is_trained() {
            return Err(Error::Serve("index backend requires a trained index".into()));
        }
        let probe = vec![0.0f32; index.dim()];
        if let Err(e) = index.query_exec(&QueryRequest::top_k(&probe, 1), &exec) {
            return Err(Error::Serve(format!("index backend probe search failed: {e}")));
        }
        Ok(Self { index, exec })
    }

    pub fn index(&self) -> &Arc<dyn Index> {
        &self.index
    }

    /// The executor this backend runs queries on.
    pub fn executor(&self) -> &QueryExecutor {
        &self.exec
    }
}

impl SearchBackend for IndexBackend {
    fn dim(&self) -> usize {
        self.index.dim()
    }

    fn search_batch(
        &self,
        queries: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let req = QueryRequest {
            queries,
            kind: QueryKind::TopK { k },
            filter: None,
            params: params.cloned(),
            trace: false,
        };
        let r = self.index.query_exec(&req, &self.exec)?.into_search_result(k);
        Ok((r.distances, r.labels))
    }

    fn query_batch(&self, req: &QueryRequest<'_>) -> Result<QueryResponse> {
        self.index.query_exec(req, &self.exec)
    }

    fn query_batch_with_luts(&self, req: &QueryRequest<'_>, luts: &[f32]) -> Result<QueryResponse> {
        self.index.query_with_luts_exec(req, luts, &self.exec)
    }

    fn lut_signature(&self) -> Option<u64> {
        self.index.lut_signature()
    }

    fn compute_scan_luts(&self, queries: &[f32]) -> Option<Vec<f32>> {
        self.index.compute_scan_luts(queries)
    }

    fn search_batch_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let req = QueryRequest {
            queries,
            kind: QueryKind::TopK { k },
            filter: None,
            params: params.cloned(),
            trace: false,
        };
        let r = self.index.query_with_luts_exec(&req, luts, &self.exec)?.into_search_result(k);
        Ok((r.distances, r.labels))
    }

    fn insert(&self, vectors: &[f32], ids: Option<&[i64]>) -> Result<Vec<i64>> {
        self.index.insert(vectors, ids).map_err(|e| Error::Serve(e.to_string()))
    }

    fn delete(&self, ids: &[i64]) -> Result<usize> {
        self.index.delete(ids).map_err(|e| Error::Serve(e.to_string()))
    }

    fn segment_stats(&self) -> Option<crate::segment::SegmentStats> {
        self.index.segment_stats()
    }

    fn describe(&self) -> String {
        self.index.describe()
    }
}

/// Backend over a sealed [`IvfPq4`] index (the Table 1 configuration).
pub struct IvfBackend {
    index: IvfPq4,
    exec: QueryExecutor,
}

impl IvfBackend {
    /// Takes a trained+filled index; seals it for immutable serving on
    /// the process-global executor.
    pub fn new(index: IvfPq4) -> Result<Self> {
        Self::with_executor(index, QueryExecutor::global().clone())
    }

    /// [`IvfBackend::new`] on an explicit (typically shared) executor.
    pub fn with_executor(mut index: IvfPq4, exec: QueryExecutor) -> Result<Self> {
        index.seal()?;
        Ok(Self { index, exec })
    }

    pub fn index(&self) -> &IvfPq4 {
        &self.index
    }
}

impl SearchBackend for IvfBackend {
    fn dim(&self) -> usize {
        self.index.dim
    }

    fn search_batch(
        &self,
        queries: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let resp = self.query_batch(&QueryRequest {
            queries,
            kind: QueryKind::TopK { k },
            filter: None,
            params: params.cloned(),
            trace: false,
        })?;
        let r = resp.into_search_result(k);
        Ok((r.distances, r.labels))
    }

    fn query_batch(&self, req: &QueryRequest<'_>) -> Result<QueryResponse> {
        let (nprobe, ef_search, fs) =
            params::effective_ivf(req.params.as_ref(), self.index.nprobe, &self.index.fastscan);
        let (hits, stats, traces) = self.index.query_exec_traced_with(
            req.queries,
            None,
            &req.kind,
            req.filter.as_ref(),
            nprobe,
            ef_search,
            &fs,
            &self.exec,
            req.trace,
        )?;
        Ok(QueryResponse { hits, stats, traces })
    }

    fn query_batch_with_luts(&self, req: &QueryRequest<'_>, luts: &[f32]) -> Result<QueryResponse> {
        let (nprobe, ef_search, fs) =
            params::effective_ivf(req.params.as_ref(), self.index.nprobe, &self.index.fastscan);
        let (hits, stats, traces) = self.index.query_exec_traced_with(
            req.queries,
            Some(luts),
            &req.kind,
            req.filter.as_ref(),
            nprobe,
            ef_search,
            &fs,
            &self.exec,
            req.trace,
        )?;
        Ok(QueryResponse { hits, stats, traces })
    }

    fn lut_signature(&self) -> Option<u64> {
        self.index.pq.as_ref().map(|pq| pq.signature())
    }

    fn compute_scan_luts(&self, queries: &[f32]) -> Option<Vec<f32>> {
        self.index.compute_scan_luts(queries).ok()
    }

    fn search_batch_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let resp = self.query_batch_with_luts(
            &QueryRequest {
                queries,
                kind: QueryKind::TopK { k },
                filter: None,
                params: params.cloned(),
                trace: false,
            },
            luts,
        )?;
        let r = resp.into_search_result(k);
        Ok((r.distances, r.labels))
    }

    fn describe(&self) -> String {
        format!(
            "ivf(nlist={}, nprobe={}, n={}, kernel={})",
            self.index.params.nlist,
            self.index.nprobe,
            self.index.ntotal(),
            self.index.fastscan.backend
        )
    }
}

/// Backend over the AOT-compiled PJRT search pipeline (`runtime/`):
/// queries are padded to the artifact's fixed batch Q and the codes are the
/// fixed-N scan unit — the three-layer path with python nowhere at runtime.
pub struct PjrtBackend {
    engine: Arc<EngineHandle>,
    artifact: String,
    q: usize,
    n: usize,
    d: usize,
    m: usize,
    k_art: usize,
    codes: Vec<i32>,
    codebooks: Vec<f32>,
}

impl PjrtBackend {
    /// `codes`: `n × m` (values < 16), `codebooks`: `m × 16 × dsub` — both
    /// must match the artifact named by (d, m) in the manifest.
    pub fn new(
        engine: Arc<EngineHandle>,
        d: usize,
        codes: Vec<i32>,
        codebooks: Vec<f32>,
    ) -> Result<Self> {
        let meta = engine
            .manifest
            .find_by("search", &[("d", d)])
            .ok_or_else(|| Error::Runtime(format!("no search artifact for d={d}")))?;
        let (q, n, m, k_art) =
            (meta.params["q"], meta.params["n"], meta.params["m"], meta.params["k"]);
        if codes.len() != n * m {
            return Err(Error::Runtime(format!(
                "codes len {} != n*m = {}",
                codes.len(),
                n * m
            )));
        }
        if codebooks.len() != m * 16 * (d / m) {
            return Err(Error::Runtime("codebooks shape mismatch".into()));
        }
        Ok(Self { artifact: meta.name.clone(), engine, q, n, d, m, k_art, codes, codebooks })
    }

    pub fn scan_unit(&self) -> usize {
        self.n
    }
}

impl SearchBackend for PjrtBackend {
    fn dim(&self) -> usize {
        self.d
    }

    // the artifact's parameters are baked in at AOT-compile time, so
    // per-request SearchParams have nothing to override here
    fn search_batch(
        &self,
        queries: &[f32],
        k: usize,
        _params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        if k > self.k_art {
            return Err(Error::Serve(format!("k={k} exceeds artifact k={}", self.k_art)));
        }
        let nq = queries.len() / self.d;
        let mut distances = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        // process in fixed-Q windows, padding the tail with zeros
        for chunk in queries.chunks(self.q * self.d) {
            let real = chunk.len() / self.d;
            let mut padded = chunk.to_vec();
            padded.resize(self.q * self.d, 0.0);
            let out = self.engine.execute(
                &self.artifact,
                vec![
                    Tensor::F32(padded, vec![self.q, self.d]),
                    Tensor::I32(self.codes.clone(), vec![self.n, self.m]),
                    Tensor::F32(self.codebooks.clone(), vec![self.m, 16, self.d / self.m]),
                ],
            )?;
            let d_out = out[0].as_f32()?;
            let l_out = out[1].as_i32()?;
            for qi in 0..real {
                distances.extend_from_slice(&d_out[qi * self.k_art..qi * self.k_art + k]);
                labels.extend(
                    l_out[qi * self.k_art..qi * self.k_art + k].iter().map(|&x| x as i64),
                );
            }
        }
        Ok((distances, labels))
    }

    fn describe(&self) -> String {
        format!("pjrt({}, n={}, q={})", self.artifact, self.n, self.q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivf::IvfParams;
    use crate::pq::PqParams;
    use crate::util::rng::Rng;

    fn toy_index() -> (IvfPq4, Vec<f32>) {
        let dim = 16;
        let mut rng = Rng::new(121);
        let data: Vec<f32> = (0..800 * dim).map(|_| rng.next_gaussian()).collect();
        let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(4));
        idx.train(&data).unwrap();
        idx.add(&data).unwrap();
        idx.nprobe = 4;
        (idx, data)
    }

    #[test]
    fn ivf_backend_batches() {
        let (idx, data) = toy_index();
        let be = IvfBackend::new(idx).unwrap();
        assert_eq!(be.dim(), 16);
        let queries = &data[..3 * 16];
        let (d, l) = be.search_batch(queries, 5, None).unwrap();
        assert_eq!(d.len(), 15);
        assert_eq!(l.len(), 15);
        assert!(be.describe().contains("nlist=4"));
        // per-request override goes through without mutating the backend
        let narrow = SearchParams::new().with_nprobe(1);
        let (d1, _l1) = be.search_batch(queries, 5, Some(&narrow)).unwrap();
        assert_eq!(d1.len(), 15);
        assert_eq!(be.index().nprobe, 4);
    }

    #[test]
    fn index_backend_over_dyn_index() {
        use crate::index::index_factory;
        let mut rng = Rng::new(123);
        let data: Vec<f32> = (0..500 * 16).map(|_| rng.next_gaussian()).collect();
        let mut idx = index_factory(16, "PQ4x4fs").unwrap();
        idx.train(&data).unwrap();
        idx.add(&data).unwrap();
        idx.seal().unwrap();
        let be = IndexBackend::new(Arc::from(idx)).unwrap();
        let (d, l) = be.search_batch(&data[..2 * 16], 3, None).unwrap();
        assert_eq!((d.len(), l.len()), (6, 6));
        assert!(be.describe().contains("PQ4x4fs"));
    }

    #[test]
    fn pjrt_backend_padding_and_k() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts missing; skipping");
            return;
        }
        let engine = Arc::new(EngineHandle::spawn(dir).unwrap());
        let Some(meta) = engine.manifest.find_by("search", &[("d", 64)]) else { return };
        let (n, m, d) = (meta.params["n"], meta.params["m"], meta.params["d"]);
        let mut rng = Rng::new(122);
        let codes: Vec<i32> = (0..n * m).map(|_| (rng.next_u32() % 16) as i32).collect();
        let codebooks: Vec<f32> =
            (0..m * 16 * (d / m)).map(|_| rng.next_gaussian()).collect();
        let be = PjrtBackend::new(engine, d, codes, codebooks).unwrap();
        // 3 queries (< Q=8) exercises the padding path
        let queries: Vec<f32> = (0..3 * d).map(|_| rng.next_gaussian()).collect();
        let (dist, lab) = be.search_batch(&queries, 5, None).unwrap();
        assert_eq!(dist.len(), 15);
        assert!(lab.iter().all(|&l| l >= 0 && (l as usize) < n));
        // ascending per query
        for qi in 0..3 {
            let row = &dist[qi * 5..(qi + 1) * 5];
            assert!(row.windows(2).all(|w| w[0] <= w[1]), "{row:?}");
        }
        assert!(be.search_batch(&queries, 100, None).is_err()); // k > artifact k
    }
}
