//! Search backends the coordinator can route to.

use crate::ivf::IvfPq4;
use crate::runtime::{EngineHandle, Tensor};
use crate::{Error, Result};
use std::sync::Arc;

/// A batched search implementation behind the batcher.
pub trait SearchBackend: Send + Sync {
    fn dim(&self) -> usize;
    /// Search `nq × dim` queries; returns `(distances, labels)` `nq × k`.
    fn search_batch(&self, queries: &[f32], k: usize) -> Result<(Vec<f32>, Vec<i64>)>;
    fn describe(&self) -> String;
}

/// Backend over a sealed [`IvfPq4`] index (the Table 1 configuration).
pub struct IvfBackend {
    index: IvfPq4,
}

impl IvfBackend {
    /// Takes a trained+filled index; seals it for immutable serving.
    pub fn new(mut index: IvfPq4) -> Result<Self> {
        index.seal()?;
        Ok(Self { index })
    }

    pub fn index(&self) -> &IvfPq4 {
        &self.index
    }
}

impl SearchBackend for IvfBackend {
    fn dim(&self) -> usize {
        self.index.dim
    }

    fn search_batch(&self, queries: &[f32], k: usize) -> Result<(Vec<f32>, Vec<i64>)> {
        self.index.search_sealed(queries, k)
    }

    fn describe(&self) -> String {
        format!(
            "ivf(nlist={}, nprobe={}, n={}, kernel={})",
            self.index.params.nlist,
            self.index.nprobe,
            self.index.ntotal(),
            self.index.fastscan.backend
        )
    }
}

/// Backend over the AOT-compiled PJRT search pipeline (`runtime/`):
/// queries are padded to the artifact's fixed batch Q and the codes are the
/// fixed-N scan unit — the three-layer path with python nowhere at runtime.
pub struct PjrtBackend {
    engine: Arc<EngineHandle>,
    artifact: String,
    q: usize,
    n: usize,
    d: usize,
    m: usize,
    k_art: usize,
    codes: Vec<i32>,
    codebooks: Vec<f32>,
}

impl PjrtBackend {
    /// `codes`: `n × m` (values < 16), `codebooks`: `m × 16 × dsub` — both
    /// must match the artifact named by (d, m) in the manifest.
    pub fn new(
        engine: Arc<EngineHandle>,
        d: usize,
        codes: Vec<i32>,
        codebooks: Vec<f32>,
    ) -> Result<Self> {
        let meta = engine
            .manifest
            .find_by("search", &[("d", d)])
            .ok_or_else(|| Error::Runtime(format!("no search artifact for d={d}")))?;
        let (q, n, m, k_art) =
            (meta.params["q"], meta.params["n"], meta.params["m"], meta.params["k"]);
        if codes.len() != n * m {
            return Err(Error::Runtime(format!(
                "codes len {} != n*m = {}",
                codes.len(),
                n * m
            )));
        }
        if codebooks.len() != m * 16 * (d / m) {
            return Err(Error::Runtime("codebooks shape mismatch".into()));
        }
        Ok(Self { artifact: meta.name.clone(), engine, q, n, d, m, k_art, codes, codebooks })
    }

    pub fn scan_unit(&self) -> usize {
        self.n
    }
}

impl SearchBackend for PjrtBackend {
    fn dim(&self) -> usize {
        self.d
    }

    fn search_batch(&self, queries: &[f32], k: usize) -> Result<(Vec<f32>, Vec<i64>)> {
        if k > self.k_art {
            return Err(Error::Serve(format!("k={k} exceeds artifact k={}", self.k_art)));
        }
        let nq = queries.len() / self.d;
        let mut distances = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        // process in fixed-Q windows, padding the tail with zeros
        for chunk in queries.chunks(self.q * self.d) {
            let real = chunk.len() / self.d;
            let mut padded = chunk.to_vec();
            padded.resize(self.q * self.d, 0.0);
            let out = self.engine.execute(
                &self.artifact,
                vec![
                    Tensor::F32(padded, vec![self.q, self.d]),
                    Tensor::I32(self.codes.clone(), vec![self.n, self.m]),
                    Tensor::F32(self.codebooks.clone(), vec![self.m, 16, self.d / self.m]),
                ],
            )?;
            let d_out = out[0].as_f32()?;
            let l_out = out[1].as_i32()?;
            for qi in 0..real {
                distances.extend_from_slice(&d_out[qi * self.k_art..qi * self.k_art + k]);
                labels.extend(
                    l_out[qi * self.k_art..qi * self.k_art + k].iter().map(|&x| x as i64),
                );
            }
        }
        Ok((distances, labels))
    }

    fn describe(&self) -> String {
        format!("pjrt({}, n={}, q={})", self.artifact, self.n, self.q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ivf::IvfParams;
    use crate::pq::PqParams;
    use crate::util::rng::Rng;

    fn toy_index() -> (IvfPq4, Vec<f32>) {
        let dim = 16;
        let mut rng = Rng::new(121);
        let data: Vec<f32> = (0..800 * dim).map(|_| rng.next_gaussian()).collect();
        let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(4));
        idx.train(&data).unwrap();
        idx.add(&data).unwrap();
        idx.nprobe = 4;
        (idx, data)
    }

    #[test]
    fn ivf_backend_batches() {
        let (idx, data) = toy_index();
        let be = IvfBackend::new(idx).unwrap();
        assert_eq!(be.dim(), 16);
        let queries = &data[..3 * 16];
        let (d, l) = be.search_batch(queries, 5).unwrap();
        assert_eq!(d.len(), 15);
        assert_eq!(l.len(), 15);
        assert!(be.describe().contains("nlist=4"));
    }

    #[test]
    fn pjrt_backend_padding_and_k() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("artifacts missing; skipping");
            return;
        }
        let engine = Arc::new(EngineHandle::spawn(dir).unwrap());
        let Some(meta) = engine.manifest.find_by("search", &[("d", 64)]) else { return };
        let (n, m, d) = (meta.params["n"], meta.params["m"], meta.params["d"]);
        let mut rng = Rng::new(122);
        let codes: Vec<i32> = (0..n * m).map(|_| (rng.next_u32() % 16) as i32).collect();
        let codebooks: Vec<f32> =
            (0..m * 16 * (d / m)).map(|_| rng.next_gaussian()).collect();
        let be = PjrtBackend::new(engine, d, codes, codebooks).unwrap();
        // 3 queries (< Q=8) exercises the padding path
        let queries: Vec<f32> = (0..3 * d).map(|_| rng.next_gaussian()).collect();
        let (dist, lab) = be.search_batch(&queries, 5).unwrap();
        assert_eq!(dist.len(), 15);
        assert!(lab.iter().all(|&l| l >= 0 && (l as usize) < n));
        // ascending per query
        for qi in 0..3 {
            let row = &dist[qi * 5..(qi + 1) * 5];
            assert!(row.windows(2).all(|w| w[0] <= w[1]), "{row:?}");
        }
        assert!(be.search_batch(&queries, 100).is_err()); // k > artifact k
    }
}
