//! Shard router: fan a query batch out over multiple index shards and
//! merge per-shard top-k — the horizontal-scaling layer above the batcher
//! (how a billion-vector corpus is actually served: N_shard × IVF indexes,
//! each like the paper's Table 1 configuration).

use super::service::{IndexBackend, SearchBackend};
use crate::exec::QueryExecutor;
use crate::index::query::{Hit, QueryKind, QueryRequest, QueryResponse, QueryStats};
use crate::index::{Index, SearchParams};
use crate::Result;
use std::sync::Arc;

/// A backend that routes to `shards` and merges results.
///
/// Shards own disjoint id spaces (each shard must already return *global*
/// ids, e.g. via `add_with_ids`). Shard searches fan out on the executor's
/// persistent worker pool ([`QueryExecutor::run_shards`]) — lock-free
/// (`search_batch` is `&self`), at most one participant per shard — and
/// merge via a bounded heap. Per-request [`SearchParams`] are forwarded to
/// every shard.
///
/// **NUMA-aware placement:** shards are interleaved across the machine's
/// NUMA nodes at construction ([`crate::exec::pool::NumaTopology`]), and
/// the pool's placed fan-out has workers drain their own node's shards
/// before stealing cross-node — so each shard's scan usually runs on a
/// core local to the memory it touches, without ever idling a worker while
/// shard work remains. On single-node machines this degrades to plain
/// work-stealing.
///
/// **Batch-level LUT reuse:** when every shard reports the same
/// [`SearchBackend::lut_signature`] (same trained quantizer — the normal
/// deployment, where one codebook is trained once and shards split the
/// corpus), the router computes each query's scan LUTs **once** per
/// `search_batch` call and hands them to every shard via
/// [`SearchBackend::search_batch_with_luts`], instead of every shard
/// rebuilding them. Batcher windows group by `(k, params)`, so one LUT
/// build serves the whole group's fan-out. Mismatched or absent signatures
/// fall back to per-shard computation — never wrong, just slower.
pub struct ShardedBackend {
    shards: Vec<Arc<dyn SearchBackend>>,
    dim: usize,
    /// Common LUT signature of all shards, if they agree (checked once at
    /// construction — shards are immutable after sealing).
    shared_luts: Option<u64>,
    /// The executor whose worker pool carries the shard fan-out.
    exec: QueryExecutor,
    /// NUMA node index each shard is placed on (interleaved round-robin
    /// across the detected topology).
    shard_nodes: Vec<usize>,
}

impl ShardedBackend {
    pub fn new(shards: Vec<Arc<dyn SearchBackend>>) -> Result<Self> {
        Self::with_executor(shards, QueryExecutor::global().clone())
    }

    /// [`ShardedBackend::new`] fanning out on an explicit executor's pool.
    pub fn with_executor(
        shards: Vec<Arc<dyn SearchBackend>>,
        exec: QueryExecutor,
    ) -> Result<Self> {
        if shards.is_empty() {
            return Err(crate::Error::Serve("no shards".into()));
        }
        let dim = shards[0].dim();
        if shards.iter().any(|s| s.dim() != dim) {
            return Err(crate::Error::Serve("shard dimension mismatch".into()));
        }
        let shared_luts = shards[0]
            .lut_signature()
            .filter(|sig| shards.iter().all(|s| s.lut_signature() == Some(*sig)));
        let shard_nodes = crate::exec::pool::topology().interleave(shards.len());
        Ok(Self { shards, dim, shared_luts, exec, shard_nodes })
    }

    /// Convenience: shard over sealed indexes held as `Arc<dyn Index>`,
    /// all on the process-global executor (one thread budget + scratch
    /// pool shared across the fan-out, not one per shard).
    pub fn from_indexes(indexes: Vec<Arc<dyn Index>>) -> Result<Self> {
        Self::from_indexes_with_executor(indexes, QueryExecutor::global().clone())
    }

    /// [`ShardedBackend::from_indexes`] on an explicit executor shared by
    /// every shard backend.
    pub fn from_indexes_with_executor(
        indexes: Vec<Arc<dyn Index>>,
        exec: QueryExecutor,
    ) -> Result<Self> {
        let shards = indexes
            .into_iter()
            .map(|idx| {
                let backend = IndexBackend::with_executor(idx, exec.clone())?;
                Ok(Arc::new(backend) as Arc<dyn SearchBackend>)
            })
            .collect::<Result<Vec<_>>>()?;
        Self::with_executor(shards, exec)
    }

    pub fn nshards(&self) -> usize {
        self.shards.len()
    }

    /// NUMA node index each shard was placed on (introspection for tests
    /// and the metrics exporter).
    pub fn shard_nodes(&self) -> &[usize] {
        &self.shard_nodes
    }

    /// Whether the shards share one quantizer and the router reuses one
    /// LUT build across the fan-out (introspection for tests/metrics).
    pub fn reuses_luts(&self) -> bool {
        self.shared_luts.is_some() && self.shards.len() > 1
    }

    /// Fan a typed request out to every shard (reusing one LUT build when
    /// the codebooks agree) and collect the per-shard responses in shard
    /// order.
    fn fan_out(&self, req: &QueryRequest<'_>) -> Result<Vec<QueryResponse>> {
        // batch-level LUT reuse: LUTs depend only on the query vectors, so
        // one build serves every kind/filter combination
        let shared_luts: Option<Vec<f32>> = if self.reuses_luts() {
            self.shards[0].compute_scan_luts(req.queries)
        } else {
            None
        };
        // fan out on the persistent pool: at most one participant per
        // shard, shards placed on their NUMA node, idle participants
        // steal cross-node — nobody waits behind a slow shard chunk
        let luts = shared_luts.as_deref();
        let results: Vec<Result<QueryResponse>> = self.exec.run_shards(
            self.shards.len(),
            |i| self.shard_nodes[i],
            |i| match luts {
                Some(l) => self.shards[i].query_batch_with_luts(req, l),
                None => self.shards[i].query_batch(req),
            },
        );
        results.into_iter().collect()
    }
}

/// Merge one query's per-shard hit rows: ascending `(distance, label)`
/// with duplicate external labels collapsed to their best distance.
///
/// Dedupe matters: the same label can legitimately live on several shards
/// (duplicate adds during a rebalance, replicated hot ids), and a merged
/// top-k that returns one label twice wastes result slots and breaks
/// consumers that key on labels.
fn merge_rows(rows: Vec<&[Hit]>, limit: Option<usize>) -> Vec<Hit> {
    let mut all: Vec<Hit> = rows.into_iter().flatten().copied().collect();
    all.sort_by(|a, b| {
        a.distance
            .partial_cmp(&b.distance)
            .unwrap()
            .then(a.label.cmp(&b.label))
    });
    let mut seen = std::collections::HashSet::with_capacity(all.len());
    all.retain(|h| seen.insert(h.label));
    if let Some(k) = limit {
        all.truncate(k);
    }
    all
}

/// Merge per-shard stats of one query: scan work adds up, selectivity is
/// weighted by how many codes each shard considered, and the concurrency
/// gauges (threads used, scratch high-water) take the per-shard maximum —
/// they are capacity facts, not additive work.
fn merge_stats(per_shard: Vec<&QueryStats>) -> QueryStats {
    let mut out = QueryStats {
        codes_scanned: 0,
        lists_probed: 0,
        filter_selectivity: 1.0,
        threads_used: 1,
        scratch_bytes: 0,
        ..Default::default()
    };
    let mut weighted = 0.0f64;
    for s in &per_shard {
        out.codes_scanned += s.codes_scanned;
        out.lists_probed += s.lists_probed;
        out.threads_used = out.threads_used.max(s.threads_used);
        out.scratch_bytes = out.scratch_bytes.max(s.scratch_bytes);
        // segment + storage facts add up across shards like scan work
        out.segments_scanned += s.segments_scanned;
        out.memtable_entries += s.memtable_entries;
        out.tombstones += s.tombstones;
        out.bytes_mapped += s.bytes_mapped;
        out.prefetch_lists += s.prefetch_lists;
        weighted += s.filter_selectivity * s.codes_scanned as f64;
    }
    if out.codes_scanned > 0 {
        out.filter_selectivity = weighted / out.codes_scanned as f64;
    } else if let Some(first) = per_shard.first() {
        out.filter_selectivity = first.filter_selectivity;
    }
    out
}

impl SearchBackend for ShardedBackend {
    fn dim(&self) -> usize {
        self.dim
    }

    fn search_batch(
        &self,
        queries: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let nq = queries.len() / self.dim;
        if k == 0 || nq == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        let req = QueryRequest {
            queries,
            kind: QueryKind::TopK { k },
            filter: None,
            params: params.cloned(),
            trace: false,
        };
        let resp = self.query_batch(&req)?;
        let mut distances = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        for row in resp.hits {
            let (d, l) = crate::index::query::pad_hits(&row, k);
            distances.extend(d);
            labels.extend(l);
        }
        Ok((distances, labels))
    }

    fn query_batch(&self, req: &QueryRequest<'_>) -> Result<QueryResponse> {
        let nq = req.queries.len() / self.dim;
        if nq == 0 {
            return Ok(QueryResponse::default());
        }
        let shard_resps = self.fan_out(req)?;
        let limit = match req.kind {
            QueryKind::TopK { k } => Some(k),
            QueryKind::Range { .. } => None,
        };
        let mut hits = Vec::with_capacity(nq);
        let mut stats = Vec::with_capacity(nq);
        let mut traces = Vec::with_capacity(if req.trace { nq } else { 0 });
        for qi in 0..nq {
            hits.push(merge_rows(
                shard_resps.iter().map(|r| r.hits[qi].as_slice()).collect(),
                limit,
            ));
            stats.push(merge_stats(shard_resps.iter().map(|r| &r.stats[qi]).collect()));
            if req.trace {
                // shard spans sum per phase: the fan-out runs shards
                // concurrently, so the merged `total` reads as aggregate
                // shard work, not wall clock — same convention as
                // `codes_scanned` adding up across shards
                let rows: Vec<&[crate::obs::TraceSpan]> = shard_resps
                    .iter()
                    .map(|r| r.traces.get(qi).map(|t| t.as_slice()).unwrap_or(&[]))
                    .collect();
                traces.push(crate::obs::merge_spans(&rows));
            }
        }
        Ok(QueryResponse { hits, stats, traces })
    }

    fn describe(&self) -> String {
        format!("sharded(x{}, {})", self.shards.len(), self.shards[0].describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::IvfBackend;
    use crate::datasets::SyntheticDataset;
    use crate::ivf::{IvfParams, IvfPq4};
    use crate::pq::PqParams;
    use crate::util::topk::TopK;

    /// Regression (duplicate-add scenario): a label that legitimately
    /// lives on several shards must appear at most once in the merged
    /// top-k, at its best distance — never twice.
    #[test]
    fn merge_dedupes_duplicate_labels_across_shards() {
        let ds = SyntheticDataset::sift_like(600, 5, 236);
        let dim = ds.dim;
        // both shards index the SAME vectors with the SAME global ids
        let mk = || -> Arc<dyn SearchBackend> {
            let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(8));
            idx.train(&ds.train).unwrap();
            let ids: Vec<i64> = (0..600).collect();
            idx.add_with_ids(&ds.base, &ids).unwrap();
            idx.nprobe = 4;
            idx.fastscan.reservoir_factor = 32;
            Arc::new(IvfBackend::new(idx).unwrap())
        };
        let router = ShardedBackend::new(vec![mk(), mk()]).unwrap();
        let (d, l) = router.search_batch(&ds.queries, 5, None).unwrap();
        for qi in 0..5 {
            let row = &l[qi * 5..(qi + 1) * 5];
            let mut seen = std::collections::HashSet::new();
            for &label in row.iter().filter(|&&x| x >= 0) {
                assert!(seen.insert(label), "q{qi}: duplicate label {label} in {row:?}");
            }
            // both shards hold every id, so a full top-5 must exist
            assert!(row.iter().all(|&x| x >= 0), "q{qi}: padded row {row:?}");
            let dr = &d[qi * 5..(qi + 1) * 5];
            assert!(dr.windows(2).all(|w| w[0] <= w[1]), "q{qi}: unsorted {dr:?}");
        }
        // typed path dedupes the same way
        let req = QueryRequest::top_k(&ds.queries, 5);
        let resp = router.query_batch(&req).unwrap();
        for row in &resp.hits {
            let mut seen = std::collections::HashSet::new();
            assert!(row.iter().all(|h| seen.insert(h.label)), "{row:?}");
        }
    }

    /// Build `nshards` IVF shards over disjoint halves of one dataset with
    /// global ids, and check the router merges to the same results as one
    /// big index.
    #[test]
    fn sharded_matches_monolithic() {
        let ds = SyntheticDataset::sift_like(4_000, 25, 231);
        let dim = ds.dim;
        let nshards = 4;
        let per = ds.n() / nshards;

        let mut shards: Vec<Arc<dyn SearchBackend>> = Vec::new();
        for s in 0..nshards {
            let mut idx = IvfPq4::new(dim, IvfParams::new(8), PqParams::new_4bit(8));
            idx.train(&ds.train).unwrap();
            let slice = &ds.base[s * per * dim..(s + 1) * per * dim];
            let ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
            idx.add_with_ids(slice, &ids).unwrap();
            idx.nprobe = 8; // all lists
            idx.fastscan.reservoir_factor = 32;
            shards.push(Arc::new(IvfBackend::new(idx).unwrap()));
        }
        let router = ShardedBackend::new(shards).unwrap();
        assert_eq!(router.nshards(), 4);

        // monolithic reference with the same training seed
        let mut mono = IvfPq4::new(dim, IvfParams::new(8), PqParams::new_4bit(8));
        mono.train(&ds.train).unwrap();
        mono.add(&ds.base).unwrap();
        mono.nprobe = 8;
        mono.fastscan.reservoir_factor = 32;
        let mono = IvfBackend::new(mono).unwrap();

        let (d_s, _l_s) = router.search_batch(&ds.queries, 5, None).unwrap();
        let (d_m, _l_m) = mono.search_batch(&ds.queries, 5, None).unwrap();
        // same PQ (same seed) ⇒ same distances for the merged top-k
        for qi in 0..25 {
            for r in 0..5 {
                let a = d_s[qi * 5 + r];
                let b = d_m[qi * 5 + r];
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                    "q{qi} r{r}: sharded {a} vs mono {b}"
                );
            }
        }
    }

    /// `from_indexes` wiring: sealed `Arc<dyn Index>` shards with global
    /// ids merge correctly, and an unsealed shard is rejected up front by
    /// the `IndexBackend` probe search.
    #[test]
    fn from_indexes_wires_dyn_shards() {
        use crate::index::{Index, IndexIvfPq4};
        let ds = SyntheticDataset::sift_like(1_000, 4, 234);
        let dim = ds.dim;
        let per = ds.n() / 2;
        let mut shards: Vec<Arc<dyn Index>> = Vec::new();
        for s in 0..2 {
            let mut idx = IndexIvfPq4::new(dim, 4, 8, false, 8);
            idx.train(&ds.train).unwrap();
            let slice = &ds.base[s * per * dim..(s + 1) * per * dim];
            let ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
            idx.inner_mut().add_with_ids(slice, &ids).unwrap();
            idx.set_param("nprobe", "4").unwrap();
            idx.set_param("reservoir_factor", "32").unwrap();
            idx.seal().unwrap();
            shards.push(Arc::new(idx));
        }
        let router = ShardedBackend::from_indexes(shards).unwrap();
        assert_eq!(router.nshards(), 2);
        // a query equal to a base row of each shard must surface that
        // shard's global id through the merge (rerank puts it on top)
        let (da, la) = router.search_batch(&ds.base[..dim], 5, None).unwrap();
        assert!(la.contains(&0), "{la:?}");
        assert!(da.windows(2).all(|w| w[0] <= w[1]), "{da:?}");
        let qb = &ds.base[per * dim..(per + 1) * dim];
        let (_db, lb) = router.search_batch(qb, 5, None).unwrap();
        assert!(lb.contains(&(per as i64)), "{lb:?}");

        // an unsealed shard fails at construction, not at serve time
        let mut unsealed = IndexIvfPq4::new(dim, 4, 8, false, 8);
        unsealed.train(&ds.train).unwrap();
        unsealed.add(&ds.base).unwrap();
        let unsealed_shards: Vec<Arc<dyn Index>> = vec![Arc::new(unsealed)];
        assert!(ShardedBackend::from_indexes(unsealed_shards).is_err());
    }

    /// Batch-level LUT reuse: shards trained identically share one LUT
    /// build per batch; results must be bit-identical to the per-shard
    /// rebuild path, and shards with *different* codebooks must not share.
    #[test]
    fn lut_reuse_across_shards_is_transparent() {
        let ds = SyntheticDataset::sift_like(2_000, 10, 235);
        let dim = ds.dim;
        let per = ds.n() / 2;
        let mk_shard = |s: usize, seed: u64| -> Arc<dyn SearchBackend> {
            let mut params = IvfParams::new(4);
            params.seed = seed;
            let mut pq_params = PqParams::new_4bit(8);
            pq_params.seed = seed;
            let mut idx = IvfPq4::new(dim, params, pq_params);
            idx.train(&ds.train).unwrap();
            let slice = &ds.base[s * per * dim..(s + 1) * per * dim];
            let ids: Vec<i64> = (s * per..(s + 1) * per).map(|i| i as i64).collect();
            idx.add_with_ids(slice, &ids).unwrap();
            idx.nprobe = 4;
            idx.fastscan.reservoir_factor = 32;
            Arc::new(IvfBackend::new(idx).unwrap())
        };

        // same training seed on both shards → one quantizer → reuse on
        let shared = ShardedBackend::new(vec![mk_shard(0, 7), mk_shard(1, 7)]).unwrap();
        assert!(shared.reuses_luts(), "equal codebooks must enable LUT reuse");
        let (d_shared, l_shared) = shared.search_batch(&ds.queries, 5, None).unwrap();

        // per-shard manual fan-out (no reuse) must give identical results
        let a = mk_shard(0, 7);
        let b = mk_shard(1, 7);
        let (da, la) = a.search_batch(&ds.queries, 5, None).unwrap();
        let (db, lb) = b.search_batch(&ds.queries, 5, None).unwrap();
        let mut d_manual = Vec::new();
        let mut l_manual = Vec::new();
        for qi in 0..10 {
            let mut heap = TopK::new(5);
            for (d, l) in [(&da, &la), (&db, &lb)] {
                for r in 0..5 {
                    if l[qi * 5 + r] >= 0 {
                        heap.push(d[qi * 5 + r], l[qi * 5 + r]);
                    }
                }
            }
            let (d, l) = heap.into_sorted();
            d_manual.extend(d);
            l_manual.extend(l);
        }
        assert_eq!(d_shared, d_manual, "LUT reuse changed distances");
        assert_eq!(l_shared, l_manual, "LUT reuse changed labels");

        // different training seeds → different signatures → no reuse,
        // still well-formed results
        let mixed = ShardedBackend::new(vec![mk_shard(0, 7), mk_shard(1, 8)]).unwrap();
        assert!(!mixed.reuses_luts(), "distinct codebooks must not share LUTs");
        let (dm, lm) = mixed.search_batch(&ds.queries, 5, None).unwrap();
        assert_eq!((dm.len(), lm.len()), (50, 50));
    }

    /// NUMA placement: shards are interleaved across the detected nodes
    /// round-robin, and the fan-out still answers correctly.
    #[test]
    fn shard_placement_interleaves_nodes() {
        let ds = SyntheticDataset::gaussian(300, 2, 16, 240);
        let mk = || -> Arc<dyn SearchBackend> {
            let mut idx = IvfPq4::new(16, IvfParams::new(2), PqParams::new_4bit(4));
            idx.train(&ds.base).unwrap();
            idx.add(&ds.base).unwrap();
            Arc::new(IvfBackend::new(idx).unwrap())
        };
        let router =
            ShardedBackend::with_executor(vec![mk(), mk(), mk()], QueryExecutor::new(4)).unwrap();
        let nnodes = crate::exec::pool::topology().node_count();
        assert_eq!(router.shard_nodes().len(), 3);
        for (i, &nd) in router.shard_nodes().iter().enumerate() {
            assert_eq!(nd, i % nnodes, "shard {i} not interleaved");
        }
        let (d, l) = router.search_batch(&ds.queries, 3, None).unwrap();
        assert_eq!((d.len(), l.len()), (6, 6));
    }

    #[test]
    fn rejects_empty_and_mismatched() {
        assert!(ShardedBackend::new(vec![]).is_err());
        let ds16 = SyntheticDataset::gaussian(300, 2, 16, 232);
        let ds32 = SyntheticDataset::gaussian(300, 2, 32, 233);
        let mk = |ds: &SyntheticDatasetData, dim: usize| -> Arc<dyn SearchBackend> {
            let mut idx = IvfPq4::new(dim, IvfParams::new(2), PqParams::new_4bit(4));
            idx.train(&ds.base).unwrap();
            idx.add(&ds.base).unwrap();
            Arc::new(IvfBackend::new(idx).unwrap())
        };
        type SyntheticDatasetData = crate::datasets::Dataset;
        let a = mk(&ds16, 16);
        let b = mk(&ds32, 32);
        assert!(ShardedBackend::new(vec![a, b]).is_err());
    }
}
