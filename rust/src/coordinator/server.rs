//! TCP serving front-end: newline-delimited JSON over a socket, one thread
//! per connection, all requests funneled through the shared [`Batcher`].
//!
//! Protocol (requests and responses are single JSON lines). The `search`
//! verb carries the full typed query model: an optional `"kind"`
//! (`"topk"`, the default, or `"range"` with `"radius"`) and an optional
//! `"filter"` (`{"id_range": [start, end)}` or `{"id_set": [ids…]}`):
//!
//! ```text
//!   → {"search": {"vector": [f32…], "k": 10,
//!                 "filter": {"id_range": [0, 1000]},
//!                 "params": {"nprobe": 8, "rerank": false}}}   (filter/params optional)
//!   ← {"ok": {"labels": […], "distances": […], "batch_size": n,
//!             "stats": {"codes_scanned": …, "lists_probed": …,
//!                       "filter_selectivity": …}}}
//!   → {"search": {"vector": [f32…], "kind": "range", "radius": 1.5,
//!                 "filter": {"id_set": [3, 17, 99]}}}
//!   ← {"ok": {"labels": […], "distances": […], …}}     (variable length)
//!   → {"insert": {"vectors": [[f32…], …], "ids": [i64…]}}   (ids optional)
//!   ← {"ok": {"ids": [i64…]}}                       (assigned labels)
//!   → {"delete": {"ids": [i64…]}}
//!   ← {"ok": {"deleted": n}}
//!   → {"stats": true}
//!   ← {"ok": { …metrics, incl. codes_scanned/filter_selectivity and the
//!              segment gauges (segments/memtable_entries/tombstones)… }}
//!   → {"metrics": true}
//!   ← {"ok": "<Prometheus text exposition>"}   (gauges refreshed, incl.
//!                                               mincore-sampled residency)
//!   → {"slowlog": true}
//!   ← {"ok": [{"e2e_us": …, "kind": "topk", "nq": 1, "trace": […]}, …]}
//!   → {"ping": true}
//!   ← {"ok": "pong"}
//!   ← {"err": "message"}           (any failure)
//! ```
//!
//! A `search` request may additionally carry `"trace": true`; the response
//! body then includes a `"trace"` array of per-phase spans
//! (`{"phase": "list_scan", "us": …, "count": …, "bytes": …}`) for that
//! query. Tracing never changes results — only the span array is added.
//!
//! `insert` and `delete` require a mutable (segmented) backend; sealed
//! single-segment backends answer them with an error. Mutations bypass
//! the batcher — they go straight to the backend, whose snapshot-swap
//! discipline keeps in-flight batched queries lock-free and consistent.
//!
//! Predicate filters are in-process closures and cannot cross the wire.
//! Range responses are truncated to the nearest `MAX_WIRE_RANGE_HITS`
//! hits — the radius analog of the top-k path's `k <= 1024` cap.
//!
//! For scrapers that speak HTTP rather than the line protocol,
//! [`ServerConfig::metrics_addr`] binds a one-endpoint HTTP listener that
//! answers every GET with the same Prometheus exposition the `metrics`
//! verb returns.

use super::batcher::{Batcher, BatcherConfig};
use super::service::SearchBackend;
use crate::index::query::{Filter, Hit, QueryKind, QueryStats};
use crate::index::SearchParams;
use crate::obs::{Phase, TraceSpan};
use crate::util::json::Json;
use crate::{Error, Result};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// e.g. "127.0.0.1:0" (0 = ephemeral port).
    pub addr: String,
    /// When set, also bind a plain-HTTP listener here whose every GET
    /// answers with the Prometheus text exposition (`--metrics-addr`).
    pub metrics_addr: Option<String>,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:0".into(), metrics_addr: None, batcher: BatcherConfig::default() }
    }
}

/// A running server (drop or call [`Server::stop`] to shut down).
pub struct Server {
    pub addr: std::net::SocketAddr,
    /// Bound address of the HTTP metrics endpoint, when configured.
    pub metrics_addr: Option<std::net::SocketAddr>,
    batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    metrics_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving in background threads.
    pub fn start(backend: Arc<dyn SearchBackend>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| Error::Serve(format!("bind {}: {e}", cfg.addr)))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let batcher = Arc::new(Batcher::start(backend.clone(), cfg.batcher));
        let stop = Arc::new(AtomicBool::new(false));

        let accept_thread = {
            let batcher = batcher.clone();
            let stop = stop.clone();
            let backend = backend.clone();
            let dim = backend.dim();
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            let batcher = batcher.clone();
                            let backend = backend.clone();
                            std::thread::spawn(move || {
                                let _ = handle_connection(stream, batcher, backend, dim);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            })
        };
        let (metrics_addr, metrics_thread) = match &cfg.metrics_addr {
            None => (None, None),
            Some(addr) => {
                let (bound, thread) =
                    spawn_metrics_http(addr, batcher.clone(), backend, stop.clone())?;
                (Some(bound), Some(thread))
            }
        };
        Ok(Server {
            addr,
            metrics_addr,
            batcher,
            stop,
            accept_thread: Some(accept_thread),
            metrics_thread,
        })
    }

    pub fn metrics_json(&self) -> Json {
        self.batcher.metrics.to_json()
    }

    /// Signal shutdown and join the acceptor (and the HTTP exporter, if
    /// one was configured).
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.metrics_thread.take() {
            let _ = t.join();
        }
    }
}

/// Refresh the lifecycle/residency gauges and render the Prometheus text
/// exposition — shared by the `metrics` verb and the HTTP endpoint so a
/// scrape is always a fresh snapshot, whichever door it came through.
fn render_prometheus(batcher: &Batcher, backend: &dyn SearchBackend) -> String {
    batcher.metrics.record_segment_stats(backend.segment_stats());
    // ask the kernel which mapped code pages are actually resident
    // (mincore ground truth) before snapshotting the storage gauges
    crate::storage::sample_residency();
    batcher.metrics.record_storage_stats();
    batcher.metrics.to_prometheus()
}

/// One-endpoint HTTP exporter: every GET answers 200 with the Prometheus
/// exposition. Deliberately minimal (no routing, no keep-alive) — it
/// exists so a stock Prometheus scraper can read the gauges without
/// speaking the line-JSON protocol.
fn spawn_metrics_http(
    addr: &str,
    batcher: Arc<Batcher>,
    backend: Arc<dyn SearchBackend>,
    stop: Arc<AtomicBool>,
) -> Result<(std::net::SocketAddr, std::thread::JoinHandle<()>)> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Serve(format!("bind metrics {addr}: {e}")))?;
    let bound = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let thread = std::thread::spawn(move || {
        while !stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = serve_metrics_scrape(stream, &batcher, backend.as_ref());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                Err(_) => break,
            }
        }
    });
    Ok((bound, thread))
}

fn serve_metrics_scrape(
    stream: TcpStream,
    batcher: &Batcher,
    backend: &dyn SearchBackend,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(2)))?;
    // consume the request head (request line + headers) so well-behaved
    // clients don't see a reset; the response is the same for any path
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || line.trim().is_empty() {
            break;
        }
    }
    let body = render_prometheus(batcher, backend);
    let mut writer = BufWriter::new(stream);
    write!(
        writer,
        "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
        body.len(),
        body
    )?;
    writer.flush()
}

fn handle_connection(
    stream: TcpStream,
    batcher: Arc<Batcher>,
    backend: Arc<dyn SearchBackend>,
    dim: usize,
) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let response = handle_request(line.trim(), &batcher, backend.as_ref(), dim);
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
}

fn handle_request(line: &str, batcher: &Batcher, backend: &dyn SearchBackend, dim: usize) -> Json {
    let err = |msg: String| {
        let mut o = Json::obj();
        o.set("err", Json::Str(msg));
        o
    };
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("bad json: {e}")),
    };
    if req.get("ping").is_some() {
        let mut o = Json::obj();
        o.set("ok", Json::Str("pong".into()));
        return o;
    }
    if req.get("stats").is_some() {
        // refresh the segment-lifecycle gauges so the snapshot reflects
        // mutations that arrived through other connections
        batcher.metrics.record_segment_stats(backend.segment_stats());
        // and the storage residency gauges (mapped/resident code bytes)
        batcher.metrics.record_storage_stats();
        let mut o = Json::obj();
        o.set("ok", batcher.metrics.to_json());
        return o;
    }
    if req.get("metrics").is_some() {
        let mut o = Json::obj();
        o.set("ok", Json::Str(render_prometheus(batcher, backend)));
        return o;
    }
    if req.get("slowlog").is_some() {
        let mut o = Json::obj();
        o.set("ok", batcher.metrics.slowlog_json());
        return o;
    }
    if let Some(insert) = req.get("insert") {
        return match handle_insert(insert, batcher, backend, dim) {
            Ok(ok) => ok,
            Err(e) => err(e.to_string()),
        };
    }
    if let Some(delete) = req.get("delete") {
        return match handle_delete(delete, batcher, backend) {
            Ok(ok) => ok,
            Err(e) => err(e.to_string()),
        };
    }
    let Some(search) = req.get("search") else {
        return err("expected search/insert/delete/stats/metrics/slowlog/ping".into());
    };
    let Some(vector) = search.get("vector").and_then(|v| v.as_arr()) else {
        return err("search.vector missing".into());
    };
    let vector: Vec<f32> = vector.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect();
    if vector.len() != dim {
        return err(format!("vector dim {} != index dim {dim}", vector.len()));
    }
    // query kind: "topk" (default, takes "k") or "range" (takes "radius")
    let kind = match search.get("kind").and_then(|x| x.as_str()) {
        None | Some("topk") => {
            let k = search.get("k").and_then(|x| x.as_usize()).unwrap_or(10);
            if k == 0 || k > 1024 {
                return err(format!("bad k {k}"));
            }
            QueryKind::TopK { k }
        }
        Some("range") => {
            let Some(radius) = search.get("radius").and_then(|x| x.as_f64()) else {
                return err("range query requires a numeric radius".into());
            };
            if !radius.is_finite() || radius < 0.0 {
                return err(format!("bad radius {radius}"));
            }
            QueryKind::Range { radius: radius as f32 }
        }
        Some(other) => return err(format!("bad kind {other:?} (topk|range)")),
    };
    let filter = match search.get("filter") {
        None => None,
        Some(obj) => match filter_from_json(obj) {
            Ok(f) => Some(f),
            Err(e) => return err(e.to_string()),
        },
    };
    let params = match search.get("params") {
        None => None,
        Some(obj) => {
            match search_params_from_json(obj).and_then(|p| {
                // the shortlist product caps are k-based; range queries
                // have no k, so they validate against the base bounds only
                match kind {
                    QueryKind::TopK { k } => p.validate_for_request(k)?,
                    QueryKind::Range { .. } => p.validate_bounds()?,
                }
                Ok(p)
            }) {
                Ok(p) => Some(p),
                Err(e) => return err(e.to_string()),
            }
        }
    };
    let trace = matches!(search.get("trace"), Some(Json::Bool(true)));
    let result = if trace {
        batcher.query_traced(vector, kind, filter, params)
    } else {
        batcher.query(vector, kind, filter, params)
    };
    match result {
        Ok(mut resp) => {
            // serving boundary: a huge radius must not let one request
            // serialize the whole corpus in a single JSON line. Hits are
            // sorted ascending, so truncation keeps the nearest.
            if matches!(kind, QueryKind::Range { .. })
                && resp.labels.len() > MAX_WIRE_RANGE_HITS
            {
                resp.labels.truncate(MAX_WIRE_RANGE_HITS);
                resp.distances.truncate(MAX_WIRE_RANGE_HITS);
            }
            let mut stats = Json::obj();
            stats
                .set("codes_scanned", Json::Num(resp.stats.codes_scanned as f64))
                .set("lists_probed", Json::Num(resp.stats.lists_probed as f64))
                .set("filter_selectivity", Json::Num(resp.stats.filter_selectivity))
                .set("threads_used", Json::Num(resp.stats.threads_used as f64))
                .set("scratch_bytes", Json::Num(resp.stats.scratch_bytes as f64))
                .set("segments_scanned", Json::Num(resp.stats.segments_scanned as f64))
                .set("memtable_entries", Json::Num(resp.stats.memtable_entries as f64))
                .set("tombstones", Json::Num(resp.stats.tombstones as f64))
                .set("bytes_mapped", Json::Num(resp.stats.bytes_mapped as f64))
                .set("prefetch_lists", Json::Num(resp.stats.prefetch_lists as f64));
            let mut body = Json::obj();
            body.set("labels", Json::Arr(resp.labels.iter().map(|&l| Json::Num(l as f64)).collect()))
                .set(
                    "distances",
                    Json::Arr(resp.distances.iter().map(|&d| Json::Num(d as f64)).collect()),
                )
                .set("batch_size", Json::Num(resp.batch_size as f64))
                .set("queue_us", Json::Num(resp.queue_us as f64))
                .set("service_us", Json::Num(resp.service_us as f64))
                .set("stats", stats);
            if trace {
                body.set("trace", trace_to_json(&resp.trace));
            }
            let mut o = Json::obj();
            o.set("ok", body);
            o
        }
        Err(e) => err(e.to_string()),
    }
}

/// `{"insert": {"vectors": [[…]…], "ids": […]?}}` → `{"ok": {"ids": […]}}`.
/// Goes straight to the backend (not through the batcher): segmented
/// backends mutate behind a snapshot swap, so concurrent batched queries
/// keep reading their consistent snapshots.
fn handle_insert(insert: &Json, batcher: &Batcher, backend: &dyn SearchBackend, dim: usize) -> Result<Json> {
    let rows = insert
        .get("vectors")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Serve("insert.vectors must be an array of vectors".into()))?;
    if rows.is_empty() {
        return Err(Error::Serve("insert.vectors is empty".into()));
    }
    if rows.len() > MAX_WIRE_INSERT_ROWS {
        return Err(Error::Serve(format!(
            "insert batch too large ({} > {MAX_WIRE_INSERT_ROWS})",
            rows.len()
        )));
    }
    let mut flat = Vec::with_capacity(rows.len() * dim);
    for (i, row) in rows.iter().enumerate() {
        let row = row
            .as_arr()
            .ok_or_else(|| Error::Serve(format!("insert.vectors[{i}] must be an array")))?;
        if row.len() != dim {
            return Err(Error::Serve(format!(
                "insert.vectors[{i}] dim {} != index dim {dim}",
                row.len()
            )));
        }
        for x in row {
            flat.push(
                x.as_f64()
                    .ok_or_else(|| Error::Serve(format!("insert.vectors[{i}] entries must be numbers")))?
                    as f32,
            );
        }
    }
    let ids = match insert.get("ids") {
        None => None,
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| Error::Serve("insert.ids must be an array of ids".into()))?;
            let ids: Option<Vec<i64>> = arr.iter().map(|x| x.as_f64().map(|v| v as i64)).collect();
            Some(ids.ok_or_else(|| Error::Serve("insert.ids entries must be numbers".into()))?)
        }
    };
    let assigned = backend.insert(&flat, ids.as_deref())?;
    batcher.metrics.inserts_total.fetch_add(assigned.len() as u64, Ordering::Relaxed);
    batcher.metrics.record_segment_stats(backend.segment_stats());
    let mut body = Json::obj();
    body.set("ids", Json::Arr(assigned.iter().map(|&id| Json::Num(id as f64)).collect()));
    let mut o = Json::obj();
    o.set("ok", body);
    Ok(o)
}

/// `{"delete": {"ids": […]}}` → `{"ok": {"deleted": n}}` where `n` counts
/// the ids that were actually live.
fn handle_delete(delete: &Json, batcher: &Batcher, backend: &dyn SearchBackend) -> Result<Json> {
    let arr = delete
        .get("ids")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Serve("delete.ids must be an array of ids".into()))?;
    if arr.len() > MAX_WIRE_ID_SET {
        return Err(Error::Serve(format!(
            "delete.ids too large ({} > {MAX_WIRE_ID_SET})",
            arr.len()
        )));
    }
    let ids: Option<Vec<i64>> = arr.iter().map(|x| x.as_f64().map(|v| v as i64)).collect();
    let ids = ids.ok_or_else(|| Error::Serve("delete.ids entries must be numbers".into()))?;
    let deleted = backend.delete(&ids)?;
    batcher.metrics.deletes_total.fetch_add(deleted as u64, Ordering::Relaxed);
    batcher.metrics.record_segment_stats(backend.segment_stats());
    let mut body = Json::obj();
    body.set("deleted", Json::Num(deleted as f64));
    let mut o = Json::obj();
    o.set("ok", body);
    Ok(o)
}

/// Largest id-set filter accepted over the wire — a remote client does not
/// get to make the server build multi-million-entry sets per request.
const MAX_WIRE_ID_SET: usize = 1 << 20;

/// Most vectors accepted in one `insert` line — bounds per-request memory
/// the same way `MAX_WIRE_ID_SET` bounds filter materialization.
const MAX_WIRE_INSERT_ROWS: usize = 4096;

/// Most range hits returned per wire response (nearest kept). The top-k
/// path caps `k` at 1024; this is the counterpart bound for radius
/// queries, whose natural result size is corpus-dependent.
const MAX_WIRE_RANGE_HITS: usize = 1 << 16;

/// Parse a wire filter object: `{"id_range": [start, end)}` or
/// `{"id_set": [ids…]}`.
fn filter_from_json(obj: &Json) -> Result<Filter> {
    // every entry must be numeric — silently narrowing a malformed filter
    // would return wrong (quietly smaller) result sets
    fn all_i64(arr: &[Json], what: &str) -> Result<Vec<i64>> {
        arr.iter()
            .map(|x| {
                x.as_f64()
                    .map(|v| v as i64)
                    .ok_or_else(|| Error::Serve(format!("filter.{what} entries must be numbers")))
            })
            .collect()
    }
    if let Some(r) = obj.get("id_range") {
        let Some(arr) = r.as_arr() else {
            return Err(Error::Serve("filter.id_range must be [start, end]".into()));
        };
        let parts = all_i64(arr, "id_range")?;
        if parts.len() != 2 {
            return Err(Error::Serve("filter.id_range must be [start, end]".into()));
        }
        return Ok(Filter::id_range(parts[0], parts[1]));
    }
    if let Some(s) = obj.get("id_set") {
        let Some(arr) = s.as_arr() else {
            return Err(Error::Serve("filter.id_set must be an array of ids".into()));
        };
        if arr.len() > MAX_WIRE_ID_SET {
            return Err(Error::Serve(format!(
                "filter.id_set too large ({} > {MAX_WIRE_ID_SET})",
                arr.len()
            )));
        }
        return Ok(Filter::id_set(&all_i64(arr, "id_set")?));
    }
    Err(Error::Serve("filter must carry id_range or id_set".into()))
}

/// Serialize a filter for the wire (the client side of
/// [`filter_from_json`]). Predicate filters are process-local closures.
fn filter_to_json(filter: &Filter) -> Result<Json> {
    let mut o = Json::obj();
    match filter {
        Filter::IdRange { start, end } => {
            o.set(
                "id_range",
                Json::Arr(vec![Json::Num(*start as f64), Json::Num(*end as f64)]),
            );
        }
        Filter::IdSet(set) => {
            o.set(
                "id_set",
                Json::Arr(set.ids().iter().map(|&id| Json::Num(id as f64)).collect()),
            );
        }
        Filter::Predicate(_) => {
            return Err(Error::Serve(
                "predicate filters cannot be serialized over the wire".into(),
            ))
        }
    }
    Ok(o)
}

/// Serialize trace spans for the wire: an array of
/// `{"phase": "list_scan", "us": …, "count": …, "bytes": …}` objects.
fn trace_to_json(spans: &[TraceSpan]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|s| {
                let mut o = Json::obj();
                o.set("phase", Json::Str(s.phase.name().into()))
                    .set("us", Json::Num(s.us as f64))
                    .set("count", Json::Num(s.count as f64))
                    .set("bytes", Json::Num(s.bytes as f64));
                o
            })
            .collect(),
    )
}

/// Parse a wire trace array back into spans; rows with an unknown phase
/// name are dropped (a newer server may emit phases this client predates).
pub(crate) fn trace_from_json(v: &Json) -> Vec<TraceSpan> {
    let Some(rows) = v.as_arr() else { return Vec::new() };
    rows.iter()
        .filter_map(|row| {
            let phase = Phase::from_name(row.get("phase")?.as_str()?)?;
            Some(TraceSpan {
                phase,
                us: row.get("us").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
                count: row.get("count").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
                bytes: row.get("bytes").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64,
            })
        })
        .collect()
}

/// Parse the wire `stats` object into [`QueryStats`] — every field the
/// server serializes, with the type's defaults for anything absent.
pub(crate) fn query_stats_from_json(s: &Json) -> QueryStats {
    QueryStats {
        codes_scanned: s.get("codes_scanned").and_then(|x| x.as_usize()).unwrap_or(0),
        lists_probed: s.get("lists_probed").and_then(|x| x.as_usize()).unwrap_or(0),
        filter_selectivity: s
            .get("filter_selectivity")
            .and_then(|x| x.as_f64())
            .unwrap_or(1.0),
        threads_used: s.get("threads_used").and_then(|x| x.as_usize()).unwrap_or(1),
        scratch_bytes: s.get("scratch_bytes").and_then(|x| x.as_usize()).unwrap_or(0),
        segments_scanned: s.get("segments_scanned").and_then(|x| x.as_usize()).unwrap_or(0),
        memtable_entries: s.get("memtable_entries").and_then(|x| x.as_usize()).unwrap_or(0),
        tombstones: s.get("tombstones").and_then(|x| x.as_usize()).unwrap_or(0),
        bytes_mapped: s.get("bytes_mapped").and_then(|x| x.as_usize()).unwrap_or(0),
        prefetch_lists: s.get("prefetch_lists").and_then(|x| x.as_usize()).unwrap_or(0),
    }
}

/// Parse a JSON object of per-request overrides through the shared
/// [`SearchParams::assign`] parser (numbers, bools and strings accepted).
fn search_params_from_json(obj: &Json) -> Result<SearchParams> {
    let Json::Obj(map) = obj else {
        return Err(Error::Serve("search.params must be an object".into()));
    };
    let mut params = SearchParams::default();
    for (key, value) in map {
        let text = match value {
            Json::Str(s) => s.clone(),
            Json::Bool(b) => b.to_string(),
            Json::Num(x) if x.fract() == 0.0 => format!("{}", *x as i64),
            other => other.to_string(),
        };
        params.assign(key, &text)?;
    }
    // remote clients don't get to size our buffers or pick kernels this
    // host cannot execute (the caller additionally applies the k-aware
    // product caps via validate_for_request)
    params.validate_bounds()?;
    Ok(params)
}

/// Line-JSON client for the server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).map_err(|e| Error::Serve(format!("connect: {e}")))?;
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn roundtrip(&mut self, req: &Json) -> Result<Json> {
        self.writer.write_all(req.to_string().as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let v = Json::parse(line.trim()).map_err(|e| Error::Serve(format!("bad response: {e}")))?;
        if let Some(e) = v.get("err") {
            return Err(Error::Serve(e.as_str().unwrap_or("unknown").to_string()));
        }
        v.get("ok").cloned().ok_or_else(|| Error::Serve("missing ok".into()))
    }

    pub fn ping(&mut self) -> Result<()> {
        let mut req = Json::obj();
        req.set("ping", Json::Bool(true));
        let ok = self.roundtrip(&req)?;
        if ok.as_str() == Some("pong") {
            Ok(())
        } else {
            Err(Error::Serve("bad pong".into()))
        }
    }

    pub fn stats(&mut self) -> Result<Json> {
        let mut req = Json::obj();
        req.set("stats", Json::Bool(true));
        self.roundtrip(&req)
    }

    /// Search; returns `(distances, labels, batch_size)`.
    pub fn search(&mut self, vector: &[f32], k: usize) -> Result<(Vec<f32>, Vec<i64>, usize)> {
        self.search_with(vector, k, None)
    }

    /// [`Client::search`] with per-request parameter overrides.
    pub fn search_with(
        &mut self,
        vector: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<f32>, Vec<i64>, usize)> {
        let mut inner = Json::obj();
        inner
            .set("vector", Json::Arr(vector.iter().map(|&x| Json::Num(x as f64)).collect()))
            .set("k", Json::Num(k as f64));
        if let Some(p) = params {
            let mut pobj = Json::obj();
            for (key, value) in p.to_kv() {
                pobj.set(key, Json::Str(value));
            }
            inner.set("params", pobj);
        }
        let mut req = Json::obj();
        req.set("search", inner);
        let ok = self.roundtrip(&req)?;
        let labels = ok
            .get("labels")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Serve("missing labels".into()))?
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as i64)
            .collect();
        let distances = ok
            .get("distances")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Serve("missing distances".into()))?
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as f32)
            .collect();
        let batch = ok.get("batch_size").and_then(|x| x.as_usize()).unwrap_or(1);
        Ok((distances, labels, batch))
    }

    /// The typed query entry: top-k or range, optionally filtered (`IdSet`
    /// / `IdRange` only — predicate filters cannot cross the wire).
    /// Returns real hits (padding stripped) plus the per-query stats.
    pub fn query(
        &mut self,
        vector: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<Hit>, QueryStats)> {
        let (hits, stats, _trace) = self.query_inner(vector, kind, filter, params, false)?;
        Ok((hits, stats))
    }

    /// [`Client::query`] with per-phase tracing: the extra return value is
    /// the server-side span breakdown for this query (plan compile, LUT
    /// build, scan, merge, rerank, …). Results are bit-identical to the
    /// untraced call.
    pub fn query_traced(
        &mut self,
        vector: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        params: Option<&SearchParams>,
    ) -> Result<(Vec<Hit>, QueryStats, Vec<TraceSpan>)> {
        self.query_inner(vector, kind, filter, params, true)
    }

    fn query_inner(
        &mut self,
        vector: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        params: Option<&SearchParams>,
        trace: bool,
    ) -> Result<(Vec<Hit>, QueryStats, Vec<TraceSpan>)> {
        let mut inner = Json::obj();
        inner.set("vector", Json::Arr(vector.iter().map(|&x| Json::Num(x as f64)).collect()));
        match kind {
            QueryKind::TopK { k } => {
                inner.set("kind", Json::Str("topk".into())).set("k", Json::Num(*k as f64));
            }
            QueryKind::Range { radius } => {
                inner
                    .set("kind", Json::Str("range".into()))
                    .set("radius", Json::Num(*radius as f64));
            }
        }
        if let Some(f) = filter {
            inner.set("filter", filter_to_json(f)?);
        }
        if let Some(p) = params {
            let mut pobj = Json::obj();
            for (key, value) in p.to_kv() {
                pobj.set(key, Json::Str(value));
            }
            inner.set("params", pobj);
        }
        if trace {
            inner.set("trace", Json::Bool(true));
        }
        let mut req = Json::obj();
        req.set("search", inner);
        let ok = self.roundtrip(&req)?;
        let labels =
            ok.get("labels").and_then(|x| x.as_arr()).ok_or_else(|| Error::Serve("missing labels".into()))?;
        let distances = ok
            .get("distances")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Serve("missing distances".into()))?;
        // parse index-aligned: top-k padding serializes as (null, -1) — a
        // null distance or a negative label marks a pad slot, not a hit
        let mut hits = Vec::new();
        for (l, d) in labels.iter().zip(distances.iter()) {
            let (Some(label), Some(distance)) = (l.as_f64(), d.as_f64()) else { continue };
            if label < 0.0 {
                continue;
            }
            hits.push(Hit { distance: distance as f32, label: label as i64 });
        }
        let stats = ok.get("stats").map(query_stats_from_json).unwrap_or_default();
        let spans = ok.get("trace").map(trace_from_json).unwrap_or_default();
        Ok((hits, stats, spans))
    }

    /// Fetch the Prometheus text exposition over the line protocol.
    pub fn metrics_text(&mut self) -> Result<String> {
        let mut req = Json::obj();
        req.set("metrics", Json::Bool(true));
        let ok = self.roundtrip(&req)?;
        ok.as_str()
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Serve("metrics body must be a string".into()))
    }

    /// Fetch the slow-query log: the worst end-to-end queries the server
    /// has seen, each with its phase trace when one was captured.
    pub fn slowlog(&mut self) -> Result<Json> {
        let mut req = Json::obj();
        req.set("slowlog", Json::Bool(true));
        self.roundtrip(&req)
    }

    /// Insert rows into a mutable (segmented) backend; returns the
    /// assigned labels. `ids` pins explicit labels (upsert semantics).
    pub fn insert(&mut self, vectors: &[Vec<f32>], ids: Option<&[i64]>) -> Result<Vec<i64>> {
        let mut inner = Json::obj();
        inner.set(
            "vectors",
            Json::Arr(
                vectors
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|&x| Json::Num(x as f64)).collect()))
                    .collect(),
            ),
        );
        if let Some(ids) = ids {
            inner.set("ids", Json::Arr(ids.iter().map(|&id| Json::Num(id as f64)).collect()));
        }
        let mut req = Json::obj();
        req.set("insert", inner);
        let ok = self.roundtrip(&req)?;
        Ok(ok
            .get("ids")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Serve("missing ids".into()))?
            .iter()
            .filter_map(|x| x.as_f64())
            .map(|x| x as i64)
            .collect())
    }

    /// Delete ids from a mutable (segmented) backend; returns how many
    /// were live.
    pub fn delete(&mut self, ids: &[i64]) -> Result<usize> {
        let mut inner = Json::obj();
        inner.set("ids", Json::Arr(ids.iter().map(|&id| Json::Num(id as f64)).collect()));
        let mut req = Json::obj();
        req.set("delete", inner);
        let ok = self.roundtrip(&req)?;
        ok.get("deleted")
            .and_then(|x| x.as_usize())
            .ok_or_else(|| Error::Serve("missing deleted".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::IvfBackend;
    use crate::ivf::{IvfParams, IvfPq4};
    use crate::pq::PqParams;
    use crate::util::rng::Rng;

    fn toy_backend() -> (Arc<dyn SearchBackend>, Vec<f32>) {
        let dim = 16;
        let mut rng = Rng::new(131);
        let data: Vec<f32> = (0..600 * dim).map(|_| rng.next_gaussian()).collect();
        let mut idx = IvfPq4::new(dim, IvfParams::new(4), PqParams::new_4bit(4));
        idx.train(&data).unwrap();
        idx.add(&data).unwrap();
        idx.nprobe = 4;
        (Arc::new(IvfBackend::new(idx).unwrap()), data)
    }

    #[test]
    fn serve_roundtrip() {
        let (backend, data) = toy_backend();
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        client.ping().unwrap();
        let (d, l, _batch) = client.search(&data[..16], 5).unwrap();
        assert_eq!(d.len(), 5);
        assert_eq!(l.len(), 5);
        assert!(d.windows(2).all(|w| w[0] <= w[1]));
        let stats = client.stats().unwrap();
        assert!(stats.get("requests_total").unwrap().as_usize().unwrap() >= 1);
        server.stop();
    }

    /// A deliberately slow backend: overload tests need service time to
    /// dominate so the bounded admission queue actually fills.
    struct SlowBackend {
        dim: usize,
        delay: std::time::Duration,
    }

    impl SearchBackend for SlowBackend {
        fn dim(&self) -> usize {
            self.dim
        }
        fn search_batch(
            &self,
            queries: &[f32],
            k: usize,
            _params: Option<&SearchParams>,
        ) -> Result<(Vec<f32>, Vec<i64>)> {
            std::thread::sleep(self.delay);
            let nq = queries.len() / self.dim;
            Ok((vec![0.0; nq * k], vec![0; nq * k]))
        }
        fn describe(&self) -> String {
            "slow-test-backend".into()
        }
    }

    /// Overload at the wire: with a bounded admission queue and a slow
    /// backend, a burst gets a mix of served responses and `overloaded`
    /// rejections, the control plane (ping) stays responsive throughout,
    /// and the server recovers once the burst drains.
    #[test]
    fn overload_wire_rejection_keeps_server_responsive() {
        let backend: Arc<dyn SearchBackend> =
            Arc::new(SlowBackend { dim: 8, delay: std::time::Duration::from_millis(25) });
        let mut cfg = ServerConfig::default();
        cfg.batcher.max_batch = 1;
        cfg.batcher.max_wait = std::time::Duration::ZERO;
        cfg.batcher.queue_depth = 2;
        let server = Server::start(backend, cfg).unwrap();
        let addr = server.addr;
        let mut handles = Vec::new();
        for _ in 0..8 {
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.search(&[0.0; 8], 3)
            }));
        }
        // the data plane is saturated; the control plane must still answer
        let mut control = Client::connect(&addr).unwrap();
        control.ping().unwrap();
        let mut ok = 0usize;
        let mut overloaded = 0usize;
        for h in handles {
            match h.join().unwrap() {
                Ok((d, _, _)) => {
                    assert_eq!(d.len(), 3);
                    ok += 1;
                }
                Err(e) => {
                    assert!(e.to_string().contains("overloaded"), "{e}");
                    overloaded += 1;
                }
            }
        }
        assert!(ok >= 1, "no request was served");
        assert!(overloaded >= 1, "bounded queue never rejected: ok={ok}");
        // rejections are visible on the scrape and the server recovered
        let j = server.metrics_json();
        assert!(
            j.get("admission_rejections_total").unwrap().as_usize().unwrap() >= overloaded,
            "{j:?}"
        );
        let (d, _, _) = control.search(&[0.0; 8], 3).unwrap();
        assert_eq!(d.len(), 3);
        server.stop();
    }

    #[test]
    fn concurrent_clients() {
        let (backend, data) = toy_backend();
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let addr = server.addr;
        let data = Arc::new(data);
        let mut handles = Vec::new();
        for t in 0..4 {
            let data = data.clone();
            handles.push(std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                for i in 0..5 {
                    let qi = (t * 5 + i) % 30;
                    let (d, l, _) = c.search(&data[qi * 16..(qi + 1) * 16], 3).unwrap();
                    assert_eq!(d.len(), 3);
                    assert!(l.iter().all(|&x| x >= 0));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(server.metrics_json().get("requests_total").unwrap().as_usize().unwrap() >= 20);
        server.stop();
    }

    /// The typed wire surface: filtered top-k and range queries round-trip
    /// through the line-JSON protocol with stats attached.
    #[test]
    fn query_verbs_roundtrip() {
        let (backend, data) = toy_backend();
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let q = &data[..16];
        // filtered top-k: every returned label obeys the range
        let (hits, stats) = client
            .query(
                q,
                &QueryKind::TopK { k: 5 },
                Some(&Filter::id_range(0, 100)),
                Some(&SearchParams::new().with_nprobe(4)),
            )
            .unwrap();
        assert!(!hits.is_empty());
        assert!(hits.iter().all(|h| (0..100).contains(&h.label)), "{hits:?}");
        assert!(stats.codes_scanned > 0);
        assert!(stats.filter_selectivity <= 1.0);
        // id_set filter
        let (hits, _stats) = client
            .query(q, &QueryKind::TopK { k: 5 }, Some(&Filter::id_set(&[1, 2, 3])), None)
            .unwrap();
        assert!(hits.iter().all(|h| (1..=3).contains(&h.label)), "{hits:?}");
        // range query: the query is base row 0, so id 0 (distance = its own
        // quantization error, far below this radius) must be a hit
        let (hits, _stats) =
            client.query(q, &QueryKind::Range { radius: 100.0 }, None, None).unwrap();
        assert!(hits.iter().any(|h| h.label == 0), "{hits:?}");
        assert!(hits.windows(2).all(|w| w[0].distance <= w[1].distance));
        // malformed: bad radius / bad kind / predicate filter client-side
        let bad = client.query(q, &QueryKind::Range { radius: f32::NAN }, None, None);
        assert!(bad.is_err());
        let pred = Filter::predicate(|_| true);
        assert!(client.query(q, &QueryKind::TopK { k: 3 }, Some(&pred), None).is_err());
        // server-side stats verb now exposes the scan-work histograms
        let stats = client.stats().unwrap();
        assert!(stats.get("codes_scanned_mean").unwrap().as_f64().unwrap() > 0.0);
        assert!(stats.get("filter_selectivity_mean").is_some());
        server.stop();
    }

    /// Mutation verbs against a segmented backend: insert → search sees
    /// the rows, delete → tombstoned rows stop answering, and the stats
    /// verb surfaces the segment-lifecycle gauges. A sealed backend
    /// refuses both verbs.
    #[test]
    fn mutation_verbs_roundtrip() {
        use crate::coordinator::service::IndexBackend;
        use crate::index::index_factory;
        let dim = 8;
        let mut rng = Rng::new(77);
        let train: Vec<f32> = (0..512 * dim).map(|_| rng.next_gaussian()).collect();
        let mut idx = index_factory(dim, "SEG64,PQ4x4fs").unwrap();
        idx.train(&train).unwrap();
        let backend: Arc<dyn SearchBackend> =
            Arc::new(IndexBackend::new(Arc::from(idx)).unwrap());
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let rows: Vec<Vec<f32>> =
            (0..100).map(|i| train[i * dim..(i + 1) * dim].to_vec()).collect();
        let ids: Vec<i64> = (0..100).collect();
        let assigned = client.insert(&rows, Some(&ids)).unwrap();
        assert_eq!(assigned, ids);
        // the batch crossed the flush threshold, so the scan covers at
        // least one sealed segment; the query itself finds row 0 exactly
        let (hits, stats) =
            client.query(&rows[0], &QueryKind::TopK { k: 3 }, None, None).unwrap();
        assert_eq!(hits[0].label, 0, "{hits:?}");
        assert!(stats.segments_scanned >= 1);
        // deleting a live id and a never-seen id deletes exactly one row
        assert_eq!(client.delete(&[0, 1_000_000]).unwrap(), 1);
        let (hits, _) = client.query(&rows[0], &QueryKind::TopK { k: 3 }, None, None).unwrap();
        assert!(hits.iter().all(|h| h.label != 0), "{hits:?}");
        let j = client.stats().unwrap();
        assert_eq!(j.get("inserts_total").unwrap().as_usize().unwrap(), 100);
        assert_eq!(j.get("deletes_total").unwrap().as_usize().unwrap(), 1);
        assert!(j.get("segments").unwrap().as_usize().unwrap() >= 1);
        assert_eq!(j.get("tombstones").unwrap().as_usize().unwrap(), 1);
        server.stop();
        // sealed single-segment backends answer mutations with an error
        let (sealed, _) = toy_backend();
        let server = Server::start(sealed, ServerConfig::default()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let err = client.insert(&[vec![0.0; 16]], None).unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err}");
        let err = client.delete(&[1]).unwrap_err();
        assert!(err.to_string().contains("read-only"), "{err}");
        server.stop();
    }

    #[test]
    fn protocol_errors() {
        let (backend, _) = toy_backend();
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        // wrong dimension
        let err = client.search(&[1.0, 2.0], 3).unwrap_err();
        assert!(err.to_string().contains("dim"), "{err}");
        // bad k
        let err = client.search(&vec![0.0; 16], 0).unwrap_err();
        assert!(err.to_string().contains("bad k"), "{err}");
        // good per-request params pass through
        let (d, _l, _b) = client
            .search_with(&vec![0.0; 16], 3, Some(&SearchParams::new().with_nprobe(4)))
            .unwrap();
        assert_eq!(d.len(), 3);
        // an unknown params key is rejected by the shared parser
        let mut pobj = Json::obj();
        pobj.set("bogus", Json::Num(1.0));
        let mut inner = Json::obj();
        inner
            .set("vector", Json::Arr(vec![Json::Num(0.0); 16]))
            .set("k", Json::Num(3.0))
            .set("params", pobj);
        let mut raw = Json::obj();
        raw.set("search", inner);
        let err = client.roundtrip(&raw).unwrap_err();
        assert!(err.to_string().contains("unknown parameter"), "{err}");
        // malformed json straight through the socket
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        w.write_all(b"this is not json\n").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("err"), "{line}");
        server.stop();
    }

    /// Every wire stats field survives the parse — a field the client
    /// silently dropped would read as its default forever.
    #[test]
    fn query_stats_from_json_parses_every_field() {
        let wire = r#"{"codes_scanned": 11, "lists_probed": 12,
                       "filter_selectivity": 0.25, "threads_used": 3,
                       "scratch_bytes": 14, "segments_scanned": 15,
                       "memtable_entries": 16, "tombstones": 17,
                       "bytes_mapped": 18, "prefetch_lists": 19}"#;
        let s = query_stats_from_json(&Json::parse(wire).unwrap());
        assert_eq!(s.codes_scanned, 11);
        assert_eq!(s.lists_probed, 12);
        assert!((s.filter_selectivity - 0.25).abs() < 1e-9);
        assert_eq!(s.threads_used, 3);
        assert_eq!(s.scratch_bytes, 14);
        assert_eq!(s.segments_scanned, 15);
        assert_eq!(s.memtable_entries, 16);
        assert_eq!(s.tombstones, 17);
        assert_eq!(s.bytes_mapped, 18);
        assert_eq!(s.prefetch_lists, 19);
        // absent fields fall back to the type's defaults
        let empty = query_stats_from_json(&Json::parse("{}").unwrap());
        assert_eq!(empty.codes_scanned, 0);
        assert!((empty.filter_selectivity - 1.0).abs() < 1e-9);
    }

    /// Spans round-trip through the wire encoding; rows with unknown
    /// phase names (a newer server) are dropped, not mangled.
    #[test]
    fn trace_spans_roundtrip_the_wire() {
        let spans = vec![
            TraceSpan { phase: Phase::LutBuild, us: 42, count: 0, bytes: 0 },
            TraceSpan { phase: Phase::ListScan, us: 1000, count: 512, bytes: 8192 },
            TraceSpan { phase: Phase::Total, us: 1100, count: 0, bytes: 0 },
        ];
        let wire = trace_to_json(&spans);
        assert_eq!(trace_from_json(&wire), spans);
        let with_unknown =
            Json::parse(r#"[{"phase": "warp_drive", "us": 9}, {"phase": "rerank", "us": 7}]"#)
                .unwrap();
        let parsed = trace_from_json(&with_unknown);
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].phase, Phase::Rerank);
        assert_eq!(parsed[0].us, 7);
    }

    /// The traced wire path end-to-end: identical hits, a span breakdown
    /// whose phases feed the histograms, a valid `metrics` exposition,
    /// and a populated slowlog.
    #[test]
    fn traced_search_and_metrics_verbs() {
        let (backend, data) = toy_backend();
        let server = Server::start(backend, ServerConfig::default()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let q = &data[..16];
        let (plain_hits, _) = client.query(q, &QueryKind::TopK { k: 5 }, None, None).unwrap();
        let (hits, stats, spans) =
            client.query_traced(q, &QueryKind::TopK { k: 5 }, None, None).unwrap();
        // tracing must not change results
        assert_eq!(hits, plain_hits);
        assert!(stats.codes_scanned > 0);
        assert!(!spans.is_empty(), "traced query returned no spans");
        assert!(
            spans.iter().any(|s| s.phase == Phase::Total && s.us > 0),
            "no total span: {spans:?}"
        );
        // untraced responses must not carry a trace array
        let (_, _, no_spans) = client.query_inner(q, &QueryKind::TopK { k: 5 }, None, None, false).unwrap();
        assert!(no_spans.is_empty());
        // the exposition covers the phase histograms the trace just fed
        let text = client.metrics_text().unwrap();
        assert!(text.contains("# TYPE armpq_phase_us histogram"), "{text}");
        assert!(text.contains("armpq_requests_total"), "{text}");
        assert!(text.contains("armpq_resident_sampled_bytes"), "{text}");
        // every query is a slowlog candidate, so the log is non-empty and
        // its traced entries carry spans
        let log = client.slowlog().unwrap();
        let rows = log.as_arr().unwrap();
        assert!(!rows.is_empty());
        assert!(rows[0].get("e2e_us").and_then(|x| x.as_f64()).unwrap() > 0.0);
        server.stop();
    }

    /// The HTTP exporter answers a plain GET with the same exposition.
    #[test]
    fn http_metrics_endpoint_scrapes() {
        let (backend, _) = toy_backend();
        let cfg = ServerConfig {
            metrics_addr: Some("127.0.0.1:0".into()),
            ..ServerConfig::default()
        };
        let server = Server::start(backend, cfg).unwrap();
        let addr = server.metrics_addr.expect("metrics endpoint not bound");
        use std::io::Read;
        let stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        w.write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
        w.flush().unwrap();
        let mut body = String::new();
        BufReader::new(stream).read_to_string(&mut body).unwrap();
        assert!(body.starts_with("HTTP/1.1 200 OK"), "{body}");
        assert!(body.contains("text/plain; version=0.0.4"), "{body}");
        assert!(body.contains("# TYPE armpq_e2e_us histogram"), "{body}");
        server.stop();
    }
}
