//! Inverted-file index with 4-bit PQ distance estimation (paper §4, §5.2).
//!
//! The dataset is partitioned into `nlist` cells by a coarse k-means
//! quantizer; a query probes the `nprobe` nearest cells and runs the
//! fastscan kernel over each cell's packed codes. Coarse assignment is
//! either a linear scan over the centroids ([`CoarseQuantizer::Flat`]) or
//! an HNSW graph walk ([`CoarseQuantizer::Hnsw`]) — the combination
//! "inverted index + HNSW + PQ" evaluated in the paper's Table 1.
//!
//! Distance estimation follows faiss `IVFPQFastScan` defaults:
//! `by_residual = false`, i.e. the PQ codes encode raw vectors and one LUT
//! set (built once per query from the full query vector) is shared across
//! all probed cells.
//!
//! # Per-list scanning and thread-count determinism
//!
//! A query's candidate set is defined **per probed list**: every list is
//! scanned with its own reservoir (or range collector), the per-list
//! candidates are concatenated in probe order, and one final deterministic
//! selection + re-rank produces the answer. Because no admission threshold
//! crosses a list boundary, the candidate set does not depend on how lists
//! are interleaved — so the executor may scan lists serially on one
//! thread, fan the batch out across queries, or fan a single
//! large-`nprobe` query out across its probed lists
//! (`QueryExecutor::run_tasks`), and the results are **bit-identical** in
//! every case. Candidates carry `(list, position)` instead of external
//! ids, so re-ranking reads codes directly from the packed lists — the old
//! per-query label→position `HashMap` is gone.

use crate::exec::{MaskPlan, QueryExecutor, ScanScratch};
use crate::hnsw::{Hnsw, HnswParams};
use crate::index::query::{Filter, Hit, QueryKind, QueryStats};
use crate::obs::{Phase, TraceSpan};
use crate::kmeans::{KMeans, KMeansParams};
use crate::pq::bitwidth::build_width_luts_with;
use crate::pq::fastscan::{scan_filtered, FastScanParams, FilterMask, ScanSink};
use crate::pq::{CodeWidth, PackedCodes, PqParams, ProductQuantizer};
use crate::util::topk::{TopK, U16Reservoir};
use crate::{Error, Result};

/// Strategy for the coarse (cell-assignment) search.
pub enum CoarseQuantizer {
    /// Exact linear scan over centroids.
    Flat,
    /// HNSW graph over the centroids (paper §5.2; ef defaults to 4×nprobe).
    Hnsw { graph: Hnsw, ef_search: usize },
}

impl CoarseQuantizer {
    /// `nprobe` nearest centroids, ascending by distance, written into the
    /// reusable `out` buffer (`heap_buf` is recycled heap storage — the
    /// flat arm runs allocation-free after warmup; the HNSW graph walk
    /// allocates internally). `ef_override` (per-request) replaces the
    /// stored HNSW candidate-list width.
    #[allow(clippy::too_many_arguments)]
    fn assign_into(
        &self,
        centroids: &[f32],
        nlist: usize,
        dim: usize,
        q: &[f32],
        nprobe: usize,
        ef_override: Option<usize>,
        out: &mut Vec<usize>,
        heap_buf: &mut Vec<(f32, i64)>,
    ) {
        out.clear();
        match self {
            CoarseQuantizer::Flat => {
                let mut heap =
                    TopK::from_storage(nprobe.min(nlist), std::mem::take(heap_buf));
                for c in 0..nlist {
                    let d = crate::util::l2_sq(q, &centroids[c * dim..(c + 1) * dim]);
                    heap.push(d, c as i64);
                }
                out.extend(heap.as_sorted_hits().iter().map(|&(_, l)| l as usize));
                *heap_buf = heap.into_storage();
            }
            CoarseQuantizer::Hnsw { graph, ef_search } => {
                // same resolution for both surfaces (stored default and
                // per-request override): the 4×nprobe auto floor applies
                // either way, so shim-set and per-request ef_search agree
                let ef = ef_override.unwrap_or(*ef_search).max(4 * nprobe);
                let (_d, ids) = graph.search(q, nprobe, ef);
                out.extend(ids.into_iter().filter(|&l| l >= 0).map(|l| l as usize));
            }
        }
    }
}

/// One inverted list: external ids + packed codes (width-parametric).
struct IvfList {
    ids: Vec<i64>,
    /// Flat codes retained during building; dropped at seal time.
    staging: Vec<u8>,
    packed: Option<PackedCodes>,
}

impl IvfList {
    fn new() -> Self {
        Self { ids: Vec::new(), staging: Vec::new(), packed: None }
    }
}

/// Build-time parameters for [`IvfPq4`].
#[derive(Clone, Debug)]
pub struct IvfParams {
    pub nlist: usize,
    /// Use an HNSW graph over centroids for coarse assignment.
    pub coarse_hnsw: bool,
    pub hnsw_m: usize,
    pub train_iters: usize,
    pub seed: u64,
}

impl IvfParams {
    pub fn new(nlist: usize) -> Self {
        Self { nlist, coarse_hnsw: false, hnsw_m: 32, train_iters: 20, seed: 99 }
    }
}

/// IVF + PQ fastscan index (the paper's large-scale configuration),
/// width-parametric: the fastscan kernel runs at 2-, 4- or 8-bit codes
/// ([`CodeWidth`]). The type keeps its historical `…Pq4` name — 4-bit is
/// the paper's (and the default) operating point.
pub struct IvfPq4 {
    pub dim: usize,
    pub params: IvfParams,
    /// Internal quantizer parameters (`width.pq_params(pq_m)`; for 8-bit
    /// this trains `2 × pq_m` half-space sub-quantizers).
    pub pq_params: PqParams,
    /// User-facing sub-quantizers per vector.
    pub pq_m: usize,
    /// Fastscan code width.
    pub width: CodeWidth,
    pub pq: Option<ProductQuantizer>,
    centroids: Vec<f32>,
    coarse: CoarseQuantizer,
    lists: Vec<IvfList>,
    ntotal: usize,
    /// Default search width (paper Table 1 sweeps 1, 2, 4); per-request
    /// values passed to [`IvfPq4::search_with`] override it per call.
    pub nprobe: usize,
    /// Default HNSW coarse candidate-list width (0 = auto: 4×nprobe).
    /// Carried here so it survives being set before `train()` builds the
    /// coarse graph; [`IvfPq4::set_ef_search`] keeps both in sync.
    ef_default: usize,
    /// Default kernel parameters (overridden per call the same way).
    pub fastscan: FastScanParams,
}

impl IvfPq4 {
    /// 4-bit constructor (the paper's configuration). `pq_params` must be a
    /// `K = 16` parameter set; use [`IvfPq4::new_width`] for 2-/8-bit.
    pub fn new(dim: usize, params: IvfParams, pq_params: PqParams) -> Self {
        let pq_m = pq_params.m;
        Self {
            dim,
            params,
            pq_params,
            pq_m,
            width: CodeWidth::W4,
            pq: None,
            centroids: Vec::new(),
            coarse: CoarseQuantizer::Flat,
            lists: Vec::new(),
            ntotal: 0,
            nprobe: 1,
            ef_default: 0,
            fastscan: FastScanParams::default(),
        }
    }

    /// Width-parametric constructor: `m` user-facing sub-quantizers scanned
    /// at `width` bits per code.
    pub fn new_width(dim: usize, params: IvfParams, m: usize, width: CodeWidth) -> Self {
        let mut index = Self::new(dim, params, width.pq_params(m));
        index.pq_m = m;
        index.width = width;
        index
    }

    pub fn is_trained(&self) -> bool {
        self.pq.is_some()
    }

    pub fn ntotal(&self) -> usize {
        self.ntotal
    }

    /// Train coarse quantizer + PQ codebooks on `n × dim` vectors.
    pub fn train(&mut self, data: &[f32]) -> Result<()> {
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        self.width.validate(self.dim, self.pq_m)?;
        let mut kp = KMeansParams::new(self.params.nlist);
        kp.iters = self.params.train_iters;
        kp.seed = self.params.seed;
        let km = KMeans::train(data, self.dim, &kp)?;
        self.centroids = km.centroids.clone();

        // PQ trained on raw vectors (by_residual = false).
        self.pq = Some(ProductQuantizer::train(data, self.dim, &self.pq_params)?);

        // Coarse structure over the centroids.
        self.coarse = if self.params.coarse_hnsw {
            let mut graph = Hnsw::new(
                self.dim,
                HnswParams {
                    m: self.params.hnsw_m,
                    ef_construction: 2 * self.params.hnsw_m,
                    seed: self.params.seed,
                },
            );
            graph.add_batch(&self.centroids)?;
            CoarseQuantizer::Hnsw { graph, ef_search: self.ef_default }
        } else {
            CoarseQuantizer::Flat
        };

        self.lists = (0..self.params.nlist).map(|_| IvfList::new()).collect();
        Ok(())
    }

    /// Add vectors with sequential ids.
    pub fn add(&mut self, data: &[f32]) -> Result<()> {
        let start = self.ntotal as i64;
        let n = data.len() / self.dim;
        let ids: Vec<i64> = (start..start + n as i64).collect();
        self.add_with_ids(data, &ids)
    }

    /// Add vectors with explicit external ids.
    pub fn add_with_ids(&mut self, data: &[f32], ids: &[i64]) -> Result<()> {
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        let n = data.len() / self.dim;
        if ids.len() != n {
            return Err(Error::InvalidParameter(format!("{} ids for {n} vectors", ids.len())));
        }
        // coarse-assign + encode
        let assign: Vec<u32> = {
            let nlist = self.params.nlist;
            let dim = self.dim;
            let cents = &self.centroids;
            crate::util::threads::parallel_map(n, crate::util::threads::default_threads(), |i| {
                crate::kmeans::nearest_centroid(&data[i * dim..(i + 1) * dim], cents, nlist, dim)
                    .0 as u32
            })
        };
        let codes = pq.encode(data)?;
        let m = pq.m;
        for i in 0..n {
            let list = &mut self.lists[assign[i] as usize];
            // a zero-copy-loaded list has rows only in its packed block;
            // rematerialize the flat columns before appending, or the
            // repack at seal() would silently drop the mapped rows
            if list.staging.is_empty() && !list.ids.is_empty() {
                if let Some(p) = &list.packed {
                    list.staging = p.unpack();
                }
            }
            list.ids.push(ids[i]);
            list.staging.extend_from_slice(&codes[i * m..(i + 1) * m]);
            list.packed = None; // invalidate packing
        }
        self.ntotal += n;
        Ok(())
    }

    /// Pack any dirty lists — ends the build phase. Idempotent: sealing an
    /// already-sealed index is a no-op.
    pub fn seal(&mut self) -> Result<()> {
        self.pq.as_ref().ok_or(Error::NotTrained)?;
        for list in &mut self.lists {
            if list.packed.is_none() && !list.ids.is_empty() {
                list.packed = Some(PackedCodes::pack(&list.staging, self.pq_m, self.width)?);
            }
        }
        Ok(())
    }

    /// Whether every non-empty list is packed (searchable without reseal).
    pub fn is_sealed(&self) -> bool {
        self.lists.iter().all(|l| l.packed.is_some() || l.ids.is_empty())
    }

    /// Set the default HNSW coarse candidate-list width (0 = auto:
    /// 4×nprobe). Takes effect whether called before or after `train()`;
    /// meaningless (but harmless) with a flat coarse quantizer.
    pub fn set_ef_search(&mut self, ef: usize) {
        self.ef_default = ef;
        if let CoarseQuantizer::Hnsw { ef_search, .. } = &mut self.coarse {
            *ef_search = ef;
        }
    }

    /// Search a batch of queries (`nq × dim`) with the index's default
    /// parameters, returning `(distances, labels)` each `nq × k`.
    ///
    /// Read-only: the index must be sealed ([`IvfPq4::seal`]) — searching
    /// with unpacked staged codes returns [`Error::NotSealed`] instead of
    /// silently repacking.
    pub fn search(&self, queries: &[f32], k: usize) -> Result<(Vec<f32>, Vec<i64>)> {
        self.search_with(queries, k, self.nprobe, None, &self.fastscan)
    }

    /// [`IvfPq4::search`] with explicit per-request parameters: probe
    /// width, optional HNSW candidate-list width, and kernel parameters.
    /// A flattened-and-padded wrapper over the [`IvfPq4::query_exec_with`]
    /// machinery (top-k, unfiltered).
    pub fn search_with(
        &self,
        queries: &[f32],
        k: usize,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let (rows, _stats) = self.query_exec_with(
            queries,
            None,
            &QueryKind::TopK { k },
            None,
            nprobe,
            ef_search,
            fastscan,
            QueryExecutor::global(),
        )?;
        Ok(Self::flatten_padded(rows, k, queries.len() / self.dim.max(1)))
    }

    /// [`IvfPq4::search_with`] with precomputed per-query f32 LUTs
    /// (`nq × lut_len`, from [`IvfPq4::compute_scan_luts`] of an index with
    /// the same trained quantizer) — the batch-level LUT-reuse entry the
    /// coordinator uses so one LUT build serves a whole shard fan-out.
    pub fn search_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        k: usize,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let (rows, _stats) = self.query_exec_with(
            queries,
            Some(luts),
            &QueryKind::TopK { k },
            None,
            nprobe,
            ef_search,
            fastscan,
            QueryExecutor::global(),
        )?;
        Ok(Self::flatten_padded(rows, k, queries.len() / self.dim.max(1)))
    }

    /// The typed query entry: top-k or range, optionally filtered, with
    /// explicit runtime parameters, on the process-global executor.
    #[allow(clippy::too_many_arguments)]
    pub fn query_with(
        &self,
        queries: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>)> {
        self.query_exec_with(
            queries,
            None,
            kind,
            filter,
            nprobe,
            ef_search,
            fastscan,
            QueryExecutor::global(),
        )
    }

    /// [`IvfPq4::query_with`] with precomputed per-query f32 LUTs.
    #[allow(clippy::too_many_arguments)]
    pub fn query_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>)> {
        self.query_exec_with(
            queries,
            Some(luts),
            kind,
            filter,
            nprobe,
            ef_search,
            fastscan,
            QueryExecutor::global(),
        )
    }

    /// Per-query f32 scan LUTs (`nq × m_codes × sub_ksub`), shareable with
    /// any index whose trained quantizer is identical.
    pub fn compute_scan_luts(&self, queries: &[f32]) -> Result<Vec<f32>> {
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: queries.len() % self.dim });
        }
        Ok(pq.compute_luts_batch(queries))
    }

    fn flatten_padded(rows: Vec<Vec<Hit>>, k: usize, nq: usize) -> (Vec<f32>, Vec<i64>) {
        if k == 0 || nq == 0 {
            return (Vec::new(), Vec::new());
        }
        let mut dists = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        for row in rows {
            let (d, l) = crate::index::query::pad_hits(&row, k);
            dists.extend(d);
            labels.extend(l);
        }
        (dists, labels)
    }

    /// Selectivity-aware probe escalation: a filter that admits a fraction
    /// `sel` of the corpus thins every probed list by the same factor, so
    /// the probe width scales by `1/sel` to keep the expected candidate
    /// count — capped at 16× the requested width and at `nlist` (full
    /// probe). Opaque filters (predicates) don't escalate: their
    /// selectivity is unknowable without scanning.
    fn escalated_nprobe(&self, nprobe: usize, filter: Option<&Filter>) -> usize {
        let Some(hint) = filter.and_then(|f| f.selectivity_hint(self.ntotal)) else {
            return nprobe;
        };
        if hint <= 0.0 || hint >= 1.0 {
            return nprobe;
        }
        let scaled = (nprobe as f64 / hint).ceil() as usize;
        scaled.min(nprobe.saturating_mul(16)).min(self.params.nlist).max(nprobe)
    }

    /// The plan/execute query core: top-k or range, optionally filtered,
    /// with explicit runtime parameters, on an explicit executor.
    ///
    /// Builds the request's plan once (validation, escalated probe width,
    /// lazily-compiled per-list filter masks shared across the batch),
    /// then fans out: across queries when the batch is at least as wide as
    /// the executor, otherwise across each query's probed lists — a single
    /// large-`nprobe` query uses the whole socket. Per-list candidate
    /// semantics make both schedules return bit-identical results (see the
    /// module docs).
    #[allow(clippy::too_many_arguments)]
    pub fn query_exec_with(
        &self,
        queries: &[f32],
        luts: Option<&[f32]>,
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
        exec: &QueryExecutor,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>)> {
        let (hits, stats, _traces) = self.query_exec_traced_with(
            queries, luts, kind, filter, nprobe, ef_search, fastscan, exec, false,
        )?;
        Ok((hits, stats))
    }

    /// [`IvfPq4::query_exec_with`] plus per-query trace collection: when
    /// `trace` is set each query also returns its per-phase
    /// [`TraceSpan`] breakdown (coarse quantization, LUT build, list
    /// scan, rerank, total — see [`crate::obs`]). Results are
    /// bit-identical with tracing on or off; with it off this *is*
    /// `query_exec_with` (no timestamps, no allocations).
    #[allow(clippy::too_many_arguments)]
    pub fn query_exec_traced_with(
        &self,
        queries: &[f32],
        luts: Option<&[f32]>,
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
        exec: &QueryExecutor,
        trace: bool,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>, Vec<Vec<TraceSpan>>)> {
        kind.validate()?;
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: queries.len() % self.dim });
        }
        let nq = queries.len() / self.dim;
        let lut_len = pq.m * pq.ksub;
        if let Some(ls) = luts {
            if ls.len() != nq * lut_len {
                return Err(Error::InvalidParameter(format!(
                    "precomputed luts length {} != nq {nq} × {lut_len}",
                    ls.len()
                )));
            }
        }
        if nq == 0 {
            return Ok((Vec::new(), Vec::new(), Vec::new()));
        }
        // degenerate answers still honor the trace contract: one (empty)
        // span row per query when tracing was requested
        let empty_traces = |nq: usize| if trace { vec![Vec::new(); nq] } else { Vec::new() };
        if self.ntotal == 0 || matches!(kind, QueryKind::TopK { k: 0 }) {
            return Ok((
                vec![Vec::new(); nq],
                vec![QueryStats::default(); nq],
                empty_traces(nq),
            ));
        }
        if !self.is_sealed() {
            return Err(Error::NotSealed);
        }
        // a provably-empty filter answers without probing anything
        if filter.is_some_and(|f| f.is_provably_empty()) {
            let stats = QueryStats {
                codes_scanned: 0,
                lists_probed: 0,
                filter_selectivity: 0.0,
                ..Default::default()
            };
            return Ok((vec![Vec::new(); nq], vec![stats; nq], empty_traces(nq)));
        }
        // ---- plan: everything below is resolved once per request ----
        let plan_t0 = trace.then(std::time::Instant::now);
        let nprobe = self.escalated_nprobe(nprobe.max(1), filter);
        // per-list filter masks, compiled lazily (only probed lists pay)
        // and shared read-only across the whole batch and all workers
        let masks = match filter {
            Some(_) => MaskPlan::lists(self.params.nlist),
            None => MaskPlan::None,
        };
        // request-level plan cost, attributed to each query it served
        let plan_us = plan_t0.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0);
        let run_one = |qi: usize, scratch: &mut ScanScratch, list_exec: Option<&QueryExecutor>| {
            if trace {
                scratch.trace_mut().enable();
                scratch.trace_mut().add(Phase::PlanCompile, plan_us, 0, 0);
            }
            let t_total = scratch.trace().start();
            let q = &queries[qi * self.dim..(qi + 1) * self.dim];
            let mut lbuf = scratch.take_luts();
            let luts_f32: &[f32] = match luts {
                Some(ls) => &ls[qi * lut_len..(qi + 1) * lut_len],
                None => {
                    let t_lut = scratch.trace().start();
                    pq.compute_luts_into(q, &mut lbuf);
                    scratch.trace_mut().finish(Phase::LutBuild, t_lut);
                    &lbuf
                }
            };
            let (row, st) = self.query_one_exec(
                pq, q, luts_f32, kind, filter, &masks, nprobe, ef_search, fastscan, scratch,
                list_exec,
            );
            scratch.put_luts(lbuf);
            let spans = if trace {
                scratch.trace_mut().finish(Phase::Total, t_total);
                // fold the shared plan time into Total so the per-phase
                // sum and the total keep describing the same window
                scratch.trace_mut().add(Phase::Total, plan_us, 0, 0);
                scratch.trace_mut().drain()
            } else {
                Vec::new()
            };
            (row, st, spans)
        };
        // ---- execute: batch fan-out, or intra-query multi-list fan-out
        // for batches too small to fill the thread budget. Both schedules
        // compute the identical per-list candidate sets.
        let batch_mode = nq >= exec.threads() || exec.threads() <= 1;
        let results: Vec<(Vec<Hit>, QueryStats, Vec<TraceSpan>)> = if batch_mode {
            exec.run_batch(nq, |qi, scratch| run_one(qi, scratch, None))
        } else {
            let mut guard = exec.checkout_scratch();
            (0..nq).map(|qi| run_one(qi, &mut *guard, Some(exec))).collect()
        };
        let mut hits = Vec::with_capacity(nq);
        let mut stats = Vec::with_capacity(nq);
        let mut traces = if trace { Vec::with_capacity(nq) } else { Vec::new() };
        for (row, mut st, spans) in results {
            // batch mode: the fan-out width is the batch's; intra-query
            // mode: query_one_exec already recorded the width its actual
            // probe count fanned out over (may be below nprobe when the
            // coarse quantizer returns fewer lists)
            if batch_mode {
                st.threads_used = exec.threads_for(nq);
            }
            st.scratch_bytes = exec.scratch_high_water_bytes();
            hits.push(row);
            stats.push(st);
            if trace {
                traces.push(spans);
            }
        }
        Ok((hits, stats, traces))
    }

    /// Scan one probed list into per-list candidates: `(d16, position)`
    /// pairs from the list's own reservoir (top-k) or range collector.
    /// `storage` is recycled between lists; the returned counts are
    /// `(candidates, codes_considered, codes_admitted)`.
    #[allow(clippy::too_many_arguments)]
    fn scan_one_list(
        &self,
        c: usize,
        kind: &QueryKind,
        kluts: &crate::pq::fastscan::KernelLuts,
        range_bound: u16,
        filter: Option<&Filter>,
        masks: &MaskPlan,
        fastscan: &FastScanParams,
        storage: Vec<(u16, i64)>,
    ) -> (Vec<(u16, i64)>, usize, usize) {
        let list = &self.lists[c];
        let Some(packed) = &list.packed else {
            // empty (never-packed) list: the recycled storage still holds
            // the PREVIOUS list's candidates — hand back an empty set, or
            // the caller would merge stale candidates under this list's id
            let mut storage = storage;
            storage.clear();
            return (storage, 0, 0);
        };
        let n = list.ids.len();
        let mask: Option<&FilterMask> = match filter {
            Some(f) => masks.list_mask(c, || f.build_mask(Some(&list.ids), n)),
            None => None,
        };
        let admitted = mask.map(|m| m.pass_count()).unwrap_or(n);
        // scan with identity labels: candidates are *positions within the
        // list* — re-ranking reads codes straight from (list, position),
        // external ids are applied at output time
        match kind {
            QueryKind::TopK { k } => {
                let mut reservoir =
                    U16Reservoir::from_storage(*k, fastscan.reservoir_factor, storage);
                {
                    let mut sink = ScanSink::TopK(&mut reservoir);
                    scan_filtered(packed, kluts, fastscan.backend, None, mask, &mut sink);
                }
                (reservoir.into_candidates(), n, admitted)
            }
            QueryKind::Range { .. } => {
                let mut raw = storage;
                raw.clear(); // recycled between lists: drop the previous list's hits
                {
                    let mut sink = ScanSink::Range { bound: range_bound, hits: &mut raw };
                    scan_filtered(packed, kluts, fastscan.backend, None, mask, &mut sink);
                }
                (raw, n, admitted)
            }
        }
    }

    /// One query against the plan: coarse-assign, scan each probed list
    /// into its own candidate set (serially, or fanned out over
    /// `list_exec` when given — same results either way), merge in probe
    /// order through one deterministic final selection, re-rank.
    #[allow(clippy::too_many_arguments)]
    fn query_one_exec(
        &self,
        pq: &ProductQuantizer,
        q: &[f32],
        luts_f32: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        masks: &MaskPlan,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
        scratch: &mut ScanScratch,
        list_exec: Option<&QueryExecutor>,
    ) -> (Vec<Hit>, QueryStats) {
        // 1. coarse quantization (paper §4 step 1-2)
        let t_coarse = scratch.trace().start();
        let mut probes = scratch.take_probes();
        {
            let mut hbuf = scratch.take_heap();
            self.coarse.assign_into(
                &self.centroids,
                self.params.nlist,
                self.dim,
                q,
                nprobe,
                ef_search,
                &mut probes,
                &mut hbuf,
            );
            scratch.put_heap(hbuf);
        }
        let n_probes = probes.len() as u64;
        scratch.trace_mut().finish_with(Phase::CoarseQuant, t_coarse, n_probes, 0);

        // 2. one LUT set shared across probed lists (by_residual = false),
        //    quantized/fused per the index's code width, built on scratch
        let t_lut = scratch.trace().start();
        let wl = build_width_luts_with(luts_f32, self.pq_m, self.width, scratch.wl_buf_mut());
        let range_bound = match kind {
            QueryKind::Range { radius } => wl.qluts.collection_bound(*radius, fastscan.rerank),
            QueryKind::TopK { .. } => 0,
        };
        scratch.trace_mut().finish(Phase::LutBuild, t_lut);

        // 3. per-list fastscan into candidates, merged in probe order.
        //    Candidates encode (list, position) in the label: position in
        //    the low 32 bits, probe-list id above. Traced, the whole
        //    fork/join (or serial walk) is one wall-clock ListScan span.
        let t_scan = scratch.trace().start();
        let mut merged = scratch.take_merged();
        let mut considered = 0usize;
        let mut passed = 0usize;
        let mut prefetched = 0usize;
        match list_exec {
            Some(lexec) if probes.len() > 1 && lexec.threads() > 1 => {
                // intra-query fan-out: each probed list is an independent
                // task; results are collected (and merged) in probe order.
                // The scan runs on the task worker's pooled storage (no
                // working-set growth after warmup); only the exact-size
                // candidate copy crosses back — the one allocation this
                // schedule needs for the cross-thread hand-off.
                let per_list = lexec.run_tasks(probes.len(), |i, task_scratch| {
                    let (cands, n, admitted) = self.scan_one_list(
                        probes[i],
                        kind,
                        &wl.kernel,
                        range_bound,
                        filter,
                        masks,
                        fastscan,
                        task_scratch.take_items(),
                    );
                    let result = cands.as_slice().to_vec();
                    task_scratch.put_items(cands);
                    (result, n, admitted)
                });
                for (i, (cands, n, admitted)) in per_list.into_iter().enumerate() {
                    considered += n;
                    passed += admitted;
                    let c = probes[i] as i64;
                    merged.extend(cands.iter().map(|&(d, pos)| (d, (c << 32) | pos)));
                }
            }
            _ => {
                // serial per-list scans on this worker's scratch —
                // identical candidate sets, zero allocations after warmup
                let mut storage = scratch.take_items();
                for (pi, &c) in probes.iter().enumerate() {
                    // touch the next probed list's packed block while this
                    // one is being scanned: on mapped (mmap) indexes that
                    // turns a cold page fault into an overlap with work
                    if let Some(&next) = probes.get(pi + 1) {
                        if let Some(p) = &self.lists[next].packed {
                            crate::storage::prefetch_span(&p.data);
                            prefetched += 1;
                        }
                    }
                    let (cands, n, admitted) = self.scan_one_list(
                        c,
                        kind,
                        &wl.kernel,
                        range_bound,
                        filter,
                        masks,
                        fastscan,
                        storage,
                    );
                    considered += n;
                    passed += admitted;
                    merged.extend(cands.iter().map(|&(d, pos)| (d, ((c as i64) << 32) | pos)));
                    storage = cands;
                }
                scratch.put_items(storage);
            }
        }
        let bytes_mapped: usize = probes
            .iter()
            .filter_map(|&c| self.lists[c].packed.as_ref())
            .map(|p| p.mapped_bytes())
            .sum();
        scratch.trace_mut().finish_with(
            Phase::ListScan,
            t_scan,
            considered as u64,
            bytes_mapped as u64,
        );
        let st = QueryStats {
            codes_scanned: considered,
            lists_probed: probes.len(),
            filter_selectivity: if filter.is_some() && considered > 0 {
                passed as f64 / considered as f64
            } else {
                1.0
            },
            // intra-query fan-out width over the lists actually probed
            // (the caller overwrites this with the batch width in batch
            // mode); serial scans report 1
            threads_used: list_exec.map(|le| le.threads_for(probes.len())).unwrap_or(1),
            bytes_mapped,
            prefetch_lists: prefetched,
            ..Default::default()
        };

        // 4. deterministic final selection + exact re-rank. Candidates are
        //    addressed as (list, position): codes come straight from the
        //    packed list, the external id from the list's id array —
        //    duplicate external ids re-rank independently, never a panic.
        let unpack = |pref: i64| ((pref >> 32) as usize, (pref & 0xFFFF_FFFF) as usize);
        let t_rerank = scratch.trace().start();
        let n_cands = merged.len() as u64;
        let row: Vec<Hit> = match kind {
            QueryKind::TopK { k } => {
                let mut selection =
                    U16Reservoir::from_storage(*k, fastscan.reservoir_factor, scratch.take_items());
                for &(d, pref) in merged.iter() {
                    selection.push(d, pref);
                }
                let cands = selection.into_candidates();
                let mut heap = TopK::from_storage(*k, scratch.take_heap());
                let mut codes_buf = scratch.take_codes();
                codes_buf.resize(pq.m, 0);
                for &(d16, pref) in cands.iter() {
                    let (c, j) = unpack(pref);
                    let list = &self.lists[c];
                    let d = if fastscan.rerank {
                        let packed = list.packed.as_ref().unwrap();
                        for (mi, slot) in codes_buf.iter_mut().enumerate() {
                            *slot = packed.code_at(j, mi);
                        }
                        pq.adc_distance(luts_f32, &codes_buf)
                    } else {
                        wl.qluts.decode(d16)
                    };
                    heap.push(d, list.ids[j]);
                }
                let row = heap
                    .as_sorted_hits()
                    .iter()
                    .map(|&(distance, label)| Hit { distance, label })
                    .collect();
                scratch.put_codes(codes_buf);
                scratch.put_heap(heap.into_storage());
                scratch.put_items(cands);
                row
            }
            QueryKind::Range { radius } => {
                let mut codes_buf = scratch.take_codes();
                codes_buf.resize(pq.m, 0);
                let mut out: Vec<Hit> = Vec::with_capacity(merged.len());
                for &(d16, pref) in merged.iter() {
                    let (c, j) = unpack(pref);
                    let list = &self.lists[c];
                    if fastscan.rerank {
                        let packed = list.packed.as_ref().unwrap();
                        for (mi, slot) in codes_buf.iter_mut().enumerate() {
                            *slot = packed.code_at(j, mi);
                        }
                        let d = pq.adc_distance(luts_f32, &codes_buf);
                        if d <= *radius {
                            out.push(Hit { distance: d, label: list.ids[j] });
                        }
                    } else {
                        out.push(Hit { distance: wl.qluts.decode(d16), label: list.ids[j] });
                    }
                }
                out.sort_by(|a, b| {
                    a.distance
                        .partial_cmp(&b.distance)
                        .unwrap()
                        .then(a.label.cmp(&b.label))
                });
                scratch.put_codes(codes_buf);
                out
            }
        };
        merged.clear();
        scratch.put_merged(merged);
        wl.recycle(scratch.wl_buf_mut());
        scratch.put_probes(probes);
        scratch.trace_mut().finish_with(Phase::Rerank, t_rerank, n_cands, 0);
        (row, st)
    }

    /// Coarse centroids (`nlist × dim`) — persistence accessor.
    pub fn centroids(&self) -> &[f32] {
        &self.centroids
    }

    /// Ids + flat staging codes of one list — persistence accessor.
    /// (Lists keep their flat codes alongside the packed form.)
    pub fn list_contents(&self, c: usize) -> (&[i64], &[u8]) {
        (&self.lists[c].ids, &self.lists[c].staging)
    }

    /// The kernel-ready packed block of one list (`None` while empty or
    /// unsealed) — the v3 persistence accessor: format v3 stores the
    /// packed layout verbatim so a mapped reopen needs no repack.
    pub fn list_packed(&self, c: usize) -> Option<&PackedCodes> {
        self.lists[c].packed.as_ref()
    }

    /// Flat code columns of one list, rematerialized from the packed
    /// block when the staging was never kept (zero-copy loads).
    pub fn list_flat_codes(&self, c: usize) -> std::borrow::Cow<'_, [u8]> {
        let list = &self.lists[c];
        if list.staging.is_empty() && !list.ids.is_empty() {
            match &list.packed {
                Some(p) => std::borrow::Cow::Owned(p.unpack()),
                None => std::borrow::Cow::Borrowed(&list.staging[..]),
            }
        } else {
            std::borrow::Cow::Borrowed(&list.staging[..])
        }
    }

    /// Rebuild from persisted parts; the result is sealed and ready to
    /// serve. The HNSW coarse graph is rebuilt from the centroids
    /// (deterministic for a fixed seed). `width`/`m` describe the fastscan
    /// layout (`pq` holds `width.code_columns(m)` internal sub-quantizers).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        dim: usize,
        params: IvfParams,
        pq_params: PqParams,
        m: usize,
        width: CodeWidth,
        pq: ProductQuantizer,
        centroids: Vec<f32>,
        lists: Vec<(Vec<i64>, Vec<u8>)>,
    ) -> Result<Self> {
        if width.code_columns(m) != pq.m {
            return Err(Error::InvalidParameter(format!(
                "{width} layout needs {} quantizer columns, PQ has {}",
                width.code_columns(m),
                pq.m
            )));
        }
        // width/codebook mismatch (corrupt or hand-edited file) must fail
        // loudly here, not return silently wrong distances at search time
        if pq.ksub != width.sub_ksub() {
            return Err(Error::InvalidParameter(format!(
                "{width} fastscan needs a K={} quantizer, file has K={}",
                width.sub_ksub(),
                pq.ksub
            )));
        }
        if lists.len() != params.nlist || centroids.len() != params.nlist * dim {
            return Err(Error::InvalidParameter("IVF parts shape mismatch".into()));
        }
        let coarse = if params.coarse_hnsw {
            let mut graph = Hnsw::new(
                dim,
                HnswParams {
                    m: params.hnsw_m,
                    ef_construction: 2 * params.hnsw_m,
                    seed: params.seed,
                },
            );
            graph.add_batch(&centroids)?;
            CoarseQuantizer::Hnsw { graph, ef_search: 0 }
        } else {
            CoarseQuantizer::Flat
        };
        let ntotal = lists.iter().map(|(ids, _)| ids.len()).sum();
        let lists = lists
            .into_iter()
            .map(|(ids, staging)| IvfList { ids, staging, packed: None })
            .collect();
        let mut index = Self {
            dim,
            params,
            pq_params,
            pq_m: m,
            width,
            pq: Some(pq),
            centroids,
            coarse,
            lists,
            ntotal,
            nprobe: 1,
            ef_default: 0,
            fastscan: FastScanParams::default(),
        };
        index.seal()?;
        Ok(index)
    }

    /// Rebuild from already-packed lists (format v3): each list adopts its
    /// packed block — heap-owned or a mapped window — without keeping (or
    /// ever materializing) flat staging columns. The result is sealed.
    #[allow(clippy::too_many_arguments)]
    pub fn from_packed_parts(
        dim: usize,
        params: IvfParams,
        pq_params: PqParams,
        m: usize,
        width: CodeWidth,
        pq: ProductQuantizer,
        centroids: Vec<f32>,
        lists: Vec<(Vec<i64>, Option<PackedCodes>)>,
    ) -> Result<Self> {
        if width.code_columns(m) != pq.m {
            return Err(Error::InvalidParameter(format!(
                "{width} layout needs {} quantizer columns, PQ has {}",
                width.code_columns(m),
                pq.m
            )));
        }
        if pq.ksub != width.sub_ksub() {
            return Err(Error::InvalidParameter(format!(
                "{width} fastscan needs a K={} quantizer, file has K={}",
                width.sub_ksub(),
                pq.ksub
            )));
        }
        if lists.len() != params.nlist || centroids.len() != params.nlist * dim {
            return Err(Error::InvalidParameter("IVF parts shape mismatch".into()));
        }
        let mut checked = Vec::with_capacity(lists.len());
        let mut ntotal = 0usize;
        for (c, (ids, packed)) in lists.into_iter().enumerate() {
            match &packed {
                Some(p) if p.n != ids.len() => {
                    return Err(Error::CorruptIndex(format!(
                        "list {c}: {} ids but packed block holds {} rows",
                        ids.len(),
                        p.n
                    )));
                }
                None if !ids.is_empty() => {
                    return Err(Error::CorruptIndex(format!(
                        "list {c}: {} ids but no packed block",
                        ids.len()
                    )));
                }
                _ => {}
            }
            ntotal += ids.len();
            checked.push(IvfList { ids, staging: Vec::new(), packed });
        }
        let coarse = if params.coarse_hnsw {
            let mut graph = Hnsw::new(
                dim,
                HnswParams {
                    m: params.hnsw_m,
                    ef_construction: 2 * params.hnsw_m,
                    seed: params.seed,
                },
            );
            graph.add_batch(&centroids)?;
            CoarseQuantizer::Hnsw { graph, ef_search: 0 }
        } else {
            CoarseQuantizer::Flat
        };
        Ok(Self {
            dim,
            params,
            pq_params,
            pq_m: m,
            width,
            pq: Some(pq),
            centroids,
            coarse,
            lists: checked,
            ntotal,
            nprobe: 1,
            ef_default: 0,
            fastscan: FastScanParams::default(),
        })
    }

    /// Occupancy histogram stats: (min, mean, max) list length.
    pub fn list_stats(&self) -> (usize, f64, usize) {
        let lens: Vec<usize> = self.lists.iter().map(|l| l.ids.len()).collect();
        let min = lens.iter().cloned().min().unwrap_or(0);
        let max = lens.iter().cloned().max().unwrap_or(0);
        let mean = if lens.is_empty() {
            0.0
        } else {
            lens.iter().sum::<usize>() as f64 / lens.len() as f64
        };
        (min, mean, max)
    }

    /// Memory cost of the packed codes, bits per vector (paper Table 1:
    /// 64 bits/code at M=16).
    pub fn code_bits_per_vector(&self) -> f64 {
        let bytes: usize = self
            .lists
            .iter()
            .filter_map(|l| l.packed.as_ref().map(|p| p.data.len()))
            .sum();
        if self.ntotal == 0 {
            0.0
        } else {
            bytes as f64 * 8.0 / self.ntotal as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Loosely clustered data: enough structure for IVF, enough noise that
    /// PQ codes are distinct (tight clusters would make every member share
    /// one code and turn recall into a tie-breaking lottery).
    fn clustered_data(n: usize, dim: usize, nclusters: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let centers: Vec<f32> = (0..nclusters * dim).map(|_| rng.next_gaussian() * 5.0).collect();
        let mut data = Vec::with_capacity(n * dim);
        for i in 0..n {
            let c = i % nclusters;
            for j in 0..dim {
                data.push(centers[c * dim + j] + rng.next_gaussian() * 2.0);
            }
        }
        data
    }

    fn brute_nn(data: &[f32], dim: usize, q: &[f32]) -> i64 {
        let n = data.len() / dim;
        let mut best = (f32::INFINITY, -1i64);
        for i in 0..n {
            let d = crate::util::l2_sq(q, &data[i * dim..(i + 1) * dim]);
            if d < best.0 {
                best = (d, i as i64);
            }
        }
        best.1
    }

    fn build(n: usize, dim: usize, nlist: usize, m: usize, hnsw: bool, seed: u64) -> (IvfPq4, Vec<f32>) {
        let data = clustered_data(n, dim, 32, seed);
        let mut params = IvfParams::new(nlist);
        params.coarse_hnsw = hnsw;
        let mut idx = IvfPq4::new(dim, params, PqParams::new_4bit(m));
        idx.train(&data).unwrap();
        idx.add(&data).unwrap();
        idx.seal().unwrap();
        (idx, data)
    }

    #[test]
    fn recall_reasonable_flat_coarse() {
        let (mut idx, data) = build(3000, 16, 20, 8, false, 61);
        idx.nprobe = 8;
        let nq = 50;
        let mut hits = 0;
        for qi in 0..nq {
            let q = &data[qi * 16..(qi + 1) * 16];
            let (_d, l) = idx.search(q, 10).unwrap();
            let gt = brute_nn(&data, 16, q);
            if l.contains(&gt) {
                hits += 1;
            }
        }
        assert!(hits >= 35, "recall@10 {hits}/50");
    }

    /// Probing every list with re-ranking must match the flat naive-PQ
    /// search (same codes, full coverage) — the strongest correctness
    /// property of the IVF composition.
    #[test]
    fn full_probe_matches_flat_pq() {
        use crate::pq::search_adc;
        let (mut idx, data) = build(1500, 16, 12, 8, false, 69);
        idx.nprobe = 12; // all lists
        idx.fastscan.reservoir_factor = 64; // tie-proof reservoir
        let pq = ProductQuantizer::train(&data, 16, &PqParams::new_4bit(8)).unwrap();
        let codes = pq.encode(&data).unwrap();
        for qi in 0..20 {
            let q = &data[qi * 16..(qi + 1) * 16];
            let luts = pq.compute_luts(q);
            let (d_flat, _) = search_adc(&pq, &luts, &codes, None, 5);
            let (d_ivf, _) = idx.search(q, 5).unwrap();
            for r in 0..5 {
                assert!(
                    (d_flat[r] - d_ivf[r]).abs() < 1e-4 * (1.0 + d_flat[r].abs()),
                    "q{qi} rank {r}: flat {} vs ivf {}",
                    d_flat[r],
                    d_ivf[r]
                );
            }
        }
    }

    #[test]
    fn hnsw_coarse_matches_flat_mostly() {
        let (mut flat, data) = build(2000, 16, 16, 8, false, 62);
        let (mut hnsw, _) = build(2000, 16, 16, 8, true, 62);
        flat.nprobe = 2;
        hnsw.nprobe = 2;
        let mut agree = 0;
        for qi in 0..30 {
            let q = &data[qi * 16..(qi + 1) * 16];
            let (_df, lf) = flat.search(q, 1).unwrap();
            let (_dh, lh) = hnsw.search(q, 1).unwrap();
            if lf[0] == lh[0] {
                agree += 1;
            }
        }
        assert!(agree >= 24, "flat/hnsw agreement {agree}/30");
    }

    #[test]
    fn nprobe_monotone_recall() {
        let (mut idx, data) = build(4000, 16, 32, 8, false, 63);
        let nq = 60;
        let mut recalls = Vec::new();
        for nprobe in [1usize, 4, 32] {
            idx.nprobe = nprobe;
            let mut hits = 0;
            for qi in 0..nq {
                let q = &data[qi * 16..(qi + 1) * 16];
                let (_d, l) = idx.search(q, 10).unwrap();
                if l.contains(&brute_nn(&data, 16, q)) {
                    hits += 1;
                }
            }
            recalls.push(hits);
        }
        assert!(
            recalls[0] <= recalls[1] + 3 && recalls[1] <= recalls[2] + 3,
            "roughly monotone expected: {recalls:?}"
        );
        assert!(recalls[2] >= 40, "nprobe=32 recall {}/60", recalls[2]);
    }

    #[test]
    fn untrained_errors() {
        let mut idx = IvfPq4::new(8, IvfParams::new(4), PqParams::new_4bit(2));
        assert!(idx.add(&[0.0; 8]).is_err());
        assert!(idx.search(&[0.0; 8], 1).is_err());
    }

    /// Every code width composes with IVF: probing every list with
    /// re-ranking must match the flat exact-ADC scan over the same codes
    /// (tie-proof — both rank by the identical per-code exact distance),
    /// and the code memory scales with the width.
    #[test]
    fn all_widths_compose_with_ivf() {
        use crate::pq::search_adc;
        let data = clustered_data(1200, 16, 32, 71);
        for width in CodeWidth::ALL {
            let mut idx = IvfPq4::new_width(16, IvfParams::new(6), 8, width);
            idx.train(&data).unwrap();
            idx.add(&data).unwrap();
            idx.seal().unwrap();
            idx.nprobe = 6;
            idx.fastscan.reservoir_factor = 64;
            // flat reference over the same internal quantizer + codes
            let pq = idx.pq.as_ref().unwrap();
            let codes = pq.encode(&data).unwrap();
            for qi in 0..8 {
                let q = &data[qi * 16..(qi + 1) * 16];
                let luts = pq.compute_luts(q);
                let (d_flat, _) = search_adc(pq, &luts, &codes, None, 5);
                let (d_ivf, l) = idx.search(q, 5).unwrap();
                assert_eq!(l.len(), 5, "{width}");
                assert!(d_ivf.windows(2).all(|w| w[0] <= w[1]), "{width}: unsorted {d_ivf:?}");
                for r in 0..5 {
                    assert!(
                        (d_flat[r] - d_ivf[r]).abs() < 1e-4 * (1.0 + d_flat[r].abs()),
                        "{width} q{qi} rank {r}: flat {} vs ivf {}",
                        d_flat[r],
                        d_ivf[r]
                    );
                }
            }
            let bits = idx.code_bits_per_vector();
            let want = (width.bits() * 8) as f64; // m = 8
            assert!(
                bits >= want && bits < want * 1.4,
                "{width}: bits/vec {bits} (want ≈ {want})"
            );
        }
    }

    /// Precomputed-LUT search (the coordinator's batch-level reuse entry)
    /// must return bit-identical results to the self-computing path.
    #[test]
    fn search_with_luts_matches_search_with() {
        let (mut idx, data) = build(1500, 16, 10, 8, false, 72);
        idx.nprobe = 4;
        let queries = &data[..5 * 16];
        let luts = idx.compute_scan_luts(queries).unwrap();
        let (d0, l0) = idx.search_with(queries, 6, 4, None, &idx.fastscan).unwrap();
        let (d1, l1) =
            idx.search_with_luts(queries, &luts, 6, 4, None, &idx.fastscan).unwrap();
        assert_eq!(l0, l1);
        assert_eq!(d0, d1);
        // wrong-sized LUTs are rejected, not misread
        assert!(idx
            .search_with_luts(queries, &luts[..luts.len() - 1], 6, 4, None, &idx.fastscan)
            .is_err());
    }

    /// Full-probe filtered query ≡ unfiltered-query-then-post-filter,
    /// bit-identical (at nprobe = nlist both paths see every list, so the
    /// per-list mask pushdown is the only difference under test).
    #[test]
    fn filtered_query_full_probe_matches_postfilter() {
        let (mut idx, data) = build(1500, 16, 10, 8, false, 75);
        idx.fastscan.reservoir_factor = 8; // k below makes capacity >= n anyway
        let queries = &data[..6 * 16];
        let filter = Filter::id_range(200, 700);
        let fs = idx.fastscan.clone();
        // ask for the COMPLETE admitted set (k = admitted count) so the
        // comparison is insensitive to tie-breaking at a k boundary: both
        // sides are full sets sorted by (distance, label)
        let (filtered, stats) = idx
            .query_with(queries, &QueryKind::TopK { k: 500 }, Some(&filter), 10, None, &fs)
            .unwrap();
        let (full, _) = idx
            .query_with(queries, &QueryKind::TopK { k: 1500 }, None, 10, None, &fs)
            .unwrap();
        for qi in 0..6 {
            let want: Vec<Hit> = full[qi]
                .iter()
                .filter(|h| filter.matches(h.label))
                .copied()
                .collect();
            assert_eq!(filtered[qi], want, "q{qi}");
            let st = &stats[qi];
            assert_eq!(st.lists_probed, 10, "q{qi}");
            assert_eq!(st.codes_scanned, 1500, "q{qi}");
            assert!((st.filter_selectivity - 500.0 / 1500.0).abs() < 1e-9, "q{qi}");
        }
    }

    /// Selectivity-aware nprobe escalation: a 10%-selective filter widens
    /// the probe (capped at nlist), an opaque predicate does not.
    #[test]
    fn selective_filters_escalate_nprobe() {
        let (idx, _) = build(2000, 16, 16, 8, false, 76);
        let sparse = Filter::id_range(0, 200); // 10% of 2000
        assert_eq!(idx.escalated_nprobe(2, Some(&sparse)), 16); // 2/0.1=20 → nlist cap
        let half = Filter::id_range(0, 1000);
        assert_eq!(idx.escalated_nprobe(2, Some(&half)), 4);
        let opaque = Filter::predicate(|_| true);
        assert_eq!(idx.escalated_nprobe(2, Some(&opaque)), 2);
        assert_eq!(idx.escalated_nprobe(2, None), 2);
        // the 16× escalation cap binds before nlist when nprobe is tiny
        let needle = Filter::id_set(&[3]);
        assert_eq!(idx.escalated_nprobe(1, Some(&needle)), 16.min(idx.params.nlist));
        // and escalation actually finds a selective needle: id 0 lives in
        // exactly one list, but a 1-probe query for a far-away centroid
        // must still find it once the filter narrows the target set
        let origin = [0.0f32; 16];
        let (hits, _) = idx
            .query_with(
                &origin,
                &QueryKind::TopK { k: 1 },
                Some(&Filter::id_set(&[7])),
                1,
                None,
                &idx.fastscan,
            )
            .unwrap();
        assert_eq!(hits[0].first().map(|h| h.label), Some(7));
    }

    /// Provably-empty filters answer without probing.
    #[test]
    fn empty_filter_short_circuits() {
        let (idx, data) = build(800, 16, 8, 4, false, 77);
        let (hits, stats) = idx
            .query_with(
                &data[..16],
                &QueryKind::TopK { k: 5 },
                Some(&Filter::id_range(10, 10)),
                8,
                None,
                &idx.fastscan,
            )
            .unwrap();
        assert!(hits[0].is_empty());
        assert_eq!(stats[0].lists_probed, 0);
        assert_eq!(stats[0].filter_selectivity, 0.0);
    }

    /// IVF range queries: at full probe with re-ranking, hits are exactly
    /// the ids whose exact ADC distance is within the radius.
    #[test]
    fn range_query_full_probe_exact() {
        use crate::pq::adc::adc_distances_all;
        let (mut idx, data) = build(1000, 16, 8, 8, false, 78);
        idx.fastscan.reservoir_factor = 64;
        let pq = idx.pq.as_ref().unwrap();
        let codes = pq.encode(&data).unwrap();
        let q = &data[..16];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(pq, &luts, &codes);
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let radius = sorted[30];
        let (hits, stats) = idx
            .query_with(q, &QueryKind::Range { radius }, None, 8, None, &idx.fastscan)
            .unwrap();
        let want = all.iter().filter(|&&d| d <= radius).count();
        assert_eq!(hits[0].len(), want);
        assert!(hits[0].windows(2).all(|w| w[0].distance <= w[1].distance));
        for h in &hits[0] {
            assert!((h.distance - all[h.label as usize]).abs() < 1e-6);
        }
        assert_eq!(stats[0].codes_scanned, 1000);
    }

    /// Regression: probing an EMPTY inverted list must hand back an empty
    /// candidate set — the recycled per-list scan storage previously
    /// leaked the preceding list's candidates under the empty list's id
    /// (panicking re-rank or mislabeling hits), and only on the serial
    /// schedule, which also broke thread-count determinism.
    #[test]
    fn empty_probed_lists_yield_no_candidates() {
        use crate::exec::QueryExecutor;
        let data = clustered_data(600, 16, 4, 80);
        let mut idx = IvfPq4::new(16, IvfParams::new(12), PqParams::new_4bit(4));
        idx.train(&data).unwrap();
        // add only cluster 0's members: most of the 12 lists stay empty
        let subset: Vec<f32> = (0..600)
            .filter(|i| i % 4 == 0)
            .flat_map(|i| data[i * 16..(i + 1) * 16].to_vec())
            .collect();
        idx.add(&subset).unwrap();
        idx.seal().unwrap();
        let q = &data[..16];
        let fs = idx.fastscan.clone();
        let kind = QueryKind::TopK { k: 10 };
        // serial schedule (1 thread → per-list loop on recycled storage)
        let exec1 = QueryExecutor::new(1);
        let (hits1, stats) = idx
            .query_exec_with(q, None, &kind, None, 12, None, &fs, &exec1)
            .unwrap();
        assert_eq!(stats[0].lists_probed, 12);
        assert!(!hits1[0].is_empty() && hits1[0].len() <= 10);
        // every label comes from the 150 vectors actually added
        assert!(hits1[0].iter().all(|h| (0..150).contains(&h.label)), "{:?}", hits1[0]);
        // intra-query parallel schedule agrees bit for bit
        let exec4 = QueryExecutor::new(4);
        let (hits4, _) = idx
            .query_exec_with(q, None, &kind, None, 12, None, &fs, &exec4)
            .unwrap();
        assert_eq!(hits1, hits4, "empty-list handling differs between schedules");
        // range kind exercises the same storage recycling
        let (rhits1, _) = idx
            .query_exec_with(q, None, &QueryKind::Range { radius: 1e9 }, None, 12, None, &fs, &exec1)
            .unwrap();
        let (rhits4, _) = idx
            .query_exec_with(q, None, &QueryKind::Range { radius: 1e9 }, None, 12, None, &fs, &exec4)
            .unwrap();
        assert_eq!(rhits1[0].len(), 150, "range over all added vectors");
        assert_eq!(rhits1, rhits4);
    }

    #[test]
    fn incremental_add_requires_reseal() {
        let (mut idx, data) = build(1000, 16, 8, 4, false, 64);
        let (_, _) = idx.search(&data[..16], 1).unwrap();
        // add more: the index is dirty again and must refuse to search
        let extra = clustered_data(64, 16, 32, 65);
        idx.add(&extra).unwrap();
        assert_eq!(idx.ntotal(), 1064);
        assert!(!idx.is_sealed());
        assert!(matches!(idx.search(&extra[..16], 1), Err(crate::Error::NotSealed)));
        idx.seal().unwrap();
        let (_d, l) = idx.search(&extra[..16], 1).unwrap();
        assert!(l[0] >= 0);
    }

    #[test]
    fn per_request_overrides_beat_defaults() {
        let (idx, data) = build(2000, 16, 16, 8, false, 70);
        // defaults: nprobe=1; explicit wide probe must cover all lists
        let wide = FastScanParams { reservoir_factor: 64, ..idx.fastscan.clone() };
        let q = &data[..16];
        let (_d1, _l1) = idx.search(q, 5).unwrap();
        let (_d2, l2) = idx.search_with(q, 5, 16, None, &wide).unwrap();
        // the wide search finds the true nearest (query = base row 0)
        assert!(l2.contains(&0), "full probe missed exact match: {l2:?}");
        // defaults untouched
        assert_eq!(idx.nprobe, 1);
    }

    #[test]
    fn external_ids_respected() {
        let data = clustered_data(500, 16, 8, 66);
        let mut idx = IvfPq4::new(16, IvfParams::new(4), PqParams::new_4bit(4));
        idx.train(&data).unwrap();
        let ids: Vec<i64> = (0..500).map(|i| 10_000 + i).collect();
        idx.add_with_ids(&data, &ids).unwrap();
        idx.seal().unwrap();
        let (_d, l) = idx.search(&data[..16], 5).unwrap();
        assert!(l.iter().all(|&x| x >= 10_000));
    }

    #[test]
    fn code_memory_matches_paper_formula() {
        // M=16, K=16 → 64 bits/code (paper Table 1), modulo block padding
        let (mut idx, _) = build(3200, 16, 4, 16, false, 67);
        idx.seal().unwrap();
        let bits = idx.code_bits_per_vector();
        assert!(bits >= 64.0 && bits < 70.0, "bits/vector {bits}");
    }

    #[test]
    fn list_stats_sane() {
        let (mut idx, _) = build(1000, 16, 10, 4, false, 68);
        idx.seal().unwrap();
        let (min, mean, max) = idx.list_stats();
        assert!(min <= mean as usize && mean as usize <= max);
        assert_eq!(
            (mean * 10.0).round() as usize,
            1000
        );
    }
}
