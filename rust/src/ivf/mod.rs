//! Inverted-file index with 4-bit PQ distance estimation (paper §4, §5.2).
//!
//! The dataset is partitioned into `nlist` cells by a coarse k-means
//! quantizer; a query probes the `nprobe` nearest cells and runs the
//! fastscan kernel over each cell's packed codes. Coarse assignment is
//! either a linear scan over the centroids ([`CoarseQuantizer::Flat`]) or
//! an HNSW graph walk ([`CoarseQuantizer::Hnsw`]) — the combination
//! "inverted index + HNSW + PQ" evaluated in the paper's Table 1.
//!
//! Distance estimation follows faiss `IVFPQFastScan` defaults:
//! `by_residual = false`, i.e. the PQ codes encode raw vectors and one LUT
//! set (built once per query from the full query vector) is shared across
//! all probed cells.

use crate::hnsw::{Hnsw, HnswParams};
use crate::index::query::{Filter, Hit, QueryKind, QueryStats};
use crate::kmeans::{KMeans, KMeansParams};
use crate::pq::bitwidth::build_width_luts;
use crate::pq::fastscan::{scan_filtered, FastScanParams, FilterMask, ScanSink};
use crate::pq::{CodeWidth, PackedCodes, PqParams, ProductQuantizer};
use crate::util::topk::{TopK, U16Reservoir};
use crate::{Error, Result};
use std::collections::HashMap;

/// Strategy for the coarse (cell-assignment) search.
pub enum CoarseQuantizer {
    /// Exact linear scan over centroids.
    Flat,
    /// HNSW graph over the centroids (paper §5.2; ef defaults to 4×nprobe).
    Hnsw { graph: Hnsw, ef_search: usize },
}

impl CoarseQuantizer {
    /// `nprobe` nearest centroids, ascending by distance. `ef_override`
    /// (per-request) replaces the stored HNSW candidate-list width.
    fn assign(
        &self,
        centroids: &[f32],
        nlist: usize,
        dim: usize,
        q: &[f32],
        nprobe: usize,
        ef_override: Option<usize>,
    ) -> Vec<usize> {
        match self {
            CoarseQuantizer::Flat => {
                let mut heap = TopK::new(nprobe.min(nlist));
                for c in 0..nlist {
                    let d = crate::util::l2_sq(q, &centroids[c * dim..(c + 1) * dim]);
                    heap.push(d, c as i64);
                }
                heap.into_sorted().1.into_iter().filter(|&l| l >= 0).map(|l| l as usize).collect()
            }
            CoarseQuantizer::Hnsw { graph, ef_search } => {
                // same resolution for both surfaces (stored default and
                // per-request override): the 4×nprobe auto floor applies
                // either way, so shim-set and per-request ef_search agree
                let ef = ef_override.unwrap_or(*ef_search).max(4 * nprobe);
                let (_d, ids) = graph.search(q, nprobe, ef);
                ids.into_iter().filter(|&l| l >= 0).map(|l| l as usize).collect()
            }
        }
    }
}

/// One inverted list: external ids + packed codes (width-parametric).
struct IvfList {
    ids: Vec<i64>,
    /// Flat codes retained during building; dropped at seal time.
    staging: Vec<u8>,
    packed: Option<PackedCodes>,
}

impl IvfList {
    fn new() -> Self {
        Self { ids: Vec::new(), staging: Vec::new(), packed: None }
    }
}

/// Build-time parameters for [`IvfPq4`].
#[derive(Clone, Debug)]
pub struct IvfParams {
    pub nlist: usize,
    /// Use an HNSW graph over centroids for coarse assignment.
    pub coarse_hnsw: bool,
    pub hnsw_m: usize,
    pub train_iters: usize,
    pub seed: u64,
}

impl IvfParams {
    pub fn new(nlist: usize) -> Self {
        Self { nlist, coarse_hnsw: false, hnsw_m: 32, train_iters: 20, seed: 99 }
    }
}

/// IVF + PQ fastscan index (the paper's large-scale configuration),
/// width-parametric: the fastscan kernel runs at 2-, 4- or 8-bit codes
/// ([`CodeWidth`]). The type keeps its historical `…Pq4` name — 4-bit is
/// the paper's (and the default) operating point.
pub struct IvfPq4 {
    pub dim: usize,
    pub params: IvfParams,
    /// Internal quantizer parameters (`width.pq_params(pq_m)`; for 8-bit
    /// this trains `2 × pq_m` half-space sub-quantizers).
    pub pq_params: PqParams,
    /// User-facing sub-quantizers per vector.
    pub pq_m: usize,
    /// Fastscan code width.
    pub width: CodeWidth,
    pub pq: Option<ProductQuantizer>,
    centroids: Vec<f32>,
    coarse: CoarseQuantizer,
    lists: Vec<IvfList>,
    ntotal: usize,
    /// Default search width (paper Table 1 sweeps 1, 2, 4); per-request
    /// values passed to [`IvfPq4::search_with`] override it per call.
    pub nprobe: usize,
    /// Default HNSW coarse candidate-list width (0 = auto: 4×nprobe).
    /// Carried here so it survives being set before `train()` builds the
    /// coarse graph; [`IvfPq4::set_ef_search`] keeps both in sync.
    ef_default: usize,
    /// Default kernel parameters (overridden per call the same way).
    pub fastscan: FastScanParams,
}

impl IvfPq4 {
    /// 4-bit constructor (the paper's configuration). `pq_params` must be a
    /// `K = 16` parameter set; use [`IvfPq4::new_width`] for 2-/8-bit.
    pub fn new(dim: usize, params: IvfParams, pq_params: PqParams) -> Self {
        let pq_m = pq_params.m;
        Self {
            dim,
            params,
            pq_params,
            pq_m,
            width: CodeWidth::W4,
            pq: None,
            centroids: Vec::new(),
            coarse: CoarseQuantizer::Flat,
            lists: Vec::new(),
            ntotal: 0,
            nprobe: 1,
            ef_default: 0,
            fastscan: FastScanParams::default(),
        }
    }

    /// Width-parametric constructor: `m` user-facing sub-quantizers scanned
    /// at `width` bits per code.
    pub fn new_width(dim: usize, params: IvfParams, m: usize, width: CodeWidth) -> Self {
        let mut index = Self::new(dim, params, width.pq_params(m));
        index.pq_m = m;
        index.width = width;
        index
    }

    pub fn is_trained(&self) -> bool {
        self.pq.is_some()
    }

    pub fn ntotal(&self) -> usize {
        self.ntotal
    }

    /// Train coarse quantizer + PQ codebooks on `n × dim` vectors.
    pub fn train(&mut self, data: &[f32]) -> Result<()> {
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        self.width.validate(self.dim, self.pq_m)?;
        let mut kp = KMeansParams::new(self.params.nlist);
        kp.iters = self.params.train_iters;
        kp.seed = self.params.seed;
        let km = KMeans::train(data, self.dim, &kp)?;
        self.centroids = km.centroids.clone();

        // PQ trained on raw vectors (by_residual = false).
        self.pq = Some(ProductQuantizer::train(data, self.dim, &self.pq_params)?);

        // Coarse structure over the centroids.
        self.coarse = if self.params.coarse_hnsw {
            let mut graph = Hnsw::new(
                self.dim,
                HnswParams {
                    m: self.params.hnsw_m,
                    ef_construction: 2 * self.params.hnsw_m,
                    seed: self.params.seed,
                },
            );
            graph.add_batch(&self.centroids)?;
            CoarseQuantizer::Hnsw { graph, ef_search: self.ef_default }
        } else {
            CoarseQuantizer::Flat
        };

        self.lists = (0..self.params.nlist).map(|_| IvfList::new()).collect();
        Ok(())
    }

    /// Add vectors with sequential ids.
    pub fn add(&mut self, data: &[f32]) -> Result<()> {
        let start = self.ntotal as i64;
        let n = data.len() / self.dim;
        let ids: Vec<i64> = (start..start + n as i64).collect();
        self.add_with_ids(data, &ids)
    }

    /// Add vectors with explicit external ids.
    pub fn add_with_ids(&mut self, data: &[f32], ids: &[i64]) -> Result<()> {
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        let n = data.len() / self.dim;
        if ids.len() != n {
            return Err(Error::InvalidParameter(format!("{} ids for {n} vectors", ids.len())));
        }
        // coarse-assign + encode
        let assign: Vec<u32> = {
            let nlist = self.params.nlist;
            let dim = self.dim;
            let cents = &self.centroids;
            crate::util::threads::parallel_map(n, crate::util::threads::default_threads(), |i| {
                crate::kmeans::nearest_centroid(&data[i * dim..(i + 1) * dim], cents, nlist, dim)
                    .0 as u32
            })
        };
        let codes = pq.encode(data)?;
        let m = pq.m;
        for i in 0..n {
            let list = &mut self.lists[assign[i] as usize];
            list.ids.push(ids[i]);
            list.staging.extend_from_slice(&codes[i * m..(i + 1) * m]);
            list.packed = None; // invalidate packing
        }
        self.ntotal += n;
        Ok(())
    }

    /// Pack any dirty lists — ends the build phase. Idempotent: sealing an
    /// already-sealed index is a no-op.
    pub fn seal(&mut self) -> Result<()> {
        self.pq.as_ref().ok_or(Error::NotTrained)?;
        for list in &mut self.lists {
            if list.packed.is_none() && !list.ids.is_empty() {
                list.packed = Some(PackedCodes::pack(&list.staging, self.pq_m, self.width)?);
            }
        }
        Ok(())
    }

    /// Whether every non-empty list is packed (searchable without reseal).
    pub fn is_sealed(&self) -> bool {
        self.lists.iter().all(|l| l.packed.is_some() || l.ids.is_empty())
    }

    /// Set the default HNSW coarse candidate-list width (0 = auto:
    /// 4×nprobe). Takes effect whether called before or after `train()`;
    /// meaningless (but harmless) with a flat coarse quantizer.
    pub fn set_ef_search(&mut self, ef: usize) {
        self.ef_default = ef;
        if let CoarseQuantizer::Hnsw { ef_search, .. } = &mut self.coarse {
            *ef_search = ef;
        }
    }

    /// Search a batch of queries (`nq × dim`) with the index's default
    /// parameters, returning `(distances, labels)` each `nq × k`.
    ///
    /// Read-only: the index must be sealed ([`IvfPq4::seal`]) — searching
    /// with unpacked staged codes returns [`Error::NotSealed`] instead of
    /// silently repacking.
    pub fn search(&self, queries: &[f32], k: usize) -> Result<(Vec<f32>, Vec<i64>)> {
        self.search_with(queries, k, self.nprobe, None, &self.fastscan)
    }

    /// [`IvfPq4::search`] with explicit per-request parameters: probe
    /// width, optional HNSW candidate-list width, and kernel parameters.
    /// A flattened-and-padded wrapper over the [`IvfPq4::query_with`]
    /// machinery (top-k, unfiltered).
    pub fn search_with(
        &self,
        queries: &[f32],
        k: usize,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let (rows, _stats) = self.query_impl(
            queries,
            None,
            &QueryKind::TopK { k },
            None,
            nprobe,
            ef_search,
            fastscan,
        )?;
        Ok(Self::flatten_padded(rows, k, queries.len() / self.dim.max(1)))
    }

    /// [`IvfPq4::search_with`] with precomputed per-query f32 LUTs
    /// (`nq × lut_len`, from [`IvfPq4::compute_scan_luts`] of an index with
    /// the same trained quantizer) — the batch-level LUT-reuse entry the
    /// coordinator uses so one LUT build serves a whole shard fan-out.
    pub fn search_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        k: usize,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<f32>, Vec<i64>)> {
        let (rows, _stats) = self.query_impl(
            queries,
            Some(luts),
            &QueryKind::TopK { k },
            None,
            nprobe,
            ef_search,
            fastscan,
        )?;
        Ok(Self::flatten_padded(rows, k, queries.len() / self.dim.max(1)))
    }

    /// The typed query entry: top-k or range, optionally filtered, with
    /// explicit runtime parameters. Returns per-query variable-length hits
    /// plus per-query stats.
    #[allow(clippy::too_many_arguments)]
    pub fn query_with(
        &self,
        queries: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>)> {
        self.query_impl(queries, None, kind, filter, nprobe, ef_search, fastscan)
    }

    /// [`IvfPq4::query_with`] with precomputed per-query f32 LUTs.
    #[allow(clippy::too_many_arguments)]
    pub fn query_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>)> {
        self.query_impl(queries, Some(luts), kind, filter, nprobe, ef_search, fastscan)
    }

    /// Per-query f32 scan LUTs (`nq × m_codes × sub_ksub`), shareable with
    /// any index whose trained quantizer is identical.
    pub fn compute_scan_luts(&self, queries: &[f32]) -> Result<Vec<f32>> {
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: queries.len() % self.dim });
        }
        Ok(pq.compute_luts_batch(queries))
    }

    fn flatten_padded(rows: Vec<Vec<Hit>>, k: usize, nq: usize) -> (Vec<f32>, Vec<i64>) {
        if k == 0 || nq == 0 {
            return (Vec::new(), Vec::new());
        }
        let mut dists = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        for row in rows {
            let (d, l) = crate::index::query::pad_hits(&row, k);
            dists.extend(d);
            labels.extend(l);
        }
        (dists, labels)
    }

    /// Selectivity-aware probe escalation: a filter that admits a fraction
    /// `sel` of the corpus thins every probed list by the same factor, so
    /// the probe width scales by `1/sel` to keep the expected candidate
    /// count — capped at 16× the requested width and at `nlist` (full
    /// probe). Opaque filters (predicates) don't escalate: their
    /// selectivity is unknowable without scanning.
    fn escalated_nprobe(&self, nprobe: usize, filter: Option<&Filter>) -> usize {
        let Some(hint) = filter.and_then(|f| f.selectivity_hint(self.ntotal)) else {
            return nprobe;
        };
        if hint <= 0.0 || hint >= 1.0 {
            return nprobe;
        }
        let scaled = (nprobe as f64 / hint).ceil() as usize;
        scaled.min(nprobe.saturating_mul(16)).min(self.params.nlist).max(nprobe)
    }

    #[allow(clippy::too_many_arguments)]
    fn query_impl(
        &self,
        queries: &[f32],
        luts: Option<&[f32]>,
        kind: &QueryKind,
        filter: Option<&Filter>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> Result<(Vec<Vec<Hit>>, Vec<QueryStats>)> {
        kind.validate()?;
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: queries.len() % self.dim });
        }
        let nq = queries.len() / self.dim;
        let lut_len = pq.m * pq.ksub;
        if let Some(ls) = luts {
            if ls.len() != nq * lut_len {
                return Err(Error::InvalidParameter(format!(
                    "precomputed luts length {} != nq {nq} × {lut_len}",
                    ls.len()
                )));
            }
        }
        if nq == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        if self.ntotal == 0 || matches!(kind, QueryKind::TopK { k: 0 }) {
            return Ok((vec![Vec::new(); nq], vec![QueryStats::default(); nq]));
        }
        if !self.is_sealed() {
            return Err(Error::NotSealed);
        }
        // a provably-empty filter answers without probing anything
        if filter.is_some_and(|f| f.is_provably_empty()) {
            let stats = QueryStats { codes_scanned: 0, lists_probed: 0, filter_selectivity: 0.0 };
            return Ok((vec![Vec::new(); nq], vec![stats; nq]));
        }
        let nprobe = self.escalated_nprobe(nprobe.max(1), filter);
        // per-list filter mask slices, built lazily once per *call* (they
        // depend on the filter, not the query) and shared across the batch
        let mut list_masks: HashMap<usize, FilterMask> = HashMap::new();
        let mut hits = Vec::with_capacity(nq);
        let mut stats = Vec::with_capacity(nq);
        let mut luts_buf = Vec::new();
        for qi in 0..nq {
            let q = &queries[qi * self.dim..(qi + 1) * self.dim];
            let luts_f32 = match luts {
                Some(ls) => &ls[qi * lut_len..(qi + 1) * lut_len],
                None => {
                    luts_buf = pq.compute_luts(q);
                    &luts_buf[..]
                }
            };
            let (row, st) = self.query_one(
                pq,
                q,
                luts_f32,
                kind,
                filter,
                &mut list_masks,
                nprobe,
                ef_search,
                fastscan,
            );
            hits.push(row);
            stats.push(st);
        }
        Ok((hits, stats))
    }

    #[allow(clippy::too_many_arguments)]
    fn query_one(
        &self,
        pq: &ProductQuantizer,
        q: &[f32],
        luts_f32: &[f32],
        kind: &QueryKind,
        filter: Option<&Filter>,
        list_masks: &mut HashMap<usize, FilterMask>,
        nprobe: usize,
        ef_search: Option<usize>,
        fastscan: &FastScanParams,
    ) -> (Vec<Hit>, QueryStats) {
        // 1. coarse quantization (paper §4 step 1-2)
        let probes =
            self.coarse.assign(&self.centroids, self.params.nlist, self.dim, q, nprobe, ef_search);

        // 2. one LUT set shared across probed lists (by_residual = false),
        //    quantized/fused per the index's code width
        let wl = build_width_luts(luts_f32, self.pq_m, self.width);
        let (qluts, kluts) = (wl.qluts, wl.kernel);

        // 3. fastscan distance estimation over each probed list, with the
        //    filter sliced into a per-list position mask
        let mut considered = 0usize;
        let mut passed = 0usize;
        let mut scan_list = |sink: &mut ScanSink<'_>| {
            for &c in &probes {
                let list = &self.lists[c];
                let Some(packed) = &list.packed else { continue };
                considered += list.ids.len();
                let mask: Option<&FilterMask> = match filter {
                    Some(f) => {
                        let m = list_masks
                            .entry(c)
                            .or_insert_with(|| f.build_mask(Some(&list.ids), list.ids.len()));
                        Some(m)
                    }
                    None => None,
                };
                passed += mask.map(|m| m.pass_count()).unwrap_or(list.ids.len());
                scan_filtered(packed, &kluts, fastscan.backend, Some(&list.ids), mask, sink);
            }
        };
        let cands: Vec<(u16, i64)> = match kind {
            QueryKind::TopK { k } => {
                let mut reservoir = U16Reservoir::new(*k, fastscan.reservoir_factor);
                {
                    let mut sink = ScanSink::TopK(&mut reservoir);
                    scan_list(&mut sink);
                }
                reservoir.into_candidates()
            }
            QueryKind::Range { radius } => {
                let bound = qluts.collection_bound(*radius, fastscan.rerank);
                let mut raw = Vec::new();
                {
                    let mut sink = ScanSink::Range { bound, hits: &mut raw };
                    scan_list(&mut sink);
                }
                raw
            }
        };
        let st = QueryStats {
            codes_scanned: considered,
            lists_probed: probes.len(),
            filter_selectivity: if filter.is_some() && considered > 0 {
                passed as f64 / considered as f64
            } else {
                1.0
            },
        };

        // 4. re-rank with exact f32 tables; candidates are addressed by
        //    external id, located through a per-search map over probed lists
        let exact = |pos_map: &HashMap<i64, (usize, usize)>,
                     codes_buf: &mut [u8],
                     d16: u16,
                     id: i64| {
            // Every candidate id comes from a probed list, so the map
            // covers it; duplicate external ids collapse to one position,
            // which re-ranks one representative of the duplicate set —
            // defensible, and never a panic. Fall back to the decoded
            // coarse distance if an id is missing.
            match pos_map.get(&id) {
                Some(&(c, j)) => {
                    let packed = self.lists[c].packed.as_ref().unwrap();
                    for (mi, slot) in codes_buf.iter_mut().enumerate() {
                        *slot = packed.code_at(j, mi);
                    }
                    pq.adc_distance(luts_f32, codes_buf)
                }
                None => qluts.decode(d16),
            }
        };
        let pos_map: Option<HashMap<i64, (usize, usize)>> = fastscan.rerank.then(|| {
            let mut map = HashMap::new();
            for &c in &probes {
                for (j, &id) in self.lists[c].ids.iter().enumerate() {
                    map.insert(id, (c, j));
                }
            }
            map
        });
        let row: Vec<Hit> = match kind {
            QueryKind::TopK { k } => {
                let mut heap = TopK::new(*k);
                match &pos_map {
                    Some(map) => {
                        let mut codes_buf = vec![0u8; pq.m];
                        for (d16, id) in cands {
                            heap.push(exact(map, &mut codes_buf, d16, id), id);
                        }
                    }
                    None => {
                        for (d16, id) in cands {
                            heap.push(qluts.decode(d16), id);
                        }
                    }
                }
                heap.into_hits()
                    .into_iter()
                    .map(|(distance, label)| Hit { distance, label })
                    .collect()
            }
            QueryKind::Range { radius } => {
                let mut out: Vec<(f32, i64)> = match &pos_map {
                    Some(map) => {
                        let mut codes_buf = vec![0u8; pq.m];
                        cands
                            .into_iter()
                            .map(|(d16, id)| (exact(map, &mut codes_buf, d16, id), id))
                            .filter(|&(d, _)| d <= *radius)
                            .collect()
                    }
                    None => cands.into_iter().map(|(d16, id)| (qluts.decode(d16), id)).collect(),
                };
                out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                out.into_iter().map(|(distance, label)| Hit { distance, label }).collect()
            }
        };
        (row, st)
    }

    /// Coarse centroids (`nlist × dim`) — persistence accessor.
    pub fn centroids(&self) -> &[f32] {
        &self.centroids
    }

    /// Ids + flat staging codes of one list — persistence accessor.
    /// (Lists keep their flat codes alongside the packed form.)
    pub fn list_contents(&self, c: usize) -> (&[i64], &[u8]) {
        (&self.lists[c].ids, &self.lists[c].staging)
    }

    /// Rebuild from persisted parts; the result is sealed and ready to
    /// serve. The HNSW coarse graph is rebuilt from the centroids
    /// (deterministic for a fixed seed). `width`/`m` describe the fastscan
    /// layout (`pq` holds `width.code_columns(m)` internal sub-quantizers).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        dim: usize,
        params: IvfParams,
        pq_params: PqParams,
        m: usize,
        width: CodeWidth,
        pq: ProductQuantizer,
        centroids: Vec<f32>,
        lists: Vec<(Vec<i64>, Vec<u8>)>,
    ) -> Result<Self> {
        if width.code_columns(m) != pq.m {
            return Err(Error::InvalidParameter(format!(
                "{width} layout needs {} quantizer columns, PQ has {}",
                width.code_columns(m),
                pq.m
            )));
        }
        // width/codebook mismatch (corrupt or hand-edited file) must fail
        // loudly here, not return silently wrong distances at search time
        if pq.ksub != width.sub_ksub() {
            return Err(Error::InvalidParameter(format!(
                "{width} fastscan needs a K={} quantizer, file has K={}",
                width.sub_ksub(),
                pq.ksub
            )));
        }
        if lists.len() != params.nlist || centroids.len() != params.nlist * dim {
            return Err(Error::InvalidParameter("IVF parts shape mismatch".into()));
        }
        let coarse = if params.coarse_hnsw {
            let mut graph = Hnsw::new(
                dim,
                HnswParams {
                    m: params.hnsw_m,
                    ef_construction: 2 * params.hnsw_m,
                    seed: params.seed,
                },
            );
            graph.add_batch(&centroids)?;
            CoarseQuantizer::Hnsw { graph, ef_search: 0 }
        } else {
            CoarseQuantizer::Flat
        };
        let ntotal = lists.iter().map(|(ids, _)| ids.len()).sum();
        let lists = lists
            .into_iter()
            .map(|(ids, staging)| IvfList { ids, staging, packed: None })
            .collect();
        let mut index = Self {
            dim,
            params,
            pq_params,
            pq_m: m,
            width,
            pq: Some(pq),
            centroids,
            coarse,
            lists,
            ntotal,
            nprobe: 1,
            ef_default: 0,
            fastscan: FastScanParams::default(),
        };
        index.seal()?;
        Ok(index)
    }

    /// Occupancy histogram stats: (min, mean, max) list length.
    pub fn list_stats(&self) -> (usize, f64, usize) {
        let lens: Vec<usize> = self.lists.iter().map(|l| l.ids.len()).collect();
        let min = lens.iter().cloned().min().unwrap_or(0);
        let max = lens.iter().cloned().max().unwrap_or(0);
        let mean = if lens.is_empty() {
            0.0
        } else {
            lens.iter().sum::<usize>() as f64 / lens.len() as f64
        };
        (min, mean, max)
    }

    /// Memory cost of the packed codes, bits per vector (paper Table 1:
    /// 64 bits/code at M=16).
    pub fn code_bits_per_vector(&self) -> f64 {
        let bytes: usize = self
            .lists
            .iter()
            .filter_map(|l| l.packed.as_ref().map(|p| p.data.len()))
            .sum();
        if self.ntotal == 0 {
            0.0
        } else {
            bytes as f64 * 8.0 / self.ntotal as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Loosely clustered data: enough structure for IVF, enough noise that
    /// PQ codes are distinct (tight clusters would make every member share
    /// one code and turn recall into a tie-breaking lottery).
    fn clustered_data(n: usize, dim: usize, nclusters: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let centers: Vec<f32> = (0..nclusters * dim).map(|_| rng.next_gaussian() * 5.0).collect();
        let mut data = Vec::with_capacity(n * dim);
        for i in 0..n {
            let c = i % nclusters;
            for j in 0..dim {
                data.push(centers[c * dim + j] + rng.next_gaussian() * 2.0);
            }
        }
        data
    }

    fn brute_nn(data: &[f32], dim: usize, q: &[f32]) -> i64 {
        let n = data.len() / dim;
        let mut best = (f32::INFINITY, -1i64);
        for i in 0..n {
            let d = crate::util::l2_sq(q, &data[i * dim..(i + 1) * dim]);
            if d < best.0 {
                best = (d, i as i64);
            }
        }
        best.1
    }

    fn build(n: usize, dim: usize, nlist: usize, m: usize, hnsw: bool, seed: u64) -> (IvfPq4, Vec<f32>) {
        let data = clustered_data(n, dim, 32, seed);
        let mut params = IvfParams::new(nlist);
        params.coarse_hnsw = hnsw;
        let mut idx = IvfPq4::new(dim, params, PqParams::new_4bit(m));
        idx.train(&data).unwrap();
        idx.add(&data).unwrap();
        idx.seal().unwrap();
        (idx, data)
    }

    #[test]
    fn recall_reasonable_flat_coarse() {
        let (mut idx, data) = build(3000, 16, 20, 8, false, 61);
        idx.nprobe = 8;
        let nq = 50;
        let mut hits = 0;
        for qi in 0..nq {
            let q = &data[qi * 16..(qi + 1) * 16];
            let (_d, l) = idx.search(q, 10).unwrap();
            let gt = brute_nn(&data, 16, q);
            if l.contains(&gt) {
                hits += 1;
            }
        }
        assert!(hits >= 35, "recall@10 {hits}/50");
    }

    /// Probing every list with re-ranking must match the flat naive-PQ
    /// search (same codes, full coverage) — the strongest correctness
    /// property of the IVF composition.
    #[test]
    fn full_probe_matches_flat_pq() {
        use crate::pq::search_adc;
        let (mut idx, data) = build(1500, 16, 12, 8, false, 69);
        idx.nprobe = 12; // all lists
        idx.fastscan.reservoir_factor = 64; // tie-proof reservoir
        let pq = ProductQuantizer::train(&data, 16, &PqParams::new_4bit(8)).unwrap();
        let codes = pq.encode(&data).unwrap();
        for qi in 0..20 {
            let q = &data[qi * 16..(qi + 1) * 16];
            let luts = pq.compute_luts(q);
            let (d_flat, _) = search_adc(&pq, &luts, &codes, None, 5);
            let (d_ivf, _) = idx.search(q, 5).unwrap();
            for r in 0..5 {
                assert!(
                    (d_flat[r] - d_ivf[r]).abs() < 1e-4 * (1.0 + d_flat[r].abs()),
                    "q{qi} rank {r}: flat {} vs ivf {}",
                    d_flat[r],
                    d_ivf[r]
                );
            }
        }
    }

    #[test]
    fn hnsw_coarse_matches_flat_mostly() {
        let (mut flat, data) = build(2000, 16, 16, 8, false, 62);
        let (mut hnsw, _) = build(2000, 16, 16, 8, true, 62);
        flat.nprobe = 2;
        hnsw.nprobe = 2;
        let mut agree = 0;
        for qi in 0..30 {
            let q = &data[qi * 16..(qi + 1) * 16];
            let (_df, lf) = flat.search(q, 1).unwrap();
            let (_dh, lh) = hnsw.search(q, 1).unwrap();
            if lf[0] == lh[0] {
                agree += 1;
            }
        }
        assert!(agree >= 24, "flat/hnsw agreement {agree}/30");
    }

    #[test]
    fn nprobe_monotone_recall() {
        let (mut idx, data) = build(4000, 16, 32, 8, false, 63);
        let nq = 60;
        let mut recalls = Vec::new();
        for nprobe in [1usize, 4, 32] {
            idx.nprobe = nprobe;
            let mut hits = 0;
            for qi in 0..nq {
                let q = &data[qi * 16..(qi + 1) * 16];
                let (_d, l) = idx.search(q, 10).unwrap();
                if l.contains(&brute_nn(&data, 16, q)) {
                    hits += 1;
                }
            }
            recalls.push(hits);
        }
        assert!(
            recalls[0] <= recalls[1] + 3 && recalls[1] <= recalls[2] + 3,
            "roughly monotone expected: {recalls:?}"
        );
        assert!(recalls[2] >= 40, "nprobe=32 recall {}/60", recalls[2]);
    }

    #[test]
    fn untrained_errors() {
        let mut idx = IvfPq4::new(8, IvfParams::new(4), PqParams::new_4bit(2));
        assert!(idx.add(&[0.0; 8]).is_err());
        assert!(idx.search(&[0.0; 8], 1).is_err());
    }

    /// Every code width composes with IVF: probing every list with
    /// re-ranking must match the flat exact-ADC scan over the same codes
    /// (tie-proof — both rank by the identical per-code exact distance),
    /// and the code memory scales with the width.
    #[test]
    fn all_widths_compose_with_ivf() {
        use crate::pq::search_adc;
        let data = clustered_data(1200, 16, 32, 71);
        for width in CodeWidth::ALL {
            let mut idx = IvfPq4::new_width(16, IvfParams::new(6), 8, width);
            idx.train(&data).unwrap();
            idx.add(&data).unwrap();
            idx.seal().unwrap();
            idx.nprobe = 6;
            idx.fastscan.reservoir_factor = 64;
            // flat reference over the same internal quantizer + codes
            let pq = idx.pq.as_ref().unwrap();
            let codes = pq.encode(&data).unwrap();
            for qi in 0..8 {
                let q = &data[qi * 16..(qi + 1) * 16];
                let luts = pq.compute_luts(q);
                let (d_flat, _) = search_adc(pq, &luts, &codes, None, 5);
                let (d_ivf, l) = idx.search(q, 5).unwrap();
                assert_eq!(l.len(), 5, "{width}");
                assert!(d_ivf.windows(2).all(|w| w[0] <= w[1]), "{width}: unsorted {d_ivf:?}");
                for r in 0..5 {
                    assert!(
                        (d_flat[r] - d_ivf[r]).abs() < 1e-4 * (1.0 + d_flat[r].abs()),
                        "{width} q{qi} rank {r}: flat {} vs ivf {}",
                        d_flat[r],
                        d_ivf[r]
                    );
                }
            }
            let bits = idx.code_bits_per_vector();
            let want = (width.bits() * 8) as f64; // m = 8
            assert!(
                bits >= want && bits < want * 1.4,
                "{width}: bits/vec {bits} (want ≈ {want})"
            );
        }
    }

    /// Precomputed-LUT search (the coordinator's batch-level reuse entry)
    /// must return bit-identical results to the self-computing path.
    #[test]
    fn search_with_luts_matches_search_with() {
        let (mut idx, data) = build(1500, 16, 10, 8, false, 72);
        idx.nprobe = 4;
        let queries = &data[..5 * 16];
        let luts = idx.compute_scan_luts(queries).unwrap();
        let (d0, l0) = idx.search_with(queries, 6, 4, None, &idx.fastscan).unwrap();
        let (d1, l1) =
            idx.search_with_luts(queries, &luts, 6, 4, None, &idx.fastscan).unwrap();
        assert_eq!(l0, l1);
        assert_eq!(d0, d1);
        // wrong-sized LUTs are rejected, not misread
        assert!(idx
            .search_with_luts(queries, &luts[..luts.len() - 1], 6, 4, None, &idx.fastscan)
            .is_err());
    }

    /// Full-probe filtered query ≡ unfiltered-query-then-post-filter,
    /// bit-identical (at nprobe = nlist both paths see every list, so the
    /// per-list mask pushdown is the only difference under test).
    #[test]
    fn filtered_query_full_probe_matches_postfilter() {
        let (mut idx, data) = build(1500, 16, 10, 8, false, 75);
        idx.fastscan.reservoir_factor = 8; // k below makes capacity >= n anyway
        let queries = &data[..6 * 16];
        let filter = Filter::id_range(200, 700);
        let fs = idx.fastscan.clone();
        // ask for the COMPLETE admitted set (k = admitted count) so the
        // comparison is insensitive to tie-breaking at a k boundary: both
        // sides are full sets sorted by (distance, label)
        let (filtered, stats) = idx
            .query_with(queries, &QueryKind::TopK { k: 500 }, Some(&filter), 10, None, &fs)
            .unwrap();
        let (full, _) = idx
            .query_with(queries, &QueryKind::TopK { k: 1500 }, None, 10, None, &fs)
            .unwrap();
        for qi in 0..6 {
            let want: Vec<Hit> = full[qi]
                .iter()
                .filter(|h| filter.matches(h.label))
                .copied()
                .collect();
            assert_eq!(filtered[qi], want, "q{qi}");
            let st = &stats[qi];
            assert_eq!(st.lists_probed, 10, "q{qi}");
            assert_eq!(st.codes_scanned, 1500, "q{qi}");
            assert!((st.filter_selectivity - 500.0 / 1500.0).abs() < 1e-9, "q{qi}");
        }
    }

    /// Selectivity-aware nprobe escalation: a 10%-selective filter widens
    /// the probe (capped at nlist), an opaque predicate does not.
    #[test]
    fn selective_filters_escalate_nprobe() {
        let (idx, _) = build(2000, 16, 16, 8, false, 76);
        let sparse = Filter::id_range(0, 200); // 10% of 2000
        assert_eq!(idx.escalated_nprobe(2, Some(&sparse)), 16); // 2/0.1=20 → nlist cap
        let half = Filter::id_range(0, 1000);
        assert_eq!(idx.escalated_nprobe(2, Some(&half)), 4);
        let opaque = Filter::predicate(|_| true);
        assert_eq!(idx.escalated_nprobe(2, Some(&opaque)), 2);
        assert_eq!(idx.escalated_nprobe(2, None), 2);
        // the 16× escalation cap binds before nlist when nprobe is tiny
        let needle = Filter::id_set(&[3]);
        assert_eq!(idx.escalated_nprobe(1, Some(&needle)), 16.min(idx.params.nlist));
        // and escalation actually finds a selective needle: id 0 lives in
        // exactly one list, but a 1-probe query for a far-away centroid
        // must still find it once the filter narrows the target set
        let origin = [0.0f32; 16];
        let (hits, _) = idx
            .query_with(
                &origin,
                &QueryKind::TopK { k: 1 },
                Some(&Filter::id_set(&[7])),
                1,
                None,
                &idx.fastscan,
            )
            .unwrap();
        assert_eq!(hits[0].first().map(|h| h.label), Some(7));
    }

    /// Provably-empty filters answer without probing.
    #[test]
    fn empty_filter_short_circuits() {
        let (idx, data) = build(800, 16, 8, 4, false, 77);
        let (hits, stats) = idx
            .query_with(
                &data[..16],
                &QueryKind::TopK { k: 5 },
                Some(&Filter::id_range(10, 10)),
                8,
                None,
                &idx.fastscan,
            )
            .unwrap();
        assert!(hits[0].is_empty());
        assert_eq!(stats[0].lists_probed, 0);
        assert_eq!(stats[0].filter_selectivity, 0.0);
    }

    /// IVF range queries: at full probe with re-ranking, hits are exactly
    /// the ids whose exact ADC distance is within the radius.
    #[test]
    fn range_query_full_probe_exact() {
        use crate::pq::adc::adc_distances_all;
        let (mut idx, data) = build(1000, 16, 8, 8, false, 78);
        idx.fastscan.reservoir_factor = 64;
        let pq = idx.pq.as_ref().unwrap();
        let codes = pq.encode(&data).unwrap();
        let q = &data[..16];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(pq, &luts, &codes);
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let radius = sorted[30];
        let (hits, stats) = idx
            .query_with(q, &QueryKind::Range { radius }, None, 8, None, &idx.fastscan)
            .unwrap();
        let want = all.iter().filter(|&&d| d <= radius).count();
        assert_eq!(hits[0].len(), want);
        assert!(hits[0].windows(2).all(|w| w[0].distance <= w[1].distance));
        for h in &hits[0] {
            assert!((h.distance - all[h.label as usize]).abs() < 1e-6);
        }
        assert_eq!(stats[0].codes_scanned, 1000);
    }

    #[test]
    fn incremental_add_requires_reseal() {
        let (mut idx, data) = build(1000, 16, 8, 4, false, 64);
        let (_, _) = idx.search(&data[..16], 1).unwrap();
        // add more: the index is dirty again and must refuse to search
        let extra = clustered_data(64, 16, 32, 65);
        idx.add(&extra).unwrap();
        assert_eq!(idx.ntotal(), 1064);
        assert!(!idx.is_sealed());
        assert!(matches!(idx.search(&extra[..16], 1), Err(crate::Error::NotSealed)));
        idx.seal().unwrap();
        let (_d, l) = idx.search(&extra[..16], 1).unwrap();
        assert!(l[0] >= 0);
    }

    #[test]
    fn per_request_overrides_beat_defaults() {
        let (idx, data) = build(2000, 16, 16, 8, false, 70);
        // defaults: nprobe=1; explicit wide probe must cover all lists
        let wide = FastScanParams { reservoir_factor: 64, ..idx.fastscan.clone() };
        let q = &data[..16];
        let (_d1, _l1) = idx.search(q, 5).unwrap();
        let (_d2, l2) = idx.search_with(q, 5, 16, None, &wide).unwrap();
        // the wide search finds the true nearest (query = base row 0)
        assert!(l2.contains(&0), "full probe missed exact match: {l2:?}");
        // defaults untouched
        assert_eq!(idx.nprobe, 1);
    }

    #[test]
    fn external_ids_respected() {
        let data = clustered_data(500, 16, 8, 66);
        let mut idx = IvfPq4::new(16, IvfParams::new(4), PqParams::new_4bit(4));
        idx.train(&data).unwrap();
        let ids: Vec<i64> = (0..500).map(|i| 10_000 + i).collect();
        idx.add_with_ids(&data, &ids).unwrap();
        idx.seal().unwrap();
        let (_d, l) = idx.search(&data[..16], 5).unwrap();
        assert!(l.iter().all(|&x| x >= 10_000));
    }

    #[test]
    fn code_memory_matches_paper_formula() {
        // M=16, K=16 → 64 bits/code (paper Table 1), modulo block padding
        let (mut idx, _) = build(3200, 16, 4, 16, false, 67);
        idx.seal().unwrap();
        let bits = idx.code_bits_per_vector();
        assert!(bits >= 64.0 && bits < 70.0, "bits/vector {bits}");
    }

    #[test]
    fn list_stats_sane() {
        let (mut idx, _) = build(1000, 16, 10, 4, false, 68);
        idx.seal().unwrap();
        let (min, mean, max) = idx.list_stats();
        assert!(min <= mean as usize && mean as usize <= max);
        assert_eq!(
            (mean * 10.0).round() as usize,
            1000
        );
    }
}
