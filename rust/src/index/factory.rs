//! faiss-style index factory strings.
//!
//! Grammar (subset of the faiss factory covering the paper's configs):
//!
//! ```text
//!   "Flat"                      exact scan
//!   "PQ16x4"                    naive 4-bit PQ (Fig. 2 baseline)
//!   "PQ16x8"  /  "PQ16"         naive 8-bit PQ
//!   "PQ16x4fs"                  4-bit fastscan (the paper's kernel)
//!   "IVF1000,PQ16x4fs"          IVF + flat coarse + fastscan
//!   "IVF30000_HNSW32,PQ16x4fs"  IVF + HNSW coarse + fastscan (Table 1)
//! ```

use super::pq_index::{IndexIvfPq4, IndexPq, IndexPq4FastScan};
use super::{flat::IndexFlat, Index};
use crate::pq::PqParams;
use crate::{Error, Result};

/// Create an index from a factory string.
pub fn index_factory(dim: usize, spec: &str) -> Result<Box<dyn Index>> {
    let spec = spec.trim();
    let err = |msg: &str| Error::Factory(spec.to_string(), msg.to_string());

    if spec.eq_ignore_ascii_case("flat") {
        return Ok(Box::new(IndexFlat::new(dim)));
    }

    let parts: Vec<&str> = spec.split(',').map(|s| s.trim()).collect();
    match parts.as_slice() {
        [pq_spec] => {
            let pq = parse_pq(pq_spec).ok_or_else(|| err("expected PQ<m>[x<bits>][fs]"))?;
            build_flat_pq(dim, pq, spec)
        }
        [ivf_spec, pq_spec] => {
            let (nlist, hnsw_m) =
                parse_ivf(ivf_spec).ok_or_else(|| err("expected IVF<nlist>[_HNSW<m>]"))?;
            let pq = parse_pq(pq_spec).ok_or_else(|| err("expected PQ<m>x4fs after IVF"))?;
            if !(pq.nbits == 4 && pq.fastscan) {
                return Err(err("IVF composition requires PQ<m>x4fs"));
            }
            Ok(Box::new(IndexIvfPq4::new(
                dim,
                nlist,
                pq.m,
                hnsw_m.is_some(),
                hnsw_m.unwrap_or(32),
            )))
        }
        _ => Err(err("too many components")),
    }
}

struct PqSpec {
    m: usize,
    nbits: usize,
    fastscan: bool,
}

fn parse_pq(s: &str) -> Option<PqSpec> {
    let rest = s.strip_prefix("PQ")?;
    let (body, fastscan) = match rest.strip_suffix("fs") {
        Some(b) => (b, true),
        None => (rest, false),
    };
    let (m, nbits) = match body.split_once('x') {
        Some((m, b)) => (m.parse().ok()?, b.parse().ok()?),
        None => (body.parse().ok()?, 8usize),
    };
    if m == 0 {
        return None;
    }
    Some(PqSpec { m, nbits, fastscan })
}

fn parse_ivf(s: &str) -> Option<(usize, Option<usize>)> {
    let rest = s.strip_prefix("IVF")?;
    match rest.split_once("_HNSW") {
        Some((nlist, m)) => Some((nlist.parse().ok()?, Some(m.parse().ok()?))),
        None => Some((rest.parse().ok()?, None)),
    }
}

fn build_flat_pq(dim: usize, pq: PqSpec, spec: &str) -> Result<Box<dyn Index>> {
    match (pq.nbits, pq.fastscan) {
        (4, true) => Ok(Box::new(IndexPq4FastScan::new(dim, pq.m))),
        (4, false) => Ok(Box::new(IndexPq::new(dim, PqParams::new_4bit(pq.m)))),
        (8, false) => Ok(Box::new(IndexPq::new(dim, PqParams::new_8bit(pq.m)))),
        (b, true) if b != 4 => {
            Err(Error::Factory(spec.to_string(), "fastscan requires 4-bit codes".into()))
        }
        (b, _) => Err(Error::Factory(spec.to_string(), format!("unsupported nbits {b}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;

    #[test]
    fn parses_all_paper_configs() {
        for spec in ["Flat", "PQ8x4", "PQ16x4fs", "PQ4", "PQ4x8", "IVF100,PQ16x4fs", "IVF100_HNSW32,PQ16x4fs"] {
            let idx = index_factory(64, spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(idx.dim(), 64, "{spec}");
        }
    }

    #[test]
    fn descriptions_roundtrip_key_facts() {
        let idx = index_factory(32, "IVF50_HNSW16,PQ8x4fs").unwrap();
        let d = idx.describe();
        assert!(d.contains("IVF50"), "{d}");
        assert!(d.contains("HNSW16"), "{d}");
        assert!(d.contains("PQ8x4fs"), "{d}");
    }

    #[test]
    fn rejects_nonsense() {
        for spec in ["", "IVF", "PQ0x4fs", "PQx4", "IVF10,PQ8x8", "IVF10,Flat", "A,B,C", "PQ8x6fs"] {
            assert!(index_factory(16, spec).is_err(), "{spec:?} should fail");
        }
    }

    #[test]
    fn factory_index_end_to_end() {
        let ds = SyntheticDataset::gaussian(500, 5, 16, 111);
        let mut idx = index_factory(ds.dim, "PQ4x4fs").unwrap();
        idx.train(&ds.base).unwrap();
        idx.add(&ds.base).unwrap();
        let r = idx.search(&ds.queries, 3).unwrap();
        assert_eq!(r.nq(), 5);
        assert!(r.labels.iter().all(|&l| l >= -1 && l < 500));
    }
}
