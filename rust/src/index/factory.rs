//! faiss-style index factory strings.
//!
//! Grammar (subset of the faiss factory covering the paper's configs plus
//! the Quicker-ADC width axis):
//!
//! ```text
//!   "Flat"                      exact scan
//!   "PQ16x4"                    naive 4-bit PQ (Fig. 2 baseline)
//!   "PQ16x8"  /  "PQ16"         naive 8-bit PQ
//!   "PQ16x4fs"                  4-bit fastscan (the paper's kernel)
//!   "PQ16x2fs"                  2-bit fastscan (faster/coarser)
//!   "PQ16x8fs"                  8-bit fastscan (slower/finer)
//!   "IVF1000,PQ16x4fs"          IVF + flat coarse + fastscan
//!   "IVF100,PQ16x2fs,nprobe=8"  any fastscan width composes with IVF
//!   "IVF30000_HNSW32,PQ16x4fs"  IVF + HNSW coarse + fastscan (Table 1)
//!   "SEG,PQ16x4fs"              streaming segmented index (insert/delete)
//!   "SEG1024,PQ16x2fs"          …with a 1024-row memtable flush threshold
//! ```
//!
//! Trailing `key=value` components set default [`SearchParams`] on the
//! built index through the shared parser — the same keys `set_param` and
//! the CLI accept:
//!
//! ```text
//!   "IVF100,PQ16x4fs,nprobe=8,rerank=false"
//! ```

use super::pq_index::{IndexIvfPq4, IndexPq, IndexPq4FastScan};
use super::{flat::IndexFlat, Index, SearchParams};
use crate::pq::{CodeWidth, PqParams};
use crate::segment::{SegmentedIndex, SegmentedParams};
use crate::storage::OpenOptions;
use crate::{Error, Result};

/// Create an index from a factory string.
pub fn index_factory(dim: usize, spec: &str) -> Result<Box<dyn Index>> {
    let spec = spec.trim();
    let err = |msg: String| Error::Factory(spec.to_string(), msg);

    if spec.eq_ignore_ascii_case("flat") {
        return Ok(Box::new(IndexFlat::new(dim)));
    }

    let mut parts: Vec<&str> = spec.split(',').map(|s| s.trim()).collect();

    // Peel trailing `key=value` components into default search parameters.
    // Storage keys (`mmap` / `budget_mb`) are accepted and ignored here:
    // they configure how a *saved* index is opened, not how a fresh one is
    // built — `spec_open_options` extracts them for the open path.
    let (params, _open) = peel_trailing_params(&mut parts).map_err(&err)?;

    let mut index: Box<dyn Index> = match parts.as_slice() {
        [] => return Err(err("missing index component".into())),
        [pq_spec] => {
            let pq = parse_pq(pq_spec)
                .ok_or_else(|| err(format!("component {pq_spec:?}: expected PQ<m>[x<bits>][fs]")))?;
            build_flat_pq(dim, pq, spec)?
        }
        [seg_spec, pq_spec] if parse_seg(seg_spec).is_some() => {
            let flush_threshold = parse_seg(seg_spec).unwrap();
            let pq = parse_pq(pq_spec)
                .ok_or_else(|| err(format!("component {pq_spec:?}: expected PQ<m>x<bits>fs after SEG")))?;
            if !pq.fastscan {
                return Err(err(format!(
                    "component {pq_spec:?}: SEG composition requires a fastscan PQ (PQ<m>x{{2,4,8}}fs)"
                )));
            }
            let width = CodeWidth::from_bits(pq.nbits).ok_or_else(|| {
                err(format!(
                    "component {pq_spec:?}: fastscan supports 2-, 4- or 8-bit codes, got {}",
                    pq.nbits
                ))
            })?;
            let mut seg_params = SegmentedParams::default();
            if let Some(t) = flush_threshold {
                seg_params.flush_threshold = t;
            }
            Box::new(
                SegmentedIndex::new(dim, pq.m, width, seg_params)
                    .map_err(|e| err(format!("component {seg_spec:?}: {e}")))?,
            )
        }
        [ivf_spec, pq_spec] => {
            let (nlist, hnsw_m) = parse_ivf(ivf_spec)
                .ok_or_else(|| err(format!("component {ivf_spec:?}: expected IVF<nlist>[_HNSW<m>]")))?;
            let pq = parse_pq(pq_spec)
                .ok_or_else(|| err(format!("component {pq_spec:?}: expected PQ<m>x<bits>fs after IVF")))?;
            if !pq.fastscan {
                return Err(err(format!(
                    "component {pq_spec:?}: IVF composition requires a fastscan PQ (PQ<m>x{{2,4,8}}fs)"
                )));
            }
            let width = CodeWidth::from_bits(pq.nbits).ok_or_else(|| {
                err(format!(
                    "component {pq_spec:?}: fastscan supports 2-, 4- or 8-bit codes, got {}",
                    pq.nbits
                ))
            })?;
            Box::new(IndexIvfPq4::new_width(
                dim,
                nlist,
                pq.m,
                width,
                hnsw_m.is_some(),
                hnsw_m.unwrap_or(32),
            ))
        }
        _ => return Err(err("too many components".into())),
    };

    // Apply the trailing params as defaults; a key the built index type
    // doesn't support is a spec error and names itself.
    for (key, value) in params.to_kv() {
        index
            .set_param(key, &value)
            .map_err(|e| err(format!("params component {key:?}: {e}")))?;
    }
    Ok(index)
}

/// [`index_factory`] plus default [`SearchParams`] applied afterwards
/// (e.g. from a config file). Unlike in-spec trailing components, keys the
/// index type doesn't support are skipped — one config can drive sweeps
/// over heterogeneous factory strings.
pub fn index_factory_with(
    dim: usize,
    spec: &str,
    defaults: &SearchParams,
) -> Result<Box<dyn Index>> {
    let mut index = index_factory(dim, spec)?;
    for (key, value) in defaults.to_kv() {
        let _ = index.set_param(key, &value);
    }
    Ok(index)
}

/// The default [`SearchParams`] a factory spec's trailing `key=value`
/// components set, without building the index — lets callers (e.g. the
/// CLI's implicit-default logic) see which keys a spec configures.
pub fn spec_search_params(spec: &str) -> Result<SearchParams> {
    let spec = spec.trim();
    let mut parts: Vec<&str> = spec.split(',').map(|s| s.trim()).collect();
    peel_trailing_params(&mut parts)
        .map(|(params, _)| params)
        .map_err(|msg| Error::Factory(spec.to_string(), msg))
}

/// The storage [`OpenOptions`] a factory spec's trailing components set
/// (`"IVF100,PQ16x4fs,mmap=true,budget_mb=512"`), without building the
/// index — the open path (CLI `serve --index-file`, coordinator config)
/// uses this to decide heap vs mapped loading.
pub fn spec_open_options(spec: &str) -> Result<OpenOptions> {
    let spec = spec.trim();
    let mut parts: Vec<&str> = spec.split(',').map(|s| s.trim()).collect();
    peel_trailing_params(&mut parts)
        .map(|(_, open)| open)
        .map_err(|msg| Error::Factory(spec.to_string(), msg))
}

/// Pop trailing `key=value` components off `parts` and parse them into a
/// [`SearchParams`] plus storage [`OpenOptions`], assigning left-to-right
/// so duplicate keys resolve last-wins like every other config surface.
/// Storage keys (`mmap` / `budget_mb`) are consumed before the search
/// parser sees them, so one spec string can carry both kinds.
fn peel_trailing_params(
    parts: &mut Vec<&str>,
) -> std::result::Result<(SearchParams, OpenOptions), String> {
    let mut trailing = Vec::new();
    while parts.last().is_some_and(|s| s.contains('=')) {
        trailing.push(parts.pop().unwrap());
    }
    trailing.reverse();
    let mut params = SearchParams::default();
    let mut open = OpenOptions::default();
    for comp in trailing {
        let (key, value) = comp.split_once('=').unwrap();
        let consumed = open
            .assign(key.trim(), value.trim())
            .map_err(|e| format!("params component {comp:?}: {e}"))?;
        if consumed {
            continue;
        }
        params
            .assign(key.trim(), value.trim())
            .map_err(|e| format!("params component {comp:?}: {e}"))?;
    }
    Ok((params, open))
}

struct PqSpec {
    m: usize,
    nbits: usize,
    fastscan: bool,
}

fn parse_pq(s: &str) -> Option<PqSpec> {
    let rest = s.strip_prefix("PQ")?;
    let (body, fastscan) = match rest.strip_suffix("fs") {
        Some(b) => (b, true),
        None => (rest, false),
    };
    let (m, nbits) = match body.split_once('x') {
        Some((m, b)) => (m.parse().ok()?, b.parse().ok()?),
        None => (body.parse().ok()?, 8usize),
    };
    if m == 0 {
        return None;
    }
    Some(PqSpec { m, nbits, fastscan })
}

/// `"SEG"` → `Some(None)` (default flush threshold), `"SEG1024"` →
/// `Some(Some(1024))`, anything else → `None`.
fn parse_seg(s: &str) -> Option<Option<usize>> {
    let rest = s.strip_prefix("SEG")?;
    if rest.is_empty() {
        Some(None)
    } else {
        Some(Some(rest.parse().ok()?))
    }
}

fn parse_ivf(s: &str) -> Option<(usize, Option<usize>)> {
    let rest = s.strip_prefix("IVF")?;
    match rest.split_once("_HNSW") {
        Some((nlist, m)) => Some((nlist.parse().ok()?, Some(m.parse().ok()?))),
        None => Some((rest.parse().ok()?, None)),
    }
}

fn build_flat_pq(dim: usize, pq: PqSpec, spec: &str) -> Result<Box<dyn Index>> {
    let component = format!(
        "PQ{}x{}{}",
        pq.m,
        pq.nbits,
        if pq.fastscan { "fs" } else { "" }
    );
    match (pq.nbits, pq.fastscan) {
        (_, true) => match CodeWidth::from_bits(pq.nbits) {
            Some(width) => Ok(Box::new(IndexPq4FastScan::new_width(dim, pq.m, width))),
            // unsupported widths (e.g. "PQ16x3fs") fail as a *named
            // component*, not a generic parse error
            None => Err(Error::Factory(
                spec.to_string(),
                format!(
                    "component {component:?}: fastscan supports 2-, 4- or 8-bit codes, got {}",
                    pq.nbits
                ),
            )),
        },
        (4, false) => Ok(Box::new(IndexPq::new(dim, PqParams::new_4bit(pq.m)))),
        (8, false) => Ok(Box::new(IndexPq::new(dim, PqParams::new_8bit(pq.m)))),
        (b, false) => Err(Error::Factory(
            spec.to_string(),
            format!("component {component:?}: unsupported nbits {b} (naive PQ takes 4 or 8)"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;

    #[test]
    fn parses_all_paper_configs() {
        for spec in ["Flat", "PQ8x4", "PQ16x4fs", "PQ4", "PQ4x8", "IVF100,PQ16x4fs", "IVF100_HNSW32,PQ16x4fs"] {
            let idx = index_factory(64, spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert_eq!(idx.dim(), 64, "{spec}");
        }
    }

    #[test]
    fn parses_all_fastscan_widths() {
        for (spec, want) in [
            ("PQ16x2fs", "PQ16x2fs"),
            ("PQ16x4fs", "PQ16x4fs"),
            ("PQ16x8fs", "PQ16x8fs"),
            ("IVF100,PQ16x2fs", "PQ16x2fs"),
            ("IVF100,PQ16x8fs,nprobe=8", "PQ16x8fs"),
            ("IVF50_HNSW16,PQ8x2fs", "PQ8x2fs"),
        ] {
            let idx = index_factory(64, spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(idx.describe().contains(want), "{spec}: {}", idx.describe());
        }
    }

    /// Satellite: unsupported widths fail as a *named component*, not a
    /// generic parse error — the message cites the component and the
    /// supported width set.
    #[test]
    fn unsupported_width_errors_name_the_component() {
        for spec in ["PQ16x3fs", "PQ16x6fs", "PQ8x16fs"] {
            let e = index_factory(64, spec).unwrap_err().to_string();
            assert!(e.contains("component"), "{spec}: {e}");
            assert!(e.contains("2-, 4- or 8-bit"), "{spec}: {e}");
        }
        let e = index_factory(64, "IVF10,PQ16x3fs").unwrap_err().to_string();
        assert!(e.contains("PQ16x3fs") && e.contains("2-, 4- or 8-bit"), "{e}");
    }

    #[test]
    fn descriptions_roundtrip_key_facts() {
        let idx = index_factory(32, "IVF50_HNSW16,PQ8x4fs").unwrap();
        let d = idx.describe();
        assert!(d.contains("IVF50"), "{d}");
        assert!(d.contains("HNSW16"), "{d}");
        assert!(d.contains("PQ8x4fs"), "{d}");
    }

    #[test]
    fn rejects_nonsense() {
        for spec in ["", "IVF", "PQ0x4fs", "PQx4", "IVF10,PQ8x8", "IVF10,Flat", "A,B,C", "PQ8x6fs"] {
            assert!(index_factory(16, spec).is_err(), "{spec:?} should fail");
        }
    }

    #[test]
    fn parses_segmented_specs() {
        for (spec, want) in [
            ("SEG,PQ8x4fs", "SEG(PQ8x4fs"),
            ("SEG128,PQ8x2fs", "SEG(PQ8x2fs"),
            ("SEG,PQ8x8fs,rerank=false", "SEG(PQ8x8fs"),
        ] {
            let idx = index_factory(64, spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(idx.describe().starts_with(want), "{spec}: {}", idx.describe());
        }
        // non-fastscan PQ, zero flush threshold, and junk suffixes all fail
        for spec in ["SEG,PQ8x4", "SEG0,PQ8x4fs", "SEGx,PQ8x4fs", "SEG,PQ8x3fs"] {
            assert!(index_factory(64, spec).is_err(), "{spec:?} should fail");
        }
    }

    #[test]
    fn segmented_factory_streams_end_to_end() {
        let ds = SyntheticDataset::gaussian(400, 4, 16, 212);
        let mut idx = index_factory(ds.dim, "SEG64,PQ4x4fs").unwrap();
        idx.train(&ds.base).unwrap();
        // stream through the trait's &self surface
        let ids = idx.insert(&ds.base, None).unwrap();
        assert_eq!(ids.len(), 400);
        let removed = idx.delete(&ids[..10]).unwrap();
        assert_eq!(removed, 10);
        assert_eq!(idx.ntotal(), 390);
        idx.flush().unwrap();
        idx.compact().unwrap();
        let stats = idx.segment_stats().unwrap();
        assert_eq!(stats.segments, 1);
        assert_eq!(stats.tombstones, 0);
        let r = idx.search(&ds.queries, 3, None).unwrap();
        assert_eq!(r.nq(), 4);
        assert!(r.labels.iter().all(|&l| !(0..10).contains(&l)));
    }

    #[test]
    fn trailing_params_set_defaults() {
        let idx = index_factory(32, "IVF10,PQ8x4fs,nprobe=7,rerank=false").unwrap();
        assert!(idx.describe().contains("nprobe=7"), "{}", idx.describe());
        // ef_search applies to the HNSW-coarse composition
        index_factory(32, "IVF10_HNSW8,PQ8x4fs,ef_search=64").unwrap();
        // duplicate keys resolve last-wins like every other config surface
        let idx = index_factory(32, "IVF10,PQ8x4fs,nprobe=8,nprobe=3").unwrap();
        assert!(idx.describe().contains("nprobe=3"), "{}", idx.describe());
        // unknown key, bad value, unsupported key: all name the component
        let e = index_factory(32, "IVF10,PQ8x4fs,bogus=1").unwrap_err().to_string();
        assert!(e.contains("bogus"), "{e}");
        let e = index_factory(32, "IVF10,PQ8x4fs,nprobe=abc").unwrap_err().to_string();
        assert!(e.contains("nprobe=abc"), "{e}");
        let e = index_factory(32, "PQ8x4fs,nprobe=4").unwrap_err().to_string();
        assert!(e.contains("nprobe"), "{e}"); // flat fastscan has no nprobe
    }

    #[test]
    fn storage_keys_peel_into_open_options() {
        // storage keys configure the open path and never reach the
        // SearchParams parser — a build with them still succeeds
        let idx = index_factory(32, "IVF10,PQ8x4fs,mmap=true,budget_mb=64,nprobe=5").unwrap();
        assert!(idx.describe().contains("nprobe=5"), "{}", idx.describe());
        let open = spec_open_options("IVF10,PQ8x4fs,mmap=true,budget_mb=64,nprobe=5").unwrap();
        assert_eq!(open, OpenOptions { mmap: true, budget_mb: Some(64) });
        // defaults: heap open, no budget
        assert_eq!(spec_open_options("PQ8x4fs").unwrap(), OpenOptions::heap());
        // and the search-params view of the same spec omits storage keys
        let sp = spec_search_params("PQ8x4fs,mmap=true,nprobe=3").unwrap();
        assert_eq!(sp, SearchParams::new().with_nprobe(3));
        // bad storage values are named spec errors
        let e = index_factory(32, "PQ8x4fs,mmap=maybe").unwrap_err().to_string();
        assert!(e.contains("mmap"), "{e}");
        assert!(spec_open_options("PQ8x4fs,budget_mb=lots").is_err());
    }

    #[test]
    fn factory_with_skips_unsupported_defaults() {
        let defaults = SearchParams::new().with_nprobe(9).with_rerank(false);
        // nprobe applies to the IVF index…
        let ivf = index_factory_with(32, "IVF10,PQ8x4fs", &defaults).unwrap();
        assert!(ivf.describe().contains("nprobe=9"), "{}", ivf.describe());
        // …and is silently skipped for the flat fastscan index
        let flat = index_factory_with(32, "PQ8x4fs", &defaults).unwrap();
        assert!(flat.describe().starts_with("PQ8x4fs"), "{}", flat.describe());
    }

    #[test]
    fn factory_index_end_to_end() {
        let ds = SyntheticDataset::gaussian(500, 5, 16, 111);
        let mut idx = index_factory(ds.dim, "PQ4x4fs").unwrap();
        idx.train(&ds.base).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let r = idx.search(&ds.queries, 3, None).unwrap();
        assert_eq!(r.nq(), 5);
        assert!(r.labels.iter().all(|&l| l >= -1 && l < 500));
    }
}
