//! Typed per-request search parameters.
//!
//! [`SearchParams`] carries every runtime search knob as an `Option`: an
//! unset field falls back to the index's build-time default, a set field
//! overrides it *for that call only*. Because the parameters travel with
//! the request instead of being mutated into the index, a sealed index can
//! be shared behind `Arc<dyn Index>` and searched from many threads with
//! different settings concurrently — no lock, no cross-request leakage.
//!
//! [`SearchParams::assign`] is the single string-keyed parser: the
//! `set_param` compatibility shim, the CLI `--nprobe`/`--backend` flags,
//! config files, and the factory's trailing `key=value` segments all
//! funnel through it, so every surface accepts the same keys with the
//! same spellings.
//!
//! [`SearchRequest`] bundles a query batch, `k`, and optional overrides
//! for layers (the TCP server, the batcher) that pass whole requests
//! around.

use crate::pq::fastscan::FastScanParams;
use crate::simd::Backend;
use crate::{Error, Result};

/// Per-request search parameter overrides (all optional).
///
/// Unset fields inherit the index's defaults; set fields win for the one
/// call they accompany. Not every index consumes every field — irrelevant
/// fields are ignored (e.g. `nprobe` on a flat PQ index), mirroring faiss'
/// `SearchParameters` downcast behavior.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SearchParams {
    /// IVF probe width (number of inverted lists scanned).
    pub nprobe: Option<usize>,
    /// HNSW coarse-quantizer candidate-list width.
    pub ef_search: Option<usize>,
    /// Fastscan kernel implementation.
    pub backend: Option<Backend>,
    /// Re-rank reservoir candidates with exact f32 tables.
    pub rerank: Option<bool>,
    /// Reservoir over-collection factor relative to k.
    pub reservoir_factor: Option<usize>,
    /// Shortlist width multiplier for refinement wrappers.
    pub refine_factor: Option<usize>,
}

impl SearchParams {
    pub fn new() -> Self {
        Self::default()
    }

    /// True when no field is set (the request carries no overrides).
    pub fn is_empty(&self) -> bool {
        *self == Self::default()
    }

    pub fn with_nprobe(mut self, nprobe: usize) -> Self {
        self.nprobe = Some(nprobe);
        self
    }

    pub fn with_ef_search(mut self, ef_search: usize) -> Self {
        self.ef_search = Some(ef_search);
        self
    }

    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn with_rerank(mut self, rerank: bool) -> Self {
        self.rerank = Some(rerank);
        self
    }

    pub fn with_reservoir_factor(mut self, factor: usize) -> Self {
        self.reservoir_factor = Some(factor);
        self
    }

    pub fn with_refine_factor(mut self, factor: usize) -> Self {
        self.refine_factor = Some(factor);
        self
    }

    /// Parse one string-keyed parameter into the typed struct — THE parser
    /// shared by the `set_param` shim, CLI flags, config files, and the
    /// factory's trailing params segments. Unknown keys error.
    pub fn assign(&mut self, key: &str, value: &str) -> Result<()> {
        fn parse_usize(key: &str, value: &str) -> Result<usize> {
            value
                .parse()
                .map_err(|_| Error::InvalidParameter(format!("bad {key}={value}")))
        }
        match key {
            "nprobe" => self.nprobe = Some(parse_usize(key, value)?),
            "ef_search" => self.ef_search = Some(parse_usize(key, value)?),
            "reservoir_factor" => self.reservoir_factor = Some(parse_usize(key, value)?),
            "refine_factor" => self.refine_factor = Some(parse_usize(key, value)?),
            "rerank" => {
                self.rerank = Some(match value {
                    "true" | "1" | "yes" => true,
                    "false" | "0" | "no" => false,
                    _ => return Err(Error::InvalidParameter(format!("bad rerank={value}"))),
                })
            }
            "backend" => {
                self.backend = Some(Backend::parse(value).ok_or_else(|| {
                    Error::InvalidParameter(format!("bad backend {value}"))
                })?)
            }
            _ => {
                return Err(Error::InvalidParameter(format!("unknown parameter {key}={value}")))
            }
        }
        Ok(())
    }

    /// Build from `key=value` pairs.
    pub fn from_kv<'a>(pairs: impl IntoIterator<Item = (&'a str, &'a str)>) -> Result<Self> {
        let mut p = Self::default();
        for (k, v) in pairs {
            p.assign(k, v)?;
        }
        Ok(p)
    }

    /// The set fields as string pairs — the inverse of [`SearchParams::assign`],
    /// used for wire serialization and the `set_param` passthrough.
    pub fn to_kv(&self) -> Vec<(&'static str, String)> {
        let mut out = Vec::new();
        if let Some(v) = self.nprobe {
            out.push(("nprobe", v.to_string()));
        }
        if let Some(v) = self.ef_search {
            out.push(("ef_search", v.to_string()));
        }
        if let Some(v) = self.backend {
            out.push(("backend", v.name().to_string()));
        }
        if let Some(v) = self.rerank {
            out.push(("rerank", v.to_string()));
        }
        if let Some(v) = self.reservoir_factor {
            out.push(("reservoir_factor", v.to_string()));
        }
        if let Some(v) = self.refine_factor {
            out.push(("refine_factor", v.to_string()));
        }
        out
    }

    /// Reject values no sane request carries — the serving boundary calls
    /// this on client-supplied params so a remote override cannot trigger
    /// huge allocations (`reservoir_factor` scales a per-query buffer by
    /// `k × factor`), overflow, or a SIMD backend this host cannot run
    /// (dispatching an unavailable `#[target_feature]` kernel is UB).
    /// Trusted in-process callers may skip it.
    pub fn validate_bounds(&self) -> Result<()> {
        if let Some(b) = self.backend {
            if !b.is_available() {
                return Err(Error::InvalidParameter(format!(
                    "backend {b} not available on this host"
                )));
            }
        }
        const MAX_NPROBE: usize = 1 << 20;
        const MAX_EF: usize = 1 << 20;
        const MAX_FACTOR: usize = 1 << 16;
        for (key, value, max) in [
            ("nprobe", self.nprobe, MAX_NPROBE),
            ("ef_search", self.ef_search, MAX_EF),
            ("reservoir_factor", self.reservoir_factor, MAX_FACTOR),
            ("refine_factor", self.refine_factor, MAX_FACTOR),
        ] {
            if let Some(v) = value {
                if v > max {
                    return Err(Error::InvalidParameter(format!(
                        "{key}={v} exceeds limit {max}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// [`SearchParams::validate_bounds`] plus request-size-aware caps: the
    /// reservoir and refinement shortlists allocate `O(k × factor)` per
    /// query, so the serving boundary must bound the *product*, not each
    /// factor alone.
    pub fn validate_for_request(&self, k: usize) -> Result<()> {
        self.validate_bounds()?;
        const MAX_SHORTLIST: usize = 1 << 20;
        for (key, factor) in [
            ("reservoir_factor", self.reservoir_factor),
            ("refine_factor", self.refine_factor),
        ] {
            if let Some(f) = factor {
                if k.saturating_mul(f) > MAX_SHORTLIST {
                    return Err(Error::InvalidParameter(format!(
                        "{key}={f} with k={k} exceeds shortlist limit {MAX_SHORTLIST}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Effective kernel parameters: this request's overrides applied over
    /// the index's defaults.
    pub fn fastscan(&self, base: &FastScanParams) -> FastScanParams {
        FastScanParams {
            backend: self.backend.unwrap_or(base.backend),
            rerank: self.rerank.unwrap_or(base.rerank),
            reservoir_factor: self.reservoir_factor.unwrap_or(base.reservoir_factor),
        }
    }
}

/// Resolve `Option<&SearchParams>` over a base [`FastScanParams`].
pub fn effective_fastscan(base: &FastScanParams, params: Option<&SearchParams>) -> FastScanParams {
    match params {
        Some(p) => p.fastscan(base),
        None => base.clone(),
    }
}

/// Resolve per-request overrides against IVF defaults into the concrete
/// `(nprobe, ef_search, FastScanParams)` triple `IvfPq4::search_with`
/// takes — the single definition shared by the index layer
/// (`IndexIvfPq4::search`) and the coordinator (`IvfBackend`).
pub fn effective_ivf(
    params: Option<&SearchParams>,
    default_nprobe: usize,
    base: &FastScanParams,
) -> (usize, Option<usize>, FastScanParams) {
    (
        params.and_then(|p| p.nprobe).unwrap_or(default_nprobe),
        params.and_then(|p| p.ef_search),
        effective_fastscan(base, params),
    )
}

/// One search call as a value: a query batch, `k`, and optional per-request
/// parameter overrides. Built fluently:
///
/// ```ignore
/// let req = SearchRequest::new(&queries, 10).nprobe(8).rerank(false);
/// let result = index.search_req(&req)?;
/// ```
#[derive(Clone, Debug)]
pub struct SearchRequest<'a> {
    /// Row-major `nq × dim` query batch.
    pub queries: &'a [f32],
    pub k: usize,
    pub params: Option<SearchParams>,
}

impl<'a> SearchRequest<'a> {
    pub fn new(queries: &'a [f32], k: usize) -> Self {
        Self { queries, k, params: None }
    }

    /// Replace the whole override set.
    pub fn with_params(mut self, params: SearchParams) -> Self {
        self.params = Some(params);
        self
    }

    fn params_mut(&mut self) -> &mut SearchParams {
        self.params.get_or_insert_with(SearchParams::default)
    }

    pub fn nprobe(mut self, v: usize) -> Self {
        self.params_mut().nprobe = Some(v);
        self
    }

    pub fn ef_search(mut self, v: usize) -> Self {
        self.params_mut().ef_search = Some(v);
        self
    }

    pub fn backend(mut self, v: Backend) -> Self {
        self.params_mut().backend = Some(v);
        self
    }

    pub fn rerank(mut self, v: bool) -> Self {
        self.params_mut().rerank = Some(v);
        self
    }

    pub fn reservoir_factor(mut self, v: usize) -> Self {
        self.params_mut().reservoir_factor = Some(v);
        self
    }

    pub fn refine_factor(mut self, v: usize) -> Self {
        self.params_mut().refine_factor = Some(v);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_parses_every_key() {
        let mut p = SearchParams::new();
        for (k, v) in [
            ("nprobe", "8"),
            ("ef_search", "64"),
            ("backend", "portable"),
            ("rerank", "false"),
            ("reservoir_factor", "16"),
            ("refine_factor", "4"),
        ] {
            p.assign(k, v).unwrap();
        }
        assert_eq!(p.nprobe, Some(8));
        assert_eq!(p.ef_search, Some(64));
        assert_eq!(p.backend, Some(Backend::Portable));
        assert_eq!(p.rerank, Some(false));
        assert_eq!(p.reservoir_factor, Some(16));
        assert_eq!(p.refine_factor, Some(4));
    }

    #[test]
    fn assign_rejects_bad_input() {
        let mut p = SearchParams::new();
        assert!(p.assign("nprobe", "abc").is_err());
        assert!(p.assign("rerank", "banana").is_err());
        assert!(p.assign("backend", "avx512").is_err());
        assert!(p.assign("bogus", "1").is_err());
    }

    #[test]
    fn bounds_reject_absurd_values() {
        assert!(SearchParams::new().with_nprobe(64).validate_bounds().is_ok());
        assert!(SearchParams::new()
            .with_reservoir_factor(100_000_000_000_000)
            .validate_bounds()
            .is_err());
        assert!(SearchParams::new().with_ef_search(usize::MAX).validate_bounds().is_err());
        assert!(SearchParams::new().validate_bounds().is_ok());
        // the portable backend is always available; a backend this host
        // lacks must be rejected at the boundary (UB to dispatch it)
        assert!(SearchParams::new().with_backend(Backend::Portable).validate_bounds().is_ok());
        if let Some(missing) =
            [Backend::Ssse3, Backend::Neon].into_iter().find(|b| !b.is_available())
        {
            assert!(SearchParams::new().with_backend(missing).validate_bounds().is_err());
        }
        // per-factor limits pass but the k × factor product is capped:
        // reservoir/refine shortlists allocate O(k × factor) per query
        let p = SearchParams::new().with_reservoir_factor(65_536);
        assert!(p.validate_bounds().is_ok());
        assert!(p.validate_for_request(10).is_ok());
        assert!(p.validate_for_request(1024).is_err());
        assert!(SearchParams::new()
            .with_refine_factor(65_536)
            .validate_for_request(1024)
            .is_err());
    }

    #[test]
    fn to_kv_roundtrips_through_assign() {
        let p = SearchParams::new()
            .with_nprobe(4)
            .with_backend(Backend::Portable)
            .with_rerank(true)
            .with_reservoir_factor(32);
        let kv = p.to_kv();
        let q = SearchParams::from_kv(kv.iter().map(|(k, v)| (*k, v.as_str()))).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn fastscan_overrides_only_set_fields() {
        let base = FastScanParams {
            backend: Backend::Portable,
            rerank: true,
            reservoir_factor: 8,
        };
        let p = SearchParams::new().with_reservoir_factor(64);
        let eff = p.fastscan(&base);
        assert_eq!(eff.backend, Backend::Portable);
        assert!(eff.rerank);
        assert_eq!(eff.reservoir_factor, 64);
        // empty params → identical to base
        let eff2 = effective_fastscan(&base, None);
        assert_eq!(eff2.reservoir_factor, 8);
    }

    #[test]
    fn request_builder_collects_overrides() {
        let q = [0.0f32; 8];
        let req = SearchRequest::new(&q, 5).nprobe(2).rerank(false);
        let p = req.params.as_ref().unwrap();
        assert_eq!(p.nprobe, Some(2));
        assert_eq!(p.rerank, Some(false));
        assert_eq!(req.k, 5);
        assert!(SearchRequest::new(&q, 5).params.is_none());
    }
}
