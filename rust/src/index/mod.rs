//! Unified index API: the `Index` trait, the concrete index types, and the
//! faiss-style factory strings (`"IVF1000_HNSW32,PQ16x4fs"`).
//!
//! # Lifecycle: a mutable build phase, then an immutable query phase
//!
//! Every index goes through two phases with distinct mutability:
//!
//! 1. **Build** (`&mut self`): [`Index::train`] fits codebooks/centroids,
//!    [`Index::add`] stages vectors, and [`Index::seal`] packs the staged
//!    codes into the kernel's interleaved SIMD layout. `seal` is
//!    idempotent — call it once after the last `add`.
//! 2. **Query** (`&self`): [`Index::search`] is read-only, so a sealed
//!    index can be shared behind `Arc<dyn Index>` and searched from many
//!    threads concurrently without a lock. Searching an index with
//!    unsealed staged codes returns [`crate::Error::NotSealed`] instead of
//!    silently repacking.
//!
//! Runtime knobs (`nprobe`, `ef_search`, `backend`, `rerank`, …) travel
//! *with each request* as a typed [`SearchParams`] — unset fields fall
//! back to the index's defaults, set fields win for that call only, and
//! concurrent requests with different parameters never interfere.
//!
//! ```no_run
//! use armpq::index::{index_factory, Index, SearchParams};
//! # let queries = vec![0.0f32; 64];
//! let mut index = index_factory(64, "IVF100,PQ16x4fs").unwrap();
//! // build phase (&mut)
//! # let data = vec![0.0f32; 64 * 1000];
//! index.train(&data).unwrap();
//! index.add(&data).unwrap();
//! index.seal().unwrap();
//! // query phase (&self) — per-request overrides, no index mutation
//! let wide = SearchParams::new().with_nprobe(16);
//! let result = index.search(&queries, 10, Some(&wide)).unwrap();
//! ```
//!
//! # The `set_param` compatibility shim
//!
//! [`Index::set_param`] (string key/value, `&mut self`) survives as a thin
//! shim for existing sweep scripts: it parses through the same
//! [`SearchParams::assign`] parser and stores the result as the index's
//! *defaults*. New code should prefer passing [`SearchParams`] per call —
//! the shim mutates shared state and therefore cannot express per-request
//! tuning; it is kept for compatibility and may be removed once callers
//! have migrated.

pub mod factory;
pub mod flat;
pub mod io;
pub mod params;
pub mod pq_index;
pub mod refine;

pub use factory::index_factory;
pub use flat::IndexFlat;
pub use params::{SearchParams, SearchRequest};
pub use pq_index::{IndexIvfPq4, IndexPq, IndexPq4FastScan};
pub use refine::IndexRefineFlat;

use crate::Result;

/// Search output: `nq × k` row-major distances and labels
/// (missing results padded with `(INFINITY, -1)`).
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub k: usize,
    pub distances: Vec<f32>,
    pub labels: Vec<i64>,
}

impl SearchResult {
    /// A well-formed result with no hits: `nq × k` of `(INFINITY, -1)`.
    /// This is what every index returns for `k == 0`, an empty query
    /// batch, or an empty index.
    pub fn empty(nq: usize, k: usize) -> Self {
        Self { k, distances: vec![f32::INFINITY; nq * k], labels: vec![-1; nq * k] }
    }

    pub fn nq(&self) -> usize {
        if self.k == 0 {
            0
        } else {
            self.labels.len() / self.k
        }
    }

    /// Labels of query `qi`.
    pub fn row(&self, qi: usize) -> &[i64] {
        &self.labels[qi * self.k..(qi + 1) * self.k]
    }
}

/// The common index interface (mirrors the faiss `Index` API surface the
/// paper's implementation plugs into, with faiss' newer
/// `SearchParameters`-per-call convention).
///
/// `Send + Sync` is part of the contract: a sealed index must be shareable
/// across threads behind `Arc<dyn Index>`.
pub trait Index: Send + Sync {
    /// Vector dimensionality.
    fn dim(&self) -> usize;
    /// Number of indexed vectors.
    fn ntotal(&self) -> usize;
    /// Whether codebooks/centroids have been trained.
    fn is_trained(&self) -> bool;
    /// Train on `n × dim` vectors (build phase).
    fn train(&mut self, data: &[f32]) -> Result<()>;
    /// Add `n × dim` vectors with sequential ids (build phase).
    fn add(&mut self, data: &[f32]) -> Result<()>;
    /// Finish the build phase: pack staged codes for the search kernel.
    /// Idempotent; indexes without a packing step default to a no-op.
    fn seal(&mut self) -> Result<()> {
        Ok(())
    }
    /// Search a batch of queries (`nq × dim`) for the `k` nearest,
    /// optionally overriding runtime parameters for this call only.
    /// Read-only: safe to call concurrently on a sealed index.
    fn search(&self, queries: &[f32], k: usize, params: Option<&SearchParams>)
        -> Result<SearchResult>;
    /// [`Index::search`] over a bundled [`SearchRequest`].
    fn search_req(&self, req: &SearchRequest<'_>) -> Result<SearchResult> {
        self.search(req.queries, req.k, req.params.as_ref())
    }
    /// Fingerprint of this index's scan-LUT construction (a hash over the
    /// trained quantizer). Two indexes with equal `Some` signatures accept
    /// each other's [`Index::compute_scan_luts`] output — the contract the
    /// coordinator uses to build per-query LUTs **once** per batch group
    /// and reuse them across a shard fan-out. `None` (the default) opts
    /// out of sharing.
    fn lut_signature(&self) -> Option<u64> {
        None
    }
    /// Per-query scan LUTs (`nq × lut_len` f32) for
    /// [`Index::search_with_luts`] on any index with the same
    /// [`Index::lut_signature`]. `None` if this index has no shared-LUT
    /// fast path.
    fn compute_scan_luts(&self, _queries: &[f32]) -> Option<Vec<f32>> {
        None
    }
    /// [`Index::search`] with precomputed LUTs from a signature-equal
    /// index. The default ignores the LUTs and recomputes (always correct,
    /// never faster).
    fn search_with_luts(
        &self,
        queries: &[f32],
        _luts: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<SearchResult> {
        self.search(queries, k, params)
    }
    /// Compatibility shim: set a *default* runtime parameter from strings
    /// (e.g. `"nprobe" = "4"`). Parses through [`SearchParams::assign`];
    /// unknown or unsupported keys error. Prefer per-request
    /// [`SearchParams`].
    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        Err(crate::Error::InvalidParameter(format!("unknown parameter {key}={value}")))
    }
    /// Short human-readable description.
    fn describe(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_result_accessors() {
        let r = SearchResult { k: 2, distances: vec![0.1, 0.2, 0.3, 0.4], labels: vec![5, 6, 7, 8] };
        assert_eq!(r.nq(), 2);
        assert_eq!(r.row(1), &[7, 8]);
    }

    #[test]
    fn empty_result_well_formed() {
        let r = SearchResult::empty(3, 2);
        assert_eq!(r.nq(), 3);
        assert!(r.distances.iter().all(|d| d.is_infinite()));
        assert!(r.labels.iter().all(|&l| l == -1));
        // k = 0: zero-size, nq() must not divide by zero
        let z = SearchResult::empty(5, 0);
        assert_eq!(z.nq(), 0);
        assert!(z.labels.is_empty() && z.distances.is_empty());
    }
}
