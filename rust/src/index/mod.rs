//! Unified index API: the `Index` trait, the concrete index types, and the
//! faiss-style factory strings (`"IVF1000_HNSW32,PQ16x4fs"`).
//!
//! This is the crate's public surface for applications: every index
//! supports `train → add → search`, plus string-keyed runtime parameters
//! (`nprobe`, `ef_search`, `rerank`, …) so benchmark sweeps don't need
//! type-specific code.

pub mod factory;
pub mod flat;
pub mod io;
pub mod pq_index;
pub mod refine;

pub use factory::index_factory;
pub use flat::IndexFlat;
pub use pq_index::{IndexIvfPq4, IndexPq, IndexPq4FastScan};
pub use refine::IndexRefineFlat;

use crate::Result;

/// Search output: `nq × k` row-major distances and labels
/// (missing results padded with `(INFINITY, -1)`).
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub k: usize,
    pub distances: Vec<f32>,
    pub labels: Vec<i64>,
}

impl SearchResult {
    pub fn nq(&self) -> usize {
        self.labels.len() / self.k
    }

    /// Labels of query `qi`.
    pub fn row(&self, qi: usize) -> &[i64] {
        &self.labels[qi * self.k..(qi + 1) * self.k]
    }
}

/// The common index interface (mirrors the faiss `Index` API surface the
/// paper's implementation plugs into).
pub trait Index: Send {
    /// Vector dimensionality.
    fn dim(&self) -> usize;
    /// Number of indexed vectors.
    fn ntotal(&self) -> usize;
    /// Whether codebooks/centroids have been trained.
    fn is_trained(&self) -> bool;
    /// Train on `n × dim` vectors.
    fn train(&mut self, data: &[f32]) -> Result<()>;
    /// Add `n × dim` vectors with sequential ids.
    fn add(&mut self, data: &[f32]) -> Result<()>;
    /// Search a batch of queries (`nq × dim`) for the `k` nearest.
    fn search(&mut self, queries: &[f32], k: usize) -> Result<SearchResult>;
    /// Set a runtime parameter (e.g. `"nprobe" = "4"`). Unknown keys error.
    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        Err(crate::Error::InvalidParameter(format!("unknown parameter {key}={value}")))
    }
    /// Short human-readable description.
    fn describe(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_result_accessors() {
        let r = SearchResult { k: 2, distances: vec![0.1, 0.2, 0.3, 0.4], labels: vec![5, 6, 7, 8] };
        assert_eq!(r.nq(), 2);
        assert_eq!(r.row(1), &[7, 8]);
    }
}
