//! Unified index API: the `Index` trait, the concrete index types, and the
//! faiss-style factory strings (`"IVF1000_HNSW32,PQ16x4fs"`,
//! `"SEG,PQ16x4fs"`).
//!
//! # Lifecycle: the segment contract
//!
//! The fastscan kernels require a frozen, packed code layout. That used to
//! be the *index* lifecycle — build mutably, seal once, query forever —
//! but it is really a **segment** lifecycle: the unit that must be frozen
//! is a packed code block, not the whole index. Two families implement the
//! trait against that contract:
//!
//! * **Sealed indexes** ([`IndexPq4FastScan`], [`IndexIvfPq4`], …) are a
//!   single segment with the build phase exposed: [`Index::train`] fits
//!   codebooks/centroids, [`Index::add`] stages vectors, and
//!   [`Index::seal`] packs the staged codes into the kernel's interleaved
//!   SIMD layout (idempotent; querying unsealed staged codes returns
//!   [`crate::Error::NotSealed`] instead of silently repacking). After
//!   `seal`, queries (`&self`) run lock-free behind `Arc<dyn Index>`.
//! * **The segmented index** ([`crate::segment::SegmentedIndex`], factory
//!   `"SEG,PQ16x4fs"`) runs the same lifecycle *per segment*, continuously:
//!   [`Index::insert`] lands rows in a small exact-scanned memtable,
//!   [`Index::delete`] tombstones sealed rows (compiled into the
//!   [`crate::pq::fastscan::FilterMask`] admission path, composed with any
//!   user filter), [`Index::flush`] seals the memtable into a new packed
//!   segment, and [`Index::compact`] merges the stack and drops tombstoned
//!   rows. All of these take `&self` — mutation happens by swapping an
//!   immutable snapshot, so readers stay lock-free on the sealed stack and
//!   `seal` = `flush` + `compact` degenerates to the one-segment case.
//!
//! Queries are read-only on both families and bit-identical at every
//! executor thread count; a flushed-and-compacted segmented index answers
//! bit-identically to a one-shot sealed index over the surviving rows.
//!
//! # One request/response pair for every query mode
//!
//! [`Index::query`] takes a typed [`QueryRequest`] — the query vectors
//! plus *what to ask* ([`QueryKind::TopK`] or [`QueryKind::Range`]), *who
//! may answer* (an optional [`Filter`]: id bitset, id range, or caller
//! predicate) and *how to search* (the per-request [`SearchParams`]
//! overrides) — and returns a [`QueryResponse`]: per-query
//! variable-length hits plus typed per-query stats (codes scanned, lists
//! probed, filter selectivity).
//!
//! Filters are **pushed down into the fastscan kernels**: the index
//! compiles the `Filter` into a block-aligned bitmask
//! ([`crate::pq::fastscan::FilterMask`]; per probed list for IVF), so a
//! filtered-out vector costs one bit in the SIMD admission mask instead
//! of a post-hoc rescan — and filtered results are bit-identical to
//! post-filtering an unfiltered exhaustive scan. Range queries reuse the
//! u16-quantized LUT threshold in-register and collect hits instead of
//! maintaining a reservoir.
//!
//! ```no_run
//! use armpq::index::{index_factory, Filter, Index, QueryRequest, SearchParams};
//! # let queries = vec![0.0f32; 64];
//! let mut index = index_factory(64, "IVF100,PQ16x4fs").unwrap();
//! // build phase (&mut)
//! # let data = vec![0.0f32; 64 * 1000];
//! index.train(&data).unwrap();
//! index.add(&data).unwrap();
//! index.seal().unwrap();
//! // query phase (&self): filtered top-k with per-request overrides
//! let req = QueryRequest::top_k(&queries, 10)
//!     .with_filter(Filter::id_range(0, 500))
//!     .with_params(SearchParams::new().with_nprobe(16));
//! let resp = index.query(&req).unwrap();
//! println!("hits {:?} selectivity {}", resp.hits[0], resp.stats[0].filter_selectivity);
//! // radius query: every id with distance <= 1.5 (L2-squared)
//! let resp = index.query(&QueryRequest::range(&queries, 1.5)).unwrap();
//! # let _ = resp;
//! ```
//!
//! # The `search` and `set_param` compatibility shims
//!
//! [`Index::search`] survives as a thin shim that builds a `TopK` request
//! and flattens the response into the fixed-shape [`SearchResult`]
//! (`nq × k`, padded with `(INFINITY, -1)`). It is a provided trait
//! method — concrete indexes implement only `query`. Existing callers
//! keep working unchanged; new code should prefer `query`, which can also
//! express filters and radius search. The same deprecation path applies
//! to [`Index::set_param`] (string key/value, `&mut self`): it parses
//! through [`SearchParams::assign`] and stores the result as the index's
//! *defaults* — kept for sweep scripts, superseded by per-request
//! [`SearchParams`].

pub mod factory;
pub mod flat;
pub mod io;
pub mod params;
pub mod pq_index;
pub mod query;
pub mod refine;

pub use factory::index_factory;
pub use flat::IndexFlat;
pub use params::{SearchParams, SearchRequest};
pub use pq_index::{IndexIvfPq4, IndexPq, IndexPq4FastScan};
pub use query::{Filter, Hit, IdSet, QueryKind, QueryRequest, QueryResponse, QueryStats};
pub use refine::IndexRefineFlat;

pub use crate::segment::{SegmentStats, SegmentedIndex};

use crate::exec::QueryExecutor;
use crate::Result;

/// Search output: `nq × k` row-major distances and labels
/// (missing results padded with `(INFINITY, -1)`).
#[derive(Clone, Debug)]
pub struct SearchResult {
    pub k: usize,
    pub distances: Vec<f32>,
    pub labels: Vec<i64>,
}

impl SearchResult {
    /// A well-formed result with no hits: `nq × k` of `(INFINITY, -1)`.
    /// This is what every index returns for `k == 0`, an empty query
    /// batch, or an empty index.
    pub fn empty(nq: usize, k: usize) -> Self {
        Self { k, distances: vec![f32::INFINITY; nq * k], labels: vec![-1; nq * k] }
    }

    pub fn nq(&self) -> usize {
        if self.k == 0 {
            0
        } else {
            self.labels.len() / self.k
        }
    }

    /// Labels of query `qi`.
    pub fn row(&self, qi: usize) -> &[i64] {
        &self.labels[qi * self.k..(qi + 1) * self.k]
    }
}

/// The common index interface (mirrors the faiss `Index` API surface the
/// paper's implementation plugs into, with a typed request/response pair
/// instead of faiss' `search`/`range_search` method family).
///
/// `Send + Sync` is part of the contract: a sealed index must be shareable
/// across threads behind `Arc<dyn Index>`.
pub trait Index: Send + Sync {
    /// Vector dimensionality.
    fn dim(&self) -> usize;
    /// Number of indexed vectors.
    fn ntotal(&self) -> usize;
    /// Whether codebooks/centroids have been trained.
    fn is_trained(&self) -> bool;
    /// Train on `n × dim` vectors (build phase).
    fn train(&mut self, data: &[f32]) -> Result<()>;
    /// Add `n × dim` vectors with sequential ids (build phase).
    fn add(&mut self, data: &[f32]) -> Result<()>;
    /// Finish the build phase: pack staged codes for the search kernel.
    /// Idempotent; indexes without a packing step default to a no-op.
    fn seal(&mut self) -> Result<()> {
        Ok(())
    }
    /// The plan/execute core every index implements: answer a typed
    /// [`QueryRequest`] (top-k or range, optionally filtered, with
    /// per-request parameter overrides) on an explicit
    /// [`crate::exec::QueryExecutor`] — the coordinator threads one shared
    /// executor through every backend; standalone callers go through the
    /// [`Index::query`] shim and the process-global executor. Read-only:
    /// safe to call concurrently on a sealed index, and results are
    /// bit-identical for every executor thread count.
    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse>;
    /// THE query entry point: [`Index::query_exec`] on the process-global
    /// executor (`ARMPQ_THREADS` wide).
    fn query(&self, req: &QueryRequest<'_>) -> Result<QueryResponse> {
        self.query_exec(req, QueryExecutor::global())
    }
    /// [`Index::query_exec`] with precomputed scan LUTs (`nq × lut_len`
    /// f32) from a signature-equal index — the batch-level LUT-reuse entry
    /// the coordinator fans out to shards. The default ignores the LUTs
    /// and recomputes (always correct, never faster).
    fn query_with_luts_exec(
        &self,
        req: &QueryRequest<'_>,
        _luts: &[f32],
        exec: &QueryExecutor,
    ) -> Result<QueryResponse> {
        self.query_exec(req, exec)
    }
    /// [`Index::query_with_luts_exec`] on the process-global executor.
    fn query_with_luts(&self, req: &QueryRequest<'_>, luts: &[f32]) -> Result<QueryResponse> {
        self.query_with_luts_exec(req, luts, QueryExecutor::global())
    }
    /// Compatibility shim over [`Index::query`]: top-k, unfiltered,
    /// flattened into a fixed-shape padded [`SearchResult`].
    fn search(&self, queries: &[f32], k: usize, params: Option<&SearchParams>)
        -> Result<SearchResult> {
        let req = QueryRequest {
            queries,
            kind: QueryKind::TopK { k },
            filter: None,
            params: params.cloned(),
            trace: false,
        };
        Ok(self.query(&req)?.into_search_result(k))
    }
    /// [`Index::search`] over a bundled [`SearchRequest`].
    fn search_req(&self, req: &SearchRequest<'_>) -> Result<SearchResult> {
        self.search(req.queries, req.k, req.params.as_ref())
    }
    /// Fingerprint of this index's scan-LUT construction (a hash over the
    /// trained quantizer). Two indexes with equal `Some` signatures accept
    /// each other's [`Index::compute_scan_luts`] output — the contract the
    /// coordinator uses to build per-query LUTs **once** per batch group
    /// and reuse them across a shard fan-out. `None` (the default) opts
    /// out of sharing.
    fn lut_signature(&self) -> Option<u64> {
        None
    }
    /// Per-query scan LUTs (`nq × lut_len` f32) for
    /// [`Index::query_with_luts`]/[`Index::search_with_luts`] on any index
    /// with the same [`Index::lut_signature`]. `None` if this index has no
    /// shared-LUT fast path.
    fn compute_scan_luts(&self, _queries: &[f32]) -> Option<Vec<f32>> {
        None
    }
    /// [`Index::search`] with precomputed LUTs from a signature-equal
    /// index. Routed through [`Index::query_with_luts`].
    fn search_with_luts(
        &self,
        queries: &[f32],
        luts: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<SearchResult> {
        let req = QueryRequest {
            queries,
            kind: QueryKind::TopK { k },
            filter: None,
            params: params.cloned(),
            trace: false,
        };
        Ok(self.query_with_luts(&req, luts)?.into_search_result(k))
    }
    /// Append `n × dim` vectors to a **streaming** index (`&self`: callable
    /// through `Arc<dyn Index>` concurrently with queries). `ids: None`
    /// assigns sequential ids; explicit ids upsert (an id's previous live
    /// row is replaced). Returns the assigned ids. Sealed single-segment
    /// indexes don't support streaming mutation — build a segmented index
    /// (factory `"SEG,PQ16x4fs"`) instead.
    fn insert(&self, _data: &[f32], _ids: Option<&[i64]>) -> Result<Vec<i64>> {
        Err(crate::Error::InvalidParameter(
            "this index is sealed-only; streaming insert needs a segmented index \
             (factory \"SEG,PQ16x4fs\")"
                .into(),
        ))
    }
    /// Remove rows by id from a streaming index (`&self`); returns how many
    /// live rows were removed. Memtable rows disappear immediately, sealed
    /// rows are tombstoned out of the kernel admission masks.
    fn delete(&self, _ids: &[i64]) -> Result<usize> {
        Err(crate::Error::InvalidParameter(
            "this index is sealed-only; delete needs a segmented index \
             (factory \"SEG,PQ16x4fs\")"
                .into(),
        ))
    }
    /// Streaming maintenance: seal the mutable front into a packed segment.
    /// No-op on sealed single-segment indexes (nothing is ever unfrozen).
    fn flush(&self) -> Result<()> {
        Ok(())
    }
    /// Streaming maintenance: merge sealed segments and drop tombstoned
    /// rows. No-op on sealed single-segment indexes.
    fn compact(&self) -> Result<()> {
        Ok(())
    }
    /// Segment-lifecycle counters, if this index has a segment lifecycle
    /// (`None` for sealed single-segment indexes).
    fn segment_stats(&self) -> Option<SegmentStats> {
        None
    }
    /// Compatibility shim: set a *default* runtime parameter from strings
    /// (e.g. `"nprobe" = "4"`). Parses through [`SearchParams::assign`];
    /// unknown or unsupported keys error. Prefer per-request
    /// [`SearchParams`].
    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        Err(crate::Error::InvalidParameter(format!("unknown parameter {key}={value}")))
    }
    /// Short human-readable description.
    fn describe(&self) -> String;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_result_accessors() {
        let r = SearchResult { k: 2, distances: vec![0.1, 0.2, 0.3, 0.4], labels: vec![5, 6, 7, 8] };
        assert_eq!(r.nq(), 2);
        assert_eq!(r.row(1), &[7, 8]);
    }

    #[test]
    fn empty_result_well_formed() {
        let r = SearchResult::empty(3, 2);
        assert_eq!(r.nq(), 3);
        assert!(r.distances.iter().all(|d| d.is_infinite()));
        assert!(r.labels.iter().all(|&l| l == -1));
        // k = 0: zero-size, nq() must not divide by zero
        let z = SearchResult::empty(5, 0);
        assert_eq!(z.nq(), 0);
        assert!(z.labels.is_empty() && z.distances.is_empty());
    }
}
