//! Exact brute-force index (ground truth / small-scale baseline).

use super::{Index, SearchParams, SearchResult};
use crate::util::threads::{default_threads, parallel_map};
use crate::util::topk::TopK;
use crate::{Error, Result};

/// Uncompressed exact-L2 index.
pub struct IndexFlat {
    dim: usize,
    data: Vec<f32>,
}

impl IndexFlat {
    pub fn new(dim: usize) -> Self {
        Self { dim, data: Vec::new() }
    }

    /// Raw stored vectors (`ntotal × dim`).
    pub fn vectors(&self) -> &[f32] {
        &self.data
    }
}

impl Index for IndexFlat {
    fn dim(&self) -> usize {
        self.dim
    }

    fn ntotal(&self) -> usize {
        self.data.len() / self.dim
    }

    fn is_trained(&self) -> bool {
        true // nothing to train
    }

    fn train(&mut self, _data: &[f32]) -> Result<()> {
        Ok(())
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        self.data.extend_from_slice(data);
        Ok(())
    }

    fn search(
        &self,
        queries: &[f32],
        k: usize,
        _params: Option<&SearchParams>,
    ) -> Result<SearchResult> {
        if queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: queries.len() % self.dim });
        }
        let nq = queries.len() / self.dim;
        let n = self.ntotal();
        if k == 0 || nq == 0 || n == 0 {
            return Ok(SearchResult::empty(nq, k));
        }
        let dim = self.dim;
        let data = &self.data;
        let rows: Vec<(Vec<f32>, Vec<i64>)> = parallel_map(nq, default_threads(), |qi| {
            let q = &queries[qi * dim..(qi + 1) * dim];
            let mut heap = TopK::new(k);
            for i in 0..n {
                let d = crate::util::l2_sq(q, &data[i * dim..(i + 1) * dim]);
                if d < heap.threshold() {
                    heap.push(d, i as i64);
                }
            }
            heap.into_sorted()
        });
        let mut distances = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        for (d, l) in rows {
            distances.extend(d);
            labels.extend(l);
        }
        Ok(SearchResult { k, distances, labels })
    }

    fn describe(&self) -> String {
        format!("Flat(d={}, n={})", self.dim, self.ntotal())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_search() {
        let dim = 8;
        let mut rng = Rng::new(91);
        let data: Vec<f32> = (0..200 * dim).map(|_| rng.next_gaussian()).collect();
        let mut idx = IndexFlat::new(dim);
        idx.add(&data).unwrap();
        assert_eq!(idx.ntotal(), 200);
        // query = row 13 exactly
        let r = idx.search(&data[13 * dim..14 * dim], 3, None).unwrap();
        assert_eq!(r.labels[0], 13);
        assert!(r.distances[0] < 1e-9);
        // distances ascending
        assert!(r.distances[0] <= r.distances[1] && r.distances[1] <= r.distances[2]);
    }

    #[test]
    fn batch_queries() {
        let dim = 4;
        let data: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let mut idx = IndexFlat::new(dim);
        idx.add(&data).unwrap();
        let queries = data[..2 * dim].to_vec();
        let r = idx.search(&queries, 2, None).unwrap();
        assert_eq!(r.nq(), 2);
        assert_eq!(r.row(0)[0], 0);
        assert_eq!(r.row(1)[0], 1);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut idx = IndexFlat::new(4);
        assert!(idx.add(&[1.0; 3]).is_err());
        assert!(idx.search(&[1.0; 5], 1, None).is_err());
    }

    #[test]
    fn degenerate_searches_well_formed() {
        let mut idx = IndexFlat::new(4);
        // empty index: padded result
        let r = idx.search(&[0.0; 4], 2, None).unwrap();
        assert_eq!(r.labels, vec![-1, -1]);
        idx.add(&[0.0; 8]).unwrap();
        // k == 0 and empty batch: well-formed empty results
        assert_eq!(idx.search(&[0.0; 4], 0, None).unwrap().nq(), 0);
        assert_eq!(idx.search(&[], 3, None).unwrap().nq(), 0);
    }
}
