//! Exact brute-force index (ground truth / small-scale baseline).

use super::query::{Hit, QueryKind, QueryRequest, QueryResponse, QueryStats};
use super::Index;
use crate::exec::QueryExecutor;
use crate::util::topk::TopK;
use crate::{Error, Result};

/// Uncompressed exact-L2 index.
pub struct IndexFlat {
    dim: usize,
    data: Vec<f32>,
}

impl IndexFlat {
    pub fn new(dim: usize) -> Self {
        Self { dim, data: Vec::new() }
    }

    /// Raw stored vectors (`ntotal × dim`).
    pub fn vectors(&self) -> &[f32] {
        &self.data
    }
}

impl Index for IndexFlat {
    fn dim(&self) -> usize {
        self.dim
    }

    fn ntotal(&self) -> usize {
        self.data.len() / self.dim
    }

    fn is_trained(&self) -> bool {
        true // nothing to train
    }

    fn train(&mut self, _data: &[f32]) -> Result<()> {
        Ok(())
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        self.data.extend_from_slice(data);
        Ok(())
    }

    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse> {
        req.kind.validate()?;
        if req.queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch {
                expected: self.dim,
                got: req.queries.len() % self.dim,
            });
        }
        let nq = req.queries.len() / self.dim;
        let n = self.ntotal();
        let degenerate = n == 0 || matches!(req.kind, QueryKind::TopK { k: 0 });
        if nq == 0 || degenerate {
            return Ok(QueryResponse::empty(nq));
        }
        let dim = self.dim;
        let data = &self.data;
        let queries = req.queries;
        let kind = req.kind;
        // admission is query-independent: evaluate the filter once per
        // call (labels are identity positions), not once per (query, row)
        let keep_bits: Option<Vec<bool>> = req
            .filter
            .as_ref()
            .map(|f| (0..n as i64).map(|id| f.matches(id)).collect());
        let selectivity = keep_bits
            .as_ref()
            .map(|b| b.iter().filter(|&&x| x).count() as f64 / n as f64)
            .unwrap_or(1.0);
        let keep_bits = keep_bits.as_deref();
        let out: Vec<(Vec<Hit>, QueryStats)> = exec.run_batch(nq, |qi, _scratch| {
            let q = &queries[qi * dim..(qi + 1) * dim];
            let hits: Vec<(f32, i64)> = match kind {
                QueryKind::TopK { k } => {
                    let mut heap = TopK::new(k);
                    for i in 0..n {
                        if keep_bits.is_some_and(|b| !b[i]) {
                            continue;
                        }
                        let d = crate::util::l2_sq(q, &data[i * dim..(i + 1) * dim]);
                        if d < heap.threshold() {
                            heap.push(d, i as i64);
                        }
                    }
                    heap.into_hits()
                }
                QueryKind::Range { radius } => {
                    let mut hits = Vec::new();
                    for i in 0..n {
                        if keep_bits.is_some_and(|b| !b[i]) {
                            continue;
                        }
                        let d = crate::util::l2_sq(q, &data[i * dim..(i + 1) * dim]);
                        if d <= radius {
                            hits.push((d, i as i64));
                        }
                    }
                    hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                    hits
                }
            };
            let stats = QueryStats {
                codes_scanned: n,
                lists_probed: 1,
                filter_selectivity: selectivity,
                ..Default::default()
            };
            (hits.into_iter().map(|(distance, label)| Hit { distance, label }).collect(), stats)
        });
        let mut hits = Vec::with_capacity(nq);
        let mut stats = Vec::with_capacity(nq);
        for (h, s) in out {
            hits.push(h);
            stats.push(s);
        }
        exec.stamp_stats(&mut stats, nq);
        Ok(QueryResponse { hits, stats, traces: Vec::new() })
    }

    fn describe(&self) -> String {
        format!("Flat(d={}, n={})", self.dim, self.ntotal())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_search() {
        let dim = 8;
        let mut rng = Rng::new(91);
        let data: Vec<f32> = (0..200 * dim).map(|_| rng.next_gaussian()).collect();
        let mut idx = IndexFlat::new(dim);
        idx.add(&data).unwrap();
        assert_eq!(idx.ntotal(), 200);
        // query = row 13 exactly
        let r = idx.search(&data[13 * dim..14 * dim], 3, None).unwrap();
        assert_eq!(r.labels[0], 13);
        assert!(r.distances[0] < 1e-9);
        // distances ascending
        assert!(r.distances[0] <= r.distances[1] && r.distances[1] <= r.distances[2]);
    }

    #[test]
    fn batch_queries() {
        let dim = 4;
        let data: Vec<f32> = (0..40).map(|i| i as f32).collect();
        let mut idx = IndexFlat::new(dim);
        idx.add(&data).unwrap();
        let queries = data[..2 * dim].to_vec();
        let r = idx.search(&queries, 2, None).unwrap();
        assert_eq!(r.nq(), 2);
        assert_eq!(r.row(0)[0], 0);
        assert_eq!(r.row(1)[0], 1);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut idx = IndexFlat::new(4);
        assert!(idx.add(&[1.0; 3]).is_err());
        assert!(idx.search(&[1.0; 5], 1, None).is_err());
    }

    #[test]
    fn filtered_and_range_queries_exact() {
        use crate::index::Filter;
        let dim = 4;
        let data: Vec<f32> = (0..80).map(|i| i as f32).collect(); // 20 vectors
        let mut idx = IndexFlat::new(dim);
        idx.add(&data).unwrap();
        let q = &data[..dim]; // == row 0
        // filtered top-k: row 0 excluded → best admitted is row 5
        let req = QueryRequest::top_k(q, 3).with_filter(Filter::id_range(5, 10));
        let r = idx.query(&req).unwrap();
        assert_eq!(r.hits[0][0].label, 5);
        assert!(r.hits[0].iter().all(|h| (5..10).contains(&h.label)));
        assert!((r.stats[0].filter_selectivity - 0.25).abs() < 1e-9);
        assert_eq!(r.stats[0].codes_scanned, 20);
        // range: exact L2² boundary, row 0 at distance 0 then row 1 at 4·16
        let r = idx.query(&QueryRequest::range(q, 64.0)).unwrap();
        assert_eq!(
            r.hits[0].iter().map(|h| h.label).collect::<Vec<_>>(),
            vec![0, 1]
        );
        assert_eq!(r.hits[0][1].distance, 64.0); // boundary inclusive
    }

    #[test]
    fn degenerate_searches_well_formed() {
        let mut idx = IndexFlat::new(4);
        // empty index: padded result
        let r = idx.search(&[0.0; 4], 2, None).unwrap();
        assert_eq!(r.labels, vec![-1, -1]);
        idx.add(&[0.0; 8]).unwrap();
        // k == 0 and empty batch: well-formed empty results
        assert_eq!(idx.search(&[0.0; 4], 0, None).unwrap().nq(), 0);
        assert_eq!(idx.search(&[], 3, None).unwrap().nq(), 0);
    }
}
