//! Index persistence: compact little-endian binary format with magic +
//! version, so trained indexes are built once and served forever
//! (the deployment story behind `armpq serve`).
//!
//! Layout: `ARMPQIDX` magic, u32 version, u32 kind tag, then kind-specific
//! sections. Only fixed-width LE integers/floats — no serde dependency.

use crate::index::pq_index::IndexPq4FastScan;
use crate::ivf::{IvfParams, IvfPq4};
use crate::pq::{CodeWidth, PqParams, ProductQuantizer};
use crate::segment::{Memtable, SealedSegment, SegmentedIndex, SegmentedParams};
use crate::{Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 8] = b"ARMPQIDX";
/// v1: 4-bit only. v2 appends the fastscan code width (+ user-facing M for
/// IVF); v1 files still load as 4-bit. The segmented kinds (manifest +
/// per-segment files) were introduced at v2 directly.
const VERSION: u32 = 2;
const KIND_PQ4FS: u32 = 1;
const KIND_IVFPQ4: u32 = 2;
/// Segmented-index manifest: geometry, codebook, tombstones, memtable, and
/// the segment count — the per-segment code blocks live in sibling
/// [`KIND_SEGMENT`] files.
const KIND_SEGMENTED: u32 = 3;
/// One sealed segment (`{base}.seg{i}`): ids + unpacked code columns;
/// packing is rebuilt at load (same deterministic layout).
const KIND_SEGMENT: u32 = 4;

// ------------------------------------------------------------ primitives

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, x: u32) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, x: u64) -> Result<()> {
        self.w.write_all(&x.to_le_bytes())?;
        Ok(())
    }
    fn f32s(&mut self, xs: &[f32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn bytes(&mut self, xs: &[u8]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        self.w.write_all(xs)?;
        Ok(())
    }
    fn i64s(&mut self, xs: &[i64]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn len_checked(&mut self, elem: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        // 16 GiB sanity cap against corrupt headers
        if n.saturating_mul(elem) > 16 << 30 {
            return Err(Error::Dataset(format!("corrupt length {n}")));
        }
        Ok(n)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_checked(4)?;
        let mut out = vec![0f32; n];
        let mut b = [0u8; 4];
        for x in &mut out {
            self.r.read_exact(&mut b)?;
            *x = f32::from_le_bytes(b);
        }
        Ok(out)
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.len_checked(1)?;
        let mut out = vec![0u8; n];
        self.r.read_exact(&mut out)?;
        Ok(out)
    }
    fn i64s(&mut self) -> Result<Vec<i64>> {
        let n = self.len_checked(8)?;
        let mut out = vec![0i64; n];
        let mut b = [0u8; 8];
        for x in &mut out {
            self.r.read_exact(&mut b)?;
            *x = i64::from_le_bytes(b);
        }
        Ok(out)
    }
}

fn write_pq<W: Write>(w: &mut Writer<W>, pq: &ProductQuantizer) -> Result<()> {
    w.u32(pq.dim as u32)?;
    w.u32(pq.m as u32)?;
    w.u32(pq.ksub as u32)?;
    w.f32s(&pq.centroids)
}

fn read_pq<R: Read>(r: &mut Reader<R>) -> Result<ProductQuantizer> {
    let dim = r.u32()? as usize;
    let m = r.u32()? as usize;
    let ksub = r.u32()? as usize;
    if m == 0 || dim % m != 0 {
        return Err(Error::Dataset("corrupt PQ header".into()));
    }
    let centroids = r.f32s()?;
    if centroids.len() != m * ksub * (dim / m) {
        return Err(Error::Dataset("PQ centroid size mismatch".into()));
    }
    Ok(ProductQuantizer { dim, m, ksub, dsub: dim / m, centroids })
}

// ------------------------------------------------------------ flat PQ4fs

/// Save a trained+filled [`IndexPq4FastScan`] (any code width).
pub fn save_pq4fs(index: &IndexPq4FastScan, path: &Path) -> Result<()> {
    let pq = index.pq().ok_or(Error::NotTrained)?;
    let f = std::fs::File::create(path)?;
    let mut w = Writer { w: BufWriter::new(f) };
    w.w.write_all(MAGIC)?;
    w.u32(VERSION)?;
    w.u32(KIND_PQ4FS)?;
    w.u32(index.width().bits() as u32)?;
    write_pq(&mut w, pq)?;
    w.bytes(index.staging_codes())?;
    Ok(())
}

/// Load an [`IndexPq4FastScan`] (v1 files are 4-bit by definition).
pub fn load_pq4fs(path: &Path) -> Result<IndexPq4FastScan> {
    let f = std::fs::File::open(path)?;
    let mut r = Reader { r: BufReader::new(f) };
    let version = check_header(&mut r, KIND_PQ4FS)?;
    let width = read_width(&mut r, version)?;
    let pq = read_pq(&mut r)?;
    let codes = r.bytes()?;
    IndexPq4FastScan::from_parts_width(pq, codes, width)
}

fn read_width<R: Read>(r: &mut Reader<R>, version: u32) -> Result<CodeWidth> {
    if version < 2 {
        return Ok(CodeWidth::W4);
    }
    let bits = r.u32()? as usize;
    CodeWidth::from_bits(bits)
        .ok_or_else(|| Error::Dataset(format!("corrupt code width {bits}")))
}

// ------------------------------------------------------------ IVF-PQ4

/// Save a trained+filled [`IvfPq4`] (lists are stored unpacked; packing is
/// rebuilt at load time — `from_parts` returns a sealed index).
pub fn save_ivfpq4(index: &IvfPq4, path: &Path) -> Result<()> {
    let pq = index.pq.as_ref().ok_or(Error::NotTrained)?;
    let f = std::fs::File::create(path)?;
    let mut w = Writer { w: BufWriter::new(f) };
    w.w.write_all(MAGIC)?;
    w.u32(VERSION)?;
    w.u32(KIND_IVFPQ4)?;
    w.u32(index.width.bits() as u32)?;
    w.u32(index.pq_m as u32)?;
    w.u32(index.dim as u32)?;
    w.u32(index.params.nlist as u32)?;
    w.u32(if index.params.coarse_hnsw { 1 } else { 0 })?;
    w.u32(index.params.hnsw_m as u32)?;
    w.u64(index.params.seed)?;
    write_pq(&mut w, pq)?;
    w.f32s(index.centroids())?;
    w.u32(index.params.nlist as u32)?;
    for c in 0..index.params.nlist {
        let (ids, codes) = index.list_contents(c);
        w.i64s(ids)?;
        w.bytes(codes)?;
    }
    Ok(())
}

/// Load an [`IvfPq4`]. The HNSW coarse graph (if any) is rebuilt from the
/// centroids deterministically (same seed ⇒ same graph).
pub fn load_ivfpq4(path: &Path) -> Result<IvfPq4> {
    let f = std::fs::File::open(path)?;
    let mut r = Reader { r: BufReader::new(f) };
    let version = check_header(&mut r, KIND_IVFPQ4)?;
    let (width, m_stored) = if version >= 2 {
        let w = read_width(&mut r, version)?;
        (w, Some(r.u32()? as usize))
    } else {
        (CodeWidth::W4, None)
    };
    let dim = r.u32()? as usize;
    let nlist = r.u32()? as usize;
    let coarse_hnsw = r.u32()? == 1;
    let hnsw_m = r.u32()? as usize;
    let seed = r.u64()?;
    let pq = read_pq(&mut r)?;
    let centroids = r.f32s()?;
    if centroids.len() != nlist * dim {
        return Err(Error::Dataset("centroid size mismatch".into()));
    }
    let nlist2 = r.u32()? as usize;
    if nlist2 != nlist {
        return Err(Error::Dataset("list count mismatch".into()));
    }
    let mut lists = Vec::with_capacity(nlist);
    for _ in 0..nlist {
        let ids = r.i64s()?;
        let codes = r.bytes()?;
        if codes.len() != ids.len() * pq.m {
            return Err(Error::Dataset("list codes mismatch".into()));
        }
        lists.push((ids, codes));
    }
    let mut params = IvfParams::new(nlist);
    params.coarse_hnsw = coarse_hnsw;
    params.hnsw_m = hnsw_m;
    params.seed = seed;
    let pq_params = PqParams { m: pq.m, ksub: pq.ksub, train_iters: 0, seed };
    let m = m_stored.unwrap_or(pq.m); // v1: user M == internal columns
    IvfPq4::from_parts(dim, params, pq_params, m, width, pq, centroids, lists)
}

// ------------------------------------------------------------ segmented

/// The sibling file holding segment `i` of the manifest at `base`.
fn segment_path(base: &Path, i: usize) -> PathBuf {
    let mut name = base.as_os_str().to_os_string();
    name.push(format!(".seg{i}"));
    PathBuf::from(name)
}

/// Save a trained [`SegmentedIndex`]: a manifest at `path` plus one
/// `{path}.seg{i}` file per sealed segment. The snapshot is taken once, so
/// a save concurrent with inserts captures a consistent point in time.
pub fn save_segmented(index: &SegmentedIndex, path: &Path) -> Result<()> {
    let (dim, m, width, params, pq, snap, next_id) = index.parts();
    let pq = pq.ok_or(Error::NotTrained)?;
    let f = std::fs::File::create(path)?;
    let mut w = Writer { w: BufWriter::new(f) };
    w.w.write_all(MAGIC)?;
    w.u32(VERSION)?;
    w.u32(KIND_SEGMENTED)?;
    w.u32(width.bits() as u32)?;
    w.u32(m as u32)?;
    w.u32(dim as u32)?;
    w.u64(params.flush_threshold as u64)?;
    w.u64(params.max_segments as u64)?;
    w.u64(next_id as u64)?;
    write_pq(&mut w, &pq)?;
    // sorted for byte-deterministic output (HashSet order is not)
    let mut tombs: Vec<i64> = snap.tombstones.iter().copied().collect();
    tombs.sort_unstable();
    w.i64s(&tombs)?;
    w.i64s(snap.memtable.ids())?;
    w.f32s(snap.memtable.vectors())?;
    w.bytes(snap.memtable.codes())?;
    w.u32(snap.segments.len() as u32)?;
    drop(w);
    for (i, seg) in snap.segments.iter().enumerate() {
        let f = std::fs::File::create(segment_path(path, i))?;
        let mut w = Writer { w: BufWriter::new(f) };
        w.w.write_all(MAGIC)?;
        w.u32(VERSION)?;
        w.u32(KIND_SEGMENT)?;
        w.u32(width.bits() as u32)?;
        w.i64s(&seg.ids)?;
        w.bytes(&seg.codes)?;
    }
    Ok(())
}

/// Load a [`SegmentedIndex`] saved by [`save_segmented`]: the manifest at
/// `path` plus its `{path}.seg{i}` siblings. Packed layouts are rebuilt
/// deterministically, so queries answer bit-identically to the saved
/// instance.
pub fn load_segmented(path: &Path) -> Result<SegmentedIndex> {
    let f = std::fs::File::open(path)?;
    let mut r = Reader { r: BufReader::new(f) };
    let version = check_header(&mut r, KIND_SEGMENTED)?;
    let width = read_width(&mut r, version)?;
    let m = r.u32()? as usize;
    let dim = r.u32()? as usize;
    let params = SegmentedParams {
        flush_threshold: r.u64()? as usize,
        max_segments: r.u64()? as usize,
    };
    let next_id = r.u64()? as i64;
    let pq = read_pq(&mut r)?;
    let tombstones: std::collections::HashSet<i64> = r.i64s()?.into_iter().collect();
    let mem_ids = r.i64s()?;
    let mem_vectors = r.f32s()?;
    let mem_codes = r.bytes()?;
    let code_cols = width.code_columns(m);
    if mem_vectors.len() != mem_ids.len() * dim || mem_codes.len() != mem_ids.len() * code_cols {
        return Err(Error::Dataset("segmented manifest: memtable size mismatch".into()));
    }
    let memtable = Memtable::from_parts(mem_ids, mem_vectors, mem_codes);
    let nseg = r.u32()? as usize;
    let mut segments = Vec::with_capacity(nseg);
    for i in 0..nseg {
        let f = std::fs::File::open(segment_path(path, i))?;
        let mut r = Reader { r: BufReader::new(f) };
        let version = check_header(&mut r, KIND_SEGMENT)?;
        let seg_width = read_width(&mut r, version)?;
        if seg_width != width {
            return Err(Error::Dataset(format!(
                "segment {i}: width {seg_width} does not match manifest {width}"
            )));
        }
        let ids = r.i64s()?;
        let codes = r.bytes()?;
        // build() re-validates shape and re-packs the kernel layout
        segments.push(SealedSegment::build(ids, codes, m, width)?);
    }
    SegmentedIndex::from_parts(
        dim, m, width, params, pq, segments, tombstones, memtable, next_id,
    )
}

fn check_header<R: Read>(r: &mut Reader<R>, expect_kind: u32) -> Result<u32> {
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Dataset("not an armpq index file".into()));
    }
    let version = r.u32()?;
    if !(1..=VERSION).contains(&version) {
        return Err(Error::Dataset(format!("unsupported index version {version}")));
    }
    let kind = r.u32()?;
    if kind != expect_kind {
        return Err(Error::Dataset(format!("wrong index kind {kind} (expected {expect_kind})")));
    }
    Ok(version)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;
    use crate::index::Index;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("armpq_idxio_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn pq4fs_roundtrip_identical_results() {
        let ds = SyntheticDataset::gaussian(1_000, 10, 32, 201);
        let mut idx = IndexPq4FastScan::new(ds.dim, 8);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let before = idx.search(&ds.queries, 5, None).unwrap();

        let path = tmp("flat.armpq");
        save_pq4fs(&idx, &path).unwrap();
        let loaded = load_pq4fs(&path).unwrap();
        assert_eq!(loaded.ntotal(), 1_000);
        assert!(loaded.is_sealed(), "load must return a sealed index");
        let after = loaded.search(&ds.queries, 5, None).unwrap();
        assert_eq!(before.labels, after.labels);
        assert_eq!(before.distances, after.distances);
    }

    #[test]
    fn ivfpq4_roundtrip_identical_results() {
        let ds = SyntheticDataset::gaussian(1_500, 10, 16, 202);
        let mut params = IvfParams::new(8);
        params.coarse_hnsw = true;
        params.hnsw_m = 8;
        let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(4));
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.nprobe = 8;
        idx.seal().unwrap();
        let (d0, l0) = idx.search(&ds.queries, 5).unwrap();

        let path = tmp("ivf.armpq");
        save_ivfpq4(&idx, &path).unwrap();
        let mut loaded = load_ivfpq4(&path).unwrap();
        loaded.nprobe = 8;
        assert_eq!(loaded.ntotal(), 1_500);
        assert!(loaded.is_sealed(), "load must return a sealed index");
        let (d1, l1) = loaded.search(&ds.queries, 5).unwrap();
        assert_eq!(l0, l1);
        assert_eq!(d0, d1);
    }

    /// Every fastscan width survives the save/load cycle with identical
    /// results (the v2 format carries the width).
    #[test]
    fn width_roundtrips_identically() {
        let ds = SyntheticDataset::gaussian(800, 8, 32, 205);
        for width in CodeWidth::ALL {
            let mut idx = crate::index::pq_index::IndexPq4FastScan::new_width(ds.dim, 8, width);
            idx.train(&ds.train).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            let before = idx.search(&ds.queries, 5, None).unwrap();
            let path = tmp(&format!("flat_w{}.armpq", width.bits()));
            save_pq4fs(&idx, &path).unwrap();
            let loaded = load_pq4fs(&path).unwrap();
            assert_eq!(loaded.width(), width);
            let after = loaded.search(&ds.queries, 5, None).unwrap();
            assert_eq!(before.labels, after.labels, "{width}");
            assert_eq!(before.distances, after.distances, "{width}");
        }
        // IVF at a non-default width
        let mut idx = IvfPq4::new_width(ds.dim, IvfParams::new(4), 8, CodeWidth::W2);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.nprobe = 4;
        idx.seal().unwrap();
        let (d0, l0) = idx.search(&ds.queries, 5).unwrap();
        let path = tmp("ivf_w2.armpq");
        save_ivfpq4(&idx, &path).unwrap();
        let mut loaded = load_ivfpq4(&path).unwrap();
        loaded.nprobe = 4;
        assert_eq!(loaded.width, CodeWidth::W2);
        assert_eq!(loaded.pq_m, 8);
        let (d1, l1) = loaded.search(&ds.queries, 5).unwrap();
        assert_eq!(l0, l1);
        assert_eq!(d0, d1);
    }

    #[test]
    fn rejects_wrong_magic_and_kind() {
        let path = tmp("bad.armpq");
        std::fs::write(&path, b"NOTANIDX0000000000000000").unwrap();
        assert!(load_pq4fs(&path).is_err());

        // valid flat index loaded as IVF must fail on the kind tag
        let ds = SyntheticDataset::gaussian(500, 2, 16, 203);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let path2 = tmp("flat2.armpq");
        save_pq4fs(&idx, &path2).unwrap();
        let err = match load_ivfpq4(&path2) {
            Err(e) => e,
            Ok(_) => panic!("loading flat index as IVF must fail"),
        };
        assert!(err.to_string().contains("kind"), "{err}");
    }

    #[test]
    fn untrained_save_fails() {
        let idx = IndexPq4FastScan::new(16, 4);
        assert!(save_pq4fs(&idx, &tmp("x.armpq")).is_err());
    }

    #[test]
    fn truncated_file_fails_cleanly() {
        let ds = SyntheticDataset::gaussian(300, 2, 16, 204);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let path = tmp("trunc.armpq");
        save_pq4fs(&idx, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_pq4fs(&path).is_err());
    }
}
