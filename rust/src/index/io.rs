//! Index persistence: compact little-endian binary format with magic +
//! version, so trained indexes are built once and served forever
//! (the deployment story behind `armpq serve`).
//!
//! Layout: `ARMPQIDX` magic, u32 version, u32 kind tag, then kind-specific
//! sections. Only fixed-width LE integers/floats — no serde dependency.
//!
//! # Format v3: page-aligned packed code regions
//!
//! v3 stores every packed code block (the kernel's interleaved layout) as
//! a *code region*: a u64 byte length, zero padding up to the next
//! 64-byte absolute file offset, then the packed bytes verbatim. Because
//! an `mmap` base address is page-aligned, every region is 64-byte
//! aligned in memory too — so [`open_index`]/`load_*_with` can hand the
//! kernels zero-copy [`CodeStore::Mapped`] windows straight into the
//! file. Heap loads read the same regions into owned buffers; both paths
//! answer bit-identically. v1/v2 files (flat code columns, repacked at
//! load) continue to load.
//!
//! All saves are crash-safe: content is written to a `{path}.tmp`
//! sibling, fsynced, and atomically renamed over the target. Loaders
//! report truncated or corrupt files as [`Error::CorruptIndex`] instead
//! of surfacing a bare I/O error mid-read.

use crate::index::pq_index::{IndexIvfPq4, IndexPq4FastScan};
use crate::index::Index;
use crate::ivf::{IvfParams, IvfPq4};
use crate::pq::{CodeWidth, PackedCodes, PqParams, ProductQuantizer};
use crate::segment::{Memtable, SealedSegment, SegmentedIndex, SegmentedParams};
use crate::storage::{CodeStore, MemoryBudget, Mmap, OpenOptions};
use crate::{Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: &[u8; 8] = b"ARMPQIDX";
/// v1: 4-bit only. v2 appends the fastscan code width (+ user-facing M for
/// IVF); v1 files still load as 4-bit. The segmented kinds (manifest +
/// per-segment files) were introduced at v2 directly. v3 stores packed
/// code blocks as 64-byte-aligned regions (mmap-able zero-copy) and
/// stamps segment files with a content hash; v1/v2 files still load.
const VERSION: u32 = 3;
const KIND_PQ4FS: u32 = 1;
const KIND_IVFPQ4: u32 = 2;
/// Segmented-index manifest: geometry, codebook, tombstones, memtable, and
/// the segment count — the per-segment code blocks live in sibling
/// [`KIND_SEGMENT`] files.
const KIND_SEGMENTED: u32 = 3;
/// One sealed segment (`{base}.seg{i}`): ids + packed code region (v3) or
/// unpacked code columns (v2, repacked at load).
const KIND_SEGMENT: u32 = 4;

/// Code regions begin at multiples of this absolute file offset, matching
/// the cache-line granularity the dual-lane kernels stream at.
const CODE_ALIGN: usize = 64;

/// Sanity cap applied to every length header (simultaneously a corrupt-
/// file guard and an OOM guard: no section is ever this large).
const MAX_SECTION: usize = 16 << 30;

// ------------------------------------------------------------ primitives

fn pad_to_align(pos: u64) -> usize {
    ((CODE_ALIGN as u64 - pos % CODE_ALIGN as u64) % CODE_ALIGN as u64) as usize
}

/// FNV-1a over `bytes`, chained from `h` (seed [`FNV_SEED`]).
const FNV_SEED: u64 = 0xcbf2_9ce4_8422_2325;
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct Writer<W: Write> {
    w: W,
    /// Absolute file offset of the next byte — code-region padding is
    /// computed from this, so writer and loader can never disagree.
    pos: u64,
}

impl<W: Write> Writer<W> {
    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.w.write_all(bytes)?;
        self.pos += bytes.len() as u64;
        Ok(())
    }
    fn u32(&mut self, x: u32) -> Result<()> {
        self.put(&x.to_le_bytes())
    }
    fn u64(&mut self, x: u64) -> Result<()> {
        self.put(&x.to_le_bytes())
    }
    fn f32s(&mut self, xs: &[f32]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.put(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn bytes(&mut self, xs: &[u8]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        self.put(xs)
    }
    fn i64s(&mut self, xs: &[i64]) -> Result<()> {
        self.u64(xs.len() as u64)?;
        for &x in xs {
            self.put(&x.to_le_bytes())?;
        }
        Ok(())
    }
    /// One v3 code region: u64 length, zero padding to the next 64-byte
    /// file offset, then the packed bytes verbatim.
    fn code_region(&mut self, data: &[u8]) -> Result<()> {
        self.u64(data.len() as u64)?;
        let pad = pad_to_align(self.pos);
        self.put(&[0u8; CODE_ALIGN][..pad])?;
        self.put(data)
    }
    fn header(&mut self, kind: u32) -> Result<()> {
        self.put(MAGIC)?;
        self.u32(VERSION)?;
        self.u32(kind)
    }
}

/// Write `path` crash-safely: the content goes to a `{path}.tmp` sibling,
/// is flushed + fsynced, and atomically renamed over the target — a crash
/// mid-save leaves the previous file intact, never a torn one.
fn atomic_write(
    path: &Path,
    write: impl FnOnce(&mut Writer<BufWriter<std::fs::File>>) -> Result<()>,
) -> Result<()> {
    let tmp = {
        let mut name = path.as_os_str().to_os_string();
        name.push(".tmp");
        PathBuf::from(name)
    };
    let f = std::fs::File::create(&tmp)?;
    let mut w = Writer { w: BufWriter::new(f), pos: 0 };
    let res = write(&mut w).and_then(|()| {
        w.w.flush()?;
        w.w.get_ref().sync_all()?;
        Ok(())
    });
    if let Err(e) = res {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// The read side of the format, implemented by a buffered file (heap
/// loads: code regions are copied into owned buffers) and by a mapped
/// file (zero-copy loads: code regions become [`CodeStore::Mapped`]
/// windows). Each kind's loader is written once against this trait.
trait IndexSource {
    /// Read exactly `buf.len()` bytes; a short read is a corrupt file.
    fn fill(&mut self, buf: &mut [u8]) -> Result<()>;
    fn skip(&mut self, n: usize) -> Result<()>;
    fn position(&self) -> u64;
    /// One v3 code region (see [`Writer::code_region`]).
    fn code_region(&mut self) -> Result<CodeStore>;

    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.fill(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn len_checked(&mut self, elem: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        if n.saturating_mul(elem) > MAX_SECTION {
            return Err(Error::CorruptIndex(format!("implausible section length {n}")));
        }
        Ok(n)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len_checked(4)?;
        let mut out = vec![0f32; n];
        let mut b = [0u8; 4];
        for x in &mut out {
            self.fill(&mut b)?;
            *x = f32::from_le_bytes(b);
        }
        Ok(out)
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.len_checked(1)?;
        let mut out = vec![0u8; n];
        self.fill(&mut out)?;
        Ok(out)
    }
    fn i64s(&mut self) -> Result<Vec<i64>> {
        let n = self.len_checked(8)?;
        let mut out = vec![0i64; n];
        let mut b = [0u8; 8];
        for x in &mut out {
            self.fill(&mut b)?;
            *x = i64::from_le_bytes(b);
        }
        Ok(out)
    }
}

struct FileSource {
    r: BufReader<std::fs::File>,
    pos: u64,
}

impl FileSource {
    fn open(path: &Path) -> Result<Self> {
        Ok(Self { r: BufReader::new(std::fs::File::open(path)?), pos: 0 })
    }
}

impl IndexSource for FileSource {
    fn fill(&mut self, buf: &mut [u8]) -> Result<()> {
        self.r.read_exact(buf).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Error::CorruptIndex(format!("unexpected end of file at offset {}", self.pos))
            } else {
                Error::from(e)
            }
        })?;
        self.pos += buf.len() as u64;
        Ok(())
    }
    fn skip(&mut self, n: usize) -> Result<()> {
        let mut buf = [0u8; CODE_ALIGN];
        let mut left = n;
        while left > 0 {
            let take = left.min(CODE_ALIGN);
            self.fill(&mut buf[..take])?;
            left -= take;
        }
        Ok(())
    }
    fn position(&self) -> u64 {
        self.pos
    }
    fn code_region(&mut self) -> Result<CodeStore> {
        let len = self.len_checked(1)?;
        self.skip(pad_to_align(self.pos))?;
        let mut out = vec![0u8; len];
        self.fill(&mut out)?;
        Ok(CodeStore::from(out))
    }
}

/// A mapped index file: scalar sections are decoded by copying (they are
/// tiny), code regions become zero-copy windows into the shared map, each
/// admitted against the open's [`MemoryBudget`].
struct MapSource {
    map: Arc<Mmap>,
    pos: usize,
    budget: MemoryBudget,
}

impl MapSource {
    fn open(path: &Path, budget: MemoryBudget) -> Result<Self> {
        Ok(Self { map: Arc::new(Mmap::open(path)?), pos: 0, budget })
    }
}

impl IndexSource for MapSource {
    fn fill(&mut self, buf: &mut [u8]) -> Result<()> {
        let end = self.pos.checked_add(buf.len()).filter(|&e| e <= self.map.len());
        let Some(end) = end else {
            return Err(Error::CorruptIndex(format!(
                "unexpected end of file at offset {}",
                self.pos
            )));
        };
        buf.copy_from_slice(&self.map[self.pos..end]);
        self.pos = end;
        Ok(())
    }
    fn skip(&mut self, n: usize) -> Result<()> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.map.len());
        let Some(end) = end else {
            return Err(Error::CorruptIndex(format!(
                "unexpected end of file at offset {}",
                self.pos
            )));
        };
        self.pos = end;
        Ok(())
    }
    fn position(&self) -> u64 {
        self.pos as u64
    }
    fn code_region(&mut self) -> Result<CodeStore> {
        let len = self.len_checked(1)?;
        self.skip(pad_to_align(self.pos as u64))?;
        let offset = self.pos;
        let store = CodeStore::from_mapped(self.map.clone(), offset, len)?;
        self.skip(len)?;
        self.budget.admit_region(&self.map, offset, len);
        Ok(store)
    }
}

fn write_pq<W: Write>(w: &mut Writer<W>, pq: &ProductQuantizer) -> Result<()> {
    w.u32(pq.dim as u32)?;
    w.u32(pq.m as u32)?;
    w.u32(pq.ksub as u32)?;
    w.f32s(&pq.centroids)
}

fn read_pq<S: IndexSource>(r: &mut S) -> Result<ProductQuantizer> {
    let dim = r.u32()? as usize;
    let m = r.u32()? as usize;
    let ksub = r.u32()? as usize;
    if m == 0 || dim % m != 0 {
        return Err(Error::CorruptIndex("corrupt PQ header".into()));
    }
    let centroids = r.f32s()?;
    if centroids.len() != m * ksub * (dim / m) {
        return Err(Error::CorruptIndex("PQ centroid size mismatch".into()));
    }
    Ok(ProductQuantizer { dim, m, ksub, dsub: dim / m, centroids })
}

/// User-facing sub-quantizer count of an internal quantizer at `width`
/// (8-bit fastscan splits each user sub-quantizer over two columns).
fn user_m(width: CodeWidth, pq_m: usize) -> usize {
    match width {
        CodeWidth::W8 => pq_m / 2,
        _ => pq_m,
    }
}

// ------------------------------------------------------------ flat PQ4fs

/// Save a trained+filled [`IndexPq4FastScan`] (any code width) in format
/// v3: the packed block is written as an aligned code region, so the file
/// can be reopened zero-copy. Unsealed staging codes are packed on the
/// fly (the file always holds the kernel layout).
pub fn save_pq4fs(index: &IndexPq4FastScan, path: &Path) -> Result<()> {
    let pq = index.pq().ok_or(Error::NotTrained)?;
    let width = index.width();
    let mut on_the_fly = None;
    let packed: Option<&PackedCodes> = match index.packed() {
        Some(p) => Some(p),
        None if !index.staging_codes().is_empty() => {
            on_the_fly =
                Some(PackedCodes::pack(index.staging_codes(), user_m(width, pq.m), width)?);
            on_the_fly.as_ref()
        }
        None => None,
    };
    atomic_write(path, |w| {
        w.header(KIND_PQ4FS)?;
        w.u32(width.bits() as u32)?;
        write_pq(w, pq)?;
        match packed {
            Some(p) => {
                w.u64(p.n as u64)?;
                w.u32(p.m as u32)?;
                w.code_region(&p.data)
            }
            None => {
                w.u64(0)?;
                w.u32(user_m(width, pq.m) as u32)?;
                w.code_region(&[])
            }
        }
    })
}

/// Load an [`IndexPq4FastScan`] into heap memory (v1 files are 4-bit by
/// definition).
pub fn load_pq4fs(path: &Path) -> Result<IndexPq4FastScan> {
    load_pq4fs_with(path, &OpenOptions::default())
}

/// [`load_pq4fs`] with explicit open options: `opts.mmap` maps the file
/// and adopts the packed block zero-copy (v3 files; older versions fall
/// back to a copying load through the same map).
pub fn load_pq4fs_with(path: &Path, opts: &OpenOptions) -> Result<IndexPq4FastScan> {
    if opts.mmap {
        load_pq4fs_src(&mut MapSource::open(path, opts.budget())?)
    } else {
        load_pq4fs_src(&mut FileSource::open(path)?)
    }
}

fn load_pq4fs_src<S: IndexSource>(r: &mut S) -> Result<IndexPq4FastScan> {
    let version = check_header(r, KIND_PQ4FS)?;
    let width = read_width(r, version)?;
    let pq = read_pq(r)?;
    if version < 3 {
        let codes = r.bytes()?;
        return IndexPq4FastScan::from_parts_width(pq, codes, width);
    }
    let n = r.len_checked(1)?;
    let m = r.u32()? as usize;
    let store = r.code_region()?;
    let packed = PackedCodes::from_store(store, n, m, width)?;
    IndexPq4FastScan::from_packed_width(pq, packed, width)
}

fn read_width<S: IndexSource>(r: &mut S, version: u32) -> Result<CodeWidth> {
    if version < 2 {
        return Ok(CodeWidth::W4);
    }
    let bits = r.u32()? as usize;
    CodeWidth::from_bits(bits)
        .ok_or_else(|| Error::CorruptIndex(format!("corrupt code width {bits}")))
}

// ------------------------------------------------------------ IVF-PQ4

/// Save a trained+filled [`IvfPq4`] in format v3: each list's packed
/// block is an aligned code region (empty lists write a zero-length
/// region), so probed lists can be scanned straight off the map.
pub fn save_ivfpq4(index: &IvfPq4, path: &Path) -> Result<()> {
    let pq = index.pq.as_ref().ok_or(Error::NotTrained)?;
    atomic_write(path, |w| {
        w.header(KIND_IVFPQ4)?;
        w.u32(index.width.bits() as u32)?;
        w.u32(index.pq_m as u32)?;
        w.u32(index.dim as u32)?;
        w.u32(index.params.nlist as u32)?;
        w.u32(if index.params.coarse_hnsw { 1 } else { 0 })?;
        w.u32(index.params.hnsw_m as u32)?;
        w.u64(index.params.seed)?;
        write_pq(w, pq)?;
        w.f32s(index.centroids())?;
        w.u32(index.params.nlist as u32)?;
        for c in 0..index.params.nlist {
            let (ids, staging) = index.list_contents(c);
            w.i64s(ids)?;
            match index.list_packed(c) {
                Some(p) => w.code_region(&p.data)?,
                None if !ids.is_empty() => {
                    // unsealed list: pack on the fly so the file always
                    // holds the kernel layout
                    let p = PackedCodes::pack(staging, index.pq_m, index.width)?;
                    w.code_region(&p.data)?;
                }
                None => w.code_region(&[])?,
            }
        }
        Ok(())
    })
}

/// Load an [`IvfPq4`] into heap memory. The HNSW coarse graph (if any) is
/// rebuilt from the centroids deterministically (same seed ⇒ same graph).
pub fn load_ivfpq4(path: &Path) -> Result<IvfPq4> {
    load_ivfpq4_with(path, &OpenOptions::default())
}

/// [`load_ivfpq4`] with explicit open options (see [`load_pq4fs_with`]).
pub fn load_ivfpq4_with(path: &Path, opts: &OpenOptions) -> Result<IvfPq4> {
    if opts.mmap {
        load_ivfpq4_src(&mut MapSource::open(path, opts.budget())?)
    } else {
        load_ivfpq4_src(&mut FileSource::open(path)?)
    }
}

fn load_ivfpq4_src<S: IndexSource>(r: &mut S) -> Result<IvfPq4> {
    let version = check_header(r, KIND_IVFPQ4)?;
    let (width, m_stored) = if version >= 2 {
        let w = read_width(r, version)?;
        (w, Some(r.u32()? as usize))
    } else {
        (CodeWidth::W4, None)
    };
    let dim = r.u32()? as usize;
    let nlist = r.u32()? as usize;
    let coarse_hnsw = r.u32()? == 1;
    let hnsw_m = r.u32()? as usize;
    let seed = r.u64()?;
    let pq = read_pq(r)?;
    let centroids = r.f32s()?;
    if centroids.len() != nlist * dim {
        return Err(Error::CorruptIndex("centroid size mismatch".into()));
    }
    let nlist2 = r.u32()? as usize;
    if nlist2 != nlist {
        return Err(Error::CorruptIndex("list count mismatch".into()));
    }
    let mut params = IvfParams::new(nlist);
    params.coarse_hnsw = coarse_hnsw;
    params.hnsw_m = hnsw_m;
    params.seed = seed;
    let pq_params = PqParams { m: pq.m, ksub: pq.ksub, train_iters: 0, seed };
    let m = m_stored.unwrap_or(pq.m); // v1: user M == internal columns
    if version < 3 {
        let mut lists = Vec::with_capacity(nlist);
        for _ in 0..nlist {
            let ids = r.i64s()?;
            let codes = r.bytes()?;
            if codes.len() != ids.len() * pq.m {
                return Err(Error::CorruptIndex("list codes mismatch".into()));
            }
            lists.push((ids, codes));
        }
        return IvfPq4::from_parts(dim, params, pq_params, m, width, pq, centroids, lists);
    }
    let mut lists = Vec::with_capacity(nlist);
    for c in 0..nlist {
        let ids = r.i64s()?;
        let store = r.code_region()?;
        let packed = if ids.is_empty() {
            if !store.is_empty() {
                return Err(Error::CorruptIndex(format!(
                    "list {c}: empty list with a {}-byte code region",
                    store.len()
                )));
            }
            None
        } else {
            Some(PackedCodes::from_store(store, ids.len(), m, width)?)
        };
        lists.push((ids, packed));
    }
    IvfPq4::from_packed_parts(dim, params, pq_params, m, width, pq, centroids, lists)
}

// ------------------------------------------------------------ segmented

/// The sibling file holding segment `i` of the manifest at `base`.
fn segment_path(base: &Path, i: usize) -> PathBuf {
    let mut name = base.as_os_str().to_os_string();
    name.push(format!(".seg{i}"));
    PathBuf::from(name)
}

/// Content stamp of one segment file: FNV-1a over the geometry, ids, and
/// packed bytes. Stored in the v3 segment header so an unchanged sealed
/// segment can be recognized (and its rewrite skipped) without reading
/// the whole file back.
fn segment_stamp(width: CodeWidth, ids: &[i64], data: &[u8]) -> u64 {
    let mut h = fnv1a(FNV_SEED, &(width.bits() as u64).to_le_bytes());
    h = fnv1a(h, &(ids.len() as u64).to_le_bytes());
    for &id in ids {
        h = fnv1a(h, &id.to_le_bytes());
    }
    h = fnv1a(h, &(data.len() as u64).to_le_bytes());
    fnv1a(h, data)
}

/// Exact byte length [`save_segmented`] produces for a v3 segment file
/// with `n` ids and `data_len` packed bytes — mirrors the writer.
fn segment_file_len(n: usize, data_len: usize) -> u64 {
    // magic(8) + version(4) + kind(4) + width(4) + stamp(8) = 28,
    // i64s = 8 + 8n, region length field = 8
    let before_pad = 28 + 8 + 8 * n as u64 + 8;
    before_pad + pad_to_align(before_pad) as u64 + data_len as u64
}

/// The stamp of an existing v3 segment file, or `None` when the file is
/// missing, an older version, or not a segment file at all.
fn read_segment_stamp(path: &Path) -> Option<u64> {
    let mut f = std::fs::File::open(path).ok()?;
    let mut head = [0u8; 28];
    f.read_exact(&mut head).ok()?;
    if &head[..8] != MAGIC {
        return None;
    }
    let version = u32::from_le_bytes(head[8..12].try_into().unwrap());
    let kind = u32::from_le_bytes(head[12..16].try_into().unwrap());
    if version != VERSION || kind != KIND_SEGMENT {
        return None;
    }
    Some(u64::from_le_bytes(head[20..28].try_into().unwrap()))
}

/// Save a trained [`SegmentedIndex`]: a manifest at `path` plus one
/// `{path}.seg{i}` file per sealed segment. The snapshot is taken once, so
/// a save concurrent with inserts captures a consistent point in time.
///
/// Sealed segments are immutable, so a segment file whose length and
/// content stamp already match is left untouched — repeated flush+save
/// cycles cost O(memtable), not O(index).
pub fn save_segmented(index: &SegmentedIndex, path: &Path) -> Result<()> {
    let (dim, m, width, params, pq, snap, next_id) = index.parts();
    let pq = pq.ok_or(Error::NotTrained)?;
    atomic_write(path, |w| {
        w.header(KIND_SEGMENTED)?;
        w.u32(width.bits() as u32)?;
        w.u32(m as u32)?;
        w.u32(dim as u32)?;
        w.u64(params.flush_threshold as u64)?;
        w.u64(params.max_segments as u64)?;
        w.u64(next_id as u64)?;
        write_pq(w, &pq)?;
        // sorted for byte-deterministic output (HashSet order is not)
        let mut tombs: Vec<i64> = snap.tombstones.iter().copied().collect();
        tombs.sort_unstable();
        w.i64s(&tombs)?;
        w.i64s(snap.memtable.ids())?;
        w.f32s(snap.memtable.vectors())?;
        w.bytes(snap.memtable.codes())?;
        w.u32(snap.segments.len() as u32)
    })?;
    for (i, seg) in snap.segments.iter().enumerate() {
        let sp = segment_path(path, i);
        let data: &[u8] = &seg.packed.data;
        let stamp = segment_stamp(width, &seg.ids, data);
        if let Ok(meta) = std::fs::metadata(&sp) {
            if meta.len() == segment_file_len(seg.ids.len(), data.len())
                && read_segment_stamp(&sp) == Some(stamp)
            {
                continue; // unchanged sealed segment: skip the rewrite
            }
        }
        atomic_write(&sp, |w| {
            w.header(KIND_SEGMENT)?;
            w.u32(width.bits() as u32)?;
            w.u64(stamp)?;
            w.i64s(&seg.ids)?;
            w.code_region(data)
        })?;
    }
    Ok(())
}

/// Load a [`SegmentedIndex`] saved by [`save_segmented`] into heap
/// memory: the manifest at `path` plus its `{path}.seg{i}` siblings.
pub fn load_segmented(path: &Path) -> Result<SegmentedIndex> {
    load_segmented_with(path, &OpenOptions::default())
}

/// [`load_segmented`] with explicit open options: `opts.mmap` maps each
/// v3 segment file and adopts its packed block zero-copy; one
/// [`MemoryBudget`] spans all segments. Queries answer bit-identically to
/// the heap load either way.
pub fn load_segmented_with(path: &Path, opts: &OpenOptions) -> Result<SegmentedIndex> {
    // the manifest holds only scalars + the memtable — always heap-read
    let r = &mut FileSource::open(path)?;
    let version = check_header(r, KIND_SEGMENTED)?;
    let width = read_width(r, version)?;
    let m = r.u32()? as usize;
    let dim = r.u32()? as usize;
    let params = SegmentedParams {
        flush_threshold: r.u64()? as usize,
        max_segments: r.u64()? as usize,
    };
    let next_id = r.u64()? as i64;
    let pq = read_pq(r)?;
    let tombstones: std::collections::HashSet<i64> = r.i64s()?.into_iter().collect();
    let mem_ids = r.i64s()?;
    let mem_vectors = r.f32s()?;
    let mem_codes = r.bytes()?;
    let code_cols = width.code_columns(m);
    if mem_vectors.len() != mem_ids.len() * dim || mem_codes.len() != mem_ids.len() * code_cols {
        return Err(Error::CorruptIndex("segmented manifest: memtable size mismatch".into()));
    }
    let memtable = Memtable::from_parts(mem_ids, mem_vectors, mem_codes);
    let nseg = r.u32()? as usize;
    let mut segments = Vec::with_capacity(nseg);
    let mut budget = opts.budget();
    for i in 0..nseg {
        let sp = segment_path(path, i);
        let seg = if opts.mmap {
            let mut src = MapSource::open(&sp, budget)?;
            let seg = load_segment_src(&mut src, i, m, width)?;
            budget = src.budget;
            seg
        } else {
            load_segment_src(&mut FileSource::open(&sp)?, i, m, width)?
        };
        segments.push(seg);
    }
    SegmentedIndex::from_parts(
        dim, m, width, params, pq, segments, tombstones, memtable, next_id,
    )
}

fn load_segment_src<S: IndexSource>(
    r: &mut S,
    i: usize,
    m: usize,
    width: CodeWidth,
) -> Result<SealedSegment> {
    let version = check_header(r, KIND_SEGMENT)?;
    let seg_width = read_width(r, version)?;
    if seg_width != width {
        return Err(Error::CorruptIndex(format!(
            "segment {i}: width {seg_width} does not match manifest {width}"
        )));
    }
    if version < 3 {
        let ids = r.i64s()?;
        let codes = r.bytes()?;
        // build() re-validates shape and re-packs the kernel layout
        return SealedSegment::build(ids, codes, m, width);
    }
    let _stamp = r.u64()?; // writer-side change detection, not verified here
    let ids = r.i64s()?;
    let store = r.code_region()?;
    let packed = PackedCodes::from_store(store, ids.len(), m, width)?;
    SealedSegment::from_packed(ids, packed)
}

// ------------------------------------------------------------ open

fn check_header<S: IndexSource>(r: &mut S, expect_kind: u32) -> Result<u32> {
    let (version, kind) = read_magic_version_kind(r)?;
    if kind != expect_kind {
        return Err(Error::CorruptIndex(format!(
            "wrong index kind {kind} (expected {expect_kind})"
        )));
    }
    Ok(version)
}

fn read_magic_version_kind<S: IndexSource>(r: &mut S) -> Result<(u32, u32)> {
    let mut magic = [0u8; 8];
    r.fill(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::CorruptIndex("not an armpq index file".into()));
    }
    let version = r.u32()?;
    if !(1..=VERSION).contains(&version) {
        return Err(Error::CorruptIndex(format!("unsupported index version {version}")));
    }
    let kind = r.u32()?;
    Ok((version, kind))
}

/// Open any saved index behind the [`Index`] trait, dispatching on the
/// file's kind tag. `opts.mmap` makes sealed code blocks zero-copy
/// ([`CodeStore::Mapped`]); `opts.budget_mb` caps how much of them is
/// advised resident at open.
pub fn open_index(path: &Path, opts: &OpenOptions) -> Result<Box<dyn Index>> {
    let (_version, kind) = read_magic_version_kind(&mut FileSource::open(path)?)?;
    match kind {
        KIND_PQ4FS => Ok(Box::new(load_pq4fs_with(path, opts)?)),
        KIND_IVFPQ4 => Ok(Box::new(IndexIvfPq4::from_inner(load_ivfpq4_with(path, opts)?))),
        KIND_SEGMENTED => Ok(Box::new(load_segmented_with(path, opts)?)),
        KIND_SEGMENT => Err(Error::CorruptIndex(
            "this is a bare segment file; open its manifest instead".into(),
        )),
        k => Err(Error::CorruptIndex(format!("unknown index kind {k}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;
    use crate::index::Index;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("armpq_idxio_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn pq4fs_roundtrip_identical_results() {
        let ds = SyntheticDataset::gaussian(1_000, 10, 32, 201);
        let mut idx = IndexPq4FastScan::new(ds.dim, 8);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let before = idx.search(&ds.queries, 5, None).unwrap();

        let path = tmp("flat.armpq");
        save_pq4fs(&idx, &path).unwrap();
        let loaded = load_pq4fs(&path).unwrap();
        assert_eq!(loaded.ntotal(), 1_000);
        assert!(loaded.is_sealed(), "load must return a sealed index");
        let after = loaded.search(&ds.queries, 5, None).unwrap();
        assert_eq!(before.labels, after.labels);
        assert_eq!(before.distances, after.distances);

        // the mapped open answers bit-identically and is actually mapped
        let mapped = load_pq4fs_with(&path, &OpenOptions::mapped()).unwrap();
        let p = mapped.packed().unwrap();
        assert!(p.data.is_mapped());
        assert_eq!(p.data.as_ptr() as usize % CODE_ALIGN, 0, "region must be 64-byte aligned");
        let after = mapped.search(&ds.queries, 5, None).unwrap();
        assert_eq!(before.labels, after.labels);
        assert_eq!(before.distances, after.distances);
    }

    #[test]
    fn ivfpq4_roundtrip_identical_results() {
        let ds = SyntheticDataset::gaussian(1_500, 10, 16, 202);
        let mut params = IvfParams::new(8);
        params.coarse_hnsw = true;
        params.hnsw_m = 8;
        let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(4));
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.nprobe = 8;
        idx.seal().unwrap();
        let (d0, l0) = idx.search(&ds.queries, 5).unwrap();

        let path = tmp("ivf.armpq");
        save_ivfpq4(&idx, &path).unwrap();
        let mut loaded = load_ivfpq4(&path).unwrap();
        loaded.nprobe = 8;
        assert_eq!(loaded.ntotal(), 1_500);
        assert!(loaded.is_sealed(), "load must return a sealed index");
        let (d1, l1) = loaded.search(&ds.queries, 5).unwrap();
        assert_eq!(l0, l1);
        assert_eq!(d0, d1);

        let mut mapped = load_ivfpq4_with(&path, &OpenOptions::mapped()).unwrap();
        mapped.nprobe = 8;
        let (d2, l2) = mapped.search(&ds.queries, 5).unwrap();
        assert_eq!(l0, l2);
        assert_eq!(d0, d2);
    }

    /// Every fastscan width survives the save/load cycle with identical
    /// results (the format carries the width).
    #[test]
    fn width_roundtrips_identically() {
        let ds = SyntheticDataset::gaussian(800, 8, 32, 205);
        for width in CodeWidth::ALL {
            let mut idx = crate::index::pq_index::IndexPq4FastScan::new_width(ds.dim, 8, width);
            idx.train(&ds.train).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            let before = idx.search(&ds.queries, 5, None).unwrap();
            let path = tmp(&format!("flat_w{}.armpq", width.bits()));
            save_pq4fs(&idx, &path).unwrap();
            let loaded = load_pq4fs(&path).unwrap();
            assert_eq!(loaded.width(), width);
            let after = loaded.search(&ds.queries, 5, None).unwrap();
            assert_eq!(before.labels, after.labels, "{width}");
            assert_eq!(before.distances, after.distances, "{width}");
        }
        // IVF at a non-default width
        let mut idx = IvfPq4::new_width(ds.dim, IvfParams::new(4), 8, CodeWidth::W2);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.nprobe = 4;
        idx.seal().unwrap();
        let (d0, l0) = idx.search(&ds.queries, 5).unwrap();
        let path = tmp("ivf_w2.armpq");
        save_ivfpq4(&idx, &path).unwrap();
        let mut loaded = load_ivfpq4(&path).unwrap();
        loaded.nprobe = 4;
        assert_eq!(loaded.width, CodeWidth::W2);
        assert_eq!(loaded.pq_m, 8);
        let (d1, l1) = loaded.search(&ds.queries, 5).unwrap();
        assert_eq!(l0, l1);
        assert_eq!(d0, d1);
    }

    /// A hand-written v2 file (flat code columns, no alignment) still
    /// loads — the compatibility contract for pre-v3 deployments.
    #[test]
    fn v2_flat_file_still_loads() {
        let ds = SyntheticDataset::gaussian(400, 6, 16, 209);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let before = idx.search(&ds.queries, 5, None).unwrap();

        let path = tmp("v2_flat.armpq");
        let f = std::fs::File::create(&path).unwrap();
        let mut w = Writer { w: BufWriter::new(f), pos: 0 };
        w.put(MAGIC).unwrap();
        w.u32(2).unwrap(); // the v2 layout, byte for byte
        w.u32(KIND_PQ4FS).unwrap();
        w.u32(idx.width().bits() as u32).unwrap();
        write_pq(&mut w, idx.pq().unwrap()).unwrap();
        w.bytes(&idx.flat_codes()).unwrap();
        w.w.flush().unwrap();

        for opts in [OpenOptions::heap(), OpenOptions::mapped()] {
            let loaded = load_pq4fs_with(&path, &opts).unwrap();
            assert_eq!(loaded.ntotal(), 400);
            let after = loaded.search(&ds.queries, 5, None).unwrap();
            assert_eq!(before.labels, after.labels);
            assert_eq!(before.distances, after.distances);
        }
    }

    #[test]
    fn rejects_wrong_magic_and_kind() {
        let path = tmp("bad.armpq");
        std::fs::write(&path, b"NOTANIDX0000000000000000").unwrap();
        assert!(matches!(load_pq4fs(&path).unwrap_err(), Error::CorruptIndex(_)));

        // valid flat index loaded as IVF must fail on the kind tag
        let ds = SyntheticDataset::gaussian(500, 2, 16, 203);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let path2 = tmp("flat2.armpq");
        save_pq4fs(&idx, &path2).unwrap();
        let err = match load_ivfpq4(&path2) {
            Err(e) => e,
            Ok(_) => panic!("loading flat index as IVF must fail"),
        };
        assert!(err.to_string().contains("kind"), "{err}");
        // but the kind-dispatching open succeeds on the same file
        let opened = open_index(&path2, &OpenOptions::heap()).unwrap();
        assert_eq!(opened.ntotal(), 500);
    }

    #[test]
    fn untrained_save_fails() {
        let idx = IndexPq4FastScan::new(16, 4);
        assert!(save_pq4fs(&idx, &tmp("x.armpq")).is_err());
    }

    #[test]
    fn truncated_file_fails_cleanly() {
        let ds = SyntheticDataset::gaussian(300, 2, 16, 204);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let path = tmp("trunc.armpq");
        save_pq4fs(&idx, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        for opts in [OpenOptions::heap(), OpenOptions::mapped()] {
            assert!(matches!(
                load_pq4fs_with(&path, &opts).unwrap_err(),
                Error::CorruptIndex(_)
            ));
        }
    }

    /// Saves are atomic: no `.tmp` sibling survives a successful save,
    /// and a failed save never replaces the existing file.
    #[test]
    fn atomic_save_leaves_no_tmp() {
        let ds = SyntheticDataset::gaussian(300, 2, 16, 207);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let path = tmp("atomic.armpq");
        save_pq4fs(&idx, &path).unwrap();
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "temp sibling must be renamed away");
        assert!(load_pq4fs(&path).is_ok());
    }

    /// Re-saving a segmented index leaves unchanged sealed segment files
    /// untouched on disk (same inode — never rewritten), while the
    /// manifest is rewritten every time.
    #[cfg(unix)]
    #[test]
    fn unchanged_segments_skip_rewrite() {
        use crate::segment::SegmentedParams;
        use std::os::unix::fs::MetadataExt;

        let ds = SyntheticDataset::gaussian(600, 4, 8, 208);
        // thresholds high enough that nothing flushes or compacts behind
        // the test's back — segment 0's content must stay stable
        let params = SegmentedParams { flush_threshold: 100_000, max_segments: 1_000 };
        let mut idx = SegmentedIndex::new(ds.dim, 4, CodeWidth::W4, params).unwrap();
        idx.train(&ds.train).unwrap();
        let base_ids: Vec<i64> = (0..600).collect();
        idx.insert(&ds.base, Some(&base_ids)).unwrap();
        idx.flush().unwrap();
        let path = tmp("skip.armpq");
        save_segmented(&idx, &path).unwrap();
        let seg0 = segment_path(&path, 0);
        let ino_before = std::fs::metadata(&seg0).unwrap().ino();

        // nothing changed: the segment file must not be rewritten
        save_segmented(&idx, &path).unwrap();
        assert_eq!(std::fs::metadata(&seg0).unwrap().ino(), ino_before);

        // mutate + flush: a new segment appears, segment 0 still skips
        idx.insert(&ds.queries, Some(&[9000, 9001, 9002, 9003])).unwrap();
        idx.flush().unwrap();
        save_segmented(&idx, &path).unwrap();
        assert_eq!(std::fs::metadata(&seg0).unwrap().ino(), ino_before);
        assert!(segment_path(&path, 1).exists());

        let loaded = load_segmented(&path).unwrap();
        assert_eq!(loaded.ntotal(), 604);
    }
}
