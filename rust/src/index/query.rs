//! The typed query model: one request/response pair for every query mode.
//!
//! [`QueryRequest`] bundles a query batch with a [`QueryKind`] (top-k or
//! radius), an optional [`Filter`] (id bitset, id range, or caller
//! predicate) and the per-request [`super::SearchParams`] overrides;
//! [`QueryResponse`] returns per-query variable-length [`Hit`] lists plus
//! typed per-query [`QueryStats`]. [`super::Index::query`] is the single
//! entry point — `Index::search` survives as a thin shim that builds a
//! `TopK` request.
//!
//! Filters are evaluated *inside* the fastscan kernels: the index layers
//! compile a `Filter` into a block-aligned
//! [`crate::pq::fastscan::FilterMask`] (for IVF, one slice per probed
//! list), so a filtered position costs one bit test in the pruned-compare
//! admission mask instead of a post-hoc rescan of the results.

use super::{SearchParams, SearchResult};
use crate::pq::fastscan::FilterMask;
use crate::{Error, Result};
use std::collections::HashSet;
use std::fmt;
use std::sync::Arc;

/// What question the query asks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryKind {
    /// The `k` nearest neighbors (per query), distances ascending.
    TopK { k: usize },
    /// Every hit with distance `<= radius` (L2-squared, the same domain as
    /// returned distances), ascending. On quantized indexes the boundary is
    /// exact when re-ranking is on (the default) and quantization-accurate
    /// otherwise; on IVF indexes coverage is limited to the probed lists.
    Range { radius: f32 },
}

impl QueryKind {
    /// Reject values no sane request carries (a NaN/infinite radius would
    /// poison threshold math and batch grouping).
    pub fn validate(&self) -> Result<()> {
        if let QueryKind::Range { radius } = self {
            if !radius.is_finite() {
                return Err(Error::InvalidParameter(format!(
                    "range radius must be finite, got {radius}"
                )));
            }
        }
        Ok(())
    }
}

/// FNV-1a over a byte stream — the same cheap stable hash the quantizer
/// signature uses; good enough for grouping keys and metrics labels.
fn fnv1a(seed: u64, bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = seed ^ 0xcbf2_9ce4_8422_2325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Spans wider than this fall back to a hash set: a bitset over a sparse
/// id space (say `{0, i64::MAX}`) must not allocate the span.
const DENSE_SPAN_LIMIT: i64 = 1 << 22;

#[derive(Clone, Debug, PartialEq)]
enum SetRepr {
    /// Bitset over `[offset, offset + 64·words.len())`.
    Dense { offset: i64, words: Vec<u64> },
    /// Fallback for id sets whose span exceeds [`DENSE_SPAN_LIMIT`].
    Sparse(HashSet<i64>),
}

/// An explicit set of allowed external ids (the `IdSet` filter payload).
///
/// Stored as a bitset when the id span allows it (one bit test per
/// membership check — the representation the kernels' mask build wants),
/// with a hash-set fallback for pathologically sparse id spaces.
#[derive(Clone, Debug, PartialEq)]
pub struct IdSet {
    repr: SetRepr,
    /// Sorted, deduplicated member ids (kept for wire serialization).
    ids: Vec<i64>,
    signature: u64,
}

impl IdSet {
    pub fn from_ids(ids: &[i64]) -> Self {
        let mut sorted = ids.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let signature =
            fnv1a(0x1d5e7, sorted.iter().flat_map(|id| id.to_le_bytes()));
        let repr = match (sorted.first().copied(), sorted.last().copied()) {
            (Some(lo), Some(hi))
                if hi.checked_sub(lo).is_some_and(|s| s < DENSE_SPAN_LIMIT) =>
            {
                let span = (hi - lo) as usize + 1;
                let mut words = vec![0u64; span.div_ceil(64)];
                for &id in &sorted {
                    let b = (id - lo) as usize;
                    words[b / 64] |= 1u64 << (b % 64);
                }
                SetRepr::Dense { offset: lo, words }
            }
            (Some(_), Some(_)) => SetRepr::Sparse(sorted.iter().copied().collect()),
            _ => SetRepr::Dense { offset: 0, words: Vec::new() },
        };
        Self { repr, ids: sorted, signature }
    }

    #[inline]
    pub fn contains(&self, id: i64) -> bool {
        match &self.repr {
            SetRepr::Dense { offset, words } => match id.checked_sub(*offset) {
                Some(b) if (b as usize) < words.len() * 64 => {
                    let b = b as usize;
                    words[b / 64] >> (b % 64) & 1 == 1
                }
                _ => false,
            },
            SetRepr::Sparse(set) => set.contains(&id),
        }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Sorted member ids (wire serialization).
    pub fn ids(&self) -> &[i64] {
        &self.ids
    }
}

/// A predicate over external labels, pushed down into the scan kernels.
///
/// `IdSet` and `IdRange` are data (comparable, serializable over the
/// line-JSON protocol); `Predicate` is an arbitrary in-process closure —
/// it batches only with clones of the same `Arc` and cannot cross the
/// wire.
#[derive(Clone)]
pub enum Filter {
    /// Only ids in the set pass.
    IdSet(Arc<IdSet>),
    /// Only ids in the half-open range `[start, end)` pass.
    IdRange { start: i64, end: i64 },
    /// Only ids the closure approves pass.
    Predicate(Arc<dyn Fn(i64) -> bool + Send + Sync>),
}

impl Filter {
    pub fn id_set(ids: &[i64]) -> Self {
        Filter::IdSet(Arc::new(IdSet::from_ids(ids)))
    }

    /// Half-open `[start, end)`; an inverted range is normalized to empty.
    pub fn id_range(start: i64, end: i64) -> Self {
        Filter::IdRange { start, end: end.max(start) }
    }

    pub fn predicate(f: impl Fn(i64) -> bool + Send + Sync + 'static) -> Self {
        Filter::Predicate(Arc::new(f))
    }

    #[inline]
    pub fn matches(&self, id: i64) -> bool {
        match self {
            Filter::IdSet(set) => set.contains(id),
            Filter::IdRange { start, end } => (*start..*end).contains(&id),
            Filter::Predicate(f) => f(id),
        }
    }

    /// Stable fingerprint for metrics and logging. Batch grouping compares
    /// filters with `==` (exact), not by signature — a hash collision must
    /// never merge two different filters into one backend call.
    pub fn signature(&self) -> u64 {
        match self {
            Filter::IdSet(set) => fnv1a(1, set.signature.to_le_bytes()),
            Filter::IdRange { start, end } => fnv1a(
                2,
                start.to_le_bytes().into_iter().chain(end.to_le_bytes()),
            ),
            Filter::Predicate(f) => {
                fnv1a(3, (Arc::as_ptr(f) as *const () as usize).to_le_bytes())
            }
        }
    }

    /// Estimated fraction of `ntotal` ids that pass — `None` when the
    /// filter is opaque (a predicate). Drives IVF's selectivity-aware
    /// nprobe escalation; it is a *hint* (an `IdRange` may cover ids that
    /// were never added), never a correctness input.
    pub fn selectivity_hint(&self, ntotal: usize) -> Option<f64> {
        if ntotal == 0 {
            return Some(1.0);
        }
        let count = match self {
            Filter::IdSet(set) => set.len() as f64,
            // saturating: a wire client may send a range spanning the whole
            // i64 domain, whose width exceeds i64
            Filter::IdRange { start, end } => end.saturating_sub(*start) as f64,
            Filter::Predicate(_) => return None,
        };
        Some((count / ntotal as f64).min(1.0))
    }

    /// Whether the filter passes no id at all, knowable without scanning.
    pub fn is_provably_empty(&self) -> bool {
        match self {
            Filter::IdSet(set) => set.is_empty(),
            Filter::IdRange { start, end } => start >= end,
            Filter::Predicate(_) => false,
        }
    }

    /// Compile into a block-aligned kernel mask over `n` scan positions:
    /// bit `v` of block word `b` is set iff the external label of position
    /// `32·b + v` passes (`labels = None` means label = position, the flat
    /// index convention).
    pub fn build_mask(&self, labels: Option<&[i64]>, n: usize) -> FilterMask {
        match labels {
            Some(ls) => FilterMask::from_fn(n, |pos| self.matches(ls[pos])),
            None => FilterMask::from_fn(n, |pos| self.matches(pos as i64)),
        }
    }
}

impl fmt::Debug for Filter {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Filter::IdSet(set) => write!(f, "IdSet(len={})", set.len()),
            Filter::IdRange { start, end } => write!(f, "IdRange({start}..{end})"),
            Filter::Predicate(_) => write!(f, "Predicate(..)"),
        }
    }
}

impl PartialEq for Filter {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Filter::IdSet(a), Filter::IdSet(b)) => Arc::ptr_eq(a, b) || a == b,
            (
                Filter::IdRange { start: a0, end: a1 },
                Filter::IdRange { start: b0, end: b1 },
            ) => a0 == b0 && a1 == b1,
            (Filter::Predicate(a), Filter::Predicate(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

/// One query call as a value: a batch of vectors, what to ask ([`QueryKind`]),
/// who may answer ([`Filter`]), and how to search ([`SearchParams`]).
#[derive(Clone, Debug)]
pub struct QueryRequest<'a> {
    /// Row-major `nq × dim` query batch.
    pub queries: &'a [f32],
    pub kind: QueryKind,
    pub filter: Option<Filter>,
    pub params: Option<SearchParams>,
    /// Collect a per-phase [`crate::obs::TraceSpan`] breakdown for every
    /// query in the batch (returned in [`QueryResponse::traces`]).
    /// Tracing never changes results — hits and stats are bit-identical
    /// with it on or off — and costs nothing when `false`.
    pub trace: bool,
}

impl<'a> QueryRequest<'a> {
    pub fn top_k(queries: &'a [f32], k: usize) -> Self {
        Self { queries, kind: QueryKind::TopK { k }, filter: None, params: None, trace: false }
    }

    pub fn range(queries: &'a [f32], radius: f32) -> Self {
        Self {
            queries,
            kind: QueryKind::Range { radius },
            filter: None,
            params: None,
            trace: false,
        }
    }

    pub fn with_filter(mut self, filter: Filter) -> Self {
        self.filter = Some(filter);
        self
    }

    pub fn with_params(mut self, params: SearchParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Ask for the per-phase trace breakdown.
    pub fn with_trace(mut self) -> Self {
        self.trace = true;
        self
    }
}

/// One search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub distance: f32,
    pub label: i64,
}

/// Per-query execution statistics, returned with every [`QueryResponse`]
/// and aggregated into the coordinator's metrics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QueryStats {
    /// Code positions the scan considered (probed-list sizes for IVF, the
    /// whole packed set for flat indexes).
    pub codes_scanned: usize,
    /// Inverted lists probed (1 for flat indexes, 0 when nothing was
    /// scanned).
    pub lists_probed: usize,
    /// Fraction of considered positions the filter admitted (1.0 when
    /// unfiltered).
    pub filter_selectivity: f64,
    /// Worker threads that cooperated on the call this query rode in
    /// (batch fan-out width, or probed-list fan-out for a lone IVF query).
    pub threads_used: usize,
    /// Executor scratch-arena high-water mark, in bytes, at response time
    /// (the steady-state working set the allocation-free scan path reuses).
    pub scratch_bytes: usize,
    /// Scan units the query fanned out over on a segmented index (sealed
    /// segments plus the memtable if non-empty; 0 for sealed indexes).
    pub segments_scanned: usize,
    /// Mutable-front rows at snapshot time (0 for sealed indexes).
    pub memtable_entries: usize,
    /// Dead sealed rows awaiting compaction at snapshot time (0 for sealed
    /// indexes) — the compaction-pressure signal.
    pub tombstones: usize,
    /// Packed code bytes this query scanned out of memory-mapped regions
    /// (0 for heap-loaded indexes) — the zero-copy coverage signal.
    pub bytes_mapped: usize,
    /// Probed lists / scan units whose codes were software-prefetched one
    /// step ahead of the scan.
    pub prefetch_lists: usize,
}

impl Default for QueryStats {
    fn default() -> Self {
        Self {
            codes_scanned: 0,
            lists_probed: 0,
            filter_selectivity: 1.0,
            threads_used: 1,
            scratch_bytes: 0,
            segments_scanned: 0,
            memtable_entries: 0,
            tombstones: 0,
            bytes_mapped: 0,
            prefetch_lists: 0,
        }
    }
}

/// Typed answer to a [`QueryRequest`]: per-query variable-length hits
/// (ascending by `(distance, label)`; at most `k` for `TopK`, unbounded for
/// `Range`) plus per-query stats.
#[derive(Clone, Debug, Default)]
pub struct QueryResponse {
    pub hits: Vec<Vec<Hit>>,
    pub stats: Vec<QueryStats>,
    /// Per-query phase breakdowns, parallel to `hits`, when the request
    /// set [`QueryRequest::trace`]; empty otherwise (never allocated on
    /// the untraced path).
    pub traces: Vec<Vec<crate::obs::TraceSpan>>,
}

impl QueryResponse {
    /// A well-formed response with `nq` empty hit lists.
    pub fn empty(nq: usize) -> Self {
        Self {
            hits: vec![Vec::new(); nq],
            stats: vec![QueryStats::default(); nq],
            traces: Vec::new(),
        }
    }

    pub fn nq(&self) -> usize {
        self.hits.len()
    }

    /// Flatten into the fixed-shape [`SearchResult`] the `search` shim
    /// returns: each row truncated/padded to exactly `k` entries with
    /// `(INFINITY, -1)`.
    pub fn into_search_result(self, k: usize) -> SearchResult {
        let nq = self.hits.len();
        let mut distances = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        for row in self.hits {
            let take = row.len().min(k);
            for h in &row[..take] {
                distances.push(h.distance);
                labels.push(h.label);
            }
            for _ in take..k {
                distances.push(f32::INFINITY);
                labels.push(-1);
            }
        }
        SearchResult { k, distances, labels }
    }
}

/// Pad/truncate one hit row to exactly `k` `(distance, label)` entries —
/// the row-level counterpart of [`QueryResponse::into_search_result`],
/// used by serving layers that answer one query at a time.
pub fn pad_hits(row: &[Hit], k: usize) -> (Vec<f32>, Vec<i64>) {
    let take = row.len().min(k);
    let mut d: Vec<f32> = row[..take].iter().map(|h| h.distance).collect();
    let mut l: Vec<i64> = row[..take].iter().map(|h| h.label).collect();
    while d.len() < k {
        d.push(f32::INFINITY);
        l.push(-1);
    }
    (d, l)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn id_set_dense_and_sparse() {
        let dense = IdSet::from_ids(&[3, 1, 7, 3, 100]);
        assert_eq!(dense.len(), 4);
        assert!(dense.contains(1) && dense.contains(100));
        assert!(!dense.contains(2) && !dense.contains(-5) && !dense.contains(101));
        assert!(matches!(dense.repr, SetRepr::Dense { .. }));
        // a span wider than the dense limit must not allocate the span
        let sparse = IdSet::from_ids(&[0, i64::MAX - 1]);
        assert!(matches!(sparse.repr, SetRepr::Sparse(_)));
        assert!(sparse.contains(0) && sparse.contains(i64::MAX - 1));
        assert!(!sparse.contains(1));
        let empty = IdSet::from_ids(&[]);
        assert!(empty.is_empty());
        assert!(!empty.contains(0));
    }

    #[test]
    fn filter_matches_and_emptiness() {
        let set = Filter::id_set(&[2, 4, 6]);
        assert!(set.matches(4) && !set.matches(5));
        assert!(!set.is_provably_empty());
        assert!(Filter::id_set(&[]).is_provably_empty());

        let range = Filter::id_range(10, 20);
        assert!(range.matches(10) && range.matches(19));
        assert!(!range.matches(20) && !range.matches(9));
        assert!(Filter::id_range(5, 5).is_provably_empty());
        // inverted ranges normalize to empty instead of underflowing
        assert!(Filter::id_range(9, 3).is_provably_empty());

        let pred = Filter::predicate(|id| id % 2 == 0);
        assert!(pred.matches(4) && !pred.matches(5));
        assert!(!pred.is_provably_empty());
    }

    #[test]
    fn filter_equality_and_signatures() {
        let a = Filter::id_range(0, 10);
        let b = Filter::id_range(0, 10);
        let c = Filter::id_range(0, 11);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.signature(), b.signature());
        assert_ne!(a.signature(), c.signature());

        let s1 = Filter::id_set(&[1, 2, 3]);
        let s2 = Filter::id_set(&[3, 2, 1, 1]); // order/dup insensitive
        assert_eq!(s1, s2);
        assert_eq!(s1.signature(), s2.signature());
        assert_ne!(s1, a);

        let p = Filter::predicate(|_| true);
        let p2 = p.clone();
        assert_eq!(p, p2); // same Arc
        assert_ne!(p, Filter::predicate(|_| true)); // different closure
    }

    #[test]
    fn selectivity_hints() {
        assert_eq!(Filter::id_range(0, 50).selectivity_hint(100), Some(0.5));
        assert_eq!(Filter::id_range(0, 500).selectivity_hint(100), Some(1.0));
        assert_eq!(Filter::id_set(&[1, 2]).selectivity_hint(100), Some(0.02));
        assert_eq!(Filter::predicate(|_| true).selectivity_hint(100), None);
    }

    #[test]
    fn mask_build_identity_and_mapped_labels() {
        let f = Filter::id_range(2, 5);
        let m = f.build_mask(None, 8);
        assert_eq!(m.pass_count(), 3);
        assert!(!m.passes(1) && m.passes(2) && m.passes(4) && !m.passes(5));
        // mapped labels: positions pass per their external id
        let labels = [100i64, 3, 4, 100];
        let m = f.build_mask(Some(&labels), 4);
        assert_eq!(m.pass_count(), 2);
        assert!(!m.passes(0) && m.passes(1) && m.passes(2) && !m.passes(3));
    }

    #[test]
    fn kind_validation() {
        assert!(QueryKind::TopK { k: 0 }.validate().is_ok());
        assert!(QueryKind::Range { radius: 1.5 }.validate().is_ok());
        assert!(QueryKind::Range { radius: f32::NAN }.validate().is_err());
        assert!(QueryKind::Range { radius: f32::INFINITY }.validate().is_err());
    }

    #[test]
    fn response_padding_roundtrip() {
        let resp = QueryResponse {
            hits: vec![
                vec![Hit { distance: 1.0, label: 7 }],
                Vec::new(),
                vec![
                    Hit { distance: 0.5, label: 1 },
                    Hit { distance: 0.6, label: 2 },
                    Hit { distance: 0.7, label: 3 },
                ],
            ],
            stats: vec![QueryStats::default(); 3],
            traces: Vec::new(),
        };
        assert_eq!(resp.nq(), 3);
        let r = resp.into_search_result(2);
        assert_eq!(r.k, 2);
        assert_eq!(r.labels, vec![7, -1, -1, -1, 1, 2]);
        assert_eq!(r.distances[0], 1.0);
        assert!(r.distances[1].is_infinite());
        // row-level padding helper agrees
        let (d, l) = pad_hits(&[Hit { distance: 2.0, label: 9 }], 3);
        assert_eq!(l, vec![9, -1, -1]);
        assert!(d[2].is_infinite());
        let e = QueryResponse::empty(2);
        assert_eq!(e.nq(), 2);
        assert_eq!(e.stats[0].filter_selectivity, 1.0);
    }
}
