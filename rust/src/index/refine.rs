//! Exact re-ranking wrapper (faiss `IndexRefineFlat` analog).
//!
//! The paper positions 4-bit PQ as memory-efficient but low-recall
//! (Table 1: 0.072 vs Link&Code's 0.668 at 13× the memory). The standard
//! way to buy recall back is a refinement stage: keep the raw vectors,
//! let the quantized index shortlist `k × refine_factor` candidates, then
//! re-rank the shortlist with exact distances. This wrapper makes that a
//! first-class index type.

use super::query::{Hit, QueryKind, QueryRequest, QueryResponse};
use super::{Index, SearchParams};
use crate::exec::QueryExecutor;
use crate::util::topk::TopK;
use crate::{Error, Result};

/// Wraps a base index with an exact-distance refinement pass.
pub struct IndexRefineFlat {
    base: Box<dyn Index>,
    /// Raw vectors, indexed by the base index's sequential labels.
    vectors: Vec<f32>,
    /// Default shortlist width multiplier (search k·factor through the
    /// base); per-request `SearchParams::refine_factor` overrides it.
    pub refine_factor: usize,
}

impl IndexRefineFlat {
    pub fn new(base: Box<dyn Index>) -> Self {
        Self { base, vectors: Vec::new(), refine_factor: 4 }
    }
}

impl Index for IndexRefineFlat {
    fn dim(&self) -> usize {
        self.base.dim()
    }

    fn ntotal(&self) -> usize {
        self.vectors.len() / self.base.dim().max(1)
    }

    fn is_trained(&self) -> bool {
        self.base.is_trained()
    }

    fn train(&mut self, data: &[f32]) -> Result<()> {
        self.base.train(data)
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        self.base.add(data)?;
        self.vectors.extend_from_slice(data);
        Ok(())
    }

    fn seal(&mut self) -> Result<()> {
        self.base.seal()
    }

    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse> {
        req.kind.validate()?;
        let dim = self.base.dim();
        if req.queries.len() % dim != 0 {
            return Err(Error::DimMismatch { expected: dim, got: req.queries.len() % dim });
        }
        let nq_in = req.queries.len() / dim;
        if nq_in == 0 || self.ntotal() == 0 || matches!(req.kind, QueryKind::TopK { k: 0 }) {
            return Ok(QueryResponse::empty(nq_in));
        }
        // the base shortlists (filter pushed down into its kernels); the
        // refinement pass re-ranks the shortlist with exact raw-vector L2
        let base_kind = match req.kind {
            QueryKind::TopK { k } => {
                let refine_factor = req
                    .params
                    .as_ref()
                    .and_then(|p| p.refine_factor)
                    .unwrap_or(self.refine_factor);
                QueryKind::TopK { k: (k * refine_factor).max(k) }
            }
            // the base's (possibly quantized) radius decides the shortlist;
            // the exact pass below re-trims to the true boundary
            QueryKind::Range { radius } => QueryKind::Range { radius },
        };
        let base_req = QueryRequest {
            queries: req.queries,
            kind: base_kind,
            filter: req.filter.clone(),
            params: req.params.clone(),
            trace: req.trace,
        };
        // the base shortlist rides the same executor; the exact re-rank
        // pass then fans out over the batch with per-thread heap storage
        let coarse = self.base.query_exec(&base_req, exec)?;
        let kind = req.kind;
        let queries = req.queries;
        let hits: Vec<Vec<Hit>> = exec.run_batch(coarse.nq(), |qi, scratch| {
            let row = &coarse.hits[qi];
            let q = &queries[qi * dim..(qi + 1) * dim];
            let exact = |label: i64| {
                let v = &self.vectors[label as usize * dim..(label as usize + 1) * dim];
                crate::util::l2_sq(q, v)
            };
            match kind {
                QueryKind::TopK { k } => {
                    let mut heap = TopK::from_storage(k, scratch.take_heap());
                    for h in row {
                        if h.label >= 0 {
                            heap.push(exact(h.label), h.label);
                        }
                    }
                    let refined: Vec<Hit> = heap
                        .as_sorted_hits()
                        .iter()
                        .map(|&(distance, label)| Hit { distance, label })
                        .collect();
                    scratch.put_heap(heap.into_storage());
                    refined
                }
                QueryKind::Range { radius } => {
                    let mut out: Vec<(f32, i64)> = row
                        .iter()
                        .filter(|h| h.label >= 0)
                        .map(|h| (exact(h.label), h.label))
                        .filter(|&(d, _)| d <= radius)
                        .collect();
                    out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                    out.into_iter().map(|(distance, label)| Hit { distance, label }).collect()
                }
            }
        });
        let mut stats = coarse.stats;
        exec.stamp_stats(&mut stats, hits.len());
        // the exact pass is untraced; the base's phase spans carry through
        Ok(QueryResponse { hits, stats, traces: coarse.traces })
    }

    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "refine_factor" => {
                let mut p = SearchParams::default();
                p.assign(key, value)?;
                self.refine_factor = p.refine_factor.unwrap();
                Ok(())
            }
            _ => self.base.set_param(key, value),
        }
    }

    fn describe(&self) -> String {
        format!("Refine(x{}, {})", self.refine_factor, self.base.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;
    use crate::eval::{ground_truth, recall_at_r};
    use crate::index::index_factory;

    #[test]
    fn refinement_recovers_recall() {
        let ds = SyntheticDataset::sift_like(5_000, 50, 211);
        let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);

        let mut plain = index_factory(ds.dim, "PQ8x4fs").unwrap();
        plain.train(&ds.train).unwrap();
        plain.add(&ds.base).unwrap();
        plain.seal().unwrap();
        let rp = plain.search(&ds.queries, 10, None).unwrap();
        let rec_plain = recall_at_r(&gt, 1, &rp.labels, 10, 1);

        let mut refined = IndexRefineFlat::new(index_factory(ds.dim, "PQ8x4fs").unwrap());
        refined.refine_factor = 16;
        refined.train(&ds.train).unwrap();
        refined.add(&ds.base).unwrap();
        refined.seal().unwrap();
        let rr = refined.search(&ds.queries, 10, None).unwrap();
        let rec_refined = recall_at_r(&gt, 1, &rr.labels, 10, 1);

        assert!(
            rec_refined >= rec_plain + 0.1,
            "refine {rec_refined} vs plain {rec_plain}"
        );
        // refined distances are exact L2 → sorted, and top-1 is exact
        for qi in 0..50 {
            let row = &rr.distances[qi * 10..(qi + 1) * 10];
            assert!(row.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn exact_distances_returned() {
        let ds = SyntheticDataset::gaussian(500, 5, 16, 212);
        let mut refined = IndexRefineFlat::new(index_factory(ds.dim, "PQ4x4fs").unwrap());
        refined.train(&ds.train).unwrap();
        refined.add(&ds.base).unwrap();
        refined.seal().unwrap();
        let r = refined.search(&ds.queries, 3, None).unwrap();
        for qi in 0..5 {
            for (j, &label) in r.row(qi).iter().enumerate() {
                if label < 0 {
                    continue;
                }
                let v = &ds.base[label as usize * ds.dim..(label as usize + 1) * ds.dim];
                let exact = crate::util::l2_sq(ds.query(qi), v);
                assert!((exact - r.distances[qi * 3 + j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn set_param_passthrough() {
        let mut refined = IndexRefineFlat::new(index_factory(32, "IVF8,PQ8x4fs").unwrap());
        refined.set_param("refine_factor", "8").unwrap();
        assert_eq!(refined.refine_factor, 8);
        refined.set_param("nprobe", "4").unwrap(); // forwarded to base
        assert!(refined.set_param("bogus", "1").is_err());
        assert!(refined.describe().starts_with("Refine(x8"));
    }
}
