//! Exact re-ranking wrapper (faiss `IndexRefineFlat` analog).
//!
//! The paper positions 4-bit PQ as memory-efficient but low-recall
//! (Table 1: 0.072 vs Link&Code's 0.668 at 13× the memory). The standard
//! way to buy recall back is a refinement stage: keep the raw vectors,
//! let the quantized index shortlist `k × refine_factor` candidates, then
//! re-rank the shortlist with exact distances. This wrapper makes that a
//! first-class index type.

use super::{Index, SearchParams, SearchResult};
use crate::util::topk::TopK;
use crate::{Error, Result};

/// Wraps a base index with an exact-distance refinement pass.
pub struct IndexRefineFlat {
    base: Box<dyn Index>,
    /// Raw vectors, indexed by the base index's sequential labels.
    vectors: Vec<f32>,
    /// Default shortlist width multiplier (search k·factor through the
    /// base); per-request `SearchParams::refine_factor` overrides it.
    pub refine_factor: usize,
}

impl IndexRefineFlat {
    pub fn new(base: Box<dyn Index>) -> Self {
        Self { base, vectors: Vec::new(), refine_factor: 4 }
    }
}

impl Index for IndexRefineFlat {
    fn dim(&self) -> usize {
        self.base.dim()
    }

    fn ntotal(&self) -> usize {
        self.vectors.len() / self.base.dim().max(1)
    }

    fn is_trained(&self) -> bool {
        self.base.is_trained()
    }

    fn train(&mut self, data: &[f32]) -> Result<()> {
        self.base.train(data)
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        self.base.add(data)?;
        self.vectors.extend_from_slice(data);
        Ok(())
    }

    fn seal(&mut self) -> Result<()> {
        self.base.seal()
    }

    fn search(
        &self,
        queries: &[f32],
        k: usize,
        params: Option<&SearchParams>,
    ) -> Result<SearchResult> {
        let dim = self.base.dim();
        if queries.len() % dim != 0 {
            return Err(Error::DimMismatch { expected: dim, got: queries.len() % dim });
        }
        let nq_in = queries.len() / dim;
        if k == 0 || nq_in == 0 || self.ntotal() == 0 {
            return Ok(SearchResult::empty(nq_in, k));
        }
        let refine_factor =
            params.and_then(|p| p.refine_factor).unwrap_or(self.refine_factor);
        let shortlist_k = (k * refine_factor).max(k);
        let coarse = self.base.search(queries, shortlist_k, params)?;
        let nq = coarse.nq();
        let mut distances = Vec::with_capacity(nq * k);
        let mut labels = Vec::with_capacity(nq * k);
        for qi in 0..nq {
            let q = &queries[qi * dim..(qi + 1) * dim];
            let mut heap = TopK::new(k);
            for &label in coarse.row(qi) {
                if label < 0 {
                    continue;
                }
                let v = &self.vectors[label as usize * dim..(label as usize + 1) * dim];
                heap.push(crate::util::l2_sq(q, v), label);
            }
            let (d, l) = heap.into_sorted();
            distances.extend(d);
            labels.extend(l);
        }
        Ok(SearchResult { k, distances, labels })
    }

    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "refine_factor" => {
                let mut p = SearchParams::default();
                p.assign(key, value)?;
                self.refine_factor = p.refine_factor.unwrap();
                Ok(())
            }
            _ => self.base.set_param(key, value),
        }
    }

    fn describe(&self) -> String {
        format!("Refine(x{}, {})", self.refine_factor, self.base.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;
    use crate::eval::{ground_truth, recall_at_r};
    use crate::index::index_factory;

    #[test]
    fn refinement_recovers_recall() {
        let ds = SyntheticDataset::sift_like(5_000, 50, 211);
        let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);

        let mut plain = index_factory(ds.dim, "PQ8x4fs").unwrap();
        plain.train(&ds.train).unwrap();
        plain.add(&ds.base).unwrap();
        plain.seal().unwrap();
        let rp = plain.search(&ds.queries, 10, None).unwrap();
        let rec_plain = recall_at_r(&gt, 1, &rp.labels, 10, 1);

        let mut refined = IndexRefineFlat::new(index_factory(ds.dim, "PQ8x4fs").unwrap());
        refined.refine_factor = 16;
        refined.train(&ds.train).unwrap();
        refined.add(&ds.base).unwrap();
        refined.seal().unwrap();
        let rr = refined.search(&ds.queries, 10, None).unwrap();
        let rec_refined = recall_at_r(&gt, 1, &rr.labels, 10, 1);

        assert!(
            rec_refined >= rec_plain + 0.1,
            "refine {rec_refined} vs plain {rec_plain}"
        );
        // refined distances are exact L2 → sorted, and top-1 is exact
        for qi in 0..50 {
            let row = &rr.distances[qi * 10..(qi + 1) * 10];
            assert!(row.windows(2).all(|w| w[0] <= w[1]));
        }
    }

    #[test]
    fn exact_distances_returned() {
        let ds = SyntheticDataset::gaussian(500, 5, 16, 212);
        let mut refined = IndexRefineFlat::new(index_factory(ds.dim, "PQ4x4fs").unwrap());
        refined.train(&ds.train).unwrap();
        refined.add(&ds.base).unwrap();
        refined.seal().unwrap();
        let r = refined.search(&ds.queries, 3, None).unwrap();
        for qi in 0..5 {
            for (j, &label) in r.row(qi).iter().enumerate() {
                if label < 0 {
                    continue;
                }
                let v = &ds.base[label as usize * ds.dim..(label as usize + 1) * ds.dim];
                let exact = crate::util::l2_sq(ds.query(qi), v);
                assert!((exact - r.distances[qi * 3 + j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn set_param_passthrough() {
        let mut refined = IndexRefineFlat::new(index_factory(32, "IVF8,PQ8x4fs").unwrap());
        refined.set_param("refine_factor", "8").unwrap();
        assert_eq!(refined.refine_factor, 8);
        refined.set_param("nprobe", "4").unwrap(); // forwarded to base
        assert!(refined.set_param("bogus", "1").is_err());
        assert!(refined.describe().starts_with("Refine(x8"));
    }
}
