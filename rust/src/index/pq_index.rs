//! PQ-backed index types: the naive-scan baseline, the 4-bit fastscan
//! index, and the IVF(+HNSW) composition — the three systems compared in
//! the paper's evaluation.
//!
//! All three follow the build-then-query lifecycle: `train`/`add` mutate,
//! `seal` packs staged codes, and `search(&self, …)` is read-only with
//! per-request [`SearchParams`] overrides.

use super::params::{effective_fastscan, effective_ivf};
use super::query::{Hit, QueryKind, QueryRequest, QueryResponse, QueryStats};
use super::{Index, SearchParams};
use crate::exec::{range_packed, topk_packed, MaskPlan, QueryExecutor, QueryPlan};
use crate::ivf::{IvfParams, IvfPq4};
use crate::obs::{Phase, TraceSpan};
use crate::pq::adc::{range_adc, topk_adc};
use crate::pq::fastscan::FastScanParams;
use crate::pq::{CodeWidth, PackedCodes, PqParams, ProductQuantizer};
use crate::{Error, Result};

/// "Original PQ" (paper Fig. 2 baseline): flat codes + in-memory f32 LUT
/// scan. Supports both 4-bit (K=16) and 8-bit (K=256) codes.
pub struct IndexPq {
    dim: usize,
    params: PqParams,
    pq: Option<ProductQuantizer>,
    codes: Vec<u8>,
    ntotal: usize,
}

impl IndexPq {
    pub fn new(dim: usize, params: PqParams) -> Self {
        Self { dim, params, pq: None, codes: Vec::new(), ntotal: 0 }
    }

    pub fn pq(&self) -> Option<&ProductQuantizer> {
        self.pq.as_ref()
    }
}

impl Index for IndexPq {
    fn dim(&self) -> usize {
        self.dim
    }

    fn ntotal(&self) -> usize {
        self.ntotal
    }

    fn is_trained(&self) -> bool {
        self.pq.is_some()
    }

    fn train(&mut self, data: &[f32]) -> Result<()> {
        self.pq = Some(ProductQuantizer::train(data, self.dim, &self.params)?);
        Ok(())
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        let new_codes = pq.encode(data)?;
        self.ntotal += data.len() / self.dim;
        self.codes.extend(new_codes);
        Ok(())
    }

    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse> {
        req.kind.validate()?;
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if req.queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch {
                expected: self.dim,
                got: req.queries.len() % self.dim,
            });
        }
        let nq = req.queries.len() / self.dim;
        if nq == 0 || self.ntotal == 0 || matches!(req.kind, QueryKind::TopK { k: 0 }) {
            return Ok(QueryResponse::empty(nq));
        }
        // plan: the filter is query-independent (labels are identity
        // positions), so it compiles ONCE per request into a keep bitmap
        // shared read-only by every worker — a plain skip in the scan,
        // trivially bit-identical to post-filtering the unfiltered scan.
        let keep_bits: Option<Vec<bool>> = req
            .filter
            .as_ref()
            .map(|f| (0..self.ntotal as i64).map(|id| f.matches(id)).collect());
        let selectivity = keep_bits
            .as_ref()
            .map(|b| b.iter().filter(|&&x| x).count() as f64 / self.ntotal as f64)
            .unwrap_or(1.0);
        let keep_bits = keep_bits.as_deref();
        let dim = self.dim;
        let queries = req.queries;
        let kind = req.kind;
        let out: Vec<Vec<Hit>> = exec.run_batch(nq, |qi, scratch| {
            let q = &queries[qi * dim..(qi + 1) * dim];
            let keep_closure;
            let keep: Option<&dyn Fn(i64) -> bool> = match keep_bits {
                Some(bits) => {
                    keep_closure = move |id: i64| bits[id as usize];
                    Some(&keep_closure)
                }
                None => None,
            };
            let mut luts = scratch.take_luts();
            pq.compute_luts_into(q, &mut luts);
            let (row, _kept) = match kind {
                QueryKind::TopK { k } => topk_adc(pq, &luts, &self.codes, None, k, keep),
                QueryKind::Range { radius } => {
                    range_adc(pq, &luts, &self.codes, None, radius, keep)
                }
            };
            scratch.put_luts(luts);
            row.into_iter().map(|(distance, label)| Hit { distance, label }).collect()
        });
        let mut stats = vec![
            QueryStats {
                codes_scanned: self.ntotal,
                lists_probed: 1,
                filter_selectivity: selectivity,
                ..Default::default()
            };
            nq
        ];
        exec.stamp_stats(&mut stats, nq);
        Ok(QueryResponse { hits: out, stats, traces: Vec::new() })
    }

    fn describe(&self) -> String {
        format!(
            "PQ{}x{}(d={}, n={})",
            self.params.m,
            self.params.nbits(),
            self.dim,
            self.ntotal
        )
    }
}

/// The paper's contribution as a flat index: PQ with the dual-lane SIMD
/// fastscan kernel (faiss `IndexPQFastScan` analog), width-parametric —
/// 2-, 4- or 8-bit codes on the same register model ([`CodeWidth`]). The
/// type keeps its historical `…Pq4…` name; 4-bit is the default width.
pub struct IndexPq4FastScan {
    dim: usize,
    /// Internal quantizer parameters (`width.pq_params(m)`).
    params: PqParams,
    /// User-facing sub-quantizers.
    m: usize,
    /// Fastscan code width.
    width: CodeWidth,
    /// Default kernel parameters (per-request [`SearchParams`] override
    /// them without touching this).
    pub fastscan: FastScanParams,
    pq: Option<ProductQuantizer>,
    /// Flat staging codes; packed into the SIMD layout by [`Self::seal`].
    staging: Vec<u8>,
    packed: Option<PackedCodes>,
    ntotal: usize,
}

impl IndexPq4FastScan {
    /// 4-bit fastscan (the paper's configuration).
    pub fn new(dim: usize, m: usize) -> Self {
        Self::new_width(dim, m, CodeWidth::W4)
    }

    /// Width-parametric constructor: `m` sub-quantizers at `width` bits.
    pub fn new_width(dim: usize, m: usize, width: CodeWidth) -> Self {
        Self {
            dim,
            params: width.pq_params(m),
            m,
            width,
            fastscan: FastScanParams::default(),
            pq: None,
            staging: Vec::new(),
            packed: None,
            ntotal: 0,
        }
    }

    pub fn pq(&self) -> Option<&ProductQuantizer> {
        self.pq.as_ref()
    }

    /// Fastscan code width of this index.
    pub fn width(&self) -> CodeWidth {
        self.width
    }

    /// Flat staging codes (`ntotal × width.code_columns(m)`, one byte per
    /// internal sub-quantizer) — the persistence layer serializes these.
    /// Empty for zero-copy (mapped) loads; use
    /// [`IndexPq4FastScan::flat_codes`] where columns are always needed.
    pub fn staging_codes(&self) -> &[u8] {
        &self.staging
    }

    /// The kernel-ready packed block (`None` while unsealed or empty) —
    /// the v3 persistence accessor: format v3 stores the packed layout
    /// verbatim so a mapped reopen needs no repack.
    pub fn packed(&self) -> Option<&PackedCodes> {
        self.packed.as_ref()
    }

    /// Flat code columns, rematerialized from the packed block when the
    /// staging was never kept (zero-copy loads).
    pub fn flat_codes(&self) -> std::borrow::Cow<'_, [u8]> {
        if self.staging.is_empty() && self.ntotal > 0 {
            match &self.packed {
                Some(p) => std::borrow::Cow::Owned(p.unpack()),
                None => std::borrow::Cow::Borrowed(&self.staging[..]),
            }
        } else {
            std::borrow::Cow::Borrowed(&self.staging[..])
        }
    }

    /// Rebuild from persisted parts (trained internal PQ + flat codes) at
    /// 4-bit width. The result is sealed and ready to serve.
    pub fn from_parts(pq: ProductQuantizer, codes: Vec<u8>) -> Result<Self> {
        Self::from_parts_width(pq, codes, CodeWidth::W4)
    }

    /// [`IndexPq4FastScan::from_parts`] at an explicit width; `pq` is the
    /// internal quantizer (`width.code_columns(m)` columns).
    pub fn from_parts_width(
        pq: ProductQuantizer,
        codes: Vec<u8>,
        width: CodeWidth,
    ) -> Result<Self> {
        if pq.m == 0 || codes.len() % pq.m != 0 {
            return Err(Error::InvalidParameter("codes not divisible by m".into()));
        }
        // a width/codebook mismatch (corrupt or hand-edited file) must
        // fail here, not return silently wrong distances at search time
        if pq.ksub != width.sub_ksub() {
            return Err(Error::InvalidParameter(format!(
                "{width} fastscan needs a K={} quantizer, file has K={}",
                width.sub_ksub(),
                pq.ksub
            )));
        }
        let m = match width {
            CodeWidth::W8 => {
                if pq.m % 2 != 0 {
                    return Err(Error::InvalidParameter(
                        "8-bit fastscan needs an even internal column count".into(),
                    ));
                }
                pq.m / 2
            }
            _ => pq.m,
        };
        let ntotal = codes.len() / pq.m;
        let mut index = Self {
            dim: pq.dim,
            params: PqParams { m: pq.m, ksub: pq.ksub, train_iters: 0, seed: 0 },
            m,
            width,
            fastscan: FastScanParams::default(),
            pq: Some(pq),
            staging: codes,
            packed: None,
            ntotal,
        };
        index.seal()?;
        Ok(index)
    }

    /// Rebuild from an already-packed block (format v3): adopts the block
    /// — heap-owned or a mapped window — without materializing flat
    /// staging columns. The result is sealed and ready to serve.
    pub fn from_packed_width(
        pq: ProductQuantizer,
        packed: PackedCodes,
        width: CodeWidth,
    ) -> Result<Self> {
        if pq.ksub != width.sub_ksub() {
            return Err(Error::InvalidParameter(format!(
                "{width} fastscan needs a K={} quantizer, file has K={}",
                width.sub_ksub(),
                pq.ksub
            )));
        }
        if packed.width != width || packed.m_codes != pq.m {
            return Err(Error::CorruptIndex(format!(
                "packed block is {} × {} columns, quantizer is {width} × {}",
                packed.width, packed.m_codes, pq.m
            )));
        }
        let ntotal = packed.n;
        Ok(Self {
            dim: pq.dim,
            params: PqParams { m: pq.m, ksub: pq.ksub, train_iters: 0, seed: 0 },
            m: packed.m,
            width,
            fastscan: FastScanParams::default(),
            pq: Some(pq),
            staging: Vec::new(),
            packed: Some(packed),
            ntotal,
        })
    }

    /// Pack the staged codes into the kernel's interleaved layout.
    /// Idempotent: a second call on an already-sealed index is a no-op.
    pub fn seal(&mut self) -> Result<()> {
        if self.packed.is_none() && !self.staging.is_empty() {
            self.pq.as_ref().ok_or(Error::NotTrained)?;
            self.packed = Some(PackedCodes::pack(&self.staging, self.m, self.width)?);
        }
        Ok(())
    }

    /// Whether all staged codes are packed (searchable without reseal).
    pub fn is_sealed(&self) -> bool {
        self.packed.is_some() || self.staging.is_empty()
    }

    /// The plan/execute core shared by [`Index::query_exec`] and the
    /// LUT-reuse entry: builds the request's plan (resolved kernel
    /// parameters + the filter compiled into one position-space
    /// [`crate::pq::fastscan::FilterMask`] — flat fastscan uses identity
    /// labels), then fans the
    /// batch out over the executor; each worker runs the masked top-k or
    /// range kernel on its pooled scratch arena.
    fn query_luts_exec(
        &self,
        req: &QueryRequest<'_>,
        luts: Option<&[f32]>,
        exec: &QueryExecutor,
    ) -> Result<QueryResponse> {
        req.kind.validate()?;
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        if req.queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch {
                expected: self.dim,
                got: req.queries.len() % self.dim,
            });
        }
        let nq = req.queries.len() / self.dim;
        let lut_len = pq.m * pq.ksub;
        if let Some(ls) = luts {
            if ls.len() != nq * lut_len {
                return Err(Error::InvalidParameter(format!(
                    "precomputed luts length {} != nq {nq} × {lut_len}",
                    ls.len()
                )));
            }
        }
        if nq == 0 || self.ntotal == 0 || matches!(req.kind, QueryKind::TopK { k: 0 }) {
            return Ok(QueryResponse::empty(nq));
        }
        let packed = match &self.packed {
            Some(p) => p,
            None => return Err(Error::NotSealed),
        };
        // plan: resolved kernel params + the compiled filter, once per call
        let plan_t0 = req.trace.then(std::time::Instant::now);
        let plan = QueryPlan {
            queries: req.queries,
            dim: self.dim,
            nq,
            kind: req.kind,
            fs: effective_fastscan(&self.fastscan, req.params.as_ref()),
            masks: match &req.filter {
                Some(f) => MaskPlan::flat(f, self.ntotal),
                None => MaskPlan::None,
            },
            luts,
            lut_len,
        };
        let mask = plan.masks.flat_mask();
        let selectivity = mask.map(|m| m.selectivity()).unwrap_or(1.0);
        let all_filtered = mask.is_some_and(|m| m.pass_count() == 0);
        // request-level plan cost, attributed to each query it served
        let plan_us = plan_t0.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0);
        if all_filtered {
            let stats = QueryStats {
                codes_scanned: 0,
                lists_probed: 0,
                filter_selectivity: 0.0,
                ..Default::default()
            };
            return Ok(QueryResponse {
                hits: vec![Vec::new(); nq],
                stats: vec![stats; nq],
                traces: if req.trace { vec![Vec::new(); nq] } else { Vec::new() },
            });
        }
        let results: Vec<(Vec<Hit>, Vec<TraceSpan>)> = exec.run_batch(nq, |qi, scratch| {
            if req.trace {
                scratch.trace_mut().enable();
                scratch.trace_mut().add(Phase::PlanCompile, plan_us, 0, 0);
            }
            let t_total = scratch.trace().start();
            let mut lbuf = scratch.take_luts();
            let luts_f32: &[f32] = match plan.luts_for(qi) {
                Some(ls) => ls,
                None => {
                    let t_lut = scratch.trace().start();
                    pq.compute_luts_into(plan.query(qi), &mut lbuf);
                    scratch.trace_mut().finish(Phase::LutBuild, t_lut);
                    &lbuf
                }
            };
            let row = match plan.kind {
                QueryKind::TopK { k } => {
                    topk_packed(pq, packed, luts_f32, k, &plan.fs, None, mask, scratch)
                }
                QueryKind::Range { radius } => {
                    range_packed(pq, packed, luts_f32, radius, &plan.fs, None, mask, scratch)
                }
            };
            scratch.put_luts(lbuf);
            let spans = if req.trace {
                scratch.trace_mut().finish(Phase::Total, t_total);
                scratch.trace_mut().add(Phase::Total, plan_us, 0, 0);
                scratch.trace_mut().drain()
            } else {
                Vec::new()
            };
            (row, spans)
        });
        let mut hits = Vec::with_capacity(results.len());
        let mut traces = if req.trace { Vec::with_capacity(results.len()) } else { Vec::new() };
        for (row, spans) in results {
            hits.push(row);
            if req.trace {
                traces.push(spans);
            }
        }
        let mut stats = vec![
            QueryStats {
                codes_scanned: self.ntotal,
                lists_probed: 1,
                filter_selectivity: selectivity,
                bytes_mapped: packed.mapped_bytes(),
                ..Default::default()
            };
            nq
        ];
        exec.stamp_stats(&mut stats, nq);
        Ok(QueryResponse { hits, stats, traces })
    }
}

impl Index for IndexPq4FastScan {
    fn dim(&self) -> usize {
        self.dim
    }

    fn ntotal(&self) -> usize {
        self.ntotal
    }

    fn is_trained(&self) -> bool {
        self.pq.is_some()
    }

    fn train(&mut self, data: &[f32]) -> Result<()> {
        self.width.validate(self.dim, self.m)?;
        self.pq = Some(ProductQuantizer::train(data, self.dim, &self.params)?);
        Ok(())
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        let pq = self.pq.as_ref().ok_or(Error::NotTrained)?;
        let codes = pq.encode(data)?;
        // a zero-copy-loaded index has rows only in its packed block;
        // rematerialize the flat columns before appending, or the repack
        // at seal() would silently drop the mapped rows
        if self.staging.is_empty() && self.ntotal > 0 {
            if let Some(p) = &self.packed {
                self.staging = p.unpack();
            }
        }
        self.staging.extend(codes);
        self.ntotal += data.len() / self.dim;
        self.packed = None;
        Ok(())
    }

    fn seal(&mut self) -> Result<()> {
        IndexPq4FastScan::seal(self)
    }

    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse> {
        self.query_luts_exec(req, None, exec)
    }

    fn query_with_luts_exec(
        &self,
        req: &QueryRequest<'_>,
        luts: &[f32],
        exec: &QueryExecutor,
    ) -> Result<QueryResponse> {
        self.query_luts_exec(req, Some(luts), exec)
    }

    fn lut_signature(&self) -> Option<u64> {
        self.pq.as_ref().map(|pq| pq.signature())
    }

    fn compute_scan_luts(&self, queries: &[f32]) -> Option<Vec<f32>> {
        let pq = self.pq.as_ref()?;
        if queries.len() % self.dim != 0 {
            return None;
        }
        Some(pq.compute_luts_batch(queries))
    }

    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "rerank" | "reservoir_factor" | "backend" => {
                let mut p = SearchParams::default();
                p.assign(key, value)?;
                self.fastscan = p.fastscan(&self.fastscan);
                Ok(())
            }
            _ => Err(Error::InvalidParameter(format!("unknown parameter {key}"))),
        }
    }

    fn describe(&self) -> String {
        format!(
            "PQ{}x{}fs(d={}, n={}, {:?})",
            self.m,
            self.width.bits(),
            self.dim,
            self.ntotal,
            self.fastscan.backend
        )
    }
}

/// IVF + (optional HNSW coarse) + PQ fastscan — the Table 1 system,
/// width-parametric like the flat index.
pub struct IndexIvfPq4 {
    inner: IvfPq4,
}

impl IndexIvfPq4 {
    pub fn new(dim: usize, nlist: usize, m: usize, coarse_hnsw: bool, hnsw_m: usize) -> Self {
        Self::new_width(dim, nlist, m, CodeWidth::W4, coarse_hnsw, hnsw_m)
    }

    /// Width-parametric constructor (`IVF…,PQ{m}x{2,4,8}fs`).
    pub fn new_width(
        dim: usize,
        nlist: usize,
        m: usize,
        width: CodeWidth,
        coarse_hnsw: bool,
        hnsw_m: usize,
    ) -> Self {
        let mut params = IvfParams::new(nlist);
        params.coarse_hnsw = coarse_hnsw;
        params.hnsw_m = hnsw_m;
        Self { inner: IvfPq4::new_width(dim, params, m, width) }
    }

    /// Wrap an already-built [`IvfPq4`] (e.g. one populated with
    /// `add_with_ids` and tuned defaults) as a trait-object-ready index.
    pub fn from_inner(inner: IvfPq4) -> Self {
        Self { inner }
    }

    pub fn inner(&self) -> &IvfPq4 {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut IvfPq4 {
        &mut self.inner
    }
}

impl Index for IndexIvfPq4 {
    fn dim(&self) -> usize {
        self.inner.dim
    }

    fn ntotal(&self) -> usize {
        self.inner.ntotal()
    }

    fn is_trained(&self) -> bool {
        self.inner.is_trained()
    }

    fn train(&mut self, data: &[f32]) -> Result<()> {
        self.inner.train(data)
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        self.inner.add(data)
    }

    fn seal(&mut self) -> Result<()> {
        self.inner.seal()
    }

    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse> {
        // query_exec_with handles all degenerate cases (untrained, dim
        // mismatch, k == 0, empty batch, empty index) with the same
        // semantics as the other indexes
        let (nprobe, ef_search, fs) =
            effective_ivf(req.params.as_ref(), self.inner.nprobe, &self.inner.fastscan);
        let (hits, stats, traces) = self.inner.query_exec_traced_with(
            req.queries,
            None,
            &req.kind,
            req.filter.as_ref(),
            nprobe,
            ef_search,
            &fs,
            exec,
            req.trace,
        )?;
        Ok(QueryResponse { hits, stats, traces })
    }

    fn query_with_luts_exec(
        &self,
        req: &QueryRequest<'_>,
        luts: &[f32],
        exec: &QueryExecutor,
    ) -> Result<QueryResponse> {
        let (nprobe, ef_search, fs) =
            effective_ivf(req.params.as_ref(), self.inner.nprobe, &self.inner.fastscan);
        let (hits, stats, traces) = self.inner.query_exec_traced_with(
            req.queries,
            Some(luts),
            &req.kind,
            req.filter.as_ref(),
            nprobe,
            ef_search,
            &fs,
            exec,
            req.trace,
        )?;
        Ok(QueryResponse { hits, stats, traces })
    }

    fn lut_signature(&self) -> Option<u64> {
        self.inner.pq.as_ref().map(|pq| pq.signature())
    }

    fn compute_scan_luts(&self, queries: &[f32]) -> Option<Vec<f32>> {
        self.inner.compute_scan_luts(queries).ok()
    }

    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        let mut p = SearchParams::default();
        p.assign(key, value)?;
        match key {
            "nprobe" => self.inner.nprobe = p.nprobe.unwrap(),
            "ef_search" => self.inner.set_ef_search(p.ef_search.unwrap()),
            "rerank" | "reservoir_factor" | "backend" => {
                self.inner.fastscan = p.fastscan(&self.inner.fastscan)
            }
            _ => return Err(Error::InvalidParameter(format!("unknown parameter {key}"))),
        }
        Ok(())
    }

    fn describe(&self) -> String {
        format!(
            "IVF{}{},PQ{}x{}fs(d={}, n={}, nprobe={})",
            self.inner.params.nlist,
            if self.inner.params.coarse_hnsw {
                format!("_HNSW{}", self.inner.params.hnsw_m)
            } else {
                String::new()
            },
            self.inner.pq_m,
            self.inner.width.bits(),
            self.inner.dim,
            self.inner.ntotal(),
            self.inner.nprobe
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SyntheticDataset;
    use crate::eval::{ground_truth, recall_at_r};

    #[test]
    fn pq_and_fastscan_same_accuracy() {
        // the Fig. 2 claim at index level: identical recall for same M
        let ds = SyntheticDataset::gaussian(800, 40, 32, 101);
        let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);

        let mut naive = IndexPq::new(ds.dim, PqParams::new_4bit(8));
        naive.train(&ds.base).unwrap();
        naive.add(&ds.base).unwrap();
        let rn = naive.search(&ds.queries, 10, None).unwrap();

        let mut fast = IndexPq4FastScan::new(ds.dim, 8);
        fast.train(&ds.base).unwrap();
        fast.add(&ds.base).unwrap();
        fast.seal().unwrap();
        let rf = fast.search(&ds.queries, 10, None).unwrap();

        let rec_n = recall_at_r(&gt, 1, &rn.labels, 10, 10);
        let rec_f = recall_at_r(&gt, 1, &rf.labels, 10, 10);
        assert!(
            (rec_n - rec_f).abs() <= 0.05,
            "naive recall {rec_n} vs fastscan {rec_f}"
        );
    }

    #[test]
    fn ivf_index_trait_roundtrip() {
        let ds = SyntheticDataset::gaussian(1200, 20, 16, 102);
        let mut idx = IndexIvfPq4::new(ds.dim, 8, 4, false, 16);
        assert!(!idx.is_trained());
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        assert_eq!(idx.ntotal(), 1200);
        idx.set_param("nprobe", "8").unwrap();
        let r = idx.search(&ds.queries, 5, None).unwrap();
        assert_eq!(r.nq(), 20);
        assert!(idx.describe().contains("nprobe=8"));
    }

    #[test]
    fn per_request_params_override_defaults() {
        let ds = SyntheticDataset::gaussian(1200, 20, 16, 105);
        let mut idx = IndexIvfPq4::new(ds.dim, 8, 4, false, 16);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        // default nprobe = 1; full-probe override must not mutate the index
        let wide = SearchParams::new().with_nprobe(8).with_reservoir_factor(32);
        let r_wide = idx.search(&ds.queries, 5, Some(&wide)).unwrap();
        assert_eq!(idx.inner().nprobe, 1, "per-request params leaked into defaults");
        // the override matches setting the same values as defaults
        idx.set_param("nprobe", "8").unwrap();
        idx.set_param("reservoir_factor", "32").unwrap();
        let r_default = idx.search(&ds.queries, 5, None).unwrap();
        assert_eq!(r_wide.labels, r_default.labels);
        assert_eq!(r_wide.distances, r_default.distances);
    }

    #[test]
    fn set_param_validation() {
        let mut idx = IndexIvfPq4::new(16, 4, 4, false, 8);
        assert!(idx.set_param("nprobe", "abc").is_err());
        assert!(idx.set_param("bogus", "1").is_err());
        idx.set_param("rerank", "false").unwrap();
        idx.set_param("backend", "portable").unwrap();
        assert!(idx.set_param("backend", "avx512").is_err());
    }

    #[test]
    fn empty_fastscan_index_search() {
        let mut idx = IndexPq4FastScan::new(16, 4);
        let ds = SyntheticDataset::gaussian(100, 2, 16, 103);
        idx.train(&ds.base).unwrap();
        let r = idx.search(&ds.queries, 3, None).unwrap();
        assert!(r.labels.iter().all(|&l| l == -1));
    }

    #[test]
    fn unsealed_search_errors_not_silently_repacks() {
        let ds = SyntheticDataset::gaussian(300, 5, 16, 106);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.base).unwrap();
        idx.add(&ds.base).unwrap();
        assert!(!idx.is_sealed());
        let err = idx.search(&ds.queries, 3, None).unwrap_err();
        assert!(matches!(err, Error::NotSealed), "{err}");
        idx.seal().unwrap();
        assert!(idx.is_sealed());
        idx.seal().unwrap(); // idempotent
        let r = idx.search(&ds.queries, 3, None).unwrap();
        assert_eq!(r.nq(), 5);
        // adds dirty the seal again
        idx.add(&ds.base[..ds.dim * 2]).unwrap();
        assert!(!idx.is_sealed());
        assert!(matches!(idx.search(&ds.queries, 3, None), Err(Error::NotSealed)));
    }

    #[test]
    fn degenerate_searches_consistent() {
        let ds = SyntheticDataset::gaussian(400, 4, 16, 107);
        let mut fast = IndexPq4FastScan::new(ds.dim, 4);
        fast.train(&ds.base).unwrap();
        fast.add(&ds.base).unwrap();
        fast.seal().unwrap();
        let mut naive = IndexPq::new(ds.dim, PqParams::new_4bit(4));
        naive.train(&ds.base).unwrap();
        naive.add(&ds.base).unwrap();
        let mut ivf = IndexIvfPq4::new(ds.dim, 4, 4, false, 8);
        ivf.train(&ds.base).unwrap();
        ivf.add(&ds.base).unwrap();
        ivf.seal().unwrap();
        let indexes: [&dyn Index; 3] = [&fast, &naive, &ivf];
        for idx in indexes {
            // k == 0 → zero-size result, no error, nq() well-defined
            let r = idx.search(&ds.queries, 0, None).unwrap();
            assert_eq!((r.k, r.nq(), r.labels.len()), (0, 0, 0), "{}", idx.describe());
            // empty batch → zero-size result
            let r = idx.search(&[], 5, None).unwrap();
            assert_eq!((r.k, r.nq()), (5, 0), "{}", idx.describe());
        }
    }

    #[test]
    fn untrained_add_errors() {
        let mut idx = IndexPq4FastScan::new(8, 2);
        assert!(idx.add(&[0.0; 8]).is_err());
        let mut naive = IndexPq::new(8, PqParams::new_4bit(2));
        assert!(naive.add(&[0.0; 8]).is_err());
    }

    /// Build→seal→search round-trip per width, with describe strings and
    /// width-specific validation errors.
    #[test]
    fn fastscan_widths_roundtrip() {
        let ds = SyntheticDataset::gaussian(600, 10, 32, 108);
        for width in CodeWidth::ALL {
            let mut idx = IndexPq4FastScan::new_width(ds.dim, 8, width);
            assert_eq!(idx.width(), width);
            idx.train(&ds.base).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            let r = idx.search(&ds.queries, 5, None).unwrap();
            assert_eq!(r.nq(), 10, "{width}");
            assert!(r.labels.iter().all(|&l| (-1..600).contains(&l)), "{width}");
            let d = idx.describe();
            assert!(
                d.starts_with(&format!("PQ8x{}fs", width.bits())),
                "{width}: {d}"
            );
        }
        // 8-bit needs dim % 2m == 0: dim=32, m=16 → cols=32 ok; m=12 → 24 no
        let mut bad = IndexPq4FastScan::new_width(32, 12, CodeWidth::W8);
        let e = bad.train(&ds.base[..32 * 40]).unwrap_err().to_string();
        assert!(e.contains("2*m"), "{e}");
    }

    /// Recall-monotonicity property (the Quicker-ADC trade-off): at fixed
    /// M, more bits per code must not lose recall —
    /// recall(2-bit) ≤ recall(4-bit) ≤ recall(8-bit), modulo small noise.
    #[test]
    fn recall_monotone_in_width() {
        let ds = SyntheticDataset::gaussian(2000, 50, 32, 109);
        let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
        // rerank off: recall reflects raw code fidelity, the property
        // under test (rerank would let the exact pass paper over it)
        let params = SearchParams::new().with_rerank(false).with_reservoir_factor(16);
        let mut recalls = Vec::new();
        for width in [CodeWidth::W2, CodeWidth::W4, CodeWidth::W8] {
            let mut idx = IndexPq4FastScan::new_width(ds.dim, 8, width);
            idx.train(&ds.train).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            let r = idx.search(&ds.queries, 10, Some(&params)).unwrap();
            recalls.push(recall_at_r(&gt, 1, &r.labels, 10, 10));
        }
        assert!(
            recalls[0] <= recalls[1] + 0.06 && recalls[1] <= recalls[2] + 0.06,
            "recall not monotone in width: 2-bit {:.3}, 4-bit {:.3}, 8-bit {:.3}",
            recalls[0],
            recalls[1],
            recalls[2]
        );
        // and the coarsest-to-finest gap is a real accuracy difference,
        // not a tie: 8-bit must beat 2-bit outright
        assert!(
            recalls[2] > recalls[0],
            "8-bit ({:.3}) should beat 2-bit ({:.3})",
            recalls[2],
            recalls[0]
        );
    }

    /// Filtered query ≡ unfiltered-query-then-post-filter, bit-identical,
    /// for the flat fastscan index at every width (reservoir sized so
    /// nothing is pruned; rerank makes distances exact).
    #[test]
    fn filtered_query_matches_postfilter_all_widths() {
        use crate::index::{Filter, QueryRequest};
        let ds = SyntheticDataset::gaussian(500, 6, 32, 110);
        for width in CodeWidth::ALL {
            let mut idx = IndexPq4FastScan::new_width(ds.dim, 8, width);
            idx.train(&ds.train).unwrap();
            idx.add(&ds.base).unwrap();
            idx.seal().unwrap();
            let params = SearchParams::new().with_reservoir_factor(512);
            let filter = Filter::id_range(100, 300);
            let filtered = idx
                .query(
                    &QueryRequest::top_k(&ds.queries, 10)
                        .with_filter(filter.clone())
                        .with_params(params.clone()),
                )
                .unwrap();
            // reference: unfiltered with k = ntotal, post-filter, truncate
            let full = idx
                .query(&QueryRequest::top_k(&ds.queries, 500).with_params(params.clone()))
                .unwrap();
            for qi in 0..ds.queries.len() / ds.dim {
                let want: Vec<_> = full.hits[qi]
                    .iter()
                    .filter(|h| filter.matches(h.label))
                    .take(10)
                    .copied()
                    .collect();
                assert_eq!(filtered.hits[qi], want, "{width} q{qi}");
                assert!(
                    (filtered.stats[qi].filter_selectivity - 0.4).abs() < 1e-9,
                    "{width}"
                );
            }
        }
    }

    /// Degenerate filters: empty → well-formed empty responses; full →
    /// identical to unfiltered.
    #[test]
    fn empty_and_full_filter_edge_cases() {
        use crate::index::{Filter, QueryRequest};
        let ds = SyntheticDataset::gaussian(300, 4, 16, 111);
        let mut idx = IndexPq4FastScan::new(ds.dim, 4);
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        idx.seal().unwrap();
        let empty = idx
            .query(&QueryRequest::top_k(&ds.queries, 5).with_filter(Filter::id_set(&[])))
            .unwrap();
        assert_eq!(empty.nq(), 4);
        assert!(empty.hits.iter().all(|row| row.is_empty()));
        assert!(empty.stats.iter().all(|s| s.filter_selectivity == 0.0));
        // the search shim shape stays well-formed too: padded
        let r = empty.into_search_result(5);
        assert!(r.labels.iter().all(|&l| l == -1));

        let full = idx
            .query(&QueryRequest::top_k(&ds.queries, 5).with_filter(Filter::id_range(0, 300)))
            .unwrap();
        let bare = idx.query(&QueryRequest::top_k(&ds.queries, 5)).unwrap();
        assert_eq!(full.hits, bare.hits);
        assert_eq!(full.stats[0].filter_selectivity, 1.0);
    }

    /// The naive-PQ baseline answers the same typed requests (exhaustive
    /// exact ADC), so fastscan results can be differentials against it.
    #[test]
    fn naive_pq_filtered_and_range_queries() {
        use crate::index::{Filter, QueryRequest};
        let ds = SyntheticDataset::gaussian(400, 4, 16, 112);
        let mut idx = IndexPq::new(ds.dim, PqParams::new_4bit(4));
        idx.train(&ds.train).unwrap();
        idx.add(&ds.base).unwrap();
        let filtered = idx
            .query(
                &QueryRequest::top_k(&ds.queries, 8).with_filter(Filter::predicate(|id| id % 2 == 0)),
            )
            .unwrap();
        assert!(filtered.hits.iter().flatten().all(|h| h.label % 2 == 0));
        assert!((filtered.stats[0].filter_selectivity - 0.5).abs() < 1e-9);
        // range with a radius below the best distance → empty but well-formed
        let none = idx.query(&QueryRequest::range(&ds.queries, -1.0)).unwrap();
        assert!(none.hits.iter().all(|row| row.is_empty()));
        // generous radius finds hits, sorted ascending
        let some = idx.query(&QueryRequest::range(&ds.queries, 1e6)).unwrap();
        assert!(some.hits.iter().all(|row| row.len() == 400));
        assert!(some.hits[0].windows(2).all(|w| w[0].distance <= w[1].distance));
        // NaN radius rejected
        assert!(idx.query(&QueryRequest::range(&ds.queries, f32::NAN)).is_err());
    }

    #[test]
    fn pq8_index_works() {
        let ds = SyntheticDataset::gaussian(600, 10, 16, 104);
        let mut idx = IndexPq::new(ds.dim, PqParams::new_8bit(4));
        idx.train(&ds.base).unwrap();
        idx.add(&ds.base).unwrap();
        let r = idx.search(&ds.queries, 5, None).unwrap();
        assert_eq!(r.nq(), 10);
        assert!(idx.describe().starts_with("PQ4x8"));
    }
}
