//! Datasets: synthetic stand-ins for SIFT1M / Deep1M / Deep1B plus the
//! standard `fvecs`/`ivecs`/`bvecs` readers so the real files drop in.
//!
//! The paper evaluates on SIFT1M (128-D local descriptors), Deep1M and
//! Deep1B (96-D CNN descriptors). Those downloads are unavailable here, so
//! [`synthetic`] generates deterministic datasets with the property that
//! actually drives PQ recall curves: *cluster structure* (both real
//! datasets are heavily clustered). See DESIGN.md §1 for the substitution
//! argument.

pub mod io;
pub mod synthetic;

pub use synthetic::SyntheticDataset;

/// A dataset ready for indexing experiments.
pub struct Dataset {
    pub dim: usize,
    /// `n × dim` database vectors.
    pub base: Vec<f32>,
    /// `nq × dim` query vectors.
    pub queries: Vec<f32>,
    /// `nt × dim` training vectors (disjoint from base in the synthetic
    /// generators, like the real datasets' learn sets).
    pub train: Vec<f32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.base.len() / self.dim
    }

    pub fn nq(&self) -> usize {
        self.queries.len() / self.dim
    }

    pub fn query(&self, i: usize) -> &[f32] {
        &self.queries[i * self.dim..(i + 1) * self.dim]
    }
}
