//! Deterministic synthetic datasets mimicking SIFT1M and Deep1B statistics.
//!
//! * SIFT-like: 128-D, non-negative, integer-valued, heavy cluster
//!   structure, roughly constant norm (SIFT descriptors are L2-normalized
//!   then scaled to ~512 and quantized to bytes).
//! * Deep-like: 96-D, L2-normalized dense CNN-style features (Deep1B
//!   descriptors are PCA-projected and normalized), cluster structure with
//!   anisotropic within-cluster noise.
//!
//! Both are Gaussian-mixture based; what matters for reproducing the
//! paper's *curves* is that (a) k-means finds real structure, (b) PQ
//! sub-spaces carry signal, (c) queries follow the base distribution.

use super::Dataset;
use crate::util::rng::Rng;

/// Builder for the synthetic datasets used across examples and benches.
pub struct SyntheticDataset;

impl SyntheticDataset {
    /// SIFT1M-like: `n` base vectors, `nq` queries, 128-D.
    pub fn sift_like(n: usize, nq: usize, seed: u64) -> Dataset {
        let dim = 128;
        let nclusters = pick_clusters(n);
        mixture(MixtureSpec {
            n,
            nq,
            ntrain: (n / 10).clamp(2_000.min(n), 100_000),
            dim,
            nclusters,
            center_scale: 24.0,
            noise_scale: 4.0,
            seed,
            post: Post::SiftByte,
        })
    }

    /// Deep1M/Deep1B-like: `n` base vectors, `nq` queries, 96-D normalized.
    pub fn deep_like(n: usize, nq: usize, seed: u64) -> Dataset {
        let dim = 96;
        let nclusters = pick_clusters(n);
        mixture(MixtureSpec {
            n,
            nq,
            ntrain: (n / 10).clamp(2_000.min(n), 100_000),
            dim,
            nclusters,
            center_scale: 1.0,
            noise_scale: 0.18,
            seed: seed.wrapping_add(0xDEEB),
            post: Post::L2Normalize,
        })
    }

    /// Look up a generator by its stable name — the form the experiment
    /// lab's sweep specs use. The caller's `seed` is threaded through the
    /// generator verbatim, so identical `(name, n, nq, seed)` tuples
    /// produce bit-identical datasets on every host, and each recorded
    /// trial documents its `dataset_seed` for exact reproduction.
    pub fn by_name(name: &str, n: usize, nq: usize, seed: u64) -> Option<Dataset> {
        match name {
            "sift" => Some(Self::sift_like(n, nq, seed)),
            "deep" => Some(Self::deep_like(n, nq, seed)),
            "gaussian" => Some(Self::gaussian(n, nq, 32, seed)),
            _ => None,
        }
    }

    /// Small uniform-gaussian dataset (unit tests).
    pub fn gaussian(n: usize, nq: usize, dim: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut gen = |count: usize| -> Vec<f32> {
            (0..count * dim).map(|_| rng.next_gaussian()).collect()
        };
        let base = gen(n);
        let queries = gen(nq);
        let train = gen(n.min(10_000).max(256));
        Dataset { dim, base, queries, train }
    }
}

fn pick_clusters(n: usize) -> usize {
    // enough clusters for structure, few enough that each is populated
    (n / 200).clamp(16, 4096)
}

enum Post {
    /// Clamp to [0, 255] and round — SIFT descriptors are bytes.
    SiftByte,
    /// Project to the unit sphere — Deep descriptors are normalized.
    L2Normalize,
}

struct MixtureSpec {
    n: usize,
    nq: usize,
    ntrain: usize,
    dim: usize,
    nclusters: usize,
    center_scale: f32,
    noise_scale: f32,
    seed: u64,
    post: Post,
}

fn mixture(spec: MixtureSpec) -> Dataset {
    let MixtureSpec { n, nq, ntrain, dim, nclusters, center_scale, noise_scale, seed, post } =
        spec;
    let mut rng = Rng::new(seed);

    // cluster centers, with a few dominant directions to induce the
    // anisotropy real descriptors have
    let ndirs = 8.min(dim);
    let dirs: Vec<f32> = (0..ndirs * dim).map(|_| rng.next_gaussian()).collect();
    // within-cluster variation basis: real descriptors vary along a
    // low-rank manifold, not isotropically — isotropic blobs would make
    // all cluster members collide onto one PQ code (recall lottery) while
    // rank-limited noise gives the sub-quantizers structure to encode.
    let nrank = (dim / 4).max(8);
    let noise_basis: Vec<f32> =
        (0..nrank * dim).map(|_| rng.next_gaussian() / (nrank as f32).sqrt()).collect();
    let mut centers = vec![0.0f32; nclusters * dim];
    for c in 0..nclusters {
        // base random center
        for j in 0..dim {
            centers[c * dim + j] = rng.next_gaussian() * center_scale;
        }
        // plus a random combination of the dominant directions
        for k in 0..ndirs {
            let w = rng.next_gaussian() * center_scale * 0.5;
            for j in 0..dim {
                centers[c * dim + j] += w * dirs[k * dim + j];
            }
        }
        // SIFT energy is non-negative; shift positive later via post
    }
    // cluster weights: zipf-ish (real data has uneven cluster sizes)
    let mut weights: Vec<f64> = (0..nclusters).map(|i| 1.0 / (1.0 + i as f64).sqrt()).collect();
    let total: f64 = weights.iter().sum();
    for w in &mut weights {
        *w /= total;
    }
    let mut cumulative = Vec::with_capacity(nclusters);
    let mut acc = 0.0;
    for &w in &weights {
        acc += w;
        cumulative.push(acc);
    }

    let sample_rows = |count: usize, rng: &mut Rng| -> Vec<f32> {
        let mut out = vec![0.0f32; count * dim];
        for i in 0..count {
            let u = rng.next_f64();
            let c = cumulative.partition_point(|&x| x < u).min(nclusters - 1);
            let row = &mut out[i * dim..(i + 1) * dim];
            row.copy_from_slice(&centers[c * dim..(c + 1) * dim]);
            // low-rank within-cluster variation + a little isotropic jitter
            for r in 0..nrank {
                let g = rng.next_gaussian() * noise_scale * 2.0;
                for j in 0..dim {
                    row[j] += g * noise_basis[r * dim + j];
                }
            }
            for j in 0..dim {
                row[j] += rng.next_gaussian() * noise_scale * 0.25;
            }
            match post {
                Post::SiftByte => {
                    for v in row.iter_mut() {
                        *v = (v.abs()).min(255.0).round();
                    }
                }
                Post::L2Normalize => {
                    let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
                    for v in row.iter_mut() {
                        *v /= norm;
                    }
                }
            }
        }
        out
    };

    let base = sample_rows(n, &mut rng);
    // Queries: small perturbations of held-out base rows. Real benchmark
    // queries (SIFT1M/Deep1B) have true NNs much closer than the bulk
    // pairwise distance — i.i.d. mixture draws would not (distance
    // concentration in 96/128-D makes recall ~0 for ANY quantizer), so the
    // query model matches the property that makes recall measurable.
    let mut queries = Vec::with_capacity(nq * dim);
    for _ in 0..nq {
        let src = rng.below(n);
        let row = &base[src * dim..(src + 1) * dim];
        let mut qrow: Vec<f32> =
            row.iter().map(|&v| v + rng.next_gaussian() * noise_scale * 0.35).collect();
        match post {
            Post::SiftByte => {
                for v in qrow.iter_mut() {
                    *v = v.abs().min(255.0).round();
                }
            }
            Post::L2Normalize => {
                let norm = qrow.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
                for v in qrow.iter_mut() {
                    *v /= norm;
                }
            }
        }
        queries.extend(qrow);
    }
    let train = sample_rows(ntrain, &mut rng);
    Dataset { dim, base, queries, train }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sift_like_properties() {
        let ds = SyntheticDataset::sift_like(2000, 50, 71);
        assert_eq!(ds.dim, 128);
        assert_eq!(ds.n(), 2000);
        assert_eq!(ds.nq(), 50);
        assert!(!ds.train.is_empty());
        // non-negative integer-valued like SIFT bytes
        assert!(ds.base.iter().all(|&v| v >= 0.0 && v <= 255.0 && v == v.round()));
    }

    #[test]
    fn deep_like_is_normalized() {
        let ds = SyntheticDataset::deep_like(1000, 20, 72);
        assert_eq!(ds.dim, 96);
        for i in 0..50 {
            let row = &ds.base[i * 96..(i + 1) * 96];
            let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4, "row {i} norm {norm}");
        }
    }

    #[test]
    fn deterministic() {
        let a = SyntheticDataset::deep_like(500, 10, 73);
        let b = SyntheticDataset::deep_like(500, 10, 73);
        assert_eq!(a.base, b.base);
        assert_eq!(a.queries, b.queries);
        let c = SyntheticDataset::deep_like(500, 10, 74);
        assert_ne!(a.base, c.base);
    }

    #[test]
    fn has_cluster_structure() {
        // k-means on the data must beat k-means on white noise by a wide
        // margin (objective relative to total variance).
        use crate::kmeans::{KMeans, KMeansParams};
        let ds = SyntheticDataset::deep_like(2000, 1, 75);
        let km = KMeans::train(&ds.base, ds.dim, &KMeansParams::new(16)).unwrap();
        // total variance of normalized mixture data around its mean:
        let n = ds.n();
        let mut mean = vec![0.0f32; ds.dim];
        for i in 0..n {
            for j in 0..ds.dim {
                mean[j] += ds.base[i * ds.dim + j];
            }
        }
        for v in &mut mean {
            *v /= n as f32;
        }
        let var: f32 = (0..n)
            .map(|i| crate::util::l2_sq(&ds.base[i * ds.dim..(i + 1) * ds.dim], &mean))
            .sum::<f32>()
            / n as f32;
        assert!(
            km.objective < var * 0.6,
            "kmeans objective {} vs variance {var} — no structure?",
            km.objective
        );
    }

    #[test]
    fn by_name_deterministic_and_seeded() {
        for name in ["sift", "deep", "gaussian"] {
            let a = SyntheticDataset::by_name(name, 400, 8, 9).unwrap();
            let b = SyntheticDataset::by_name(name, 400, 8, 9).unwrap();
            assert_eq!(a.base, b.base, "{name}: same seed must be bit-identical");
            assert_eq!(a.queries, b.queries);
            assert_eq!(a.train, b.train);
            let c = SyntheticDataset::by_name(name, 400, 8, 10).unwrap();
            assert_ne!(a.base, c.base, "{name}: seed must matter");
        }
        assert!(SyntheticDataset::by_name("laion", 10, 1, 0).is_none());
    }

    #[test]
    fn train_disjoint_from_base() {
        let ds = SyntheticDataset::sift_like(1000, 10, 76);
        // same distribution but distinct draws
        assert_ne!(&ds.train[..ds.dim], &ds.base[..ds.dim]);
    }
}
