//! `fvecs` / `ivecs` / `bvecs` file IO — the interchange formats of the
//! SIFT1M / Deep1B benchmark suites (corpus-texmex.irisa.fr).
//!
//! Format: each vector is `[d: i32 little-endian][d elements]`, where the
//! element type is f32 (`fvecs`), i32 (`ivecs`) or u8 (`bvecs`).

use crate::{Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Read an `fvecs` file → `(dim, row-major data)`.
pub fn read_fvecs(path: &Path) -> Result<(usize, Vec<f32>)> {
    let raw = read_all(path)?;
    parse_vecs::<f32, _>(&raw, 4, |chunk| {
        f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]])
    })
}

/// Read an `ivecs` file (ground-truth ids) → `(dim, row-major data)`.
pub fn read_ivecs(path: &Path) -> Result<(usize, Vec<i32>)> {
    let raw = read_all(path)?;
    parse_vecs::<i32, _>(&raw, 4, |chunk| {
        i32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]])
    })
}

/// Read a `bvecs` file (byte vectors, e.g. SIFT1B) → `(dim, f32 data)`.
pub fn read_bvecs(path: &Path) -> Result<(usize, Vec<f32>)> {
    let raw = read_all(path)?;
    parse_vecs::<f32, _>(&raw, 1, |chunk| chunk[0] as f32)
}

/// Write an `fvecs` file from row-major data.
pub fn write_fvecs(path: &Path, dim: usize, data: &[f32]) -> Result<()> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(Error::Dataset(format!("data length {} % dim {dim} != 0", data.len())));
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for row in data.chunks(dim) {
        w.write_all(&(dim as i32).to_le_bytes())?;
        for &v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Write an `ivecs` file from row-major ids.
pub fn write_ivecs(path: &Path, dim: usize, data: &[i32]) -> Result<()> {
    if dim == 0 || data.len() % dim != 0 {
        return Err(Error::Dataset(format!("data length {} % dim {dim} != 0", data.len())));
    }
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for row in data.chunks(dim) {
        w.write_all(&(dim as i32).to_le_bytes())?;
        for &v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

fn read_all(path: &Path) -> Result<Vec<u8>> {
    let f = std::fs::File::open(path)
        .map_err(|e| Error::Dataset(format!("open {}: {e}", path.display())))?;
    let mut r = BufReader::new(f);
    let mut buf = Vec::new();
    r.read_to_end(&mut buf)?;
    Ok(buf)
}

fn parse_vecs<T, F>(raw: &[u8], elem_size: usize, decode: F) -> Result<(usize, Vec<T>)>
where
    F: Fn(&[u8]) -> T,
{
    if raw.is_empty() {
        return Err(Error::Dataset("empty vecs file".into()));
    }
    let mut pos = 0usize;
    let mut dim = 0usize;
    let mut out = Vec::new();
    while pos < raw.len() {
        if pos + 4 > raw.len() {
            return Err(Error::Dataset("truncated header".into()));
        }
        let d = i32::from_le_bytes([raw[pos], raw[pos + 1], raw[pos + 2], raw[pos + 3]]);
        if d <= 0 {
            return Err(Error::Dataset(format!("bad dimension {d}")));
        }
        let d = d as usize;
        if dim == 0 {
            dim = d;
        } else if dim != d {
            return Err(Error::Dataset(format!("inconsistent dims {dim} vs {d}")));
        }
        pos += 4;
        let bytes = d * elem_size;
        if pos + bytes > raw.len() {
            return Err(Error::Dataset("truncated row".into()));
        }
        for e in 0..d {
            out.push(decode(&raw[pos + e * elem_size..pos + (e + 1) * elem_size]));
        }
        pos += bytes;
    }
    Ok((dim, out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("armpq_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn fvecs_roundtrip() {
        let path = tmp("a.fvecs");
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5 - 3.0).collect();
        write_fvecs(&path, 8, &data).unwrap();
        let (dim, back) = read_fvecs(&path).unwrap();
        assert_eq!(dim, 8);
        assert_eq!(back, data);
    }

    #[test]
    fn ivecs_roundtrip() {
        let path = tmp("b.ivecs");
        let data: Vec<i32> = (0..30).map(|i| i * 7 - 50).collect();
        write_ivecs(&path, 10, &data).unwrap();
        let (dim, back) = read_ivecs(&path).unwrap();
        assert_eq!(dim, 10);
        assert_eq!(back, data);
    }

    #[test]
    fn bvecs_parse() {
        // hand-build a 2-row bvecs file with dim 3
        let path = tmp("c.bvecs");
        let mut bytes = Vec::new();
        for row in [[1u8, 2, 3], [250, 0, 7]] {
            bytes.extend_from_slice(&3i32.to_le_bytes());
            bytes.extend_from_slice(&row);
        }
        std::fs::write(&path, bytes).unwrap();
        let (dim, data) = read_bvecs(&path).unwrap();
        assert_eq!(dim, 3);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 250.0, 0.0, 7.0]);
    }

    #[test]
    fn rejects_truncated() {
        let path = tmp("d.fvecs");
        std::fs::write(&path, 4i32.to_le_bytes()).unwrap(); // header only
        assert!(read_fvecs(&path).is_err());
    }

    #[test]
    fn rejects_inconsistent_dims() {
        let path = tmp("e.fvecs");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&2i32.to_le_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        bytes.extend_from_slice(&2.0f32.to_le_bytes());
        bytes.extend_from_slice(&3i32.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 12]);
        std::fs::write(&path, bytes).unwrap();
        assert!(read_fvecs(&path).is_err());
    }

    #[test]
    fn rejects_missing_file() {
        assert!(read_fvecs(Path::new("/nonexistent/x.fvecs")).is_err());
    }

    #[test]
    fn write_rejects_ragged() {
        let path = tmp("f.fvecs");
        assert!(write_fvecs(&path, 5, &[1.0, 2.0, 3.0]).is_err());
    }
}
