//! k-means clustering (Lloyd's algorithm with k-means++ seeding).
//!
//! Training substrate for both the PQ sub-quantizers (K = 16 codewords per
//! sub-space, paper §2) and the IVF coarse quantizer (nlist = √N centroids,
//! paper §5.2). Matches the faiss `Clustering` defaults where they matter:
//! empty clusters are re-seeded by splitting the largest cluster, training
//! data is subsampled to a per-centroid budget, and iteration count is
//! fixed rather than tolerance-driven.

use crate::util::rng::Rng;
use crate::util::threads::{default_threads, parallel_chunks};
use crate::{Error, Result};

/// Parameters for one k-means run.
#[derive(Clone, Debug)]
pub struct KMeansParams {
    pub k: usize,
    /// Lloyd iterations (faiss default: 25 for PQ training).
    pub iters: usize,
    /// Max training points per centroid (subsample above this).
    pub max_points_per_centroid: usize,
    pub seed: u64,
    /// Emit per-iteration objective to stderr.
    pub verbose: bool,
}

impl KMeansParams {
    pub fn new(k: usize) -> Self {
        Self { k, iters: 25, max_points_per_centroid: 256, seed: 1234, verbose: false }
    }
}

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub k: usize,
    pub dim: usize,
    /// Row-major `k × dim` centroid matrix.
    pub centroids: Vec<f32>,
    /// Final objective (mean squared distance to assigned centroid).
    pub objective: f32,
}

impl KMeans {
    /// Train on `n × dim` row-major data.
    pub fn train(data: &[f32], dim: usize, params: &KMeansParams) -> Result<KMeans> {
        if dim == 0 || data.len() % dim != 0 {
            return Err(Error::InvalidParameter(format!(
                "data length {} not divisible by dim {dim}",
                data.len()
            )));
        }
        let n = data.len() / dim;
        if n < params.k {
            return Err(Error::InvalidParameter(format!(
                "need at least k={} training points, got {n}",
                params.k
            )));
        }
        let mut rng = Rng::new(params.seed);

        // Subsample to the per-centroid budget (faiss behaviour).
        let budget = params.k * params.max_points_per_centroid;
        let (train, n_train): (Vec<f32>, usize) = if n > budget {
            let idx = rng.sample_indices(n, budget);
            let mut sub = Vec::with_capacity(budget * dim);
            for &i in &idx {
                sub.extend_from_slice(&data[i * dim..(i + 1) * dim]);
            }
            (sub, budget)
        } else {
            (data.to_vec(), n)
        };

        let mut centroids = kmeanspp_init(&train, n_train, dim, params.k, &mut rng);
        let mut assign = vec![0u32; n_train];

        for it in 0..params.iters {
            let objective = assign_all(&train, n_train, dim, &centroids, params.k, &mut assign);
            update_centroids(&train, n_train, dim, params.k, &assign, &mut centroids, &mut rng);
            if params.verbose {
                eprintln!("kmeans iter {it}: objective {objective:.4}");
            }
        }
        // Final assignment for the reported objective.
        let objective = assign_all(&train, n_train, dim, &centroids, params.k, &mut assign);

        Ok(KMeans { k: params.k, dim, centroids, objective })
    }

    /// Index of the nearest centroid to `x`.
    pub fn assign_one(&self, x: &[f32]) -> usize {
        nearest_centroid(x, &self.centroids, self.k, self.dim).0
    }

    /// Assign a batch (`n × dim`), parallel over rows.
    pub fn assign_batch(&self, xs: &[f32]) -> Vec<u32> {
        let n = xs.len() / self.dim;
        let mut out = vec![0u32; n];
        let dim = self.dim;
        let k = self.k;
        let centroids = &self.centroids;
        let out_ptr = OutPtr(out.as_mut_ptr());
        parallel_chunks(n, default_threads(), |s, e| {
            let p = out_ptr;
            for i in s..e {
                let (c, _) = nearest_centroid(&xs[i * dim..(i + 1) * dim], centroids, k, dim);
                unsafe {
                    *p.0.add(i) = c as u32;
                }
            }
        });
        out
    }

    pub fn centroid(&self, i: usize) -> &[f32] {
        &self.centroids[i * self.dim..(i + 1) * self.dim]
    }
}

#[derive(Clone, Copy)]
struct OutPtr(*mut u32);
unsafe impl Send for OutPtr {}
unsafe impl Sync for OutPtr {}

/// Nearest centroid by squared L2: returns `(index, distance)`.
#[inline]
pub fn nearest_centroid(x: &[f32], centroids: &[f32], k: usize, dim: usize) -> (usize, f32) {
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for c in 0..k {
        let d = crate::util::l2_sq(x, &centroids[c * dim..(c + 1) * dim]);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    (best, best_d)
}

/// k-means++ seeding: D²-weighted sampling.
fn kmeanspp_init(data: &[f32], n: usize, dim: usize, k: usize, rng: &mut Rng) -> Vec<f32> {
    let mut centroids = Vec::with_capacity(k * dim);
    let first = rng.below(n);
    centroids.extend_from_slice(&data[first * dim..(first + 1) * dim]);

    let mut d2 = vec![0.0f32; n];
    for i in 0..n {
        d2[i] = crate::util::l2_sq(&data[i * dim..(i + 1) * dim], &centroids[..dim]);
    }

    for c in 1..k {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.next_f64() * total;
            let mut chosen = n - 1;
            for (i, &x) in d2.iter().enumerate() {
                target -= x as f64;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        };
        let new_c = &data[pick * dim..(pick + 1) * dim];
        centroids.extend_from_slice(new_c);
        // relax distances
        for i in 0..n {
            let d = crate::util::l2_sq(&data[i * dim..(i + 1) * dim], new_c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
        let _ = c;
    }
    centroids
}

/// Assign every point; returns the mean objective.
fn assign_all(
    data: &[f32],
    n: usize,
    dim: usize,
    centroids: &[f32],
    k: usize,
    assign: &mut [u32],
) -> f32 {
    use std::sync::atomic::{AtomicU64, Ordering};
    let total_bits = AtomicU64::new(0);
    let assign_ptr = OutPtr(assign.as_mut_ptr());
    parallel_chunks(n, default_threads(), |s, e| {
        let p = assign_ptr;
        let mut local = 0.0f64;
        for i in s..e {
            let (c, d) = nearest_centroid(&data[i * dim..(i + 1) * dim], centroids, k, dim);
            unsafe {
                *p.0.add(i) = c as u32;
            }
            local += d as f64;
        }
        // accumulate f64 via bit-cas loop
        let mut cur = total_bits.load(Ordering::Relaxed);
        loop {
            let new = f64::from_bits(cur) + local;
            match total_bits.compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::SeqCst,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    });
    (f64::from_bits(total_bits.load(Ordering::SeqCst)) / n as f64) as f32
}

/// Recompute centroids as assignment means; split big clusters into empties.
fn update_centroids(
    data: &[f32],
    n: usize,
    dim: usize,
    k: usize,
    assign: &[u32],
    centroids: &mut Vec<f32>,
    rng: &mut Rng,
) {
    let mut counts = vec![0usize; k];
    let mut sums = vec![0.0f64; k * dim];
    for i in 0..n {
        let c = assign[i] as usize;
        counts[c] += 1;
        let row = &data[i * dim..(i + 1) * dim];
        for (j, &v) in row.iter().enumerate() {
            sums[c * dim + j] += v as f64;
        }
    }
    for c in 0..k {
        if counts[c] > 0 {
            for j in 0..dim {
                centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
            }
        }
    }
    // Empty-cluster handling (faiss split_clusters): clone the largest
    // cluster's centroid with a tiny symmetric perturbation.
    for c in 0..k {
        if counts[c] == 0 {
            let big = (0..k).max_by_key(|&i| counts[i]).unwrap();
            let eps = 1.0 / 1024.0;
            for j in 0..dim {
                let sign = if rng.next_f32() < 0.5 { 1.0 } else { -1.0 };
                let v = centroids[big * dim + j];
                centroids[c * dim + j] = v * (1.0 + sign * eps);
                centroids[big * dim + j] = v * (1.0 - sign * eps);
            }
            // steal half the count so repeated empties pick other clusters
            counts[c] = counts[big] / 2;
            let stolen = counts[c];
            counts[big] -= stolen;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs in 8-D.
    fn blobs(n_per: usize, seed: u64) -> (Vec<f32>, usize) {
        let dim = 8;
        let mut rng = Rng::new(seed);
        let centers = [10.0f32, -10.0, 30.0];
        let mut data = Vec::with_capacity(3 * n_per * dim);
        for &c in &centers {
            for _ in 0..n_per {
                for _ in 0..dim {
                    data.push(c + rng.next_gaussian() * 0.5);
                }
            }
        }
        (data, dim)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (data, dim) = blobs(100, 5);
        let km = KMeans::train(&data, dim, &KMeansParams::new(3)).unwrap();
        // each centroid must be near one of the true centers
        let mut found = [false; 3];
        let centers = [10.0f32, -10.0, 30.0];
        for c in 0..3 {
            let mean: f32 = km.centroid(c).iter().sum::<f32>() / dim as f32;
            for (t, &tc) in centers.iter().enumerate() {
                if (mean - tc).abs() < 1.0 {
                    found[t] = true;
                }
            }
        }
        assert!(found.iter().all(|&f| f), "centroids {:?}", &km.centroids[..8]);
        assert!(km.objective < 5.0, "objective {}", km.objective);
    }

    #[test]
    fn assignment_consistent() {
        let (data, dim) = blobs(50, 6);
        let km = KMeans::train(&data, dim, &KMeansParams::new(3)).unwrap();
        let batch = km.assign_batch(&data);
        for i in 0..batch.len() {
            assert_eq!(batch[i] as usize, km.assign_one(&data[i * dim..(i + 1) * dim]));
        }
        // points in the same blob share an assignment
        for blob in 0..3 {
            let a0 = batch[blob * 50];
            for i in 0..50 {
                assert_eq!(batch[blob * 50 + i], a0, "blob {blob} point {i}");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (data, dim) = blobs(40, 7);
        let p = KMeansParams::new(4);
        let a = KMeans::train(&data, dim, &p).unwrap();
        let b = KMeans::train(&data, dim, &p).unwrap();
        assert_eq!(a.centroids, b.centroids);
    }

    #[test]
    fn rejects_bad_input() {
        assert!(KMeans::train(&[1.0, 2.0, 3.0], 2, &KMeansParams::new(1)).is_err());
        assert!(KMeans::train(&[1.0, 2.0], 2, &KMeansParams::new(5)).is_err());
    }

    #[test]
    fn handles_k_equals_n() {
        let (data, dim) = blobs(2, 8); // 6 points
        let km = KMeans::train(&data, dim, &KMeansParams::new(6)).unwrap();
        assert_eq!(km.centroids.len(), 6 * dim);
        // objective should be ~0 (every point its own centroid after splits)
        assert!(km.objective < 2.0, "objective {}", km.objective);
    }

    #[test]
    fn subsampling_path() {
        let (data, dim) = blobs(400, 9); // 1200 points
        let mut p = KMeansParams::new(3);
        p.max_points_per_centroid = 50; // force subsample: budget 150 < 1200
        let km = KMeans::train(&data, dim, &p).unwrap();
        assert!(km.objective < 5.0);
    }

    #[test]
    fn objective_decreases_with_more_k() {
        let (data, dim) = blobs(60, 10);
        let o2 = KMeans::train(&data, dim, &KMeansParams::new(2)).unwrap().objective;
        let o6 = KMeans::train(&data, dim, &KMeansParams::new(6)).unwrap().objective;
        assert!(o6 < o2, "k=6 {o6} !< k=2 {o2}");
    }
}
