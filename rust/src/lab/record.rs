//! The recorded trajectory: trials accumulate into a versioned
//! `BENCH_<host>.json` file keyed by a host fingerprint and git revision.
//!
//! Perf numbers are only meaningful on the hardware that produced them,
//! so the trajectory file is *per host class*: the fingerprint (arch, cpu
//! model, core count, best SIMD backend) names the file and gates which
//! baselines [`super::gate`] may compare against. Runs are append-only —
//! the file is the repo's perf history across PRs, and rewriting it would
//! erase exactly the signal the gate needs.

use crate::simd::best_backend;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Trajectory file format version (bump on breaking schema change).
pub const TRAJECTORY_VERSION: usize = 1;

/// What kind of host produced a set of numbers. Two hosts with equal
/// fingerprints are close enough to compare throughput within the gate's
/// noise bounds; anything else is apples to oranges.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HostFingerprint {
    /// `std::env::consts::ARCH` — `x86_64`, `aarch64`, …
    pub arch: String,
    /// `/proc/cpuinfo` model name (or `unknown` off Linux).
    pub cpu_model: String,
    pub cores: usize,
    /// `best_backend().name()` — the kernel the host would pick.
    pub best_backend: String,
}

impl HostFingerprint {
    pub fn detect() -> Self {
        let cpu_model = std::fs::read_to_string("/proc/cpuinfo")
            .ok()
            .and_then(|text| {
                text.lines()
                    .find(|l| l.starts_with("model name") || l.starts_with("Processor"))
                    .and_then(|l| l.split(':').nth(1))
                    .map(|s| s.trim().to_string())
            })
            .unwrap_or_else(|| "unknown".to_string());
        let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
        Self {
            arch: std::env::consts::ARCH.to_string(),
            cpu_model,
            cores,
            best_backend: best_backend().name().to_string(),
        }
    }

    /// Filesystem-safe short name: `x86_64-8c-ssse3`. Deliberately omits
    /// the cpu model (too volatile across cloud instance types to key a
    /// committed filename on); the full model still lives *inside* the
    /// file for human judgment.
    pub fn slug(&self) -> String {
        format!("{}-{}c-{}", self.arch, self.cores, self.best_backend)
    }

    /// Same host class: everything but the free-text cpu model matches.
    pub fn compatible(&self, other: &HostFingerprint) -> bool {
        self.arch == other.arch
            && self.cores == other.cores
            && self.best_backend == other.best_backend
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("arch", Json::Str(self.arch.clone()))
            .set("cpu_model", Json::Str(self.cpu_model.clone()))
            .set("cores", Json::Num(self.cores as f64))
            .set("best_backend", Json::Str(self.best_backend.clone()));
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| Error::Config(format!("host fingerprint missing {k:?}")))
        };
        Ok(Self {
            arch: s("arch")?,
            cpu_model: s("cpu_model")?,
            cores: j
                .get("cores")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config("host fingerprint missing cores".into()))?,
            best_backend: s("best_backend")?,
        })
    }
}

/// The current git revision (short hash), read straight from `.git` so
/// the lab needs no `git` binary: `HEAD` → deref one level of `ref:`.
pub fn git_revision(repo_root: &Path) -> String {
    let head = match std::fs::read_to_string(repo_root.join(".git/HEAD")) {
        Ok(h) => h,
        Err(_) => return "unknown".to_string(),
    };
    let head = head.trim();
    let full = if let Some(r) = head.strip_prefix("ref: ") {
        match std::fs::read_to_string(repo_root.join(".git").join(r)) {
            Ok(h) => h.trim().to_string(),
            // packed refs: scan .git/packed-refs for the ref name
            Err(_) => std::fs::read_to_string(repo_root.join(".git/packed-refs"))
                .ok()
                .and_then(|text| {
                    text.lines()
                        .find(|l| l.ends_with(r))
                        .and_then(|l| l.split_whitespace().next())
                        .map(str::to_string)
                })
                .unwrap_or_else(|| "unknown".to_string()),
        }
    } else {
        head.to_string()
    };
    full.chars().take(12).collect()
}

/// One recorded `lab run`: the trials it produced, stamped with revision
/// and wall-clock time.
#[derive(Clone, Debug)]
pub struct RunRecord {
    pub git_rev: String,
    pub spec_name: String,
    pub unix_time: u64,
    /// Trial objects in the flat record schema
    /// ([`super::runner::TrialOutcome::to_json`]).
    pub trials: Vec<Json>,
}

impl RunRecord {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("git_rev", Json::Str(self.git_rev.clone()))
            .set("spec_name", Json::Str(self.spec_name.clone()))
            .set("unix_time", Json::Num(self.unix_time as f64))
            .set("trials", Json::Arr(self.trials.clone()));
        o
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            git_rev: j
                .get("git_rev")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            spec_name: j
                .get("spec_name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Config("run record missing spec_name".into()))?
                .to_string(),
            unix_time: j.get("unix_time").and_then(Json::as_usize).unwrap_or(0) as u64,
            trials: j
                .get("trials")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Config("run record missing trials".into()))?
                .to_vec(),
        })
    }
}

/// The per-host perf history: an append-only list of [`RunRecord`]s.
#[derive(Clone, Debug)]
pub struct Trajectory {
    pub version: usize,
    pub host: HostFingerprint,
    pub runs: Vec<RunRecord>,
}

impl Trajectory {
    /// A fresh, empty trajectory for this host.
    pub fn new(host: HostFingerprint) -> Self {
        Self { version: TRAJECTORY_VERSION, host, runs: Vec::new() }
    }

    /// The canonical file path for a host under `dir`:
    /// `dir/BENCH_<slug>.json`.
    pub fn path_for(dir: &Path, host: &HostFingerprint) -> PathBuf {
        dir.join(format!("BENCH_{}.json", host.slug()))
    }

    /// Load from `path`, or start fresh for `host` if the file does not
    /// exist. A present-but-unparsable file is an error — never silently
    /// overwrite history.
    pub fn load_or_new(path: &Path, host: HostFingerprint) -> Result<Self> {
        if !path.exists() {
            return Ok(Self::new(host));
        }
        let text = std::fs::read_to_string(path)?;
        let t = Self::from_json_text(&text)?;
        if !t.host.compatible(&host) {
            return Err(Error::Config(format!(
                "trajectory {} was recorded on {} but this host is {}",
                path.display(),
                t.host.slug(),
                host.slug()
            )));
        }
        Ok(t)
    }

    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = Json::parse(text)
            .map_err(|e| Error::Config(format!("bad trajectory json: {e}")))?;
        let version = j
            .get("version")
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Config("trajectory missing version".into()))?;
        if version != TRAJECTORY_VERSION {
            return Err(Error::Config(format!(
                "trajectory version {version} unsupported (expected {TRAJECTORY_VERSION})"
            )));
        }
        let host = HostFingerprint::from_json(
            j.get("host").ok_or_else(|| Error::Config("trajectory missing host".into()))?,
        )?;
        let runs = j
            .get("runs")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("trajectory missing runs".into()))?
            .iter()
            .map(RunRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { version, host, runs })
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("version", Json::Num(self.version as f64))
            .set("host", self.host.to_json())
            .set("runs", Json::Arr(self.runs.iter().map(RunRecord::to_json).collect()));
        o
    }

    /// Append a run and persist: write to a sibling temp file, then rename
    /// over the target so a crash never truncates the history.
    pub fn append_and_save(&mut self, path: &Path, run: RunRecord) -> Result<()> {
        self.runs.push(run);
        self.save(path)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = path.with_extension("json.tmp");
        std::fs::write(&tmp, self.to_json().to_pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// The most recent run for `spec_name`, the gate's baseline.
    pub fn last_run_for_spec(&self, spec_name: &str) -> Option<&RunRecord> {
        self.runs.iter().rev().find(|r| r.spec_name == spec_name)
    }
}

/// Validate one trial object against the record schema (the check CI runs
/// over every emitted trial). Returns the list of violations, empty when
/// the object conforms.
pub fn validate_trial_json(j: &Json) -> Vec<String> {
    let mut errs = Vec::new();
    for key in ["id", "case", "spec_name", "dataset", "factory", "backend", "kind", "status"] {
        if j.get(key).and_then(Json::as_str).is_none() {
            errs.push(format!("missing or non-string field {key:?}"));
        }
    }
    for key in [
        "n", "nq", "k", "width_bits", "threads", "filter_pct", "nprobe", "repeat",
        "dataset_seed", "trial_seed",
    ] {
        if j.get(key).and_then(Json::as_f64).is_none() {
            errs.push(format!("missing or non-numeric field {key:?}"));
        }
    }
    match j.get("status").and_then(Json::as_str) {
        Some("ok") => {
            for key in [
                "build_s", "qps", "p50_ms", "p95_ms", "p99_ms", "recall_at_1",
                "recall_at_k", "codes_scanned",
            ] {
                match j.get(key).and_then(Json::as_f64) {
                    Some(v) if v >= 0.0 => {}
                    Some(_) => errs.push(format!("negative field {key:?}")),
                    None => errs.push(format!("ok trial missing numeric field {key:?}")),
                }
            }
            for key in ["recall_at_1", "recall_at_k"] {
                if let Some(v) = j.get(key).and_then(Json::as_f64) {
                    if v > 1.0 {
                        errs.push(format!("{key:?} above 1.0"));
                    }
                }
            }
            if !matches!(j.get("phase_us"), Some(Json::Obj(_))) {
                errs.push("ok trial missing phase_us object".into());
            }
        }
        Some("skipped") | Some("failed") => {
            if j.get("error").and_then(Json::as_str).is_none() {
                errs.push("non-ok trial missing error string".into());
            }
        }
        Some(other) => errs.push(format!("unknown status {other:?}")),
        None => {} // already reported above
    }
    errs
}

/// Convert a [`Table`] (the `bench-*` CLI output shape) into the record
/// format: one object per row, keyed by the table headers — the `--json`
/// bridge that lets the existing bench commands emit through the same
/// pipeline the lab uses.
pub fn table_to_json(table: &Table) -> Json {
    let rows: Vec<Json> = table
        .rows
        .iter()
        .map(|row| {
            let mut o = Json::obj();
            for (h, cell) in table.headers.iter().zip(row) {
                // numeric cells stay numbers so downstream tooling can plot
                match cell.parse::<f64>() {
                    Ok(x) if x.is_finite() => o.set(h, Json::Num(x)),
                    _ => o.set(h, Json::Str(cell.clone())),
                };
            }
            o
        })
        .collect();
    let mut o = Json::obj();
    o.set("title", Json::Str(table.title.clone()))
        .set("headers", Json::Arr(table.headers.iter().map(|h| Json::Str(h.clone())).collect()))
        .set("rows", Json::Arr(rows));
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_host() -> HostFingerprint {
        HostFingerprint {
            arch: "x86_64".into(),
            cpu_model: "Test CPU".into(),
            cores: 8,
            best_backend: "ssse3".into(),
        }
    }

    fn ok_trial(id: &str, qps: f64) -> Json {
        let mut o = Json::obj();
        for (k, v) in [("id", id), ("case", "c"), ("spec_name", "s"), ("dataset", "gaussian"),
                       ("factory", "Flat"), ("backend", "portable"), ("kind", "topk"),
                       ("status", "ok")] {
            o.set(k, Json::Str(v.into()));
        }
        for k in ["n", "nq", "k", "width_bits", "threads", "filter_pct", "nprobe",
                  "repeat", "dataset_seed", "trial_seed", "build_s", "p50_ms",
                  "p95_ms", "p99_ms", "codes_scanned"] {
            o.set(k, Json::Num(1.0));
        }
        o.set("qps", Json::Num(qps))
            .set("recall_at_1", Json::Num(0.9))
            .set("recall_at_k", Json::Num(0.95))
            .set("phase_us", Json::obj());
        o
    }

    /// Append + save + reload must round-trip exactly (idempotent history).
    #[test]
    fn lab_trajectory_append_roundtrip() {
        let dir = std::env::temp_dir().join(format!("armpq_lab_rec_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let host = test_host();
        let path = Trajectory::path_for(&dir, &host);
        assert!(path.to_str().unwrap().ends_with("BENCH_x86_64-8c-ssse3.json"));

        let mut t = Trajectory::load_or_new(&path, host.clone()).unwrap();
        assert!(t.runs.is_empty());
        t.append_and_save(&path, RunRecord {
            git_rev: "abc123".into(),
            spec_name: "smoke".into(),
            unix_time: 1000,
            trials: vec![ok_trial("t1", 50.0)],
        })
        .unwrap();
        t.append_and_save(&path, RunRecord {
            git_rev: "def456".into(),
            spec_name: "smoke".into(),
            unix_time: 2000,
            trials: vec![ok_trial("t1", 60.0)],
        })
        .unwrap();

        let back = Trajectory::load_or_new(&path, host.clone()).unwrap();
        assert_eq!(back.runs.len(), 2);
        assert_eq!(back.runs[1].git_rev, "def456");
        assert_eq!(back.last_run_for_spec("smoke").unwrap().unix_time, 2000);
        assert!(back.last_run_for_spec("other").is_none());
        // byte-level idempotency: re-saving an unmodified load changes nothing
        let before = std::fs::read_to_string(&path).unwrap();
        back.save(&path).unwrap();
        assert_eq!(before, std::fs::read_to_string(&path).unwrap());

        // wrong host class must refuse to adopt the file
        let mut other = host.clone();
        other.best_backend = "neon".into();
        assert!(Trajectory::load_or_new(&path, other).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lab_trial_schema_validation() {
        assert!(validate_trial_json(&ok_trial("t", 10.0)).is_empty());
        let mut bad = ok_trial("t", 10.0);
        bad.set("recall_at_1", Json::Num(1.5));
        assert!(validate_trial_json(&bad).iter().any(|e| e.contains("recall_at_1")));
        let mut skipped = ok_trial("t", 10.0);
        skipped.set("status", Json::Str("skipped".into()));
        assert!(validate_trial_json(&skipped)
            .iter()
            .any(|e| e.contains("missing error")));
        skipped.set("error", Json::Str("backend unavailable".into()));
        assert!(validate_trial_json(&skipped).is_empty());
    }

    #[test]
    fn lab_table_to_json_bridge() {
        let mut t = Table::new("micro", &["width", "backend", "ns_per_code"]);
        t.row(vec!["4".into(), "ssse3".into(), "0.31".into()]);
        let j = table_to_json(&t);
        assert_eq!(j.get("title").unwrap().as_str().unwrap(), "micro");
        let row = &j.get("rows").unwrap().as_arr().unwrap()[0];
        assert_eq!(row.get("width").unwrap().as_usize().unwrap(), 4);
        assert_eq!(row.get("backend").unwrap().as_str().unwrap(), "ssse3");
    }
}
