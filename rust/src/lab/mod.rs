//! The experiment lab: declarative sweeps, a trial runner, a recorded
//! perf/recall trajectory, and a CI regression gate.
//!
//! The paper's claim is a *measured* one ("a 10x improvement over the
//! naive PQ with the same accuracy"), so the repo keeps its numbers the
//! same way it keeps its code — declared, versioned and gated:
//!
//! * [`spec`] — a sweep spec (inline JSON / JSONL) over the axes the
//!   Quicker-ADC line of work shows must be first-class — dataset × n ×
//!   factory × code width × backend × threads × query kind × filter
//!   selectivity × nprobe — expanding **deterministically** into a trial
//!   list (same spec text → same trials, byte for byte, on any host).
//! * [`runner`] — executes each trial end-to-end through the existing
//!   factory / [`crate::exec::QueryExecutor`] paths and harvests QPS,
//!   recall@k vs exact-flat ground truth (the [`crate::eval`]
//!   definitions), p50/p95/p99 latency and the per-phase
//!   [`crate::obs::TraceSpan`] split — one flat JSON object per trial.
//! * [`record`] — appends runs into a versioned `BENCH_<host>.json`
//!   trajectory keyed by host fingerprint and git revision; the perf
//!   history that survives across PRs.
//! * [`gate`] — compares a fresh run against the last recorded baseline
//!   for the same host class and fails on a >10% throughput drop or a
//!   recall drop beyond the noise bounds estimated from repeats.
//!
//! Surfaced as `armpq lab run|compare|report`; the committed smoke spec
//! (`experiments/lab_smoke.json`) runs on synthetic data in under a
//! minute and is what CI executes on every push.

pub mod gate;
pub mod record;
pub mod runner;
pub mod spec;

pub use gate::{compare, enforce, CaseStatus, GateConfig, GateReport};
pub use record::{
    git_revision, table_to_json, validate_trial_json, HostFingerprint, RunRecord,
    Trajectory, TRAJECTORY_VERSION,
};
pub use runner::{LabRunner, TrialMetrics, TrialOutcome, TrialStatus};
pub use spec::{SweepSpec, TrialKind, TrialSpec};

use std::sync::atomic::{AtomicU64, Ordering};

/// Last-gate verdict encoding for the `lab_gate_verdict` gauge.
pub const GATE_NONE: u64 = 0;
pub const GATE_PASS: u64 = 1;
pub const GATE_FAIL: u64 = 2;

/// Process-wide lab counters, exported through
/// [`crate::coordinator::metrics::Metrics`] like the storage gauges — so
/// a long sweep is observable from the same `/metrics` scrape as served
/// traffic.
#[derive(Debug)]
pub struct LabCounters {
    trials_total: AtomicU64,
    trials_failed: AtomicU64,
    /// 0 = no gate run yet, 1 = last gate passed, 2 = last gate failed.
    last_gate: AtomicU64,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LabCountersSnapshot {
    pub trials_total: u64,
    pub trials_failed: u64,
    pub last_gate: u64,
}

impl LabCounters {
    pub fn record_trial(&self, failed: bool) {
        self.trials_total.fetch_add(1, Ordering::Relaxed);
        if failed {
            self.trials_failed.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn record_gate(&self, passed: bool) {
        self.last_gate
            .store(if passed { GATE_PASS } else { GATE_FAIL }, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LabCountersSnapshot {
        LabCountersSnapshot {
            trials_total: self.trials_total.load(Ordering::Relaxed),
            trials_failed: self.trials_failed.load(Ordering::Relaxed),
            last_gate: self.last_gate.load(Ordering::Relaxed),
        }
    }
}

/// The process-wide counter registry.
pub fn counters() -> &'static LabCounters {
    static COUNTERS: LabCounters = LabCounters {
        trials_total: AtomicU64::new(0),
        trials_failed: AtomicU64::new(0),
        last_gate: AtomicU64::new(GATE_NONE),
    };
    &COUNTERS
}
