//! Declarative sweep specs: a JSON document describing an experiment
//! grid (dataset × n × factory × width × backend × threads × query kind ×
//! filter selectivity × nprobe × repeats) that expands **deterministically**
//! into a flat trial list.
//!
//! The same spec text always produces the same trials in the same order —
//! the expansion is a pure function with a fixed nesting order (factory,
//! width, backend, threads, kind, filter, nprobe, repeat), so a recorded
//! trajectory can be compared case-by-case across runs and git revisions.
//!
//! Spec files are either one JSON object, a JSON array of objects, or
//! JSONL (one object per line, `#`-comments allowed) — `lab.jsonl` style.

use crate::simd::Backend;
use crate::util::json::Json;
use crate::{Error, Result};

/// What a trial asks the index: the two [`crate::index::QueryKind`] modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialKind {
    TopK,
    Range,
}

impl TrialKind {
    pub fn name(self) -> &'static str {
        match self {
            TrialKind::TopK => "topk",
            TrialKind::Range => "range",
        }
    }

    pub fn parse(s: &str) -> Option<TrialKind> {
        match s {
            "topk" | "top_k" => Some(TrialKind::TopK),
            "range" => Some(TrialKind::Range),
            _ => None,
        }
    }
}

/// One parsed sweep spec (one JSON object). Axes are lists; scalars are
/// shared by every trial the spec expands to.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub name: String,
    /// `sift` | `deep` | `gaussian` (see [`crate::datasets::SyntheticDataset::by_name`]).
    pub dataset: String,
    pub n: usize,
    pub nq: usize,
    pub k: usize,
    /// Dataset RNG seed: identical specs produce bit-identical datasets.
    pub seed: u64,
    /// Repeated runs per grid point — the gate estimates noise from these.
    pub repeats: usize,
    /// Factory strings; a `{w}` placeholder expands over `widths`
    /// (`"PQ16x{w}fs"` → `PQ16x2fs`, `PQ16x4fs`, …). Strings without the
    /// placeholder ignore the width axis.
    pub factories: Vec<String>,
    pub widths: Vec<usize>,
    pub backends: Vec<Backend>,
    pub threads: Vec<usize>,
    pub kinds: Vec<TrialKind>,
    /// Filter selectivity as percent of ids admitted; 100 = unfiltered.
    pub filter_pct: Vec<usize>,
    /// Per-request nprobe values; 0 = index default (also what non-IVF
    /// factories use).
    pub nprobes: Vec<usize>,
}

/// One fully-resolved trial: everything the runner needs, nothing implicit.
#[derive(Clone, Debug, PartialEq)]
pub struct TrialSpec {
    /// Unique within a run: `case` plus the repeat ordinal.
    pub id: String,
    /// The grid point shared by all repeats — the gate's comparison key.
    pub case: String,
    pub spec_name: String,
    pub dataset: String,
    pub n: usize,
    pub nq: usize,
    pub k: usize,
    pub factory: String,
    /// Code width substituted into the factory string; 0 when the factory
    /// string fixed its own width (no `{w}` placeholder).
    pub width_bits: usize,
    pub backend: Backend,
    pub threads: usize,
    pub kind: TrialKind,
    pub filter_pct: usize,
    pub nprobe: usize,
    pub repeat: usize,
    /// Seed the dataset generator receives — the spec's `seed`, verbatim.
    pub dataset_seed: u64,
    /// Per-trial seed (FNV over the case key and spec seed), recorded so
    /// any future randomized workload stays reproducible per trial.
    pub trial_seed: u64,
}

/// FNV-1a over bytes, seeded — the repo's standard cheap stable hash.
fn fnv1a(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ seed;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn get_usize(o: &Json, key: &str, default: usize) -> Result<usize> {
    match o.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .map(|x| x as usize)
            .ok_or_else(|| Error::Config(format!("lab spec: {key} expects a number"))),
    }
}

fn get_usize_list(o: &Json, key: &str, default: &[usize]) -> Result<Vec<usize>> {
    match o.get(key) {
        None => Ok(default.to_vec()),
        Some(Json::Arr(v)) => v
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|f| f as usize)
                    .ok_or_else(|| Error::Config(format!("lab spec: {key} expects numbers")))
            })
            .collect(),
        Some(Json::Num(x)) => Ok(vec![*x as usize]),
        Some(_) => Err(Error::Config(format!("lab spec: {key} expects a number array"))),
    }
}

fn get_str_list(o: &Json, key: &str, default: &[&str]) -> Result<Vec<String>> {
    match o.get(key) {
        None => Ok(default.iter().map(|s| s.to_string()).collect()),
        Some(Json::Arr(v)) => v
            .iter()
            .map(|x| {
                x.as_str()
                    .map(String::from)
                    .ok_or_else(|| Error::Config(format!("lab spec: {key} expects strings")))
            })
            .collect(),
        Some(Json::Str(s)) => Ok(vec![s.clone()]),
        Some(_) => Err(Error::Config(format!("lab spec: {key} expects a string array"))),
    }
}

impl SweepSpec {
    /// Parse one spec from a JSON object.
    pub fn from_json(o: &Json) -> Result<SweepSpec> {
        let name = o
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| Error::Config("lab spec: missing \"name\"".into()))?
            .to_string();
        let factories = get_str_list(o, "factories", &[])?;
        if factories.is_empty() {
            return Err(Error::Config(format!(
                "lab spec {name:?}: \"factories\" must list at least one factory string"
            )));
        }
        let backends = get_str_list(o, "backends", &["portable"])?
            .iter()
            .map(|s| {
                Backend::parse(s).ok_or_else(|| {
                    Error::Config(format!(
                        "lab spec {name:?}: unknown backend {s:?} (portable|ssse3|neon)"
                    ))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let kinds = get_str_list(o, "kinds", &["topk"])?
            .iter()
            .map(|s| {
                TrialKind::parse(s).ok_or_else(|| {
                    Error::Config(format!("lab spec {name:?}: unknown kind {s:?} (topk|range)"))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let widths = get_usize_list(o, "widths", &[4])?;
        for &w in &widths {
            if crate::pq::CodeWidth::from_bits(w).is_none() {
                return Err(Error::Config(format!(
                    "lab spec {name:?}: width {w} is not one of 2|4|8"
                )));
            }
        }
        let filter_pct = get_usize_list(o, "filter_pct", &[100])?;
        for &p in &filter_pct {
            if p == 0 || p > 100 {
                return Err(Error::Config(format!(
                    "lab spec {name:?}: filter_pct {p} must be in 1..=100"
                )));
            }
        }
        let spec = SweepSpec {
            dataset: o
                .get("dataset")
                .and_then(|v| v.as_str())
                .unwrap_or("sift")
                .to_string(),
            n: get_usize(o, "n", 20_000)?,
            nq: get_usize(o, "nq", 50)?,
            k: get_usize(o, "k", 10)?,
            seed: get_usize(o, "seed", 20_220_501)? as u64,
            repeats: get_usize(o, "repeats", 2)?.max(1),
            factories,
            widths,
            backends,
            threads: get_usize_list(o, "threads", &[1])?,
            kinds,
            filter_pct,
            nprobes: get_usize_list(o, "nprobes", &[0])?,
            name,
        };
        if crate::datasets::SyntheticDataset::by_name(&spec.dataset, 1, 1, 0).is_none() {
            return Err(Error::Config(format!(
                "lab spec {:?}: unknown dataset {:?} (sift|deep|gaussian)",
                spec.name, spec.dataset
            )));
        }
        Ok(spec)
    }

    /// Parse a spec document: a single JSON object, a JSON array of
    /// objects, or JSONL (one object per line; blank lines and `#`
    /// comments skipped).
    pub fn parse_text(text: &str) -> Result<Vec<SweepSpec>> {
        if let Ok(v) = Json::parse(text) {
            return match &v {
                Json::Obj(_) => Ok(vec![SweepSpec::from_json(&v)?]),
                Json::Arr(items) => items.iter().map(SweepSpec::from_json).collect(),
                _ => Err(Error::Config("lab spec: expected object or array".into())),
            };
        }
        // JSONL fallback
        let mut out = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let v = Json::parse(line).map_err(|e| {
                Error::Config(format!("lab spec line {}: {e}", lineno + 1))
            })?;
            out.push(SweepSpec::from_json(&v)?);
        }
        if out.is_empty() {
            return Err(Error::Config("lab spec: no spec objects found".into()));
        }
        Ok(out)
    }

    /// Expand into the flat trial list. Pure and deterministic: fixed
    /// nesting order (factory, width, backend, threads, kind, filter,
    /// nprobe, repeat), no host inspection — unavailable backends are the
    /// *runner's* concern (it records them as skipped) so the trial list
    /// is identical on every machine.
    pub fn expand(&self) -> Vec<TrialSpec> {
        let mut out = Vec::new();
        for factory_tpl in &self.factories {
            let widths: Vec<usize> = if factory_tpl.contains("{w}") {
                self.widths.clone()
            } else {
                vec![0] // width fixed by the factory string itself
            };
            for &w in &widths {
                let factory = if w == 0 {
                    factory_tpl.clone()
                } else {
                    factory_tpl.replace("{w}", &w.to_string())
                };
                for &backend in &self.backends {
                    for &threads in &self.threads {
                        for &kind in &self.kinds {
                            for &pct in &self.filter_pct {
                                for &nprobe in &self.nprobes {
                                    let case = format!(
                                        "{}/{}{}q{}k{}/{}/{}/t{}/{}/f{}/p{}",
                                        self.name,
                                        self.dataset,
                                        self.n,
                                        self.nq,
                                        self.k,
                                        factory,
                                        backend.name(),
                                        threads,
                                        kind.name(),
                                        pct,
                                        nprobe
                                    );
                                    let trial_seed =
                                        fnv1a(self.seed, case.as_bytes());
                                    for repeat in 0..self.repeats {
                                        out.push(TrialSpec {
                                            id: format!("{case}/r{repeat}"),
                                            case: case.clone(),
                                            spec_name: self.name.clone(),
                                            dataset: self.dataset.clone(),
                                            n: self.n,
                                            nq: self.nq,
                                            k: self.k,
                                            factory: factory.clone(),
                                            width_bits: w,
                                            backend,
                                            threads,
                                            kind,
                                            filter_pct: pct,
                                            nprobe,
                                            repeat,
                                            dataset_seed: self.seed,
                                            trial_seed,
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

impl TrialSpec {
    /// The spec half of a recorded trial object (the runner merges in the
    /// measurement half).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Str(self.id.clone()))
            .set("case", Json::Str(self.case.clone()))
            .set("spec_name", Json::Str(self.spec_name.clone()))
            .set("dataset", Json::Str(self.dataset.clone()))
            .set("n", Json::Num(self.n as f64))
            .set("nq", Json::Num(self.nq as f64))
            .set("k", Json::Num(self.k as f64))
            .set("factory", Json::Str(self.factory.clone()))
            .set("width_bits", Json::Num(self.width_bits as f64))
            .set("backend", Json::Str(self.backend.name().to_string()))
            .set("threads", Json::Num(self.threads as f64))
            .set("kind", Json::Str(self.kind.name().to_string()))
            .set("filter_pct", Json::Num(self.filter_pct as f64))
            .set("nprobe", Json::Num(self.nprobe as f64))
            .set("repeat", Json::Num(self.repeat as f64))
            .set("dataset_seed", Json::Num(self.dataset_seed as f64))
            .set("trial_seed", Json::Num(self.trial_seed as f64));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMOKE: &str = r#"{
        "name": "t",
        "dataset": "gaussian",
        "n": 2000, "nq": 20, "k": 5, "seed": 7, "repeats": 2,
        "factories": ["PQ8x{w}fs"],
        "widths": [2, 4],
        "backends": ["portable", "ssse3"],
        "threads": [1],
        "kinds": ["topk", "range"],
        "filter_pct": [100],
        "nprobes": [0]
    }"#;

    #[test]
    fn lab_expansion_deterministic_and_ordered() {
        let a = SweepSpec::parse_text(SMOKE).unwrap();
        let b = SweepSpec::parse_text(SMOKE).unwrap();
        assert_eq!(a.len(), 1);
        let ta = a[0].expand();
        let tb = b[0].expand();
        assert_eq!(ta, tb, "same spec text must expand to the same trials");
        // 1 factory × 2 widths × 2 backends × 1 thread × 2 kinds × 2 repeats
        assert_eq!(ta.len(), 16);
        // fixed nesting order: width is the outermost varying axis here
        assert_eq!(ta[0].factory, "PQ8x2fs");
        assert_eq!(ta[0].repeat, 0);
        assert_eq!(ta[1].repeat, 1);
        assert_eq!(ta[1].case, ta[0].case, "repeats share the case key");
        assert_ne!(ta[1].id, ta[0].id);
        assert_eq!(ta[15].factory, "PQ8x4fs");
        // every id unique
        let mut ids: Vec<&str> = ta.iter().map(|t| t.id.as_str()).collect();
        ids.sort();
        ids.dedup();
        assert_eq!(ids.len(), 16);
        // trial seed is a function of the case, not the repeat
        assert_eq!(ta[0].trial_seed, ta[1].trial_seed);
        assert_ne!(ta[0].trial_seed, ta[2].trial_seed);
    }

    #[test]
    fn lab_spec_defaults_and_errors() {
        let minimal = r#"{"name": "m", "factories": ["Flat"]}"#;
        let s = &SweepSpec::parse_text(minimal).unwrap()[0];
        assert_eq!(s.dataset, "sift");
        assert_eq!(s.repeats, 2);
        assert_eq!(s.backends, vec![Backend::Portable]);
        // factory without {w}: width axis collapses
        assert_eq!(s.expand().len(), 2);
        assert_eq!(s.expand()[0].width_bits, 0);

        assert!(SweepSpec::parse_text(r#"{"factories": ["Flat"]}"#).is_err());
        assert!(SweepSpec::parse_text(r#"{"name": "x", "factories": []}"#).is_err());
        assert!(SweepSpec::parse_text(
            r#"{"name": "x", "factories": ["Flat"], "backends": ["avx512"]}"#
        )
        .is_err());
        assert!(SweepSpec::parse_text(
            r#"{"name": "x", "factories": ["Flat"], "widths": [3]}"#
        )
        .is_err());
        assert!(SweepSpec::parse_text(
            r#"{"name": "x", "factories": ["Flat"], "filter_pct": [0]}"#
        )
        .is_err());
        assert!(SweepSpec::parse_text(
            r#"{"name": "x", "factories": ["Flat"], "dataset": "laion"}"#
        )
        .is_err());
    }

    #[test]
    fn lab_spec_jsonl_and_array_forms() {
        let jsonl = "# comment\n{\"name\": \"a\", \"factories\": [\"Flat\"]}\n\n{\"name\": \"b\", \"factories\": [\"Flat\"]}\n";
        let specs = SweepSpec::parse_text(jsonl).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "a");
        assert_eq!(specs[1].name, "b");

        let arr = r#"[{"name": "a", "factories": ["Flat"]}, {"name": "b", "factories": ["Flat"]}]"#;
        assert_eq!(SweepSpec::parse_text(arr).unwrap().len(), 2);
        assert!(SweepSpec::parse_text("").is_err());
    }

    #[test]
    fn lab_trial_spec_json_has_seed_documented() {
        let s = &SweepSpec::parse_text(SMOKE).unwrap()[0];
        let t = &s.expand()[0];
        let j = t.to_json();
        assert_eq!(j.get("dataset_seed").unwrap().as_usize().unwrap(), 7);
        assert!(j.get("trial_seed").is_some());
        assert_eq!(j.get("backend").unwrap().as_str().unwrap(), "portable");
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "topk");
    }
}
