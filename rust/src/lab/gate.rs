//! The regression gate: compare a fresh run against the last recorded
//! baseline for the same host class and fail on configurable regressions.
//!
//! The unit of comparison is the trial *case* (everything but the repeat
//! axis): repeats of a case are aggregated into mean QPS / mean recall
//! plus a recall standard deviation, and the baseline's spread across
//! repeats is what defines "noise" — a recall drop only fails the gate
//! when it exceeds what the baseline's own repeats scatter over. QPS uses
//! a plain relative threshold (default 10%, the acceptance bound), since
//! wall-clock noise is environment- not spec-driven.

use crate::util::json::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Gate thresholds; defaults match the repo's acceptance criteria.
#[derive(Clone, Debug)]
pub struct GateConfig {
    /// Fail when fresh mean QPS < (1 - max_qps_drop) × baseline mean QPS.
    pub max_qps_drop: f64,
    /// Noise floor for recall: drops within `max(noise_mult × baseline
    /// std, min_recall_epsilon)` pass. A single-repeat baseline has zero
    /// measured spread, so the epsilon keeps the gate usable there.
    pub min_recall_epsilon: f64,
    pub noise_mult: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        Self { max_qps_drop: 0.10, min_recall_epsilon: 0.02, noise_mult: 2.0 }
    }
}

/// Per-case verdict status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaseStatus {
    Pass,
    Regression,
    Improved,
    /// In the fresh run but not the baseline (new grid point) — informational.
    New,
    /// In the baseline but not the fresh run (grid point removed) —
    /// informational; spec evolution must not fail old history.
    Missing,
}

impl CaseStatus {
    pub fn name(self) -> &'static str {
        match self {
            CaseStatus::Pass => "pass",
            CaseStatus::Regression => "regression",
            CaseStatus::Improved => "improved",
            CaseStatus::New => "new",
            CaseStatus::Missing => "missing",
        }
    }
}

/// One case's comparison outcome.
#[derive(Clone, Debug)]
pub struct CaseVerdict {
    pub case: String,
    pub status: CaseStatus,
    pub baseline_qps: f64,
    pub fresh_qps: f64,
    /// fresh/baseline; 1.0 when either side is absent.
    pub qps_ratio: f64,
    pub baseline_recall: f64,
    pub fresh_recall: f64,
    pub detail: String,
}

impl CaseVerdict {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(self.case.clone()))
            .set("status", Json::Str(self.status.name().to_string()))
            .set("baseline_qps", Json::Num(self.baseline_qps))
            .set("fresh_qps", Json::Num(self.fresh_qps))
            .set("qps_ratio", Json::Num(self.qps_ratio))
            .set("baseline_recall", Json::Num(self.baseline_recall))
            .set("fresh_recall", Json::Num(self.fresh_recall))
            .set("detail", Json::Str(self.detail.clone()));
        o
    }
}

/// The whole gate outcome: pass iff no case regressed.
#[derive(Clone, Debug)]
pub struct GateReport {
    pub verdicts: Vec<CaseVerdict>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        !self.verdicts.iter().any(|v| v.status == CaseStatus::Regression)
    }

    pub fn regressions(&self) -> usize {
        self.verdicts.iter().filter(|v| v.status == CaseStatus::Regression).count()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("passed", Json::Bool(self.passed()))
            .set("regressions", Json::Num(self.regressions() as f64))
            .set(
                "verdicts",
                Json::Arr(self.verdicts.iter().map(CaseVerdict::to_json).collect()),
            );
        o
    }

    /// Human one-liner per case, regressions first.
    pub fn render(&self) -> String {
        let mut lines = Vec::new();
        let mut sorted: Vec<&CaseVerdict> = self.verdicts.iter().collect();
        sorted.sort_by_key(|v| match v.status {
            CaseStatus::Regression => 0,
            CaseStatus::Improved => 1,
            CaseStatus::Pass => 2,
            CaseStatus::New => 3,
            CaseStatus::Missing => 4,
        });
        for v in sorted {
            lines.push(format!(
                "{:<10} {}  qps {:.1} -> {:.1} ({:+.1}%)  recall {:.4} -> {:.4}  {}",
                v.status.name(),
                v.case,
                v.baseline_qps,
                v.fresh_qps,
                (v.qps_ratio - 1.0) * 100.0,
                v.baseline_recall,
                v.fresh_recall,
                v.detail,
            ));
        }
        lines.join("\n")
    }
}

/// Aggregates of one case over its repeats.
#[derive(Clone, Copy, Debug, Default)]
struct CaseAgg {
    qps_mean: f64,
    recall_mean: f64,
    recall_std: f64,
    repeats: usize,
}

/// Group `ok` trials by case and aggregate over repeats. Skipped/failed
/// trials never enter the comparison (a backend absent on this host must
/// not read as a throughput regression).
fn aggregate(trials: &[Json]) -> BTreeMap<String, CaseAgg> {
    let mut groups: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for t in trials {
        if t.get("status").and_then(Json::as_str) != Some("ok") {
            continue;
        }
        let (Some(case), Some(qps), Some(recall)) = (
            t.get("case").and_then(Json::as_str),
            t.get("qps").and_then(Json::as_f64),
            t.get("recall_at_k").and_then(Json::as_f64),
        ) else {
            continue;
        };
        let e = groups.entry(case.to_string()).or_default();
        e.0.push(qps);
        e.1.push(recall);
    }
    groups
        .into_iter()
        .map(|(case, (qps, recall))| {
            let n = qps.len() as f64;
            let qps_mean = qps.iter().sum::<f64>() / n;
            let recall_mean = recall.iter().sum::<f64>() / n;
            let var = recall.iter().map(|r| (r - recall_mean).powi(2)).sum::<f64>() / n;
            (case, CaseAgg {
                qps_mean,
                recall_mean,
                recall_std: var.sqrt(),
                repeats: qps.len(),
            })
        })
        .collect()
}

/// Compare fresh trials against baseline trials (both in the flat record
/// schema) under `cfg`.
pub fn compare(baseline: &[Json], fresh: &[Json], cfg: &GateConfig) -> GateReport {
    let base = aggregate(baseline);
    let new = aggregate(fresh);
    let mut verdicts = Vec::new();

    for (case, f) in &new {
        let Some(b) = base.get(case) else {
            verdicts.push(CaseVerdict {
                case: case.clone(),
                status: CaseStatus::New,
                baseline_qps: 0.0,
                fresh_qps: f.qps_mean,
                qps_ratio: 1.0,
                baseline_recall: 0.0,
                fresh_recall: f.recall_mean,
                detail: "no baseline for case".into(),
            });
            continue;
        };
        let qps_ratio = if b.qps_mean > 0.0 { f.qps_mean / b.qps_mean } else { 1.0 };
        let recall_delta = f.recall_mean - b.recall_mean;
        let noise = (cfg.noise_mult * b.recall_std).max(cfg.min_recall_epsilon);

        let qps_regressed = qps_ratio < 1.0 - cfg.max_qps_drop;
        let recall_regressed = recall_delta < -noise;
        let (status, detail) = if qps_regressed && recall_regressed {
            (CaseStatus::Regression, format!(
                "qps {:.1}% below threshold and recall {:.4} below noise bound {:.4}",
                (1.0 - qps_ratio) * 100.0, -recall_delta, noise
            ))
        } else if qps_regressed {
            (CaseStatus::Regression, format!(
                "qps dropped {:.1}% (> {:.0}% allowed)",
                (1.0 - qps_ratio) * 100.0,
                cfg.max_qps_drop * 100.0
            ))
        } else if recall_regressed {
            (CaseStatus::Regression, format!(
                "recall dropped {:.4} (> noise bound {:.4} from {} baseline repeats)",
                -recall_delta, noise, b.repeats
            ))
        } else if qps_ratio > 1.0 + cfg.max_qps_drop || recall_delta > noise {
            (CaseStatus::Improved, String::new())
        } else {
            (CaseStatus::Pass, String::new())
        };
        verdicts.push(CaseVerdict {
            case: case.clone(),
            status,
            baseline_qps: b.qps_mean,
            fresh_qps: f.qps_mean,
            qps_ratio,
            baseline_recall: b.recall_mean,
            fresh_recall: f.recall_mean,
            detail,
        });
    }
    for (case, b) in &base {
        if !new.contains_key(case) {
            verdicts.push(CaseVerdict {
                case: case.clone(),
                status: CaseStatus::Missing,
                baseline_qps: b.qps_mean,
                fresh_qps: 0.0,
                qps_ratio: 1.0,
                baseline_recall: b.recall_mean,
                fresh_recall: 0.0,
                detail: "case absent from fresh run".into(),
            });
        }
    }
    GateReport { verdicts }
}

/// Run the gate and turn failure into an `Err` (the CLI's non-zero exit).
/// Also records the verdict in [`super::counters`] for the metrics export.
pub fn enforce(baseline: &[Json], fresh: &[Json], cfg: &GateConfig) -> Result<GateReport> {
    let report = compare(baseline, fresh, cfg);
    super::counters().record_gate(report.passed());
    if report.passed() {
        Ok(report)
    } else {
        let msg = format!(
            "{} case(s) regressed:\n{}",
            report.regressions(),
            report.render()
        );
        Err(Error::Config(msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trial(case: &str, repeat: usize, qps: f64, recall: f64) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(case.into()))
            .set("id", Json::Str(format!("{case}/r{repeat}")))
            .set("status", Json::Str("ok".into()))
            .set("repeat", Json::Num(repeat as f64))
            .set("qps", Json::Num(qps))
            .set("recall_at_k", Json::Num(recall));
        o
    }

    fn skipped(case: &str) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(case.into()))
            .set("status", Json::Str("skipped".into()))
            .set("error", Json::Str("backend unavailable".into()));
        o
    }

    /// >10% QPS drop fails; 5% passes; big gain reports improved.
    #[test]
    fn lab_gate_qps_verdicts() {
        let cfg = GateConfig::default();
        let base = vec![trial("a", 0, 100.0, 0.9), trial("a", 1, 102.0, 0.9)];

        let drop = vec![trial("a", 0, 80.0, 0.9)];
        let r = compare(&base, &drop, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(!r.passed());
        assert!(enforce(&base, &drop, &cfg).is_err());

        let ok = vec![trial("a", 0, 96.0, 0.9)];
        let r = compare(&base, &ok, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Pass);
        assert!(enforce(&base, &ok, &cfg).is_ok());

        let gain = vec![trial("a", 0, 150.0, 0.9)];
        assert_eq!(compare(&base, &gain, &cfg).verdicts[0].status, CaseStatus::Improved);
    }

    /// Recall noise bounds come from the baseline's repeat spread: a drop
    /// inside the spread passes, one beyond it (and beyond the epsilon
    /// floor) regresses.
    #[test]
    fn lab_gate_recall_noise_bounds() {
        let cfg = GateConfig::default();
        // baseline recall scatters ±0.03 → std 0.03, noise bound 0.06
        let base = vec![trial("a", 0, 100.0, 0.90), trial("a", 1, 100.0, 0.96)];
        let within = vec![trial("a", 0, 100.0, 0.88)]; // -0.05 < 0.06 bound
        assert_eq!(compare(&base, &within, &cfg).verdicts[0].status, CaseStatus::Pass);
        let beyond = vec![trial("a", 0, 100.0, 0.80)]; // -0.13 > 0.06 bound
        let r = compare(&base, &beyond, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(r.verdicts[0].detail.contains("recall"));

        // single-repeat baseline: epsilon floor (0.02) is the bound
        let base1 = vec![trial("a", 0, 100.0, 0.90)];
        let small = vec![trial("a", 0, 100.0, 0.89)];
        assert_eq!(compare(&base1, &small, &cfg).verdicts[0].status, CaseStatus::Pass);
        let big = vec![trial("a", 0, 100.0, 0.85)];
        assert_eq!(compare(&base1, &big, &cfg).verdicts[0].status, CaseStatus::Regression);
    }

    /// New/missing cases and skipped trials are informational, never fatal.
    #[test]
    fn lab_gate_new_missing_skipped() {
        let cfg = GateConfig::default();
        let base = vec![trial("a", 0, 100.0, 0.9), skipped("neon_case")];
        let fresh = vec![trial("b", 0, 50.0, 0.8), skipped("neon_case")];
        let r = compare(&base, &fresh, &cfg);
        assert!(r.passed(), "{}", r.render());
        let statuses: Vec<_> = r.verdicts.iter().map(|v| (v.case.clone(), v.status)).collect();
        assert!(statuses.contains(&("b".to_string(), CaseStatus::New)));
        assert!(statuses.contains(&("a".to_string(), CaseStatus::Missing)));
        // the skipped pseudo-case never shows up at all
        assert!(!r.verdicts.iter().any(|v| v.case == "neon_case"));
    }

    /// Repeats aggregate to means before comparison.
    #[test]
    fn lab_gate_aggregates_repeats() {
        let cfg = GateConfig::default();
        let base = vec![trial("a", 0, 90.0, 0.9), trial("a", 1, 110.0, 0.9)]; // mean 100
        let fresh = vec![trial("a", 0, 85.0, 0.9), trial("a", 1, 105.0, 0.9)]; // mean 95
        let r = compare(&base, &fresh, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Pass);
        assert!((r.verdicts[0].qps_ratio - 0.95).abs() < 1e-9);
    }
}
