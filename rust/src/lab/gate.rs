//! The regression gate: compare a fresh run against the last recorded
//! baseline for the same host class and fail on configurable regressions.
//!
//! The unit of comparison is the trial *case* (everything but the repeat
//! axis): repeats of a case are aggregated into mean QPS / mean recall
//! plus a recall standard deviation, and the baseline's spread across
//! repeats is what defines "noise" — a recall drop only fails the gate
//! when it exceeds what the baseline's own repeats scatter over. QPS uses
//! a plain relative threshold (default 10%, the acceptance bound), since
//! wall-clock noise is environment- not spec-driven.
//!
//! Beyond mean throughput the gate also watches the *shape* of a case:
//! tail latency (mean p99 across repeats, relative threshold — a pool or
//! queueing change can leave QPS flat while the p99 collapses under a
//! convoy) and phase shares (each trace phase's fraction of total phase
//! time, absolute drift threshold — a kernel regression that moves time
//! from `lut_build` into `list_scan` shows up here long before it moves
//! the mean). Trials recorded before these fields existed simply lack
//! them, and either side missing data skips that check rather than
//! failing it — spec evolution must not fail old history.

use crate::util::json::Json;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// Gate thresholds; defaults match the repo's acceptance criteria.
#[derive(Clone, Debug)]
pub struct GateConfig {
    /// Fail when fresh mean QPS < (1 - max_qps_drop) × baseline mean QPS.
    pub max_qps_drop: f64,
    /// Noise floor for recall: drops within `max(noise_mult × baseline
    /// std, min_recall_epsilon)` pass. A single-repeat baseline has zero
    /// measured spread, so the epsilon keeps the gate usable there.
    pub min_recall_epsilon: f64,
    pub noise_mult: f64,
    /// Fail when fresh mean p99 > (1 + max_p99_increase) × baseline mean
    /// p99. Looser than the QPS bound: tails are noisier than means.
    pub max_p99_increase: f64,
    /// Fail when any phase's share of total phase time moves by more than
    /// this (absolute, 0..1) between baseline and fresh.
    pub max_phase_share_drift: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        Self {
            max_qps_drop: 0.10,
            min_recall_epsilon: 0.02,
            noise_mult: 2.0,
            max_p99_increase: 0.25,
            max_phase_share_drift: 0.15,
        }
    }
}

/// Per-case verdict status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaseStatus {
    Pass,
    Regression,
    Improved,
    /// In the fresh run but not the baseline (new grid point) — informational.
    New,
    /// In the baseline but not the fresh run (grid point removed) —
    /// informational; spec evolution must not fail old history.
    Missing,
}

impl CaseStatus {
    pub fn name(self) -> &'static str {
        match self {
            CaseStatus::Pass => "pass",
            CaseStatus::Regression => "regression",
            CaseStatus::Improved => "improved",
            CaseStatus::New => "new",
            CaseStatus::Missing => "missing",
        }
    }
}

/// One case's comparison outcome.
#[derive(Clone, Debug)]
pub struct CaseVerdict {
    pub case: String,
    pub status: CaseStatus,
    pub baseline_qps: f64,
    pub fresh_qps: f64,
    /// fresh/baseline; 1.0 when either side is absent.
    pub qps_ratio: f64,
    pub baseline_recall: f64,
    pub fresh_recall: f64,
    /// Mean p99 latency per side; 0.0 when the side recorded no p99.
    pub baseline_p99_ms: f64,
    pub fresh_p99_ms: f64,
    pub detail: String,
}

impl CaseVerdict {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(self.case.clone()))
            .set("status", Json::Str(self.status.name().to_string()))
            .set("baseline_qps", Json::Num(self.baseline_qps))
            .set("fresh_qps", Json::Num(self.fresh_qps))
            .set("qps_ratio", Json::Num(self.qps_ratio))
            .set("baseline_recall", Json::Num(self.baseline_recall))
            .set("fresh_recall", Json::Num(self.fresh_recall))
            .set("baseline_p99_ms", Json::Num(self.baseline_p99_ms))
            .set("fresh_p99_ms", Json::Num(self.fresh_p99_ms))
            .set("detail", Json::Str(self.detail.clone()));
        o
    }
}

/// The whole gate outcome: pass iff no case regressed.
#[derive(Clone, Debug)]
pub struct GateReport {
    pub verdicts: Vec<CaseVerdict>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        !self.verdicts.iter().any(|v| v.status == CaseStatus::Regression)
    }

    pub fn regressions(&self) -> usize {
        self.verdicts.iter().filter(|v| v.status == CaseStatus::Regression).count()
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("passed", Json::Bool(self.passed()))
            .set("regressions", Json::Num(self.regressions() as f64))
            .set(
                "verdicts",
                Json::Arr(self.verdicts.iter().map(CaseVerdict::to_json).collect()),
            );
        o
    }

    /// Human one-liner per case, regressions first.
    pub fn render(&self) -> String {
        let mut lines = Vec::new();
        let mut sorted: Vec<&CaseVerdict> = self.verdicts.iter().collect();
        sorted.sort_by_key(|v| match v.status {
            CaseStatus::Regression => 0,
            CaseStatus::Improved => 1,
            CaseStatus::Pass => 2,
            CaseStatus::New => 3,
            CaseStatus::Missing => 4,
        });
        for v in sorted {
            lines.push(format!(
                "{:<10} {}  qps {:.1} -> {:.1} ({:+.1}%)  recall {:.4} -> {:.4}  {}",
                v.status.name(),
                v.case,
                v.baseline_qps,
                v.fresh_qps,
                (v.qps_ratio - 1.0) * 100.0,
                v.baseline_recall,
                v.fresh_recall,
                v.detail,
            ));
        }
        lines.join("\n")
    }
}

/// Aggregates of one case over its repeats.
#[derive(Clone, Debug, Default)]
struct CaseAgg {
    qps_mean: f64,
    recall_mean: f64,
    recall_std: f64,
    repeats: usize,
    /// Mean p99 over the repeats that recorded one; `None` when none did
    /// (pre-p99 history) — the p99 check skips rather than fails then.
    p99_mean: Option<f64>,
    /// Each phase's mean share of total per-trial phase time, 0..1.
    /// Empty when no repeat carried a non-empty `phase_us` object.
    phase_share: BTreeMap<String, f64>,
}

/// Group `ok` trials by case and aggregate over repeats. Skipped/failed
/// trials never enter the comparison (a backend absent on this host must
/// not read as a throughput regression).
fn aggregate(trials: &[Json]) -> BTreeMap<String, CaseAgg> {
    #[derive(Default)]
    struct Acc {
        qps: Vec<f64>,
        recall: Vec<f64>,
        p99: Vec<f64>,
        /// per-phase sum of shares — trials are weighted equally
        /// regardless of their absolute phase totals
        shares: BTreeMap<String, f64>,
        phase_trials: usize,
    }
    let mut groups: BTreeMap<String, Acc> = BTreeMap::new();
    for t in trials {
        if t.get("status").and_then(Json::as_str) != Some("ok") {
            continue;
        }
        let (Some(case), Some(qps), Some(recall)) = (
            t.get("case").and_then(Json::as_str),
            t.get("qps").and_then(Json::as_f64),
            t.get("recall_at_k").and_then(Json::as_f64),
        ) else {
            continue;
        };
        let e = groups.entry(case.to_string()).or_default();
        e.qps.push(qps);
        e.recall.push(recall);
        if let Some(p99) = t.get("p99_ms").and_then(Json::as_f64) {
            e.p99.push(p99);
        }
        if let Some(Json::Obj(phases)) = t.get("phase_us") {
            let total: f64 = phases.values().filter_map(Json::as_f64).sum();
            if total > 0.0 {
                e.phase_trials += 1;
                for (name, v) in phases {
                    let Some(us) = v.as_f64() else { continue };
                    *e.shares.entry(name.clone()).or_default() += us / total;
                }
            }
        }
    }
    groups
        .into_iter()
        .map(|(case, acc)| {
            let n = acc.qps.len() as f64;
            let qps_mean = acc.qps.iter().sum::<f64>() / n;
            let recall_mean = acc.recall.iter().sum::<f64>() / n;
            let var =
                acc.recall.iter().map(|r| (r - recall_mean).powi(2)).sum::<f64>() / n;
            let p99_mean = if acc.p99.is_empty() {
                None
            } else {
                Some(acc.p99.iter().sum::<f64>() / acc.p99.len() as f64)
            };
            // a phase absent from some repeats averages over ALL
            // phase-bearing repeats (its share there was zero)
            let phase_share = acc
                .shares
                .into_iter()
                .map(|(name, sum)| (name, sum / acc.phase_trials.max(1) as f64))
                .collect();
            (case, CaseAgg {
                qps_mean,
                recall_mean,
                recall_std: var.sqrt(),
                repeats: acc.qps.len(),
                p99_mean,
                phase_share,
            })
        })
        .collect()
}

/// The largest absolute per-phase share move between two aggregated phase
/// maps, with the phase that moved it. Phases absent from one side count
/// as share 0.0 there. `None` when either side has no phase data at all.
fn max_phase_drift(
    base: &BTreeMap<String, f64>,
    fresh: &BTreeMap<String, f64>,
) -> Option<(String, f64)> {
    if base.is_empty() || fresh.is_empty() {
        return None;
    }
    let mut worst: Option<(String, f64)> = None;
    for name in base.keys().chain(fresh.keys()) {
        let b = base.get(name).copied().unwrap_or(0.0);
        let f = fresh.get(name).copied().unwrap_or(0.0);
        let d = (f - b).abs();
        if worst.as_ref().map_or(true, |(_, w)| d > *w) {
            worst = Some((name.clone(), d));
        }
    }
    worst
}

/// Compare fresh trials against baseline trials (both in the flat record
/// schema) under `cfg`.
pub fn compare(baseline: &[Json], fresh: &[Json], cfg: &GateConfig) -> GateReport {
    let base = aggregate(baseline);
    let new = aggregate(fresh);
    let mut verdicts = Vec::new();

    for (case, f) in &new {
        let Some(b) = base.get(case) else {
            verdicts.push(CaseVerdict {
                case: case.clone(),
                status: CaseStatus::New,
                baseline_qps: 0.0,
                fresh_qps: f.qps_mean,
                qps_ratio: 1.0,
                baseline_recall: 0.0,
                fresh_recall: f.recall_mean,
                baseline_p99_ms: 0.0,
                fresh_p99_ms: f.p99_mean.unwrap_or(0.0),
                detail: "no baseline for case".into(),
            });
            continue;
        };
        let qps_ratio = if b.qps_mean > 0.0 { f.qps_mean / b.qps_mean } else { 1.0 };
        let recall_delta = f.recall_mean - b.recall_mean;
        let noise = (cfg.noise_mult * b.recall_std).max(cfg.min_recall_epsilon);

        let qps_regressed = qps_ratio < 1.0 - cfg.max_qps_drop;
        let recall_regressed = recall_delta < -noise;
        // Tail latency: gate only when both sides measured a p99 (and the
        // baseline's is nonzero — a sub-clock-resolution baseline can't
        // support a relative bound).
        let p99_regressed = match (b.p99_mean, f.p99_mean) {
            (Some(bp), Some(fp)) if bp > 0.0 => {
                fp > bp * (1.0 + cfg.max_p99_increase)
            }
            _ => false,
        };
        // Phase shape: gate only when both sides carried phase data.
        let phase_drift = max_phase_drift(&b.phase_share, &f.phase_share)
            .filter(|(_, d)| *d > cfg.max_phase_share_drift);

        let mut problems = Vec::new();
        if qps_regressed && recall_regressed {
            problems.push(format!(
                "qps {:.1}% below threshold and recall {:.4} below noise bound {:.4}",
                (1.0 - qps_ratio) * 100.0, -recall_delta, noise
            ));
        } else if qps_regressed {
            problems.push(format!(
                "qps dropped {:.1}% (> {:.0}% allowed)",
                (1.0 - qps_ratio) * 100.0,
                cfg.max_qps_drop * 100.0
            ));
        } else if recall_regressed {
            problems.push(format!(
                "recall dropped {:.4} (> noise bound {:.4} from {} baseline repeats)",
                -recall_delta, noise, b.repeats
            ));
        }
        if p99_regressed {
            problems.push(format!(
                "p99 rose {:.2}ms -> {:.2}ms (> {:.0}% allowed)",
                b.p99_mean.unwrap_or(0.0),
                f.p99_mean.unwrap_or(0.0),
                cfg.max_p99_increase * 100.0
            ));
        }
        if let Some((phase, d)) = &phase_drift {
            problems.push(format!(
                "phase '{phase}' share drifted {:.0}pp (> {:.0}pp allowed)",
                d * 100.0,
                cfg.max_phase_share_drift * 100.0
            ));
        }
        let (status, detail) = if !problems.is_empty() {
            (CaseStatus::Regression, problems.join("; "))
        } else if qps_ratio > 1.0 + cfg.max_qps_drop || recall_delta > noise {
            (CaseStatus::Improved, String::new())
        } else {
            (CaseStatus::Pass, String::new())
        };
        verdicts.push(CaseVerdict {
            case: case.clone(),
            status,
            baseline_qps: b.qps_mean,
            fresh_qps: f.qps_mean,
            qps_ratio,
            baseline_recall: b.recall_mean,
            fresh_recall: f.recall_mean,
            baseline_p99_ms: b.p99_mean.unwrap_or(0.0),
            fresh_p99_ms: f.p99_mean.unwrap_or(0.0),
            detail,
        });
    }
    for (case, b) in &base {
        if !new.contains_key(case) {
            verdicts.push(CaseVerdict {
                case: case.clone(),
                status: CaseStatus::Missing,
                baseline_qps: b.qps_mean,
                fresh_qps: 0.0,
                qps_ratio: 1.0,
                baseline_recall: b.recall_mean,
                fresh_recall: 0.0,
                baseline_p99_ms: b.p99_mean.unwrap_or(0.0),
                fresh_p99_ms: 0.0,
                detail: "case absent from fresh run".into(),
            });
        }
    }
    GateReport { verdicts }
}

/// Run the gate and turn failure into an `Err` (the CLI's non-zero exit).
/// Also records the verdict in [`super::counters`] for the metrics export.
pub fn enforce(baseline: &[Json], fresh: &[Json], cfg: &GateConfig) -> Result<GateReport> {
    let report = compare(baseline, fresh, cfg);
    super::counters().record_gate(report.passed());
    if report.passed() {
        Ok(report)
    } else {
        let msg = format!(
            "{} case(s) regressed:\n{}",
            report.regressions(),
            report.render()
        );
        Err(Error::Config(msg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trial(case: &str, repeat: usize, qps: f64, recall: f64) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(case.into()))
            .set("id", Json::Str(format!("{case}/r{repeat}")))
            .set("status", Json::Str("ok".into()))
            .set("repeat", Json::Num(repeat as f64))
            .set("qps", Json::Num(qps))
            .set("recall_at_k", Json::Num(recall));
        o
    }

    /// A trial that also carries the tail/shape fields the gate watches.
    fn trial_full(
        case: &str,
        repeat: usize,
        qps: f64,
        recall: f64,
        p99_ms: f64,
        phases: &[(&str, f64)],
    ) -> Json {
        let mut t = trial(case, repeat, qps, recall);
        t.set("p99_ms", Json::Num(p99_ms));
        let mut p = Json::obj();
        for (name, us) in phases {
            p.set(name, Json::Num(*us));
        }
        t.set("phase_us", p);
        t
    }

    fn skipped(case: &str) -> Json {
        let mut o = Json::obj();
        o.set("case", Json::Str(case.into()))
            .set("status", Json::Str("skipped".into()))
            .set("error", Json::Str("backend unavailable".into()));
        o
    }

    /// >10% QPS drop fails; 5% passes; big gain reports improved.
    #[test]
    fn lab_gate_qps_verdicts() {
        let cfg = GateConfig::default();
        let base = vec![trial("a", 0, 100.0, 0.9), trial("a", 1, 102.0, 0.9)];

        let drop = vec![trial("a", 0, 80.0, 0.9)];
        let r = compare(&base, &drop, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(!r.passed());
        assert!(enforce(&base, &drop, &cfg).is_err());

        let ok = vec![trial("a", 0, 96.0, 0.9)];
        let r = compare(&base, &ok, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Pass);
        assert!(enforce(&base, &ok, &cfg).is_ok());

        let gain = vec![trial("a", 0, 150.0, 0.9)];
        assert_eq!(compare(&base, &gain, &cfg).verdicts[0].status, CaseStatus::Improved);
    }

    /// Recall noise bounds come from the baseline's repeat spread: a drop
    /// inside the spread passes, one beyond it (and beyond the epsilon
    /// floor) regresses.
    #[test]
    fn lab_gate_recall_noise_bounds() {
        let cfg = GateConfig::default();
        // baseline recall scatters ±0.03 → std 0.03, noise bound 0.06
        let base = vec![trial("a", 0, 100.0, 0.90), trial("a", 1, 100.0, 0.96)];
        let within = vec![trial("a", 0, 100.0, 0.88)]; // -0.05 < 0.06 bound
        assert_eq!(compare(&base, &within, &cfg).verdicts[0].status, CaseStatus::Pass);
        let beyond = vec![trial("a", 0, 100.0, 0.80)]; // -0.13 > 0.06 bound
        let r = compare(&base, &beyond, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(r.verdicts[0].detail.contains("recall"));

        // single-repeat baseline: epsilon floor (0.02) is the bound
        let base1 = vec![trial("a", 0, 100.0, 0.90)];
        let small = vec![trial("a", 0, 100.0, 0.89)];
        assert_eq!(compare(&base1, &small, &cfg).verdicts[0].status, CaseStatus::Pass);
        let big = vec![trial("a", 0, 100.0, 0.85)];
        assert_eq!(compare(&base1, &big, &cfg).verdicts[0].status, CaseStatus::Regression);
    }

    /// New/missing cases and skipped trials are informational, never fatal.
    #[test]
    fn lab_gate_new_missing_skipped() {
        let cfg = GateConfig::default();
        let base = vec![trial("a", 0, 100.0, 0.9), skipped("neon_case")];
        let fresh = vec![trial("b", 0, 50.0, 0.8), skipped("neon_case")];
        let r = compare(&base, &fresh, &cfg);
        assert!(r.passed(), "{}", r.render());
        let statuses: Vec<_> = r.verdicts.iter().map(|v| (v.case.clone(), v.status)).collect();
        assert!(statuses.contains(&("b".to_string(), CaseStatus::New)));
        assert!(statuses.contains(&("a".to_string(), CaseStatus::Missing)));
        // the skipped pseudo-case never shows up at all
        assert!(!r.verdicts.iter().any(|v| v.case == "neon_case"));
    }

    /// Tail latency gates relatively: a >25% p99 rise fails even when QPS
    /// and recall are flat; history without p99 skips the check.
    #[test]
    fn lab_gate_p99_tail_regression() {
        let cfg = GateConfig::default();
        let ph: &[(&str, f64)] = &[("lut_build", 300.0), ("list_scan", 700.0)];
        let base = vec![
            trial_full("a", 0, 100.0, 0.9, 10.0, ph),
            trial_full("a", 1, 100.0, 0.9, 10.0, ph),
        ];

        let convoy = vec![trial_full("a", 0, 100.0, 0.9, 14.0, ph)]; // +40%
        let r = compare(&base, &convoy, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(r.verdicts[0].detail.contains("p99"), "{}", r.verdicts[0].detail);
        assert!((r.verdicts[0].baseline_p99_ms - 10.0).abs() < 1e-9);
        assert!((r.verdicts[0].fresh_p99_ms - 14.0).abs() < 1e-9);

        let ok = vec![trial_full("a", 0, 100.0, 0.9, 11.0, ph)]; // +10%
        assert_eq!(compare(&base, &ok, &cfg).verdicts[0].status, CaseStatus::Pass);

        // pre-p99 baseline: the check skips, it does not fail
        let old = vec![trial("a", 0, 100.0, 0.9)];
        let r = compare(&old, &convoy, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Pass);
        assert_eq!(r.verdicts[0].baseline_p99_ms, 0.0);
    }

    /// Phase shares gate on absolute drift: time migrating between phases
    /// fails even at equal totals, and a brand-new phase counts as
    /// drifting from share zero. Either side without phase data skips.
    #[test]
    fn lab_gate_phase_share_drift() {
        let cfg = GateConfig::default();
        let base = vec![trial_full(
            "a", 0, 100.0, 0.9, 10.0,
            &[("lut_build", 300.0), ("list_scan", 700.0)],
        )];

        // same total phase time, but 20pp moved lut_build -> list_scan
        let shifted = vec![trial_full(
            "a", 0, 100.0, 0.9, 10.0,
            &[("lut_build", 100.0), ("list_scan", 900.0)],
        )];
        let r = compare(&base, &shifted, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(r.verdicts[0].detail.contains("phase"), "{}", r.verdicts[0].detail);

        // 5pp drift stays under the 15pp default
        let small = vec![trial_full(
            "a", 0, 100.0, 0.9, 10.0,
            &[("lut_build", 250.0), ("list_scan", 750.0)],
        )];
        assert_eq!(compare(&base, &small, &cfg).verdicts[0].status, CaseStatus::Pass);

        // a new phase eating 20% of the budget drifts from zero
        let grew = vec![trial_full(
            "a", 0, 100.0, 0.9, 10.0,
            &[("lut_build", 240.0), ("list_scan", 560.0), ("rerank", 200.0)],
        )];
        let r = compare(&base, &grew, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Regression);
        assert!(r.verdicts[0].detail.contains("rerank"), "{}", r.verdicts[0].detail);

        // fresh side without phase data: check skips
        let bare = vec![trial("a", 0, 100.0, 0.9)];
        assert_eq!(compare(&base, &bare, &cfg).verdicts[0].status, CaseStatus::Pass);
    }

    /// Repeats aggregate to means before comparison.
    #[test]
    fn lab_gate_aggregates_repeats() {
        let cfg = GateConfig::default();
        let base = vec![trial("a", 0, 90.0, 0.9), trial("a", 1, 110.0, 0.9)]; // mean 100
        let fresh = vec![trial("a", 0, 85.0, 0.9), trial("a", 1, 105.0, 0.9)]; // mean 95
        let r = compare(&base, &fresh, &cfg);
        assert_eq!(r.verdicts[0].status, CaseStatus::Pass);
        assert!((r.verdicts[0].qps_ratio - 0.95).abs() < 1e-9);
    }
}
