//! The trial runner: executes one [`TrialSpec`] end-to-end — build (or
//! reuse) the index through the existing factory path, run the workload
//! through a [`QueryExecutor`] sized to the trial's thread count, and
//! harvest QPS, recall vs the exact-flat ground truth (the same
//! [`crate::eval`] definitions the figure runners use), p50/p95/p99
//! latency, and per-phase time from the trace spans — one structured JSON
//! object per trial.
//!
//! Datasets, ground truths and built indexes are cached across the trial
//! list (keyed by their full deterministic inputs), so a sweep over
//! backends × threads × kinds pays for each index build once.

use super::spec::{TrialKind, TrialSpec};
use crate::datasets::{Dataset, SyntheticDataset};
use crate::eval::{ground_truth, recall_at_r};
use crate::exec::QueryExecutor;
use crate::index::{index_factory, Filter, Index, QueryRequest, SearchParams};
use crate::obs::merge_spans;
use crate::util::json::Json;
use crate::util::l2_sq;
use crate::util::timer::{LatencyStats, Timer};
use crate::{Error, Result};
use std::collections::HashMap;

/// Timed full-batch passes per trial; run-to-run noise is estimated from
/// the spec's `repeats` axis (separate trials), not from these.
const BATCH_PASSES: usize = 2;

/// What happened to one trial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialStatus {
    Ok,
    /// The trial's backend is not available on this host (e.g. `neon` on
    /// x86_64) — expansion is host-independent, so this is expected.
    Skipped,
    Failed,
}

impl TrialStatus {
    pub fn name(self) -> &'static str {
        match self {
            TrialStatus::Ok => "ok",
            TrialStatus::Skipped => "skipped",
            TrialStatus::Failed => "failed",
        }
    }
}

/// The measurement half of a recorded trial.
#[derive(Clone, Debug, Default)]
pub struct TrialMetrics {
    pub build_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub recall_at_1: f64,
    pub recall_at_k: f64,
    /// Codes scanned across one full query batch (from `QueryStats`).
    pub codes_scanned: u64,
    /// Range trials: the derived radius and total hits returned.
    pub radius: f64,
    pub hits_total: u64,
    /// Per-phase µs summed over one traced batch, by stable phase name.
    pub phase_us: Vec<(String, u64)>,
}

/// One completed trial: spec + status + measurements (when `Ok`).
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub spec: TrialSpec,
    pub status: TrialStatus,
    pub metrics: Option<TrialMetrics>,
    pub error: Option<String>,
}

impl TrialOutcome {
    /// The recorded trial object: the spec fields plus the measurement
    /// fields, one flat JSON object (the record schema CI validates).
    pub fn to_json(&self) -> Json {
        let mut o = self.spec.to_json();
        o.set("status", Json::Str(self.status.name().to_string()));
        if let Some(e) = &self.error {
            o.set("error", Json::Str(e.clone()));
        }
        if let Some(m) = &self.metrics {
            let mut phases = Json::obj();
            for (name, us) in &m.phase_us {
                phases.set(name, Json::Num(*us as f64));
            }
            o.set("build_s", Json::Num(m.build_s))
                .set("qps", Json::Num(m.qps))
                .set("p50_ms", Json::Num(m.p50_ms))
                .set("p95_ms", Json::Num(m.p95_ms))
                .set("p99_ms", Json::Num(m.p99_ms))
                .set("recall_at_1", Json::Num(m.recall_at_1))
                .set("recall_at_k", Json::Num(m.recall_at_k))
                .set("codes_scanned", Json::Num(m.codes_scanned as f64))
                .set("radius", Json::Num(m.radius))
                .set("hits_total", Json::Num(m.hits_total as f64))
                .set("phase_us", phases);
        }
        o
    }
}

struct GroundTruthEntry {
    /// `nq × k` labels over the (possibly filtered) id space.
    labels: Vec<i64>,
    /// Median exact distance to the k-th NN — the derived range radius.
    kth_dist_median: f64,
}

struct IndexEntry {
    index: Box<dyn Index>,
    build_s: f64,
}

/// Executes trial lists with dataset/ground-truth/index caching.
#[derive(Default)]
pub struct LabRunner {
    datasets: HashMap<(String, usize, usize, u64), Dataset>,
    ground_truths: HashMap<(String, usize, usize, u64, usize, usize), GroundTruthEntry>,
    indexes: HashMap<(String, usize, usize, u64, String), IndexEntry>,
}

impl LabRunner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run every trial in order, invoking `emit` with each outcome as it
    /// completes (the CLI streams one JSON line per trial). Counters in
    /// [`super::counters`] track totals/failures for the metrics export.
    pub fn run_all(
        &mut self,
        trials: &[TrialSpec],
        mut emit: impl FnMut(&TrialOutcome),
    ) -> Vec<TrialOutcome> {
        let mut out = Vec::with_capacity(trials.len());
        for spec in trials {
            let outcome = self.run_trial(spec);
            super::counters().record_trial(outcome.status == TrialStatus::Failed);
            emit(&outcome);
            out.push(outcome);
        }
        out
    }

    /// Run one trial. Infrastructure errors become `Failed` outcomes, not
    /// process errors — a sweep must survive a single bad grid point.
    pub fn run_trial(&mut self, spec: &TrialSpec) -> TrialOutcome {
        if !spec.backend.is_available() {
            return TrialOutcome {
                spec: spec.clone(),
                status: TrialStatus::Skipped,
                metrics: None,
                error: Some(format!(
                    "backend {} unavailable on this host",
                    spec.backend.name()
                )),
            };
        }
        match self.measure(spec) {
            Ok(metrics) => TrialOutcome {
                spec: spec.clone(),
                status: TrialStatus::Ok,
                metrics: Some(metrics),
                error: None,
            },
            Err(e) => TrialOutcome {
                spec: spec.clone(),
                status: TrialStatus::Failed,
                metrics: None,
                error: Some(e.to_string()),
            },
        }
    }

    fn dataset(&mut self, spec: &TrialSpec) -> Result<&Dataset> {
        let key =
            (spec.dataset.clone(), spec.n, spec.nq, spec.dataset_seed);
        if !self.datasets.contains_key(&key) {
            let ds = SyntheticDataset::by_name(
                &spec.dataset,
                spec.n,
                spec.nq,
                spec.dataset_seed,
            )
            .ok_or_else(|| {
                Error::Config(format!("unknown dataset {:?}", spec.dataset))
            })?;
            self.datasets.insert(key.clone(), ds);
        }
        Ok(&self.datasets[&key])
    }

    /// Exact ground truth over the first `filter_pct`% of ids (the lab's
    /// filters are id ranges, so the filtered universe is a prefix).
    fn ground_truth_for(&mut self, spec: &TrialSpec) -> Result<&GroundTruthEntry> {
        let key = (
            spec.dataset.clone(),
            spec.n,
            spec.nq,
            spec.dataset_seed,
            spec.filter_pct,
            spec.k,
        );
        if !self.ground_truths.contains_key(&key) {
            let (dim, base, queries, m) = {
                let ds = self.dataset(spec)?;
                let m = filtered_count(ds.n(), spec.filter_pct);
                if m < spec.k {
                    return Err(Error::Config(format!(
                        "trial {}: filtered universe ({m} ids) smaller than k={}",
                        spec.id, spec.k
                    )));
                }
                (ds.dim, ds.base.clone(), ds.queries.clone(), m)
            };
            let labels = ground_truth(&base[..m * dim], &queries, dim, spec.k);
            let mut kth: Vec<f64> = (0..queries.len() / dim)
                .map(|qi| {
                    let truth = labels[qi * spec.k + spec.k - 1] as usize;
                    l2_sq(
                        &queries[qi * dim..(qi + 1) * dim],
                        &base[truth * dim..(truth + 1) * dim],
                    ) as f64
                })
                .collect();
            kth.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let kth_dist_median = kth[kth.len() / 2];
            self.ground_truths
                .insert(key.clone(), GroundTruthEntry { labels, kth_dist_median });
        }
        Ok(&self.ground_truths[&key])
    }

    fn index(&mut self, spec: &TrialSpec) -> Result<(&dyn Index, f64)> {
        let key = (
            spec.dataset.clone(),
            spec.n,
            spec.nq,
            spec.dataset_seed,
            spec.factory.clone(),
        );
        if !self.indexes.contains_key(&key) {
            let (dim, train, base) = {
                let ds = self.dataset(spec)?;
                (ds.dim, ds.train.clone(), ds.base.clone())
            };
            let t = Timer::start();
            let mut index = index_factory(dim, &spec.factory)?;
            index.train(&train)?;
            index.add(&base)?;
            index.seal()?;
            let build_s = t.elapsed_s();
            self.indexes.insert(key.clone(), IndexEntry { index, build_s });
        }
        let e = &self.indexes[&key];
        Ok((e.index.as_ref(), e.build_s))
    }

    fn measure(&mut self, spec: &TrialSpec) -> Result<TrialMetrics> {
        let radius = match spec.kind {
            TrialKind::Range => self.ground_truth_for(spec)?.kth_dist_median as f32,
            TrialKind::TopK => {
                self.ground_truth_for(spec)?; // ensure cached before borrows below
                0.0
            }
        };
        let (dim, nq) = {
            let ds = self.dataset(spec)?;
            (ds.dim, ds.nq())
        };
        let (_, build_s) = self.index(spec)?;

        let mut params = SearchParams::new();
        params.backend = Some(spec.backend);
        if spec.nprobe > 0 {
            params.nprobe = Some(spec.nprobe);
        }
        let filter = (spec.filter_pct < 100).then(|| {
            let m = filtered_count(spec.n, spec.filter_pct);
            Filter::id_range(0, m as i64)
        });

        let exec = QueryExecutor::new(spec.threads);
        // Borrow-order note: the caches are populated above, so these
        // lookups are reads; the dataset and index borrows can coexist.
        let ds_key = (spec.dataset.clone(), spec.n, spec.nq, spec.dataset_seed);
        let gt_key = (
            spec.dataset.clone(),
            spec.n,
            spec.nq,
            spec.dataset_seed,
            spec.filter_pct,
            spec.k,
        );
        let idx_key = (
            spec.dataset.clone(),
            spec.n,
            spec.nq,
            spec.dataset_seed,
            spec.factory.clone(),
        );
        let ds = &self.datasets[&ds_key];
        let gt = &self.ground_truths[&gt_key];
        let index = self.indexes[&idx_key].index.as_ref();

        // 1. One traced batch pass: recall, phase split, scan counters.
        //    (Tracing is bit-identical to not tracing — obs_ tests pin it —
        //    so the results double as the recall measurement.)
        let traced = build_request(spec, radius, &params, &filter, &ds.queries).with_trace();
        let resp = index.query_exec(&traced, &exec)?;
        let codes_scanned: u64 = resp.stats.iter().map(|s| s.codes_scanned as u64).sum();
        let rows: Vec<&[crate::obs::TraceSpan]> =
            resp.traces.iter().map(|v| v.as_slice()).collect();
        let phase_us: Vec<(String, u64)> = merge_spans(&rows)
            .iter()
            .map(|s| (s.phase.name().to_string(), s.us))
            .collect();
        let hits_total: u64 = resp.hits.iter().map(|h| h.len() as u64).sum();
        let (recall_at_1, recall_at_k) = match spec.kind {
            TrialKind::TopK => {
                let flat = resp.into_search_result(spec.k);
                (
                    recall_at_r(&gt.labels, spec.k, &flat.labels, spec.k, 1),
                    recall_at_r(&gt.labels, spec.k, &flat.labels, spec.k, spec.k),
                )
            }
            TrialKind::Range => {
                // Range recall: fraction of queries whose true NN is among
                // the returned hits (the NN's exact distance is ≤ the
                // derived radius for at least half the queries by
                // construction; queries whose NN lies beyond the radius
                // count as recalled when they return no closer miss).
                let mut hit = 0usize;
                for (qi, hits) in resp.hits.iter().enumerate() {
                    let truth = gt.labels[qi * spec.k];
                    let truth_d = l2_sq(
                        &ds.queries[qi * dim..(qi + 1) * dim],
                        &ds.base[truth as usize * dim..(truth as usize + 1) * dim],
                    );
                    if truth_d > radius || hits.iter().any(|h| h.label == truth) {
                        hit += 1;
                    }
                }
                let r = hit as f64 / nq as f64;
                (r, r)
            }
        };

        // 2. Per-query latency distribution (single stream, untraced).
        let mut lat = LatencyStats::new();
        for qi in 0..nq {
            let q = &ds.queries[qi * dim..(qi + 1) * dim];
            let req = build_request(spec, radius, &params, &filter, q);
            let t = Timer::start();
            let _ = index.query_exec(&req, &exec)?;
            lat.record_ms(t.elapsed_ms());
        }

        // 3. Throughput: best of `BATCH_PASSES` timed full-batch passes.
        let mut best_s = f64::INFINITY;
        for _ in 0..BATCH_PASSES {
            let req = build_request(spec, radius, &params, &filter, &ds.queries);
            let t = Timer::start();
            let _ = index.query_exec(&req, &exec)?;
            best_s = best_s.min(t.elapsed_s());
        }
        let qps = nq as f64 / best_s.max(1e-12);

        Ok(TrialMetrics {
            build_s,
            qps,
            p50_ms: lat.percentile_ms(50.0),
            p95_ms: lat.percentile_ms(95.0),
            p99_ms: lat.percentile_ms(99.0),
            recall_at_1,
            recall_at_k,
            codes_scanned,
            radius: radius as f64,
            hits_total,
            phase_us,
        })
    }
}

fn filtered_count(n: usize, pct: usize) -> usize {
    (n * pct / 100).max(1)
}

/// Assemble the trial's [`QueryRequest`] over `queries` (free function so
/// the borrowed request lifetime tracks `queries`, not the runner).
fn build_request<'q>(
    spec: &TrialSpec,
    radius: f32,
    params: &SearchParams,
    filter: &Option<Filter>,
    queries: &'q [f32],
) -> QueryRequest<'q> {
    let req = match spec.kind {
        TrialKind::TopK => QueryRequest::top_k(queries, spec.k),
        TrialKind::Range => QueryRequest::range(queries, radius),
    };
    let req = req.with_params(params.clone());
    match filter {
        Some(f) => req.with_filter(f.clone()),
        None => req,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lab::spec::SweepSpec;

    fn tiny_spec(kinds: &str, factory: &str) -> Vec<TrialSpec> {
        let text = format!(
            r#"{{"name": "unit", "dataset": "gaussian", "n": 1200, "nq": 16,
                "k": 5, "seed": 42, "repeats": 1, "factories": ["{factory}"],
                "backends": ["portable"], "threads": [1], "kinds": [{kinds}]}}"#
        );
        SweepSpec::parse_text(&text).unwrap()[0].expand()
    }

    /// The lab's recall path must agree with a direct `eval/` computation
    /// on an exact index (Flat): both must report perfect recall, and the
    /// trial object must carry the full record schema.
    #[test]
    fn lab_recall_agrees_with_eval_on_exact_index() {
        let trials = tiny_spec("\"topk\"", "Flat");
        assert_eq!(trials.len(), 1);
        let mut runner = LabRunner::new();
        let out = runner.run_trial(&trials[0]);
        assert_eq!(out.status, TrialStatus::Ok, "{:?}", out.error);
        let m = out.metrics.unwrap();
        // Flat is exact: the lab must measure exactly what eval/ defines.
        let ds = SyntheticDataset::by_name("gaussian", 1200, 16, 42).unwrap();
        let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
        let idx = {
            let mut i = index_factory(ds.dim, "Flat").unwrap();
            i.train(&ds.train).unwrap();
            i.add(&ds.base).unwrap();
            i.seal().unwrap();
            i
        };
        let r = idx.search(&ds.queries, 5, None).unwrap();
        let eval_recall = recall_at_r(&gt, 1, &r.labels, 5, 1);
        assert_eq!(m.recall_at_1, eval_recall);
        assert_eq!(m.recall_at_1, 1.0);
        assert!(m.qps > 0.0 && m.p50_ms > 0.0 && m.p99_ms >= m.p50_ms);
        let j = out.to_json();
        for key in [
            "id", "case", "factory", "backend", "threads", "kind", "status",
            "qps", "recall_at_1", "p50_ms", "p95_ms", "p99_ms", "phase_us",
            "dataset_seed", "trial_seed",
        ] {
            assert!(j.get(key).is_some(), "trial json missing {key}");
        }
    }

    /// Range trials derive a radius from the exact k-th NN distance and
    /// count the true NN among the hits.
    #[test]
    fn lab_range_trial_runs() {
        let trials = tiny_spec("\"range\"", "PQ8x4fs");
        let mut runner = LabRunner::new();
        let out = runner.run_trial(&trials[0]);
        assert_eq!(out.status, TrialStatus::Ok, "{:?}", out.error);
        let m = out.metrics.unwrap();
        assert!(m.radius > 0.0);
        assert!(m.hits_total > 0);
        assert!(m.recall_at_1 > 0.0);
    }

    /// Unavailable backends are recorded as skipped, never failed — and
    /// a bad factory string fails its trial without aborting the sweep.
    #[test]
    fn lab_skip_and_fail_statuses() {
        let unavailable = ["portable", "ssse3", "neon"].iter().find_map(|n| {
            let b = crate::simd::Backend::parse(n).unwrap();
            (!b.is_available()).then_some(*n)
        });
        if let Some(name) = unavailable {
            let text = format!(
                r#"{{"name": "s", "dataset": "gaussian", "n": 600, "nq": 4,
                    "k": 3, "repeats": 1, "factories": ["Flat"],
                    "backends": ["{name}"]}}"#
            );
            let trials = SweepSpec::parse_text(&text).unwrap()[0].expand();
            let out = LabRunner::new().run_trial(&trials[0]);
            assert_eq!(out.status, TrialStatus::Skipped);
        }
        let bad = tiny_spec("\"topk\"", "PQ16x3fs");
        let before = crate::lab::counters().snapshot();
        let outs = LabRunner::new().run_all(&bad, |_| {});
        assert_eq!(outs[0].status, TrialStatus::Failed);
        assert!(outs[0].error.is_some());
        let after = crate::lab::counters().snapshot();
        // >= not ==: other tests in this binary feed the same process-
        // global counters concurrently
        assert!(after.trials_total >= before.trials_total + 1);
        assert!(after.trials_failed >= before.trials_failed + 1);
    }
}
