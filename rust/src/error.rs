//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: `thiserror` is not in the vendored
//! crate set, and the messages below are load-bearing (tests and callers
//! match on their wording).

use std::fmt;

/// Unified error type for all `armpq` operations.
#[derive(Debug)]
pub enum Error {
    /// The index (or quantizer) must be trained before this operation.
    NotTrained,

    /// The index has staged vectors that are not packed for search yet;
    /// call `seal()` after the last `add()` before searching.
    NotSealed,

    /// Dimension of the provided vectors does not match the index.
    DimMismatch { expected: usize, got: usize },

    /// Invalid parameter combination.
    InvalidParameter(String),

    /// Failed to parse an index-factory string.
    Factory(String, String),

    /// Configuration file / key errors.
    Config(String),

    /// Dataset file IO and format errors.
    Dataset(String),

    /// A persisted index file is truncated or structurally invalid
    /// (bad magic, impossible section length, payload shorter than its
    /// header promises). Loaders return this instead of panicking
    /// mid-`read_exact` so a corrupt file can never take a server down.
    CorruptIndex(String),

    /// PJRT runtime errors (artifact loading, compilation, execution).
    Runtime(String),

    /// Coordinator / serving errors.
    Serve(String),

    /// The serving admission queue is full: the request was rejected at
    /// the door instead of queueing unboundedly. Clients should back off
    /// and retry; the server stays responsive for admitted work.
    Overloaded,

    /// Underlying IO error.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::NotTrained => write!(f, "index is not trained (call train() first)"),
            Error::NotSealed => {
                write!(f, "index is not sealed (call seal() after add() before searching)")
            }
            Error::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: expected {expected}, got {got}")
            }
            Error::InvalidParameter(msg) => write!(f, "invalid parameter: {msg}"),
            Error::Factory(spec, msg) => {
                write!(f, "cannot parse factory string {spec:?}: {msg}")
            }
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Dataset(msg) => write!(f, "dataset error: {msg}"),
            Error::CorruptIndex(msg) => write!(f, "corrupt index file: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Serve(msg) => write!(f, "serve error: {msg}"),
            Error::Overloaded => {
                write!(f, "server overloaded: admission queue full, retry with backoff")
            }
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = Error::DimMismatch { expected: 128, got: 96 };
        assert_eq!(e.to_string(), "dimension mismatch: expected 128, got 96");
        assert!(Error::NotTrained.to_string().contains("train"));
        let e = Error::CorruptIndex("payload 12 bytes short".into());
        assert!(e.to_string().contains("corrupt index file"), "{e}");
        // the wire protocol greps for this word to classify rejections
        assert!(Error::Overloaded.to_string().contains("overloaded"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
