//! Crate-wide error type.

use thiserror::Error;

/// Unified error type for all `armpq` operations.
#[derive(Error, Debug)]
pub enum Error {
    /// The index (or quantizer) must be trained before this operation.
    #[error("index is not trained (call train() first)")]
    NotTrained,

    /// Dimension of the provided vectors does not match the index.
    #[error("dimension mismatch: expected {expected}, got {got}")]
    DimMismatch { expected: usize, got: usize },

    /// Invalid parameter combination.
    #[error("invalid parameter: {0}")]
    InvalidParameter(String),

    /// Failed to parse an index-factory string.
    #[error("cannot parse factory string {0:?}: {1}")]
    Factory(String, String),

    /// Configuration file / key errors.
    #[error("config error: {0}")]
    Config(String),

    /// Dataset file IO and format errors.
    #[error("dataset error: {0}")]
    Dataset(String),

    /// PJRT runtime errors (artifact loading, compilation, execution).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator / serving errors.
    #[error("serve error: {0}")]
    Serve(String),

    /// Underlying IO error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = Error::DimMismatch { expected: 128, got: 96 };
        assert_eq!(e.to_string(), "dimension mismatch: expected 128, got 96");
        assert!(Error::NotTrained.to_string().contains("train"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
