//! Evaluation harness: exact ground truth, Recall@R, and the
//! recall-vs-QPS measurements behind the paper's Fig. 2 and Table 1.

use crate::util::threads::{default_threads, parallel_map};
use crate::util::timer::Timer;
use crate::util::topk::TopK;

/// Exact k-NN ground truth by parallel brute force.
/// Returns labels as `nq × k` row-major (distances discarded).
pub fn ground_truth(base: &[f32], queries: &[f32], dim: usize, k: usize) -> Vec<i64> {
    let n = base.len() / dim;
    let nq = queries.len() / dim;
    let rows: Vec<Vec<i64>> = parallel_map(nq, default_threads(), |qi| {
        let q = &queries[qi * dim..(qi + 1) * dim];
        let mut heap = TopK::new(k);
        for i in 0..n {
            let d = crate::util::l2_sq(q, &base[i * dim..(i + 1) * dim]);
            if d < heap.threshold() {
                heap.push(d, i as i64);
            }
        }
        heap.into_sorted().1
    });
    rows.into_iter().flatten().collect()
}

/// Recall@R as the paper uses it: the fraction of queries whose *true
/// nearest neighbor* (`gt[qi][0]`) appears among the first `r` results.
pub fn recall_at_r(gt: &[i64], gt_k: usize, results: &[i64], res_k: usize, r: usize) -> f64 {
    assert!(r <= res_k, "r={r} exceeds result width {res_k}");
    let nq = gt.len() / gt_k;
    assert_eq!(results.len() / res_k, nq, "query count mismatch");
    let mut hits = 0usize;
    for qi in 0..nq {
        let truth = gt[qi * gt_k];
        if results[qi * res_k..qi * res_k + r].contains(&truth) {
            hits += 1;
        }
    }
    hits as f64 / nq as f64
}

/// Intersection-recall (k-recall@k): |result ∩ gt| / k averaged over
/// queries — the stricter metric some PQ papers report.
pub fn intersection_recall(gt: &[i64], gt_k: usize, results: &[i64], res_k: usize, k: usize) -> f64 {
    assert!(k <= gt_k && k <= res_k);
    let nq = gt.len() / gt_k;
    let mut total = 0usize;
    for qi in 0..nq {
        let truth = &gt[qi * gt_k..qi * gt_k + k];
        let got = &results[qi * res_k..qi * res_k + k];
        total += got.iter().filter(|g| truth.contains(g)).count();
    }
    total as f64 / (nq * k) as f64
}

/// One Fig. 2-style measurement: run `search` over all queries one by one
/// (single stream, like the paper's single-thread protocol), returning
/// `(recall@1, mean ms/query, QPS)`.
pub fn measure_search<F>(
    queries: &[f32],
    dim: usize,
    gt: &[i64],
    gt_k: usize,
    k: usize,
    trials: usize,
    mut search: F,
) -> SearchMeasurement
where
    F: FnMut(&[f32], usize) -> (Vec<f32>, Vec<i64>),
{
    let nq = queries.len() / dim;
    // warm + collect labels once for recall
    let mut all_labels = Vec::with_capacity(nq * k);
    for qi in 0..nq {
        let (_d, l) = search(&queries[qi * dim..(qi + 1) * dim], k);
        all_labels.extend(l);
    }
    let recall = recall_at_r(gt, gt_k, &all_labels, k, 1);
    let recall_at_k = recall_at_r(gt, gt_k, &all_labels, k, k);

    // timed trials (paper: average of five)
    let mut per_trial_ms = Vec::with_capacity(trials);
    for _ in 0..trials {
        let t = Timer::start();
        for qi in 0..nq {
            let (_d, _l) = search(&queries[qi * dim..(qi + 1) * dim], k);
        }
        per_trial_ms.push(t.elapsed_ms() / nq as f64);
    }
    per_trial_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ms_per_query = per_trial_ms[per_trial_ms.len() / 2];
    SearchMeasurement { recall_at_1: recall, recall_at_k, ms_per_query, qps: 1e3 / ms_per_query }
}

/// Result of [`measure_search`].
#[derive(Clone, Debug)]
pub struct SearchMeasurement {
    pub recall_at_1: f64,
    pub recall_at_k: f64,
    pub ms_per_query: f64,
    pub qps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn ground_truth_is_exact() {
        let mut rng = Rng::new(81);
        let dim = 8;
        let base: Vec<f32> = (0..100 * dim).map(|_| rng.next_gaussian()).collect();
        // queries = perturbed base rows, so the GT is known
        let mut queries = Vec::new();
        for i in [3usize, 42, 77] {
            let mut row = base[i * dim..(i + 1) * dim].to_vec();
            for v in &mut row {
                *v += 0.001;
            }
            queries.extend(row);
        }
        let gt = ground_truth(&base, &queries, dim, 5);
        assert_eq!(gt[0], 3);
        assert_eq!(gt[5], 42);
        assert_eq!(gt[10], 77);
    }

    #[test]
    fn recall_computation() {
        // 2 queries, gt_k=3, res_k=2
        let gt = vec![7, 1, 2, /* q1 */ 9, 4, 5];
        let results = vec![7, 0, /* q1 */ 8, 3];
        assert_eq!(recall_at_r(&gt, 3, &results, 2, 1), 0.5);
        assert_eq!(recall_at_r(&gt, 3, &results, 2, 2), 0.5);
        let results2 = vec![0, 7, 8, 9];
        assert_eq!(recall_at_r(&gt, 3, &results2, 2, 1), 0.0);
        assert_eq!(recall_at_r(&gt, 3, &results2, 2, 2), 1.0);
    }

    #[test]
    fn intersection_recall_computation() {
        let gt = vec![1, 2, 3, 4];
        let results = vec![2, 1, 9, 9];
        assert_eq!(intersection_recall(&gt, 4, &results, 4, 2), 1.0);
        assert_eq!(intersection_recall(&gt, 4, &results, 4, 4), 0.5);
    }

    #[test]
    fn measure_search_runs() {
        let mut rng = Rng::new(82);
        let dim = 4;
        let base: Vec<f32> = (0..50 * dim).map(|_| rng.next_gaussian()).collect();
        let queries = base[..10 * dim].to_vec();
        let gt = ground_truth(&base, &queries, dim, 1);
        let m = measure_search(&queries, dim, &gt, 1, 1, 3, |q, k| {
            // exact scan: recall must be 1.0
            let mut heap = TopK::new(k);
            for i in 0..50 {
                heap.push(crate::util::l2_sq(q, &base[i * dim..(i + 1) * dim]), i as i64);
            }
            heap.into_sorted()
        });
        assert_eq!(m.recall_at_1, 1.0);
        assert!(m.ms_per_query > 0.0);
        assert!(m.qps > 0.0);
    }

    #[test]
    #[should_panic]
    fn recall_rejects_r_too_large() {
        recall_at_r(&[1], 1, &[1], 1, 2);
    }
}
