//! The mutable front of a [`crate::segment::SegmentedIndex`]: a small
//! append-only batch of vectors that has not been sealed into a packed
//! segment yet.
//!
//! # Value semantics (copy-on-write)
//!
//! A `Memtable` is an immutable value. Mutations ([`Memtable::with_appended`],
//! [`Memtable::with_removed`]) build a *new* memtable and leave the old one
//! untouched, so a snapshot holding `Arc<Memtable>` stays valid forever —
//! readers scanning an old snapshot never observe a half-applied insert.
//! The copy cost is bounded by the flush threshold (the background worker
//! seals the memtable into a packed segment long before it grows large).
//!
//! # Scan semantics
//!
//! Vectors are PQ-encoded **at insert time** against the shared codebook,
//! and the memtable scan computes exact ADC distances over those codes
//! ([`crate::pq::codebook::ProductQuantizer::adc_distance`]) — the *same*
//! distance the sealed re-rank path computes from
//! [`crate::pq::layout::PackedCodes::code_at`]. Under the default
//! `rerank = true` configuration a flush is therefore invisible: the row
//! moves from the memtable to a sealed segment and its reported distance
//! does not change by a single bit.

use crate::index::query::Hit;
use crate::pq::codebook::ProductQuantizer;
use crate::pq::fastscan::FilterMask;
use crate::util::topk::TopK;

/// An immutable batch of unsealed rows: ids, raw vectors (kept for future
/// re-encoding on codebook evolution and for debugging), and insert-time
/// PQ codes (`len × pq.m` internal columns).
#[derive(Debug, Default, Clone)]
pub struct Memtable {
    ids: Vec<i64>,
    vectors: Vec<f32>,
    codes: Vec<u8>,
}

impl Memtable {
    /// The empty memtable.
    pub fn empty() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// External ids, insertion order.
    pub fn ids(&self) -> &[i64] {
        &self.ids
    }

    /// Raw vectors (`len × dim`, insertion order).
    pub fn vectors(&self) -> &[f32] {
        &self.vectors
    }

    /// Insert-time PQ codes (`len × code_cols`, insertion order).
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Rebuild from persisted parts (the manifest loader).
    pub(crate) fn from_parts(ids: Vec<i64>, vectors: Vec<f32>, codes: Vec<u8>) -> Self {
        Self { ids, vectors, codes }
    }

    /// A new memtable with `ids`/`vectors`/`codes` appended (the old value
    /// is untouched — snapshot readers keep scanning it).
    pub fn with_appended(&self, ids: &[i64], vectors: &[f32], codes: &[u8]) -> Self {
        let mut next = self.clone();
        next.ids.extend_from_slice(ids);
        next.vectors.extend_from_slice(vectors);
        next.codes.extend_from_slice(codes);
        next
    }

    /// A new memtable with every row whose id satisfies `remove` dropped;
    /// returns the new value and how many rows were removed. Relative row
    /// order of the survivors is preserved (the deterministic-merge
    /// discipline orders equal distances by label, but compaction order
    /// must stay insertion order).
    pub fn with_removed(&self, remove: impl Fn(i64) -> bool, dim: usize, code_cols: usize) -> (Self, usize) {
        let mut next = Memtable::empty();
        let mut removed = 0usize;
        for (row, &id) in self.ids.iter().enumerate() {
            if remove(id) {
                removed += 1;
                continue;
            }
            next.ids.push(id);
            next.vectors.extend_from_slice(&self.vectors[row * dim..(row + 1) * dim]);
            next.codes.extend_from_slice(&self.codes[row * code_cols..(row + 1) * code_cols]);
        }
        (next, removed)
    }

    /// Exhaustive exact-ADC top-k over the memtable rows admitted by
    /// `mask` (position space, like the sealed kernels). Returns ascending
    /// `(distance, label)` hits, at most `k`.
    pub fn scan_topk(
        &self,
        pq: &ProductQuantizer,
        luts_f32: &[f32],
        k: usize,
        mask: Option<&FilterMask>,
        heap_storage: Vec<(f32, i64)>,
    ) -> (Vec<Hit>, Vec<(f32, i64)>) {
        if k == 0 {
            return (Vec::new(), heap_storage);
        }
        let cols = pq.m;
        let mut heap = TopK::from_storage(k, heap_storage);
        for (row, &id) in self.ids.iter().enumerate() {
            if mask.is_some_and(|m| !m.passes(row)) {
                continue;
            }
            let d = pq.adc_distance(luts_f32, &self.codes[row * cols..(row + 1) * cols]);
            heap.push(d, id);
        }
        let hits = heap
            .as_sorted_hits()
            .iter()
            .map(|&(distance, label)| Hit { distance, label })
            .collect();
        (hits, heap.into_storage())
    }

    /// Exhaustive exact-ADC range scan over admitted memtable rows:
    /// every `(distance, label)` with distance `<= radius`, ascending by
    /// `(distance, label)`.
    pub fn scan_range(
        &self,
        pq: &ProductQuantizer,
        luts_f32: &[f32],
        radius: f32,
        mask: Option<&FilterMask>,
    ) -> Vec<Hit> {
        let cols = pq.m;
        let mut hits: Vec<Hit> = Vec::new();
        for (row, &id) in self.ids.iter().enumerate() {
            if mask.is_some_and(|m| !m.passes(row)) {
                continue;
            }
            let d = pq.adc_distance(luts_f32, &self.codes[row * cols..(row + 1) * cols]);
            if d <= radius {
                hits.push(Hit { distance: d, label: id });
            }
        }
        hits.sort_by(|a, b| {
            a.distance
                .partial_cmp(&b.distance)
                .unwrap()
                .then(a.label.cmp(&b.label))
        });
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::PqParams;
    use crate::util::rng::Rng;

    fn toy_pq(dim: usize, m: usize, seed: u64) -> (ProductQuantizer, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..400 * dim).map(|_| rng.next_gaussian()).collect();
        let pq = ProductQuantizer::train(&data, dim, &PqParams::new_4bit(m)).unwrap();
        (pq, data)
    }

    #[test]
    fn append_is_copy_on_write() {
        let (pq, data) = toy_pq(16, 4, 301);
        let dim = 16;
        let codes = pq.encode(&data[..4 * dim]).unwrap();
        let base = Memtable::empty();
        let a = base.with_appended(&[10, 11], &data[..2 * dim], &codes[..2 * pq.m]);
        let b = a.with_appended(&[12, 13], &data[2 * dim..4 * dim], &codes[2 * pq.m..4 * pq.m]);
        // the older values are untouched
        assert_eq!(base.len(), 0);
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 4);
        assert_eq!(b.ids(), &[10, 11, 12, 13]);
        assert_eq!(b.codes().len(), 4 * pq.m);
        assert_eq!(b.vectors().len(), 4 * dim);
    }

    #[test]
    fn removal_preserves_survivor_order() {
        let (pq, data) = toy_pq(16, 4, 302);
        let dim = 16;
        let codes = pq.encode(&data[..5 * dim]).unwrap();
        let mt = Memtable::empty().with_appended(&[1, 2, 3, 4, 5], &data[..5 * dim], &codes[..5 * pq.m]);
        let (next, removed) = mt.with_removed(|id| id % 2 == 0, dim, pq.m);
        assert_eq!(removed, 2);
        assert_eq!(next.ids(), &[1, 3, 5]);
        // survivor rows carry their own codes, not shifted neighbors'
        assert_eq!(&next.codes()[pq.m..2 * pq.m], &codes[2 * pq.m..3 * pq.m]);
        // removing nothing is a cheap identity
        let (same, zero) = next.with_removed(|_| false, dim, pq.m);
        assert_eq!(zero, 0);
        assert_eq!(same.ids(), next.ids());
    }

    #[test]
    fn scan_matches_adc_oracle() {
        let (pq, data) = toy_pq(16, 4, 303);
        let dim = 16;
        let n = 50;
        let codes = pq.encode(&data[..n * dim]).unwrap();
        let ids: Vec<i64> = (100..100 + n as i64).collect();
        let mt = Memtable::empty().with_appended(&ids, &data[..n * dim], &codes);
        let luts = pq.compute_luts(&data[..dim]);
        // oracle: exact ADC over all rows
        let mut oracle: Vec<(f32, i64)> = (0..n)
            .map(|row| (pq.adc_distance(&luts, &codes[row * pq.m..(row + 1) * pq.m]), ids[row]))
            .collect();
        oracle.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let (hits, _store) = mt.scan_topk(&pq, &luts, 7, None, Vec::new());
        let got: Vec<(f32, i64)> = hits.iter().map(|h| (h.distance, h.label)).collect();
        assert_eq!(got, oracle[..7].to_vec());
        // masked scan drops exactly the masked positions
        let mask = FilterMask::from_fn(n, |p| p % 2 == 0);
        let (hits_m, _store) = mt.scan_topk(&pq, &luts, 7, Some(&mask), Vec::new());
        let want: Vec<(f32, i64)> = oracle
            .iter()
            .filter(|&&(_, id)| (id - 100) % 2 == 0)
            .take(7)
            .copied()
            .collect();
        let got_m: Vec<(f32, i64)> = hits_m.iter().map(|h| (h.distance, h.label)).collect();
        assert_eq!(got_m, want);
        // range agrees with the top-k prefix at the same boundary
        let radius = oracle[9].0;
        let range = mt.scan_range(&pq, &luts, radius, None);
        assert!(range.len() >= 10);
        assert!(range.iter().all(|h| h.distance <= radius));
        assert!(range.windows(2).all(|w| w[0].distance <= w[1].distance));
    }
}
