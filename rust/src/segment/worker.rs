//! The background flush/compaction worker.
//!
//! One detached maintenance thread per [`SegmentedIndex`] (spawned on
//! demand, idempotent). The loop is deliberately boring: wake up — either
//! on the insert-path `wake` notification when the memtable crosses the
//! flush threshold, or on a coarse timeout — and run one
//! `SegInner::maintain` pass (flush if due, compact if the stack is deep).
//! All the concurrency subtlety lives in the snapshot-swap scheme of
//! [`crate::segment::index`]: the worker takes the same `writer` mutex as
//! every other mutator and readers never notice it exists.
//!
//! Shutdown lives in `SegmentedIndex::stop_background` (which `drop`
//! delegates to): set the `stop` flag, ring `wake`, join. Both directions
//! are idempotent — spawn after stop restarts the loop, stop without a
//! worker is a no-op. The worker holds only an `Arc<SegInner>`, so
//! stopping while the thread is mid-flush is safe — the inner state
//! outlives the loop.

use crate::segment::index::SegmentedIndex;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// How long the worker sleeps between unsolicited maintenance passes.
/// Short enough that compaction pressure drains promptly, long enough to
/// stay invisible in profiles.
const IDLE_TICK: Duration = Duration::from_millis(20);

/// Start the background worker for `idx` (no-op if already running).
pub(crate) fn spawn(idx: &SegmentedIndex) {
    let mut slot = idx.worker.lock().unwrap();
    if slot.is_some() {
        return;
    }
    *idx.inner.stop.lock().unwrap() = false;
    idx.inner.worker_on.store(true, Ordering::SeqCst);
    let inner = idx.inner.clone();
    *slot = Some(std::thread::spawn(move || {
        loop {
            {
                let guard = inner.stop.lock().unwrap();
                if *guard {
                    return;
                }
                // wait for an insert-path nudge or the idle tick; spurious
                // wakeups just cost one cheap maintain() no-op
                let (guard, _timeout) = inner.wake.wait_timeout(guard, IDLE_TICK).unwrap();
                if *guard {
                    return;
                }
            }
            // maintenance failures (e.g. a poisoned invariant) must not
            // kill the thread silently mid-loop; the next explicit
            // flush()/compact() call surfaces the same error to a caller
            let _ = inner.maintain();
        }
    }));
}
