//! Streaming mutable index: an LSM-style segment stack over the sealed
//! fastscan kernel contract.
//!
//! # Why segments
//!
//! The paper's 4-bit fastscan kernels require a frozen, SIMD-interleaved
//! code layout — PRs 1–5 hardened that into the `train`/`add`/`seal` →
//! lock-free `Arc<dyn Index>` contract. A production ANN service, however,
//! takes inserts and deletes continuously. The classic resolution (used by
//! every production ARM vector stack this repo tracks) is to keep the
//! kernel contract *per segment* instead of per index:
//!
//! * a small mutable **memtable** ([`Memtable`]) absorbs inserts and is
//!   scanned exactly (ADC over insert-time codes against the shared
//!   codebook) — never packed, never large;
//! * a stack of **sealed segments** ([`SealedSegment`]) — each one exactly
//!   the immutable packed block of a standalone index — serves the bulk of
//!   the data through the unchanged fastscan kernels;
//! * **tombstones** record deleted ids; they compile into the existing
//!   [`crate::pq::fastscan::FilterMask`] admission path (composed with any
//!   user filter), so deleted rows vanish from kernels without touching
//!   packed codes;
//! * a background **flush/compaction worker** seals the memtable into a
//!   new segment and merges the stack back toward one segment, physically
//!   dropping tombstoned rows.
//!
//! # Contracts carried over from the sealed world
//!
//! * **Lock-free reads.** All reader-visible state lives in one immutable
//!   snapshot behind a copy-on-write pointer; a query dereferences it once
//!   and never takes a lock a writer holds during flush or compaction.
//! * **Determinism.** Scan units (segments, then the memtable) are scanned
//!   by pure kernels and merged in unit order by `(distance, label)` — the
//!   per-probed-list merge discipline of [`crate::ivf`] extended to
//!   segments. Results are bit-identical at every executor thread count,
//!   and after `flush` + `compact` they are bit-identical to a one-shot
//!   sealed index built from the surviving vectors with the same codebook.
//! * **One live row per id.** Insert is upsert; each tombstone names
//!   exactly one dead sealed row. `ntotal` stays O(1) and merges never see
//!   duplicate labels.

pub mod index;
pub mod memtable;
pub mod sealed;
pub(crate) mod worker;

pub use index::SegmentedIndex;
pub use memtable::Memtable;
pub use sealed::SealedSegment;

/// Tuning knobs for the segment lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentedParams {
    /// Memtable rows that trigger a flush into a sealed segment.
    pub flush_threshold: usize,
    /// Sealed-segment count above which a compaction merges the stack.
    pub max_segments: usize,
}

impl Default for SegmentedParams {
    fn default() -> Self {
        Self { flush_threshold: 4096, max_segments: 8 }
    }
}

/// Segment-lifecycle observability: surfaced through
/// [`crate::index::Index::segment_stats`], the coordinator's `stats` verb,
/// and [`crate::coordinator::metrics`] gauges, so compaction pressure is
/// visible before it becomes tail latency.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SegmentStats {
    /// Sealed segments currently in the stack.
    pub segments: usize,
    /// Rows across all sealed segments (live + tombstoned).
    pub sealed_rows: usize,
    /// Rows in the mutable memtable.
    pub memtable_entries: usize,
    /// Dead sealed rows awaiting compaction.
    pub tombstones: usize,
    /// Lifetime flush count.
    pub flushes: u64,
    /// Lifetime compaction count.
    pub compactions: u64,
}
