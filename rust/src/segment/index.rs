//! [`SegmentedIndex`]: the streaming mutable index — an LSM-style stack of
//! sealed fastscan segments behind a copy-on-write snapshot pointer.
//!
//! # Concurrency model: snapshot swap, never in-place mutation
//!
//! All index state a reader touches lives in one immutable [`Snapshot`]
//! (sealed segments, tombstone set, memtable) behind
//! `RwLock<Arc<Snapshot>>`. A query clones the `Arc` under a momentary
//! read lock and then runs entirely lock-free on frozen data — concurrent
//! flush/compaction can never block a reader on the sealed stack, and a
//! reader can never observe a torn segment set. Writers serialize on a
//! separate `writer` mutex, build the next snapshot off-line, and swap the
//! pointer; the old snapshot stays alive until its last reader drops it.
//!
//! # Id semantics: unique live ids (upsert)
//!
//! Every external id has **at most one live row**. Re-inserting an id
//! replaces the old row: a memtable copy is removed directly, a sealed
//! copy is tombstoned (flush physically purges the dead copy before
//! sealing the replacement, so a tombstone always refers to exactly one
//! dead sealed row). This keeps `ntotal` O(1), keeps merge free of
//! duplicate labels, and gives `delete` exact row counts.
//!
//! # Determinism
//!
//! Scan units (sealed segments in stack order, then the memtable) are each
//! scanned by the same pure kernels as a standalone index, and merged in
//! unit order by `(distance, label)` — the per-probed-list discipline of
//! [`crate::ivf`] extended to segments. Results are bit-identical at every
//! executor thread count, and a flushed-and-compacted index is
//! bit-identical to a one-shot [`crate::index::IndexPq4FastScan`] built
//! from the surviving vectors with the same codebook.

use crate::exec::{range_packed, topk_packed, MaskPlan, QueryExecutor, ScanScratch};
use crate::index::params::effective_fastscan;
use crate::index::query::{Hit, QueryKind, QueryRequest, QueryResponse, QueryStats};
use crate::index::{Index, SearchParams};
use crate::obs::{Phase, TraceSpan};
use crate::pq::fastscan::{FastScanParams, FilterMask};
use crate::pq::{CodeWidth, ProductQuantizer};
use crate::segment::memtable::Memtable;
use crate::segment::sealed::SealedSegment;
use crate::segment::{SegmentStats, SegmentedParams};
use crate::{Error, Result};
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// One immutable view of the whole index. Readers hold an `Arc` to it for
/// the duration of a query; writers replace the pointer wholesale.
#[derive(Clone, Default)]
pub(crate) struct Snapshot {
    /// Sealed segments, oldest first (unit scan/merge order).
    pub segments: Vec<Arc<SealedSegment>>,
    /// Ids whose single sealed copy is dead. Compiled into the per-segment
    /// [`FilterMask`] admission path; never applied to the memtable (a
    /// tombstoned id's live replacement, if any, lives there).
    pub tombstones: Arc<HashSet<i64>>,
    /// The mutable front (immutable value, swapped on every mutation).
    pub memtable: Arc<Memtable>,
}

impl Snapshot {
    fn sealed_rows(&self) -> usize {
        self.segments.iter().map(|s| s.len()).sum()
    }

    /// Live rows: every sealed row minus its tombstone (exactly one dead
    /// row per tombstone — the upsert invariant), plus the memtable.
    fn live(&self) -> usize {
        self.sealed_rows().saturating_sub(self.tombstones.len()) + self.memtable.len()
    }
}

/// The shared heart of a [`SegmentedIndex`]: all state plus the mutation
/// and query logic, so the background worker (holding only an
/// `Arc<SegInner>`) can flush and compact exactly like the front object.
pub(crate) struct SegInner {
    dim: usize,
    /// User-facing sub-quantizer count (the factory `PQ{m}x{bits}fs` m).
    m: usize,
    width: CodeWidth,
    params: SegmentedParams,
    /// Codebook shared by every segment and the memtable — one LUT per
    /// query serves the whole fan-out.
    pq: RwLock<Option<Arc<ProductQuantizer>>>,
    snap: RwLock<Arc<Snapshot>>,
    /// Serializes mutators (insert/delete/flush/compact). Readers never
    /// touch it.
    writer: Mutex<()>,
    next_id: AtomicI64,
    fastscan: RwLock<FastScanParams>,
    flushes: AtomicU64,
    compactions: AtomicU64,
    /// Background worker wiring: liveness flag, stop flag + wake condvar.
    pub(crate) worker_on: AtomicBool,
    pub(crate) stop: Mutex<bool>,
    pub(crate) wake: Condvar,
}

impl SegInner {
    /// Internal code columns per row (`width.code_columns(m)` = the
    /// trained quantizer's `pq.m`).
    fn code_cols(&self) -> usize {
        self.width.code_columns(self.m)
    }

    pub(crate) fn snapshot(&self) -> Arc<Snapshot> {
        self.snap.read().unwrap().clone()
    }

    fn install(&self, next: Snapshot) {
        *self.snap.write().unwrap() = Arc::new(next);
    }

    fn pq(&self) -> Result<Arc<ProductQuantizer>> {
        self.pq.read().unwrap().clone().ok_or(Error::NotTrained)
    }

    fn train(&self, data: &[f32]) -> Result<()> {
        if self.snapshot().live() > 0 {
            return Err(Error::InvalidParameter(
                "segmented index: train before the first insert (the codebook is shared \
                 by every segment and cannot change under live rows)"
                    .into(),
            ));
        }
        self.width.validate(self.dim, self.m)?;
        let pq = ProductQuantizer::train(data, self.dim, &self.width.pq_params(self.m))?;
        *self.pq.write().unwrap() = Some(Arc::new(pq));
        Ok(())
    }

    /// Append rows (upsert: an id's previous live row is replaced). Codes
    /// are encoded against the shared codebook *here*, so the memtable's
    /// exact-ADC distances equal the sealed re-rank distances and a flush
    /// is invisible under the default `rerank = true`.
    pub(crate) fn insert(&self, data: &[f32], ids: Option<&[i64]>) -> Result<Vec<i64>> {
        let pq = self.pq()?;
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        let n = data.len() / self.dim;
        if let Some(ids) = ids {
            if ids.len() != n {
                return Err(Error::InvalidParameter(format!(
                    "insert: {} ids for {n} vectors",
                    ids.len()
                )));
            }
            let mut seen = HashSet::with_capacity(ids.len());
            if let Some(dup) = ids.iter().find(|id| !seen.insert(**id)) {
                return Err(Error::InvalidParameter(format!(
                    "insert: duplicate id {dup} within one batch"
                )));
            }
        }
        if n == 0 {
            return Ok(Vec::new());
        }
        let assigned: Vec<i64> = match ids {
            Some(ids) => {
                let max = ids.iter().copied().max().unwrap();
                self.next_id.fetch_max(max.saturating_add(1), Ordering::SeqCst);
                ids.to_vec()
            }
            None => {
                let base = self.next_id.fetch_add(n as i64, Ordering::SeqCst);
                (base..base + n as i64).collect()
            }
        };
        let codes = pq.encode(data)?;

        let guard = self.writer.lock().unwrap();
        let snap = self.snapshot();
        let inserted: HashSet<i64> = assigned.iter().copied().collect();
        // replace any previous live memtable copy of a re-inserted id
        let (memtable, _replaced) = snap.memtable.with_removed(
            |id| inserted.contains(&id),
            self.dim,
            self.code_cols(),
        );
        // tombstone any previous live sealed copy (flush purges the dead
        // row before sealing the replacement)
        let mut tombstones = (*snap.tombstones).clone();
        for seg in &snap.segments {
            for &id in &inserted {
                if seg.id_set.contains(&id) {
                    tombstones.insert(id);
                }
            }
        }
        let memtable = memtable.with_appended(&assigned, data, &codes);
        let full = memtable.len() >= self.params.flush_threshold;
        self.install(Snapshot {
            segments: snap.segments.clone(),
            tombstones: Arc::new(tombstones),
            memtable: Arc::new(memtable),
        });
        drop(guard);
        if full {
            if self.worker_on.load(Ordering::SeqCst) {
                self.wake.notify_all();
            } else {
                // no background worker: maintenance runs inline, so test
                // workloads stay deterministic
                self.flush()?;
                if self.snapshot().segments.len() > self.params.max_segments {
                    self.compact()?;
                }
            }
        }
        Ok(assigned)
    }

    /// Remove rows by id. Memtable rows disappear immediately; sealed rows
    /// are tombstoned (they vanish from the kernels via the mask admission
    /// path and are physically dropped at the next compaction). Returns
    /// the number of live rows removed.
    pub(crate) fn delete(&self, ids: &[i64]) -> Result<usize> {
        let del: HashSet<i64> = ids.iter().copied().collect();
        if del.is_empty() {
            return Ok(0);
        }
        let _guard = self.writer.lock().unwrap();
        let snap = self.snapshot();
        let (memtable, removed_mem) =
            snap.memtable.with_removed(|id| del.contains(&id), self.dim, self.code_cols());
        let mut tombstones = (*snap.tombstones).clone();
        let mut removed_sealed = 0usize;
        for &id in &del {
            let sealed = snap.segments.iter().any(|s| s.id_set.contains(&id));
            if sealed && tombstones.insert(id) {
                removed_sealed += 1;
            }
        }
        self.install(Snapshot {
            segments: snap.segments.clone(),
            tombstones: Arc::new(tombstones),
            memtable: Arc::new(memtable),
        });
        Ok(removed_mem + removed_sealed)
    }

    /// Seal the memtable into a new segment. Before sealing, ids being
    /// flushed that carry a tombstone (re-inserted ids) have their dead
    /// sealed copy physically purged and the tombstone dropped, so the
    /// freshly sealed replacement is never masked by its own id.
    pub(crate) fn flush(&self) -> Result<()> {
        let _guard = self.writer.lock().unwrap();
        let snap = self.snapshot();
        if snap.memtable.is_empty() {
            return Ok(());
        }
        let resurrected: HashSet<i64> = snap
            .memtable
            .ids()
            .iter()
            .copied()
            .filter(|id| snap.tombstones.contains(id))
            .collect();
        let (mut segments, tombstones) = if resurrected.is_empty() {
            (snap.segments.clone(), snap.tombstones.clone())
        } else {
            let purged = purge_segments(&snap.segments, &resurrected, self.m, self.width)?;
            let mut tomb = (*snap.tombstones).clone();
            for id in &resurrected {
                tomb.remove(id);
            }
            (purged, Arc::new(tomb))
        };
        let seg = SealedSegment::build(
            snap.memtable.ids().to_vec(),
            snap.memtable.codes().to_vec(),
            self.m,
            self.width,
        )?;
        segments.push(Arc::new(seg));
        self.install(Snapshot { segments, tombstones, memtable: Arc::new(Memtable::empty()) });
        self.flushes.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Merge all sealed segments into one, dropping tombstoned rows.
    /// Surviving rows keep segment-stack then within-segment order, so a
    /// compacted stack scans in the same order an equivalently-built
    /// one-shot index would — the bit-identity anchor.
    pub(crate) fn compact(&self) -> Result<()> {
        let _guard = self.writer.lock().unwrap();
        let snap = self.snapshot();
        if snap.segments.len() <= 1 && snap.tombstones.is_empty() {
            return Ok(());
        }
        let cols = self.code_cols();
        let mut ids: Vec<i64> = Vec::with_capacity(snap.sealed_rows());
        let mut codes: Vec<u8> = Vec::with_capacity(snap.sealed_rows() * cols);
        for seg in &snap.segments {
            // mapped segments have no flat columns; this unpacks on demand
            let flat = seg.flat_codes();
            for (row, &id) in seg.ids.iter().enumerate() {
                if snap.tombstones.contains(&id) {
                    continue;
                }
                ids.push(id);
                codes.extend_from_slice(&flat[row * cols..(row + 1) * cols]);
            }
        }
        let segments = if ids.is_empty() {
            Vec::new()
        } else {
            vec![Arc::new(SealedSegment::build(ids, codes, self.m, self.width)?)]
        };
        self.install(Snapshot {
            segments,
            tombstones: Arc::new(HashSet::new()),
            memtable: snap.memtable.clone(),
        });
        self.compactions.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// One background maintenance pass: flush when the memtable is past
    /// the threshold, compact when the stack is past `max_segments`.
    pub(crate) fn maintain(&self) -> Result<()> {
        if self.snapshot().memtable.len() >= self.params.flush_threshold {
            self.flush()?;
        }
        if self.snapshot().segments.len() > self.params.max_segments {
            self.compact()?;
        }
        Ok(())
    }

    pub(crate) fn stats(&self) -> SegmentStats {
        let snap = self.snapshot();
        SegmentStats {
            segments: snap.segments.len(),
            sealed_rows: snap.sealed_rows(),
            memtable_entries: snap.memtable.len(),
            tombstones: snap.tombstones.len(),
            flushes: self.flushes.load(Ordering::SeqCst),
            compactions: self.compactions.load(Ordering::SeqCst),
        }
    }

    /// The plan/execute core: snapshot once, build lazy per-unit masks
    /// (tombstones composed with the user filter), fan out on the
    /// executor, merge in unit order.
    fn query_luts_exec(
        &self,
        req: &QueryRequest<'_>,
        luts: Option<&[f32]>,
        exec: &QueryExecutor,
    ) -> Result<QueryResponse> {
        req.kind.validate()?;
        let pq = self.pq()?;
        if req.queries.len() % self.dim != 0 {
            return Err(Error::DimMismatch {
                expected: self.dim,
                got: req.queries.len() % self.dim,
            });
        }
        let nq = req.queries.len() / self.dim;
        let lut_len = pq.m * pq.ksub;
        if let Some(ls) = luts {
            if ls.len() != nq * lut_len {
                return Err(Error::InvalidParameter(format!(
                    "precomputed luts length {} != nq {nq} × {lut_len}",
                    ls.len()
                )));
            }
        }
        let snap = self.snapshot();
        if nq == 0 || snap.live() == 0 || matches!(req.kind, QueryKind::TopK { k: 0 }) {
            return Ok(QueryResponse::empty(nq));
        }
        let memtable_entries = snap.memtable.len();
        let ntomb = snap.tombstones.len();
        if req.filter.as_ref().is_some_and(|f| f.is_provably_empty()) {
            let stats = QueryStats {
                codes_scanned: 0,
                lists_probed: 0,
                filter_selectivity: 0.0,
                segments_scanned: 0,
                memtable_entries,
                tombstones: ntomb,
                ..Default::default()
            };
            return Ok(QueryResponse {
                hits: vec![Vec::new(); nq],
                stats: vec![stats; nq],
                traces: Vec::new(),
            });
        }

        // scan units: sealed segments in stack order, then the memtable
        let plan_t0 = req.trace.then(std::time::Instant::now);
        let mut units: Vec<Unit<'_>> =
            snap.segments.iter().map(|s| Unit::Sealed(s.as_ref())).collect();
        if !snap.memtable.is_empty() {
            units.push(Unit::Mem(snap.memtable.as_ref()));
        }
        let nunits = units.len();
        let fs = effective_fastscan(&self.fastscan.read().unwrap(), req.params.as_ref());
        let masks = if req.filter.is_some() || ntomb > 0 {
            MaskPlan::lists(nunits)
        } else {
            MaskPlan::None
        };
        // request-level plan cost, attributed to each query it served
        let plan_us = plan_t0.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0);
        let filter = req.filter.as_ref();
        let tomb = snap.tombstones.as_ref();
        let scan_unit = |u: usize, luts_f32: &[f32], scratch: &mut ScanScratch| -> Vec<Hit> {
            // per-unit mask: query-independent, built at most once per unit
            // for the whole batch (shared through the plan's OnceLock slots)
            let mask = masks.list_mask(u, || match units[u] {
                Unit::Sealed(seg) => FilterMask::from_fn(seg.len(), |pos| {
                    let id = seg.ids[pos];
                    !tomb.contains(&id) && filter.map_or(true, |f| f.matches(id))
                }),
                // tombstones never apply to the memtable: a tombstoned
                // id's live replacement is exactly what lives here
                Unit::Mem(mt) => FilterMask::from_fn(mt.len(), |pos| {
                    filter.map_or(true, |f| f.matches(mt.ids()[pos]))
                }),
            });
            match units[u] {
                Unit::Sealed(seg) => match req.kind {
                    QueryKind::TopK { k } => topk_packed(
                        &pq,
                        &seg.packed,
                        luts_f32,
                        k,
                        &fs,
                        Some(seg.ids.as_slice()),
                        mask,
                        scratch,
                    ),
                    QueryKind::Range { radius } => range_packed(
                        &pq,
                        &seg.packed,
                        luts_f32,
                        radius,
                        &fs,
                        Some(seg.ids.as_slice()),
                        mask,
                        scratch,
                    ),
                },
                Unit::Mem(mt) => {
                    let t_mem = scratch.trace().start();
                    let hits = match req.kind {
                        QueryKind::TopK { k } => {
                            let (hits, store) =
                                mt.scan_topk(&pq, luts_f32, k, mask, scratch.take_heap());
                            scratch.put_heap(store);
                            hits
                        }
                        QueryKind::Range { radius } => mt.scan_range(&pq, luts_f32, radius, mask),
                    };
                    scratch.trace_mut().finish_with(
                        Phase::MemtableScan,
                        t_mem,
                        mt.len() as u64,
                        0,
                    );
                    hits
                }
            }
        };

        // Traced queries take the serial unit walk even when the fan-out
        // would apply: both paths are bit-identical (the thread-count
        // invariant), and the serial walk keeps every phase a wall-clock
        // leaf so the trace's phase sum tracks end-to-end latency.
        let fan_units = nq == 1 && exec.threads() > 1 && nunits > 1 && !req.trace;
        let results: Vec<(Vec<Hit>, Vec<TraceSpan>)> = if fan_units {
            // single wide query: fan the units out instead of the batch —
            // one LUT build serves every segment (shared codebook)
            let owned;
            let luts_f32: &[f32] = match luts {
                Some(ls) => ls,
                None => {
                    owned = pq.compute_luts(&req.queries[..self.dim]);
                    &owned
                }
            };
            let rows = exec.run_tasks(nunits, |u, scratch| scan_unit(u, luts_f32, scratch));
            vec![(merge_unit_rows(rows, req.kind), Vec::new())]
        } else {
            exec.run_batch(nq, |qi, scratch| {
                if req.trace {
                    scratch.trace_mut().enable();
                    scratch.trace_mut().add(Phase::PlanCompile, plan_us, 0, 0);
                    scratch.trace_mut().set_scan_phase(Phase::SegmentScan);
                }
                let t_total = scratch.trace().start();
                let mut lbuf = scratch.take_luts();
                let luts_f32: &[f32] = match luts {
                    Some(ls) => &ls[qi * lut_len..(qi + 1) * lut_len],
                    None => {
                        let t_lut = scratch.trace().start();
                        pq.compute_luts_into(
                            &req.queries[qi * self.dim..(qi + 1) * self.dim],
                            &mut lbuf,
                        );
                        scratch.trace_mut().finish(Phase::LutBuild, t_lut);
                        &lbuf
                    }
                };
                let rows: Vec<Vec<Hit>> = (0..nunits)
                    .map(|u| {
                        // hide the next unit's cold-page latency behind
                        // this unit's scan (pays off on mapped segments)
                        if u + 1 < nunits {
                            if let Unit::Sealed(next) = units[u + 1] {
                                crate::storage::prefetch_span(&next.packed.data);
                            }
                        }
                        scan_unit(u, luts_f32, scratch)
                    })
                    .collect();
                scratch.put_luts(lbuf);
                let t_merge = scratch.trace().start();
                let n_in: u64 = rows.iter().map(|r| r.len() as u64).sum();
                let row = merge_unit_rows(rows, req.kind);
                scratch.trace_mut().finish_with(Phase::Merge, t_merge, n_in, 0);
                let spans = if req.trace {
                    scratch.trace_mut().finish(Phase::Total, t_total);
                    scratch.trace_mut().add(Phase::Total, plan_us, 0, 0);
                    scratch.trace_mut().drain()
                } else {
                    Vec::new()
                };
                (row, spans)
            })
        };
        let mut hits = Vec::with_capacity(results.len());
        let mut traces = if req.trace { Vec::with_capacity(results.len()) } else { Vec::new() };
        for (row, spans) in results {
            hits.push(row);
            if req.trace {
                traces.push(spans);
            }
        }

        // stats: every query of the batch scanned every unit, and every
        // unit mask was built during the scan
        let codes_scanned: usize = units.iter().map(|u| u.len()).sum();
        let selectivity = if let MaskPlan::Lists(slots) = &masks {
            let (mut pass, mut total) = (0usize, 0usize);
            for (u, unit) in units.iter().enumerate() {
                total += unit.len();
                pass += slots[u].get().map_or(unit.len(), |m| m.pass_count());
            }
            if total == 0 { 1.0 } else { pass as f64 / total as f64 }
        } else {
            1.0
        };
        let bytes_mapped: usize = units
            .iter()
            .map(|u| match u {
                Unit::Sealed(seg) => seg.packed.mapped_bytes(),
                Unit::Mem(_) => 0,
            })
            .sum();
        // the unit fan-out scans segments concurrently, so "one ahead"
        // prefetch only exists on the serial per-query walk
        let prefetch_lists = if fan_units {
            0
        } else {
            units.iter().skip(1).filter(|u| matches!(u, Unit::Sealed(_))).count()
        };
        let mut stats = vec![
            QueryStats {
                codes_scanned,
                lists_probed: nunits,
                filter_selectivity: selectivity,
                segments_scanned: nunits,
                memtable_entries,
                tombstones: ntomb,
                bytes_mapped,
                prefetch_lists,
                ..Default::default()
            };
            nq
        ];
        exec.stamp_stats(&mut stats, if nq == 1 { nunits } else { nq });
        Ok(QueryResponse { hits, stats, traces })
    }
}

/// One scan unit of the fan-out.
#[derive(Clone, Copy)]
enum Unit<'s> {
    Sealed(&'s SealedSegment),
    Mem(&'s Memtable),
}

impl Unit<'_> {
    fn len(&self) -> usize {
        match self {
            Unit::Sealed(seg) => seg.len(),
            Unit::Mem(mt) => mt.len(),
        }
    }
}

/// Deterministic per-segment merge: flatten the per-unit rows (already
/// unit-ordered), sort by `(distance, label)` — the same total order every
/// kernel emits — and truncate to `k` for top-k. Ids are unique across
/// units (the upsert invariant), so no dedup pass is needed and the
/// comparator's tie-break is total.
fn merge_unit_rows(rows: Vec<Vec<Hit>>, kind: QueryKind) -> Vec<Hit> {
    let mut all: Vec<Hit> = rows.into_iter().flatten().collect();
    all.sort_by(|a, b| {
        a.distance
            .partial_cmp(&b.distance)
            .unwrap()
            .then(a.label.cmp(&b.label))
    });
    if let QueryKind::TopK { k } = kind {
        all.truncate(k);
    }
    all
}

/// Rebuild `segments` without the rows whose ids are in `drop`. Segments
/// untouched by `drop` are shared, not copied; a segment losing all rows
/// disappears.
fn purge_segments(
    segments: &[Arc<SealedSegment>],
    drop: &HashSet<i64>,
    user_m: usize,
    width: CodeWidth,
) -> Result<Vec<Arc<SealedSegment>>> {
    let mut out = Vec::with_capacity(segments.len());
    for seg in segments {
        if !drop.iter().any(|id| seg.id_set.contains(id)) {
            out.push(seg.clone());
            continue;
        }
        let cols = seg.code_cols();
        let flat = seg.flat_codes();
        let mut ids = Vec::new();
        let mut codes = Vec::new();
        for (row, &id) in seg.ids.iter().enumerate() {
            if drop.contains(&id) {
                continue;
            }
            ids.push(id);
            codes.extend_from_slice(&flat[row * cols..(row + 1) * cols]);
        }
        if !ids.is_empty() {
            out.push(Arc::new(SealedSegment::build(ids, codes, user_m, width)?));
        }
    }
    Ok(out)
}

/// The streaming mutable index (see the module doc for the architecture).
///
/// Implements the full [`Index`] surface: the build-phase methods map onto
/// the streaming ones (`add` = `insert`, `seal` = `flush` + `compact`),
/// and the streaming methods (`insert`/`delete`/`flush`/`compact`) take
/// `&self` — a `SegmentedIndex` behind `Arc<dyn Index>` mutates safely
/// from many threads.
pub struct SegmentedIndex {
    pub(crate) inner: Arc<SegInner>,
    /// Background flush/compaction worker, if spawned.
    pub(crate) worker: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SegmentedIndex {
    /// A new untrained segmented index.
    pub fn new(dim: usize, m: usize, width: CodeWidth, params: SegmentedParams) -> Result<Self> {
        width.validate(dim, m)?;
        if params.flush_threshold == 0 || params.max_segments == 0 {
            return Err(Error::InvalidParameter(
                "segmented index: flush_threshold and max_segments must be >= 1".into(),
            ));
        }
        Ok(Self {
            inner: Arc::new(SegInner {
                dim,
                m,
                width,
                params,
                pq: RwLock::new(None),
                snap: RwLock::new(Arc::new(Snapshot::default())),
                writer: Mutex::new(()),
                next_id: AtomicI64::new(0),
                fastscan: RwLock::new(FastScanParams::default()),
                flushes: AtomicU64::new(0),
                compactions: AtomicU64::new(0),
                worker_on: AtomicBool::new(false),
                stop: Mutex::new(false),
                wake: Condvar::new(),
            }),
            worker: Mutex::new(None),
        })
    }

    /// The paper's 4-bit configuration with default segment parameters.
    pub fn new_4bit(dim: usize, m: usize) -> Result<Self> {
        Self::new(dim, m, CodeWidth::W4, SegmentedParams::default())
    }

    /// Append rows; `ids: None` assigns sequential ids. Re-inserting an id
    /// replaces its previous row (upsert). `&self`: callable through
    /// `Arc<dyn Index>` concurrently with queries.
    pub fn insert(&self, data: &[f32], ids: Option<&[i64]>) -> Result<Vec<i64>> {
        self.inner.insert(data, ids)
    }

    /// Remove rows by id; returns the number of live rows removed.
    pub fn delete(&self, ids: &[i64]) -> Result<usize> {
        self.inner.delete(ids)
    }

    /// Seal the memtable into a new segment (no-op when empty).
    pub fn flush(&self) -> Result<()> {
        self.inner.flush()
    }

    /// Merge the sealed stack into one segment, dropping tombstoned rows.
    pub fn compact(&self) -> Result<()> {
        self.inner.compact()
    }

    /// Segment-lifecycle observability counters. Always `Some` here;
    /// `Option` keeps the signature identical to the `Index` trait method
    /// this otherwise shadows — an inherent `SegmentStats` return would
    /// out-resolve the trait for concrete receivers and break every
    /// caller written against the trait shape.
    pub fn segment_stats(&self) -> Option<SegmentStats> {
        Some(self.inner.stats())
    }

    /// Start the background flush/compaction worker (idempotent). Without
    /// it, maintenance runs inline at the insert that crosses a threshold
    /// — deterministic, which is what the differential tests want.
    pub fn spawn_background(&self) {
        crate::segment::worker::spawn(self);
    }

    /// Stop and join the background worker (idempotent; no-op when none
    /// is running). The index stays fully usable afterwards — maintenance
    /// reverts to running inline on the mutating path, and
    /// [`SegmentedIndex::spawn_background`] may restart the worker. `Drop`
    /// delegates here, so an explicit call simply moves the join earlier
    /// (e.g. a server draining its backend before teardown).
    pub fn stop_background(&self) {
        let handle = self.worker.lock().unwrap().take();
        let Some(handle) = handle else { return };
        *self.inner.stop.lock().unwrap() = true;
        self.inner.wake.notify_all();
        let _ = handle.join();
        self.inner.worker_on.store(false, Ordering::SeqCst);
    }

    /// Rebuild from persisted parts (`index/io.rs`).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_parts(
        dim: usize,
        m: usize,
        width: CodeWidth,
        params: SegmentedParams,
        pq: ProductQuantizer,
        segments: Vec<SealedSegment>,
        tombstones: HashSet<i64>,
        memtable: Memtable,
        next_id: i64,
    ) -> Result<Self> {
        if pq.m != width.code_columns(m) || pq.ksub != width.sub_ksub() {
            return Err(Error::InvalidParameter(format!(
                "segmented index: quantizer shape {}x{} does not match m={m} ({})",
                pq.m, pq.ksub, width
            )));
        }
        let idx = Self::new(dim, m, width, params)?;
        *idx.inner.pq.write().unwrap() = Some(Arc::new(pq));
        idx.inner.next_id.store(next_id, Ordering::SeqCst);
        idx.inner.install(Snapshot {
            segments: segments.into_iter().map(Arc::new).collect(),
            tombstones: Arc::new(tombstones),
            memtable: Arc::new(memtable),
        });
        Ok(idx)
    }

    /// Persistence view (crate-internal, used by `index/io.rs`): geometry,
    /// segment parameters, codebook, current snapshot, id counter.
    #[allow(clippy::type_complexity)]
    pub(crate) fn parts(
        &self,
    ) -> (usize, usize, CodeWidth, SegmentedParams, Option<Arc<ProductQuantizer>>, Arc<Snapshot>, i64)
    {
        let inner = &self.inner;
        (
            inner.dim,
            inner.m,
            inner.width,
            inner.params,
            inner.pq.read().unwrap().clone(),
            inner.snapshot(),
            inner.next_id.load(Ordering::SeqCst),
        )
    }
}

impl Drop for SegmentedIndex {
    fn drop(&mut self) {
        self.stop_background();
    }
}

impl Index for SegmentedIndex {
    fn dim(&self) -> usize {
        self.inner.dim
    }

    fn ntotal(&self) -> usize {
        self.inner.snapshot().live()
    }

    fn is_trained(&self) -> bool {
        self.inner.pq.read().unwrap().is_some()
    }

    fn train(&mut self, data: &[f32]) -> Result<()> {
        self.inner.train(data)
    }

    fn add(&mut self, data: &[f32]) -> Result<()> {
        self.inner.insert(data, None).map(|_| ())
    }

    /// `seal` maps onto the streaming lifecycle: flush the memtable and
    /// compact to a single segment — after which queries are bit-identical
    /// to a one-shot sealed index over the surviving rows.
    fn seal(&mut self) -> Result<()> {
        self.inner.flush()?;
        self.inner.compact()
    }

    fn query_exec(&self, req: &QueryRequest<'_>, exec: &QueryExecutor) -> Result<QueryResponse> {
        self.inner.query_luts_exec(req, None, exec)
    }

    fn query_with_luts_exec(
        &self,
        req: &QueryRequest<'_>,
        luts: &[f32],
        exec: &QueryExecutor,
    ) -> Result<QueryResponse> {
        self.inner.query_luts_exec(req, Some(luts), exec)
    }

    fn lut_signature(&self) -> Option<u64> {
        self.inner.pq.read().unwrap().as_ref().map(|pq| pq.signature())
    }

    fn compute_scan_luts(&self, queries: &[f32]) -> Option<Vec<f32>> {
        let pq = self.inner.pq.read().unwrap().clone()?;
        if queries.len() % self.inner.dim != 0 {
            return None;
        }
        Some(pq.compute_luts_batch(queries))
    }

    fn insert(&self, data: &[f32], ids: Option<&[i64]>) -> Result<Vec<i64>> {
        self.inner.insert(data, ids)
    }

    fn delete(&self, ids: &[i64]) -> Result<usize> {
        self.inner.delete(ids)
    }

    fn flush(&self) -> Result<()> {
        self.inner.flush()
    }

    fn compact(&self) -> Result<()> {
        self.inner.compact()
    }

    fn segment_stats(&self) -> Option<SegmentStats> {
        Some(self.inner.stats())
    }

    fn set_param(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "rerank" | "reservoir_factor" | "backend" => {
                let mut p = SearchParams::default();
                p.assign(key, value)?;
                let current = self.inner.fastscan.read().unwrap().clone();
                *self.inner.fastscan.write().unwrap() = p.fastscan(&current);
                Ok(())
            }
            _ => Err(Error::InvalidParameter(format!("unknown parameter {key}"))),
        }
    }

    fn describe(&self) -> String {
        let s = self.inner.stats();
        format!(
            "SEG(PQ{}x{}fs, d={}, n={}, segs={}, mem={}, tomb={})",
            self.inner.m,
            self.inner.width.bits(),
            self.inner.dim,
            self.ntotal(),
            s.segments,
            s.memtable_entries,
            s.tombstones,
        )
    }
}
