//! An immutable sealed segment: a packed fastscan code block plus the
//! external ids of its rows.
//!
//! A sealed segment is exactly the frozen layout the paper's kernels
//! assume — the same [`PackedCodes`] block an [`crate::index::IndexPq4FastScan`]
//! builds at `seal()`. The segmented index keeps the *unpacked* internal
//! code columns alongside the packed block: compaction concatenates
//! surviving rows' code columns across segments and re-packs once, and
//! persistence writes the columns verbatim (re-packing on load), so no
//! path ever has to reverse the SIMD interleave.

use crate::error::{Error, Result};
use crate::pq::{CodeWidth, PackedCodes};
use std::borrow::Cow;
use std::collections::HashSet;

/// One immutable segment of the stack: `n` rows, each with an external id
/// and `code_cols` internal code columns, packed for the fastscan kernels.
#[derive(Debug)]
pub struct SealedSegment {
    /// External ids, row order (kernel `labels` slice).
    pub ids: Vec<i64>,
    /// Unpacked internal code columns (`n × code_cols`). Empty for
    /// segments loaded zero-copy from a mapped v3 file — use
    /// [`SealedSegment::flat_codes`], which reverses the interleave on
    /// demand, wherever row-major columns are needed.
    pub codes: Vec<u8>,
    /// The kernel-ready packed block (heap-owned or a mapped window).
    pub packed: PackedCodes,
    /// Membership view of `ids` for O(1) tombstone admission checks.
    pub id_set: HashSet<i64>,
}

impl SealedSegment {
    /// Seal `ids` + unpacked `codes` (internal columns) into a packed
    /// segment. `user_m` is the *user-facing* sub-quantizer count the
    /// packer expects (for 8-bit codes each user sub-quantizer spans two
    /// internal columns). Empty segments are never built — the caller
    /// skips the flush instead.
    pub fn build(ids: Vec<i64>, codes: Vec<u8>, user_m: usize, width: CodeWidth) -> Result<Self> {
        if ids.is_empty() {
            return Err(Error::InvalidParameter("segment: refusing to seal 0 rows".into()));
        }
        let code_cols = width.code_columns(user_m);
        if codes.len() != ids.len() * code_cols {
            return Err(Error::InvalidParameter(format!(
                "segment: {} ids but {} code bytes (expected {} per row)",
                ids.len(),
                codes.len(),
                code_cols
            )));
        }
        let packed = PackedCodes::pack(&codes, user_m, width)?;
        let id_set: HashSet<i64> = ids.iter().copied().collect();
        Ok(Self { ids, codes, packed, id_set })
    }

    /// Adopt an already-packed block (a mapped region of a v3 index file,
    /// or a heap-loaded one) without materializing the row-major columns.
    /// The packed geometry must agree with the id count.
    pub fn from_packed(ids: Vec<i64>, packed: PackedCodes) -> Result<Self> {
        if ids.is_empty() {
            return Err(Error::InvalidParameter("segment: refusing to adopt 0 rows".into()));
        }
        if packed.n != ids.len() {
            return Err(Error::CorruptIndex(format!(
                "segment: {} ids but packed block holds {} rows",
                ids.len(),
                packed.n
            )));
        }
        let id_set: HashSet<i64> = ids.iter().copied().collect();
        Ok(Self { ids, codes: Vec::new(), packed, id_set })
    }

    /// Rows in this segment.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Number of internal code columns per row (from the packed geometry,
    /// which is present whether or not the flat columns are).
    pub fn code_cols(&self) -> usize {
        self.packed.m_codes
    }

    /// Row-major internal code columns (`n × code_cols`): borrowed when
    /// the segment kept them (built in-process), reconstructed from the
    /// packed block when it did not (mapped zero-copy load). Compaction
    /// and v2-era persistence go through this so they never care which.
    pub fn flat_codes(&self) -> Cow<'_, [u8]> {
        if self.codes.is_empty() && !self.ids.is_empty() {
            Cow::Owned(self.packed.unpack())
        } else {
            Cow::Borrowed(&self.codes[..])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_validates_shape() {
        // 4-bit, m=4: one internal column per user sub-quantizer
        let ids = vec![7, 8, 9];
        let codes = vec![1u8; 3 * 4];
        let seg = SealedSegment::build(ids, codes, 4, CodeWidth::W4).unwrap();
        assert_eq!(seg.len(), 3);
        assert_eq!(seg.code_cols(), 4);
        assert!(seg.id_set.contains(&8));
        assert_eq!(seg.packed.n, 3);

        assert!(SealedSegment::build(vec![], vec![], 4, CodeWidth::W4).is_err());
        assert!(SealedSegment::build(vec![1], vec![0u8; 3], 4, CodeWidth::W4).is_err());
    }

    #[test]
    fn from_packed_derives_flat_codes() {
        let ids: Vec<i64> = (0..10).collect();
        let codes: Vec<u8> = (0..10 * 4).map(|i| (i % 16) as u8).collect();
        let packed = PackedCodes::pack(&codes, 4, CodeWidth::W4).unwrap();
        let seg = SealedSegment::from_packed(ids, packed).unwrap();
        assert!(seg.codes.is_empty(), "adoption must not materialize columns");
        assert_eq!(seg.code_cols(), 4);
        assert_eq!(seg.flat_codes().as_ref(), &codes[..]);
        assert!(seg.id_set.contains(&9));
        // geometry disagreement is corrupt, not UB
        let packed2 = PackedCodes::pack(&codes, 4, CodeWidth::W4).unwrap();
        assert!(matches!(
            SealedSegment::from_packed(vec![1, 2], packed2).unwrap_err(),
            Error::CorruptIndex(_)
        ));
        let packed3 = PackedCodes::pack(&codes, 4, CodeWidth::W4).unwrap();
        assert!(SealedSegment::from_packed(vec![], packed3).is_err());
    }

    #[test]
    fn packed_roundtrips_codes() {
        let ids: Vec<i64> = (0..10).collect();
        let codes: Vec<u8> = (0..10 * 4).map(|i| (i % 16) as u8).collect();
        let seg = SealedSegment::build(ids, codes.clone(), 4, CodeWidth::W4).unwrap();
        for i in 0..10 {
            for c in 0..4 {
                assert_eq!(seg.packed.code_at(i, c), codes[i * 4 + c]);
            }
        }
    }
}
