//! An immutable sealed segment: a packed fastscan code block plus the
//! external ids of its rows.
//!
//! A sealed segment is exactly the frozen layout the paper's kernels
//! assume — the same [`PackedCodes`] block an [`crate::index::IndexPq4FastScan`]
//! builds at `seal()`. The segmented index keeps the *unpacked* internal
//! code columns alongside the packed block: compaction concatenates
//! surviving rows' code columns across segments and re-packs once, and
//! persistence writes the columns verbatim (re-packing on load), so no
//! path ever has to reverse the SIMD interleave.

use crate::error::{Error, Result};
use crate::pq::{CodeWidth, PackedCodes};
use std::collections::HashSet;

/// One immutable segment of the stack: `n` rows, each with an external id
/// and `code_cols` internal code columns, packed for the fastscan kernels.
#[derive(Debug)]
pub struct SealedSegment {
    /// External ids, row order (kernel `labels` slice).
    pub ids: Vec<i64>,
    /// Unpacked internal code columns (`n × code_cols`), kept for
    /// compaction and persistence.
    pub codes: Vec<u8>,
    /// The kernel-ready packed block.
    pub packed: PackedCodes,
    /// Membership view of `ids` for O(1) tombstone admission checks.
    pub id_set: HashSet<i64>,
}

impl SealedSegment {
    /// Seal `ids` + unpacked `codes` (internal columns) into a packed
    /// segment. `user_m` is the *user-facing* sub-quantizer count the
    /// packer expects (for 8-bit codes each user sub-quantizer spans two
    /// internal columns). Empty segments are never built — the caller
    /// skips the flush instead.
    pub fn build(ids: Vec<i64>, codes: Vec<u8>, user_m: usize, width: CodeWidth) -> Result<Self> {
        if ids.is_empty() {
            return Err(Error::InvalidParameter("segment: refusing to seal 0 rows".into()));
        }
        let code_cols = width.code_columns(user_m);
        if codes.len() != ids.len() * code_cols {
            return Err(Error::InvalidParameter(format!(
                "segment: {} ids but {} code bytes (expected {} per row)",
                ids.len(),
                codes.len(),
                code_cols
            )));
        }
        let packed = PackedCodes::pack(&codes, user_m, width)?;
        let id_set: HashSet<i64> = ids.iter().copied().collect();
        Ok(Self { ids, codes, packed, id_set })
    }

    /// Rows in this segment.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Number of internal code columns per row.
    pub fn code_cols(&self) -> usize {
        self.codes.len() / self.ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_validates_shape() {
        // 4-bit, m=4: one internal column per user sub-quantizer
        let ids = vec![7, 8, 9];
        let codes = vec![1u8; 3 * 4];
        let seg = SealedSegment::build(ids, codes, 4, CodeWidth::W4).unwrap();
        assert_eq!(seg.len(), 3);
        assert_eq!(seg.code_cols(), 4);
        assert!(seg.id_set.contains(&8));
        assert_eq!(seg.packed.n, 3);

        assert!(SealedSegment::build(vec![], vec![], 4, CodeWidth::W4).is_err());
        assert!(SealedSegment::build(vec![1], vec![0u8; 3], 4, CodeWidth::W4).is_err());
    }

    #[test]
    fn packed_roundtrips_codes() {
        let ids: Vec<i64> = (0..10).collect();
        let codes: Vec<u8> = (0..10 * 4).map(|i| (i % 16) as u8).collect();
        let seg = SealedSegment::build(ids, codes.clone(), 4, CodeWidth::W4).unwrap();
        for i in 0..10 {
            for c in 0..4 {
                assert_eq!(seg.packed.code_at(i, c), codes[i * 4 + c]);
            }
        }
    }
}
