//! The plan/execute query layer: one execution engine for every index,
//! now running on a **persistent worker pool**.
//!
//! Before this layer, each index (and the IVF layer and the coordinator
//! above them) improvised its own per-query buffers and its own loop over
//! the batch — allocation-heavy and single-threaded. And until the
//! persistent-runtime PR, even the parallel era spawned fresh
//! `std::thread::scope` threads per call. This module splits query
//! execution into four pieces with sharp ownership rules:
//!
//! * **[`QueryPlan`] / [`MaskPlan`]** — everything resolved *once per
//!   request*: effective parameters (per-request overrides folded over
//!   index defaults), the filter compiled into block-aligned kernel masks
//!   ([`MaskPlan`]: eager for flat indexes, lazy per inverted list for
//!   IVF), and the precomputed-LUT recipe. Read-only; shared by all
//!   participants. The flat fastscan index builds a [`QueryPlan`]
//!   wholesale; the IVF layer resolves the same ingredients (escalated
//!   probe width + [`MaskPlan`] + LUT slices) against its list-structured
//!   state.
//! * **[`ScanScratch`] / [`ScratchPool`]** — everything *per participant*:
//!   f32 LUT staging, quantized kernel-table bytes, reservoir/range
//!   candidate storage, re-rank heap + code buffers, the coarse probe
//!   list. Arenas are pooled, grown, never shrunk: after warmup the scan
//!   path performs **zero heap allocations** for its working set (the
//!   response rows are the only steady-state allocation).
//! * **[`pool::WorkerPool`]** — the threads themselves, spawned **once**
//!   per executor and kept for its lifetime: per-worker injector queues,
//!   work-stealing at single-unit granularity (a skewed IVF probe list no
//!   longer serializes behind the slowest static chunk), NUMA-aware
//!   placement from `/sys/devices/system/node`, optional core pinning via
//!   `sched_setaffinity` (`ARMPQ_PIN`). Scoped borrows ride the
//!   persistent threads through a claim/revoke job protocol — see the
//!   module docs of [`pool`] for the safety argument.
//! * **[`QueryExecutor`]** — the stateless engine: a thread budget + the
//!   worker pool + the scratch pool. Query batches fan out across
//!   participants ([`QueryExecutor::run_batch`]); a single large-`nprobe`
//!   IVF query fans its probed lists out instead
//!   ([`QueryExecutor::run_tasks`]); the sharded router fans shards out
//!   with node placement ([`QueryExecutor::run_shards`]). Executors are
//!   `Arc`-backed and shared — the coordinator threads one executor
//!   through every backend, shard and connection.
//!   [`QueryExecutor::new_scoped`] keeps the pre-pool per-call spawning
//!   alive as the differential baseline and bench comparison arm.
//!
//! # Why results cannot depend on the thread count (or the pool)
//!
//! Parallel helpers only distribute work. The per-item closures are pure
//! functions of the item index, results land in item order through
//! disjoint per-index output slots, and the IVF layer defines its
//! candidate set *per probed list* (each list scanned with its own
//! reservoir, merged in probe order through one final deterministic
//! selection) rather than through a cross-list threshold that would
//! depend on scan interleaving. Work-stealing moves *where* a unit runs,
//! never *what* it computes or *which slot* it fills. `ARMPQ_THREADS=1`
//! and `ARMPQ_THREADS=4`, pooled and scoped, therefore return
//! bit-identical results — enforced by the `threads_` integration tests
//! across every backend × width × query kind × filter.
//!
//! This preserves the PR-2 invariant from the other side: indexes stay
//! sealed `Arc<dyn Index>` values searched through `&self`, and the
//! executor holds no per-query state, so the pair is lock-free end to end
//! (the scratch pool's mutex is touched once per participant per fan-out,
//! never per code).

pub mod executor;
pub mod plan;
pub mod pool;
pub mod scan;
pub mod scratch;

pub use executor::QueryExecutor;
pub use plan::{MaskPlan, QueryPlan};
pub use pool::{NumaTopology, WorkerPool};
pub use scan::{range_packed, topk_packed};
pub use scratch::{ScanScratch, ScratchGuard, ScratchPool};
