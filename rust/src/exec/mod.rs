//! The plan/execute query layer: one execution engine for every index.
//!
//! Before this layer, each index (and the IVF layer and the coordinator
//! above them) improvised its own per-query buffers and its own loop over
//! the batch — allocation-heavy and single-threaded. This module splits
//! query execution into three pieces with sharp ownership rules:
//!
//! * **[`QueryPlan`] / [`MaskPlan`]** — everything resolved *once per
//!   request*: effective parameters (per-request overrides folded over
//!   index defaults), the filter compiled into block-aligned kernel masks
//!   ([`MaskPlan`]: eager for flat indexes, lazy per inverted list for
//!   IVF), and the precomputed-LUT recipe. Read-only; shared by all
//!   workers. The flat fastscan index builds a [`QueryPlan`] wholesale;
//!   the IVF layer resolves the same ingredients (escalated probe width +
//!   [`MaskPlan`] + LUT slices) against its list-structured state.
//! * **[`ScanScratch`] / [`ScratchPool`]** — everything *per worker*: f32
//!   LUT staging, quantized kernel-table bytes, reservoir/range candidate
//!   storage, re-rank heap + code buffers, the coarse probe list. Arenas
//!   are pooled, grown, never shrunk: after warmup the scan path performs
//!   **zero heap allocations** for its working set (the response rows are
//!   the only steady-state allocation).
//! * **[`QueryExecutor`]** — the stateless engine: a thread budget plus
//!   the scratch pool. Query batches fan out across workers
//!   ([`QueryExecutor::run_batch`]); a single large-`nprobe` IVF query
//!   fans its probed lists out instead ([`QueryExecutor::run_tasks`]).
//!   Executors are `Arc`-backed and shared — the coordinator threads one
//!   executor through every backend, shard and connection.
//!
//! # Why results cannot depend on the thread count
//!
//! Parallel helpers only distribute work. The per-item closures are pure
//! functions of the item index, results land in item order, and the IVF
//! layer defines its candidate set *per probed list* (each list scanned
//! with its own reservoir, merged in probe order through one final
//! deterministic selection) rather than through a cross-list threshold
//! that would depend on scan interleaving. `ARMPQ_THREADS=1` and
//! `ARMPQ_THREADS=4` therefore return bit-identical results — enforced by
//! the `threads_` integration tests across every backend × width × query
//! kind × filter.
//!
//! This preserves the PR-2 invariant from the other side: indexes stay
//! sealed `Arc<dyn Index>` values searched through `&self`, and the
//! executor holds no per-query state, so the pair is lock-free end to end
//! (the scratch pool's mutex is touched twice per worker-chunk, never per
//! code).

pub mod executor;
pub mod plan;
pub mod scan;
pub mod scratch;

pub use executor::QueryExecutor;
pub use plan::{MaskPlan, QueryPlan};
pub use scan::{range_packed, topk_packed};
pub use scratch::{ScanScratch, ScratchGuard, ScratchPool};
