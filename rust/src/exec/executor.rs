//! [`QueryExecutor`]: the stateless engine that runs a
//! [`crate::exec::QueryPlan`] across worker threads with pooled scratch.
//!
//! The executor owns exactly two things: a thread budget and a
//! [`ScratchPool`]. It holds **no query state** — plans are read-only,
//! scratch is per-worker — so one executor is safely shared by every
//! index, shard and server connection in the process (`Arc` inside,
//! `Clone` is cheap). [`QueryExecutor::global`] is the process-wide
//! default, sized by `ARMPQ_THREADS` / available parallelism.
//!
//! # Determinism
//!
//! `run_batch`/`run_tasks` only distribute work; the per-item closures are
//! pure functions of the item index (scratch is workspace, never carried
//! state), and results land in item order. Together with the per-list IVF
//! scan semantics (see [`crate::ivf`]) this makes query results
//! **bit-identical for every thread count** — `ARMPQ_THREADS=1` and `=4`
//! must (and do, see the `threads_` integration tests) return the same
//! bytes.

use super::scratch::{ScratchGuard, ScratchPool};
use crate::index::query::QueryStats;
use crate::util::threads::parallel_map_init;
use std::sync::{Arc, OnceLock};

#[derive(Debug)]
struct ExecInner {
    threads: usize,
    pool: ScratchPool,
}

/// Shared, stateless query engine: thread budget + scratch pool.
#[derive(Clone, Debug)]
pub struct QueryExecutor {
    inner: Arc<ExecInner>,
}

impl QueryExecutor {
    /// An executor with an explicit thread budget (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Self {
            inner: Arc::new(ExecInner {
                threads: threads.max(1),
                pool: ScratchPool::default(),
            }),
        }
    }

    /// The process-wide default executor (`ARMPQ_THREADS` overrides the
    /// host's available parallelism; resolved once at first use).
    pub fn global() -> &'static QueryExecutor {
        static GLOBAL: OnceLock<QueryExecutor> = OnceLock::new();
        GLOBAL.get_or_init(|| QueryExecutor::new(crate::util::threads::default_threads()))
    }

    /// Configured thread budget.
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Worker threads a fan-out of `n` items actually uses.
    pub fn threads_for(&self, n: usize) -> usize {
        self.inner.threads.min(n.max(1))
    }

    /// Scratch-arena high-water mark in bytes (see
    /// [`ScratchPool::high_water_bytes`]).
    pub fn scratch_high_water_bytes(&self) -> usize {
        self.inner.pool.high_water_bytes()
    }

    /// Check one scratch arena out for serial use (e.g. a small batch that
    /// parallelizes *inside* each query instead of across queries).
    pub fn checkout_scratch(&self) -> ScratchGuard<'_> {
        self.inner.pool.checkout()
    }

    /// Run `f(i, scratch)` for `i ∈ [0, n)` across the thread budget,
    /// collecting results in item order. Each worker checks exactly one
    /// scratch arena out of the pool for its whole chunk.
    pub fn run_batch<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut super::ScanScratch) -> T + Sync,
    {
        parallel_map_init(
            n,
            self.threads_for(n),
            || self.inner.pool.checkout(),
            |i, guard| f(i, &mut **guard),
        )
    }

    /// [`QueryExecutor::run_batch`] under its intra-query name: fan one
    /// query's independent scan tasks (e.g. probed IVF lists) out over the
    /// budget, results in task order.
    pub fn run_tasks<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut super::ScanScratch) -> T + Sync,
    {
        self.run_batch(n, f)
    }

    /// Stamp the concurrency facts into a response's stats: `width` is the
    /// fan-out width the call used (nq for batch fan-out, probe count for
    /// intra-query fan-out).
    pub fn stamp_stats(&self, stats: &mut [QueryStats], width: usize) {
        let threads_used = self.threads_for(width);
        let scratch_bytes = self.scratch_high_water_bytes();
        for s in stats {
            s.threads_used = threads_used;
            s.scratch_bytes = scratch_bytes;
        }
    }

    /// Diagnostic: arenas constructed over the pool's lifetime.
    pub fn scratch_arenas_created(&self) -> usize {
        self.inner.pool.arenas_created()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_batch_ordered_and_parallel() {
        let exec = QueryExecutor::new(4);
        let v = exec.run_batch(100, |i, _s| i * 3);
        assert_eq!(v, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(exec.threads(), 4);
        assert_eq!(exec.threads_for(2), 2);
        assert_eq!(exec.threads_for(0), 1);
    }

    #[test]
    fn scratch_pool_bounded_by_concurrency() {
        let exec = QueryExecutor::new(4);
        for _ in 0..8 {
            let _ = exec.run_batch(64, |i, s| {
                let mut v = s.take_items();
                v.push((i as u16, i as i64));
                s.put_items(v);
                i
            });
        }
        // at most one arena per worker slot, ever — reuse across calls
        assert!(
            exec.scratch_arenas_created() <= 4,
            "arenas {} > thread budget",
            exec.scratch_arenas_created()
        );
        assert!(exec.scratch_high_water_bytes() > 0);
    }

    #[test]
    fn clones_share_the_pool() {
        let a = QueryExecutor::new(2);
        let b = a.clone();
        let _ = a.run_batch(8, |i, _| i);
        let before = a.scratch_arenas_created();
        let _ = b.run_batch(8, |i, _| i);
        assert_eq!(b.scratch_arenas_created(), before, "clone built its own arenas");
    }

    #[test]
    fn global_is_singleton() {
        let a = QueryExecutor::global();
        let b = QueryExecutor::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.threads() >= 1);
    }

    #[test]
    fn stamp_stats_fills_concurrency_fields() {
        let exec = QueryExecutor::new(8);
        let mut stats = vec![QueryStats::default(); 3];
        exec.stamp_stats(&mut stats, 2);
        assert!(stats.iter().all(|s| s.threads_used == 2));
    }
}
