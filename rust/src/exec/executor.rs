//! [`QueryExecutor`]: the stateless engine that runs a
//! [`crate::exec::QueryPlan`] across a **persistent worker pool** with
//! pooled scratch.
//!
//! The executor owns exactly three things: a thread budget, a
//! [`ScratchPool`], and (in the default mode) a [`WorkerPool`] whose
//! threads are spawned once and live as long as the executor. It holds
//! **no query state** — plans are read-only, scratch is per-participant —
//! so one executor is safely shared by every index, shard and server
//! connection in the process (`Arc` inside, `Clone` is cheap).
//! [`QueryExecutor::global`] is the process-wide default, sized by
//! `ARMPQ_THREADS` / available parallelism and pinned when `ARMPQ_PIN` is
//! set.
//!
//! [`QueryExecutor::new_scoped`] builds the pre-pool executor — per-call
//! `std::thread::scope` threads with static chunking. It exists as the
//! differential baseline (bit-identity tests) and the bench comparison
//! arm (`run_thread_scaling`'s `scoped` rows); serving paths use the
//! pooled mode.
//!
//! # Determinism
//!
//! `run_batch`/`run_tasks` only distribute work; the per-item closures are
//! pure functions of the item index (scratch is workspace, never carried
//! state), and results land in item order through disjoint per-index
//! slots. Together with the per-list IVF scan semantics (see
//! [`crate::ivf`]) this makes query results **bit-identical for every
//! thread count, and for pooled vs scoped execution** — `ARMPQ_THREADS=1`
//! and `=4` must (and do, see the `threads_` integration tests) return
//! the same bytes, no matter which worker stole which unit.

use super::pool::{pin_from_env, WorkerPool};
use super::scratch::{ScratchGuard, ScratchPool};
use crate::index::query::QueryStats;
use crate::util::threads::{pool_map_placed, scoped_map_init};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

#[derive(Debug)]
struct ExecInner {
    threads: usize,
    pool: ScratchPool,
    /// `Some` = persistent-pool mode (the default); `None` = scoped
    /// per-call spawning (the differential/bench baseline).
    workers: Option<WorkerPool>,
    /// Participants of the most recent fan-out — actual pool accounting
    /// (submitter + helpers that executed units), feeding
    /// `QueryStats.threads_used`. Racy across concurrent batches by
    /// design: it is a stats gauge, never a correctness input.
    last_fanout: AtomicUsize,
}

/// Shared, stateless query engine: thread budget + worker pool + scratch.
#[derive(Clone, Debug)]
pub struct QueryExecutor {
    inner: Arc<ExecInner>,
}

static GLOBAL: OnceLock<QueryExecutor> = OnceLock::new();

impl QueryExecutor {
    /// An executor with an explicit thread budget (clamped to ≥ 1),
    /// backed by a persistent pool of `threads - 1` workers (the
    /// submitter is always the remaining participant). Workers pin to
    /// cores when `ARMPQ_PIN` is truthy.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        Self {
            inner: Arc::new(ExecInner {
                threads,
                pool: ScratchPool::default(),
                workers: Some(WorkerPool::new(threads - 1, pin_from_env())),
                last_fanout: AtomicUsize::new(0),
            }),
        }
    }

    /// The pre-pool executor: same thread budget, but fan-outs spawn
    /// scoped threads per call with static chunking. Baseline for the
    /// `threads_` bit-identity tests and the scoped-vs-pool bench rows.
    pub fn new_scoped(threads: usize) -> Self {
        Self {
            inner: Arc::new(ExecInner {
                threads: threads.max(1),
                pool: ScratchPool::default(),
                workers: None,
                last_fanout: AtomicUsize::new(0),
            }),
        }
    }

    /// The process-wide default executor (`ARMPQ_THREADS` overrides the
    /// host's available parallelism; resolved once at first use). Always
    /// pool-backed.
    pub fn global() -> &'static QueryExecutor {
        GLOBAL.get_or_init(|| QueryExecutor::new(crate::util::threads::default_threads()))
    }

    /// The global executor if something already forced its creation —
    /// lets the metrics exporter scrape pool gauges without spawning a
    /// pool as a side effect.
    pub fn global_get() -> Option<&'static QueryExecutor> {
        GLOBAL.get()
    }

    /// Configured thread budget.
    pub fn threads(&self) -> usize {
        self.inner.threads
    }

    /// Worker threads a fan-out of `n` items actually budgets for.
    pub fn threads_for(&self, n: usize) -> usize {
        self.inner.threads.min(n.max(1))
    }

    /// The persistent pool backing this executor (`None` in scoped mode).
    pub fn worker_pool(&self) -> Option<&WorkerPool> {
        self.inner.workers.as_ref()
    }

    /// Scratch-arena high-water mark in bytes (see
    /// [`ScratchPool::high_water_bytes`]).
    pub fn scratch_high_water_bytes(&self) -> usize {
        self.inner.pool.high_water_bytes()
    }

    /// Check one scratch arena out for serial use (e.g. a small batch that
    /// parallelizes *inside* each query instead of across queries).
    pub fn checkout_scratch(&self) -> ScratchGuard<'_> {
        self.inner.pool.checkout()
    }

    /// Run `f(i, scratch)` for `i ∈ [0, n)` across the thread budget,
    /// collecting results in item order. Each participant checks exactly
    /// one scratch arena out of the pool, lazily, for all the units it
    /// claims — so arenas stay bounded by the budget even though units are
    /// claimed one at a time (work-stealing granularity).
    pub fn run_batch<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut super::ScanScratch) -> T + Sync,
    {
        let threads = self.threads_for(n);
        match &self.inner.workers {
            Some(pool) if threads > 1 && n > 1 && pool.workers() > 0 => {
                let (out, participants) = pool_map_placed(
                    pool,
                    n,
                    threads,
                    |_| 0,
                    || self.inner.pool.checkout(),
                    |i, guard| f(i, &mut **guard),
                );
                self.inner.last_fanout.store(participants.max(1), Ordering::Relaxed);
                out
            }
            _ => {
                self.inner.last_fanout.store(threads, Ordering::Relaxed);
                scoped_map_init(
                    n,
                    threads,
                    || self.inner.pool.checkout(),
                    |i, guard| f(i, &mut **guard),
                )
            }
        }
    }

    /// [`QueryExecutor::run_batch`] under its intra-query name: fan one
    /// query's independent scan tasks (e.g. probed IVF lists, segment scan
    /// units) out over the budget, results in task order. On the pool,
    /// tasks are claimed one at a time, so a skewed task-length
    /// distribution no longer serializes behind the slowest static chunk.
    pub fn run_tasks<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut super::ScanScratch) -> T + Sync,
    {
        self.run_batch(n, f)
    }

    /// Fan `n` independent shard tasks out, one participant per shard at
    /// most, with NUMA placement: task `i` prefers a worker assigned to
    /// node `node_of(i)` and is stolen cross-node only when that node's
    /// work is drained. No scan scratch involved (shards own their own
    /// executors' scratch); results in task order. Scoped mode spawns one
    /// scoped thread per shard — the pre-pool router behavior.
    pub fn run_shards<T, P, F>(&self, n: usize, node_of: P, f: F) -> Vec<T>
    where
        T: Send,
        P: Fn(usize) -> usize,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        match &self.inner.workers {
            Some(pool) if n > 1 && pool.workers() > 0 => {
                let (out, participants) =
                    pool_map_placed(pool, n, n, node_of, || (), |i, _| f(i));
                self.inner.last_fanout.store(participants.max(1), Ordering::Relaxed);
                out
            }
            _ => scoped_map_init(n, n, || (), |i, _: &mut ()| f(i)),
        }
    }

    /// Stamp the concurrency facts into a response's stats: `width` is the
    /// fan-out width the call used (nq for batch fan-out, probe count for
    /// intra-query fan-out). `threads_used` reports the *measured*
    /// participant count of the fan-out when the pool recorded one — real
    /// accounting, not the configured budget — clamped to the budget.
    pub fn stamp_stats(&self, stats: &mut [QueryStats], width: usize) {
        let budget = self.threads_for(width);
        let measured = self.inner.last_fanout.load(Ordering::Relaxed);
        let threads_used = if measured == 0 { budget } else { measured.min(budget) };
        let scratch_bytes = self.scratch_high_water_bytes();
        for s in stats {
            s.threads_used = threads_used;
            s.scratch_bytes = scratch_bytes;
        }
    }

    /// Diagnostic: arenas constructed over the pool's lifetime.
    pub fn scratch_arenas_created(&self) -> usize {
        self.inner.pool.arenas_created()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_batch_ordered_and_parallel() {
        let exec = QueryExecutor::new(4);
        let v = exec.run_batch(100, |i, _s| i * 3);
        assert_eq!(v, (0..100).map(|i| i * 3).collect::<Vec<_>>());
        assert_eq!(exec.threads(), 4);
        assert_eq!(exec.threads_for(2), 2);
        assert_eq!(exec.threads_for(0), 1);
        assert_eq!(exec.worker_pool().map(|p| p.workers()), Some(3));
    }

    #[test]
    fn scratch_pool_bounded_by_concurrency() {
        let exec = QueryExecutor::new(4);
        for _ in 0..8 {
            let _ = exec.run_batch(64, |i, s| {
                let mut v = s.take_items();
                v.push((i as u16, i as i64));
                s.put_items(v);
                i
            });
        }
        // at most one arena per participant slot, ever — reuse across calls
        assert!(
            exec.scratch_arenas_created() <= 4,
            "arenas {} > thread budget",
            exec.scratch_arenas_created()
        );
        assert!(exec.scratch_high_water_bytes() > 0);
    }

    #[test]
    fn clones_share_the_pool() {
        let a = QueryExecutor::new(2);
        let b = a.clone();
        let _ = a.run_batch(8, |i, _| i);
        let before = a.scratch_arenas_created();
        let _ = b.run_batch(8, |i, _| i);
        assert_eq!(b.scratch_arenas_created(), before, "clone built its own arenas");
    }

    #[test]
    fn global_is_singleton() {
        let a = QueryExecutor::global();
        let b = QueryExecutor::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.threads() >= 1);
        assert!(a.worker_pool().is_some(), "global executor must be pool-backed");
        assert!(QueryExecutor::global_get().is_some());
    }

    #[test]
    fn stamp_stats_fills_concurrency_fields() {
        let exec = QueryExecutor::new(8);
        let mut stats = vec![QueryStats::default(); 3];
        exec.stamp_stats(&mut stats, 2);
        // no fan-out ran yet: the budget is reported, clamped by width
        assert!(stats.iter().all(|s| s.threads_used == 2));
        let _ = exec.run_batch(64, |i, _s| i);
        exec.stamp_stats(&mut stats, 64);
        // after a real fan-out: measured participants, within the budget
        assert!(stats.iter().all(|s| s.threads_used >= 1 && s.threads_used <= 8));
    }

    /// Tentpole differential: pooled and scoped executors return identical
    /// bytes for the same batch at every thread count.
    #[test]
    fn exec_pool_matches_scoped_executor_bit_identical() {
        let work = |i: usize| ((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15), i as u32);
        for &t in &[1usize, 2, 4] {
            let pooled = QueryExecutor::new(t);
            let scoped = QueryExecutor::new_scoped(t);
            let a = pooled.run_batch(73, |i, _s| work(i));
            let b = scoped.run_batch(73, |i, _s| work(i));
            assert_eq!(a, b, "divergence at threads={t}");
        }
    }

    #[test]
    fn exec_run_shards_ordered_with_placement() {
        let exec = QueryExecutor::new(3);
        let v = exec.run_shards(5, |i| i % 2, |i| i * 10);
        assert_eq!(v, vec![0, 10, 20, 30, 40]);
        // scoped mode takes the per-shard spawn path
        let scoped = QueryExecutor::new_scoped(3);
        let v = scoped.run_shards(5, |i| i % 2, |i| i * 10);
        assert_eq!(v, vec![0, 10, 20, 30, 40]);
    }
}
