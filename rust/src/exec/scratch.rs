//! Per-thread scan scratch arenas.
//!
//! Every buffer the per-query scan path needs — the f32 LUT staging, the
//! quantized [`crate::pq::fastscan::KernelLuts`] bytes, reservoir/range
//! candidate storage, the re-rank heap and code-gather buffers, the coarse
//! probe list — lives in one [`ScanScratch`] arena. Arenas are checked out
//! of a [`ScratchPool`] (one per in-flight worker), **grown but never
//! shrunk**, and returned on drop, so after warmup the steady-state scan
//! path performs zero heap allocations: every `take_*` hands out a cleared
//! buffer whose capacity survived the previous query.
//!
//! The take/put discipline (move the `Vec` out, use it, move it back)
//! instead of long-lived `&mut` borrows keeps the borrow checker out of
//! the hot path: a worker can hold the LUT buffer *and* hand the rest of
//! the scratch to a helper at the same time.

use crate::obs::TraceBuf;
use crate::pq::bitwidth::WidthLutsBuf;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One worker's reusable scan workspace. All buffers start empty and grow
/// to the index's working-set shape on first use.
#[derive(Debug, Default)]
pub struct ScanScratch {
    /// Per-query f32 ADC table (`m_codes × sub_ksub`).
    luts_f32: Vec<f32>,
    /// Quantized + kernel-arranged table storage (see
    /// [`crate::pq::bitwidth::build_width_luts_with`]).
    wl_buf: WidthLutsBuf,
    /// Reservoir / range-collection candidate storage.
    items: Vec<(u16, i64)>,
    /// IVF merged-candidate staging (per-list results, probe order).
    merged: Vec<(u16, i64)>,
    /// Re-rank top-k heap storage.
    heap: Vec<(f32, i64)>,
    /// Re-rank code gather buffer (`m_codes` bytes).
    codes: Vec<u8>,
    /// Coarse-quantizer probe list.
    probes: Vec<usize>,
    /// Per-query trace span accumulator (inline slots — adds nothing to
    /// the heap footprint; disabled unless the query asked for a trace).
    trace: TraceBuf,
}

macro_rules! take_put {
    ($take:ident, $put:ident, $field:ident, $t:ty) => {
        #[doc = concat!("Take the `", stringify!($field), "` buffer (cleared, capacity kept).")]
        pub fn $take(&mut self) -> $t {
            let mut v = std::mem::take(&mut self.$field);
            v.clear();
            v
        }
        #[doc = concat!("Return the `", stringify!($field), "` buffer for reuse.")]
        pub fn $put(&mut self, v: $t) {
            self.$field = v;
        }
    };
}

impl ScanScratch {
    take_put!(take_luts, put_luts, luts_f32, Vec<f32>);
    take_put!(take_items, put_items, items, Vec<(u16, i64)>);
    take_put!(take_merged, put_merged, merged, Vec<(u16, i64)>);
    take_put!(take_heap, put_heap, heap, Vec<(f32, i64)>);
    take_put!(take_codes, put_codes, codes, Vec<u8>);
    take_put!(take_probes, put_probes, probes, Vec<usize>);

    /// The width-LUT staging buffers (used in place, not taken: the built
    /// [`crate::pq::bitwidth::WidthLuts`] owns them until recycled).
    pub fn wl_buf_mut(&mut self) -> &mut WidthLutsBuf {
        &mut self.wl_buf
    }

    /// The per-query trace accumulator (read side: ambient scan phase,
    /// enabled check, span timer construction).
    pub fn trace(&self) -> &TraceBuf {
        &self.trace
    }

    /// The per-query trace accumulator (record side: enable, span
    /// recording, drain-at-end). Pooled arenas always come back with the
    /// buffer drained and disabled, so an untraced query never pays for a
    /// traced predecessor.
    pub fn trace_mut(&mut self) -> &mut TraceBuf {
        &mut self.trace
    }

    /// Bytes currently reserved by this arena (capacity accounting; the
    /// pool folds this into its high-water mark on check-in).
    pub fn reserved_bytes(&self) -> usize {
        use std::mem::size_of;
        self.luts_f32.capacity() * size_of::<f32>()
            + self.wl_buf.reserved_bytes()
            + self.items.capacity() * size_of::<(u16, i64)>()
            + self.merged.capacity() * size_of::<(u16, i64)>()
            + self.heap.capacity() * size_of::<(f32, i64)>()
            + self.codes.capacity()
            + self.probes.capacity() * size_of::<usize>()
    }
}

/// A pool of [`ScanScratch`] arenas, one checked out per in-flight worker.
/// In steady state the pool holds as many arenas as the executor's peak
/// concurrency and `checkout` never constructs a new one.
#[derive(Debug, Default)]
pub struct ScratchPool {
    arenas: Mutex<Vec<ScanScratch>>,
    /// Largest `reserved_bytes` ever checked back in.
    high_water: AtomicUsize,
    /// Arenas constructed over the pool's lifetime (a reuse diagnostic:
    /// stable after warmup).
    created: AtomicUsize,
}

impl ScratchPool {
    /// Check an arena out (reusing a pooled one when available).
    pub fn checkout(&self) -> ScratchGuard<'_> {
        let scratch = match self.arenas.lock().unwrap().pop() {
            Some(s) => s,
            None => {
                self.created.fetch_add(1, Ordering::Relaxed);
                ScanScratch::default()
            }
        };
        ScratchGuard { pool: self, scratch: Some(scratch) }
    }

    fn restore(&self, mut scratch: ScanScratch) {
        // An error path may bail between enable and drain; never park an
        // armed trace where the next (untraced) checkout would feed it.
        scratch.trace.disarm();
        self.high_water.fetch_max(scratch.reserved_bytes(), Ordering::Relaxed);
        self.arenas.lock().unwrap().push(scratch);
    }

    /// Largest arena footprint (bytes) observed so far — the
    /// `scratch_bytes` figure surfaced through `QueryStats` and the
    /// coordinator's `stats` verb.
    pub fn high_water_bytes(&self) -> usize {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Total arenas ever constructed (not currently pooled — ever).
    pub fn arenas_created(&self) -> usize {
        self.created.load(Ordering::Relaxed)
    }

    /// Arenas currently parked in the pool.
    pub fn arenas_idle(&self) -> usize {
        self.arenas.lock().unwrap().len()
    }
}

/// RAII checkout of one [`ScanScratch`]: derefs to the arena, returns it
/// to the pool on drop (also on unwind).
pub struct ScratchGuard<'p> {
    pool: &'p ScratchPool,
    scratch: Option<ScanScratch>,
}

impl Deref for ScratchGuard<'_> {
    type Target = ScanScratch;
    fn deref(&self) -> &ScanScratch {
        self.scratch.as_ref().expect("scratch present until drop")
    }
}

impl DerefMut for ScratchGuard<'_> {
    fn deref_mut(&mut self) -> &mut ScanScratch {
        self.scratch.as_mut().expect("scratch present until drop")
    }
}

impl Drop for ScratchGuard<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.scratch.take() {
            self.pool.restore(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_arenas() {
        let pool = ScratchPool::default();
        {
            let mut g = pool.checkout();
            let mut v = g.take_luts();
            v.resize(1024, 0.0);
            g.put_luts(v);
        }
        assert_eq!(pool.arenas_created(), 1);
        assert_eq!(pool.arenas_idle(), 1);
        assert!(pool.high_water_bytes() >= 1024 * 4);
        // the second checkout reuses the grown arena: same capacity back
        {
            let mut g = pool.checkout();
            let v = g.take_luts();
            assert!(v.is_empty());
            assert!(v.capacity() >= 1024, "capacity lost across checkouts");
            g.put_luts(v);
        }
        assert_eq!(pool.arenas_created(), 1, "pool allocated a second arena");
    }

    #[test]
    fn concurrent_checkouts_get_distinct_arenas() {
        let pool = ScratchPool::default();
        let a = pool.checkout();
        let b = pool.checkout();
        drop(a);
        drop(b);
        assert_eq!(pool.arenas_created(), 2);
        assert_eq!(pool.arenas_idle(), 2);
    }

    #[test]
    fn take_put_roundtrip_keeps_capacity() {
        let mut s = ScanScratch::default();
        let mut items = s.take_items();
        items.reserve(777);
        let cap = items.capacity();
        s.put_items(items);
        let again = s.take_items();
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap);
    }
}
