//! Persistent worker pool: the serving runtime underneath every fan-out.
//!
//! Before this module existed, every parallel helper in
//! [`crate::util::threads`] spawned fresh `std::thread::scope` threads per
//! call. That is correct — the scoped borrow checker proves it — but it puts
//! thread creation on the query path and, worse, it commits to a static
//! chunking of the work up front: with skewed IVF probe lists one chunk can
//! hold all the long lists and the other threads idle behind it.
//!
//! [`WorkerPool`] fixes both. Workers are spawned **once** (owned by
//! [`crate::exec::QueryExecutor`]), optionally pinned to cores, and fed by
//! per-worker injector queues with work-stealing. Parallel calls submit
//! *helper jobs* that all run the same claiming body over a shared unit
//! cursor, so load balance is decided unit-by-unit at run time rather than
//! chunk-by-chunk at submit time.
//!
//! ## How scoped borrows ride a persistent pool
//!
//! The old helpers could close over stack data because `std::thread::scope`
//! joins before returning. A persistent pool gets the same guarantee from a
//! small state machine per helper job:
//!
//! ```text
//!   Pending ──worker claims──▶ Claimed ──body returns──▶ Done
//!      │
//!      └────submitter revokes──▶ Revoked   (body never dereferenced)
//! ```
//!
//! [`WorkerPool::run`] erases the body's lifetime into a raw pointer, posts
//! the jobs, runs the body inline itself, then **settles**: every job still
//! `Pending` is flipped to `Revoked` (its pointer is never dereferenced),
//! and every `Claimed` job is waited out on its condvar. `run` therefore
//! never returns — not even by panic, thanks to a drop guard — while any
//! worker can still touch the caller's stack. That is the entire safety
//! argument; everything else is ordinary queueing.
//!
//! ## Determinism
//!
//! The pool decides only *which participant* executes a unit, never what
//! the unit computes: unit bodies are pure functions of the unit index that
//! write to disjoint, index-keyed output slots. Any claim order therefore
//! produces bit-identical results — the same invariant the scoped helpers
//! upheld, now independent of queue timing and steals.
//!
//! ## NUMA
//!
//! [`NumaTopology::detect`] parses `/sys/devices/system/node/node*/cpulist`
//! (single-node fallback elsewhere). Workers are assigned nodes round-robin
//! and, when pinning is enabled (`ARMPQ_PIN=1` or `--pin`), bound to a cpu
//! of their node via a hand-declared `sched_setaffinity` wrapper — a no-op
//! off Linux, same libc idiom as `storage/mmap.rs`. [`WorkerPool::run_units_placed`]
//! buckets units by a caller-supplied node hint; each participant drains its
//! own node's bucket first and steals cross-node only when local work runs
//! dry, so sharded routers get NUMA-local scans without giving up progress.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long an idle worker sleeps between queue re-checks. Submitters
/// notify the condvar on every post, so this is only a shutdown/steal
/// latency backstop, not the wakeup path.
const IDLE_TICK: Duration = Duration::from_millis(20);

// ---------------------------------------------------------------------------
// process-global counters (the `storage::counters()` pattern)
// ---------------------------------------------------------------------------

/// Monotone process-global pool counters, folded across every pool the
/// process creates (tests, the global executor, explicit executors). The
/// coordinator's metrics snapshot these into `armpq_pool_*` families.
pub struct PoolCounters {
    /// Helper jobs executed by a worker other than the one they were
    /// queued on — the work-stealing rate.
    pub steals: AtomicU64,
    /// Helper jobs executed by pool workers (submitter-inline work is not
    /// counted: it never crossed a queue).
    pub tasks_executed: AtomicU64,
}

/// The process-global [`PoolCounters`] instance.
pub fn counters() -> &'static PoolCounters {
    static COUNTERS: OnceLock<PoolCounters> = OnceLock::new();
    COUNTERS.get_or_init(|| PoolCounters {
        steals: AtomicU64::new(0),
        tasks_executed: AtomicU64::new(0),
    })
}

// ---------------------------------------------------------------------------
// NUMA topology
// ---------------------------------------------------------------------------

/// One NUMA node: its sysfs id and the cpus it owns.
#[derive(Debug, Clone)]
pub struct NumaNode {
    pub id: usize,
    pub cpus: Vec<usize>,
}

/// The machine's NUMA layout, discovered from sysfs on Linux and collapsed
/// to a single node holding every cpu elsewhere (or when sysfs is absent,
/// e.g. in minimal containers).
#[derive(Debug, Clone)]
pub struct NumaTopology {
    pub nodes: Vec<NumaNode>,
}

impl NumaTopology {
    /// Discover the topology from `/sys/devices/system/node`.
    pub fn detect() -> NumaTopology {
        Self::from_sysfs(Path::new("/sys/devices/system/node"))
    }

    /// Parse a sysfs node directory; testable with a fake root.
    pub(crate) fn from_sysfs(root: &Path) -> NumaTopology {
        let mut nodes = Vec::new();
        if let Ok(rd) = std::fs::read_dir(root) {
            for entry in rd.flatten() {
                let name = entry.file_name().to_string_lossy().into_owned();
                let Some(id) = name.strip_prefix("node").and_then(|s| s.parse::<usize>().ok())
                else {
                    continue;
                };
                let Ok(list) = std::fs::read_to_string(entry.path().join("cpulist")) else {
                    continue;
                };
                let cpus = parse_cpulist(&list);
                if !cpus.is_empty() {
                    nodes.push(NumaNode { id, cpus });
                }
            }
        }
        nodes.sort_by_key(|n| n.id);
        if nodes.is_empty() {
            let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            nodes.push(NumaNode { id: 0, cpus: (0..ncpu).collect() });
        }
        NumaTopology { nodes }
    }

    /// Number of nodes (always ≥ 1).
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Interleave `n` shards (or workers) across nodes round-robin,
    /// returning one node *index* (0..node_count) per item.
    pub fn interleave(&self, n: usize) -> Vec<usize> {
        (0..n).map(|i| i % self.nodes.len()).collect()
    }
}

/// The process-global detected topology.
pub fn topology() -> &'static NumaTopology {
    static TOPO: OnceLock<NumaTopology> = OnceLock::new();
    TOPO.get_or_init(NumaTopology::detect)
}

/// Parse a sysfs cpulist (`"0-3,8,10-11"`) into a sorted, deduped cpu set.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for part in s.trim().split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('-') {
            Some((a, b)) => {
                if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                    for c in a..=b.min(a + 4096) {
                        cpus.push(c);
                    }
                }
            }
            None => {
                if let Ok(c) = part.parse::<usize>() {
                    cpus.push(c);
                }
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

// ---------------------------------------------------------------------------
// core pinning (Linux sched_setaffinity, no-op elsewhere)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    // Hand-declared like `storage/mmap.rs`'s madvise/mincore: std already
    // links libc, so an extern block is all a no-new-crates build needs.
    extern "C" {
        pub fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
}

/// Bit capacity of the affinity mask we pass to the kernel (1024 cpus,
/// glibc's default `cpu_set_t` size).
const CPU_MASK_WORDS: usize = 16;

/// Pin the calling thread to `cpu`. Returns whether the kernel accepted
/// the mask; always `false` (and side-effect free) off Linux or for cpus
/// beyond the mask capacity.
pub fn pin_current_thread(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        if cpu >= CPU_MASK_WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; CPU_MASK_WORDS];
        mask[cpu / 64] |= 1u64 << (cpu % 64);
        // pid 0 = the calling thread; the mask is read, never written.
        unsafe { sys::sched_setaffinity(0, CPU_MASK_WORDS * 8, mask.as_ptr()) == 0 }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

/// Whether worker pinning was requested via `ARMPQ_PIN` (truthy:
/// `1`/`true`/`yes`). The `--pin` serve flag sets this variable so the
/// lazily-created global executor observes it.
pub fn pin_from_env() -> bool {
    std::env::var("ARMPQ_PIN")
        .map(|v| matches!(v.trim(), "1" | "true" | "yes"))
        .unwrap_or(false)
}

// ---------------------------------------------------------------------------
// helper jobs
// ---------------------------------------------------------------------------

enum JobState {
    /// Queued; nobody has touched the body pointer.
    Pending,
    /// A worker is executing the body *right now* — the submitter must wait.
    Claimed,
    /// The submitter finished first; the body pointer must never be
    /// dereferenced. The job husk drains from its queue harmlessly.
    Revoked,
    /// The body ran to completion (or unwound); the pointer is dead again.
    Done,
}

struct HelperJob {
    /// Lifetime-erased pointer to the submitting call's `body` closure.
    /// Only dereferenced between `Pending → Claimed` and `→ Done`, and the
    /// submitter's settle loop outlives every such window, so the pointee
    /// is always alive when read.
    body: *const (dyn Fn(usize) + Sync),
    state: Mutex<JobState>,
    cv: Condvar,
    panicked: AtomicBool,
}

// SAFETY: the raw pointer is only dereferenced under the state-machine
// protocol documented on `body` and in the module docs; the pointee is
// `Sync`, so shared execution from worker threads is sound.
unsafe impl Send for HelperJob {}
unsafe impl Sync for HelperJob {}

/// Flip still-pending jobs to `Revoked`, wait out `Claimed` ones.
/// Returns (jobs that ran to `Done`, whether any of them panicked).
fn settle_jobs(jobs: &[Arc<HelperJob>]) -> (usize, bool) {
    let mut helped = 0;
    let mut panicked = false;
    for job in jobs {
        let mut st = job.state.lock().unwrap();
        loop {
            match *st {
                JobState::Pending => {
                    *st = JobState::Revoked;
                    break;
                }
                JobState::Claimed => st = job.cv.wait(st).unwrap(),
                JobState::Done => {
                    helped += 1;
                    break;
                }
                JobState::Revoked => break,
            }
        }
        drop(st);
        panicked |= job.panicked.load(Ordering::Acquire);
    }
    (helped, panicked)
}

/// Settles on drop so a panicking submitter body can never unwind past
/// jobs that still hold a pointer into its stack frame.
struct SettleOnDrop<'a> {
    jobs: &'a [Arc<HelperJob>],
    armed: bool,
}

impl Drop for SettleOnDrop<'_> {
    fn drop(&mut self) {
        if self.armed {
            settle_jobs(self.jobs);
        }
    }
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

struct PoolShared {
    /// Per-worker injector queues; worker `t` pops `queues[t]` first and
    /// steals from the others in ring order.
    queues: Vec<Mutex<VecDeque<Arc<HelperJob>>>>,
    /// Jobs currently sitting in queues (the `pool_queue_depth` gauge).
    queued: AtomicUsize,
    /// Sleep lock + condvar for idle workers; submitters notify after
    /// bumping `queued` so wakeups cannot be lost.
    sleep: Mutex<()>,
    wake: Condvar,
    shutdown: AtomicBool,
    /// Node *index* (0..topology().node_count()) each worker belongs to.
    worker_nodes: Vec<usize>,
    /// Nanoseconds each worker has spent executing bodies, for the
    /// busy-fraction gauges.
    busy_ns: Vec<AtomicU64>,
    started: Instant,
    pin: bool,
}

impl PoolShared {
    fn pop_job(&self, t: usize) -> Option<(Arc<HelperJob>, bool)> {
        let nw = self.queues.len();
        for d in 0..nw {
            let w = (t + d) % nw;
            let mut q = self.queues[w].lock().unwrap();
            if let Some(job) = q.pop_front() {
                drop(q);
                self.queued.fetch_sub(1, Ordering::AcqRel);
                return Some((job, w != t));
            }
        }
        None
    }

    fn execute(&self, t: usize, job: &HelperJob, stolen: bool) {
        {
            let mut st = job.state.lock().unwrap();
            match *st {
                JobState::Pending => *st = JobState::Claimed,
                // Revoked husk: the submitter already returned; drop it.
                _ => return,
            }
        }
        let c = counters();
        c.tasks_executed.fetch_add(1, Ordering::Relaxed);
        if stolen {
            c.steals.fetch_add(1, Ordering::Relaxed);
        }
        let start = Instant::now();
        // SAFETY: state is Claimed, so the submitter's settle loop is
        // blocked on our condvar and the pointee outlives this call.
        let body = unsafe { &*job.body };
        let result = catch_unwind(AssertUnwindSafe(|| body(self.worker_nodes[t])));
        self.busy_ns[t].fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if result.is_err() {
            job.panicked.store(true, Ordering::Release);
        }
        let mut st = job.state.lock().unwrap();
        *st = JobState::Done;
        drop(st);
        job.cv.notify_all();
    }

    fn worker_main(self: &Arc<Self>, t: usize) {
        if self.pin {
            let topo = topology();
            let node = &topo.nodes[self.worker_nodes[t] % topo.nodes.len()];
            let nnodes = topo.nodes.len();
            let cpu = node.cpus[(t / nnodes.max(1)) % node.cpus.len()];
            let _ = pin_current_thread(cpu);
        }
        loop {
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            match self.pop_job(t) {
                Some((job, stolen)) => self.execute(t, &job, stolen),
                None => {
                    let guard = self.sleep.lock().unwrap();
                    if self.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    if self.queued.load(Ordering::Acquire) == 0 {
                        let _ = self.wake.wait_timeout(guard, IDLE_TICK);
                    }
                }
            }
        }
    }
}

/// Point-in-time pool state for the metrics exporter.
#[derive(Debug, Clone, Default)]
pub struct PoolSnapshot {
    pub workers: usize,
    pub queue_depth: usize,
    /// Per-worker busy time as permille of the pool's lifetime.
    pub busy_permille: Vec<u64>,
}

/// A persistent set of worker threads. `workers` may be 0, in which case
/// every [`run`](WorkerPool::run) executes inline on the submitter — the
/// natural shape for `threads = 1` executors.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// Round-robin cursor over worker queues for fresh submissions.
    rr: AtomicUsize,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads, assigned to NUMA nodes
    /// round-robin and pinned to a cpu of their node when `pin` is set.
    pub fn new(workers: usize, pin: bool) -> WorkerPool {
        let topo = topology();
        let worker_nodes: Vec<usize> = (0..workers).map(|t| t % topo.node_count()).collect();
        let shared = Arc::new(PoolShared {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: AtomicUsize::new(0),
            sleep: Mutex::new(()),
            wake: Condvar::new(),
            shutdown: AtomicBool::new(false),
            worker_nodes,
            busy_ns: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            started: Instant::now(),
            pin,
        });
        let handles = (0..workers)
            .map(|t| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("armpq-worker-{t}"))
                    .spawn(move || sh.worker_main(t))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles: Mutex::new(handles), rr: AtomicUsize::new(0) }
    }

    /// Number of persistent workers (excludes submitters).
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }

    /// Jobs currently queued and unclaimed.
    pub fn queue_depth(&self) -> usize {
        self.shared.queued.load(Ordering::Acquire)
    }

    /// Node index each worker is assigned to.
    pub fn worker_nodes(&self) -> &[usize] {
        &self.shared.worker_nodes
    }

    /// Gauge snapshot for the metrics exporter.
    pub fn snapshot(&self) -> PoolSnapshot {
        let elapsed = self.shared.started.elapsed().as_nanos().max(1) as u64;
        PoolSnapshot {
            workers: self.workers(),
            queue_depth: self.queue_depth(),
            busy_permille: self
                .shared
                .busy_ns
                .iter()
                .map(|b| (b.load(Ordering::Relaxed).saturating_mul(1000) / elapsed).min(1000))
                .collect(),
        }
    }

    /// Run `body` on up to `parallelism` participants (the submitter plus
    /// at most `parallelism - 1` helper jobs). Every participant receives
    /// its NUMA node index; the submitter reports node 0. Returns how many
    /// participants actually executed the body — helpers that were revoked
    /// before a worker claimed them don't count.
    ///
    /// `body` must be safe to run concurrently with itself (`Sync`) and
    /// must not depend on *which* participants run: the pool guarantees at
    /// least one execution (the submitter's) and at most `parallelism`.
    pub fn run(&self, parallelism: usize, body: &(dyn Fn(usize) + Sync)) -> usize {
        let helpers = parallelism.saturating_sub(1).min(self.workers());
        if helpers == 0 {
            body(0);
            return 1;
        }
        // SAFETY: lifetime erasure only; the settle protocol (see module
        // docs) keeps every dereference within `body`'s real lifetime.
        let raw: *const (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(body)
        };
        let jobs: Vec<Arc<HelperJob>> = (0..helpers)
            .map(|_| {
                Arc::new(HelperJob {
                    body: raw,
                    state: Mutex::new(JobState::Pending),
                    cv: Condvar::new(),
                    panicked: AtomicBool::new(false),
                })
            })
            .collect();
        let start = self.rr.fetch_add(helpers, Ordering::Relaxed);
        for (h, job) in jobs.iter().enumerate() {
            let w = (start + h) % self.workers();
            self.shared.queues[w].lock().unwrap().push_back(Arc::clone(job));
            self.shared.queued.fetch_add(1, Ordering::Release);
        }
        {
            let _guard = self.shared.sleep.lock().unwrap();
            self.shared.wake.notify_all();
        }
        let mut guard = SettleOnDrop { jobs: &jobs, armed: true };
        body(0);
        guard.armed = false;
        drop(guard);
        let (helped, panicked) = settle_jobs(&jobs);
        if panicked {
            panic!("worker pool task panicked");
        }
        1 + helped
    }

    /// Work-stealing unit loop: run `f(i, &mut state)` exactly once for
    /// every `i in 0..n`, with units claimed one at a time off a shared
    /// cursor so no participant serializes behind a statically-assigned
    /// chunk. `init` runs lazily, once per participant that claims at
    /// least one unit (≤ `parallelism` times). Returns the number of
    /// participants that executed units.
    ///
    /// Determinism contract: `f` must be a pure function of `i` writing to
    /// disjoint per-`i` destinations, so claim order cannot change results.
    pub fn run_units<S, I, F>(&self, n: usize, parallelism: usize, init: I, f: F) -> usize
    where
        I: Fn() -> S + Sync,
        F: Fn(usize, &mut S) + Sync,
    {
        self.run_units_placed(n, parallelism, |_| 0, init, f)
    }

    /// [`run_units`](WorkerPool::run_units) with NUMA placement: units are
    /// bucketed by `node_of(i) % node_count`, and each participant drains
    /// its own node's bucket before stealing cross-node, so same-node work
    /// is preferred but the pool never idles while any unit remains.
    pub fn run_units_placed<P, S, I, F>(
        &self,
        n: usize,
        parallelism: usize,
        node_of: P,
        init: I,
        f: F,
    ) -> usize
    where
        P: Fn(usize) -> usize,
        I: Fn() -> S + Sync,
        F: Fn(usize, &mut S) + Sync,
    {
        if n == 0 {
            return 0;
        }
        let parallelism = parallelism.max(1).min(n);
        if parallelism <= 1 || self.workers() == 0 {
            let mut state = init();
            for i in 0..n {
                f(i, &mut state);
            }
            return 1;
        }
        let nnodes = topology().node_count();
        let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); nnodes];
        for i in 0..n {
            buckets[node_of(i) % nnodes].push(i);
        }
        let cursors: Vec<AtomicUsize> = (0..nnodes).map(|_| AtomicUsize::new(0)).collect();
        let worked = AtomicUsize::new(0);
        let body = |my_node: usize| {
            let mut state: Option<S> = None;
            loop {
                let mut unit = None;
                for d in 0..nnodes {
                    let nd = (my_node + d) % nnodes;
                    let c = cursors[nd].fetch_add(1, Ordering::Relaxed);
                    if c < buckets[nd].len() {
                        unit = Some(buckets[nd][c]);
                        break;
                    }
                }
                match unit {
                    Some(i) => {
                        let st = match state.as_mut() {
                            Some(st) => st,
                            None => {
                                worked.fetch_add(1, Ordering::Relaxed);
                                state.get_or_insert_with(&init)
                            }
                        };
                        f(i, st);
                    }
                    None => break,
                }
            }
        };
        self.run(parallelism, &body);
        worked.load(Ordering::Relaxed).max(1)
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers())
            .field("queue_depth", &self.queue_depth())
            .field("pin", &self.shared.pin)
            .finish()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _guard = self.shared.sleep.lock().unwrap();
            self.shared.wake.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn exec_pool_cpulist_parses_ranges_and_singles() {
        assert_eq!(parse_cpulist("0-3,8,10-11\n"), vec![0, 1, 2, 3, 8, 10, 11]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("2,2,1-2"), vec![1, 2]);
    }

    #[test]
    fn exec_pool_topology_has_at_least_one_node_with_cpus() {
        let topo = NumaTopology::detect();
        assert!(topo.node_count() >= 1);
        assert!(topo.nodes.iter().all(|n| !n.cpus.is_empty()));
        let placement = topo.interleave(7);
        assert_eq!(placement.len(), 7);
        assert!(placement.iter().all(|&nd| nd < topo.node_count()));
    }

    #[test]
    fn exec_pool_units_each_run_exactly_once() {
        let pool = WorkerPool::new(3, false);
        let n = 257;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let participants =
            pool.run_units(n, 4, || (), |i, _| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        assert!(participants >= 1 && participants <= 4);
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn exec_pool_placed_units_cover_all_nodes() {
        let pool = WorkerPool::new(2, false);
        let n = 64;
        let hits: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        // fake a 4-way placement; node_of is folded mod real node count
        pool.run_units_placed(n, 3, |i| i % 4, || (), |i, _| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn exec_pool_inline_when_no_workers() {
        let pool = WorkerPool::new(0, false);
        let ran = AtomicU32::new(0);
        let participants = pool.run(8, &|_node| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(participants, 1);
        assert_eq!(ran.load(Ordering::Relaxed), 1);
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn exec_pool_init_runs_at_most_once_per_participant() {
        let pool = WorkerPool::new(3, false);
        let inits = AtomicU32::new(0);
        let pool_participants = pool.run_units(
            100,
            4,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
            },
            |_i, _s| std::thread::yield_now(),
        );
        let inits = inits.load(Ordering::Relaxed);
        assert!(inits >= 1 && inits as usize <= 4, "inits={inits}");
        assert_eq!(inits as usize, pool_participants);
    }

    #[test]
    fn exec_pool_counters_and_snapshot_move() {
        let pool = WorkerPool::new(2, false);
        let before = counters().tasks_executed.load(Ordering::Relaxed);
        for _ in 0..8 {
            pool.run_units(64, 3, || (), |_i, _s| {
                std::thread::yield_now();
            });
        }
        // Helpers may all be revoked under extreme scheduling, so don't
        // assert growth — only monotonicity and a well-formed snapshot.
        assert!(counters().tasks_executed.load(Ordering::Relaxed) >= before);
        let snap = pool.snapshot();
        assert_eq!(snap.workers, 2);
        assert_eq!(snap.busy_permille.len(), 2);
        assert!(snap.busy_permille.iter().all(|&p| p <= 1000));
    }

    #[test]
    fn exec_pool_shutdown_joins_cleanly() {
        let pool = WorkerPool::new(4, false);
        pool.run_units(32, 4, || (), |_i, _s| {});
        drop(pool); // Drop joins every worker; hanging here fails the test
    }

    #[test]
    // No `expected`: the panic surfaces as "unit 7 exploded" when the
    // submitter claims unit 7 inline, or as the pool's "worker pool task
    // panicked" when a helper hit it first. Either way `run` must unwind.
    #[should_panic]
    fn exec_pool_panic_in_unit_propagates_to_submitter() {
        let pool = WorkerPool::new(2, false);
        pool.run_units(16, 3, || (), |i, _s| {
            if i == 7 {
                panic!("unit 7 exploded");
            }
        });
    }
}
