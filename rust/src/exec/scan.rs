//! Scratch-aware scan cores: the flat-index top-k and range scans,
//! re-expressed over a [`ScanScratch`] arena so the steady-state path
//! allocates nothing but its output row.
//!
//! These are drop-in equivalents of
//! [`crate::pq::fastscan::topk_fastscan_with_luts`] /
//! [`crate::pq::fastscan::range_fastscan_with_luts`] — same candidate
//! admission, same re-rank order, bit-identical hits (asserted by the
//! differential tests below); only the buffer lifetimes differ.

use super::scratch::ScanScratch;
use crate::index::query::Hit;
use crate::obs::Phase;
use crate::pq::bitwidth::build_width_luts_with;
use crate::pq::codebook::ProductQuantizer;
use crate::pq::fastscan::{scan_filtered_counted, FastScanParams, FilterMask, ScanSink};
use crate::pq::layout::PackedCodes;
use crate::util::topk::{TopK, U16Reservoir};

/// Filtered top-k over one packed code set, allocation-free after warmup:
/// the `k` best `(distance, label)` pairs among admitted positions,
/// ascending, unpadded. `filter` is in position space; `labels` renames
/// results only (identity when `None`).
#[allow(clippy::too_many_arguments)]
pub fn topk_packed(
    pq: &ProductQuantizer,
    packed: &PackedCodes,
    luts_f32: &[f32],
    k: usize,
    fs: &FastScanParams,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    scratch: &mut ScanScratch,
) -> Vec<Hit> {
    if k == 0 {
        return Vec::new();
    }
    let t_lut = scratch.trace().start();
    let wl = build_width_luts_with(luts_f32, packed.m, packed.width, scratch.wl_buf_mut());
    scratch.trace_mut().finish(Phase::LutBuild, t_lut);
    // Scan with identity labels so the reservoir carries *scan positions*;
    // external labels are applied after re-ranking (positions are
    // unambiguous — duplicate external labels never collide).
    let t_scan = scratch.trace().start();
    let mut reservoir = U16Reservoir::from_storage(k, fs.reservoir_factor, scratch.take_items());
    let counts = {
        let mut sink = ScanSink::TopK(&mut reservoir);
        scan_filtered_counted(packed, &wl.kernel, fs.backend, None, filter, &mut sink)
    };
    let cands = reservoir.into_candidates();
    let scan_phase = scratch.trace().scan_phase();
    scratch.trace_mut().finish_with(
        scan_phase,
        t_scan,
        counts.codes as u64,
        counts.mapped_bytes as u64,
    );

    let label_of = |pos: i64| labels.map(|l| l[pos as usize]).unwrap_or(pos);
    let t_rerank = scratch.trace().start();
    let n_cands = cands.len() as u64;
    let mut heap = TopK::from_storage(k, scratch.take_heap());
    if fs.rerank {
        let mut codes_buf = scratch.take_codes();
        codes_buf.resize(pq.m, 0);
        for &(_, pos) in &cands {
            let i = pos as usize;
            for (q, slot) in codes_buf.iter_mut().enumerate() {
                *slot = packed.code_at(i, q);
            }
            heap.push(pq.adc_distance(luts_f32, &codes_buf), label_of(pos));
        }
        scratch.put_codes(codes_buf);
    } else {
        for &(d16, pos) in &cands {
            heap.push(wl.qluts.decode(d16), label_of(pos));
        }
    }
    let row: Vec<Hit> = heap
        .as_sorted_hits()
        .iter()
        .map(|&(distance, label)| Hit { distance, label })
        .collect();
    scratch.put_items(cands);
    scratch.put_heap(heap.into_storage());
    wl.recycle(scratch.wl_buf_mut());
    scratch.trace_mut().finish_with(Phase::Rerank, t_rerank, n_cands, 0);
    row
}

/// Filtered range query over one packed code set, allocation-free after
/// warmup: every `(distance, label)` with distance `<= radius`, ascending
/// by `(distance, label)`. Same quantized collection bound + exact trim
/// semantics as [`crate::pq::fastscan::range_fastscan_with_luts`].
#[allow(clippy::too_many_arguments)]
pub fn range_packed(
    pq: &ProductQuantizer,
    packed: &PackedCodes,
    luts_f32: &[f32],
    radius: f32,
    fs: &FastScanParams,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    scratch: &mut ScanScratch,
) -> Vec<Hit> {
    let t_lut = scratch.trace().start();
    let wl = build_width_luts_with(luts_f32, packed.m, packed.width, scratch.wl_buf_mut());
    let bound = wl.qluts.collection_bound(radius, fs.rerank);
    scratch.trace_mut().finish(Phase::LutBuild, t_lut);
    let t_scan = scratch.trace().start();
    let mut raw = scratch.take_items();
    let counts = {
        let mut sink = ScanSink::Range { bound, hits: &mut raw };
        scan_filtered_counted(packed, &wl.kernel, fs.backend, None, filter, &mut sink)
    };
    let scan_phase = scratch.trace().scan_phase();
    scratch.trace_mut().finish_with(
        scan_phase,
        t_scan,
        counts.codes as u64,
        counts.mapped_bytes as u64,
    );
    let label_of = |pos: i64| labels.map(|l| l[pos as usize]).unwrap_or(pos);
    let t_rerank = scratch.trace().start();
    let n_raw = raw.len() as u64;
    let mut hits: Vec<Hit> = if fs.rerank {
        let mut codes_buf = scratch.take_codes();
        codes_buf.resize(pq.m, 0);
        let mut out = Vec::with_capacity(raw.len());
        for &(_, pos) in &raw {
            let i = pos as usize;
            for (q, slot) in codes_buf.iter_mut().enumerate() {
                *slot = packed.code_at(i, q);
            }
            let d = pq.adc_distance(luts_f32, &codes_buf);
            if d <= radius {
                out.push(Hit { distance: d, label: label_of(pos) });
            }
        }
        scratch.put_codes(codes_buf);
        out
    } else {
        raw.iter()
            .map(|&(d16, pos)| Hit { distance: wl.qluts.decode(d16), label: label_of(pos) })
            .collect()
    };
    hits.sort_by(|a, b| {
        a.distance
            .partial_cmp(&b.distance)
            .unwrap()
            .then(a.label.cmp(&b.label))
    });
    scratch.put_items(raw);
    wl.recycle(scratch.wl_buf_mut());
    scratch.trace_mut().finish_with(Phase::Rerank, t_rerank, n_raw, 0);
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::bitwidth::CodeWidth;
    use crate::pq::fastscan::{range_fastscan_with_luts, topk_fastscan_with_luts};
    use crate::simd::available_backends;
    use crate::util::rng::Rng;

    fn fixture(n: usize, m: usize, width: CodeWidth, seed: u64) -> (ProductQuantizer, PackedCodes, Vec<f32>) {
        let dim = 32;
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian()).collect();
        let pq = ProductQuantizer::train(&data, dim, &width.pq_params(m)).unwrap();
        let codes = pq.encode(&data).unwrap();
        let packed = PackedCodes::pack(&codes, m, width).unwrap();
        let luts = pq.compute_luts(&data[..dim]);
        (pq, packed, luts)
    }

    /// The scratch cores must match the allocating kernels bit for bit —
    /// every width, every backend, rerank on/off, filtered and not.
    #[test]
    fn scratch_scans_match_allocating_kernels() {
        for width in CodeWidth::ALL {
            let (pq, packed, luts) = fixture(300, 8, width, 900 + width.bits() as u64);
            let mask = FilterMask::from_fn(packed.n, |p| p % 3 != 0);
            let mut scratch = ScanScratch::default();
            for backend in available_backends() {
                for rerank in [true, false] {
                    let fs = FastScanParams { backend, rerank, reservoir_factor: 6 };
                    for filter in [None, Some(&mask)] {
                        let want = topk_fastscan_with_luts(
                            &pq, &packed, &luts, 7, &fs, None, filter,
                        );
                        let got =
                            topk_packed(&pq, &packed, &luts, 7, &fs, None, filter, &mut scratch);
                        let got_pairs: Vec<(f32, i64)> =
                            got.iter().map(|h| (h.distance, h.label)).collect();
                        assert_eq!(got_pairs, want, "{width} {backend:?} rerank={rerank}");

                        let radius = want.get(3).map(|&(d, _)| d).unwrap_or(1.0);
                        let want_r = range_fastscan_with_luts(
                            &pq, &packed, &luts, radius, &fs, None, filter,
                        );
                        let got_r = range_packed(
                            &pq, &packed, &luts, radius, &fs, None, filter, &mut scratch,
                        );
                        let got_pairs: Vec<(f32, i64)> =
                            got_r.iter().map(|h| (h.distance, h.label)).collect();
                        assert_eq!(got_pairs, want_r, "{width} {backend:?} rerank={rerank}");
                    }
                }
            }
        }
    }

    /// Scratch-reuse / zero-allocation acceptance: after one warmup query
    /// the arena's buffers never move or grow again across many queries of
    /// the same shape — i.e. the steady-state scan path performs no heap
    /// allocation for its working set.
    #[test]
    fn steady_state_scan_does_not_grow_scratch() {
        let (pq, packed, _) = fixture(400, 8, CodeWidth::W4, 901);
        let dim = 32;
        let mut rng = Rng::new(902);
        let queries: Vec<f32> = (0..20 * dim).map(|_| rng.next_gaussian()).collect();
        let fs = FastScanParams::default();
        let mut scratch = ScanScratch::default();
        let mut lbuf = scratch.take_luts();
        // warmup at the workload's maximal shape: same k, and a radius
        // admitting the whole corpus (the range buffer's largest form)
        pq.compute_luts_into(&queries[..dim], &mut lbuf);
        let _ = topk_packed(&pq, &packed, &lbuf, 10, &fs, None, None, &mut scratch);
        let _ = range_packed(&pq, &packed, &lbuf, 1e9, &fs, None, None, &mut scratch);
        scratch.put_luts(lbuf);
        let warm_bytes = scratch.reserved_bytes();
        let warm_lut_ptr = {
            let l = scratch.take_luts();
            let p = l.as_ptr();
            scratch.put_luts(l);
            p
        };
        // steady state: same-shape queries must not grow (or move) buffers
        for qi in 0..20 {
            let mut lbuf = scratch.take_luts();
            pq.compute_luts_into(&queries[qi * dim..(qi + 1) * dim], &mut lbuf);
            assert_eq!(lbuf.as_ptr(), warm_lut_ptr, "LUT buffer reallocated");
            let _ = topk_packed(&pq, &packed, &lbuf, 10, &fs, None, None, &mut scratch);
            let _ = range_packed(&pq, &packed, &lbuf, 1e9, &fs, None, None, &mut scratch);
            scratch.put_luts(lbuf);
            assert_eq!(
                scratch.reserved_bytes(),
                warm_bytes,
                "scratch grew after warmup at query {qi}"
            );
        }
    }
}
