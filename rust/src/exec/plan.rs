//! [`QueryPlan`]: everything about a request that is resolved **once**,
//! before any worker runs.
//!
//! A plan owns no query-dependent state: per-request parameter overrides
//! are folded into concrete values ([`QueryPlan::fs`], [`QueryPlan::nprobe`]
//! — already selectivity-escalated by the index), the filter is compiled
//! into block-aligned kernel masks ([`MaskPlan`]), and precomputed batch
//! LUTs are sliced per query. Workers read the plan immutably from any
//! thread; everything mutable lives in their
//! [`crate::exec::ScanScratch`] arenas.

use crate::index::query::{Filter, QueryKind};
use crate::pq::fastscan::{FastScanParams, FilterMask};
use std::sync::OnceLock;

/// The compiled filter of a plan.
///
/// * Flat indexes compile the filter into one position-space mask over the
///   whole packed set, eagerly (it is shared by every query of the batch).
/// * Unit-structured indexes compile one mask per scan unit — an IVF
///   inverted list, or a sealed segment / memtable of a
///   [`crate::segment::SegmentedIndex`] (where the unit mask also folds in
///   the tombstone set) — lazily through a `OnceLock` per unit, so only
///   scanned units pay and concurrent workers build each mask at most once
///   and share it without locks on the read path.
#[derive(Debug, Default)]
pub enum MaskPlan {
    /// No filter on this request.
    #[default]
    None,
    /// One mask over the whole scan domain (flat indexes).
    Flat(FilterMask),
    /// One lazily-built mask per scan unit (IVF list, or segment of a
    /// segmented index).
    Lists(Vec<OnceLock<FilterMask>>),
}

impl MaskPlan {
    /// Compile a flat-domain mask (position space over `n` with optional
    /// label mapping happening inside `Filter::build_mask`).
    pub fn flat(filter: &Filter, n: usize) -> Self {
        MaskPlan::Flat(filter.build_mask(None, n))
    }

    /// Lazy per-unit slots for an index with `nlist` scan units (IVF
    /// lists, or segments + memtable of a segmented index).
    pub fn lists(nlist: usize) -> Self {
        MaskPlan::Lists((0..nlist).map(|_| OnceLock::new()).collect())
    }

    /// The flat mask, if this plan carries one.
    pub fn flat_mask(&self) -> Option<&FilterMask> {
        match self {
            MaskPlan::Flat(m) => Some(m),
            _ => None,
        }
    }

    /// The mask of list `c`, building it on first use (`build` runs at
    /// most once per list across all workers).
    pub fn list_mask(&self, c: usize, build: impl FnOnce() -> FilterMask) -> Option<&FilterMask> {
        match self {
            MaskPlan::Lists(slots) => Some(slots[c].get_or_init(build)),
            MaskPlan::Flat(m) => Some(m),
            MaskPlan::None => None,
        }
    }
}

/// A request resolved into an executable form: what to compute (kind),
/// how to scan (resolved kernel parameters), who may answer (compiled
/// filter masks), and where per-query LUTs come from.
///
/// Built once per `query` call by the owning index, then shared read-only
/// across the executor's workers. The flat fastscan index builds one
/// wholesale; the IVF layer resolves the same ingredients (escalated
/// probe width, a lazy [`MaskPlan`], the LUT recipe) against its
/// list-structured state and threads them through
/// `IvfPq4::query_exec_with` directly — same plan-once discipline, no
/// field carried that a worker does not read.
#[derive(Debug)]
pub struct QueryPlan<'r> {
    /// Row-major query batch and its geometry.
    pub queries: &'r [f32],
    pub dim: usize,
    pub nq: usize,
    pub kind: QueryKind,
    /// Kernel parameters with per-request overrides already applied.
    pub fs: FastScanParams,
    /// Compiled filter masks.
    pub masks: MaskPlan,
    /// Precomputed per-query scan LUTs (`nq × lut_len`) from a
    /// signature-equal index, if the coordinator supplied them.
    pub luts: Option<&'r [f32]>,
    /// Length of one query's LUT row (`m_codes × sub_ksub`).
    pub lut_len: usize,
}

impl<'r> QueryPlan<'r> {
    /// Query `qi`'s precomputed LUT slice, if the plan carries batch LUTs.
    #[inline]
    pub fn luts_for(&self, qi: usize) -> Option<&'r [f32]> {
        self.luts.map(|ls| &ls[qi * self.lut_len..(qi + 1) * self.lut_len])
    }

    /// Query `qi`'s vector.
    #[inline]
    pub fn query(&self, qi: usize) -> &'r [f32] {
        &self.queries[qi * self.dim..(qi + 1) * self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::query::Filter;

    #[test]
    fn flat_mask_compiles_once_per_plan() {
        let f = Filter::id_range(2, 6);
        let plan = MaskPlan::flat(&f, 10);
        let m = plan.flat_mask().unwrap();
        assert_eq!(m.pass_count(), 4);
        assert!(m.passes(2) && !m.passes(6));
    }

    #[test]
    fn list_masks_build_lazily_and_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let plan = MaskPlan::lists(4);
        let builds = AtomicUsize::new(0);
        for _ in 0..3 {
            let m = plan
                .list_mask(1, || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    FilterMask::from_fn(8, |p| p % 2 == 0)
                })
                .unwrap();
            assert_eq!(m.pass_count(), 4);
        }
        assert_eq!(builds.load(Ordering::SeqCst), 1, "mask rebuilt");
        // untouched lists never build
        if let MaskPlan::Lists(slots) = &plan {
            assert!(slots[0].get().is_none());
        }
    }

    #[test]
    fn no_filter_means_no_masks() {
        let plan = MaskPlan::None;
        assert!(plan.flat_mask().is_none());
        assert!(plan.list_mask(0, || unreachable!()).is_none());
    }
}
