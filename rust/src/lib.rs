//! # armpq — ARM 4-bit PQ: SIMD-based ANN search (paper reproduction)
//!
//! Reproduction of *"ARM 4-bit PQ: SIMD-based Acceleration for Approximate
//! Nearest Neighbor Search on ARM"* (Matsui et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's contribution — bundling **two 128-bit SIMD registers into one
//! virtual 256-bit register** so that the 4-bit-PQ lookup table stays
//! register-resident — lives in [`simd`] (the dual-lane register model plus
//! real SSSE3 and real ARM NEON backends) and [`pq::fastscan`] (the scan
//! kernel built on it). Everything the paper
//! depends on is implemented here as well: k-means training ([`kmeans`]),
//! product quantization ([`pq`]), inverted indexing ([`ivf`]), HNSW coarse
//! quantization ([`hnsw`]), dataset synthesis and IO ([`datasets`]),
//! evaluation ([`eval`]), a PJRT runtime that executes the AOT-compiled
//! JAX/Pallas artifacts ([`runtime`]) and a batching query coordinator
//! ([`coordinator`]).
//!
//! ## Quickstart
//!
//! Indexes have a mutable **build** phase (`train` → `add` → `seal`) and
//! an immutable **query** phase: [`index::Index::query`] takes `&self`
//! and a typed [`index::QueryRequest`] — top-k or radius search,
//! optionally filtered by an id set/range/predicate (evaluated *inside*
//! the SIMD kernels), with per-request [`index::SearchParams`] — so a
//! sealed index can be shared behind `Arc<dyn Index>` and queried from
//! many threads concurrently.
//!
//! Seal is no longer the end of the story, though: it is the *per-segment*
//! contract. The segmented index ([`segment`], factory `"SEG,PQ16x4fs"`)
//! keeps taking [`index::Index::insert`] and [`index::Index::delete`]
//! after — and while — queries run, by layering a small exact-scanned
//! memtable and tombstone masks over a stack of sealed segments, with a
//! background worker flushing and compacting the stack back toward one
//! sealed segment. The frozen-layout kernels, the lock-free `Arc<dyn
//! Index>` sharing, and the bit-identical determinism below all survive
//! unchanged; they just apply per segment.
//!
//! ```no_run
//! use armpq::index::{Filter, Index, QueryRequest, SearchParams, factory};
//! use armpq::datasets::synthetic::SyntheticDataset;
//! use std::sync::Arc;
//!
//! let ds = SyntheticDataset::sift_like(10_000, 100, 123);
//! // build phase (&mut): train, add, then seal once
//! let mut index = factory::index_factory(ds.dim, "IVF100,PQ16x4fs").unwrap();
//! index.train(&ds.train).unwrap();
//! index.add(&ds.base).unwrap();
//! index.seal().unwrap();
//! // query phase (&self): read-only, tunable and filterable per request
//! let req = QueryRequest::top_k(&ds.queries, 10)
//!     .with_filter(Filter::id_range(0, 5_000))
//!     .with_params(SearchParams::new().with_nprobe(16));
//! let resp = index.query(&req).unwrap();
//! println!("top-1 of q0 = {:?} ({} codes scanned)",
//!     resp.hits[0].first(), resp.stats[0].codes_scanned);
//! // radius search: every id within 1.5 (L2-squared)
//! let near = index.query(&QueryRequest::range(&ds.queries, 1.5)).unwrap();
//! // the legacy fixed-shape API is a thin shim over query()
//! let result = index.search(&ds.queries, 10, None).unwrap();
//! println!("top-1 of q0 = {}", result.labels[0]);
//! // share across threads lock-free
//! let shared: Arc<dyn Index> = Arc::from(index);
//! let handle = {
//!     let shared = shared.clone();
//!     let q = ds.queries.clone();
//!     std::thread::spawn(move || shared.search(&q, 10, None).unwrap())
//! };
//! # let _ = (near, handle);
//! ```
//!
//! The string-keyed `set_param(key, value)` API survives as a
//! compatibility shim that parses into the same typed struct; prefer
//! passing [`index::SearchParams`] per call. Likewise `search` survives
//! as a padded-top-k shim over `query`.
//!
//! ## Execution model: plan once, execute on pooled scratch
//!
//! Under `query` sits the plan/execute layer ([`exec`]). Each request is
//! resolved **once** into a plan — effective parameters, the filter
//! compiled into block-aligned kernel masks, the precomputed-LUT recipe —
//! and then executed by a [`exec::QueryExecutor`]: a stateless engine
//! holding only a thread budget and a pool of per-worker
//! [`exec::ScanScratch`] arenas (LUT buffers, reservoirs, re-rank
//! staging — grown, never shrunk, **zero heap allocations** in the
//! steady-state scan path). Query batches fan out across workers; a
//! single large-`nprobe` IVF query fans its probed lists out instead, so
//! one query can use the whole socket.
//!
//! The division of state is what keeps this safe and reproducible:
//! sealed indexes are immutable `Arc<dyn Index>` values (the PR-2
//! invariant), plans are read-only, and all mutation lives in scratch
//! arenas owned by exactly one worker at a time — no locks on the query
//! path. Because the IVF candidate set is defined per probed list and
//! merged deterministically, results are **bit-identical for every
//! thread count** (`ARMPQ_THREADS=1` vs `=4` differ only in wall-clock).
//! [`index::Index::query`] runs on the process-global executor;
//! the coordinator threads one shared executor through every backend and
//! shard, and reports `threads_used` / scratch high-water through
//! [`index::QueryStats`] and the `stats` verb.
//!
//! ## Storage: zero-copy mapped indexes
//!
//! Index files use the page-aligned **format v3** ([`index::io`]): packed
//! code regions are 64-byte-aligned inside the file, so a loader can
//! memory-map the file once and hand every region to the kernels in
//! place. The ownership split lives in [`storage`]: a
//! [`storage::CodeStore`] is either `Owned` heap bytes (the default, and
//! what v1/v2 files still load into) or a `Mapped` window into a shared
//! [`storage::Mmap`] — cloning a mapped store bumps an `Arc`, the page
//! cache shares the bytes across processes, and a
//! [`storage::MemoryBudget`] (`mmap=true,budget_mb=…` in the factory
//! string) decides how much of the file to advise resident up front.
//! The scan loop prefetches the next probed list one list ahead
//! ([`storage::prefetch_span`]) to hide page-in latency behind the
//! current list's arithmetic.
//!
//! ## Code widths
//!
//! The fastscan kernel is generalized over code width
//! ([`pq::CodeWidth`], Quicker-ADC style): `"PQ16x2fs"` scans 2-bit codes
//! about twice as fast as the paper's `"PQ16x4fs"` at lower recall, and
//! `"PQ16x8fs"` spends 8 bits per sub-quantizer for higher recall at
//! about twice the cost — all three on the same dual-lane register model
//! and composable with IVF (`"IVF100,PQ16x2fs,nprobe=8"`).

pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod error;
pub mod eval;
pub mod exec;
pub mod experiments;
pub mod hnsw;
pub mod index;
pub mod ivf;
pub mod kmeans;
pub mod lab;
pub mod obs;
pub mod pq;
pub mod runtime;
pub mod segment;
pub mod simd;
pub mod storage;
pub mod util;

pub use error::{Error, Result};
