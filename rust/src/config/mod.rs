//! Configuration system: layered `key = value` files + CLI overrides.
//!
//! Benches, examples and the serving coordinator all read an
//! [`ExperimentConfig`]; precedence is *defaults < config file < CLI*.
//! The file format is a flat INI-subset (comments with `#`, sections
//! ignored into key prefixes: `[server]` + `port = 1` → `server.port`).

use crate::index::SearchParams;
use crate::pq::CodeWidth;
use crate::simd::Backend;
use crate::storage::OpenOptions;
use crate::util::args::Args;
use crate::{Error, Result};
use std::collections::BTreeMap;

/// A parsed configuration: flat string map with typed getters.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `key = value` text (INI-subset).
    pub fn from_str(text: &str) -> Result<Self> {
        let mut values = BTreeMap::new();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(section) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                prefix = format!("{}.", section.trim());
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value, got {raw:?}", lineno + 1))
            })?;
            values.insert(format!("{prefix}{}", k.trim()), v.trim().to_string());
        }
        Ok(Self { values })
    }

    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {}: {e}", path.display())))?;
        Self::from_str(&text)
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Overlay another config (its values win).
    pub fn merge(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse()
                .map_err(|_| Error::Config(format!("{key} expects integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|_| Error::Config(format!("{key} expects number, got {v:?}")))
            }
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key} expects bool, got {v:?}"))),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

/// The shared experiment configuration used by benches and examples.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// "sift" or "deep".
    pub dataset: String,
    pub n: usize,
    pub nq: usize,
    pub seed: u64,
    /// Index factory string.
    pub factory: String,
    pub k: usize,
    pub nprobe: usize,
    /// Whether `nprobe` was given explicitly (CLI flag or config key)
    /// rather than inherited from the built-in default — explicit values
    /// become per-request overrides, implicit ones must not shadow index
    /// defaults (e.g. a factory string's trailing `nprobe=8`).
    pub nprobe_explicit: bool,
    /// Timed trials per measurement (paper: 5).
    pub trials: usize,
    /// Fastscan kernel backend override (`portable` / `ssse3` / `neon`);
    /// `None` keeps the host's [`crate::simd::best_backend`].
    pub backend: Option<Backend>,
    /// Fastscan code width for the kernel benches (`--width 2|4|8`; first
    /// entry when a sweep list was given). Index width selection goes
    /// through the factory string (`PQ16x2fs`); this knob drives the
    /// `kernel_micro`/`ablation_layout` width axis.
    pub width: CodeWidth,
    /// The full `--width` sweep list (`"2,4,8"`), CLI or config file —
    /// what the bench commands iterate. Single-element when a scalar (or
    /// nothing) was given.
    pub widths: Vec<CodeWidth>,
    /// Open saved index files memory-mapped (`--mmap` / `mmap = true`);
    /// `None` means "not given" so a factory string's own `mmap=true`
    /// trailing key is not overridden by the built-in default.
    pub mmap: Option<bool>,
    /// Residency budget in MiB for mapped opens (`--budget-mb` /
    /// `budget_mb = 512`).
    pub budget_mb: Option<u64>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: "sift".into(),
            n: 100_000,
            nq: 100,
            seed: 20_220_501, // paper's arXiv month, for flavor
            factory: "PQ16x4fs".into(),
            k: 10,
            nprobe: 4,
            nprobe_explicit: false,
            trials: 5,
            backend: None,
            width: CodeWidth::W4,
            widths: vec![CodeWidth::W4],
            mmap: None,
            budget_mb: None,
        }
    }
}

impl ExperimentConfig {
    /// The typed per-request [`SearchParams`] this config implies — the
    /// CLI `--nprobe`/`--backend` flags and config keys land in the same
    /// struct the `set_param` shim parses into, so every surface shares
    /// one parameter vocabulary. Only *explicitly given* values become
    /// overrides: the built-in `nprobe` default must not shadow index
    /// defaults such as a factory string's trailing `nprobe=8`
    /// (`backend` is `None` unless given, so it needs no flag).
    pub fn search_params(&self) -> SearchParams {
        let mut p = SearchParams::new();
        if self.nprobe_explicit && self.nprobe > 0 {
            p.nprobe = Some(self.nprobe);
        }
        p.backend = self.backend;
        p
    }

    /// The storage [`OpenOptions`] this config implies for loading a saved
    /// index: the factory string's trailing `mmap=`/`budget_mb=` keys as
    /// the base, explicitly-given config/CLI values on top (same
    /// precedence story as `nprobe`).
    pub fn open_options(&self) -> Result<OpenOptions> {
        let mut o = crate::index::factory::spec_open_options(&self.factory)?;
        if let Some(mmap) = self.mmap {
            o.mmap = mmap;
        }
        if let Some(mb) = self.budget_mb {
            o.budget_mb = Some(mb);
        }
        Ok(o)
    }

    /// defaults < optional `--config <file>` < CLI flags.
    pub fn from_args(args: &Args) -> Result<Self> {
        let mut cfg = Config::new();
        if let Some(path) = args.get_opt("config") {
            cfg.merge(&Config::from_file(std::path::Path::new(&path))?);
        }
        let d = ExperimentConfig::default();
        let backend = match args.get_opt("backend").or_else(|| cfg.get("backend").map(String::from))
        {
            None => None,
            Some(name) => Some(Backend::parse(&name).ok_or_else(|| {
                Error::Config(format!("backend expects portable|ssse3|neon, got {name:?}"))
            })?),
        };
        // `--width` may be a sweep list for the bench commands ("2,4,8");
        // every entry is validated here, `width` is the first, and the
        // bench commands iterate `widths`.
        let widths = match args.get_opt("width").or_else(|| cfg.get("width").map(String::from)) {
            None => vec![d.width],
            Some(s) => s
                .split(',')
                .map(|part| {
                    let bits: usize = part.trim().parse().map_err(|_| {
                        Error::Config(format!("width expects 2|4|8, got {s:?}"))
                    })?;
                    CodeWidth::from_bits(bits)
                        .ok_or_else(|| Error::Config(format!("width expects 2|4|8, got {bits}")))
                })
                .collect::<Result<Vec<_>>>()?,
        };
        let width = widths[0];
        // `--mmap` is a bare flag or `--mmap true/false`; the config-file
        // key is `mmap = true`. `None` = not given (factory keys rule).
        let mmap = match args.get_opt("mmap").or_else(|| cfg.get("mmap").map(String::from)) {
            Some(v) => match v.as_str() {
                "true" | "1" | "yes" => Some(true),
                "false" | "0" | "no" => Some(false),
                _ => return Err(Error::Config(format!("mmap expects bool, got {v:?}"))),
            },
            None if args.get_flag("mmap") => Some(true),
            None => None,
        };
        let budget_mb = match args
            .get_opt("budget-mb")
            .or_else(|| cfg.get("budget_mb").map(String::from))
        {
            None => None,
            Some(v) => Some(v.replace('_', "").parse::<u64>().map_err(|_| {
                Error::Config(format!("budget_mb expects integer MiB, got {v:?}"))
            })?),
        };
        Ok(Self {
            dataset: args.get_str("dataset", &cfg.get_str("dataset", &d.dataset)),
            n: args.get_usize("n", cfg.get_usize("n", d.n)?),
            nq: args.get_usize("nq", cfg.get_usize("nq", d.nq)?),
            seed: args.get_u64("seed", cfg.get_usize("seed", d.seed as usize)? as u64),
            factory: args.get_str("factory", &cfg.get_str("factory", &d.factory)),
            k: args.get_usize("k", cfg.get_usize("k", d.k)?),
            nprobe: args.get_usize("nprobe", cfg.get_usize("nprobe", d.nprobe)?),
            nprobe_explicit: args.get_opt("nprobe").is_some() || cfg.get("nprobe").is_some(),
            trials: args.get_usize("trials", cfg.get_usize("trials", d.trials)?),
            backend,
            width,
            widths,
            mmap,
            budget_mb,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_ini_subset() {
        let cfg = Config::from_str(
            "# comment\n\
             n = 1000\n\
             dataset = deep  # trailing comment\n\
             [server]\n\
             port = 7070\n\
             batch = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get_usize("n", 0).unwrap(), 1000);
        assert_eq!(cfg.get_str("dataset", ""), "deep");
        assert_eq!(cfg.get_usize("server.port", 0).unwrap(), 7070);
        assert!(cfg.get_bool("server.batch", false).unwrap());
    }

    #[test]
    fn rejects_bad_lines_and_types() {
        assert!(Config::from_str("no equals sign").is_err());
        let cfg = Config::from_str("x = abc").unwrap();
        assert!(cfg.get_usize("x", 0).is_err());
        assert!(cfg.get_bool("x", false).is_err());
        assert_eq!(cfg.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn merge_precedence() {
        let mut a = Config::from_str("n = 1\nk = 2").unwrap();
        let b = Config::from_str("n = 10").unwrap();
        a.merge(&b);
        assert_eq!(a.get_usize("n", 0).unwrap(), 10);
        assert_eq!(a.get_usize("k", 0).unwrap(), 2);
    }

    #[test]
    fn experiment_from_cli() {
        let args = Args::parse(
            ["--n", "5000", "--factory", "IVF10,PQ8x4fs"].iter().map(|s| s.to_string()),
        );
        let e = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(e.n, 5000);
        assert_eq!(e.factory, "IVF10,PQ8x4fs");
        assert_eq!(e.nq, 100); // default preserved
    }

    #[test]
    fn search_params_only_from_explicit_values() {
        // built-in default nprobe must NOT become a per-request override
        let implicit = ExperimentConfig::from_args(&Args::parse(Vec::<String>::new())).unwrap();
        assert!(!implicit.nprobe_explicit);
        assert_eq!(implicit.search_params(), SearchParams::new());
        // an explicit CLI flag does
        let args = Args::parse(["--nprobe", "8"].iter().map(|s| s.to_string()));
        let explicit = ExperimentConfig::from_args(&args).unwrap();
        assert!(explicit.nprobe_explicit);
        assert_eq!(explicit.search_params().nprobe, Some(8));
    }

    #[test]
    fn underscored_numbers() {
        let cfg = Config::from_str("n = 1_000_000").unwrap();
        assert_eq!(cfg.get_usize("n", 0).unwrap(), 1_000_000);
    }

    #[test]
    fn width_parsed_and_validated() {
        let none = ExperimentConfig::from_args(&Args::parse(Vec::<String>::new())).unwrap();
        assert_eq!(none.width, CodeWidth::W4);
        for (s, want) in [("2", CodeWidth::W2), ("4", CodeWidth::W4), ("8", CodeWidth::W8)] {
            let args = Args::parse(["--width", s].iter().map(|x| x.to_string()));
            assert_eq!(ExperimentConfig::from_args(&args).unwrap().width, want);
        }
        let bad = Args::parse(["--width", "3"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::from_args(&bad).is_err());
        // a sweep list: scalar = first entry, `widths` carries the lot —
        // from CLI and from a config file alike
        let list = Args::parse(["--width", "2,4,8"].iter().map(|s| s.to_string()));
        let parsed = ExperimentConfig::from_args(&list).unwrap();
        assert_eq!(parsed.width, CodeWidth::W2);
        assert_eq!(parsed.widths, vec![CodeWidth::W2, CodeWidth::W4, CodeWidth::W8]);
        // every entry is validated, not just the first
        let badlist = Args::parse(["--width", "2,5"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::from_args(&badlist).is_err());
        // config-file key works too
        let mut cfg = Config::new();
        cfg.set("width", "8");
        assert_eq!(cfg.get_usize("width", 4).unwrap(), 8);
    }

    #[test]
    fn storage_open_options_from_cli_and_factory() {
        // not given: heap open, no budget
        let none = ExperimentConfig::from_args(&Args::parse(Vec::<String>::new())).unwrap();
        assert_eq!(none.open_options().unwrap(), OpenOptions::heap());
        // bare `--mmap` flag turns mapping on
        let args = Args::parse(["--mmap", "--budget-mb", "128"].iter().map(|s| s.to_string()));
        let e = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(e.mmap, Some(true));
        assert_eq!(e.budget_mb, Some(128));
        assert_eq!(
            e.open_options().unwrap(),
            OpenOptions { mmap: true, budget_mb: Some(128) }
        );
        // the factory string's trailing keys apply when the CLI is silent…
        let args = Args::parse(
            ["--factory", "PQ8x4fs,mmap=true,budget_mb=64"].iter().map(|s| s.to_string()),
        );
        let e = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(
            e.open_options().unwrap(),
            OpenOptions { mmap: true, budget_mb: Some(64) }
        );
        // …and an explicit CLI value wins over them
        let args = Args::parse(
            ["--factory", "PQ8x4fs,mmap=true", "--mmap", "false"].iter().map(|s| s.to_string()),
        );
        let e = ExperimentConfig::from_args(&args).unwrap();
        assert_eq!(e.open_options().unwrap(), OpenOptions::heap());
        // bad values are config errors
        let bad = Args::parse(["--mmap", "maybe"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::from_args(&bad).is_err());
        let bad = Args::parse(["--budget-mb", "lots"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::from_args(&bad).is_err());
    }

    #[test]
    fn backend_override_parsed_and_validated() {
        let none = ExperimentConfig::from_args(&Args::parse(Vec::<String>::new())).unwrap();
        assert_eq!(none.backend, None);
        for (name, want) in
            [("portable", Backend::Portable), ("ssse3", Backend::Ssse3), ("neon", Backend::Neon)]
        {
            let args =
                Args::parse(["--backend", name].iter().map(|s| s.to_string()));
            assert_eq!(ExperimentConfig::from_args(&args).unwrap().backend, Some(want));
        }
        let bad = Args::parse(["--backend", "avx512"].iter().map(|s| s.to_string()));
        assert!(ExperimentConfig::from_args(&bad).is_err());
    }
}
