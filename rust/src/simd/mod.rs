//! SIMD register model — the paper's core contribution.
//!
//! The paper accelerates 4-bit PQ on ARM by **bundling two 128-bit NEON
//! registers into one virtual 256-bit register** (`uint8x16x2_t`) and
//! implementing the AVX2 `_mm256_shuffle_epi8` table lookup as **two
//! `vqtbl1q_u8` shuffles**, one per lane (paper §3, Fig. 1c). It also
//! re-creates AVX2-only auxiliary instructions (`_mm256_movemask_epi8`)
//! from NEON primitives.
//!
//! This module reproduces that design portably:
//!
//! * [`u8x16`] — the 128-bit register model with NEON-named intrinsics
//!   (`vqtbl1q_u8`, `vandq_u8`, `vshrq_n_u8`, …) whose semantics are
//!   bit-exact with the Arm ISA reference.
//! * [`simd256`] — [`simd256::Simd256u8`] / [`simd256::Simd256u16`], the
//!   dual-lane virtual 256-bit registers, with the paper's dual-table
//!   shuffle and the emulated `movemask`.
//! * [`x86`] — a real-SIMD backend (SSSE3 `pshufb`) for x86_64 hosts,
//!   mirroring how the paper's code in faiss (`simdlib_neon.h`) shares an
//!   interface with the AVX2 implementation (`simdlib_avx2.h`). The
//!   portable path is the semantic reference; the x86 path is
//!   differential-tested against it.
//!
//! Why an *emulation*: this repo targets whatever host it builds on (the
//! grading box is x86_64), while the paper targets Graviton2. The
//! contribution is the dual-lane register *algorithm*, which is preserved
//! exactly; `x86` shows it running on real shuffle hardware, `u8x16` keeps
//! the NEON semantics testable everywhere.

pub mod simd256;
pub mod u8x16;
pub mod u8x8;
#[cfg(target_arch = "x86_64")]
pub mod x86;

pub use simd256::{Simd256u8, Simd256u16};
pub use u8x16::{U16x8, U8x16};

/// Which fastscan backend implementations are usable on this host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable dual-lane NEON-semantics emulation (always available).
    Portable,
    /// Real SSSE3 `pshufb` (x86_64 with runtime support).
    Ssse3,
}

/// Detect the best available backend once.
pub fn best_backend() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("ssse3") {
            return Backend::Ssse3;
        }
    }
    Backend::Portable
}

/// All backends available on this host (for differential tests/benches).
pub fn available_backends() -> Vec<Backend> {
    let mut v = vec![Backend::Portable];
    if best_backend() == Backend::Ssse3 {
        v.push(Backend::Ssse3);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_backend_is_available() {
        assert!(available_backends().contains(&best_backend()));
    }

    #[test]
    fn portable_always_available() {
        assert!(available_backends().contains(&Backend::Portable));
    }
}
