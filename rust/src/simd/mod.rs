//! SIMD register model and backends — the paper's core contribution.
//!
//! The paper accelerates 4-bit PQ on ARM by **bundling two 128-bit NEON
//! registers into one virtual 256-bit register** (`uint8x16x2_t`) and
//! implementing the AVX2 `_mm256_shuffle_epi8` table lookup as **two
//! `vqtbl1q_u8` shuffles**, one per lane (paper §3, Fig. 1c). It also
//! re-creates AVX2-only auxiliary instructions (`_mm256_movemask_epi8`)
//! from NEON primitives.
//!
//! ## The width × backend matrix
//!
//! The scan kernel is generalized over two independent axes. The
//! **backend** axis picks the shuffle hardware:
//!
//! | backend              | hardware            | role                                     |
//! |----------------------|---------------------|------------------------------------------|
//! | [`Backend::Portable`]| any                 | scalar *model* of the NEON ISA; the semantic reference every real backend is differential-tested against |
//! | [`Backend::Ssse3`]   | x86_64 with SSSE3   | real 128-bit shuffle hardware (`pshufb`), mirrors faiss `simdlib_avx2.h` vs `simdlib_neon.h` sharing one interface |
//! | [`Backend::Neon`]    | aarch64             | the paper's actual target: real `vqtbl1q_u8` dual-table shuffle, `vshrn`-based movemask emulation |
//!
//! The **width** axis ([`crate::pq::CodeWidth`], Quicker-ADC style) picks
//! how many bits each PQ code spends, all expressed in the same 16-entry
//! dual-table shuffle: 2-bit fuses sub-quantizer pairs into one sum-table
//! (≈½ the scan cost of 4-bit), 4-bit is the paper's kernel, 8-bit does
//! paired low/high-nibble half-space lookups (≈2× the cost, finer codes).
//! Every backend serves every width — the wiring difference lives in
//! [`crate::pq::fastscan::LaneWiring`], not in this module's register
//! model.
//!
//! Modules:
//!
//! * [`u8x16`] — the 128-bit register model with NEON-named intrinsics
//!   (`vqtbl1q_u8`, `vandq_u8`, `vshrq_n_u8`, …) whose semantics are
//!   bit-exact with the Arm ISA reference.
//! * [`simd256`] — [`simd256::Simd256u8`] / [`simd256::Simd256u16`], the
//!   dual-lane virtual 256-bit registers, with the paper's dual-table
//!   shuffle and the emulated `movemask` (portable backend).
//! * [`u8x8`] — the ARMv7 64-bit D-register fallback model (`vtbl2_u8`).
//! * [`x86`] — real-SIMD SSSE3 implementation (x86_64 only).
//! * [`neon`] — real-SIMD NEON implementation (aarch64 only) built on
//!   `core::arch::aarch64` intrinsics.
//!
//! The differential tests (`backends_agree_exactly`,
//! `kernel_matches_scalar_sum_all_widths`,
//! `reservoir_contents_bit_identical_across_backends_per_width` in
//! [`crate::pq::fastscan`], plus the `width_*` integration tests run as
//! named CI steps) exercise Portable vs whichever real backend the host
//! offers, at every code width: Portable vs Ssse3 on the x86_64 CI job,
//! Portable vs Neon on the aarch64 (cross/QEMU) CI job. On a host with
//! neither, only the portable model runs and the cross-checks skip.

pub mod simd256;
pub mod u8x16;
pub mod u8x8;
#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

pub use simd256::{Simd256u8, Simd256u16};
pub use u8x16::{U16x8, U8x16};

/// Which fastscan backend implementations are usable on this host.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable dual-lane NEON-semantics emulation (always available).
    Portable,
    /// Real SSSE3 `pshufb` (x86_64 with runtime support).
    Ssse3,
    /// Real ARM NEON `vqtbl1q_u8` (aarch64; the paper's target ISA).
    Neon,
}

impl Backend {
    /// Stable lowercase name (CLI flags, config keys, `set_param`).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Portable => "portable",
            Backend::Ssse3 => "ssse3",
            Backend::Neon => "neon",
        }
    }

    /// Parse a backend name as accepted by `--backend` / `set_param`.
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "portable" => Some(Backend::Portable),
            "ssse3" => Some(Backend::Ssse3),
            "neon" => Some(Backend::Neon),
            _ => None,
        }
    }

    /// Whether this backend can run on the current host.
    pub fn is_available(self) -> bool {
        available_backends().contains(&self)
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Detect the best available backend once.
pub fn best_backend() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("ssse3") {
            return Backend::Ssse3;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON (ASIMD) is architecturally mandatory in AArch64; the runtime
        // check keeps the gate explicit and mirrors the x86 path.
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Backend::Neon;
        }
    }
    Backend::Portable
}

/// All backends available on this host (for differential tests/benches).
pub fn available_backends() -> Vec<Backend> {
    let mut v = vec![Backend::Portable];
    let best = best_backend();
    if best != Backend::Portable {
        v.push(best);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_backend_is_available() {
        assert!(available_backends().contains(&best_backend()));
    }

    #[test]
    fn portable_always_available() {
        assert!(available_backends().contains(&Backend::Portable));
    }

    #[test]
    fn name_parse_roundtrip() {
        for b in [Backend::Portable, Backend::Ssse3, Backend::Neon] {
            assert_eq!(Backend::parse(b.name()), Some(b));
            assert_eq!(format!("{b}"), b.name());
        }
        assert_eq!(Backend::parse("avx512"), None);
    }

    #[test]
    fn real_backend_matches_host_arch() {
        for b in available_backends() {
            match b {
                Backend::Portable => {}
                Backend::Ssse3 => assert!(cfg!(target_arch = "x86_64")),
                Backend::Neon => assert!(cfg!(target_arch = "aarch64")),
            }
        }
    }
}
